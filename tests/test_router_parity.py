"""Device/host router parity on edge-case keys.

``shard_of_keys`` (jnp) and ``shard_of_keys_host`` / ``route_keys_host``
(numpy) are deliberately duplicated implementations of the same
multiplicative hash — the sharded runtime's crash bookkeeping, oracles and
drivers all assume they agree bit-for-bit.  This pins the contract on the
keys where integer-width coercion could silently diverge: 0, negatives,
values at and past 2^31, values past 2^32, and mixed input dtypes.  The
invariant is that both sides hash the key's residue mod 2^32.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.runtime.dfc_shard import (
    route_keys_host,
    shard_of_keys,
    shard_of_keys_host,
)

jax.config.update("jax_platform_name", "cpu")

EDGE_KEYS = [
    0,
    1,
    7,
    -1,
    -7,
    -(2**16),
    2**16,
    2**31 - 1,
    2**31,  # wraps to i32 min on device, uint32 2^31 on host — same residue
    2**31 + 12345,
    2**32 - 1,
    2**32,  # residue 0
    2**32 + 99,
    -(2**31),
    5_000_000_000,
]


def _as_dtype(keys, dtype):
    return np.asarray(keys, dtype=np.int64).astype(dtype)


@pytest.mark.parametrize("n_shards", [1, 2, 3, 7, 16])
@pytest.mark.parametrize("dtype", [np.int32, np.int64, np.uint32, np.uint64])
def test_shard_of_keys_device_host_parity(n_shards, dtype):
    if np.issubdtype(dtype, np.unsignedinteger):
        keys = [k for k in EDGE_KEYS if k >= 0]
    else:
        keys = EDGE_KEYS
    host_in = _as_dtype(keys, dtype)
    dev = np.asarray(shard_of_keys(jnp.asarray(host_in), n_shards))
    host = shard_of_keys_host(host_in, n_shards)
    np.testing.assert_array_equal(dev, host)
    assert host.dtype == np.int32 and dev.dtype == np.int32
    assert (host >= 0).all() and (host < n_shards).all()


def test_hash_is_residue_mod_2_32():
    """Keys equal mod 2^32 must route identically — the width contract both
    implementations rely on (int64 -> {int32, uint32} coercions agree)."""
    base = np.asarray([0, 1, 12345, 2**31 - 1], np.int64)
    for offset in (2**32, -(2**32), 3 * 2**32):
        shifted = base + offset
        np.testing.assert_array_equal(
            shard_of_keys_host(base, 13), shard_of_keys_host(shifted, 13)
        )
        np.testing.assert_array_equal(
            np.asarray(shard_of_keys(jnp.asarray(base), 13)),
            np.asarray(shard_of_keys(jnp.asarray(shifted), 13)),
        )


@pytest.mark.parametrize("dtype", [np.int32, np.int64])
def test_route_keys_host_table_parity(dtype):
    """The table-driven host router agrees with the device path (bucket hash
    + table lookup) on the same edge keys, including non-identity tables."""
    rng = np.random.default_rng(0)
    n_buckets, n_shards = 24, 5
    table = rng.integers(0, n_shards, n_buckets).astype(np.int32)
    keys = _as_dtype(EDGE_KEYS, dtype)
    host = route_keys_host(keys, n_shards, table)
    dev_buckets = np.asarray(shard_of_keys(jnp.asarray(keys), n_buckets))
    dev = table[dev_buckets]
    np.testing.assert_array_equal(host, dev)
    # identity table == plain hash (the PR-2 router, bit-for-bit)
    np.testing.assert_array_equal(
        route_keys_host(keys, n_shards, None),
        shard_of_keys_host(keys, n_shards),
    )


def test_mixed_dtype_batches_agree():
    """One flat batch announced with mixed host dtypes routes identically
    however the driver happened to build its arrays."""
    k64 = np.asarray([3, -9, 2**31 + 5, 2**32 + 17], np.int64)
    k32 = k64.astype(np.int32)  # wraps, same residue mod 2^32
    u32 = k64.astype(np.uint32)
    a = shard_of_keys_host(k64, 11)
    b = shard_of_keys_host(k32, 11)
    c = shard_of_keys_host(u32, 11)
    d = np.asarray(shard_of_keys(jnp.asarray(k32), 11))
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(b, c)
    np.testing.assert_array_equal(c, d)
