"""Baseline PTM stacks: sanity + the paper's qualitative ordering claims."""

import pytest

from repro.core.dfc import POP, PUSH
from repro.core.baselines import (
    OneFileStack,
    PMDKStack,
    RomulusStack,
    make_workloads,
    run_dfc_counts,
)


def _counts(cls, n, kind="push-pop", total=200):
    w = make_workloads(kind, n, total)
    st = cls(n).run(w)
    return st


def test_pmdk_flat_in_threads():
    a = _counts(PMDKStack, 1).pwb_per_op()
    b = _counts(PMDKStack, 16).pwb_per_op()
    assert abs(a - b) < 0.2


def test_romulus_amortizes_with_combining():
    a = _counts(RomulusStack, 1).pwb_per_op()
    b = _counts(RomulusStack, 32).pwb_per_op()
    assert b < a  # state-flip cost amortized over the batch


def test_onefile_grows_with_contention():
    a = _counts(OneFileStack, 1).pwb_per_op()
    b = _counts(OneFileStack, 32).pwb_per_op()
    assert b > 2 * a  # helping amplification


def test_paper_ordering_at_high_concurrency():
    """Fig 3b at 40 threads: DFC-combiner < Romulus < OneFile; PMDK worst of
    the fence-per-op world and flat."""
    n, total = 40, 400
    w = make_workloads("push-pop", n, total)
    dfc = run_dfc_counts(n, w)
    dfc_combiner_pwb = dfc["pwb_combine"] / dfc["ops"]
    dfc_total_pwb = (dfc["pwb_combine"] + dfc["pwb_announce"]) / dfc["ops"]
    rom = _counts(RomulusStack, n, total=total).pwb_per_op()
    one = _counts(OneFileStack, n, total=total).pwb_per_op()
    assert dfc_combiner_pwb < rom < one
    assert dfc_total_pwb < one


def test_counts_similar_across_workloads():
    """Paper Fig 3e/3f: all algorithms keep roughly the same per-op
    persistence counts on push-pop vs rand-op (the rand-op throughput drop is
    a phase-dynamics effect, not a count effect)."""
    n, total = 16, 1600
    pp = run_dfc_counts(n, make_workloads("push-pop", n, total), seed=1, think=(0, 30))
    ro = run_dfc_counts(n, make_workloads("rand-op", n, total), seed=1, think=(0, 30))
    pp_rate = (pp["pwb_combine"] + pp["pwb_announce"]) / pp["ops"]
    ro_rate = (ro["pwb_combine"] + ro["pwb_announce"]) / ro["ops"]
    assert abs(pp_rate - ro_rate) / pp_rate < 0.10


def test_elimination_is_batch_composition_property():
    """Balanced batches eliminate fully (no stack traffic); imbalanced
    batches pay one node pwb per surplus push — checked via combiner pwbs."""
    n = 8
    # perfectly mixed single batch: half push, half pop
    w_bal = [[(PUSH, 100 + t)] if t < n // 2 else [(POP, None)] for t in range(n)]
    c_bal = run_dfc_counts(n, w_bal, seed=2)
    # all-push single batch: every op allocates + persists a node
    w_push = [[(PUSH, 200 + t)] for t in range(n)]
    c_push = run_dfc_counts(n, w_push, seed=2)
    assert c_push["pwb_combine"] > c_bal["pwb_combine"]
