"""Heterogeneous DFC fabric + crash-consistent resharding.

Covers the PR-3 acceptance criteria: a mixed stack/queue/deque fabric matches
the per-shard sequential oracles on the vmap and Pallas backends (including
mixed-kind batches sharing lanes and R_OVERFLOW isolation across kinds), and
a crash injected at EVERY persistence op of a shard split / merge recovers
with correct per-op detectability verdicts and no lost or duplicated ops.
"""

import numpy as np
import pytest

import jax

from repro.checkpoint.dfc_checkpoint import CrashNow, FaultInjector, SimFS
from repro.core.jax_dfc import (
    KIND_CODES,
    OP_ENQ,
    OP_PUSH,
    OP_PUSHR,
    R_ACK,
    R_NONE,
    R_VALUE,
    STRUCTS,
)
from repro.runtime.dfc_shard import (
    R_OVERFLOW,
    ShardedDFCRuntime,
    route_keys_host,
    sequential_hetero_reference,
)

jax.config.update("jax_platform_name", "cpu")

MIXED = ["stack", "queue", "deque", "queue", "stack", "deque"]
S, CAP, LANES = len(MIXED), 256, 16


def _mixed_batch(rng, kinds, table, n, universe=1000):
    """Random flat batch whose op codes are valid for each key's target
    structure (codes are interpreted by the routed shard's kind)."""
    keys = rng.integers(0, universe, n)
    shard = route_keys_host(keys, len(kinds), table)
    opmax = [STRUCTS[k].n_opcodes for k in kinds]
    ops = np.asarray([rng.integers(0, opmax[s]) for s in shard], np.int32)
    params = (rng.random(n) * 100).round(2).astype(np.float32)
    return keys, ops, params


# =========================================================== mixed-kind fabric
@pytest.mark.parametrize("backend", ["jnp", "ref", "pallas"])
def test_mixed_fabric_matches_oracle(backend):
    """Acceptance: mixed stack/queue/deque shards behind one router match the
    per-shard sequential oracles on every backend, over randomized phases."""
    rng = np.random.default_rng(hash(backend) % 2**32)
    rt = ShardedDFCRuntime(MIXED, S, CAP, LANES, backend=backend, n_buckets=24)
    oracle = [[] for _ in range(S)]
    for _ in range(4):
        keys, ops, params = _mixed_batch(rng, rt.kinds, rt.table, 40)
        resp, kinds = rt.step(keys, ops, params)
        eresp, ekinds = sequential_hetero_reference(
            rt.kinds, oracle, keys, ops.tolist(), params.tolist(), LANES,
            table=rt.table,
        )
        np.testing.assert_array_equal(np.asarray(kinds), ekinds)
        np.testing.assert_allclose(
            np.asarray(resp), np.asarray(eresp, np.float32), rtol=1e-6
        )
    for s in range(S):
        np.testing.assert_allclose(rt.shard_contents(s), oracle[s])
    assert all(e % 2 == 0 for e in rt.shard_epochs())


def test_mixed_kind_batch_same_lane():
    """Ops of different kinds land on lane 0 of their shards in ONE batch;
    each is interpreted by its target structure (code 3 is OP_PUSHR on the
    deque and nothing on a stack/queue)."""
    rt = ShardedDFCRuntime(MIXED, S, CAP, LANES, n_buckets=24)
    k_stack = rt.key_for_shard(MIXED.index("stack"))
    k_queue = rt.key_for_shard(MIXED.index("queue"))
    k_deque = rt.key_for_shard(MIXED.index("deque"))
    keys = [k_stack, k_queue, k_deque]
    resp, kinds = rt.step(keys, [OP_PUSH, OP_ENQ, OP_PUSHR], [1.0, 2.0, 3.0])
    assert list(np.asarray(kinds)) == [R_ACK, R_ACK, R_ACK]
    assert rt.shard_contents(MIXED.index("stack")) == [1.0]
    assert rt.shard_contents(MIXED.index("queue")) == [2.0]
    assert rt.shard_contents(MIXED.index("deque")) == [3.0]
    # pop each back: codes 2 (pop/deq/popL) — deque popL returns the value too
    resp, kinds = rt.step(keys, [2, 2, 2], [0.0, 0.0, 0.0])
    assert list(np.asarray(kinds)) == [R_VALUE] * 3
    np.testing.assert_allclose(np.asarray(resp), [1.0, 2.0, 3.0])


def test_opcode_invalid_for_kind_is_noop():
    """A deque-only op code routed to a stack shard answers R_NONE and
    leaves the stack's contents untouched."""
    rt = ShardedDFCRuntime(MIXED, S, CAP, LANES, n_buckets=24)
    s_stack = MIXED.index("stack")
    key = rt.key_for_shard(s_stack)
    rt.step([key], [OP_PUSH], [7.0])
    resp, kinds = rt.step([key], [OP_PUSHR], [9.0])  # code 3: not a stack op
    assert list(np.asarray(kinds)) == [R_NONE]
    assert rt.shard_contents(s_stack) == [7.0]


def test_overflow_on_one_kind_isolated_from_neighbors():
    """R_OVERFLOW on a hot deque shard does not perturb stack/queue
    neighbors combined in the same fused phase."""
    rt = ShardedDFCRuntime(MIXED, S, CAP, lanes=4, n_buckets=24)
    s_deque = MIXED.index("deque")
    s_stack = MIXED.index("stack")
    s_queue = MIXED.index("queue")
    k_d = rt.key_for_shard(s_deque)
    k_s = rt.key_for_shard(s_stack)
    k_q = rt.key_for_shard(s_queue)
    keys = [k_d] * 7 + [k_s, k_q]
    ops = [OP_PUSHR] * 7 + [OP_PUSH, OP_ENQ]
    params = [float(i) for i in range(1, 10)]
    resp, kinds = rt.step(keys, ops, params)
    kinds = list(np.asarray(kinds))
    assert kinds[:4] == [R_ACK] * 4
    assert kinds[4:7] == [R_OVERFLOW] * 3  # the spill is rejected...
    assert kinds[7:] == [R_ACK, R_ACK]  # ...and neighbors of other kinds land
    assert rt.shard_contents(s_deque) == [1.0, 2.0, 3.0, 4.0]
    assert rt.shard_contents(s_stack) == [8.0]
    assert rt.shard_contents(s_queue) == [9.0]
    # overflow left no trace on any kind: re-announcing applies exactly once
    resp2, kinds2 = rt.step([k_d] * 3, [OP_PUSHR] * 3, [5.0, 6.0, 7.0])
    assert list(np.asarray(kinds2)) == [R_ACK] * 3
    assert rt.shard_contents(s_deque) == [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]


def test_meta_kind_column_and_per_kind_phases():
    """The per-shard kind column is part of the runtime metadata, and phases
    advance only for touched shards across kind groups."""
    rt = ShardedDFCRuntime(MIXED, S, CAP, LANES, n_buckets=24)
    np.testing.assert_array_equal(
        np.asarray(rt.meta["kind"]), [KIND_CODES[k] for k in MIXED]
    )
    s_queue = MIXED.index("queue")
    key = rt.key_for_shard(s_queue)
    rt.step([key], [OP_ENQ], [1.0])
    phases = np.asarray(rt.meta["phases"])
    assert phases[s_queue] == 1 and phases.sum() == 1


# ================================================================= resharding
def test_split_moves_buckets_and_relieves_overflow():
    rt = ShardedDFCRuntime("queue", 2, CAP, lanes=4, n_buckets=16)
    # find a shard and a batch of distinct-bucket keys that overflow it
    donor = 0
    keys = [rt.key_for_shard(donor, start=i * 5000) for i in range(6)]
    resp, kinds = rt.step(keys, [OP_ENQ] * 6, [float(i) for i in range(6)])
    assert list(np.asarray(kinds)).count(R_OVERFLOW) == 2
    pre_contents = rt.shard_contents(donor)
    new_id = rt.split_shard(donor)
    assert rt.n_shards == 3 and rt.kinds[new_id] == "queue"
    assert rt.shard_contents(donor) == pre_contents  # donor keeps its values
    assert rt.shard_contents(new_id) == []
    # the donor's buckets are now spread across donor + new shard
    spread = set(route_keys_host(np.asarray(keys), rt.n_shards, rt.table))
    assert spread == {donor, new_id}
    # the same hot batch no longer overflows after the split
    resp, kinds = rt.step(keys, [OP_ENQ] * 6, [10.0 + i for i in range(6)])
    assert R_OVERFLOW not in list(np.asarray(kinds))


def test_split_requires_spare_bucket_and_merge_same_kind():
    rt = ShardedDFCRuntime(["stack", "queue"], 2, CAP, LANES)  # 1 bucket each
    with pytest.raises(ValueError, match="bucket"):
        rt.split_shard(0)
    with pytest.raises(ValueError, match="kind mismatch"):
        rt.merge_shards(0, 1)
    with pytest.raises(ValueError, match="itself"):
        rt.merge_shards(1, 1)


@pytest.mark.parametrize("kind", ["stack", "queue", "deque"])
def test_merge_appends_contents(kind):
    rt = ShardedDFCRuntime(kind, 2, CAP, LANES, n_buckets=8)
    push = {"stack": OP_PUSH, "queue": OP_ENQ, "deque": OP_PUSHR}[kind]
    for s, vals in ((0, [1.0, 2.0]), (1, [3.0, 4.0])):
        key = rt.key_for_shard(s)
        rt.step([key] * 2, [push] * 2, vals)
    rt.merge_shards(1, 0)
    assert rt.shard_contents(0) == [1.0, 2.0, 3.0, 4.0]
    assert rt.shard_contents(1) == []
    assert set(rt.table.tolist()) == {0}


def test_recover_topology_from_durable_routing_record(tmp_path):
    """Recovery adopts the committed routing record (kinds, table, shard
    count) even when called with stale bootstrap arguments."""
    fs = SimFS(tmp_path)
    rt = ShardedDFCRuntime(
        ["queue", "stack"], 2, CAP, LANES, fs=fs, n_threads=1, n_buckets=8
    )
    rt.announce(0, [rt.key_for_shard(0)] * 2, [OP_ENQ] * 2, [5.0, 6.0], token=1)
    rt.combine_phase()
    rt.split_shard(0)
    rt2, _ = ShardedDFCRuntime.recover(
        fs.crash(), kind="deque", n_shards=1, capacity=CAP, lanes=LANES
    )
    assert rt2.n_shards == 3
    assert rt2.kinds == ["queue", "stack", "queue"]
    assert rt2.n_buckets == 8
    np.testing.assert_array_equal(rt2.table, rt.table)
    assert rt2.r_epoch == 2
    assert rt2.shard_contents(0) == [5.0, 6.0]


# ====================================================== reshard crash sweeps
PUSH_OF = {"stack": OP_PUSH, "queue": OP_ENQ, "deque": OP_PUSHR}


def _drive_phase(rt, token, keys, ops, params):
    rt.announce(0, keys, ops, params, token=token)
    rt.combine_phase()


def _reshard_crash_scenario(tmp, crash_at, reshard, kinds, n_buckets):
    """Insert-only workload around a reshard, with a crash at persistence op
    ``crash_at``; returns (rt2, report, phases, value->op-index map)."""
    inj = FaultInjector(crash_at=crash_at)
    fs = SimFS(tmp, inj)
    n_shards = len(kinds)
    rt = ShardedDFCRuntime(
        kinds, n_shards, CAP, LANES, fs=fs, n_threads=1, n_buckets=n_buckets
    )
    rng = np.random.default_rng(7)
    phases = []  # (token, keys, ops, params)
    val = 1.0

    def batch(token, n):
        nonlocal val
        keys = rng.integers(0, 1000, n)
        ops = [PUSH_OF[kinds[0]]] * n  # insert-only (kinds here share codes)
        params = [val + i for i in range(n)]
        val += n
        phases.append((token, [int(k) for k in keys], ops, params))
        return keys, ops, params

    try:
        _drive_phase(rt, 1, *batch(1, 8))
        reshard(rt)
        _drive_phase(rt, 2, *batch(2, 8))
    except CrashNow:
        pass  # phases[] records what the driver must re-drive post-recovery
    rt2, report = ShardedDFCRuntime.recover(
        fs.crash(), kind=kinds, n_shards=n_shards, capacity=CAP, lanes=LANES,
        n_threads=1, n_buckets=n_buckets,
    )
    return rt2, report, phases, inj.count


def _verify_exactly_once(rt2, report, phases):
    """Replay the not-applied ops, re-drive never-surfaced announcements,
    and check every announced value lives in the fabric exactly once."""
    assert all(int(e) % 2 == 0 for e in rt2.shard_epochs())
    assert rt2.r_epoch % 2 == 0
    # pre-replay: nothing is duplicated, and every applied verdict's value
    # is already present
    contents = sorted(sum((rt2.shard_contents(s) for s in range(rt2.n_shards)), []))
    assert len(contents) == len(set(contents)), "duplicated op after recovery"
    surfaced = report[0]["token"]
    if surfaced is not None:
        tok, keys, ops, params = phases[surfaced - 1]
        for i, v in enumerate(report[0]["ops"]):
            if v.applied:
                assert params[i] in contents
    rt2.replay_pending(report)
    last = surfaced or 0
    for tok, keys, ops, params in phases[last:]:
        _drive_phase(rt2, tok, keys, ops, params)
    expect = sorted(p for _, _, _, ps in phases for p in ps)
    got = sorted(sum((rt2.shard_contents(s) for s in range(rt2.n_shards)), []))
    assert got == expect, "lost or duplicated ops across the reshard crash"


def test_split_crash_sweep_exactly_once(tmp_path):
    """Acceptance: a crash at EVERY persistence op of a shard split recovers
    with correct verdicts and no lost or duplicated ops."""
    kinds = ["queue", "queue"]

    def reshard(rt):
        rt.split_shard(int(np.argmax(rt.shard_sizes())))

    _, _, _, total = _reshard_crash_scenario(
        tmp_path / "dry", None, reshard, kinds, 8
    )
    assert total > 40
    for k in range(1, total + 1):
        rt2, report, phases, _ = _reshard_crash_scenario(
            tmp_path / f"k{k}", k, reshard, kinds, 8
        )
        _verify_exactly_once(rt2, report, phases)


def test_merge_crash_sweep_exactly_once(tmp_path):
    """Acceptance twin for merges: the dst-absorbs / src-empties / reroute
    transaction is atomic under a crash at every persistence op — the sweep
    would catch a state where a value lives in both src and dst."""
    kinds = ["queue", "queue"]

    def reshard(rt):
        rt.merge_shards(1, 0)

    _, _, _, total = _reshard_crash_scenario(
        tmp_path / "dry", None, reshard, kinds, 8
    )
    assert total > 40
    for k in range(1, total + 1):
        rt2, report, phases, _ = _reshard_crash_scenario(
            tmp_path / f"k{k}", k, reshard, kinds, 8
        )
        _verify_exactly_once(rt2, report, phases)


def test_replay_skips_committed_noops(tmp_path):
    """Regression: an op whose phase COMMITTED with an R_NONE response (a
    kind-mismatched code in a mixed fabric — a legal no-op) must not be
    re-announced by replay_pending on every recovery forever."""
    kinds = ["stack", "queue"]
    fs = SimFS(tmp_path)
    rt = ShardedDFCRuntime(kinds, 2, CAP, LANES, fs=fs, n_threads=1, n_buckets=8)
    k_stack = rt.key_for_shard(0)
    k_queue = rt.key_for_shard(1)
    # code 4 (OP_POPR) is a no-op on the stack shard; the enq is a real op
    rt.announce(0, [k_stack, k_queue], [4, OP_ENQ], [0.0, 5.0], token=1)
    rt.combine_phase()
    rt2, report = ShardedDFCRuntime.recover(
        fs.crash(), kind=kinds, n_shards=2, capacity=CAP, lanes=LANES,
        n_threads=1, n_buckets=8,
    )
    v_noop, v_enq = report[0]["ops"]
    assert not v_noop.applied and v_noop.kind == R_NONE
    assert v_enq.applied
    assert rt2.replay_pending(report) == []  # converged: nothing to replay
    assert rt2.shard_contents(1) == [5.0]


def test_reshard_again_after_any_crash(tmp_path):
    """Regression: a crash at ANY persistence op of a split (including inside
    the donor-snapshot log's epoch commit) must leave the fabric able to
    reshard again after recovery — the snapshot log self-heals an odd epoch."""
    kinds = ["queue", "queue"]

    def reshard(rt):
        rt.split_shard(int(np.argmax(rt.shard_sizes())))

    _, _, _, total = _reshard_crash_scenario(
        tmp_path / "dry", None, reshard, kinds, 8
    )
    for k in range(1, total + 1, 3):
        rt2, report, phases, _ = _reshard_crash_scenario(
            tmp_path / f"k{k}", k, reshard, kinds, 8
        )
        rt2.replay_pending(report)
        hot = int(np.argmax(rt2.shard_sizes()))
        try:
            rt2.split_shard(hot)  # must never die on a poisoned snapshot log
        except ValueError:
            pass  # acceptable: the hot shard may be down to one bucket
        assert rt2.r_epoch % 2 == 0


# ============================================================== serving tier
def test_request_queue_tier_serves_every_session_once():
    """The serve launcher's request-queue tier (queue shards + slot-pool
    stack shard in ONE fabric) admits every submitted session exactly once,
    bounded by the free-slot pool."""
    from repro.launch.serve import RequestQueueTier

    tier = RequestQueueTier(n_queues=3, slots=2, capacity=512, lanes=16)
    sids = list(range(1, 10))
    assert tier.submit(sids) == []  # nothing overflows at these lanes
    assert tier.backlog() == len(sids)
    served = []
    for _ in range(20):
        admitted = tier.admit(4)
        assert len(admitted) <= 2  # pool has only 2 decode slots
        served += [sid for sid, _ in admitted]
        tier.submit([], release_slots=[slot for _, slot in admitted])
        if len(served) == len(sids):
            break
    assert sorted(served) == sids
    assert tier.backlog() == 0
    assert tier.admit(2) == []  # drained: slots return to the pool


def test_request_queue_tier_pool_larger_than_lanes_never_leaks_slots():
    """Regression: pool pushes beyond the pool shard's lanes are retried,
    not silently dropped — every seeded decode slot stays admittable."""
    from repro.launch.serve import RequestQueueTier

    tier = RequestQueueTier(n_queues=2, slots=10, capacity=512, lanes=4)
    sids = list(range(1, 11))
    waiting = tier.submit(sids)
    served = []
    for _ in range(40):
        waiting = tier.submit(waiting)
        admitted = tier.admit(10)
        assert len(admitted) <= 4  # per-phase pops bounded by pool lanes
        served += [sid for sid, _ in admitted]
        tier.submit([], release_slots=[slot for _, slot in admitted])
        if len(served) == len(sids):
            break
    assert sorted(served) == sids
    # at quiescence every seeded slot is back in the pool stack (LIFO reuse
    # means only the top few cycle, but none may leak)
    while tier._slot_retry:
        tier.submit([])
    pool = tier.rt.shard_contents(tier.pool_shard)
    assert sorted(int(v) for v in pool) == list(range(10))


def test_request_queue_tier_durable_autosplit():
    """Durable tier: announce/combine persistence path plus crash-consistent
    autosplit of a backlogged request shard."""
    from repro.launch.serve import RequestQueueTier

    tier = RequestQueueTier(
        n_queues=2, slots=2, capacity=512, lanes=32,
        durable=True, reshard_backlog=3,
    )
    sids = list(range(1, 13))
    assert tier.submit(sids) == []
    assert tier.stats["splits"] >= 1  # a hot shard split under the backlog
    assert tier.rt.n_shards > 3
    served = []
    for _ in range(30):
        admitted = tier.admit(2)
        served += [sid for sid, _ in admitted]
        tier.submit([], release_slots=[slot for _, slot in admitted])
        if len(served) == len(sids):
            break
    assert sorted(served) == sids
    p = tier.persistence_stats()
    assert p and p["pwb_per_op"] > 0


def test_hetero_crash_sweep_mixed_kinds(tmp_path):
    """Crash sweep over a MIXED fabric's combine phases: per-kind groups
    commit independently and every inserted value survives exactly once."""
    kinds = ["stack", "queue", "deque"]

    def scenario(crash_at):
        inj = FaultInjector(crash_at=crash_at)
        fs = SimFS(tmp_path / f"c{crash_at}", inj)
        rt = ShardedDFCRuntime(
            kinds, 3, CAP, LANES, fs=fs, n_threads=1, n_buckets=12
        )
        rng = np.random.default_rng(3)
        keys = rng.integers(0, 1000, 12)
        shard = rt.route_host(keys)
        ops = [PUSH_OF[kinds[s]] for s in shard]
        params = [float(i) for i in range(1, 13)]
        phases = [(1, [int(k) for k in keys], ops, params)]
        try:
            _drive_phase(rt, 1, keys, ops, params)
        except CrashNow:
            pass
        rt2, report = ShardedDFCRuntime.recover(
            fs.crash(), kind=kinds, n_shards=3, capacity=CAP, lanes=LANES,
            n_threads=1, n_buckets=12,
        )
        _verify_exactly_once(rt2, report, phases)
        return inj.count

    total = scenario(None)
    for k in range(1, total + 1, 2):
        scenario(k)
