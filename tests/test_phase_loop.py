"""Fused K-phase device dispatch (``phase_loop``): parity + crash harness.

Covers the ISSUE-6 tentpole acceptance criteria: a whole SCHEDULE of
combining phases runs as ONE device dispatch (``lax.scan`` over the phase
axis, with a Pallas grid-over-phases twin), accumulating per-phase persist
INTENTS in device arrays; the host then drains the intent log and issues
the pwb/pfence batches behind the device.  The durable schedule the drain
replays is op-for-op the serial one, so:

- responses, shard contents, and fs.stats (pwb AND pfence counts) must
  equal a serial ``announce``/``combine_phase``/``flush`` drive of the same
  schedule, and the ``sequential_hetero_reference`` oracle;
- a crash at EVERY persistence op of the intent drain — announcement
  mirror writes, shard pwbs, response publishes, epoch increments — must
  recover with per-thread detectability verdicts intact and replay to
  exactly-once (the device is up to K phases ahead of the host at every
  one of these points: the dispatch completed before the drain started);
- the scan and Pallas-grid phase axes must be bit-identical.

Fast representatives run in tier-1; the full kind x phase_axis sweep grid
is ``slow``.
"""

import numpy as np
import pytest

import jax

from repro.checkpoint.dfc_checkpoint import CrashNow, FaultInjector, SimFS
from repro.core.jax_dfc import OP_ENQ, OP_PUSH, OP_PUSHR
from repro.runtime.dfc_shard import (
    ShardedDFCRuntime,
    StaleTokenError,
    sequential_hetero_reference,
)

jax.config.update("jax_platform_name", "cpu")

CAP, LANES = 256, 16
PUSH_OF = {"stack": OP_PUSH, "queue": OP_ENQ, "deque": OP_PUSHR}


def _schedule(kinds, n_rounds, n_threads, per_thread, seed=11, mixed=False):
    """Flat [(thread, token, keys, ops, params)] schedule, one phase per
    entry, round-major (every thread announces token r+1 in round r).
    Insert-only with globally unique params unless ``mixed``."""
    rng = np.random.default_rng(seed)
    val = 1.0
    sched = []
    for r in range(n_rounds):
        for t in range(n_threads):
            keys = [int(k) for k in rng.integers(0, 1000, per_thread)]
            if mixed:
                ops = [int(o) for o in rng.integers(1, 3, per_thread)]
            else:
                ops = [PUSH_OF[kinds[0]]] * per_thread
            params = [val + i for i in range(per_thread)]
            val += per_thread
            sched.append((t, r + 1, keys, ops, params))
    return sched


def _drive_serial(rt, sched):
    """The reference drive: round-lockstep announce/combine/flush, reading
    every response — the durable schedule phase_loop must reproduce."""
    out = []
    by_tok = {}
    for entry in sched:
        by_tok.setdefault(entry[1], []).append(entry)
    for tok in sorted(by_tok):
        for (t, tk, k, o, p) in by_tok[tok]:
            rt.announce(t, k, o, p, token=tk)
        rt.combine_phase()
        rt.flush()
        for (t, tk, _k, _o, _p) in by_tok[tok]:
            out.append(rt.read_responses(t, token=tk))
    return out


def _fabric_contents(rt):
    return sorted(sum((rt.shard_contents(s) for s in range(rt.n_shards)), []))


# -------------------------------------------------------------- parity
def test_phase_loop_matches_serial_drive(tmp_path):
    """The fused loop's responses, final contents, and EXACT pwb/pfence
    counts equal the serial drive's — the drain replays the serial durable
    schedule behind the single device dispatch."""
    kinds = ["queue", "stack", "deque"]
    sched = _schedule(kinds, 4, 2, 5, mixed=True)
    # chain=2 keeps the two threads' announcements as separate batches in
    # the serial dispatch — the per-(thread, token) phase granularity the
    # fused schedule uses
    fs1 = SimFS(tmp_path / "serial")
    rt1 = ShardedDFCRuntime(
        kinds, 3, CAP, LANES, fs=fs1, n_threads=2, chain=2,
    )
    serial = _drive_serial(rt1, sched)

    fs2 = SimFS(tmp_path / "fused")
    rt2 = ShardedDFCRuntime(kinds, 3, CAP, LANES, fs=fs2, n_threads=2)
    records = rt2.phase_loop(sched)

    assert dict(fs1.stats) == dict(fs2.stats), "pwb/pfence parity broken"
    assert len(records) == len(sched)
    for rec, want in zip(records, serial):
        assert rec["resp"] == want["resp"]
        assert rec["kinds"] == want["kinds"]
        assert rec["targets"] == want["targets"]
    for s in range(3):
        assert rt1.shard_contents(s) == rt2.shard_contents(s)


def test_phase_loop_matches_oracle(tmp_path):
    """Phase-for-phase parity with ``sequential_hetero_reference`` on a
    mixed insert/remove schedule."""
    kinds = ["queue", "stack", "deque"]
    sched = _schedule(kinds, 3, 2, 6, seed=5, mixed=True)
    fs = SimFS(tmp_path)
    rt = ShardedDFCRuntime(kinds, 3, CAP, LANES, fs=fs, n_threads=2)
    records = rt.phase_loop(sched)
    lists = [[] for _ in range(3)]
    for rec, (t, tok, keys, ops, params) in zip(records, sched):
        resp, kk = sequential_hetero_reference(
            kinds, lists, list(keys), list(ops), list(params), LANES,
            table=rt.table,
        )
        assert np.allclose(rec["resp"], resp)
        assert rec["kinds"] == kk


def test_phase_loop_records_match_read_responses(tmp_path):
    """The returned records ARE the durable responses: the last two tokens
    per thread stay readable through ``read_responses`` and match; older
    tokens raise ``StaleTokenError``."""
    kinds = ["queue", "queue"]
    sched = _schedule(kinds, 3, 2, 4)
    fs = SimFS(tmp_path)
    rt = ShardedDFCRuntime(kinds, 2, CAP, LANES, fs=fs, n_threads=2)
    records = rt.phase_loop(sched)
    by_thread_tok = {(r["thread"], r["token"]): r for r in records}
    for t in (0, 1):
        for tok in (2, 3):  # the two retained slots
            val = rt.read_responses(t, token=tok)
            rec = by_thread_tok[(t, tok)]
            assert val["resp"] == rec["resp"]
            assert val["kinds"] == rec["kinds"]
        with pytest.raises(StaleTokenError):
            rt.read_responses(t, token=1)


def test_phase_loop_scan_grid_parity(tmp_path):
    """The ``lax.scan`` phase axis and the Pallas grid-over-phases axis
    produce identical records, durable stats, and contents."""
    kinds = ["queue", "stack", "deque"]
    sched = _schedule(kinds, 3, 2, 5, seed=3, mixed=True)
    runs = {}
    for axis, backend in (("scan", "ref"), ("grid", "pallas")):
        fs = SimFS(tmp_path / axis)
        rt = ShardedDFCRuntime(
            kinds, 3, CAP, LANES, fs=fs, n_threads=2, backend=backend,
        )
        recs = rt.phase_loop(sched, phase_axis=axis)
        runs[axis] = (recs, dict(fs.stats), _fabric_contents(rt))
    recs_s, stats_s, cont_s = runs["scan"]
    recs_g, stats_g, cont_g = runs["grid"]
    assert stats_s == stats_g
    assert cont_s == cont_g
    for a, b in zip(recs_s, recs_g):
        assert a["resp"] == b["resp"] and a["kinds"] == b["kinds"]
        assert a["targets"] == b["targets"]


def test_phase_loop_empty_and_single_phase(tmp_path):
    """Degenerate schedules: empty -> no durable traffic, single phase ->
    one combining phase, same as the serial path."""
    fs = SimFS(tmp_path)
    rt = ShardedDFCRuntime(["queue"], 1, CAP, LANES, fs=fs, n_threads=1)
    assert rt.phase_loop([]) == []
    assert fs.stats["pwb"] == 0 and fs.stats["pfence"] == 0
    recs = rt.phase_loop([(0, 1, [1, 2], [OP_ENQ] * 2, [1.0, 2.0])])
    assert len(recs) == 1
    assert recs[0]["resp"] == [0.0, 0.0]  # R_ACK carries no value payload
    assert recs[0]["kinds"] == [1, 1]
    assert _fabric_contents(rt) == [1.0, 2.0]


# -------------------------------------------------------- crash sweeps
def _crash_scenario(tmp, crash_at, kinds, sched, *, n_threads,
                    phase_axis="scan", backend="ref"):
    inj = FaultInjector(crash_at=crash_at)
    fs = SimFS(tmp, inj)
    n_shards = len(kinds)
    rt = ShardedDFCRuntime(
        kinds, n_shards, CAP, LANES, fs=fs, n_threads=n_threads,
        backend=backend,
    )
    try:
        rt.phase_loop(sched, phase_axis=phase_axis)
    except CrashNow:
        pass
    rt2, report = ShardedDFCRuntime.recover(
        fs.crash(), kind=kinds, n_shards=n_shards, capacity=CAP,
        lanes=LANES, n_threads=n_threads, backend=backend,
    )
    return rt2, report, inj.count


def _verify_exactly_once(rt2, report, sched, *, n_threads,
                         phase_axis="scan"):
    """Soundness: every op a verdict reports applied is durably in the
    fabric.  Completeness: replay the announced-not-applied ops, re-drive
    the never-announced phases through a fresh fused loop, and check every
    submitted value lands exactly once."""
    assert all(int(e) % 2 == 0 for e in rt2.shard_epochs())
    history = {(t, tok): params for (t, tok, _k, _o, params) in sched}
    contents = _fabric_contents(rt2)
    assert len(contents) == len(set(contents)), "duplicate after recovery"
    for t in range(n_threads):
        r = report[t]
        for rec in ([r] if r["token"] is not None else []) + (
            [r["prev"]] if r.get("prev") else []
        ):
            params = history[(t, rec["token"])]
            for i, v in enumerate(rec["ops"]):
                if v.applied:
                    assert params[i] in contents, (t, rec["token"], i)
    rt2.replay_pending(report)
    surfaced = {t: report[t]["token"] or 0 for t in range(n_threads)}
    remaining = [e for e in sched if e[1] > surfaced[e[0]]]
    if remaining:
        rt2.phase_loop(remaining, phase_axis=phase_axis)
    expect = sorted(p for (_t, _tok, _k, _o, ps) in sched for p in ps)
    assert _fabric_contents(rt2) == expect, "lost or duplicated ops"


def _sweep(tmp_path, kinds, *, n_threads=2, n_rounds=3, per_thread=4,
           step=1, seed=42, phase_axis="scan", backend="ref"):
    sched = _schedule(kinds, n_rounds, n_threads, per_thread, seed=seed)
    _rt_dry, report_dry, total = _crash_scenario(
        tmp_path / "dry", None, kinds, sched, n_threads=n_threads,
        phase_axis=phase_axis, backend=backend,
    )
    assert total > 40  # the drain really is issuing the serial op count
    for k in range(1, total + 1, step):
        rt2, report, _ = _crash_scenario(
            tmp_path / f"k{k}", k, kinds, sched, n_threads=n_threads,
            phase_axis=phase_axis, backend=backend,
        )
        _verify_exactly_once(
            rt2, report, sched, n_threads=n_threads, phase_axis=phase_axis,
        )


def test_phase_loop_crash_sweep_queue(tmp_path):
    """Acceptance representative: crash at EVERY persistence op of the
    intent drain on a 2-shard queue fabric — at each point the device has
    already finished ALL K phases and the host is mid-drain."""
    _sweep(tmp_path, ["queue", "queue"])


def test_phase_loop_crash_sweep_mixed(tmp_path):
    """Heterogeneous representative: queue+stack fabric, crash at every
    persistence op."""
    _sweep(tmp_path, ["queue", "stack"], seed=7)


def test_crash_device_ahead_of_host(tmp_path):
    """Directed ISSUE-6 case: crash BETWEEN the device finishing the whole
    K-phase dispatch and the host persisting the FIRST phase's intents
    (persistence op 1 of the drain).  Recovery must find no phase applied
    — the device's K phases of intents are all lost with the volatile
    arrays — and a full re-drive lands every value exactly once."""
    kinds = ["queue", "queue"]
    sched = _schedule(kinds, 2, 2, 3, seed=9)
    rt2, report, _ = _crash_scenario(
        tmp_path, 1, kinds, sched, n_threads=2,
    )
    for t in (0, 1):
        assert report[t]["token"] is None  # nothing announced durably
    assert _fabric_contents(rt2) == []
    _verify_exactly_once(rt2, report, sched, n_threads=2)


def test_crash_between_phases_k_and_k_minus_1(tmp_path):
    """Directed: crash with phase k-1 fully committed and phase k's intents
    still undrained — the recovered fabric is exactly the phase-(k-1)
    prefix, and the rest replays exactly once.  The crash point lands on
    the first announce pwb of phase 2's drain (phase 1 = 3 announce pwbs +
    2 pfences, 2 shard-leaf pwbs + meta, response pwb + pfence, 3 epoch
    ops)."""
    kinds = ["queue", "queue"]
    sched = _schedule(kinds, 3, 1, 2, seed=21)
    # dry run to count phase 1's ops, then crash right after them
    fs_dry = SimFS(tmp_path / "dry")
    rt_dry = ShardedDFCRuntime(kinds, 2, CAP, LANES, fs=fs_dry, n_threads=1)
    rt_dry.phase_loop(sched[:1])
    ops_phase1 = fs_dry.stats["pwb"] + fs_dry.stats["pfence"]
    rt2, report, _ = _crash_scenario(
        tmp_path, ops_phase1 + 1, kinds, sched, n_threads=1,
    )
    # phase 1 committed, phase 2 announced at the crash op but not durable
    assert _fabric_contents(rt2) == sorted(sched[0][4])
    _verify_exactly_once(rt2, report, sched, n_threads=1)


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["stack", "queue", "deque"])
@pytest.mark.parametrize("phase_axis,backend", [
    ("scan", "ref"), ("scan", "jnp"), ("grid", "pallas"),
])
def test_phase_loop_crash_sweep_grid(tmp_path, kind, phase_axis, backend):
    """Full grid: crash at every persistence op for each structure kind on
    both phase axes (scan on ref/jnp backends, Pallas grid in interpret
    mode)."""
    _sweep(
        tmp_path, [kind, kind], seed=17, phase_axis=phase_axis,
        backend=backend,
    )


def test_request_tier_bulk_waves_match_serial_submits():
    """The serving tier rides the fused loop: ``submit_waves`` commits K
    arrival rounds in one dispatch with the same rejections, durable
    stats, and final queue contents as K ``submit`` calls."""
    from repro.launch.serve import RequestQueueTier

    waves = [
        ([1, 2, 3], [], None),
        ([4, 5], [], None),
        ([6, 7, 8, 9], [], None),
    ]
    t1 = RequestQueueTier(
        n_queues=2, slots=2, capacity=512, lanes=16, durable=True,
    )
    rej_serial = [t1.submit(s, r, p) for (s, r, p) in waves]
    t2 = RequestQueueTier(
        n_queues=2, slots=2, capacity=512, lanes=16, durable=True,
    )
    rej_waves = t2.submit_waves(waves)
    assert rej_waves == rej_serial
    assert dict(t1.rt.fs.stats) == dict(t2.rt.fs.stats)
    for s in range(t1.rt.n_shards):
        assert t1.rt.shard_contents(s) == t2.rt.shard_contents(s)
