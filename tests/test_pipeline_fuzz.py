"""Differential fuzz of the pipelined durable path (hypothesis-based).

Random op/key/kind schedules flow through pipelined + chained durable
fabrics with randomly injected MID-PIPELINE crashes; after recovery, replay
and re-drive, the fabric contents must equal the sequential oracle applied
over the same per-thread op order — and the per-thread detectability
verdicts must match what the oracle says about each op (its response and
response kind).  The schedule is replayed on all three combine backends
(``jnp``, ``ref``, ``pallas``) and must agree bit-for-bit.

Runs through ``tests/_compat.py``: with hypothesis installed these are real
property tests; without it a deterministic seeded stand-in draws the same
strategy surface.
"""

import tempfile
from pathlib import Path

import numpy as np

import jax

from _compat import hypothesis, st

from repro.checkpoint.dfc_checkpoint import CrashNow, FaultInjector, SimFS
from repro.core.jax_dfc import R_NONE, STRUCTS
from repro.runtime.dfc_shard import (
    R_OVERFLOW,
    ShardedDFCRuntime,
    route_keys_host,
    sequential_hetero_reference,
)

jax.config.update("jax_platform_name", "cpu")

CAP = 128
KIND_SETS = [
    ["queue", "queue"],
    ["stack", "queue"],
    ["stack", "queue", "deque"],
    ["deque", "deque", "stack"],
]


def _schedule(kinds, shape, rng_draws):
    """Build a phase schedule whose op codes are valid for each key's routed
    structure.  ``shape`` = (n_phases, batch); ``rng_draws`` yields ints."""
    n_phases, batch = shape
    lanes = batch  # lanes == batch: overflow impossible, replay keeps order
    phases = []
    for p in range(n_phases):
        keys = [rng_draws(0, 997) for _ in range(batch)]
        shard = route_keys_host(np.asarray(keys), len(kinds))
        ops = [
            rng_draws(1, STRUCTS[kinds[s]].n_opcodes - 1) for s in shard
        ]
        params = [
            float(rng_draws(1, 10_000)) / 8.0 for _ in range(batch)
        ]
        phases.append((p + 1, keys, ops, params))
    return phases, lanes


def _oracle_run(kinds, phases, lanes):
    """Phase-by-phase sequential witness: per-token (resp, kinds) plus the
    final per-shard contents."""
    shards = [[] for _ in kinds]
    per_token = {}
    for token, keys, ops, params in phases:
        eresp, ekinds = sequential_hetero_reference(
            kinds, shards, keys, ops, params, lanes
        )
        per_token[token] = (eresp, ekinds)
    return shards, per_token


def _crashed_run(kinds, phases, lanes, crash_at, backend, chain, tmp):
    """Drive the pipelined fabric with a crash at persistence op
    ``crash_at``; recover, check verdicts against the oracle, replay,
    re-drive, and return the final per-shard contents."""
    inj = FaultInjector(crash_at=crash_at)
    fs = SimFS(tmp, inj)
    rt = ShardedDFCRuntime(
        kinds, len(kinds), CAP, lanes, fs=fs, n_threads=1,
        pipeline=True, chain=chain, backend=backend,
    )
    try:
        for token, keys, ops, params in phases:
            rt.announce(0, keys, ops, params, token=token)
            rt.combine_phase()
        rt.flush()
    except CrashNow:
        pass
    rt2, report = ShardedDFCRuntime.recover(
        fs.crash(), kind=kinds, n_shards=len(kinds), capacity=CAP,
        lanes=lanes, n_threads=1, pipeline=True, chain=chain, backend=backend,
    )
    _, per_token = _oracle_run(kinds, phases, lanes)

    # detectability verdicts vs the oracle: an op reported applied must carry
    # the oracle's response for exactly its position in the phase order
    r = report[0]
    for rec in ([r] if r["token"] is not None else []) + (
        [r["prev"]] if r.get("prev") else []
    ):
        eresp, ekinds = per_token[rec["token"]]
        for i, v in enumerate(rec["ops"]):
            assert v.kind != R_OVERFLOW  # lanes == batch: cannot overflow
            if v.applied:
                assert v.kind == ekinds[i], (rec["token"], i)
                np.testing.assert_allclose(
                    v.resp, np.float32(eresp[i]), rtol=1e-6
                )
            elif v.kind is not None:
                assert v.kind == R_NONE  # committed no-op (kind mismatch)

    rt2.replay_pending(report)
    surfaced = r["token"] or 0
    for token, keys, ops, params in phases[surfaced:]:
        rt2.announce(0, keys, ops, params, token=token)
        rt2.combine_phase()
    rt2.flush()
    return [rt2.shard_contents(s) for s in range(len(kinds))]


@hypothesis.settings(max_examples=8, deadline=None)
@hypothesis.given(
    st.integers(0, len(KIND_SETS) - 1),
    st.integers(2, 3),  # phases
    st.integers(3, 6),  # batch
    st.integers(1, 60),  # crash point (cycles through the schedule's ops)
    st.integers(1, 3),  # chain
    st.data(),
)
def test_fuzz_pipeline_crash_matches_oracle(
    kset, n_phases, batch, crash_at, chain, data
):
    """Random schedules + random mid-pipeline crash: recovered contents and
    verdicts match the oracle on every backend, and backends agree."""
    kinds = KIND_SETS[kset]
    draws = lambda lo, hi: data.draw(st.integers(lo, hi))
    phases, lanes = _schedule(kinds, (n_phases, batch), draws)
    oracle_shards, _ = _oracle_run(kinds, phases, lanes)

    per_backend = {}
    for backend in ("jnp", "ref", "pallas"):
        tmp = Path(tempfile.mkdtemp(prefix=f"dfc_fuzz_{backend}_"))
        per_backend[backend] = _crashed_run(
            kinds, phases, lanes, crash_at, backend, chain, tmp
        )
    for backend, got in per_backend.items():
        for s in range(len(kinds)):
            np.testing.assert_allclose(
                got[s], oracle_shards[s], rtol=1e-6,
                err_msg=f"{backend} shard {s} diverged from the oracle",
            )
    assert per_backend["jnp"] == per_backend["ref"] == per_backend["pallas"]


@hypothesis.settings(max_examples=6, deadline=None)
@hypothesis.given(
    st.integers(0, len(KIND_SETS) - 1),
    st.integers(2, 3),
    st.integers(3, 5),
    st.integers(1, 3),
    st.data(),
)
def test_fuzz_pipeline_crash_free_differential(
    kset, n_phases, batch, chain, data
):
    """Crash-free pipelined runs: durable responses of every retired batch
    equal the oracle's, per backend, including mixed-kind no-ops."""
    kinds = KIND_SETS[kset]
    draws = lambda lo, hi: data.draw(st.integers(lo, hi))
    phases, lanes = _schedule(kinds, (n_phases, batch), draws)
    oracle_shards, per_token = _oracle_run(kinds, phases, lanes)
    for backend in ("jnp", "ref", "pallas"):
        fs = SimFS(Path(tempfile.mkdtemp(prefix=f"dfc_difffuzz_{backend}_")))
        rt = ShardedDFCRuntime(
            kinds, len(kinds), CAP, lanes, fs=fs, n_threads=1,
            pipeline=True, chain=chain, backend=backend,
        )
        for token, keys, ops, params in phases:
            rt.announce(0, keys, ops, params, token=token)
            rt.combine_phase()
        rt.flush()
        for token, _, _, _ in phases:
            val = rt.read_responses(0, token=token)
            if val is None:
                continue  # overwritten response slot (token <= last - 2)
            eresp, ekinds = per_token[token]
            assert val["kinds"] == list(ekinds), (backend, token)
            np.testing.assert_allclose(
                val["resp"], np.asarray(eresp, np.float32), rtol=1e-6
            )
        for s in range(len(kinds)):
            np.testing.assert_allclose(
                rt.shard_contents(s), oracle_shards[s], rtol=1e-6
            )
