"""Differential fuzz of the pipelined durable path (hypothesis-based).

Random op/key/kind schedules flow through pipelined + chained durable
fabrics with randomly injected MID-PIPELINE crashes; after recovery, replay
and re-drive, the fabric contents must equal the sequential oracle applied
over the same per-thread op order — and the per-thread detectability
verdicts must match what the oracle says about each op (its response and
response kind).  The schedule is replayed on all three combine backends
(``jnp``, ``ref``, ``pallas``) and must agree bit-for-bit.  The kind sets
cover ALL FOUR structures — queue, stack, deque, and the keyed map (whose
lanes carry insert/lookup/delete/CAS with packed CAS operands).

ISSUE-5 additions: a strategy over SEEDED ANNOUNCER INTERLEAVINGS — random
multi-thread schedules drawn as (scheduler seed, n_threads, depth), driven
through ``MultiThreadDriver`` — differential against
``sequential_hetero_reference`` applied in the driver's recorded dispatch
order (crash-free, exact), and with random mid-pipeline crashes
(exactly-once per routed shard), across the same three backends.

Runs through ``tests/_compat.py``: with hypothesis installed these are real
property tests; without it a deterministic seeded stand-in draws the same
strategy surface.
"""

import tempfile
from pathlib import Path

import numpy as np

import jax

from _compat import hypothesis, st

from repro.checkpoint.dfc_checkpoint import CrashNow, FaultInjector, SimFS
from repro.core.jax_dfc import R_NONE, STRUCTS
from repro.runtime.announce_driver import MultiThreadDriver
from repro.runtime.dfc_shard import (
    R_OVERFLOW,
    ShardedDFCRuntime,
    StaleTokenError,
    route_keys_host,
    sequential_hetero_reference,
)

jax.config.update("jax_platform_name", "cpu")

CAP = 128
KIND_SETS = [
    ["queue", "queue"],
    ["stack", "queue"],
    ["stack", "queue", "deque"],
    ["deque", "deque", "stack"],
    ["queue", "map"],
    ["map", "stack", "queue", "deque"],  # all four kinds in one fabric
]


def _draw_op_param(kind, rng_draws):
    """One (op, param) valid for ``kind``.  Map params come from a SMALL
    value domain with CAS operands packed ``expected * CAS_DOM + new`` so
    hits, misses, successful CAS, and failed CAS all occur."""
    from repro.core.jax_dfc import CAS_DOM, OP_MAP_CAS

    op = rng_draws(1, STRUCTS[kind].n_opcodes - 1)
    if STRUCTS[kind].keyed:
        if op == OP_MAP_CAS:
            return op, float(rng_draws(0, 4) * CAS_DOM + rng_draws(0, 4))
        return op, float(rng_draws(0, 4))
    return op, float(rng_draws(1, 10_000)) / 8.0


def _schedule(kinds, shape, rng_draws):
    """Build a phase schedule whose op codes are valid for each key's routed
    structure.  ``shape`` = (n_phases, batch); ``rng_draws`` yields ints."""
    n_phases, batch = shape
    lanes = batch  # lanes == batch: overflow impossible, replay keeps order
    phases = []
    for p in range(n_phases):
        keys = [rng_draws(0, 997) for _ in range(batch)]
        shard = route_keys_host(np.asarray(keys), len(kinds))
        ops, params = [], []
        for s in shard:
            o, pr = _draw_op_param(kinds[s], rng_draws)
            ops.append(o)
            params.append(pr)
        phases.append((p + 1, keys, ops, params))
    return phases, lanes


def _init_shards(kinds):
    return [{} if STRUCTS[k].keyed else [] for k in kinds]


def _assert_shards_equal(kinds, got, expect, msg=""):
    """Kind-aware per-shard equality: dict semantics for keyed shards,
    ordered-sequence semantics for the ring/stack kinds."""
    for s, kind in enumerate(kinds):
        if STRUCTS[kind].keyed:
            g, e = dict(got[s]), expect[s]
            assert set(g) == set(e), (msg, s, g, e)
            for k in e:
                np.testing.assert_allclose(
                    g[k], np.float32(e[k]), rtol=1e-6,
                    err_msg=f"{msg} shard {s} key {k}",
                )
        else:
            np.testing.assert_allclose(
                got[s], expect[s], rtol=1e-6,
                err_msg=f"{msg} shard {s} diverged",
            )


def _oracle_run(kinds, phases, lanes):
    """Phase-by-phase sequential witness: per-token (resp, kinds) plus the
    final per-shard contents."""
    shards = _init_shards(kinds)
    per_token = {}
    for token, keys, ops, params in phases:
        eresp, ekinds = sequential_hetero_reference(
            kinds, shards, keys, ops, params, lanes, capacity=CAP
        )
        per_token[token] = (eresp, ekinds)
    return shards, per_token


def _crashed_run(kinds, phases, lanes, crash_at, backend, chain, tmp):
    """Drive the pipelined fabric with a crash at persistence op
    ``crash_at``; recover, check verdicts against the oracle, replay,
    re-drive, and return the final per-shard contents."""
    inj = FaultInjector(crash_at=crash_at)
    fs = SimFS(tmp, inj)
    rt = ShardedDFCRuntime(
        kinds, len(kinds), CAP, lanes, fs=fs, n_threads=1,
        pipeline=True, chain=chain, backend=backend,
    )
    try:
        for token, keys, ops, params in phases:
            rt.announce(0, keys, ops, params, token=token)
            rt.combine_phase()
        rt.flush()
    except CrashNow:
        pass
    rt2, report = ShardedDFCRuntime.recover(
        fs.crash(), kind=kinds, n_shards=len(kinds), capacity=CAP,
        lanes=lanes, n_threads=1, pipeline=True, chain=chain, backend=backend,
    )
    _, per_token = _oracle_run(kinds, phases, lanes)

    # detectability verdicts vs the oracle: an op reported applied must carry
    # the oracle's response for exactly its position in the phase order
    r = report[0]
    for rec in ([r] if r["token"] is not None else []) + (
        [r["prev"]] if r.get("prev") else []
    ):
        eresp, ekinds = per_token[rec["token"]]
        for i, v in enumerate(rec["ops"]):
            assert v.kind != R_OVERFLOW  # lanes == batch: cannot overflow
            if v.applied:
                assert v.kind == ekinds[i], (rec["token"], i)
                np.testing.assert_allclose(
                    v.resp, np.float32(eresp[i]), rtol=1e-6
                )
            elif v.kind is not None:
                assert v.kind == R_NONE  # committed no-op (kind mismatch)

    rt2.replay_pending(report)
    surfaced = r["token"] or 0
    for token, keys, ops, params in phases[surfaced:]:
        rt2.announce(0, keys, ops, params, token=token)
        rt2.combine_phase()
    rt2.flush()
    return [rt2.shard_contents(s) for s in range(len(kinds))]


@hypothesis.settings(max_examples=8, deadline=None)
@hypothesis.given(
    st.integers(0, len(KIND_SETS) - 1),
    st.integers(2, 3),  # phases
    st.integers(3, 6),  # batch
    st.integers(1, 60),  # crash point (cycles through the schedule's ops)
    st.integers(1, 3),  # chain
    st.data(),
)
def test_fuzz_pipeline_crash_matches_oracle(
    kset, n_phases, batch, crash_at, chain, data
):
    """Random schedules + random mid-pipeline crash: recovered contents and
    verdicts match the oracle on every backend, and backends agree."""
    kinds = KIND_SETS[kset]
    draws = lambda lo, hi: data.draw(st.integers(lo, hi))
    phases, lanes = _schedule(kinds, (n_phases, batch), draws)
    oracle_shards, _ = _oracle_run(kinds, phases, lanes)

    per_backend = {}
    for backend in ("jnp", "ref", "pallas"):
        tmp = Path(tempfile.mkdtemp(prefix=f"dfc_fuzz_{backend}_"))
        per_backend[backend] = _crashed_run(
            kinds, phases, lanes, crash_at, backend, chain, tmp
        )
    for backend, got in per_backend.items():
        _assert_shards_equal(kinds, got, oracle_shards, msg=backend)
    assert per_backend["jnp"] == per_backend["ref"] == per_backend["pallas"]


@hypothesis.settings(max_examples=6, deadline=None)
@hypothesis.given(
    st.integers(0, len(KIND_SETS) - 1),
    st.integers(2, 3),
    st.integers(3, 5),
    st.integers(1, 3),
    st.data(),
)
def test_fuzz_pipeline_crash_free_differential(
    kset, n_phases, batch, chain, data
):
    """Crash-free pipelined runs: durable responses of every retired batch
    equal the oracle's, per backend, including mixed-kind no-ops."""
    kinds = KIND_SETS[kset]
    draws = lambda lo, hi: data.draw(st.integers(lo, hi))
    phases, lanes = _schedule(kinds, (n_phases, batch), draws)
    oracle_shards, per_token = _oracle_run(kinds, phases, lanes)
    for backend in ("jnp", "ref", "pallas"):
        fs = SimFS(Path(tempfile.mkdtemp(prefix=f"dfc_difffuzz_{backend}_")))
        rt = ShardedDFCRuntime(
            kinds, len(kinds), CAP, lanes, fs=fs, n_threads=1,
            pipeline=True, chain=chain, backend=backend,
        )
        for token, keys, ops, params in phases:
            rt.announce(0, keys, ops, params, token=token)
            rt.combine_phase()
        rt.flush()
        for token, _, _, _ in phases:
            try:
                val = rt.read_responses(0, token=token)
            except StaleTokenError:
                continue  # overwritten response slot (token <= last - 2)
            if val is None:
                continue  # still in flight at read time
            eresp, ekinds = per_token[token]
            assert val["kinds"] == list(ekinds), (backend, token)
            np.testing.assert_allclose(
                val["resp"], np.asarray(eresp, np.float32), rtol=1e-6
            )
        _assert_shards_equal(
            kinds,
            [rt.shard_contents(s) for s in range(len(kinds))],
            oracle_shards,
            msg=backend,
        )


# ------------------------------------------------- seeded interleavings (ISSUE 5)
def _mt_schedule(kinds, n_threads, n_rounds, batch, rng_draws, insert_only):
    """Per-thread batch lists whose op codes are valid for each key's routed
    structure (or insert-only with globally unique params AND keys — unique
    keys make per-shard multiset equality exactly-once on map shards too,
    where a repeated key would overwrite instead of accumulating)."""
    lanes = batch * n_threads  # overflow impossible even fully chained
    val = [1.0]
    uniq = [0]

    def one_batch():
        if insert_only:
            keys = list(range(uniq[0], uniq[0] + batch))
            uniq[0] += batch
        else:
            keys = [rng_draws(0, 997) for _ in range(batch)]
        shard = route_keys_host(np.asarray(keys), len(kinds))
        if insert_only:
            ins = {"stack": 1, "queue": 1, "deque": 3, "map": 1}
            ops = [ins[kinds[s]] for s in shard]
            params = [val[0] + i for i in range(batch)]
            val[0] += batch
        else:
            ops, params = [], []
            for s in shard:
                o, pr = _draw_op_param(kinds[s], rng_draws)
                ops.append(o)
                params.append(pr)
        return keys, ops, params

    return [
        [one_batch() for _ in range(n_rounds)] for _ in range(n_threads)
    ], lanes


def _drive_interleaved(kinds, per_thread, lanes, *, seed, depth, backend,
                       crash_at, tmp):
    """Submit every thread's batches, run the seeded scheduler; on a crash,
    recover + replay + re-drive through a fresh driver (tokens continue).
    Returns (rt, driver, dispatch_order or None-if-crashed)."""
    inj = FaultInjector(crash_at=crash_at)
    fs = SimFS(tmp, inj)
    n_threads = len(per_thread)
    rt = ShardedDFCRuntime(
        kinds, len(kinds), CAP, lanes, fs=fs, n_threads=n_threads,
        depth=depth, chain=min(2, n_threads), backend=backend,
    )
    drv = MultiThreadDriver(rt, seed=seed)
    for t, batches in enumerate(per_thread):
        for keys, ops, params in batches:
            drv.submit(t, keys, ops, params)
    try:
        drv.run()
        return rt, drv, list(drv.dispatch_order)
    except CrashNow:
        pass
    rt2, report = ShardedDFCRuntime.recover(
        fs.crash(), kind=kinds, n_shards=len(kinds), capacity=CAP,
        lanes=lanes, n_threads=n_threads, depth=depth,
        chain=min(2, n_threads), backend=backend,
    )
    rt2.replay_pending(report)
    surf = {t: report[t]["token"] or 0 for t in range(n_threads)}
    drv2 = MultiThreadDriver(rt2, seed=seed + 1, start_tokens=surf)
    for t, token in drv.unsurfaced(report):
        keys, ops, params = drv.history[t][token]
        drv2.submit(t, keys, ops, params)
    drv2.run()
    return rt2, drv, None


@hypothesis.settings(max_examples=6, deadline=None)
@hypothesis.given(
    st.integers(0, len(KIND_SETS) - 1),
    st.integers(2, 3),  # n_threads
    st.integers(2, 3),  # depth
    st.integers(0, 2**20),  # scheduler seed
    st.data(),
)
def test_fuzz_interleaved_multithread_differential(
    kset, n_threads, depth, seed, data
):
    """Crash-free seeded interleavings, mixed ops: the final fabric equals
    ``sequential_hetero_reference`` applied in the driver's recorded
    dispatch order, per backend — and all backends agree on the same
    interleaving (same seed replays the same dispatch order)."""
    kinds = KIND_SETS[kset]
    draws = lambda lo, hi: data.draw(st.integers(lo, hi))
    per_thread, lanes = _mt_schedule(
        kinds, n_threads, 2, 3, draws, insert_only=False
    )
    per_backend = {}
    orders = []
    for backend in ("jnp", "ref", "pallas"):
        tmp = Path(tempfile.mkdtemp(prefix=f"dfc_mtfuzz_{backend}_"))
        rt, drv, order = _drive_interleaved(
            kinds, per_thread, lanes, seed=seed, depth=depth,
            backend=backend, crash_at=None, tmp=tmp,
        )
        assert order is not None
        orders.append(order)
        per_backend[backend] = [
            rt.shard_contents(s) for s in range(len(kinds))
        ]
        # oracle: each dispatched batch group combines as ONE phase over the
        # members' concatenated lanes (segment order), groups in dispatch order
        shards = _init_shards(kinds)
        for group in order:
            keys, ops, params = [], [], []
            for t, token in group:
                k, o, p = drv.history[t][token]
                keys += k
                ops += o
                params += p
            sequential_hetero_reference(
                kinds, shards, keys, ops, params, lanes, capacity=CAP
            )
        _assert_shards_equal(
            kinds, per_backend[backend], shards,
            msg=f"{backend} vs dispatch-order oracle",
        )
    assert orders[0] == orders[1] == orders[2]  # backend-independent schedule
    assert (
        per_backend["jnp"] == per_backend["ref"] == per_backend["pallas"]
    )


# ------------------------------------------------- per-side lanes (ISSUE 8)
LANE_KIND_SETS = [
    ["queue", "queue"],
    ["deque", "queue"],
    ["deque", "deque"],
]
LANE_MIXES = ["enq-heavy", "deq-heavy", "drain-oscillating"]


def _lane_mix_schedule(kinds, n_phases, batch, rng_draws, mix):
    """Lane-aware schedule generator: op mixes chosen to stress the
    head/tail lane classifier.  ``enq-heavy`` keeps most phases tail-only
    (producing side), ``deq-heavy`` keeps the consuming side hot against a
    mostly-empty fabric (drained handoffs dominate), and
    ``drain-oscillating`` alternates pure push bursts with pure pop bursts
    so shards repeatedly cross the drained boundary both ways."""
    lanes = batch
    phases = []
    for p in range(n_phases):
        keys = [rng_draws(0, 997) for _ in range(batch)]
        shard = route_keys_host(np.asarray(keys), len(kinds))
        ops = []
        for s in shard:
            push = [1] if kinds[s] == "queue" else [1, 3]
            pop = [2] if kinds[s] == "queue" else [2, 4]
            if mix == "enq-heavy":
                heavy = rng_draws(0, 9) < 8
            elif mix == "deq-heavy":
                heavy = rng_draws(0, 9) >= 8
            else:
                heavy = p % 2 == 0  # alternate pure bursts phase by phase
            codes = push if heavy else pop
            ops.append(codes[rng_draws(0, len(codes) - 1)])
        params = [float(rng_draws(1, 10_000)) / 8.0 for _ in range(batch)]
        phases.append((p + 1, keys, ops, params))
    return phases, lanes


@hypothesis.settings(max_examples=6, deadline=None)
@hypothesis.given(
    st.integers(0, len(LANE_KIND_SETS) - 1),
    st.integers(2, 4),  # phases
    st.integers(3, 6),  # batch
    st.sampled_from(LANE_MIXES),
    st.data(),
)
def test_fuzz_split_lanes_differential(kset, n_phases, batch, mix, data):
    """Two-lane fabrics are semantically IDENTICAL to one-lane fabrics: a
    split runtime driven with skewed lane mixes produces the oracle's
    responses and contents on every backend, bit-for-bit equal to the
    unsplit runtime over the same schedule — the lanes only change the
    durable commit layout, never the linearization."""
    kinds = LANE_KIND_SETS[kset]
    draws = lambda lo, hi: data.draw(st.integers(lo, hi))
    phases, lanes = _lane_mix_schedule(kinds, n_phases, batch, draws, mix)
    oracle_shards, per_token = _oracle_run(kinds, phases, lanes)
    per_config = {}
    for backend in ("jnp", "ref", "pallas"):
        for split in (False, True):
            fs = SimFS(Path(tempfile.mkdtemp(
                prefix=f"dfc_lanefuzz_{backend}_{int(split)}_"
            )))
            rt = ShardedDFCRuntime(
                kinds, len(kinds), CAP, lanes, fs=fs, n_threads=1,
                backend=backend, split_lanes=split,
            )
            for token, keys, ops, params in phases:
                rt.announce(0, keys, ops, params, token=token)
                rt.combine_phase()
            rt.flush()
            for token, _, _, _ in phases:
                try:
                    val = rt.read_responses(0, token=token)
                except StaleTokenError:
                    continue  # overwritten response slot
                eresp, ekinds = per_token[token]
                assert val["kinds"] == list(ekinds), (backend, split, token)
                np.testing.assert_allclose(
                    val["resp"], np.asarray(eresp, np.float32), rtol=1e-6
                )
            got = [rt.shard_contents(s) for s in range(len(kinds))]
            for s in range(len(kinds)):
                np.testing.assert_allclose(
                    got[s], oracle_shards[s], rtol=1e-6,
                    err_msg=(
                        f"{backend} split={split} shard {s} "
                        "diverged from the oracle"
                    ),
                )
            per_config[(backend, split)] = got
            if split:
                stats = rt.lane_stats()
                assert stats is not None
                assert all(
                    e % 2 == 0 for p in stats["epochs"].values() for e in p
                )
    # one-lane and two-lane agree exactly, per backend
    for backend in ("jnp", "ref", "pallas"):
        assert per_config[(backend, False)] == per_config[(backend, True)]


@hypothesis.settings(max_examples=6, deadline=None)
@hypothesis.given(
    st.integers(0, len(KIND_SETS) - 1),
    st.integers(2, 3),  # n_threads
    st.integers(2, 3),  # depth
    st.integers(1, 120),  # crash point
    st.integers(0, 2**20),  # scheduler seed
    st.data(),
)
def test_fuzz_interleaved_crash_exactly_once(
    kset, n_threads, depth, crash_at, seed, data
):
    """Random thread schedules + random mid-pipeline crashes: after
    recovery, replay, and re-drive, every announced value sits in exactly
    the shard the router assigns it, exactly once — per backend, and the
    backends agree (insert-only with unique params, so per-shard multiset
    equality IS exactly-once under replay reordering)."""
    kinds = KIND_SETS[kset]
    draws = lambda lo, hi: data.draw(st.integers(lo, hi))
    per_thread, lanes = _mt_schedule(
        kinds, n_threads, 2, 3, draws, insert_only=True
    )
    # oracle: per-shard multiset from the host router (order-free for
    # inserts; map shards accumulate (key, value) pairs — keys are unique)
    expect = [[] for _ in kinds]
    for batches in per_thread:
        for keys, ops, params in batches:
            shard = route_keys_host(np.asarray(keys), len(kinds))
            for k, s, p in zip(keys, shard, params):
                if STRUCTS[kinds[int(s)]].keyed:
                    expect[int(s)].append((int(k), float(p)))
                else:
                    expect[int(s)].append(p)
    expect = [sorted(e) for e in expect]
    per_backend = {}
    for backend in ("jnp", "ref", "pallas"):
        tmp = Path(tempfile.mkdtemp(prefix=f"dfc_mtcrash_{backend}_"))
        rt, _, _ = _drive_interleaved(
            kinds, per_thread, lanes, seed=seed, depth=depth,
            backend=backend, crash_at=crash_at, tmp=tmp,
        )
        got = [sorted(rt.shard_contents(s)) for s in range(len(kinds))]
        assert got == expect, f"{backend}: lost/duplicated/misrouted ops"
        per_backend[backend] = got
    assert (
        per_backend["jnp"] == per_backend["ref"] == per_backend["pallas"]
    )
