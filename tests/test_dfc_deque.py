"""DFC double-ended queue: crash-free behaviour + crash-sweeping durable
linearizability and detectability (paper's deque, sequential layer)."""

import numpy as np
import pytest

from repro.core.dfc import ACK, BOT, EMPTY, INIT, POPL, POPR, PUSHL, PUSHR
from repro.core.dfc_deque import DFCDeque
from repro.core.harness import (
    check_durable_linearizability,
    run_with_crash,
    total_steps,
)
from repro.core.linearize import is_linearizable
from repro.core.sim import History, Scheduler, workload_gen
from repro.nvm.memory import CrashMode, NVMemory

# one push (pushL) and one pop (popR) in flight on thread 0, with both-end
# concurrency from threads 1-2 — the sweep below crashes at EVERY scheduler
# step, so every yield point of both ops is hit.
SMALL = [
    [(PUSHL, 11), (POPR, None)],
    [(PUSHR, 22), (PUSHL, 23)],
    [(POPL, None), (PUSHR, 33)],
]


def run_workload(n_threads, per_thread_ops, seed=0):
    mem = NVMemory()
    d = DFCDeque(mem, n_threads)
    sched = Scheduler(seed=seed)
    hist = History()
    gens = {
        t: workload_gen(d, sched, hist, t, per_thread_ops[t])
        for t in range(n_threads)
    }
    sched.run(gens)
    return d, hist, mem


# ------------------------------------------------------------- crash-free
def test_single_thread_both_ends():
    ops = [[
        (PUSHL, 1), (PUSHR, 2), (PUSHL, 3),  # deque: 3 1 2
        (POPR, None), (POPL, None), (POPL, None), (POPL, None),
    ]]
    d, hist, _ = run_workload(1, ops)
    values = [o["value"] for o in hist.ops]
    assert values == [ACK, ACK, ACK, 2, 3, 1, EMPTY]
    assert d.peek_deque() == []


def test_pop_empty_both_ends():
    d, hist, _ = run_workload(2, [[(POPL, None)], [(POPR, None)]])
    assert all(o["value"] == EMPTY for o in hist.ops)


def test_stack_mode_lifo():
    """pushL/popL only == the stack; pushR/popR only == a right stack."""
    ops = [[(PUSHL, 1), (PUSHL, 2), (POPL, None), (POPL, None)]]
    _, hist, _ = run_workload(1, ops)
    assert [o["value"] for o in hist.ops] == [ACK, ACK, 2, 1]
    ops = [[(PUSHR, 1), (PUSHR, 2), (POPR, None), (POPR, None)]]
    _, hist, _ = run_workload(1, ops)
    assert [o["value"] for o in hist.ops] == [ACK, ACK, 2, 1]


def test_queue_mode_fifo():
    """pushR + popL == FIFO queue (and the mirror image)."""
    ops = [[(PUSHR, 1), (PUSHR, 2), (POPL, None), (POPL, None)]]
    _, hist, _ = run_workload(1, ops)
    assert [o["value"] for o in hist.ops] == [ACK, ACK, 1, 2]
    ops = [[(PUSHL, 1), (PUSHL, 2), (POPR, None), (POPR, None)]]
    _, hist, _ = run_workload(1, ops)
    assert [o["value"] for o in hist.ops] == [ACK, ACK, 1, 2]


@pytest.mark.parametrize("seed", range(8))
def test_concurrent_mixed_ends_linearizable(seed):
    workloads = [
        [(PUSHL, 100 + seed), (POPR, None)],
        [(PUSHR, 200 + seed), (POPL, None)],
        [(PUSHL, 300 + seed), (PUSHR, 400 + seed)],
        [(POPR, None)],
    ]
    d, hist, _ = run_workload(4, workloads, seed=seed)
    assert is_linearizable(hist.ops, semantics="deque")
    pushed = {o["param"] for o in hist.ops if o["name"] in (PUSHL, PUSHR)}
    popped = {
        o["value"]
        for o in hist.ops
        if o["name"] in (POPL, POPR) and o["value"] != EMPTY
    }
    remaining = set(d.peek_deque())
    assert popped | remaining == pushed
    assert popped & remaining == set()


def test_same_side_elimination_fires():
    n = 8
    ops = [[(PUSHL, t)] if t % 2 == 0 else [(POPL, None)] for t in range(n)]
    d, hist, mem = run_workload(n, ops, seed=3)
    pushed = {o["param"] for o in hist.ops if o["name"] == PUSHL}
    popped = {o["value"] for o in hist.ops if o["name"] == POPL and o["value"] != EMPTY}
    assert set(d.peek_deque()) == pushed - popped
    assert mem.stats.pwb.get("combine", 0) < 2 * n


# ----------------------------------------------------------------- crash sweep
def _sweep(workloads, seed, mode, stride=1):
    steps = total_steps(workloads, seed=seed, structure=DFCDeque)
    failures = []
    outcomes = set()
    for k in range(1, steps, stride):
        res = run_with_crash(
            workloads, crash_at=k, seed=seed, mode=mode, structure=DFCDeque
        )
        assert res.crashed
        for tid, effect in res.took_effect.items():
            outcomes.add(effect)
            if effect:
                assert res.recovered[tid] is not BOT
                assert res.recovered[tid] != INIT
        if not check_durable_linearizability(res):
            failures.append(k)
    assert not failures, f"non-linearizable effective history at crash points {failures}"
    return outcomes


@pytest.mark.parametrize("mode", [CrashMode.MIN, CrashMode.MAX])
def test_exhaustive_crash_sweep_every_step(mode):
    """Every yield step of an in-flight pushL and popR (thread 0's ops)."""
    outcomes = _sweep(SMALL, seed=0, mode=mode, stride=1)
    assert outcomes == {True, False}  # detectability fires both ways


def test_random_eviction_crash_sweep():
    _sweep(SMALL, seed=1, mode=CrashMode.RANDOM, stride=2)


@pytest.mark.parametrize("seed", range(2))
def test_crash_sweep_larger(seed):
    workloads = [
        [(PUSHL if (t + i) % 2 else PUSHR, 100 * t + i) for i in range(2)]
        + [(POPL if t % 2 else POPR, None)]
        for t in range(4)
    ]
    _sweep(workloads, seed=seed, mode=CrashMode.RANDOM, stride=7)


def test_double_crash_during_recovery():
    steps = total_steps(SMALL, seed=2, structure=DFCDeque)
    for k in range(5, steps, 11):
        for rk in (3, 29):
            res = run_with_crash(
                SMALL,
                crash_at=k,
                seed=2,
                mode=CrashMode.RANDOM,
                recovery_crash_at=rk,
                structure=DFCDeque,
            )
            assert check_durable_linearizability(res)


def test_epoch_fixed_to_even_after_recovery():
    res = run_with_crash(SMALL, crash_at=40, seed=0, mode=CrashMode.MIN, structure=DFCDeque)
    assert res.mem.read("cEpoch", "v") % 2 == 0
