"""Per-side combiners (ISSUE 8): two-lane crash/differential suite.

A split (``split_lanes=True``) queue/deque shard commits its head-side and
tail-side announcement lanes independently — each lane has its own durable
record, its own epoch in the composite ``cEpoch`` pair, and its own
one-pfence commit — EXCEPT when the consuming side outruns the producing
side: a drained shard synchronizes both lanes through a single
crash-consistent HANDOFF commit (both epochs advance atomically, same
two-increment discipline as resharding).

This suite pins the mechanism three ways:

  * device equivalence — ``dfc_lane_combine_step`` is exactly the full
    combine of the lane-masked batch, ``dfc_handoff_combine_step`` exactly
    the full combine, across jnp / ref / pallas backends;
  * crash sweep — a crash injected at EVERY persistence op of a two-lane
    schedule (tail-only, head-only, mixed-handoff, and drained-upgrade
    phases, so both sides of the handoff commit are crash points) recovers
    to the ``sequential_hetero_reference`` oracle with verdict-identical,
    exactly-once replay;
  * the full {queue, deque} x {jnp, ref, pallas} grid runs under ``slow``;
    tier-1 keeps one fast representative per kind.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.dfc_checkpoint import CrashNow, FaultInjector, SimFS
from repro.core.jax_dfc import (
    LANE_HEAD,
    LANE_TAIL,
    OP_DEQ,
    OP_ENQ,
    OP_NONE,
    OP_POPL,
    OP_POPR,
    OP_PUSHL,
    OP_PUSHR,
    R_NONE,
    STRUCTS,
    lane_of_ops_host,
)
from repro.kernels.dfc_reduce.ops import (
    dfc_handoff_combine_step,
    dfc_lane_combine_step,
)
from repro.runtime.dfc_shard import (
    ShardedDFCRuntime,
    sequential_hetero_reference,
)

jax.config.update("jax_platform_name", "cpu")

CAP, LANES = 128, 16
BACKENDS = ["jnp", "ref", "pallas"]


# -------------------------------------------------- device-step equivalence
def _stacked(kind, n_shards):
    one = STRUCTS[kind].init(CAP)
    return jax.tree_util.tree_map(
        lambda x: jnp.stack([x] * n_shards), one
    )


def _mixed_batch(kind, rng, n):
    n_ops = STRUCTS[kind].n_opcodes
    ops = rng.integers(0, n_ops, (2, n)).astype(np.int32)
    params = (rng.random((2, n)) * 100).round(2).astype(np.float32)
    return jnp.asarray(ops), jnp.asarray(params)


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind", ["queue", "deque"])
def test_lane_step_is_masked_combine(kind, backend):
    """``dfc_lane_combine_step(lane)`` must equal the ordinary sharded
    combine applied to the host-masked batch (other-lane ops -> OP_NONE):
    the device masking and the host lane classifier agree op for op."""
    rng = np.random.default_rng(17)
    state = _stacked(kind, 2)
    # preload so head-side pops have something to consume
    pre = jnp.asarray(
        np.tile([OP_ENQ if kind == "queue" else OP_PUSHR], (2, 8)), jnp.int32
    )
    prep = jnp.asarray(rng.random((2, 8)).astype(np.float32))
    state, _, _ = dfc_handoff_combine_step(
        state, pre, prep, kind=kind, backend="jnp"
    )
    ops, params = _mixed_batch(kind, rng, 10)
    for lane in (LANE_HEAD, LANE_TAIL):
        got_state, got_resp, got_kinds = dfc_lane_combine_step(
            state, ops, params, kind=kind, lane=lane, backend=backend
        )
        masked = np.asarray(ops).copy()
        for s in range(2):
            keep = lane_of_ops_host(kind, masked[s]) == lane
            masked[s][~keep] = OP_NONE
        exp_state, exp_resp, exp_kinds = dfc_handoff_combine_step(
            state, jnp.asarray(masked), params, kind=kind, backend="jnp"
        )
        _assert_trees_equal(got_state, exp_state)
        np.testing.assert_allclose(
            np.asarray(got_resp), np.asarray(exp_resp), rtol=1e-6
        )
        np.testing.assert_array_equal(
            np.asarray(got_kinds), np.asarray(exp_kinds)
        )
        # other-lane positions come back R_NONE: nothing consumed them
        other = np.asarray(ops).copy()
        for s in range(2):
            mine = lane_of_ops_host(kind, other[s]) == lane
            assert np.all(
                np.asarray(got_kinds)[s][~mine & (other[s] != OP_NONE)]
                == R_NONE
            )


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind", ["queue", "deque"])
def test_handoff_step_is_full_combine(kind, backend):
    """The handoff step linearizes exactly like the unsplit fabric: it IS
    the one-lane combine of the same batch, on every backend."""
    rng = np.random.default_rng(29)
    state = _stacked(kind, 2)
    ops, params = _mixed_batch(kind, rng, 12)
    got = dfc_handoff_combine_step(
        state, ops, params, kind=kind, backend=backend
    )
    exp = jax.vmap(STRUCTS[kind].combine)(state, ops, params)
    _assert_trees_equal(got[0], exp[0])
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(exp[1]), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(exp[2]))


def test_queue_head_lane_leaves_values_untouched():
    """The pwb win in one assert: a head-only queue phase moves ONLY the
    head counter — values and tail counter are bit-identical, which is why
    the head lane's durable record never persists a values array."""
    state = _stacked("queue", 1)
    fill = jnp.asarray([[OP_ENQ] * 6], jnp.int32)
    state, _, _ = dfc_handoff_combine_step(
        state, fill, jnp.asarray([[1.0, 2, 3, 4, 5, 6]], jnp.float32),
        kind="queue", backend="jnp",
    )
    ops = jnp.asarray([[OP_DEQ, OP_DEQ, OP_NONE]], jnp.int32)
    params = jnp.zeros((1, 3), jnp.float32)
    new, resp, kinds = dfc_lane_combine_step(
        state, ops, params, kind="queue", lane=LANE_HEAD, backend="jnp"
    )
    np.testing.assert_array_equal(np.asarray(new.values), np.asarray(state.values))
    a = (int(new.epoch[0]) // 2) % 2
    b = (int(state.epoch[0]) // 2) % 2
    assert int(new.ends[0, a, 1]) == int(state.ends[0, b, 1])  # tail frozen
    assert int(new.ends[0, a, 0]) == int(state.ends[0, b, 0]) + 2
    np.testing.assert_allclose(np.asarray(resp[0, :2]), [1.0, 2.0])


# ----------------------------------------------------- two-lane crash sweep
# Single-thread, single-shard schedules that exercise every lane mode:
# tail-only phases, head-only phases, a mixed phase (handoff with live ops
# on both sides), and head-only phases that drain the shard to empty (the
# drained-upgrade handoff).  Push params are unique, so multiset equality
# of the final contents IS exactly-once.
def _lane_schedule(kind):
    if kind == "queue":
        E, D = OP_ENQ, OP_DEQ
        rows = [
            ([E] * 4, [1.0, 2.0, 3.0, 4.0]),        # tail-only
            ([E] * 3, [5.0, 6.0, 7.0]),             # tail-only
            ([D] * 3, [0.0] * 3),                   # head-only
            ([D] * 4, [0.0] * 4),                   # head-only, drains -> handoff
            ([E] * 2, [8.0, 9.0]),                  # tail again after handoff
            ([E, D], [10.0, 0.0]),                  # mixed -> handoff (live ops)
            ([D] * 2, [0.0] * 2),                   # drains again -> handoff
        ]
    else:
        rows = [
            ([OP_PUSHR] * 4, [1.0, 2.0, 3.0, 4.0]),  # tail-only
            ([OP_PUSHL] * 3, [5.0, 6.0, 7.0]),       # head-only
            ([OP_POPL] * 2, [0.0] * 2),              # head-only
            ([OP_POPR] * 2, [0.0] * 2),              # tail-only
            ([OP_POPL, OP_POPR, OP_POPL], [0.0] * 3),  # mixed drain -> handoff
            ([OP_PUSHR, OP_PUSHL], [8.0, 9.0]),      # refill, both lanes
        ]
    token = 0
    phases = []
    for ops, params in rows:
        token += 1
        phases.append((token, [7] * len(ops), ops, params))
    return phases


def _oracle(kind, phases, table):
    """Phase-by-phase sequential reference: expected (resp, kinds) per token
    plus the expected final contents."""
    lists = [[]]
    expected = {}
    for token, keys, ops, params in phases:
        expected[token] = sequential_hetero_reference(
            [kind], lists, keys, ops, params, LANES, table=table
        )
    return expected, sorted(lists[0])


def _run_split(tmp, crash_at, kind, backend):
    inj = FaultInjector(crash_at=crash_at)
    fs = SimFS(tmp, inj)
    rt = ShardedDFCRuntime(
        [kind], 1, CAP, LANES, fs=fs, n_threads=1, backend=backend,
        split_lanes=True,
    )
    phases = _lane_schedule(kind)
    expected, final = _oracle(kind, phases, rt.table)
    try:
        for token, keys, ops, params in phases:
            rt.announce(0, keys, ops, params, token=token)
            rt.combine_phase()
        rt.flush()
    except CrashNow:
        pass
    rt2, report = ShardedDFCRuntime.recover(
        fs.crash(), kind=[kind], n_shards=1, capacity=CAP, lanes=LANES,
        n_threads=1, backend=backend, split_lanes=True,
    )
    return rt2, report, phases, expected, final, inj.count


def _verify_split(rt2, report, phases, expected, final, kind):
    # lane epochs committed in pairs: every component even
    stats = rt2.lane_stats()
    assert stats is not None
    for pair in stats["epochs"].values():
        assert all(int(e) % 2 == 0 for e in pair)
    # verdict-identical: every APPLIED op's durable response equals the
    # oracle's response for that (token, op) — the detectability contract
    by_token = {tok: i for i, (tok, *_rest) in enumerate(phases)}
    r = report[0]
    for rec in ([r] if r["token"] is not None else []) + (
        [r["prev"]] if r.get("prev") else []
    ):
        tok = rec["token"]
        eresp, ekinds = expected[tok]
        for i, v in enumerate(rec["ops"]):
            if v.applied:
                assert v.kind == int(ekinds[i]), (tok, i)
                np.testing.assert_allclose(
                    float(v.resp), float(eresp[i]), rtol=1e-6
                )
    # exactly-once replay: not-applied ops re-announced, never-surfaced
    # phases re-driven; the single thread totally orders the schedule, so
    # the recovered fabric must land exactly on the oracle
    rt2.replay_pending(report)
    surfaced = r["token"] or 0
    for token, keys, ops, params in phases:
        if token > surfaced:
            rt2.announce(0, keys, ops, params, token=token)
            rt2.combine_phase()
    rt2.flush()
    got = sorted(rt2.shard_contents(0))
    assert got == final, "lost or duplicated ops across the two-lane crash"
    # the re-driven tail end produced oracle responses too
    last = phases[-1][0]
    val = rt2.read_responses(0, token=last)
    eresp, ekinds = expected[last]
    assert val is not None and val["kinds"] == [int(k) for k in ekinds]
    np.testing.assert_allclose(
        val["resp"], np.asarray(eresp, np.float32), rtol=1e-6
    )


def _sweep_split(tmp_path, kind, backend, step=1):
    rt_dry, report_dry, phases, expected, final, total = _run_split(
        tmp_path / "dry", None, kind, backend
    )
    _verify_split(rt_dry, report_dry, phases, expected, final, kind)
    assert total > 30, "schedule too small to exercise the commit protocol"
    for k in range(1, total + 1, step):
        rt2, report, phases, expected, final, _ = _run_split(
            tmp_path / f"k{k}", k, kind, backend
        )
        _verify_split(rt2, report, phases, expected, final, kind)


# ----------------------------------------------------------- tier-1 sweeps
def test_split_queue_crash_sweep_exactly_once(tmp_path):
    """Acceptance: every persistence op of a two-lane queue schedule — lane
    records, values, response publishes, and BOTH sides of the composite
    handoff commit (odd-pair write / fsync / even-pair write) — is a safe
    crash point."""
    _sweep_split(tmp_path, "queue", "jnp")


def test_split_deque_crash_sweep_exactly_once(tmp_path):
    """Two-lane deque twin: both lanes own values, so the sweep additionally
    crosses per-lane values persists and the max-phases values election in
    recovery."""
    _sweep_split(tmp_path, "deque", "jnp", step=2)


def test_split_handoff_crash_both_sides(tmp_path):
    """Directed: crash exactly AT the handoff commit's fsync boundary —
    before it (both lanes roll back to the pre-handoff pair) and after it
    (both round up committed).  Never a half-committed pair."""
    # Dry run pins the lane classifier against the schedule: of the 7 queue
    # phases, 4 advance the head lane (p3, p4, p6, p7) and 6 advance the
    # tail lane (p1, p2, p5 plus the three handoffs p4, p6, p7 — a handoff
    # moves BOTH lanes), each by the two-increment pair.
    rt, _, phases, expected, final, total = _run_split(
        tmp_path / "dry", None, "queue", "jnp"
    )
    pre = rt.lane_stats()["epochs"][0]
    assert pre == [4 * 2, 6 * 2], pre
    for k in range(1, total + 1):
        rt2, report, phases, expected, final, _ = _run_split(
            tmp_path / f"k{k}", k, "queue", "jnp"
        )
        eh, et = rt2.lane_stats()["epochs"][0]
        # the recovered pair is never torn across a handoff: a handoff
        # phase moves both components together, so any state where exactly
        # one component advanced must stem from a single-lane phase, whose
        # record says so
        assert eh % 2 == 0 and et % 2 == 0
        _verify_split(rt2, report, phases, expected, final, "queue")


def test_split_lane_recovery_preserves_serve_handoff(tmp_path):
    """The serving tier's arrivals ride the tail lane and admissions the
    head lane; a split tier recovers with its lane pairs intact."""
    from repro.launch.serve import RequestQueueTier

    tier = RequestQueueTier(
        n_queues=2, slots=2, capacity=256, lanes=16, durable=True,
        split_lanes=True, fs=SimFS(tmp_path),
    )
    assert tier.rt.split_lanes
    tier.submit([1, 2, 3, 4])
    admitted = tier.admit(2)
    tier.submit([], release_slots=[slot for _, slot in admitted])
    stats = tier.rt.lane_stats()
    assert stats and any(p != [0, 0] for p in stats["epochs"].values())


# ------------------------------------------------------------- slow grid
@pytest.mark.slow
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("kind", ["queue", "deque"])
def test_split_crash_sweep_grid(tmp_path, kind, backend):
    """Full two-lane crash sweep across {queue, deque} x backends."""
    _sweep_split(tmp_path, kind, backend)
