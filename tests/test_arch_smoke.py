"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train-step (grad + update) on CPU, asserting output shapes and
no NaNs.  Full configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models.model import decode_step, forward, init_cache, init_params, loss_fn

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 16


def make_batch(cfg, rng):
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["image_embeddings"] = (
            jax.random.normal(jax.random.PRNGKey(7), (B, cfg.n_img_tokens, cfg.d_model))
            * 0.02
        )
    if cfg.embedding_inputs:
        batch = {
            "embeddings": jax.random.normal(rng, (B, S, cfg.d_model)) * 0.02,
            "labels": toks,
        }
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    logits, _ = forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), "NaN/inf in logits"

    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)
    assert bool(jnp.isfinite(loss))
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(sq)) and float(sq) > 0
    # one SGD step changes the loss
    new_params = jax.tree.map(lambda p, g: p - 0.05 * g.astype(p.dtype), params, grads)
    loss2 = loss_fn(new_params, cfg, batch)
    assert bool(jnp.isfinite(loss2))
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, B, S)
    if cfg.family == "vlm":
        # image KV is zero in a fresh cache; still a valid decode
        pass
    step = (
        {"embeddings": jnp.zeros((B, 1, cfg.d_model))}
        if cfg.embedding_inputs
        else {"tokens": jnp.zeros((B, 1), jnp.int32)}
    )
    logits, cache = decode_step(params, cfg, cache, step)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["len"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count_matches_spec(arch):
    """Analytic parameter count of the FULL config lands near the advertised
    size (sanity check on the configuration numbers; wide tolerance since
    marketing names round aggressively)."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "llama-3.2-vision-11b": (8.5e9, 12.5e9),
        "zamba2-7b": (6.0e9, 8.8e9),
        "smollm-135m": (0.11e9, 0.16e9),
        "qwen2-1.5b": (1.2e9, 1.9e9),
        "olmo-1b": (0.9e9, 1.4e9),
        "deepseek-coder-33b": (30e9, 36e9),
        # backbone-only: the marketed 3.3B includes T5 cross-attn + codebook
        # embeddings, which the assignment stubs out (frontend)
        "musicgen-large": (2.2e9, 4.0e9),
        "arctic-480b": (430e9, 520e9),
        "dbrx-132b": (120e9, 145e9),
        "falcon-mamba-7b": (6.0e9, 8.5e9),
    }[arch]
    assert expected[0] <= n <= expected[1], f"{arch}: {n/1e9:.2f}B params"
