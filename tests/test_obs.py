"""Flight recorder: tracing is a pure observer of the durable path.

The ISSUE-7 acceptance criteria.  The obs package may never change what the
fabric persists: with tracing enabled, durable state must be BIT-IDENTICAL
and pwb/pfence counts (total and per tag) EXACTLY unchanged versus the
untraced run — on the serial pipelined path, the fused phase loop, and
through every crash point of the intent drain.  On top of that purity
gate, the recorder itself must be useful: the sidecar survives a crash as
a valid prefix with strictly monotone seq numbers, recovery EXTENDS it
with per-thread verdict events on the same timeline, and the metrics
registry yields sane percentiles and exporters.
"""

import json

import numpy as np
import pytest

import jax

from repro.checkpoint.dfc_checkpoint import CrashNow, FaultInjector, SimFS
from repro.core.jax_dfc import OP_ENQ
from repro.obs import (
    EV_EPOCH,
    EV_PFENCE,
    EV_PWB,
    EV_RECOVER,
    EV_VERDICT,
    FabricObserver,
    Histogram,
    MetricsRegistry,
    bridge_persist_stats,
    durable_digest,
    read_trace,
    to_chrome_trace,
)
from repro.runtime.dfc_shard import ShardedDFCRuntime, StaleTokenError

jax.config.update("jax_platform_name", "cpu")

CAP, LANES = 256, 16


def _schedule(n_rounds, n_threads, per_thread, seed=11):
    """Insert-only flat schedule with globally unique params (the
    exactly-once witness), round-major."""
    rng = np.random.default_rng(seed)
    val = 1.0
    sched = []
    for r in range(n_rounds):
        for t in range(n_threads):
            keys = [int(k) for k in rng.integers(0, 1000, per_thread)]
            params = [val + i for i in range(per_thread)]
            val += per_thread
            sched.append((t, r + 1, keys, [OP_ENQ] * per_thread, params))
    return sched


def _drive_fused(root, sched, *, n_threads, obs=None, injector=None):
    fs = SimFS(root, injector)
    rt = ShardedDFCRuntime(
        ["queue", "queue"], 2, CAP, LANES, fs=fs, n_threads=n_threads,
        obs=obs,
    )
    records = rt.phase_loop(sched)
    return fs, rt, records


def _drive_pipelined(root, sched, *, n_threads, obs=None):
    fs = SimFS(root)
    rt = ShardedDFCRuntime(
        ["queue", "queue"], 2, CAP, LANES, fs=fs, n_threads=n_threads,
        depth=2, obs=obs,
    )
    for (t, tok, keys, ops, params) in sched:
        rt.announce(t, keys, ops, params, token=tok)
        rt.combine_phase()
    rt.flush()
    return fs, rt


def _report_shape(report):
    """The comparable content of a recovery report (OpVerdicts flattened)."""
    shape = {}
    for t, r in report.items():
        shape[t] = {
            "token": r["token"],
            "applied": [bool(v.applied) for v in r["ops"]],
            "prev": None
            if not r.get("prev")
            else {
                "token": r["prev"]["token"],
                "applied": [bool(v.applied) for v in r["prev"]["ops"]],
            },
        }
    return shape


# ------------------------------------------------------------- purity gates
def test_traced_fused_run_is_bit_identical(tmp_path):
    """Fused phase loop: enabling the observer changes NOTHING durable —
    equal total stats, equal per-tag pstats, equal durable digest, equal
    records — while the trace itself is non-empty with monotone seqs."""
    sched = _schedule(3, 2, 4)
    fs1, _, recs1 = _drive_fused(tmp_path / "plain", sched, n_threads=2)
    obs = FabricObserver(root=tmp_path / "traced")
    fs2, _, recs2 = _drive_fused(
        tmp_path / "traced", sched, n_threads=2, obs=obs,
    )
    obs.flush()

    assert dict(fs1.stats) == dict(fs2.stats)
    assert fs1.pstats.as_dict() == fs2.pstats.as_dict()
    assert durable_digest(tmp_path / "plain") == durable_digest(
        tmp_path / "traced"
    )
    for a, b in zip(recs1, recs2):
        assert a["resp"] == b["resp"] and a["kinds"] == b["kinds"]

    events = read_trace(obs.trace_path)
    assert events, "observer recorded nothing"
    seqs = [e["seq"] for e in events]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    # the SimFS hooks mirror the real counters one-for-one
    assert sum(1 for e in events if e["ev"] == EV_PWB) == fs2.stats["pwb"]
    assert (
        sum(1 for e in events if e["ev"] == EV_PFENCE) == fs2.stats["pfence"]
    )


def test_traced_pipelined_run_is_bit_identical(tmp_path):
    """Same purity gate on the serial announce/combine/flush path."""
    sched = _schedule(3, 2, 4, seed=5)
    fs1, rt1 = _drive_pipelined(tmp_path / "plain", sched, n_threads=2)
    obs = FabricObserver(root=tmp_path / "traced")
    fs2, rt2 = _drive_pipelined(
        tmp_path / "traced", sched, n_threads=2, obs=obs,
    )
    obs.flush()
    assert dict(fs1.stats) == dict(fs2.stats)
    assert fs1.pstats.as_dict() == fs2.pstats.as_dict()
    assert durable_digest(tmp_path / "plain") == durable_digest(
        tmp_path / "traced"
    )
    for s in range(2):
        assert rt1.shard_contents(s) == rt2.shard_contents(s)


def test_read_responses_and_stale_token_unchanged_by_tracing(tmp_path):
    """Satellite (c): ``read_responses`` values and ``StaleTokenError``
    behavior are identical with the observer attached."""
    sched = _schedule(3, 2, 4, seed=3)
    vals = {}
    for name, obs in (
        ("plain", None),
        ("traced", FabricObserver(root=tmp_path / "traced")),
    ):
        _, rt, _ = _drive_fused(
            tmp_path / name, sched, n_threads=2, obs=obs,
        )
        for t in (0, 1):
            for tok in (2, 3):  # the two retained slots
                vals[(name, t, tok)] = rt.read_responses(t, token=tok)
            with pytest.raises(StaleTokenError):
                rt.read_responses(t, token=1)
    for t in (0, 1):
        for tok in (2, 3):
            a, b = vals[("plain", t, tok)], vals[("traced", t, tok)]
            assert a["resp"] == b["resp"] and a["kinds"] == b["kinds"]


# --------------------------------------------------------- crash + recovery
def test_crash_sweep_traced_matches_untraced(tmp_path):
    """Crash at EVERY persistence op of the fused drain with tracing on:
    the recovery report (per-thread verdicts) is identical to the untraced
    crash at the same op, the pre-crash sidecar is a valid JSONL prefix
    with monotone seqs, and recovery extends it with verdict events."""
    sched = _schedule(2, 2, 3, seed=42)
    # total op count from a dry (no-crash) run
    fs_dry, _, _ = _drive_fused(tmp_path / "dry", sched, n_threads=2)
    total = fs_dry.stats["pwb"] + fs_dry.stats["pfence"]
    assert total > 30

    for k in range(1, total + 1):
        reports = {}
        for name, traced in (("plain", False), ("traced", True)):
            root = tmp_path / f"k{k}_{name}"
            obs = FabricObserver(root=root) if traced else None
            inj = FaultInjector(crash_at=k)
            try:
                _drive_fused(
                    root, sched, n_threads=2, obs=obs, injector=inj,
                )
            except CrashNow:
                pass
            if traced:
                # the durable prefix: whatever flushed before the crash
                pre = read_trace(obs.trace_path)
                seqs = [e["seq"] for e in pre]
                assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
            fs2 = SimFS(root)
            obs2 = FabricObserver(root=root) if traced else None
            _, report = ShardedDFCRuntime.recover(
                fs2, kind=["queue", "queue"], n_shards=2, capacity=CAP,
                lanes=LANES, n_threads=2, obs=obs2,
            )
            reports[name] = _report_shape(report)
            if traced:
                post = read_trace(obs.trace_path)
                assert len(post) > len(pre), "recovery did not extend trace"
                seqs = [e["seq"] for e in post]
                assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
                stages = [
                    e["stage"] for e in post if e["ev"] == EV_RECOVER
                ]
                assert stages[-2:] == ["begin", "end"]
                n_verdicts = sum(1 for e in post if e["ev"] == EV_VERDICT)
                surfaced = sum(
                    1
                    for r in report.values()
                    if r["token"] is not None
                )
                assert n_verdicts == surfaced
        assert reports["plain"] == reports["traced"], f"verdicts diverge at op {k}"


def test_recovery_trace_continues_seq_numbering(tmp_path):
    """A fresh observer on an existing sidecar continues the seq timeline
    instead of restarting at 0 — crash forensics read as ONE ordered log."""
    sched = _schedule(2, 1, 3)
    obs = FabricObserver(root=tmp_path)
    _drive_fused(tmp_path, sched, n_threads=1, obs=obs)
    obs.flush()
    first = read_trace(obs.trace_path)
    obs2 = FabricObserver(root=tmp_path)
    fs2 = SimFS(tmp_path)
    ShardedDFCRuntime.recover(
        fs2, kind=["queue", "queue"], n_shards=2, capacity=CAP, lanes=LANES,
        n_threads=1, obs=obs2,
    )
    combined = read_trace(obs.trace_path)
    assert combined[: len(first)] == first  # strictly an extension
    assert combined[len(first)]["seq"] == first[-1]["seq"] + 1


def test_epoch_events_match_committed_epochs(tmp_path):
    """Every two-increment epoch commit lands one EV_EPOCH event whose
    final per-shard value equals the fabric's committed epoch."""
    sched = _schedule(3, 1, 4)
    obs = FabricObserver(root=tmp_path)
    _, rt, _ = _drive_fused(tmp_path, sched, n_threads=1, obs=obs)
    last = {}
    for e in obs.trace.events():
        if e["ev"] == EV_EPOCH:
            last[e["shard"]] = e["epoch"]
    for s, epoch in enumerate(rt.shard_epochs()):
        assert last.get(s, 0) == int(epoch)


# ------------------------------------------------------- metrics + exporters
def test_histogram_percentiles():
    h = Histogram()
    for v in range(1, 1001):  # 1..1000 ms
        h.record(float(v))
    s = h.summary()
    assert s["count"] == 1000
    assert s["min"] == 1.0 and s["max"] == 1000.0
    # log-bucketed: percentile lands within one quarter-octave of truth
    assert 400 <= s["p50"] <= 600
    assert 900 <= s["p99"] <= 1000
    assert abs(s["mean"] - 500.5) < 1e-6
    empty = Histogram()
    assert empty.percentile(0.5) == 0.0


def test_metrics_registry_snapshot_and_exporters(tmp_path):
    reg = MetricsRegistry()
    reg.counter("hits", shard=0)
    reg.counter("hits", 2, shard=0)
    reg.gauge("backlog", 7, shard=1)
    reg.observe("lat_ms", 4.0)
    snap = reg.snapshot()
    assert snap["counters"]["hits{shard=0}"] == 3
    assert snap["gauges"]["backlog{shard=1}"] == 7
    assert snap["histograms"]["lat_ms"]["count"] == 1
    n = reg.to_jsonl(tmp_path / "m.jsonl")
    lines = (tmp_path / "m.jsonl").read_text().splitlines()
    assert len(lines) == n and n == 3
    assert all(json.loads(line) for line in lines)


def test_chrome_trace_exporter(tmp_path):
    events = [
        {"seq": 0, "ts_us": 100, "ev": "announce", "thread": 1, "dur_us": 40},
        {"seq": 1, "ts_us": 200, "ev": "epoch_commit", "shard": 0},
    ]
    n = to_chrome_trace(events, tmp_path / "t.json")
    doc = json.loads((tmp_path / "t.json").read_text())
    assert n == 2 and len(doc) == 2  # bare-array Chrome trace format
    span, instant = doc
    assert span["ph"] == "X" and span["dur"] == 40 and span["ts"] == 60
    assert instant["ph"] == "i"


def test_bridge_persist_stats(tmp_path):
    fs = SimFS(tmp_path)
    fs.write("a", b"x", tag="announce")
    fs.fsync(["a"], tag="announce")
    fs.write("b", b"y")  # untagged -> default bucket
    reg = MetricsRegistry()
    bridge_persist_stats(reg, fs.pstats)
    c = reg.snapshot()["counters"]
    assert c["persist_pwb{tag=announce}"] == 1
    assert c["persist_pfence{tag=announce}"] == 1
    assert c["persist_pwb{tag=untagged}"] == 1
    assert c["persist_pwb_total"] == 2 and c["persist_pfence_total"] == 1


def test_persist_stats_snapshot_and_diff(tmp_path):
    fs = SimFS(tmp_path)
    fs.write("a", b"x", tag="slot")
    snap = fs.pstats.snapshot()
    fs.write("b", b"y", tag="slot")
    fs.fsync(["b"], tag="phase")
    d = fs.pstats.diff(snap)
    assert d.as_dict() == {"pwb": {"slot": 1}, "pfence": {"phase": 1}}
    assert snap.as_dict() == {"pwb": {"slot": 1}, "pfence": {}}  # immutable


# --------------------------------------------------------------- fabric_top
def test_fabric_top_renders_per_shard_table(tmp_path):
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))
    import fabric_top

    sched = _schedule(3, 1, 4)
    obs = FabricObserver(root=tmp_path)
    _drive_fused(tmp_path, sched, n_threads=1, obs=obs)
    obs.flush()
    events = read_trace(obs.trace_path)
    table = fabric_top.render(events)
    assert "shard" in table and "queue" in table
    assert "pwb" in table and "announce" in table
    agg = fabric_top.aggregate(events)
    assert sum(agg["pwb"].values()) == sum(
        1 for e in events if e["ev"] == EV_PWB
    )
    assert set(agg["commits"]) <= {0, 1}


# ------------------------------------------------------------- serving tier
def test_tier_latency_percentiles(tmp_path):
    """Satellite: the serving tier reports admission (and, once served,
    service/e2e) latency p50/p99 — and only when observed."""
    from repro.launch.serve import RequestQueueTier

    obs = FabricObserver()
    tier = RequestQueueTier(
        n_queues=2, slots=4, capacity=512, lanes=16, durable=True, obs=obs,
    )
    tier.submit([1, 2, 3, 4], [], None)
    admitted = tier.admit(4)
    assert admitted
    for sid, _slot in admitted:
        tier.mark_served(sid)
    stats = tier.latency_stats()
    assert stats is not None
    for name in ("admission_ms", "service_ms", "e2e_ms"):
        s = stats[name]
        assert s["count"] == len(admitted)
        assert 0 <= s["p50"] <= s["p99"]

    plain = RequestQueueTier(
        n_queues=2, slots=4, capacity=512, lanes=16, durable=True,
    )
    assert plain.latency_stats() is None
    plain.mark_served(1)  # no-op, not a crash


def test_tier_traced_run_is_bit_identical(tmp_path):
    """Purity holds through the serving tier too: identical durable stats
    and state with and without the observer."""
    from repro.launch.serve import RequestQueueTier

    waves = [([1, 2, 3], [], None), ([4, 5], [], None)]
    runs = {}
    for name, obs in (("plain", None), ("traced", FabricObserver())):
        fs = SimFS(tmp_path / name)
        tier = RequestQueueTier(
            n_queues=2, slots=2, capacity=512, lanes=16, durable=True,
            fs=fs, obs=obs,
        )
        rej = tier.submit_waves(waves)
        tier.admit(2)
        runs[name] = (rej, dict(fs.stats), fs.pstats.as_dict())
    assert runs["plain"] == runs["traced"]
    assert durable_digest(tmp_path / "plain") == durable_digest(
        tmp_path / "traced"
    )
