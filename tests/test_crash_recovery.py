"""Durable linearizability + detectability under crash injection.

Sweeps crash points across the whole execution (every scheduler step for the
small workload, sampled for bigger ones), under all three eviction
adversaries (MIN = only fenced writes survive, MAX = everything written
survives, RANDOM = arbitrary per-line prefix).  After recovery the effective
history (completed ops + taken-effect pending ops, with recovery-provided
responses) must be linearizable as a LIFO stack, including a full post-crash
drain of the recovered stack contents.
"""

import numpy as np
import pytest

from repro.core.dfc import POP, PUSH
from repro.core.harness import (
    check_durable_linearizability,
    run_with_crash,
    total_steps,
)
from repro.nvm.memory import CrashMode

SMALL = [
    [(PUSH, 11), (POP, None)],
    [(PUSH, 22), (PUSH, 23)],
    [(POP, None), (PUSH, 33)],
]


def _sweep(workloads, seed, mode, stride):
    steps = total_steps(workloads, seed=seed)
    failures = []
    for k in range(1, steps, stride):
        res = run_with_crash(workloads, crash_at=k, seed=seed, mode=mode)
        assert res.crashed
        if not check_durable_linearizability(res):
            failures.append(k)
    assert not failures, f"non-linearizable effective history at crash points {failures}"


@pytest.mark.parametrize("mode", [CrashMode.MIN, CrashMode.MAX])
def test_exhaustive_crash_sweep_small(mode):
    _sweep(SMALL, seed=0, mode=mode, stride=1)


def test_random_eviction_crash_sweep():
    _sweep(SMALL, seed=1, mode=CrashMode.RANDOM, stride=2)


@pytest.mark.parametrize("seed", range(3))
def test_crash_sweep_larger(seed):
    workloads = [
        [(PUSH, 100 * t + i) for i in range(2)] + [(POP, None)] for t in range(5)
    ]
    _sweep(workloads, seed=seed, mode=CrashMode.RANDOM, stride=7)


def test_double_crash_during_recovery():
    """The system may crash again while Recover runs (paper §2)."""
    steps = total_steps(SMALL, seed=2)
    for k in range(5, steps, 5):
        for rk in (3, 11, 29):
            res = run_with_crash(
                SMALL, crash_at=k, seed=2, mode=CrashMode.RANDOM, recovery_crash_at=rk
            )
            assert check_durable_linearizability(res)


def test_detectability_reports_effect():
    """Recovery must report taken-effect ops with their responses: after the
    combiner's final pfence of cEpoch (v+1 persisted), every combined op must
    be reported as taken-effect."""
    # crash very late: after epoch persist most ops have completed; the
    # harness cross-checks every pending op's report against linearizability,
    # so here we just assert the mechanism fires both ways across the sweep.
    outcomes = set()
    steps = total_steps(SMALL, seed=0)
    for k in range(1, steps, 3):
        res = run_with_crash(SMALL, crash_at=k, seed=0, mode=CrashMode.MIN)
        outcomes.update(res.took_effect.values())
    assert outcomes == {True, False}


def test_recovered_stack_is_consistent_state():
    """After recovery, stack contents equal pushed-minus-popped of the
    effective history for some linearization (checked via drain)."""
    workloads = [[(PUSH, 7 * t + i) for i in range(3)] for t in range(3)]
    steps = total_steps(workloads, seed=4)
    for k in range(10, steps, 13):
        res = run_with_crash(workloads, crash_at=k, seed=4, mode=CrashMode.RANDOM)
        assert check_durable_linearizability(res)


def test_epoch_fixed_to_even_after_recovery():
    res = run_with_crash(SMALL, crash_at=40, seed=0, mode=CrashMode.MIN)
    assert res.mem.read("cEpoch", "v") % 2 == 0
