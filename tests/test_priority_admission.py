"""Priority admission at the serving tier (ISSUE 5).

``RequestQueueTier(priority=True)`` runs its request shards as DEQUES:
normal arrivals join the back of the line (``OP_PUSH_BACK``), admission
drains the front (``OP_POP_FRONT``), and a high-priority session jumps the
line with a front-of-queue push (``OP_PUSH_FRONT``).  The oracle here is a
plain Python deque model; the tests check the tier against it — including
across a crash/recover of the serving tier, where the priority ORDER must
survive because it is fabric state, not launcher bookkeeping.
"""

import numpy as np
import pytest

import jax

from repro.checkpoint.dfc_checkpoint import CrashNow, FaultInjector, SimFS
from repro.launch.serve import RequestQueueTier

jax.config.update("jax_platform_name", "cpu")


def _drain(tier, total, slots=4):
    """Admit until the backlog empties, recycling slots; returns sid order."""
    order = []
    for _ in range(4 * total + 8):
        admitted = tier.admit(slots)
        order += [sid for sid, _ in admitted]
        tier.submit([], release_slots=[slot for _, slot in admitted])
        if len(order) >= total or tier.backlog() == 0:
            break
    return order


def _oracle(arrivals):
    """Python deque model: (sid, high) arrivals in submit order -> admit
    order.  Highs push left (front), lows push right; admission pops left."""
    from collections import deque

    d = deque()
    for sid, high in arrivals:
        if high:
            d.appendleft(sid)
        else:
            d.append(sid)
    return list(d)


def test_priority_oracle_front_of_queue():
    """Single request shard: admitted order equals the deque oracle —
    high-priority sessions dequeue ahead of the whole backlog, LIFO among
    themselves, lows stay FIFO."""
    arrivals = [(1, 0), (2, 0), (3, 1), (4, 0), (5, 1), (6, 0)]
    tier = RequestQueueTier(
        n_queues=1, slots=4, capacity=512, lanes=16, durable=True,
        priority=True,
    )
    for sid, high in arrivals:
        tier.submit([sid], priorities=[high])
    got = _drain(tier, len(arrivals))
    assert got == _oracle(arrivals) == [5, 3, 1, 2, 4, 6]


def test_priority_batch_submit_matches_oracle():
    """Mixed-priority batch submits linearize like per-phase oracle steps
    (within one phase: front pushes land LIFO, back pushes FIFO)."""
    tier = RequestQueueTier(
        n_queues=1, slots=8, capacity=512, lanes=16, durable=True,
        priority=True,
    )
    tier.submit([1, 2, 3, 4], priorities=[0, 1, 0, 1])
    got = _drain(tier, 4, slots=8)
    assert got == _oracle([(1, 0), (2, 1), (3, 0), (4, 1)]) == [4, 2, 1, 3]


def test_fifo_tier_rejects_priorities():
    tier = RequestQueueTier(n_queues=1, slots=2, capacity=256, lanes=8)
    with pytest.raises(ValueError):
        tier.submit([1], priorities=[1])


def test_priority_multi_shard_front_of_line_per_shard():
    """With several request shards, priority is front-of-THEIR-queue: in the
    admitted order, no high-priority session follows a low of the SAME
    shard that arrived before it."""
    tier = RequestQueueTier(
        n_queues=3, slots=4, capacity=512, lanes=16, durable=True,
        priority=True,
    )
    lows = [1, 2, 3, 4, 5, 6]
    highs = [7, 8, 9]
    tier.submit(lows)
    tier.submit(highs, priorities=[1] * len(highs))
    shard_of = {
        sid: int(tier.rt.route_host([tier.session_key(sid)])[0])
        for sid in lows + highs
    }
    got = _drain(tier, len(lows) + len(highs))
    assert sorted(got) == sorted(lows + highs)
    for s in set(shard_of.values()):
        per_shard = [sid for sid in got if shard_of[sid] == s]
        shard_highs = [sid for sid in per_shard if sid in highs]
        shard_lows = [sid for sid in per_shard if sid in lows]
        if shard_highs and shard_lows:
            last_high = max(per_shard.index(h) for h in shard_highs)
            first_low = min(per_shard.index(l) for l in shard_lows)
            assert last_high < first_low, (s, per_shard)


def test_priority_survives_crash_recover():
    """Priority order is fabric state: restart the tier from its durable
    root mid-backlog and the high-priority sessions still dequeue first."""
    arrivals = [(1, 0), (2, 0), (3, 0), (4, 1), (5, 1)]
    tier = RequestQueueTier(
        n_queues=1, slots=4, capacity=512, lanes=16, durable=True,
        priority=True,
    )
    for sid, high in arrivals:
        tier.submit([sid], priorities=[high])
    fs = tier.rt.fs
    tier2, info = RequestQueueTier.recover(
        fs, n_queues=1, capacity=512, lanes=16, priority=True
    )
    assert info["queued"] == _oracle(arrivals) == [5, 4, 1, 2, 3]
    assert info["in_flight"] == [] and info["lost_arrivals"] == []
    assert sorted(info["pool"]) == [0, 1, 2, 3]
    got = _drain(tier2, len(arrivals))
    assert got == [5, 4, 1, 2, 3]


def _simfs_tmp(crash_at=None):
    import tempfile
    from pathlib import Path

    return SimFS(
        Path(tempfile.mkdtemp(prefix="dfc_prio_")),
        FaultInjector(crash_at=crash_at),
    )


LOWS, HIGHS = [1, 2, 3], [4, 5]


def _drive_priority(fs, served):
    """Submit lows then highs, drain with 2 slots; admitted sids append to
    ``served`` IN PLACE as they are admitted (the launcher's served-log
    analogue), so a crash mid-drain keeps the pre-crash record."""
    tier = RequestQueueTier(
        n_queues=1, slots=2, capacity=512, lanes=16, durable=True,
        fs=fs, priority=True,
    )
    tier.submit(LOWS)
    tier.submit(HIGHS, priorities=[1] * len(HIGHS))
    for _ in range(32):
        admitted = tier.admit(2)
        served += [sid for sid, _ in admitted]
        tier.submit([], release_slots=[slot for _, slot in admitted])
        if tier.backlog() == 0:
            break


def _priority_crash_sweep(step):
    """Crash at every ``step``-th persistence op of the priority schedule:
    recover + launcher-style reconciliation must serve every session exactly
    once with every high-priority session ahead of every low."""
    dry_fs, dry_served = _simfs_tmp(), []
    _drive_priority(dry_fs, dry_served)
    assert dry_served == [5, 4, 1, 2, 3]
    total = dry_fs.injector.count
    assert total > 40
    for k in range(1, total + 1, step):
        fs = _simfs_tmp(crash_at=k)
        served = []
        try:
            _drive_priority(fs, served)
        except CrashNow:
            pass
        tier2, info = RequestQueueTier.recover(
            fs.crash(), n_queues=1, capacity=512, lanes=16, priority=True
        )
        # launcher-style reconciliation (mirrors repro.launch.serve.main):
        # in-flight dequeues count as served (deduped), lost enqueues are
        # resubmitted with their original priority, the pool is rebuilt
        served += [s for s in info["in_flight"] if s not in served]
        accounted = set(served) | set(info["queued"])
        missing = [s for s in LOWS + HIGHS if s not in accounted]
        if missing:
            tier2.submit(
                missing, priorities=[int(s in HIGHS) for s in missing]
            )
        pool = tier2.pool_slots()
        free = [i for i in range(2) if i not in set(pool)][: 2 - len(pool)]
        if free:
            tier2.submit([], release_slots=free)
        for _ in range(32):
            admitted = tier2.admit(2)
            served += [sid for sid, _ in admitted if sid not in served]
            tier2.submit([], release_slots=[slot for _, slot in admitted])
            if tier2.backlog() == 0:
                break
        assert sorted(served) == sorted(LOWS + HIGHS), (k, served)
        assert len(served) == len(set(served)), (k, served)
        # front-of-queue invariant: lows are only ever admitted once no high
        # is waiting — highs always sit in front of lows in the fabric, and
        # the drain never starts before both submits, so every high precedes
        # every low in the final admission order
        assert max(served.index(h) for h in HIGHS) < min(
            served.index(l) for l in LOWS
        ), (k, served)


def test_priority_crash_sweep_exactly_once_in_order():
    """Tier-1 representative: strided sweep of the priority crash points."""
    _priority_crash_sweep(step=5)


@pytest.mark.slow
def test_priority_crash_sweep_full():
    """Full ISSUE-5 sweep: EVERY persistence op of the priority schedule is
    a safe crash point for order + exactly-once."""
    _priority_crash_sweep(step=1)
