"""Model correctness: decode/prefill consistency with full-sequence forward,
across every architecture family (reduced configs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.model import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)

jax.config.update("jax_platform_name", "cpu")

B, S, V = 2, 12, 64


def cfgs():
    return {
        "dense": ModelConfig(
            name="dense", family="dense", n_layers=3, d_model=32, n_heads=4,
            n_kv_heads=2, d_ff=64, vocab=V, remat="none", dtype="float32",
        ),
        "qkvbias": ModelConfig(
            name="qkvbias", family="dense", n_layers=2, d_model=32, n_heads=4,
            n_kv_heads=2, d_ff=64, vocab=V, qkv_bias=True, remat="none",
            dtype="float32",
        ),
        "lnp": ModelConfig(
            name="lnp", family="dense", n_layers=2, d_model=32, n_heads=4,
            n_kv_heads=4, d_ff=64, vocab=V, norm="layernorm_np", remat="none",
            dtype="float32",
        ),
        "moe": ModelConfig(
            name="moe", family="moe", n_layers=2, d_model=32, n_heads=4,
            n_kv_heads=4, d_ff=64, vocab=V, n_experts=4, top_k=2, moe_dff=48,
            dense_residual=True, remat="none", dtype="float32",
            # decode == forward only when nothing overflows the capacity
            # buffer: full-sequence dispatch drops overflow assignments,
            # per-token decode (tiny T) never does.  2.5 * T*k/e covers the
            # worst routing imbalance at B=2, S=12.
            capacity_factor=2.5,
        ),
        "ssm": ModelConfig(
            name="ssm", family="ssm", n_layers=3, d_model=32, n_heads=1,
            n_kv_heads=1, d_ff=0, vocab=V, ssm_version=1, ssm_state=4,
            remat="none", dtype="float32",
        ),
        "hybrid": ModelConfig(
            name="hybrid", family="hybrid", n_layers=5, d_model=32, n_heads=4,
            n_kv_heads=4, d_ff=64, vocab=V, ssm_version=2, ssm_state=8,
            ssm_head_dim=16, attn_every=2, remat="none", dtype="float32",
        ),
        "vlm": ModelConfig(
            name="vlm", family="vlm", n_layers=10, d_model=32, n_heads=4,
            n_kv_heads=2, d_ff=64, vocab=V, cross_attn_every=5, n_img_tokens=8,
            remat="none", dtype="float32",
        ),
        "audio": ModelConfig(
            name="audio", family="audio", n_layers=2, d_model=32, n_heads=4,
            n_kv_heads=4, d_ff=64, vocab=V, embedding_inputs=True, mlp="gelu",
            remat="none", dtype="float32",
        ),
    }


def make_batch(cfg, rng):
    toks = jax.random.randint(rng, (B, S), 0, V)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["image_embeddings"] = jax.random.normal(
            jax.random.PRNGKey(7), (B, cfg.n_img_tokens, cfg.d_model)
        )
    if cfg.embedding_inputs:
        batch = {
            "embeddings": jax.random.normal(rng, (B, S, cfg.d_model)),
            "labels": toks,
        }
    return batch


@pytest.mark.parametrize("name", list(cfgs().keys()))
def test_forward_and_loss_finite(name):
    cfg = cfgs()[name]
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, _ = forward(params, cfg, batch)
    assert logits.shape == (B, S, V)
    assert bool(jnp.all(jnp.isfinite(logits)))
    grads = jax.grad(lambda p: loss_fn(p, cfg, batch))(params)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)


@pytest.mark.parametrize("name", ["dense", "qkvbias", "lnp", "moe", "ssm", "hybrid", "vlm"])
def test_incremental_decode_matches_forward(name):
    """Token-by-token decode must reproduce the full causal forward."""
    cfg = cfgs()[name]
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    ref_logits, _ = forward(params, cfg, batch)

    cache = init_cache(cfg, B, S + 4)
    outs = []
    for i in range(S):
        step_batch = {"tokens": batch["tokens"][:, i : i + 1]}
        if cfg.family == "vlm":
            if i == 0:
                # image KV must be filled: run prefill on the first token
                lg, cache = prefill(
                    params, cfg, dict(batch, tokens=batch["tokens"][:, :1]), S + 4
                )
                outs.append(lg)
                continue
        lg, cache = decode_step(params, cfg, cache, step_batch)
        outs.append(lg)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref_logits), rtol=2e-2, atol=2e-3)


@pytest.mark.parametrize("name", ["dense", "ssm", "hybrid", "vlm"])
def test_prefill_then_decode_matches_forward(name):
    cfg = cfgs()[name]
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    ref_logits, _ = forward(params, cfg, batch)

    half = S // 2
    pre_batch = dict(batch, tokens=batch["tokens"][:, :half])
    last, cache = prefill(params, cfg, pre_batch, S + 4)
    np.testing.assert_allclose(
        np.asarray(last[:, 0]), np.asarray(ref_logits[:, half - 1]), rtol=2e-2, atol=2e-3
    )
    lg, cache = decode_step(
        params, cfg, cache, {"tokens": batch["tokens"][:, half : half + 1]}
    )
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(ref_logits[:, half]), rtol=2e-2, atol=2e-3
    )


def test_ring_window_decode_matches_windowed_forward():
    """Rolling-window decode == full forward with the same sliding window."""
    cfg = cfgs()["dense"]
    W = 6
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    # reference: full attention with sliding-window mask
    from repro.models import model as M
    from repro.models.layers import apply_norm, attention_block, mlp_block

    h = params["embed"][batch["tokens"]]
    positions = jnp.arange(S)

    def body(carry, bp):
        hh = carry
        x = apply_norm(cfg.norm, hh, bp["norm1"])
        out, _ = attention_block(x, bp["attn"], cfg, positions, window=W)
        hh = hh + out
        x = apply_norm(cfg.norm, hh, bp["norm2"])
        return hh + mlp_block(x, bp["mlp"], cfg.mlp), None

    h, _ = jax.lax.scan(body, h, params["blocks"])
    ref = M._logits(params, cfg, h)

    cache = init_cache(cfg, B, S, window=W)
    outs = []
    for i in range(S):
        lg, cache = decode_step(
            params, cfg, cache, {"tokens": batch["tokens"][:, i : i + 1]}, window=W
        )
        outs.append(lg)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-2, atol=2e-3)


def test_mamba_state_continuation():
    """Splitting a sequence into prefill + decode must equal one full scan."""
    cfg = cfgs()["ssm"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    ref_logits, _ = forward(params, cfg, batch)
    _, cache = prefill(params, cfg, dict(batch, tokens=batch["tokens"][:, : S - 1]), S)
    lg, _ = decode_step(params, cfg, cache, {"tokens": batch["tokens"][:, S - 1 :]})
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(ref_logits[:, -1]), rtol=2e-2, atol=2e-3
    )
