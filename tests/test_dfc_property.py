"""Hypothesis property tests on the paper-faithful DFC stack's invariants."""

from _compat import hypothesis, st

from repro.core.baselines import run_dfc_counts
from repro.core.dfc import ACK, EMPTY, POP, PUSH, DFCStack
from repro.core.harness import check_durable_linearizability, run_with_crash, total_steps
from repro.core.linearize import is_linearizable
from repro.core.sim import History, Scheduler, workload_gen
from repro.nvm.memory import CrashMode, NVMemory


def _workloads(op_codes, n_threads):
    """op_codes: list of lists of 0/1 per thread (1=push)."""
    out, uid = [], 0
    for t in range(n_threads):
        ops = []
        for c in op_codes[t]:
            uid += 1
            ops.append((PUSH, 1000 + uid) if c else (POP, None))
        out.append(ops)
    return out


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(
    st.lists(
        st.lists(st.integers(0, 1), min_size=1, max_size=3),
        min_size=2,
        max_size=4,
    ),
    st.integers(0, 2**16),
)
def test_property_crash_free_linearizable(op_codes, seed):
    w = _workloads(op_codes, len(op_codes))
    mem = NVMemory()
    stack = DFCStack(mem, len(w))
    sched = Scheduler(seed=seed)
    hist = History()
    gens = {t: workload_gen(stack, sched, hist, t, w[t]) for t in range(len(w))}
    sched.run(gens)
    assert is_linearizable(hist.ops)
    # conservation
    pushed = {o["param"] for o in hist.ops if o["name"] == PUSH}
    popped = {o["value"] for o in hist.ops if o["name"] == POP and o["value"] != EMPTY}
    assert popped | set(stack.peek_stack()) == pushed
    # announce-path persistence is exactly 2 pwb + 2 pfence per op (L9, L11)
    n_ops = sum(len(x) for x in w)
    assert mem.stats.pwb["announce"] == 2 * n_ops
    assert mem.stats.pfence["announce"] == 2 * n_ops
    # epoch is even and equals 2x phases
    assert mem.read("cEpoch", "v") == 2 * stack.phases


@hypothesis.settings(max_examples=12, deadline=None)
@hypothesis.given(
    st.lists(
        st.lists(st.integers(0, 1), min_size=1, max_size=2),
        min_size=2,
        max_size=3,
    ),
    st.integers(0, 2**10),
    st.floats(0.05, 0.95),
    st.sampled_from([CrashMode.MIN, CrashMode.MAX, CrashMode.RANDOM]),
)
def test_property_durable_under_random_crash(op_codes, seed, frac, mode):
    w = _workloads(op_codes, len(op_codes))
    steps = total_steps(w, seed=seed)
    crash_at = max(1, int(steps * frac))
    res = run_with_crash(w, crash_at=crash_at, seed=seed, mode=mode)
    assert check_durable_linearizability(res)
