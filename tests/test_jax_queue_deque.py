"""Vectorized JAX DFC queue/deque combine: semantics vs the sequential
oracles, Pallas kernels vs pure-jnp refs (interpret mode), property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import hypothesis, st

from repro.core.jax_dfc import (
    OP_DEQ,
    OP_ENQ,
    OP_NONE,
    OP_POPL,
    OP_POPR,
    OP_PUSHL,
    OP_PUSHR,
    R_ACK,
    R_EMPTY,
    R_NONE,
    R_VALUE,
    combine_deque,
    combine_queue,
    init_deque,
    init_queue,
    sequential_reference_deque,
    sequential_reference_queue,
)
from repro.kernels.dfc_reduce.ops import dfc_deque_combine_step, dfc_queue_combine_step

jax.config.update("jax_platform_name", "cpu")

LANE_COUNTS = (1, 7, 64, 256)


def _ring_contents(state):
    cap = state.values.shape[0]
    e = state.active_ends()
    lo, hi = int(e[0]), int(e[1])
    return [float(state.values[i % cap]) for i in range(lo, hi)]


def apply_queue_batches(batches, capacity, via="jnp"):
    state = init_queue(capacity)
    ref = []
    for ops, params in batches:
        ops_a = jnp.asarray(ops, jnp.int32)
        par_a = jnp.asarray(params, jnp.float32)
        if via == "jnp":
            state, resp, kinds = combine_queue(state, ops_a, par_a)
        else:
            state, resp, kinds = dfc_queue_combine_step(state, ops_a, par_a, backend=via)
        ref, ref_resp, ref_kinds = sequential_reference_queue(ref, ops, params)
        np.testing.assert_array_equal(np.asarray(kinds), ref_kinds)
        np.testing.assert_allclose(
            np.asarray(resp), np.asarray(ref_resp, np.float32), rtol=1e-6
        )
    np.testing.assert_allclose(_ring_contents(state), ref)
    assert int(state.epoch) == 2 * len(batches)
    return state


def apply_deque_batches(batches, capacity, via="jnp"):
    state = init_deque(capacity)
    ref = []
    for ops, params in batches:
        ops_a = jnp.asarray(ops, jnp.int32)
        par_a = jnp.asarray(params, jnp.float32)
        if via == "jnp":
            state, resp, kinds = combine_deque(state, ops_a, par_a)
        else:
            state, resp, kinds = dfc_deque_combine_step(state, ops_a, par_a, backend=via)
        ref, ref_resp, ref_kinds = sequential_reference_deque(ref, ops, params)
        np.testing.assert_array_equal(np.asarray(kinds), ref_kinds)
        np.testing.assert_allclose(
            np.asarray(resp), np.asarray(ref_resp, np.float32), rtol=1e-6
        )
    np.testing.assert_allclose(_ring_contents(state), ref)
    assert int(state.epoch) == 2 * len(batches)
    return state


# ------------------------------------------------------------------ queue
@pytest.mark.parametrize("n", LANE_COUNTS)
def test_queue_all_enq(n):
    apply_queue_batches([([OP_ENQ] * n, list(range(1, n + 1)))], capacity=2 * n + 8)


@pytest.mark.parametrize("n", LANE_COUNTS)
def test_queue_all_deq_empty(n):
    state = init_queue(2 * n)
    _, resp, kinds = combine_queue(
        state, jnp.full((n,), OP_DEQ, jnp.int32), jnp.zeros(n)
    )
    assert all(k == R_EMPTY for k in np.asarray(kinds))


def test_queue_fifo_across_batches():
    apply_queue_batches(
        [
            ([OP_ENQ] * 4, [1, 2, 3, 4]),
            ([OP_DEQ] * 2 + [OP_NONE] * 2, [0] * 4),
            ([OP_ENQ, OP_DEQ, OP_DEQ, OP_DEQ], [9, 0, 0, 0]),
        ],
        capacity=64,
    )


def test_queue_two_sided_elimination():
    """Deqs beyond the committed size are served directly from same-batch
    enqs (announcement-to-announcement), FIFO by rank."""
    ops = [OP_DEQ, OP_ENQ, OP_DEQ, OP_ENQ]
    state = init_queue(32)
    new_state, resp, kinds = combine_queue(
        state, jnp.asarray(ops, jnp.int32), jnp.asarray([0, 5.0, 0, 7.0], jnp.float32)
    )
    assert list(np.asarray(kinds)) == [R_VALUE, R_ACK, R_VALUE, R_ACK]
    assert list(np.asarray(resp)[[0, 2]]) == [5.0, 7.0]
    # fully eliminated: the ring was never touched
    assert int(new_state.active_size()) == 0
    np.testing.assert_array_equal(np.asarray(new_state.values), 0.0)


def test_queue_ring_wraps():
    """head/tail counters advance monotonically; slots wrap mod capacity."""
    n, cap = 8, 16  # contract: capacity >= committed size + lanes
    batches = []
    for r in range(6):  # 6 rounds of enq-then-deq churns the window around
        batches.append(([OP_ENQ] * n, [float(10 * r + i) for i in range(n)]))
        batches.append(([OP_DEQ] * n, [0.0] * n))
    state = apply_queue_batches(batches, capacity=cap)
    assert int(state.active_ends()[0]) == 6 * n  # counters, not slots


def test_queue_full_capacity():
    """Fill the ring to capacity (size + lanes == capacity edge)."""
    n = 8
    cap = 3 * n
    state = apply_queue_batches(
        [
            ([OP_ENQ] * n, [float(i) for i in range(n)]),
            ([OP_ENQ] * n, [float(100 + i) for i in range(n)]),
            ([OP_DEQ] * n, [0.0] * n),
            ([OP_ENQ] * n, [float(200 + i) for i in range(n)]),
        ],
        capacity=cap,
    )
    assert int(state.active_size()) == 2 * n


def test_queue_committed_window_never_overwritten():
    """Crash-consistency invariant of the double-buffered (head, tail): a
    combine only writes ring slots outside the committed window."""
    cap = 32
    state = init_queue(cap)
    state, _, _ = combine_queue(
        state, jnp.full((4,), OP_ENQ, jnp.int32), jnp.arange(1.0, 5.0)
    )
    committed = np.asarray(state.values).copy()
    e = state.active_ends()
    lo, hi = int(e[0]), int(e[1])
    window_slots = [i % cap for i in range(lo, hi)]
    # a mixed batch (deqs + enqs) must leave the committed slots bit-identical
    state2, _, _ = combine_queue(
        state,
        jnp.asarray([OP_DEQ, OP_ENQ, OP_ENQ, OP_DEQ], jnp.int32),
        jnp.asarray([0.0, 9.0, 8.0, 0.0], jnp.float32),
    )
    after = np.asarray(state2.values)
    np.testing.assert_array_equal(after[window_slots], committed[window_slots])
    # the previous (head, tail) pair is still intact in the inactive buffer
    prev = state2.ends[(int(state2.epoch) // 2 + 1) % 2]
    assert (int(prev[0]), int(prev[1])) == (lo, hi)


@pytest.mark.parametrize("n", LANE_COUNTS)
@pytest.mark.parametrize("via", ["jnp", "pallas"])
def test_queue_random_mix_matches_oracle(n, via):
    rng = np.random.default_rng(n)
    batches = []
    for _ in range(3):
        ops = rng.integers(0, 3, n).tolist()
        params = (rng.random(n) * 100).round(2).tolist()
        batches.append((ops, params))
    apply_queue_batches(batches, capacity=4 * n + 8, via=via)


# ------------------------------------------------------------------ deque
@pytest.mark.parametrize("n", LANE_COUNTS)
def test_deque_all_push_both_ends(n):
    ops = [(OP_PUSHL if i % 2 else OP_PUSHR) for i in range(n)]
    apply_deque_batches([(ops, list(range(1, n + 1)))], capacity=2 * n + 8)


@pytest.mark.parametrize("n", LANE_COUNTS)
def test_deque_all_pop_empty(n):
    ops = [(OP_POPL if i % 2 else OP_POPR) for i in range(n)]
    state = init_deque(2 * n)
    _, resp, kinds = combine_deque(
        state, jnp.asarray(ops, jnp.int32), jnp.zeros(n)
    )
    assert all(k == R_EMPTY for k in np.asarray(kinds))


def test_deque_same_side_elimination():
    ops = [OP_PUSHL, OP_POPL, OP_PUSHR, OP_POPR]
    state = init_deque(32)
    state, resp, kinds = combine_deque(
        state,
        jnp.asarray(ops, jnp.int32),
        jnp.asarray([5.0, 0, 7.0, 0], jnp.float32),
    )
    assert list(np.asarray(kinds)) == [R_ACK, R_VALUE, R_ACK, R_VALUE]
    assert list(np.asarray(resp)[[1, 3]]) == [5.0, 7.0]
    assert int(state.active_size()) == 0


def test_deque_right_pops_consume_left_pushes():
    """The canonical witness applies the left surplus first, so a right pop
    can return a value pushed left in the same phase."""
    ops = [OP_PUSHL, OP_POPR, OP_POPR]
    state = init_deque(32)
    state, _, _ = combine_deque(
        state, jnp.asarray([OP_PUSHR], jnp.int32), jnp.asarray([1.0], jnp.float32)
    )
    state, resp, kinds = combine_deque(
        state, jnp.asarray(ops, jnp.int32), jnp.asarray([2.0, 0, 0], jnp.float32)
    )
    assert list(np.asarray(kinds)) == [R_ACK, R_VALUE, R_VALUE]
    assert list(np.asarray(resp)[[1, 2]]) == [1.0, 2.0]  # committed, then pushed-left


def test_deque_window_grows_left():
    n = 4
    state = apply_deque_batches(
        [([OP_PUSHL] * n, [1.0, 2.0, 3.0, 4.0])], capacity=16
    )
    assert int(state.active_ends()[0]) == -n  # left counter went negative


def test_deque_committed_window_never_overwritten():
    cap = 32
    state = init_deque(cap)
    ops0 = [OP_PUSHL, OP_PUSHR, OP_PUSHL, OP_PUSHR]
    state, _, _ = combine_deque(
        state, jnp.asarray(ops0, jnp.int32), jnp.arange(1.0, 5.0)
    )
    committed = np.asarray(state.values).copy()
    e = state.active_ends()
    lo, hi = int(e[0]), int(e[1])
    window_slots = [i % cap for i in range(lo, hi)]
    state2, _, _ = combine_deque(
        state,
        jnp.asarray([OP_PUSHL, OP_POPR, OP_PUSHR, OP_POPL], jnp.int32),
        jnp.asarray([9.0, 0.0, 8.0, 0.0], jnp.float32),
    )
    after = np.asarray(state2.values)
    np.testing.assert_array_equal(after[window_slots], committed[window_slots])
    prev = state2.ends[(int(state2.epoch) // 2 + 1) % 2]
    assert (int(prev[0]), int(prev[1])) == (lo, hi)


@pytest.mark.parametrize("n", LANE_COUNTS)
@pytest.mark.parametrize("via", ["jnp", "pallas"])
def test_deque_random_mix_matches_oracle(n, via):
    rng = np.random.default_rng(1000 + n)
    batches = []
    for _ in range(3):
        ops = rng.integers(0, 5, n).tolist()
        params = (rng.random(n) * 100).round(2).tolist()
        batches.append((ops, params))
    apply_deque_batches(batches, capacity=4 * n + 8, via=via)


# ------------------------------------------------------------------ properties
@hypothesis.settings(max_examples=30, deadline=None)
@hypothesis.given(
    st.lists(
        st.tuples(st.integers(0, 2), st.floats(1.0, 1e4)),
        min_size=1,
        max_size=24,
    ),
    st.integers(0, 3),
)
def test_property_queue_matches_sequential_witness(lanes, n_batches):
    ops = [o for o, _ in lanes]
    params = [p for _, p in lanes]
    batches = [(ops, params)] * (n_batches + 1)
    apply_queue_batches(batches, capacity=max(128, 32 * len(lanes)))


@hypothesis.settings(max_examples=30, deadline=None)
@hypothesis.given(
    st.lists(
        st.tuples(st.integers(0, 4), st.floats(1.0, 1e4)),
        min_size=1,
        max_size=24,
    ),
    st.integers(0, 3),
)
def test_property_deque_matches_sequential_witness(lanes, n_batches):
    ops = [o for o, _ in lanes]
    params = [p for _, p in lanes]
    batches = [(ops, params)] * (n_batches + 1)
    apply_deque_batches(batches, capacity=max(128, 32 * len(lanes)))


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(st.data())
def test_property_deque_conservation(data):
    """Across arbitrary batches: pushed = popped + remaining (multisets)."""
    rng_ops = data.draw(
        st.lists(
            st.lists(st.integers(0, 4), min_size=4, max_size=16),
            min_size=1,
            max_size=4,
        )
    )
    state = init_deque(512)
    uid = 1.0
    pushed, popped = [], []
    for ops in rng_ops:
        params = []
        for o in ops:
            is_push = o in (OP_PUSHL, OP_PUSHR)
            params.append(uid if is_push else 0.0)
            if is_push:
                pushed.append(uid)
                uid += 1.0
        state, resp, kinds = combine_deque(
            state, jnp.asarray(ops, jnp.int32), jnp.asarray(params, jnp.float32)
        )
        popped += [
            float(v) for v, k in zip(np.asarray(resp), np.asarray(kinds)) if k == R_VALUE
        ]
    assert sorted(popped + _ring_contents(state)) == sorted(pushed)
