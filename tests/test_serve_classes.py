"""k priority classes at the serving tier (ISSUE 10).

``RequestQueueTier(k_classes=k)`` generalizes the binary ``priority=True``
path: each class gets its own FIFO request shard (shard c == class c) and
admission walks the shards with a WEIGHTED round-robin
(``weighted_dequeue_plan``) whose cycle cursor persists across admit
calls.  The plan is work-conserving (empty classes forfeit their credits)
and gives the lowest class a provable starvation bound: while backlogged
it waits at most ``sum(w) - w[0]`` other admissions between services.

Also pins the ISSUE-10 satellites that live at this layer: the
``pack_session``/``unpack_session`` range validation (silent modulo-wrap
corruption fix), the f32-exact CAS packing domain, and the large-batch
admission drain (the O(n^2) ``spare.pop(0)`` fix).
"""

import tempfile
from pathlib import Path

import numpy as np
import pytest

import jax

from repro.checkpoint.dfc_checkpoint import FaultInjector, SimFS
from repro.core.jax_dfc import CAS_DOM, pack_cas, unpack_cas
from repro.launch.serve import (
    PROGRESS_MAX,
    SESSION_ADMITTED,
    SESSION_CLASS_DOM,
    SESSION_QUEUED,
    SESSION_SLOT_DOM,
    SESSION_SLOT_NONE,
    SESSION_STAGE_DOM,
    RequestQueueTier,
    pack_session,
    unpack_session,
)
from repro.runtime.dfc_shard import weighted_cycle, weighted_dequeue_plan

jax.config.update("jax_platform_name", "cpu")


# ------------------------------------------------- packed session encoding

def test_pack_session_roundtrip_full_domain():
    """Every (cls, slot, stage) packs to a distinct f32-exact value below
    CAS_DOM and unpacks back exactly — the whole widened domain."""
    seen = set()
    for cls in range(SESSION_CLASS_DOM):
        for slot in range(SESSION_SLOT_DOM):
            for stage in range(SESSION_STAGE_DOM):
                p = pack_session(cls, slot, stage)
                assert 0 <= p < CAS_DOM
                assert float(np.float32(p)) == p
                assert p not in seen
                seen.add(p)
                u = unpack_session(p)
                assert (u["cls"], u["slot"], u["stage"]) == (cls, slot, stage)
                assert u["priority"] == (1 if cls > 0 else 0)


def test_pack_session_rejects_out_of_range():
    """The satellite fix: out-of-range fields raise instead of silently
    wrapping into another session's bits."""
    bad = [
        (-1, 0, 1),
        (SESSION_CLASS_DOM, 0, 1),
        (0, -1, 1),
        (0, SESSION_SLOT_DOM, 1),
        (0, 0, -1),
        (0, 0, SESSION_STAGE_DOM),
        (SESSION_CLASS_DOM + 7, SESSION_SLOT_DOM + 9, SESSION_STAGE_DOM + 3),
    ]
    for cls, slot, stage in bad:
        with pytest.raises(ValueError):
            pack_session(cls, slot, stage)


def test_unpack_session_rejects_out_of_domain():
    with pytest.raises(ValueError):
        unpack_session(-1)
    with pytest.raises(ValueError):
        unpack_session(CAS_DOM)
    with pytest.raises(ValueError):
        unpack_session(CAS_DOM * CAS_DOM)


def test_pack_cas_domain_and_roundtrip():
    assert unpack_cas(pack_cas(0, 0)) == (0, 0)
    assert unpack_cas(pack_cas(CAS_DOM - 1, CAS_DOM - 1)) == (
        CAS_DOM - 1, CAS_DOM - 1,
    )
    p = pack_cas(17, 4000)
    assert float(np.float32(p)) == p
    assert unpack_cas(p) == (17, 4000)
    for expected, new in [(-1, 0), (0, -1), (CAS_DOM, 0), (0, CAS_DOM)]:
        with pytest.raises(ValueError):
            pack_cas(expected, new)
    with pytest.raises(ValueError):
        unpack_cas(CAS_DOM * CAS_DOM)


# ------------------------------------------------- weighted dequeue plan

def test_weighted_cycle_shape():
    """Highest class first, ``weights[c]`` contiguous credits each."""
    assert weighted_cycle([1, 2, 4]) == [2, 2, 2, 2, 1, 1, 0]
    assert weighted_cycle([1, 1]) == [1, 0]
    assert weighted_cycle([3]) == [0, 0, 0]
    with pytest.raises(ValueError):
        weighted_cycle([])
    with pytest.raises(ValueError):
        weighted_cycle([1, 0])


def test_weighted_plan_full_backlog_matches_cycle():
    plan, cur = weighted_dequeue_plan([8, 8, 8], [1, 2, 4], 7, 0)
    assert plan == [2, 2, 2, 2, 1, 1, 0]
    assert cur == 0  # one full cycle consumed


def test_weighted_plan_is_work_conserving():
    """Empty classes forfeit their credits — slots never idle while ANY
    class is backlogged."""
    plan, _ = weighted_dequeue_plan([5, 0, 0], [1, 2, 4], 4, 0)
    assert plan == [0, 0, 0, 0]
    plan, _ = weighted_dequeue_plan([0, 3, 2], [1, 2, 4], 5, 0)
    assert plan == [2, 2, 1, 1, 1]


def test_weighted_plan_cursor_persists_across_calls():
    """Splitting one cycle across admit calls changes nothing: the cursor
    carries the position, so the bound spans call boundaries."""
    plan1, cur = weighted_dequeue_plan([8, 8, 8], [1, 2, 4], 3, 0)
    plan2, cur = weighted_dequeue_plan([8, 8, 8], [1, 2, 4], 4, cur)
    assert plan1 + plan2 == [2, 2, 2, 2, 1, 1, 0]
    assert cur == 0


def test_weighted_plan_starvation_bound_property():
    """Under continuous all-class backlog, any two consecutive services of
    class c are separated by at most ``sum(w) - w[c]`` other services —
    across randomized plan sizes."""
    rng = np.random.default_rng(0)
    weights = [1, 2, 4]
    w_sum = sum(weights)
    cursor = 0
    stream = []
    for _ in range(100):
        n = int(rng.integers(1, 8))
        plan, cursor = weighted_dequeue_plan([100, 100, 100], weights, n, cursor)
        assert len(plan) == n  # work-conserving under full backlog
        stream.extend(plan)
    for c, w in enumerate(weights):
        idx = [i for i, x in enumerate(stream) if x == c]
        assert idx, (c, stream[:20])
        gaps = [b - a - 1 for a, b in zip(idx, idx[1:])]
        assert max(gaps) <= w_sum - w, (c, max(gaps))


# ------------------------------------------------- k-class tier behavior

def _k_tier(k=3, weights=None, slots=8, fs=None, lanes=32):
    return RequestQueueTier(
        n_queues=k, slots=slots, capacity=512, lanes=lanes, durable=True,
        fs=fs, k_classes=k, class_weights=weights,
    )


def test_k_tier_validation():
    with pytest.raises(ValueError):  # generalizes priority=True: pick one
        _k_tier().__class__(
            n_queues=2, slots=2, capacity=256, lanes=8,
            k_classes=2, priority=True,
        )
    with pytest.raises(ValueError):  # packed class field is 2 bits
        RequestQueueTier(
            n_queues=5, slots=2, capacity=256, lanes=8,
            k_classes=SESSION_CLASS_DOM + 1,
        )
    with pytest.raises(ValueError):  # weights must parallel classes
        RequestQueueTier(
            n_queues=2, slots=2, capacity=256, lanes=8, k_classes=2,
            class_weights=[1, 2, 3],
        )
    with pytest.raises(ValueError):  # weights need the k-class mode
        RequestQueueTier(
            n_queues=2, slots=2, capacity=256, lanes=8, class_weights=[1, 2],
        )
    tier = RequestQueueTier(n_queues=1, slots=2, capacity=256, lanes=8)
    with pytest.raises(ValueError):  # classes need the k-class mode
        tier.submit([1], classes=[0])
    ktier = _k_tier()
    with pytest.raises(ValueError):  # class label outside [0, k)
        ktier.submit([1], classes=[3 + 1])
    with pytest.raises(ValueError):
        ktier.submit([1], classes=[-1])


def test_k_tier_weighted_admission_order():
    """Full backlog in every class: one admit follows the weighted cycle
    (high classes first, per their credits), FIFO within each class."""
    tier = _k_tier()
    by_class = {c: [100 * c + i for i in range(1, 8)] for c in range(3)}
    for c, sids in by_class.items():
        tier.submit(sids, classes=[c] * len(sids))
    admitted = tier.admit(7)
    assert [c for _, c in tier.admit_log] == [2, 2, 2, 2, 1, 1, 0]
    assert [sid for sid, _ in admitted] == [201, 202, 203, 204, 101, 102, 1]


def test_k_tier_lowest_class_starvation_bound():
    """Continuous backlog in every class, small admit batches: class 0 is
    never gapped past ``starvation_bound()`` admissions, and the observed
    shares track the weights."""
    tier = _k_tier(slots=2)
    bound = tier.starvation_bound()
    assert bound == (1 + 2 + 4) - 1
    next_sid = {c: 1000 * (c + 1) for c in range(3)}
    for _ in range(30):
        subs, clss = [], []
        for c in range(3):  # one fresh arrival per class keeps all backlogged
            subs.append(next_sid[c])
            next_sid[c] += 1
            clss.append(c)
        tier.submit(subs, classes=clss)
        admitted = tier.admit(2)
        tier.submit([], release_slots=[slot for _, slot in admitted])
    stream = [c for _, c in tier.admit_log]
    assert len(stream) >= 40
    counts = {c: stream.count(c) for c in range(3)}
    assert counts[2] > counts[1] > counts[0] > 0
    idx0 = [i for i, c in enumerate(stream) if c == 0]
    gaps = [b - a - 1 for a, b in zip(idx0, idx0[1:])]
    assert idx0[0] <= bound, stream[: bound + 2]
    assert max(gaps, default=0) <= bound, (gaps, stream)


def test_k_tier_progress_entries_are_separate_from_state():
    """Progress entries share the session map shard but are value-tagged:
    they never shadow the packed stage, and both survive one walk."""
    tier = _k_tier()
    tier.submit([1, 2, 3], classes=[0, 1, 2])
    admitted = tier.admit(3)
    assert sorted(sid for sid, _ in admitted) == [1, 2, 3]
    tier.record_progress({1: 5, 2: 0, 3: 4095})
    assert tier.session_progress_table() == {1: 5, 2: 0, 3: 4095}
    states = tier.session_states()
    for sid in (1, 2, 3):
        assert states[sid]["stage"] == SESSION_ADMITTED
    assert {s: st["cls"] for s, st in states.items()} == {1: 0, 2: 1, 3: 2}
    with pytest.raises(ValueError):
        tier.record_progress({1: -1})
    with pytest.raises(ValueError):
        tier.record_progress({1: PROGRESS_MAX})


def test_k_tier_classes_survive_crash_recover():
    """Class membership, FIFO order per class, and decode progress are all
    fabric state: a recovered tier admits in the same weighted order."""
    fs = SimFS(
        Path(tempfile.mkdtemp(prefix="dfc_kcls_")), FaultInjector()
    )
    tier = _k_tier(slots=4, fs=fs)
    tier.submit([1, 2, 3, 4, 5, 6], classes=[0, 1, 2, 0, 1, 2])
    tier.record_progress({9: 7})
    tier2, info = RequestQueueTier.recover(
        fs, capacity=512, lanes=32, k_classes=3
    )
    assert {s: st["cls"] for s, st in info["sessions"].items()} == {
        1: 0, 2: 1, 3: 2, 4: 0, 5: 1, 6: 2,
    }
    assert all(
        st["stage"] == SESSION_QUEUED and st["slot"] == SESSION_SLOT_NONE
        for st in info["sessions"].values()
    )
    assert sorted(info["queued"]) == [1, 2, 3, 4, 5, 6]
    assert info["progress"] == {9: 7}
    order = []
    for _ in range(8):
        admitted = tier2.admit(4)
        order += [sid for sid, _ in admitted]
        tier2.submit([], release_slots=[slot for _, slot in admitted])
        if tier2.backlog() == 0:
            break
    # weights [1,2,4], backlog 2/2/2: cycle gives c2,c2 then (c2 empty)
    # c1,c1, wrap to c0,c0 — weighted order survives the restart
    assert order == [3, 6, 2, 5, 1, 4]


# ------------------------------------------------- large-batch admission

def test_large_batch_admission_drain_exact_slot_accounting():
    """Satellite fix for the O(n^2) ``spare.pop(0)`` drain: a full-width
    admit (120 slots, the packed field's whole usable range) over a
    96-session backlog admits every session once and returns EVERY spare
    slot to the pool — held + free slots partition the range."""
    n_slots = 120  # slot ids must fit the packed 7-bit field (127 = NONE)
    tier = RequestQueueTier(
        n_queues=2, slots=n_slots, capacity=1024, lanes=256, durable=False,
        k_classes=2,
    )
    sids = list(range(1, 97))
    rejected = tier.submit(sids, classes=[s % 2 for s in sids])
    assert rejected == []
    admitted = tier.admit(n_slots)
    got = [sid for sid, _ in admitted]
    held = [slot for _, slot in admitted]
    assert sorted(got) == sids
    assert len(set(held)) == len(held) == 96
    pool = tier.pool_slots()
    assert len(pool) == n_slots - 96
    assert sorted(set(pool) | set(held)) == list(range(n_slots))
    assert tier.backlog() == 0


def test_tier_rejects_slot_ids_past_packed_field():
    """Slot ids ride the packed session encoding, so a pool wider than the
    7-bit field fails fast at construction instead of corrupting a bind."""
    with pytest.raises(ValueError):
        RequestQueueTier(
            n_queues=1, slots=SESSION_SLOT_NONE + 1, capacity=256, lanes=8,
        )
