"""Test-suite compatibility shim for optional dependencies.

``hypothesis`` is a dev-only dependency (see requirements-dev.txt).  The
tier-1 suite must collect and pass on machines that don't have it, so test
modules import it through this shim:

    from _compat import hypothesis, st

When the real library is installed it is re-exported unchanged.  Otherwise a
miniature deterministic stand-in is provided: ``@given`` runs the test body
``max_examples`` times with values drawn from a seeded NumPy RNG (seed
derived from the test name, so failures are reproducible).  Only the small
strategy surface the suite actually uses is implemented — integers, floats,
lists, tuples, sampled_from, and data().draw.
"""

from __future__ import annotations

import functools
import types
import zlib

try:  # pragma: no cover - exercised on machines with hypothesis installed
    import hypothesis  # noqa: F401
    import hypothesis.strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

    def _integers(min_value=0, max_value=1 << 30):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _floats(min_value=0.0, max_value=1.0, **kw):
        lo, hi = float(min_value), float(max_value)
        return _Strategy(lambda rng: float(lo + (hi - lo) * rng.random()))

    def _sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    def _lists(elements, min_size=0, max_size=None):
        hi = max_size if max_size is not None else min_size + 10

        def draw(rng):
            k = int(rng.integers(min_size, hi + 1))
            return [elements._draw(rng) for _ in range(k)]

        return _Strategy(draw)

    def _tuples(*strategies):
        return _Strategy(lambda rng: tuple(s._draw(rng) for s in strategies))

    class _DataObject:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy._draw(self._rng)

    def _data():
        return _Strategy(lambda rng: _DataObject(rng))

    st = types.SimpleNamespace(
        integers=_integers,
        floats=_floats,
        lists=_lists,
        tuples=_tuples,
        sampled_from=_sampled_from,
        data=_data,
    )

    _DEFAULT_MAX_EXAMPLES = 20

    def _given(*strategies):
        def decorate(f):
            @functools.wraps(f)
            def wrapper(*args, **kwargs):
                cfg = getattr(wrapper, "_stub_settings", {})
                n = cfg.get("max_examples", _DEFAULT_MAX_EXAMPLES)
                rng = np.random.default_rng(zlib.crc32(f.__name__.encode()))
                for _ in range(n):
                    f(*args, *(s._draw(rng) for s in strategies), **kwargs)

            # pytest introspects __wrapped__ for the signature and would treat
            # the strategy-drawn parameters as fixtures; hide the original.
            del wrapper.__wrapped__
            return wrapper

        return decorate

    def _settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **kw):
        def decorate(f):
            f._stub_settings = dict(max_examples=max_examples)
            return f

        return decorate

    hypothesis = types.SimpleNamespace(given=_given, settings=_settings)
