"""Grouped (EP) MoE dispatch == flat dispatch in the no-drop regime, and
sane under capacity pressure."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.model import forward, init_params, loss_fn

jax.config.update("jax_platform_name", "cpu")


def cfg_moe(**kw):
    base = dict(
        name="m", family="moe", n_layers=2, d_model=32, n_heads=4, n_kv_heads=4,
        d_ff=64, vocab=64, n_experts=4, top_k=2, moe_dff=48, dense_residual=True,
        remat="none", dtype="float32", capacity_factor=8.0,
    )
    base.update(kw)
    return ModelConfig(**base)


def test_grouped_matches_flat_no_drops():
    cfg = cfg_moe()
    p = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64)
    batch = {"tokens": toks, "labels": toks}
    l1, a1 = forward(p, cfg, batch)
    l2, a2 = forward(p, dataclasses.replace(cfg, moe_groups=4), batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


@pytest.mark.parametrize("groups", [1, 2, 8])
def test_grouped_group_count_consistency(groups):
    cfg = cfg_moe(moe_groups=groups)
    p = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0, 64)
    batch = {"tokens": toks, "labels": toks}
    ref, _ = forward(p, cfg_moe(), batch)
    got, _ = forward(p, cfg, batch)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_grouped_trains_under_capacity_pressure():
    """cf=1.0 drops tokens; loss must stay finite and differentiable."""
    cfg = cfg_moe(capacity_factor=1.0, moe_groups=4)
    p = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (4, 16), 0, 64)
    batch = {"tokens": toks, "labels": toks}
    loss, grads = jax.value_and_grad(lambda pp: loss_fn(pp, cfg, batch))(p)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))
