"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import hypothesis, st

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import chunked_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba_scan.kernel import selective_scan
from repro.kernels.mamba_scan.ref import selective_scan_ref
from repro.kernels.rmsnorm.kernel import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.5).astype(dtype)


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,hq,hkv,hd,blk",
    [
        (1, 128, 2, 2, 64, 64),
        (2, 256, 4, 2, 64, 128),
        (1, 256, 8, 1, 32, 64),  # MQA
        (2, 128, 3, 1, 64, 64),  # odd head count
    ],
)
def test_flash_attention_matches_ref(b, s, hq, hkv, hd, blk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(s + hq), 3)
    q = rand(ks[0], (b, s, hq, hd), dtype)
    k = rand(ks[1], (b, s, hkv, hd), dtype)
    v = rand(ks[2], (b, s, hkv, hd), dtype)
    got = flash_attention(q, k, v, causal=True, blk_q=blk, blk_k=blk, interpret=True)
    want = attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_non_causal():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (1, 128, 2, 32), jnp.float32)
    k = rand(ks[1], (1, 128, 2, 32), jnp.float32)
    v = rand(ks[2], (1, 128, 2, 32), jnp.float32)
    got = flash_attention(q, k, v, causal=False, blk_q=64, blk_k=64)
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("blk_k", [64, 128, 256])
def test_chunked_attention_matches_ref(blk_k):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = rand(ks[0], (2, 256, 4, 64), jnp.float32)
    k = rand(ks[1], (2, 256, 2, 64), jnp.float32)
    v = rand(ks[2], (2, 256, 2, 64), jnp.float32)
    got = chunked_attention(q, k, v, causal=True, blk_k=blk_k)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("r,d,blk", [(256, 512, 128), (64, 64, 64), (512, 4096, 256)])
def test_rmsnorm_matches_ref(r, d, blk, dtype):
    x = rand(jax.random.PRNGKey(r), (r, d), dtype)
    w = rand(jax.random.PRNGKey(d), (d,), jnp.float32) + 1.0
    got = rmsnorm(x, w, blk=blk, interpret=True)
    want = rmsnorm_ref(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


# --------------------------------------------------------------- mamba scan
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,di,n,blk_d,chunk",
    [(2, 64, 128, 8, 64, 32), (1, 128, 64, 16, 64, 64), (2, 32, 256, 4, 128, 16)],
)
def test_selective_scan_matches_ref(b, s, di, n, blk_d, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(di + s), 5)
    dt = jax.nn.softplus(rand(ks[0], (b, s, di), dtype) - 2).astype(dtype)
    a_log = (jax.random.uniform(ks[1], (di, n)) * 0.5).astype(jnp.float32)
    b_ssm = rand(ks[2], (b, s, n), dtype)
    c_ssm = rand(ks[3], (b, s, n), dtype)
    x = rand(ks[4], (b, s, di), dtype)
    d_skip = jnp.ones((di,), jnp.float32)
    got = selective_scan(dt, a_log, b_ssm, c_ssm, x, d_skip, blk_d=blk_d, chunk=chunk)
    want = selective_scan_ref(dt, a_log, b_ssm, c_ssm, x, d_skip)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(
    st.integers(1, 3), st.sampled_from([32, 64]), st.sampled_from([8, 16])
)
def test_property_scan_state_independence_of_chunking(b, s, n):
    """Chunk size must not change the result (state carries exactly)."""
    di = 32
    ks = jax.random.split(jax.random.PRNGKey(b * s + n), 5)
    dt = jax.nn.softplus(rand(ks[0], (b, s, di), jnp.float32) - 2)
    a_log = jnp.zeros((di, n))
    b_ssm = rand(ks[2], (b, s, n), jnp.float32)
    c_ssm = rand(ks[3], (b, s, n), jnp.float32)
    x = rand(ks[4], (b, s, di), jnp.float32)
    d_skip = jnp.zeros((di,))
    y1 = selective_scan(dt, a_log, b_ssm, c_ssm, x, d_skip, blk_d=32, chunk=s)
    y2 = selective_scan(dt, a_log, b_ssm, c_ssm, x, d_skip, blk_d=32, chunk=s // 2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5, rtol=1e-5)
