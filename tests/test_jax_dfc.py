"""Vectorized JAX DFC combine: semantics vs the sequential oracle, Pallas
kernel vs pure-jnp ref (interpret mode), and hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import hypothesis, st

from repro.core.jax_dfc import (
    OP_NONE,
    OP_POP,
    OP_PUSH,
    R_ACK,
    R_EMPTY,
    R_NONE,
    R_VALUE,
    StackState,
    combine,
    init_stack,
    sequential_reference,
)
from repro.kernels.dfc_reduce.ops import dfc_combine_step

jax.config.update("jax_platform_name", "cpu")


def apply_batches(batches, capacity=256, via="jnp"):
    state = init_stack(capacity)
    stack_py = []
    for ops, params in batches:
        ops_a = jnp.asarray(ops, jnp.int32)
        par_a = jnp.asarray(params, jnp.float32)
        if via == "jnp":
            state, resp, kinds = combine(state, ops_a, par_a)
        else:
            state, resp, kinds = dfc_combine_step(state, ops_a, par_a, backend=via)
        stack_py, ref_resp, ref_kinds = sequential_reference(stack_py, ops, params)
        np.testing.assert_array_equal(np.asarray(kinds), ref_kinds)
        np.testing.assert_allclose(
            np.asarray(resp), np.asarray(ref_resp, np.float32), rtol=1e-6
        )
    # final stack contents match
    top = int(state.active_size())
    np.testing.assert_allclose(np.asarray(state.values[:top]), stack_py)
    assert int(state.epoch) == 2 * len(batches)
    return state


def test_push_only_batch():
    n = 8
    apply_batches([([OP_PUSH] * n, list(range(1, n + 1)))])


def test_balanced_batch_full_elimination():
    ops = [OP_PUSH, OP_POP, OP_PUSH, OP_POP]
    state = apply_batches([(ops, [5.0, 0, 7.0, 0])])
    assert int(state.active_size()) == 0  # fully eliminated — stack untouched


def test_pop_empty():
    ops = [OP_POP, OP_POP]
    state = init_stack(64)
    _, resp, kinds = combine(state, jnp.asarray(ops, jnp.int32), jnp.zeros(2))
    assert list(np.asarray(kinds)) == [R_EMPTY, R_EMPTY]


def test_multi_phase_lifo():
    apply_batches(
        [
            ([OP_PUSH] * 4, [1, 2, 3, 4]),
            ([OP_POP] * 2 + [OP_NONE] * 2, [0] * 4),
            ([OP_PUSH, OP_POP, OP_POP, OP_POP], [9, 0, 0, 0]),
        ]
    )


def test_double_buffered_top_preserves_committed_prefix():
    """A combine must never overwrite values below the committed size —
    the crash-consistency invariant of the alternating-top design."""
    state = init_stack(64)
    state, _, _ = combine(state, jnp.full(4, OP_PUSH, jnp.int32), jnp.arange(4.0))
    before = np.asarray(state.values[:4]).copy()
    # a batch with surplus pushes appends; prefix bytes identical
    state2, _, _ = combine(state, jnp.full(4, OP_PUSH, jnp.int32), jnp.arange(10.0, 14.0))
    np.testing.assert_array_equal(np.asarray(state2.values[:4]), before)
    # a pop-surplus batch only flips the size pointer, storage prefix intact
    state3, _, _ = combine(state2, jnp.full(8, OP_POP, jnp.int32), jnp.zeros(8))
    np.testing.assert_array_equal(np.asarray(state3.values[:4]), before)
    assert int(state3.active_size()) == 0
    # the previous epoch's size pointer still reads 8 (the old committed top)
    assert int(state3.size[(int(state3.epoch) // 2 + 1) % 2]) == 8


@pytest.mark.parametrize("backend", ["pallas"])
@pytest.mark.parametrize("n", [8, 128, 256])
def test_pallas_kernel_matches_ref(backend, n):
    rng = np.random.default_rng(n)
    batches = []
    for _ in range(3):
        ops = rng.integers(0, 3, n).tolist()
        params = (rng.random(n) * 100).round(2).tolist()
        batches.append((ops, params))
    apply_batches(batches, capacity=4 * n, via=backend)


@hypothesis.settings(max_examples=40, deadline=None)
@hypothesis.given(
    st.lists(
        st.tuples(st.integers(0, 2), st.floats(1.0, 1e4, allow_nan=False)),
        min_size=1,
        max_size=24,
    ),
    st.integers(0, 3),
)
def test_property_combine_matches_sequential_witness(lanes, n_batches):
    ops = [o for o, _ in lanes]
    params = [p for _, p in lanes]
    batches = [(ops, params)] * (n_batches + 1)
    apply_batches(batches, capacity=max(128, 32 * len(lanes)))


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(st.data())
def test_property_conservation(data):
    """Across arbitrary batches: pushed = popped + remaining (multisets)."""
    rng_ops = data.draw(
        st.lists(
            st.lists(st.integers(0, 2), min_size=4, max_size=16),
            min_size=1,
            max_size=4,
        )
    )
    state = init_stack(512)
    uid = 1.0
    pushed, popped = [], []
    for ops in rng_ops:
        params = []
        for o in ops:
            params.append(uid if o == OP_PUSH else 0.0)
            if o == OP_PUSH:
                pushed.append(uid)
                uid += 1.0
        state, resp, kinds = combine(
            state, jnp.asarray(ops, jnp.int32), jnp.asarray(params, jnp.float32)
        )
        popped += [float(v) for v, k in zip(np.asarray(resp), np.asarray(kinds)) if k == R_VALUE]
    remaining = list(np.asarray(state.values[: int(state.active_size())]))
    assert sorted(popped + [float(r) for r in remaining]) == sorted(pushed)


# ------------------------------------------------- announcement ring wraparound
def _ring_vals(n, base=0.0):
    keys = jnp.arange(n, dtype=jnp.int32)
    ops = jnp.full((n,), OP_PUSH, jnp.int32)
    params = jnp.arange(n, dtype=jnp.float32) + base
    return keys, ops, params


def test_ring_fill_to_exactly_slots_drain_one_announce_again():
    """Directed ISSUE-6 audit: fill the ring to EXACTLY ``slots`` lanes,
    drain (retire) one lane, announce one more.  The admission check must
    reject the extra lane while the ring is brim-full, admit it the moment
    one lane retires, and the wrapped write must land on the retired slot
    without clobbering the still-live span."""
    from repro.core.jax_dfc import (
        init_announce_ring,
        ring_announce,
        ring_drain,
        ring_has_room,
    )

    slots = 8
    ring = init_announce_ring(slots)
    # exactly-full is admissible from empty...
    assert ring_has_room(slots, 0, 0, slots)
    # ...but not one lane more, and never a span longer than the ring
    assert not ring_has_room(slots, 0, 0, slots + 1)
    ring = ring_announce(ring, *_ring_vals(slots))
    assert int(ring.tail) == slots
    # brim-full with the whole span live: nothing fits
    assert not ring_has_room(slots, slots, 0, 1)
    # retire ONE lane -> oldest_live advances by one -> one lane fits again
    assert ring_has_room(slots, slots, 1, 1)
    assert not ring_has_room(slots, slots, 1, 2)
    ring = ring_announce(
        ring,
        jnp.asarray([99], jnp.int32),
        jnp.asarray([OP_PUSH], jnp.int32),
        jnp.asarray([99.0], jnp.float32),
    )
    # the wrapped lane landed at absolute position ``slots`` (slot 0)
    k, o, p = ring_drain(ring, slots, 1)
    assert int(k[0]) == 99 and float(p[0]) == 99.0
    # and the still-live span [1, slots) is intact
    k, o, p = ring_drain(ring, 1, slots - 1)
    np.testing.assert_array_equal(np.asarray(k), np.arange(1, slots))
    np.testing.assert_allclose(np.asarray(p), np.arange(1, slots, dtype=np.float32))


def test_ring_slots_must_be_power_of_two():
    """The device tail is an int32 that wraps mod 2^32; only a power-of-two
    slot count keeps ``tail % slots`` congruent across that wrap, so any
    other count is rejected at init."""
    from repro.core.jax_dfc import init_announce_ring

    for bad in (0, -4, 3, 6, 12, 100):
        with pytest.raises(ValueError):
            init_announce_ring(bad)
    for ok in (1, 2, 8, 64, 4096):
        ring = init_announce_ring(ok)
        assert ring.keys.shape == (ok,)


def test_ring_tail_int32_overflow_keeps_host_device_congruent():
    """Near-2^31 regression: after ~2^31 announced lanes the device tail
    overflows int32 while the host mirror counts on in unbounded Python
    ints.  With power-of-two slots the two stay congruent mod ``slots``
    across the overflow — announcing through the wrap and draining by the
    HOST absolute position must read back the announced values."""
    import dataclasses

    from repro.core.jax_dfc import init_announce_ring, ring_announce, ring_drain

    slots = 8
    host_tail = 2**31 - 4  # a real host mirror would hold this Python int
    ring = init_announce_ring(slots)
    ring = dataclasses.replace(
        ring, tail=jnp.asarray(np.int32(host_tail))  # device twin, about to wrap
    )
    ring = ring_announce(ring, *_ring_vals(8, base=100.0))  # crosses 2^31
    assert int(np.asarray(ring.tail)) < 0  # device counter DID overflow
    # host-side drain at the unbounded absolute position still finds them
    k, o, p = ring_drain(ring, host_tail, 8)
    np.testing.assert_array_equal(np.asarray(k), np.arange(8))
    np.testing.assert_allclose(np.asarray(p), np.arange(8, dtype=np.float32) + 100.0)
