"""DFC-Checkpoint: crash-point sweep over every persistence operation +
end-to-end exactly-once training resume."""

import json

import jax
import numpy as np
import pytest

from repro.checkpoint.dfc_checkpoint import (
    CrashNow,
    DFCCheckpointManager,
    FaultInjector,
    SimFS,
)
from repro.data.pipeline import DataPipeline
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import TrainRuntime

jax.config.update("jax_platform_name", "cpu")


def tiny_state(v: float):
    return [np.full((4, 4), v, np.float32), np.arange(6, dtype=np.int32) + int(v)]


def test_announce_combine_commit_roundtrip(tmp_path):
    fs = SimFS(tmp_path)
    mgr = DFCCheckpointManager(fs, n_workers=3)
    for w in range(3):
        mgr.announce(w, {"step": 1, "cursor": 1})
    announce_pwb = fs.stats["pwb"]  # parallel, non-blocking path (DFC-TOTAL)
    combined = mgr.combine(tiny_state(1.0), extra_meta={"step": 1, "cursor": 1})
    assert combined == [0, 1, 2]
    leaves, man = mgr.load_active()
    np.testing.assert_array_equal(leaves[0], tiny_state(1.0)[0])
    assert man["meta"]["step"] == 1
    # elimination: 3 announcements -> ONE slot persist.  Combiner-path pwbs
    # (2 leaves + manifest + 3 responses + 2 epoch = 8) stay below what
    # per-worker persistence would cost (3 x (2 leaves + manifest + epoch)).
    combiner_pwb = fs.stats["pwb"] - announce_pwb
    assert combiner_pwb < 3 * 4


def test_epoch_parity_after_combine(tmp_path):
    fs = SimFS(tmp_path)
    mgr = DFCCheckpointManager(fs, 1)
    mgr.announce(0, {"step": 1, "cursor": 1})
    mgr.combine(tiny_state(1.0), {"step": 1, "cursor": 1})
    # volatile epoch is even; durable epoch is odd (second increment unsynced)
    assert mgr._read_epoch() % 2 == 0
    assert int(fs.read_durable("cEpoch").decode()) % 2 == 1
    # recovery rounds it up
    state, report = DFCCheckpointManager(fs.crash(), 1).recover()
    fs2 = fs.crash()
    mgr2 = DFCCheckpointManager(fs2, 1)
    mgr2.recover()
    assert mgr2._read_epoch() % 2 == 0


def _run_with_crash(tmp_path, crash_at):
    """Two combining phases with a crash injected at persistence op k."""
    inj = FaultInjector(crash_at=crash_at)
    fs = SimFS(tmp_path, inj)
    mgr = DFCCheckpointManager(fs, 2)
    committed_states = []
    try:
        for phase, val in enumerate([1.0, 2.0], start=1):
            for w in range(2):
                mgr.announce(w, {"step": phase, "cursor": phase})
            mgr.combine(tiny_state(val), {"step": phase, "cursor": phase})
            committed_states.append(val)
        crashed = False
    except CrashNow:
        crashed = True
    # post-crash: recover on a fresh view
    fs2 = fs.crash()
    mgr2 = DFCCheckpointManager(fs2, 2)
    state, report = mgr2.recover()
    leaves, man = mgr2.load_active()
    return crashed, leaves, man, report


@pytest.mark.parametrize("crash_at", range(1, 26))
def test_crash_sweep_atomicity_and_detectability(tmp_path, crash_at):
    crashed, leaves, man, report = _run_with_crash(tmp_path / str(crash_at), crash_at)
    if leaves is None:
        # nothing committed yet — both workers must read not-committed
        assert all(not r["committed"] for r in report.values())
        return
    # atomicity: the active slot is exactly one of the committed states
    val = float(leaves[0][0, 0])
    assert val in (1.0, 2.0)
    assert man["meta"]["step"] == int(val)
    # detectability consistency: if a worker's announcement is reported
    # committed at step s, the active manifest must be at least at s
    for r in report.values():
        if r["committed"]:
            assert man["meta"]["step"] >= r["step"] or r["step"] is None


def test_lost_verdict_for_uncommitted(tmp_path):
    """Crash between announce and combine → recovery must report LOST."""
    inj = FaultInjector(crash_at=None)
    fs = SimFS(tmp_path, inj)
    mgr = DFCCheckpointManager(fs, 1)
    mgr.announce(0, {"step": 5, "cursor": 5})
    # crash before any combine
    fs2 = fs.crash()
    mgr2 = DFCCheckpointManager(fs2, 1)
    state, report = mgr2.recover()
    assert report[0]["committed"] is False
    # the verdict is durable and definite
    ann = json.loads(fs2.read(mgr2._ann_path(0, mgr2._read_valid(0) & 1)).decode())
    assert ann["val"] == "LOST"


def _make_runtime(tmp_path, injector=None, n_steps_cfg=None):
    cfg = ModelConfig(
        name="tiny", family="dense", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab=64, remat="none", dtype="float32",
    )
    fs = SimFS(tmp_path, injector)
    pipe = DataPipeline(vocab=64, batch_size=2, seq_len=8, seed=3)
    return TrainRuntime(cfg, AdamWConfig(lr=1e-3), pipe, fs, n_workers=2, ckpt_every=3)


def test_exactly_once_resume_equals_uninterrupted(tmp_path):
    """Crash mid-training; resumed run must reproduce the uninterrupted run
    bit-for-bit (exactly-once step semantics)."""
    # uninterrupted reference
    rt_ref = _make_runtime(tmp_path / "ref")
    p_ref, o_ref, _ = rt_ref.train(10)

    # crashed run: inject a crash inside the 2nd combine (somewhere in its pwbs)
    inj = FaultInjector(crash_at=40)
    rt = _make_runtime(tmp_path / "crash", inj)
    try:
        rt.train(10)
        crashed = False
    except CrashNow:
        crashed = True
    assert crashed, "injector should have fired mid-run"

    # restart on the durable view, finish training
    rt2 = _make_runtime(tmp_path / "crash")
    rt2.fs = SimFS(tmp_path / "crash")  # fresh post-crash view
    rt2.mgr = rt2.mgr.__class__(rt2.fs, rt2.n_workers)
    p2, o2, _ = rt2.train(10)

    for a, b in zip(jax.tree_util.tree_leaves(p_ref), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=0)


def _combined(kind, state, ops, params):
    import jax.numpy as jnp

    from repro.core.jax_dfc import STRUCTS

    new_state, resp, kinds = STRUCTS[kind].combine(
        state, jnp.asarray(ops, jnp.int32), jnp.asarray(params, jnp.float32)
    )
    return new_state


@pytest.mark.parametrize(
    "kind,ops",
    [
        ("stack", [1, 1, 1, 2]),
        ("queue", [1, 1, 1, 2]),
        ("deque", [1, 3, 1, 4]),
    ],
)
def test_structure_checkpoint_roundtrip(tmp_path, kind, ops):
    """Queue/deque ring states (and the stack) persist their buffer ALONGSIDE
    the (head, tail)/(left, right) counters and reload bit-identically after
    a crash — the two-increment commit applies unchanged."""
    from repro.core.jax_dfc import STRUCTS

    state = STRUCTS[kind].init(32)
    state = _combined(kind, state, ops, [5.0, 6.0, 7.0, 0.0])
    state = _combined(kind, state, [1, 0, 0, 0], [9.0, 0.0, 0.0, 0.0])

    fs = SimFS(tmp_path)
    mgr = DFCCheckpointManager(fs, n_workers=1)
    mgr.announce(0, {"step": 1, "cursor": 1})
    assert mgr.combine_structure(state, {"step": 1}) == [0]

    mgr2 = DFCCheckpointManager(fs.crash(), n_workers=1)
    mgr2.recover()
    restored, man = mgr2.load_structure()
    assert man["meta"]["struct"] == kind
    assert type(restored) is type(state)
    for a, b in zip(
        jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    if kind != "stack":
        e = restored.active_ends()
        assert man["meta"]["committed_ends"] == [int(e[0]), int(e[1])]
    # the restored state keeps combining correctly (counters intact)
    again = _combined(kind, restored, [2, 2, 0, 0], [0.0] * 4)
    expect = _combined(kind, state, [2, 2, 0, 0], [0.0] * 4)
    for a, b in zip(
        jax.tree_util.tree_leaves(expect), jax.tree_util.tree_leaves(again)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_structure_checkpoint_crash_keeps_previous(tmp_path):
    """A crash mid-way through the second structure checkpoint must leave the
    first one loadable (alternating slots + epoch parity)."""
    from repro.core.jax_dfc import STRUCTS

    q1 = _combined("queue", STRUCTS["queue"].init(16), [1, 1], [1.0, 2.0])
    q2 = _combined("queue", q1, [1, 1], [3.0, 4.0])

    inj = FaultInjector(crash_at=None)
    fs = SimFS(tmp_path, inj)
    mgr = DFCCheckpointManager(fs, n_workers=1)
    mgr.announce(0, {"step": 1, "cursor": 1})
    mgr.combine_structure(q1, {"step": 1})
    ticks_after_first = inj.count

    crash_seen = False
    for k in range(1, 12):
        inj2 = FaultInjector(crash_at=ticks_after_first + k)
        inj2.count = 0
        fs_k = SimFS(tmp_path / f"k{k}", inj2)
        mgr_k = DFCCheckpointManager(fs_k, n_workers=1)
        mgr_k.announce(0, {"step": 1, "cursor": 1})
        mgr_k.combine_structure(q1, {"step": 1})
        try:
            mgr_k.announce(0, {"step": 2, "cursor": 2})
            mgr_k.combine_structure(q2, {"step": 2})
        except CrashNow:
            crash_seen = True
        mgr_r = DFCCheckpointManager(fs_k.crash(), n_workers=1)
        mgr_r.recover()
        restored, man = mgr_r.load_structure()
        assert restored is not None
        ends = [int(e) for e in np.asarray(restored.active_ends())]
        if man["meta"]["step"] == 2:
            assert ends == [0, 4]
        else:
            assert ends == [0, 2]
            np.testing.assert_array_equal(
                np.asarray(restored.values[:2]), [1.0, 2.0]
            )
    assert crash_seen


def test_straggler_late_arrival_joins_next_phase(tmp_path):
    """FC straggler mitigation: the combiner commits what is announced; a
    late worker is picked up by the following phase (paper's late-arrival)."""
    fs = SimFS(tmp_path)
    mgr = DFCCheckpointManager(fs, 3)
    for w in (0, 1):
        mgr.announce(w, {"step": 1, "cursor": 1})
    assert sorted(mgr.combine(tiny_state(1.0), {"step": 1, "cursor": 1})) == [0, 1]
    # straggler announces after the phase
    mgr.announce(2, {"step": 1, "cursor": 1})
    assert mgr.combine(tiny_state(1.0), {"step": 1, "cursor": 1}) == [2]
    # paper guarantee: at most one extra phase for a late arrival
