"""Tuned perf levers keep every reduced arch training/decoding correctly."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.launch.tuned import TUNED, apply_tuning
from repro.models.model import decode_step, forward, init_cache, init_params, loss_fn

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 16


def make_batch(cfg, rng):
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["image_embeddings"] = (
            jax.random.normal(jax.random.PRNGKey(7), (B, cfg.n_img_tokens, cfg.d_model)) * 0.02
        )
    if cfg.embedding_inputs:
        batch = {
            "embeddings": jax.random.normal(rng, (B, S, cfg.d_model)) * 0.02,
            "labels": toks,
        }
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_tuned_reduced_smoke(arch):
    cfg = get_reduced(arch)
    overrides = dict(TUNED.get(cfg.name.replace("-smoke", ""), {}))
    # group count must divide the tiny smoke token count
    if "moe_groups" in overrides:
        overrides["moe_groups"] = 4
    cfg = dataclasses.replace(cfg, **overrides)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, _ = forward(params, cfg, batch)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss = loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    # decode with tuned flags (levers must be decode-safe)
    cache = init_cache(cfg, B, S)
    step = (
        {"embeddings": jnp.zeros((B, 1, cfg.d_model))}
        if cfg.embedding_inputs
        else {"tokens": jnp.zeros((B, 1), jnp.int32)}
    )
    lg, _ = decode_step(params, cfg, cache, step)
    assert bool(jnp.all(jnp.isfinite(lg)))


def test_apply_tuning_covers_all_archs():
    for arch in ARCH_IDS:
        cfg = apply_tuning(get_reduced(arch))  # must not raise
        assert cfg is not None
    assert set(TUNED) == set(ARCH_IDS)
