"""Sharded multi-object DFC runtime: router determinism + edge cases, fused
all-shard combine vs per-shard sequential oracles (all three structures, all
backends), and a persistence-op crash sweep verifying that every announced op
either took effect exactly once or is reported not-applied by recovery —
including phases where only SOME shards committed."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.dfc_checkpoint import CrashNow, FaultInjector, SimFS
from repro.core.jax_dfc import (
    OP_ENQ,
    OP_NONE,
    R_ACK,
    R_NONE,
    STRUCTS,
)
from repro.runtime.dfc_shard import (
    R_OVERFLOW,
    ShardedDFCRuntime,
    route_batch,
    sequential_sharded_reference,
    shard_of_keys,
    shard_of_keys_host,
)

jax.config.update("jax_platform_name", "cpu")

KINDS = [("stack", 3), ("queue", 3), ("deque", 5)]
S, CAP, LANES, THREADS, B = 8, 128, 12, 2, 8


# ==================================================================== router
def test_hash_host_device_agree():
    keys = np.random.default_rng(0).integers(0, 2**31, 512)
    np.testing.assert_array_equal(
        np.asarray(shard_of_keys(jnp.asarray(keys), 8)), shard_of_keys_host(keys, 8)
    )


def test_router_stable_batch_order():
    """Lane assignment within a shard is the op's batch-order rank."""
    keys = jnp.asarray([5, 9, 5, 5, 9], jnp.int32)
    ops = jnp.full((5,), OP_ENQ, jnp.int32)
    params = jnp.arange(1.0, 6.0)
    shard_ops, shard_params, shard, lane, ok, overflow, _ = route_batch(
        keys, ops, params, n_shards=4, lanes=4
    )
    s5 = int(shard_of_keys_host(np.asarray([5]), 4)[0])
    s9 = int(shard_of_keys_host(np.asarray([9]), 4)[0])
    assert s5 != s9  # the two keys spread for this shard count
    # batch order preserved per shard
    np.testing.assert_allclose(np.asarray(shard_params[s5, :3]), [1.0, 3.0, 4.0])
    np.testing.assert_allclose(np.asarray(shard_params[s9, :2]), [2.0, 5.0])
    assert list(np.asarray(lane)) == [0, 0, 1, 2, 1]
    assert bool(jnp.all(ok)) and not bool(jnp.any(overflow))
    # rerouting is bit-identical (deterministic)
    again = route_batch(keys, ops, params, n_shards=4, lanes=4)
    np.testing.assert_array_equal(np.asarray(shard_ops), np.asarray(again[0]))


def test_router_none_lanes_not_routed():
    keys = jnp.zeros((6,), jnp.int32)
    ops = jnp.asarray([OP_NONE, OP_ENQ, OP_NONE, OP_ENQ, OP_NONE, OP_ENQ], jnp.int32)
    shard_ops, _, _, _, ok, overflow, _ = route_batch(
        keys, ops, jnp.arange(6.0), n_shards=4, lanes=4
    )
    assert int(jnp.sum(shard_ops != OP_NONE)) == 3
    assert list(np.asarray(ok)) == [False, True, False, True, False, True]
    assert not bool(jnp.any(overflow))


def test_empty_shards_keep_state_and_epoch():
    """Shards that receive no ops in a batch advance neither state nor epoch."""
    rt = ShardedDFCRuntime("stack", S, CAP, LANES)
    key = 3
    s_hot = int(shard_of_keys_host(np.asarray([key]), S)[0])
    resp, kinds = rt.step([key] * 4, [1] * 4, [1.0, 2.0, 3.0, 4.0])
    epochs = np.asarray(rt.state.epoch)
    assert epochs[s_hot] == 2
    assert all(epochs[s] == 0 for s in range(S) if s != s_hot)
    assert all(rt.shard_contents(s) == [] for s in range(S) if s != s_hot)
    assert int(rt.meta["phases"][s_hot]) == 1
    assert int(np.sum(np.asarray(rt.meta["phases"]))) == 1


@pytest.mark.parametrize("kind,opmax", KINDS)
def test_all_ops_one_shard(kind, opmax):
    """Everything hashing to one shard still matches the oracle."""
    rng = np.random.default_rng(11)
    rt = ShardedDFCRuntime(kind, S, CAP, LANES)
    oracle = [[] for _ in range(S)]
    for _ in range(3):
        ops = rng.integers(1, opmax, LANES)
        params = (rng.random(LANES) * 100).round(2)
        keys = np.full((LANES,), 7)
        resp, kinds = rt.step(keys, ops, params)
        eresp, ekinds = sequential_sharded_reference(
            kind, oracle, keys, ops.tolist(), params.tolist(), LANES
        )
        np.testing.assert_array_equal(np.asarray(kinds), ekinds)
        np.testing.assert_allclose(np.asarray(resp), np.asarray(eresp, np.float32), rtol=1e-6)
    s_hot = int(shard_of_keys_host(np.asarray([7]), S)[0])
    for s in range(S):
        np.testing.assert_allclose(rt.shard_contents(s), oracle[s])
        if s != s_hot:
            assert oracle[s] == []


def test_overflow_fails_cleanly_neighbors_intact():
    """A batch bigger than a shard's lanes: the eligible prefix is applied,
    the rest report R_OVERFLOW, and other shards are untouched by the spill."""
    rt = ShardedDFCRuntime("queue", S, CAP, lanes=4)
    hot, cold = 7, 9
    s_hot = int(shard_of_keys_host(np.asarray([hot]), S)[0])
    s_cold = int(shard_of_keys_host(np.asarray([cold]), S)[0])
    assert s_hot != s_cold
    keys = [hot] * 10 + [cold]
    ops = [OP_ENQ] * 11
    params = [float(i) for i in range(1, 12)]
    resp, kinds = rt.step(keys, ops, params)
    kinds = list(np.asarray(kinds))
    assert kinds[:4] == [R_ACK] * 4  # first `lanes` ops applied in batch order
    assert kinds[4:10] == [R_OVERFLOW] * 6  # the spill is rejected...
    assert kinds[10] == R_ACK
    assert rt.shard_contents(s_hot) == [1.0, 2.0, 3.0, 4.0]
    assert rt.shard_contents(s_cold) == [11.0]  # ...and never leaks next door
    # a rejected op left no trace: re-announcing it applies exactly once
    resp2, kinds2 = rt.step([hot], [OP_ENQ], [5.0])
    assert list(np.asarray(kinds2)) == [R_ACK]
    assert rt.shard_contents(s_hot) == [1.0, 2.0, 3.0, 4.0, 5.0]


# ======================================================= fused combine (jit)
@pytest.mark.parametrize("kind,opmax", KINDS)
@pytest.mark.parametrize("backend", ["jnp", "ref", "pallas"])
def test_sharded_step_matches_oracle_randomized(kind, opmax, backend):
    """Acceptance: the jitted route->combine->publish step over 8 shards
    matches the per-shard sequential oracles under a randomized op sweep."""
    rng = np.random.default_rng(hash((kind, backend)) % 2**32)
    rt = ShardedDFCRuntime(kind, S, 256, 32, backend=backend)
    oracle = [[] for _ in range(S)]
    for phase in range(4):
        n = 48
        keys = rng.integers(0, 1000, n)
        ops = rng.integers(0, opmax, n)  # includes OP_NONE lanes
        params = (rng.random(n) * 100).round(2)
        resp, kinds = rt.step(keys, ops, params)
        eresp, ekinds = sequential_sharded_reference(
            kind, oracle, keys, ops.tolist(), params.tolist(), 32
        )
        np.testing.assert_array_equal(np.asarray(kinds), ekinds)
        np.testing.assert_allclose(
            np.asarray(resp), np.asarray(eresp, np.float32), rtol=1e-6
        )
    for s in range(S):
        np.testing.assert_allclose(rt.shard_contents(s), oracle[s])
    epochs = np.asarray(rt.state.epoch)
    assert all(e % 2 == 0 for e in epochs)


# ============================================================== crash sweep
def _routed_bucket_lists(keys, ops, params, n_shards, lanes):
    """Host routing: per-shard (op, param) lists + per-op (shard, overflow)."""
    shard = shard_of_keys_host(keys, n_shards)
    buckets = {s: [] for s in range(n_shards)}
    meta = []
    for j in range(len(ops)):
        if ops[j] == OP_NONE:
            meta.append((None, False))
            continue
        s = int(shard[j])
        if len(buckets[s]) >= lanes:
            meta.append((s, True))
            continue
        buckets[s].append((int(ops[j]), float(params[j])))
        meta.append((s, False))
    return buckets, meta


def _run_sharded_with_crash(tmp_path, kind, opmax, crash_at, n_phases=3):
    """Run ``n_phases`` announce+combine rounds, crash at persistence op
    ``crash_at``; return everything needed for post-crash verification."""
    inj = FaultInjector(crash_at=crash_at)
    fs = SimFS(tmp_path, inj)
    rt = ShardedDFCRuntime(kind, S, CAP, LANES, fs=fs, n_threads=THREADS)
    rng = np.random.default_rng(hash(kind) % 2**32)
    oracle = [[] for _ in range(S)]  # state after every COMPLETED phase
    token = 0
    by_token = {}  # token -> (thread, keys, ops, params)
    completed = set()  # tokens of fully-committed phases
    crashed = False
    try:
        for phase in range(n_phases):
            phase_tokens = []
            batches = []
            for t in range(THREADS):
                token += 1
                keys = rng.integers(0, 1000, B)
                ops = rng.integers(0, opmax, B)
                params = (rng.random(B) * 100).round(2)
                by_token[token] = (t, keys, ops, params)
                batches.append((t, token, keys, ops, params))
                phase_tokens.append(token)
            for t, tok, keys, ops, params in batches:
                rt.announce(t, keys, ops, params, token=tok)
            rt.combine_phase()
            # fully committed -> advance the oracle and check responses
            flat_keys = np.concatenate([b[2] for b in batches])
            flat_ops = np.concatenate([b[3] for b in batches])
            flat_par = np.concatenate([b[4] for b in batches])
            eresp, ekinds = sequential_sharded_reference(
                kind, oracle, flat_keys, flat_ops.tolist(), flat_par.tolist(), LANES
            )
            off = 0
            for t, tok, keys, ops, params in batches:
                ann = rt._read_ann(t, rt._read_valid(t) & 1)
                assert ann["token"] == tok and ann["val"] is not None
                np.testing.assert_array_equal(
                    ann["val"]["kinds"], ekinds[off : off + B]
                )
                np.testing.assert_allclose(
                    ann["val"]["resp"],
                    np.asarray(eresp[off : off + B], np.float32),
                    rtol=1e-6,
                )
                off += B
            completed.update(phase_tokens)
    except CrashNow:
        crashed = True
    fs2 = fs.crash()
    rt2, report = ShardedDFCRuntime.recover(
        fs2, kind=kind, n_shards=S, capacity=CAP, lanes=LANES, n_threads=THREADS
    )
    return crashed, rt2, report, oracle, by_token, completed, inj.count


def _verify_crash_outcome(kind, rt2, report, oracle, by_token, completed):
    """Every announced op either took effect exactly once or is reported
    not-applied; the recovered state is the oracle state of exactly the
    applied ops."""
    # which tokens does the report cover, and was that phase interrupted?
    interrupted = {}
    for t, r in report.items():
        if r["token"] is None or r["token"] in completed:
            continue
        interrupted[t] = r["token"]
    if interrupted:
        # combine_phase concatenates ready announcements in thread order; an
        # interrupted COMBINE saw every thread's phase announcement, an
        # interrupted ANNOUNCE saw none (combine never ran)
        verdicts = {t: report[t]["ops"] for t in interrupted}
        flat_keys = np.concatenate([by_token[interrupted[t]][1] for t in sorted(interrupted)])
        flat_ops = np.concatenate([by_token[interrupted[t]][2] for t in sorted(interrupted)])
        flat_par = np.concatenate([by_token[interrupted[t]][3] for t in sorted(interrupted)])
        flat_verdicts = []
        for t in sorted(interrupted):
            flat_verdicts += report[t]["ops"]
        if len(flat_verdicts) == len(flat_ops) and len(interrupted) == THREADS:
            buckets, meta = _routed_bucket_lists(flat_keys, flat_ops, flat_par, S, LANES)
            # per-shard commit verdict must be all-or-nothing
            shard_applied = {}
            for (s, ovf), v in zip(meta, flat_verdicts):
                if s is None or ovf:
                    assert not v.applied
                    continue
                shard_applied.setdefault(s, v.applied)
                assert shard_applied[s] == v.applied, "split verdict inside one shard"
            # apply exactly the committed shards' op lists to the oracle
            ref = STRUCTS[kind].reference
            for s, items in buckets.items():
                if items and shard_applied.get(s, False):
                    ops_s = [o for o, _ in items]
                    par_s = [p for _, p in items]
                    oracle[s], _, _ = ref(oracle[s], ops_s, par_s)
        else:
            # interrupted during ANNOUNCE: combine never ran, nothing applied
            assert all(not v.applied for vs in verdicts.values() for v in vs)
    # recovered fabric == oracle with exactly the applied ops
    for s in range(S):
        np.testing.assert_allclose(rt2.shard_contents(s), oracle[s])
    epochs = np.asarray(rt2.state.epoch)
    assert all(int(e) % 2 == 0 for e in epochs)


@pytest.mark.parametrize("kind,opmax", KINDS)
def test_crash_sweep_exactly_once_or_not_applied(tmp_path, kind, opmax):
    """Sweep crash points across every persistence op of the workload."""
    # dry run to count persistence ops
    crashed, *_, total = _run_sharded_with_crash(tmp_path / "dry", kind, opmax, None)
    assert not crashed
    assert total > 50
    for k in range(1, total + 1, 5):
        crashed, rt2, report, oracle, by_token, completed, _ = _run_sharded_with_crash(
            tmp_path / f"k{k}", kind, opmax, k
        )
        assert crashed
        _verify_crash_outcome(kind, rt2, report, oracle, by_token, completed)


def test_crash_mid_epoch_commits_splits_shards(tmp_path):
    """Crash between two shards' epoch commits: the committed shard's ops are
    applied, the missed shard's ops are reported not-applied, and BOTH
    recover to consistent states (one new, one old)."""
    hot, cold = 7, 9  # two keys on different shards (see overflow test)
    s_hot = int(shard_of_keys_host(np.asarray([hot]), S)[0])
    s_cold = int(shard_of_keys_host(np.asarray([cold]), S)[0])
    keys = np.asarray([hot, cold, hot, cold])
    ops = np.asarray([OP_ENQ] * 4)
    params = np.asarray([1.0, 2.0, 3.0, 4.0])

    def run(crash_at):
        inj = FaultInjector(crash_at=crash_at)
        fs = SimFS(tmp_path / f"c{crash_at}", inj)
        rt = ShardedDFCRuntime("queue", S, CAP, LANES, fs=fs, n_threads=1)
        crashed = False
        try:
            rt.announce(0, keys, ops, params, token=1)
            rt.combine_phase()
        except CrashNow:
            crashed = True
        return crashed, inj.count, fs

    # dry run: find the tick of the first epoch-commit write (2 shards touched
    # -> last 6 ticks are the two commits: write, fsync, write each)
    crashed, total, _ = run(None)
    assert not crashed
    first_commit_tick = total - 6
    # crash INSIDE the second shard's commit: first shard committed, second not
    crashed, _, fs = run(first_commit_tick + 4)
    assert crashed
    rt2, report = ShardedDFCRuntime.recover(
        fs.crash(), kind="queue", n_shards=S, capacity=CAP, lanes=LANES, n_threads=1
    )
    verdicts = report[0]["ops"]
    applied = {v.shard for v in verdicts if v.applied}
    missed = {v.shard for v in verdicts if not v.applied}
    assert len(applied) == 1 and len(missed) == 1  # split across the shards
    assert applied | missed == {s_hot, s_cold}
    (s_ok,) = applied
    (s_no,) = missed
    expect = {s_hot: [1.0, 3.0], s_cold: [2.0, 4.0]}
    np.testing.assert_allclose(rt2.shard_contents(s_ok), expect[s_ok])
    assert rt2.shard_contents(s_no) == []  # rolled back whole, not corrupted
    # responses of the committed shard are durable and correct
    for v in verdicts:
        if v.applied:
            assert v.kind == R_ACK


def test_resume_after_crash_is_exactly_once(tmp_path):
    """Re-announcing exactly the not-applied ops after recovery yields every
    value in the fabric exactly once (no loss, no duplication)."""
    rng = np.random.default_rng(5)
    values = [float(v) for v in range(1, 2 * B + 1)]
    keys = rng.integers(0, 1000, 2 * B)

    for crash_at in range(1, 120, 7):
        inj = FaultInjector(crash_at=crash_at)
        fs = SimFS(tmp_path / f"k{crash_at}", inj)
        rt = ShardedDFCRuntime("queue", S, CAP, LANES, fs=fs, n_threads=THREADS)
        try:
            for t in range(THREADS):
                sl = slice(t * B, (t + 1) * B)
                rt.announce(
                    t, keys[sl], [OP_ENQ] * B, values[sl], token=t + 1
                )
            rt.combine_phase()
        except CrashNow:
            pass
        rt2, report = ShardedDFCRuntime.recover(
            fs.crash(), kind="queue", n_shards=S, capacity=CAP, lanes=LANES,
            n_threads=THREADS,
        )
        # re-announce only what recovery reports as not applied
        for t in range(THREADS):
            sl = slice(t * B, (t + 1) * B)
            r = report[t]
            if r["token"] is None:  # announcement never surfaced
                redo = list(range(B))
            else:
                assert r["token"] == t + 1
                redo = (
                    list(range(B))
                    if not r["ops"]
                    else [i for i, v in enumerate(r["ops"]) if not v.applied]
                )
            if redo:
                rt2.step(
                    np.asarray(keys[sl])[redo],
                    [OP_ENQ] * len(redo),
                    np.asarray(values[sl])[redo],
                )
        fabric = sorted(sum((rt2.shard_contents(s) for s in range(S)), []))
        assert fabric == values, f"crash_at={crash_at}"
