"""Gradient compression + elastic plan unit/property tests."""

import jax
import jax.numpy as jnp
import numpy as np
from _compat import hypothesis, st

from repro.distributed.compression import (
    CompressionState,
    dequantize_int8,
    ef_compress_grads,
    init_compression,
    quantize_int8,
)
from repro.distributed.elastic import plan_resize

jax.config.update("jax_platform_name", "cpu")


def test_int8_roundtrip_bounded_error():
    g = jax.random.normal(jax.random.PRNGKey(0), (128, 64))
    q, s = quantize_int8(g)
    back = dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(back - g))) <= float(s) * 0.5 + 1e-6


def test_error_feedback_conserves_mass():
    """sent + residual == grad + old_residual (no gradient mass lost)."""
    params = {"a": jnp.zeros((64, 32)), "b": jnp.zeros((128,))}
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.PRNGKey(p.size), p.shape), params
    )
    state = init_compression(params)
    sent, new_state = ef_compress_grads(grads, state, frac=0.05)
    for k in params:
        total = np.asarray(sent[k], np.float32) + np.asarray(new_state.residual[k])
        np.testing.assert_allclose(total, np.asarray(grads[k]), atol=1e-6)


def test_error_feedback_long_run_conservation():
    """Over T rounds: transmitted + residual == T·g exactly (nothing lost),
    and large coordinates transmit nearly their full due mass."""
    g = {"w": jnp.ones((100,)) * jnp.linspace(0.01, 1.0, 100)}
    state = init_compression(g)
    acc = jnp.zeros((100,))
    T = 60
    for _ in range(T):
        sent, state = ef_compress_grads(g, state, frac=0.05)
        acc = acc + sent["w"]
    total = acc + state.residual["w"]
    np.testing.assert_allclose(np.asarray(total), np.asarray(T * g["w"]), rtol=1e-5)
    # the top decile transmitted the bulk of its due mass
    assert float(jnp.min(acc[-10:] / (T * g["w"][-10:]))) > 0.7


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(st.integers(1, 16), st.integers(1, 16), st.integers(0, 1000))
def test_elastic_cursor_map_no_overlap(n_old, n_new, cursor):
    plan = plan_resize(list(range(n_old)), list(range(n_new)), cursor)
    starts = sorted(plan.cursor_map.values())
    assert starts == list(range(cursor, cursor + n_new))
