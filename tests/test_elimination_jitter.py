"""Elimination-aware batching regression: the queue eliminates only when
drained (ROADMAP item; paper Figure 3 discussion).

The stack's combiner eliminates concurrent push/pop pairs regardless of the
committed state, so a balanced batch touches no storage — with the durable
path's dirty-leaf elision, only the root counters and epoch re-persist.
The FIFO queue can only pair a dequeue with a concurrent enqueue once the
committed window is DRAINED: under arrival jitter (producers running ahead
of consumers by some think-time lag), a standing backlog forms, every
dequeue is served from the ring, every enqueue appends — and the values
array is dirty every phase.

The test drives the same balanced workload through one-shard stack and
queue fabrics at lag 0 (no jitter: both fully eliminate, measured pwb/op
equal) and at lag > 0 (jitter: queue strictly worse), asserting the
pwb/op ordering queue >= stack that the paper's Figure 3 predicts.
"""

import tempfile
from pathlib import Path

import pytest

import jax

from repro.checkpoint.dfc_checkpoint import SimFS
from repro.core.jax_dfc import OP_POP, OP_PUSH
from repro.runtime.dfc_shard import ShardedDFCRuntime

jax.config.update("jax_platform_name", "cpu")

CAP, LANES = 256, 32
M = 8  # balanced ops per side per phase
PHASES = 6


def _pwb_per_op(kind: str, lag: int) -> float:
    """Measured pwb/op of ``PHASES`` balanced (M pushes + M pops) phases on a
    one-shard ``kind`` fabric whose producers run ``lag`` values ahead of
    consumers (the arrival think-time backlog).  Only the steady-state
    balanced phases are measured — the prefill that models the jitter lag is
    excluded, as is the first measured phase (cold persist of every leaf)."""
    fs = SimFS(Path(tempfile.mkdtemp(prefix=f"dfc_jitter_{kind}_")))
    rt = ShardedDFCRuntime(kind, 1, CAP, LANES, fs=fs, n_threads=1)
    token = 0
    key = rt.key_for_shard(0)

    def phase(ops, params):
        nonlocal token
        token += 1
        rt.announce(0, [key] * len(ops), ops, params, token=token)
        rt.combine_phase()

    if lag:
        phase([OP_PUSH] * lag, [100.0 + i for i in range(lag)])
    # one warm-up balanced phase: first write of each leaf into each slot
    phase([OP_PUSH] * M + [OP_POP] * M, [float(i) for i in range(2 * M)])
    phase([OP_PUSH] * M + [OP_POP] * M, [float(i) for i in range(2 * M)])
    base = dict(fs.stats)
    for p in range(PHASES):
        phase(
            [OP_PUSH] * M + [OP_POP] * M,
            [10.0 * p + i for i in range(2 * M)],
        )
    ops_measured = PHASES * 2 * M
    return (fs.stats["pwb"] - base["pwb"]) / ops_measured


def test_queue_eliminates_only_when_drained():
    """Figure-3 ordering: under jitter (standing backlog) the queue pays
    strictly more pwb/op than the stack; drained (lag 0) they tie."""
    stack_0 = _pwb_per_op("stack", lag=0)
    queue_0 = _pwb_per_op("queue", lag=0)
    stack_j = _pwb_per_op("stack", lag=3 * M)
    queue_j = _pwb_per_op("queue", lag=3 * M)

    # the paper's predicted ordering: queue >= stack, strict under jitter
    assert queue_0 >= stack_0
    assert queue_j > stack_j, (
        f"queue ({queue_j:.3f}) should pay more pwb/op than the stack "
        f"({stack_j:.3f}) when arrival jitter keeps it un-drained"
    )
    # drained, both structures fully eliminate: identical persist schedules
    assert queue_0 == pytest.approx(stack_0)
    # jitter costs the QUEUE extra persistence, not the stack
    assert queue_j > queue_0
    assert stack_j == pytest.approx(stack_0)


def _queue_lane_cost(split: bool, skewed: bool) -> dict:
    """Measured steady-state pwb/op AND pfence/op of a one-shard queue
    fabric, one-lane (``split=False``) or two-lane (``split=True``).

    ``skewed=True`` models arrival skew: a standing backlog (producers
    ``3*M`` ahead) with alternating tail-only enqueue bursts and head-only
    dequeue bursts — each burst is a single-lane phase, so the split fabric
    commits just that side's record and epoch.  ``skewed=False`` is the
    drained balanced workload: every phase fully eliminates, so neither
    layout persists values or counters and the two must pay IDENTICAL
    persistence (a drained balanced phase is a handoff — the split fabric's
    two lane records cost exactly the one-lane layout's state leaves)."""
    fs = SimFS(Path(tempfile.mkdtemp(prefix=f"dfc_lanejit_{int(split)}_")))
    rt = ShardedDFCRuntime(
        "queue", 1, CAP, LANES, fs=fs, n_threads=1, split_lanes=split
    )
    token = 0
    key = rt.key_for_shard(0)

    def phase(ops, params):
        nonlocal token
        token += 1
        rt.announce(0, [key] * len(ops), ops, params, token=token)
        rt.combine_phase()

    def burst_pair(p):
        phase([OP_PUSH] * M, [100.0 * p + i for i in range(M)])
        phase([OP_POP] * M, [0.0] * M)

    if skewed:
        phase([OP_PUSH] * (3 * M), [float(i) for i in range(3 * M)])  # lag
        burst_pair(1)  # warm-up: cold persist of every leaf, both slots
        burst_pair(2)
        base = dict(fs.stats)
        for p in range(PHASES):
            burst_pair(10 + p)
    else:
        for p in (1, 2):  # warm-up
            phase([OP_PUSH] * M + [OP_POP] * M,
                  [float(i) for i in range(2 * M)])
        base = dict(fs.stats)
        for p in range(PHASES):
            phase([OP_PUSH] * M + [OP_POP] * M,
                  [10.0 * p + i for i in range(2 * M)])
    ops_measured = PHASES * 2 * M
    return {
        "pwb": (fs.stats["pwb"] - base["pwb"]) / ops_measured,
        "pfence": (fs.stats["pfence"] - base["pfence"]) / ops_measured,
    }


def test_split_lanes_beat_one_lane_under_skew():
    """Per-side combiners (ISSUE 8): under arrival skew a two-lane queue
    commits only the active side per phase — strictly fewer pwb/op than the
    one-lane layout, which re-persists the shared counter pair and epoch
    for BOTH sides every phase.  Drained, the balanced workload fully
    eliminates and the two layouts pay identical pwb/op and pfence/op (a
    split fabric must never tax the drained fast path)."""
    one_skew = _queue_lane_cost(split=False, skewed=True)
    two_skew = _queue_lane_cost(split=True, skewed=True)
    one_drained = _queue_lane_cost(split=False, skewed=False)
    two_drained = _queue_lane_cost(split=True, skewed=False)

    assert two_skew["pwb"] < one_skew["pwb"], (
        f"two-lane ({two_skew['pwb']:.3f}) should beat one-lane "
        f"({one_skew['pwb']:.3f}) pwb/op under arrival skew"
    )
    # drained: serial-identical persistence, down to the pfence schedule
    assert two_drained["pwb"] == pytest.approx(one_drained["pwb"])
    assert two_drained["pfence"] == pytest.approx(one_drained["pfence"])
    # skew costs every layout more than the drained fast path
    assert one_skew["pwb"] > one_drained["pwb"]
    assert two_skew["pwb"] > two_drained["pwb"]


def test_stack_elides_untouched_values_leaf():
    """Mechanism check for the measurement above: a fully-eliminating stack
    phase re-persists epoch + manifest but NOT the untouched values array
    (dirty-leaf elision), while a surplus push dirties it again."""
    fs = SimFS(Path(tempfile.mkdtemp(prefix="dfc_elide_")))
    rt = ShardedDFCRuntime("stack", 1, CAP, LANES, fs=fs, n_threads=1)
    key = rt.key_for_shard(0)
    rt.announce(0, [key] * 4, [OP_PUSH] * 4, [1.0, 2.0, 3.0, 4.0], token=1)
    rt.combine_phase()
    # two balanced phases: same slot written twice with identical values
    for tok in (2, 3):
        rt.announce(0, [key, key], [OP_PUSH, OP_POP], [9.0, 0.0], token=tok)
        rt.combine_phase()
    before = fs.stats["pwb"]
    rt.announce(0, [key, key], [OP_PUSH, OP_POP], [9.0, 0.0], token=4)
    rt.combine_phase()
    balanced_cost = fs.stats["pwb"] - before
    before = fs.stats["pwb"]
    rt.announce(0, [key], [OP_PUSH], [5.0], token=5)
    rt.combine_phase()
    surplus_cost = fs.stats["pwb"] - before
    assert surplus_cost > balanced_cost  # the values leaf is dirty again
    # crash safety: elision never leaves a slot unreadable
    rt2, _ = ShardedDFCRuntime.recover(
        fs.crash(), kind="stack", n_shards=1, capacity=CAP, lanes=LANES,
        n_threads=1,
    )
    assert rt2.shard_contents(0) == [1.0, 2.0, 3.0, 4.0, 5.0]
