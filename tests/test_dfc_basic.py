"""Crash-free behaviour of the paper-faithful DFC stack."""

import numpy as np
import pytest

from repro.core.dfc import ACK, BOT, EMPTY, POP, PUSH, DFCStack
from repro.core.linearize import is_linearizable
from repro.core.sim import History, Scheduler, workload_gen
from repro.nvm.memory import CrashMode, NVMemory


def run_workload(n_threads, per_thread_ops, seed=0):
    mem = NVMemory()
    stack = DFCStack(mem, n_threads)
    sched = Scheduler(seed=seed)
    hist = History()
    gens = {
        t: workload_gen(stack, sched, hist, t, per_thread_ops[t])
        for t in range(n_threads)
    }
    sched.run(gens)
    return stack, hist, mem


def test_single_thread_push_pop():
    ops = [[(PUSH, 10), (PUSH, 20), (POP, None), (POP, None), (POP, None)]]
    stack, hist, _ = run_workload(1, ops)
    values = [o["value"] for o in hist.ops]
    assert values == [ACK, ACK, 20, 10, EMPTY]
    assert stack.peek_stack() == []


def test_pop_empty_returns_empty():
    stack, hist, _ = run_workload(2, [[(POP, None)], [(POP, None)]])
    assert all(o["value"] == EMPTY for o in hist.ops)


@pytest.mark.parametrize("seed", range(8))
def test_concurrent_push_pop_linearizable(seed):
    n = 4
    ops = [[(PUSH, 100 * t + i) for i in range(2)] + [(POP, None)] for t in range(n)]
    stack, hist, _ = run_workload(n, ops, seed=seed)
    assert is_linearizable(hist.ops)
    # conservation: stack contents + popped values == pushed values
    pushed = {o["param"] for o in hist.ops if o["name"] == PUSH}
    popped = {o["value"] for o in hist.ops if o["name"] == POP and o["value"] != EMPTY}
    remaining = set(stack.peek_stack())
    assert popped | remaining == pushed
    assert popped & remaining == set()


@pytest.mark.parametrize("seed", range(4))
def test_balanced_workload_drains(seed):
    n = 6
    ops = [[(PUSH, 10 * t + i) for i in range(3)] + [(POP, None)] * 3 for t in range(n)]
    stack, hist, _ = run_workload(n, ops, seed=seed)
    assert is_linearizable(hist.ops[:12])  # checker budget: spot-check prefix
    assert stack.peek_stack() == []


def test_elimination_reduces_persistence():
    """Paper's core claim: paired push/pops are eliminated — the stack is
    untouched and combiner-path pwbs stay low."""
    n = 8
    ops = [[(PUSH, t)] if t % 2 == 0 else [(POP, None)] for t in range(n)]
    stack, hist, mem = run_workload(n, ops, seed=3)
    pushed = {o["param"] for o in hist.ops if o["name"] == PUSH}
    popped = {o["value"] for o in hist.ops if o["name"] == POP and o["value"] != EMPTY}
    assert set(stack.peek_stack()) == pushed - popped  # conservation
    # combiner-path pwbs: responses + top + epoch per phase; no node pwbs needed
    # unless a surplus hit the stack.  With a balanced workload the total must
    # be far below what per-op persistence (>=2 pwb/op) would cost.
    combine_pwbs = mem.stats.pwb.get("combine", 0)
    assert combine_pwbs < 2 * sum(len(o) for o in ops)


def test_epoch_parity_and_phase_count():
    stack, hist, mem = run_workload(3, [[(PUSH, 1)], [(PUSH, 2)], [(PUSH, 3)]])
    assert mem.read("cEpoch", "v") % 2 == 0
    assert stack.phases >= 1
    assert sorted(stack.peek_stack()) == [1, 2, 3]


def test_announce_vs_combine_attribution():
    _, _, mem = run_workload(2, [[(PUSH, 1)], [(POP, None)]])
    # each op does exactly 2 announce pwbs + 2 announce pfences (lines 9, 11)
    assert mem.stats.pwb["announce"] == 2 * 2
    assert mem.stats.pfence["announce"] == 2 * 2
    assert mem.stats.pfence.get("combine", 0) % 2 == 0  # 2 per phase
