"""Pipelined durable path: crash-interleaving proof of exactly-once.

Covers the ISSUE-4 acceptance criteria: a crash injected at EVERY
persistence op of the pipelined path — announcement-ring mirror writes,
shard pwbs, epoch increments, response publishes — recovers to the
``sequential_hetero_reference`` oracle state with exactly-once replay,
mirroring the sweep style of ``tests/test_hetero_reshard.py``.  The sweep
runs for the overlap pipeline (``pipeline=True``), for multi-batch chaining
(``chain=2``), and for their combination, on homogeneous and mixed fabrics.

The FULL parameter grid is marked ``slow`` (the dedicated CI sweep job);
tier-1 keeps one representative sweep per mechanism so the pipelined path
cannot rot between slow runs.
"""

import numpy as np
import pytest

import jax

from repro.checkpoint.dfc_checkpoint import CrashNow, FaultInjector, SimFS
from repro.core.jax_dfc import OP_ENQ, OP_PUSH, OP_PUSHR, R_VALUE
from repro.runtime.dfc_shard import (
    ShardedDFCRuntime,
    StaleTokenError,
    sequential_hetero_reference,
)

jax.config.update("jax_platform_name", "cpu")

CAP, LANES = 256, 16
PUSH_OF = {"stack": OP_PUSH, "queue": OP_ENQ, "deque": OP_PUSHR}


def _insert_phases(kinds, n_phases, per_thread, n_threads, seed=11):
    """Insert-only announcement schedule: phases[p][t] = (token, keys, ops,
    params); every param value is unique, so multiset equality IS
    exactly-once."""
    rng = np.random.default_rng(seed)
    val = 1.0
    phases = []
    token = 0
    for _ in range(n_phases):
        row = []
        for t in range(n_threads):
            token += 1
            keys = [int(k) for k in rng.integers(0, 1000, per_thread)]
            ops = [PUSH_OF[kinds[0]]] * per_thread
            params = [val + i for i in range(per_thread)]
            val += per_thread
            row.append((token, keys, ops, params))
        phases.append(row)
    return phases


def _drive(rt, phases, start_phase=0):
    """Announce + combine each phase row; pipelined runtimes retire lazily."""
    for row in phases[start_phase:]:
        for t_idx, (token, keys, ops, params) in enumerate(row):
            rt.announce(t_idx, keys, ops, params, token=token)
        rt.combine_phase()
    rt.flush()


def _fabric_contents(rt):
    return sorted(sum((rt.shard_contents(s) for s in range(rt.n_shards)), []))


def _scenario(tmp, crash_at, kinds, *, pipeline, chain, n_threads, n_phases=3,
              per_thread=6):
    """Run the pipelined schedule with a crash at persistence op
    ``crash_at``; return (recovered rt, report, phases, op count)."""
    inj = FaultInjector(crash_at=crash_at)
    fs = SimFS(tmp, inj)
    n_shards = len(kinds)
    rt = ShardedDFCRuntime(
        kinds, n_shards, CAP, LANES, fs=fs, n_threads=n_threads,
        pipeline=pipeline, chain=chain,
    )
    phases = _insert_phases(kinds, n_phases, per_thread, n_threads)
    try:
        _drive(rt, phases)
    except CrashNow:
        pass
    rt2, report = ShardedDFCRuntime.recover(
        fs.crash(), kind=kinds, n_shards=n_shards, capacity=CAP, lanes=LANES,
        n_threads=n_threads, pipeline=pipeline, chain=chain,
    )
    return rt2, report, phases, inj.count


def _verify_exactly_once(rt2, report, phases, n_threads):
    """Replay not-applied ops (in-flight predecessors first), re-drive the
    never-surfaced phases, and check every announced value lives in the
    fabric exactly once — the ISSUE-4 acceptance check."""
    assert all(int(e) % 2 == 0 for e in rt2.shard_epochs())
    contents = _fabric_contents(rt2)
    assert len(contents) == len(set(contents)), "duplicated op after recovery"
    # every applied verdict's value is already durable, for BOTH slots
    for t in range(n_threads):
        r = report[t]
        for rec in ([r] if r["token"] is not None else []) + (
            [r["prev"]] if r.get("prev") else []
        ):
            tok = rec["token"]
            phase_row = phases[(tok - 1) // n_threads]
            _, keys, ops, params = phase_row[(tok - 1) % n_threads]
            for i, v in enumerate(rec["ops"]):
                if v.applied:
                    assert params[i] in contents, (tok, i)
    rt2.replay_pending(report)
    # re-drive, per thread, every announcement that never surfaced; surfaced
    # ones were either applied or replayed above (exactly-once either way)
    surf = {t: report[t]["token"] or 0 for t in range(n_threads)}
    for row in phases:
        announced = False
        for t_idx, (token, keys, ops, params) in enumerate(row):
            if token > surf[t_idx]:
                rt2.announce(t_idx, keys, ops, params, token=token)
                announced = True
        if announced:
            rt2.combine_phase()
    rt2.flush()
    expect = sorted(
        p for row in phases for _, _, _, ps in row for p in ps
    )
    got = _fabric_contents(rt2)
    assert got == expect, "lost or duplicated ops across the pipeline crash"


def _sweep(tmp_path, kinds, *, pipeline, chain, n_threads, step=1):
    rt_dry, report_dry, phases, total = _scenario(
        tmp_path / "dry", None, kinds,
        pipeline=pipeline, chain=chain, n_threads=n_threads,
    )
    # the dry run itself must be exactly-once and oracle-exact
    _verify_exactly_once(rt_dry, report_dry, phases, n_threads)
    assert total > 40
    for k in range(1, total + 1, step):
        rt2, report, phases, _ = _scenario(
            tmp_path / f"k{k}", k, kinds,
            pipeline=pipeline, chain=chain, n_threads=n_threads,
        )
        _verify_exactly_once(rt2, report, phases, n_threads)


# ----------------------------------------------------------- tier-1 sweeps
def test_pipeline_crash_sweep_exactly_once(tmp_path):
    """Acceptance: every persistence op of the OVERLAP pipeline (ring mirror
    write, shard pwb, epoch increments, response publish) is a safe crash
    point — single announcing thread, queue fabric."""
    _sweep(tmp_path, ["queue", "queue"], pipeline=True, chain=1, n_threads=1)


def test_chained_crash_sweep_exactly_once(tmp_path):
    """Acceptance twin for CHAINED dispatches: two batches combined in one
    fused dispatch commit batch-by-batch; a crash between the two commits
    applies a prefix of the chain, never a mix."""
    _sweep(
        tmp_path, ["queue", "queue"], pipeline=True, chain=2, n_threads=2
    )


def test_pipeline_inflight_predecessor_resolution(tmp_path):
    """Directed case for the overlap-aware recovery: batch k is dispatched
    (in flight, never retired), batch k+1 is announced on the SAME thread,
    then the fabric crashes.  Recovery must report k under ``prev`` with
    not-applied verdicts and replay k before k+1."""
    fs = SimFS(tmp_path)
    rt = ShardedDFCRuntime(
        ["queue"], 1, CAP, LANES, fs=fs, n_threads=1, pipeline=True
    )
    rt.announce(0, [1, 2], [OP_ENQ] * 2, [1.0, 2.0], token=1)
    rt.combine_phase()  # dispatch k=1; nothing retired yet
    rt.announce(0, [3, 4], [OP_ENQ] * 2, [3.0, 4.0], token=2)
    # crash before the next combine_phase would retire k=1
    rt2, report = ShardedDFCRuntime.recover(
        fs.crash(), kind=["queue"], n_shards=1, capacity=CAP, lanes=LANES,
        n_threads=1, pipeline=True,
    )
    assert rt2.shard_contents(0) == []  # neither batch committed
    r = report[0]
    assert r["token"] == 2 and all(not v.applied for v in r["ops"])
    assert r["prev"] is not None and r["prev"]["token"] == 1
    assert all(not v.applied for v in r["prev"]["ops"])
    assert rt2.replay_pending(report) == [0]
    # replay preserved per-thread op order: k's enqueues precede k+1's
    assert rt2.shard_contents(0) == [1.0, 2.0, 3.0, 4.0]


def test_pipeline_responses_durable_after_retire(tmp_path):
    """A retired batch's responses survive a crash and are readable by token
    from the OLDER announcement slot, matching the oracle responses."""
    fs = SimFS(tmp_path)
    rt = ShardedDFCRuntime(
        ["stack"], 1, CAP, LANES, fs=fs, n_threads=1, pipeline=True
    )
    rt.announce(0, [5, 6], [OP_PUSH] * 2, [7.0, 8.0], token=1)
    rt.combine_phase()
    rt.announce(0, [5], [2], [0.0], token=2)  # OP_POP
    rt.combine_phase()  # retires token 1
    rt2, report = ShardedDFCRuntime.recover(
        fs.crash(), kind=["stack"], n_shards=1, capacity=CAP, lanes=LANES,
        n_threads=1, pipeline=True,
    )
    val = rt2.read_responses(0, token=1)
    assert val is not None and val["kinds"] == [1, 1]  # R_ACK, R_ACK
    # token 2 was in flight: not applied, replayable
    assert report[0]["token"] == 2
    assert not report[0]["ops"][0].applied
    rt2.replay_pending(report)
    val2 = rt2.read_responses(0, token=2)
    assert val2 is not None and val2["kinds"] == [R_VALUE]
    assert val2["resp"] == [8.0]  # LIFO top


def test_pipeline_matches_oracle_per_phase(tmp_path):
    """Crash-free pipelined run: every retired batch's durable responses
    equal ``sequential_hetero_reference`` applied phase-by-phase, and the
    final fabric equals the oracle fabric (mixed kinds, three backends by
    the slow grid; jnp here)."""
    kinds = ["stack", "queue", "deque"]
    rng = np.random.default_rng(23)
    fs = SimFS(tmp_path)
    rt = ShardedDFCRuntime(
        kinds, 3, CAP, LANES, fs=fs, n_threads=1, pipeline=True, n_buckets=12
    )
    oracle = [[] for _ in kinds]
    expected = {}
    for tok in range(1, 5):
        keys = [int(k) for k in rng.integers(0, 1000, 10)]
        shard = rt.route_host(keys)
        ops = [int(rng.integers(1, 3)) for _ in shard]
        params = [float(v) for v in (rng.random(10) * 100).round(2)]
        eresp, ekinds = sequential_hetero_reference(
            kinds, oracle, keys, ops, params, LANES, table=rt.table
        )
        expected[tok] = (eresp, ekinds)
        rt.announce(0, keys, ops, params, token=tok)
        rt.combine_phase()
        if tok > 1:  # the predecessor retired in this phase
            val = rt.read_responses(0, token=tok - 1)
            eresp_p, ekinds_p = expected[tok - 1]
            assert val["kinds"] == list(ekinds_p)
            np.testing.assert_allclose(
                val["resp"], np.asarray(eresp_p, np.float32), rtol=1e-6
            )
    rt.flush()
    val = rt.read_responses(0, token=4)
    assert val["kinds"] == list(expected[4][1])
    for s in range(3):
        np.testing.assert_allclose(rt.shard_contents(s), oracle[s])


def test_chain_larger_than_ready_set(tmp_path):
    """Regression: a chain depth larger than the number of ready
    announcements must not build an empty tail batch — 2 announcing threads
    under chain=3 commit as two chained batches, exactly once."""
    fs = SimFS(tmp_path)
    rt = ShardedDFCRuntime(
        ["queue", "queue"], 2, CAP, LANES, fs=fs, n_threads=2,
        pipeline=True, chain=3,
    )
    rt.announce(0, [1, 2], [OP_ENQ] * 2, [1.0, 2.0], token=1)
    rt.announce(1, [3, 4], [OP_ENQ] * 2, [3.0, 4.0], token=2)
    assert sorted(rt.combine_phase()) == [0, 1]
    rt.announce(0, [5], [OP_ENQ], [5.0], token=3)  # 1 ready < chain
    assert rt.combine_phase() == [0]
    rt.flush()
    assert _fabric_contents(rt) == [1.0, 2.0, 3.0, 4.0, 5.0]
    for tok, kinds in ((1, 2), (2, 2), (3, 1)):
        t = 1 if tok == 2 else 0
        val = rt.read_responses(t, token=tok)
        assert val is not None and len(val["kinds"]) == kinds


def test_read_responses_stale_token_raises(tmp_path):
    """Regression (ISSUE 5): a token that predates BOTH announcement slots
    must raise a clear ``StaleTokenError`` — previously the lookup fell
    through to ``None``, indistinguishable from a batch still in flight, so
    a caller polling an overwritten token would spin forever."""
    fs = SimFS(tmp_path)
    rt = ShardedDFCRuntime(["queue"], 1, CAP, LANES, fs=fs, n_threads=1)
    for tok in (1, 2, 3):
        rt.announce(0, [1], [OP_ENQ], [float(tok)], token=tok)
        rt.combine_phase()
    # slots now hold tokens 2 (older) and 3 (newest): both readable
    assert rt.read_responses(0, token=2) is not None
    assert rt.read_responses(0, token=3) is not None
    with pytest.raises(StaleTokenError):
        rt.read_responses(0, token=1)
    # a FUTURE token is pending, not stale: still None, no exception
    assert rt.read_responses(0, token=99) is None
    # an announced-but-unretired batch is pending too (pipelined runtime)
    fs2 = SimFS(tmp_path / "p")
    rt2 = ShardedDFCRuntime(
        ["queue"], 1, CAP, LANES, fs=fs2, n_threads=1, depth=2
    )
    rt2.announce(0, [1], [OP_ENQ], [1.0], token=1)
    rt2.combine_phase()  # dispatched, in flight
    assert rt2.read_responses(0, token=1) is None
    rt2.flush()
    assert rt2.read_responses(0, token=1) is not None


def test_read_responses_gap_token_raises(tmp_path):
    """Regression (ISSUE 6): a requested token may predate the RETAINED
    slots without predating ``min(held)`` — per-thread tokens are monotone
    but not dense, so with held tokens {5, 9} a request for 7 was never
    announced and can never surface.  The old ``token < min(held)`` check
    let it fall through to ``None`` (a forever-spin for the caller); any
    token below ``max(held)`` that is not itself retained is provably
    stale and must raise."""
    fs = SimFS(tmp_path)
    rt = ShardedDFCRuntime(["queue"], 1, CAP, LANES, fs=fs, n_threads=1)
    for tok in (5, 9):  # sparse token sequence: slots retain {5, 9}
        rt.announce(0, [1], [OP_ENQ], [float(tok)], token=tok)
        rt.combine_phase()
    assert rt.read_responses(0, token=5) is not None
    assert rt.read_responses(0, token=9) is not None
    # 7 sits in the gap: newer than min(held)=5, older than max(held)=9,
    # never announced -> provably stale, not pending
    with pytest.raises(StaleTokenError):
        rt.read_responses(0, token=7)
    # and below the whole window stays stale too
    with pytest.raises(StaleTokenError):
        rt.read_responses(0, token=4)
    # above the window is genuinely pending
    assert rt.read_responses(0, token=10) is None


def test_read_responses_lane_filter_keeps_staleness_monotone(tmp_path):
    """Regression (ISSUE 8): the per-lane ``read_responses`` view must apply
    the lane filter AFTER staleness detection.  With split lanes a thread's
    two slots can hold tokens from DIFFERENT lanes (here a head-lane batch
    at token 5 and a tail-lane batch at token 9); a gap token like 7 is
    provably stale regardless of which lane the caller asks about — if the
    filter ran first, the head slot would vanish from the tail-lane view
    and the stale request would fall through to ``None`` (a forever-spin)."""
    from repro.core.jax_dfc import LANE_HEAD, LANE_TAIL

    fs = SimFS(tmp_path)
    rt = ShardedDFCRuntime(
        ["queue"], 1, CAP, LANES, fs=fs, n_threads=1, split_lanes=True
    )
    rt.announce(0, [1, 2], [OP_ENQ] * 2, [1.0, 2.0], token=1)
    rt.combine_phase()
    rt.announce(0, [1], [2], [0.0], token=5)  # OP_DEQ: head lane
    rt.combine_phase()
    rt.announce(0, [3], [OP_ENQ], [3.0], token=9)  # tail lane
    rt.combine_phase()
    # slots hold interleaved-lane tokens {5 (head), 9 (tail)}: both readable,
    # and the lane views split one batch's responses by side
    head = rt.read_responses(0, token=5, lane=LANE_HEAD)
    assert head is not None and head["resp"] == [1.0]  # FIFO head
    assert rt.read_responses(0, token=5, lane=LANE_TAIL)["kinds"] == []
    tail = rt.read_responses(0, token=9, lane=LANE_TAIL)
    assert tail is not None and len(tail["kinds"]) == 1
    # gap token 7 predates max(held)=9 and was never announced: stale in
    # EVERY lane view, never None
    for lane in (None, LANE_HEAD, LANE_TAIL):
        with pytest.raises(StaleTokenError):
            rt.read_responses(0, token=7, lane=lane)
    # and a token above the window stays pending in every view
    assert rt.read_responses(0, token=10, lane=LANE_TAIL) is None


def test_request_queue_tier_rides_the_ring_path():
    """The serving tier's durable phases flow through the device-side
    announcement ring (payload spans registered and consumed), in both the
    serial and the pipelined tier configuration, and still admit every
    session exactly once."""
    from repro.launch.serve import RequestQueueTier

    for pipeline in (False, True):
        tier = RequestQueueTier(
            n_queues=2, slots=2, capacity=512, lanes=16,
            durable=True, pipeline=pipeline,
        )
        assert tier.rt.ring is not None  # durable fabric staged on-device
        sids = list(range(1, 7))
        assert tier.submit(sids) == []
        # the submit phases consumed their ring spans at dispatch
        assert tier.rt._ring_tail > 0 and not tier.rt._ring_spans
        served = []
        for _ in range(20):
            admitted = tier.admit(2)
            served += [sid for sid, _ in admitted]
            tier.submit([], release_slots=[slot for _, slot in admitted])
            if len(served) == len(sids):
                break
        assert sorted(served) == sids
        p = tier.persistence_stats()
        assert p and p["pwb_per_op"] > 0


# ------------------------------------------------------------- slow grid
@pytest.mark.slow
@pytest.mark.parametrize(
    "kinds,pipeline,chain,n_threads",
    [
        (["queue", "queue"], True, 1, 2),
        (["stack", "queue", "deque"], True, 1, 1),
        (["stack", "queue", "deque"], True, 2, 3),
        (["deque", "deque"], False, 2, 2),  # chaining without overlap
    ],
    ids=["q2-threads", "mixed", "mixed-chain", "chain-only"],
)
def test_pipeline_crash_sweep_grid(tmp_path, kinds, pipeline, chain, n_threads):
    """Full crash sweep across fabrics × pipeline mechanisms (slow job)."""
    _sweep(
        tmp_path, kinds, pipeline=pipeline, chain=chain, n_threads=n_threads
    )


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["jnp", "ref", "pallas"])
def test_pipeline_backend_sweep(tmp_path, backend):
    """The pipelined sweep holds on every combine backend (the fused
    multi-batch chain runs as one scanned vmap or one scanned Pallas grid)."""
    inj_total = None
    for k in [None, 7, 23, 41, 55]:
        inj = FaultInjector(crash_at=k)
        fs = SimFS(tmp_path / f"{backend}-{k}", inj)
        rt = ShardedDFCRuntime(
            ["queue", "stack"], 2, CAP, LANES, fs=fs, n_threads=2,
            pipeline=True, chain=2, backend=backend,
        )
        phases = _insert_phases(["queue"], 2, 5, 2, seed=3)
        try:
            _drive(rt, phases)
        except CrashNow:
            pass
        rt2, report = ShardedDFCRuntime.recover(
            fs.crash(), kind=["queue", "stack"], n_shards=2, capacity=CAP,
            lanes=LANES, n_threads=2, pipeline=True, chain=2,
        )
        if k is None:
            inj_total = inj.count
        _verify_exactly_once(rt2, report, phases, 2)
    assert inj_total and inj_total > 40
