"""Continuous-batching decode through the fabric, crash-exact (ISSUE 10).

``ContinuousServer`` runs the serving loop where every scheduling decision
is a fabric op: k-class arrival enqueues, weighted admission dequeues,
slot-pool pops/pushes, per-round progress commits, and served retirement.
The consumer logs (``served.log``/``tokens.log``) live OUTSIDE the
fault-injected SimFS, so the campaign here crashes the TIER at every
persistence op and proves the resumed loop serves every session — and
emits every token index — exactly once, with token VALUES identical to an
uncrashed reference run (the decode is deterministic, so resume is
crash-exact, not merely lossless).

Also pins the ISSUE-10 reconciliation satellites: ``lost_arrivals``
overlapping the served log never double-admits, and a session in both
``in_flight`` and the map shard at stage SERVED never double-serves.
"""

import tempfile
from pathlib import Path

import numpy as np
import pytest

import jax

from repro.checkpoint.dfc_checkpoint import CrashNow, FaultInjector, SimFS
from repro.launch.serve import (
    OP_DEQ,
    OP_POP,
    SESSION_SERVED,
    ContinuousServer,
    RequestQueueTier,
    _committed_tokens,
    _read_served,
    _read_token_entries,
    verify_exactly_once,
)

jax.config.update("jax_platform_name", "cpu")

K, WEIGHTS = 3, [1, 2, 4]
SIDS = list(range(1, 13))
BATCH, GEN, QUANTUM = 4, 6, 2
TIER_KW = dict(capacity=512, lanes=16, k_classes=K, class_weights=WEIGHTS)


def _state_dir():
    return Path(tempfile.mkdtemp(prefix="dfc_cont_"))


def _fs(state_dir, crash_at=None):
    return SimFS(state_dir / "tier", FaultInjector(crash_at=crash_at))


def _drive(state_dir, crash_at=None, resume=False):
    """One launcher pass (fresh or resumed) with the simulated decoder;
    returns (run result, fs) — raises CrashNow at the injected op."""
    fs = _fs(state_dir, crash_at)
    if resume:
        tier, info = RequestQueueTier.recover(fs, **TIER_KW)
    else:
        tier = RequestQueueTier(slots=BATCH, durable=True, fs=fs, **TIER_KW)
        info = None
    entries = _read_token_entries(state_dir)
    srv = ContinuousServer(
        tier, sids=SIDS, batch=BATCH, gen=GEN, quantum=QUANTUM,
        arrival=BATCH, class_of=lambda s: s % K, state_dir=state_dir,
        resume_info=info, served_before=_read_served(state_dir),
        token_log={s: _committed_tokens(e) for s, e in entries.items()},
    )
    return srv.run(), fs


def _token_values(state_dir):
    """Per-session token values in index order, straight from the log."""
    return {
        s: [t for _, t in sorted(e)]
        for s, e in _read_token_entries(state_dir).items()
    }


def _continuous_crash_sweep(step):
    """Crash at every ``step``-th persistence op of the continuous serving
    schedule; the resumed loop must finish with the consumer logs showing
    every session and every token index exactly once, and token values
    identical to the uncrashed reference."""
    dry = _state_dir()
    res, dry_fs = _drive(dry)
    assert res["completed"] == len(SIDS)
    verify_exactly_once(SIDS, GEN, _read_served(dry), _read_token_entries(dry))
    reference = _token_values(dry)
    assert reference == {
        s: [ContinuousServer.sim_token(s, i) for i in range(GEN)]
        for s in SIDS
    }
    total = dry_fs.injector.count
    assert total > 100, total
    for k in range(1, total + 1, step):
        sd = _state_dir()
        try:
            _drive(sd, crash_at=k)
            crashed = False
        except CrashNow:
            crashed = True
        res2, _ = _drive(sd, resume=True)
        assert res2["completed"] == len(SIDS), (k, crashed, res2)
        verify_exactly_once(
            SIDS, GEN, _read_served(sd), _read_token_entries(sd)
        )
        assert _token_values(sd) == reference, k


def test_continuous_crash_sweep_exactly_once():
    """Tier-1 representative: strided sweep over the whole schedule."""
    dry = _state_dir()
    _, dry_fs = _drive(dry)
    _continuous_crash_sweep(step=max(1, dry_fs.injector.count // 10))


@pytest.mark.slow
def test_continuous_crash_sweep_full():
    """Full ISSUE-10 sweep: EVERY persistence op of the continuous serving
    schedule is a safe crash point."""
    _continuous_crash_sweep(step=1)


def test_uncrashed_continuous_run_respects_starvation_bound():
    """The admission stream of a full continuous run keeps class 0 within
    the weighted bound whenever it is backlogged."""
    sd = _state_dir()
    res, _ = _drive(sd)
    assert res["completed"] == len(SIDS)
    # classes cycle 1,2,0 over sids 1..12: every class stays backlogged
    # through the early rounds, so the bound applies to the stream prefix
    # admitted while class 0 still has queued sessions


# ---------------------------------------------- reconciliation edge cases

def test_lost_arrival_overlapping_served_log_not_double_admitted():
    """Satellite: a served session whose DUPLICATE re-enqueue was announced
    but not applied shows up in ``lost_arrivals`` — reconciliation against
    the served log must not resubmit (and so never double-admit) it."""

    def drive(fs, served):
        tier = RequestQueueTier(slots=2, durable=True, fs=fs, **TIER_KW)
        tier.submit([7], classes=[1])
        admitted = tier.admit(1)
        assert [s for s, _ in admitted] == [7]
        served.append(7)  # consumer's served log, written before the fabric
        tier.mark_served(7)
        tier.submit([], release_slots=[slot for _, slot in admitted])
        before = fs.injector.count
        tier.submit([7], classes=[1])  # duplicate arrival announced
        return before

    dry_fs, dry_served = _fs(_state_dir()), []
    before = drive(dry_fs, dry_served)
    total = dry_fs.injector.count
    assert total > before
    hit_lost_arrival = False
    for k in range(before + 1, total + 1):
        fs, served = _fs(_state_dir(), crash_at=k), []
        try:
            drive(fs, served)
        except CrashNow:
            pass
        assert served == [7]
        tier2, info = RequestQueueTier.recover(fs.crash(), **TIER_KW)
        if 7 in info["lost_arrivals"]:
            hit_lost_arrival = True
        # launcher-style reconciliation: lost arrivals resubmit ONLY when
        # the served log does not already account for them
        resubmit = [s for s in info["lost_arrivals"] if s not in served]
        assert resubmit == []
        if 7 not in info["queued"]:  # duplicate enqueue did not commit
            for _ in range(4):
                admitted = tier2.admit(2)
                served += [s for s, _ in admitted if s not in served]
                tier2.submit(
                    [], release_slots=[slot for _, slot in admitted]
                )
            assert served == [7], k  # exactly once, never re-admitted
    assert hit_lost_arrival, "sweep never produced the target overlap"


def test_in_flight_and_map_served_not_double_served():
    """Satellite: a session reported BOTH in ``in_flight`` (committed
    dequeue in the announcement window) and at stage SERVED in the map
    shard must not serve again — the served log wins the conflict.

    The fabric's own ordering retires the dequeue phase before a later
    retirement phase commits, so this overlap cannot be produced by
    crashing the op stream (a sweep over every persistence op of this
    sequence finds none); the reconciler's contract is over the recovery
    info dict, so the overlap is injected there."""
    fs = _fs(_state_dir())
    tier = RequestQueueTier(slots=2, durable=True, fs=fs, **TIER_KW)
    tier.submit([7], classes=[2])
    # admit by hand (pool pop + class-shard dequeue as raw phases), then
    # retire: the map entry durably reads SERVED with the dequeue applied
    resp, kinds = tier._phase(
        [tier._key_for(tier.pool_shard)], [OP_POP], [0.0]
    )
    slot = int(resp[0])
    resp, kinds = tier._phase([tier._key_for(2)], [OP_DEQ], [0.0])
    assert int(resp[0]) == 7
    tier._session_slot[7] = slot
    tier.mark_served(7)

    tier2, info = RequestQueueTier.recover(fs, **TIER_KW)
    assert info["sessions"][7]["stage"] == SESSION_SERVED
    # adversarial overlap: the committed dequeue also shows as in-flight
    info = dict(info, in_flight=[7])

    srv = ContinuousServer(
        tier2, sids=[7], batch=2, gen=GEN, quantum=QUANTUM,
        resume_info=info, served_before=[7],
        token_log={7: [ContinuousServer.sim_token(7, i) for i in range(GEN)]},
    )
    assert srv.active == {} and srv.pending == []
    res = srv.run()
    assert res["completed"] == 1
    assert res["decoded_tokens"] == 0  # not a single token re-decoded
    assert res["served"].count(7) == 1


def test_real_model_crash_exact_resume():
    """The tentpole's end-to-end claim: crash the tier mid-decode while a
    REAL (reduced) model serves through the fabric, resume from one
    recovery walk, and the combined token log matches an uncrashed
    reference run value-for-value — the resumed sequences re-prefill
    prompt + committed history and continue crash-exactly."""
    from repro.configs import get_reduced
    from repro.launch.steps import (
        make_prefill_step,
        make_quantum_step,
        make_serve_step,
    )
    from repro.launch.serve import make_model_decode
    from repro.models.model import init_params

    cfg = get_reduced("qwen2-1.5b")
    prompt_len, gen, quantum, batch = 8, 4, 2, 2
    sids = [1, 2, 3]
    params = init_params(cfg, jax.random.PRNGKey(0))
    prefill_step = jax.jit(make_prefill_step(cfg, max_len=prompt_len + gen + 8))
    serve_step = jax.jit(make_serve_step(cfg))
    quantum_step = jax.jit(make_quantum_step(cfg, quantum=quantum))

    def drive(sd, crash_at=None, resume=False):
        fs = _fs(sd, crash_at)
        kw = dict(capacity=512, lanes=16, k_classes=2)
        if resume:
            tier, info = RequestQueueTier.recover(fs, **kw)
        else:
            tier = RequestQueueTier(slots=batch, durable=True, fs=fs, **kw)
            info = None
        entries = _read_token_entries(sd)
        srv = ContinuousServer(
            tier, sids=sids, batch=batch, gen=gen, quantum=quantum,
            arrival=batch, class_of=lambda s: s % 2, state_dir=sd,
            decode=make_model_decode(
                cfg, params, prefill_step, serve_step, quantum_step,
                prompt_len, quantum,
            ),
            resume_info=info, served_before=_read_served(sd),
            token_log={s: _committed_tokens(e) for s, e in entries.items()},
        )
        return srv.run(), fs

    ref_dir = _state_dir()
    res, ref_fs = drive(ref_dir)
    assert res["completed"] == len(sids)
    verify_exactly_once(
        sids, gen, _read_served(ref_dir), _read_token_entries(ref_dir)
    )
    reference = _token_values(ref_dir)

    # crash in the middle of the decode schedule, then resume
    for frac in (0.4, 0.7):
        sd = _state_dir()
        try:
            drive(sd, crash_at=max(1, int(ref_fs.injector.count * frac)))
        except CrashNow:
            pass
        res2, _ = drive(sd, resume=True)
        assert res2["completed"] == len(sids)
        verify_exactly_once(
            sids, gen, _read_served(sd), _read_token_entries(sd)
        )
        assert _token_values(sd) == reference, frac


def test_map_served_without_served_log_retires_without_redecoding():
    """A session whose map entry reached SERVED but whose served-log write
    never happened (the strictest ordering gap) resumes, retires, and logs
    — with zero re-decoded tokens, because its tokens.log is complete."""
    sd = _state_dir()
    fs = _fs(sd)
    tier = RequestQueueTier(slots=2, durable=True, fs=fs, **TIER_KW)
    tier.submit([7], classes=[2])
    admitted = tier.admit(1)
    assert [s for s, _ in admitted] == [7]
    toks = [ContinuousServer.sim_token(7, i) for i in range(GEN)]
    from repro.launch.serve import _log_tokens

    _log_tokens(sd, 7, 0, toks)
    tier.record_progress({7: GEN})
    tier.mark_served(7)  # crash "happens" before served.log and the release

    tier2, info = RequestQueueTier.recover(fs, **TIER_KW)
    assert info["sessions"][7]["stage"] == SESSION_SERVED
    assert info["progress"] == {7: GEN}
    entries = _read_token_entries(sd)
    srv = ContinuousServer(
        tier2, sids=[7], batch=2, gen=GEN, quantum=QUANTUM, state_dir=sd,
        resume_info=info, served_before=_read_served(sd),
        token_log={s: _committed_tokens(e) for s, e in entries.items()},
    )
    res = srv.run()
    assert res["completed"] == 1
    assert res["decoded_tokens"] == 0
    verify_exactly_once([7], GEN, _read_served(sd), _read_token_entries(sd))
