"""DFC FIFO queue: crash-free behaviour + crash-sweeping durable
linearizability and detectability (paper's queue, sequential layer)."""

import numpy as np
import pytest

from repro.core.dfc import ACK, BOT, DEQ, EMPTY, ENQ, INIT
from repro.core.dfc_queue import DFCQueue
from repro.core.harness import (
    check_durable_linearizability,
    run_with_crash,
    total_steps,
)
from repro.core.linearize import is_linearizable
from repro.core.sim import History, Scheduler, workload_gen
from repro.nvm.memory import CrashMode, NVMemory

# one enq and one deq in flight on thread 0, concurrency from threads 1-2 —
# the sweep below crashes at EVERY scheduler step, so every yield point of
# both ops (announce writes, fences, valid-bit flips, combiner steps) is hit.
SMALL = [
    [(ENQ, 11), (DEQ, None)],
    [(ENQ, 22), (ENQ, 23)],
    [(DEQ, None), (ENQ, 33)],
]


def run_workload(n_threads, per_thread_ops, seed=0):
    mem = NVMemory()
    q = DFCQueue(mem, n_threads)
    sched = Scheduler(seed=seed)
    hist = History()
    gens = {
        t: workload_gen(q, sched, hist, t, per_thread_ops[t])
        for t in range(n_threads)
    }
    sched.run(gens)
    return q, hist, mem


# ------------------------------------------------------------ crash-free FIFO
def test_single_thread_fifo_order():
    ops = [[(ENQ, 10), (ENQ, 20), (ENQ, 30), (DEQ, None), (DEQ, None), (ENQ, 40), (DEQ, None), (DEQ, None), (DEQ, None)]]
    q, hist, _ = run_workload(1, ops)
    values = [o["value"] for o in hist.ops]
    assert values == [ACK, ACK, ACK, 10, 20, ACK, 30, 40, EMPTY]
    assert q.peek_queue() == []


def test_deq_empty_returns_empty():
    q, hist, _ = run_workload(2, [[(DEQ, None)], [(DEQ, None)]])
    assert all(o["value"] == EMPTY for o in hist.ops)


@pytest.mark.parametrize("seed", range(8))
def test_concurrent_enq_deq_linearizable(seed):
    n = 4
    ops = [[(ENQ, 100 * t + i) for i in range(2)] + [(DEQ, None)] for t in range(n)]
    q, hist, _ = run_workload(n, ops, seed=seed)
    assert is_linearizable(hist.ops, semantics="queue")
    enqueued = {o["param"] for o in hist.ops if o["name"] == ENQ}
    dequeued = {o["value"] for o in hist.ops if o["name"] == DEQ and o["value"] != EMPTY}
    remaining = set(q.peek_queue())
    assert dequeued | remaining == enqueued
    assert dequeued & remaining == set()


def test_two_sided_elimination_fires():
    """Once the queue drains, enq/deq pairs must resolve announcement-to-
    announcement without touching the structure."""
    n = 8
    ops = [[(ENQ, t)] if t % 2 == 0 else [(DEQ, None)] for t in range(n)]
    q, hist, mem = run_workload(n, ops, seed=3)
    enqueued = {o["param"] for o in hist.ops if o["name"] == ENQ}
    dequeued = {o["value"] for o in hist.ops if o["name"] == DEQ and o["value"] != EMPTY}
    assert set(q.peek_queue()) == enqueued - dequeued
    combine_pwbs = mem.stats.pwb.get("combine", 0)
    assert combine_pwbs < 2 * sum(len(o) for o in ops)


def test_announce_path_cost_matches_stack():
    _, _, mem = run_workload(2, [[(ENQ, 1)], [(DEQ, None)]])
    assert mem.stats.pwb["announce"] == 2 * 2
    assert mem.stats.pfence["announce"] == 2 * 2


# ----------------------------------------------------------------- crash sweep
def _sweep(workloads, seed, mode, stride=1):
    steps = total_steps(workloads, seed=seed, structure=DFCQueue)
    failures = []
    outcomes = set()
    for k in range(1, steps, stride):
        res = run_with_crash(
            workloads, crash_at=k, seed=seed, mode=mode, structure=DFCQueue
        )
        assert res.crashed
        # detectability: a taken-effect op's response was computed by (or
        # before) Recover; a not-taken-effect op left no visible trace that
        # matches its announcement.  The linearizability check validates the
        # reported responses against FIFO semantics.
        for tid, effect in res.took_effect.items():
            outcomes.add(effect)
            if effect:
                assert res.recovered[tid] is not BOT
                assert res.recovered[tid] != INIT
        if not check_durable_linearizability(res):
            failures.append(k)
    assert not failures, f"non-linearizable effective history at crash points {failures}"
    return outcomes


@pytest.mark.parametrize("mode", [CrashMode.MIN, CrashMode.MAX])
def test_exhaustive_crash_sweep_every_step(mode):
    """Every yield step of an in-flight enq and deq (thread 0's ops)."""
    outcomes = _sweep(SMALL, seed=0, mode=mode, stride=1)
    assert outcomes == {True, False}  # detectability fires both ways


def test_random_eviction_crash_sweep():
    _sweep(SMALL, seed=1, mode=CrashMode.RANDOM, stride=2)


@pytest.mark.parametrize("seed", range(2))
def test_crash_sweep_larger(seed):
    workloads = [
        [(ENQ, 100 * t + i) for i in range(2)] + [(DEQ, None)] for t in range(4)
    ]
    _sweep(workloads, seed=seed, mode=CrashMode.RANDOM, stride=7)


def test_double_crash_during_recovery():
    steps = total_steps(SMALL, seed=2, structure=DFCQueue)
    for k in range(5, steps, 11):
        for rk in (3, 29):
            res = run_with_crash(
                SMALL,
                crash_at=k,
                seed=2,
                mode=CrashMode.RANDOM,
                recovery_crash_at=rk,
                structure=DFCQueue,
            )
            assert check_durable_linearizability(res)


def test_epoch_fixed_to_even_after_recovery():
    res = run_with_crash(SMALL, crash_at=40, seed=0, mode=CrashMode.MIN, structure=DFCQueue)
    assert res.mem.read("cEpoch", "v") % 2 == 0


def test_recovered_queue_is_fifo_consistent():
    """After recovery the queue contents drain in FIFO order consistent with
    some linearization of the effective history (checked via the drain)."""
    workloads = [[(ENQ, 7 * t + i) for i in range(3)] for t in range(3)]
    steps = total_steps(workloads, seed=4, structure=DFCQueue)
    for k in range(10, steps, 13):
        res = run_with_crash(
            workloads, crash_at=k, seed=4, mode=CrashMode.RANDOM, structure=DFCQueue
        )
        assert check_durable_linearizability(res)
