"""Durable detectable keyed map shard (fourth structure kind).

Oracle sweeps across the three combine backends, a persistence-op crash
sweep with VERDICT-IDENTICAL exactly-once recovery (a committed op's
recovered kind/resp equal the oracle's — recovery reads durable response
slots, it never re-executes), the lookup-purity pin (a lookup-only phase
must not persist the table arrays), bucket-overflow rejection isolation,
the structure-checkpoint round-trip, and the serving tier's session-state
map surviving crash/resume.
"""

import numpy as np
import pytest

import jax

from repro.checkpoint.dfc_checkpoint import (
    CrashNow,
    DFCCheckpointManager,
    FaultInjector,
    SimFS,
)
from repro.core.jax_dfc import (
    CAS_DOM,
    OP_MAP_CAS,
    OP_MAP_DELETE,
    OP_MAP_INSERT,
    OP_MAP_LOOKUP,
    OP_NONE,
    R_ACK,
    R_CAS_FAIL,
    R_EMPTY,
    R_FULL,
    R_VALUE,
    combine_map,
    init_map,
    map_bucket_host,
    map_geometry,
    sequential_reference_map,
)
from repro.launch.serve import (
    SESSION_ADMITTED,
    SESSION_QUEUED,
    SESSION_SERVED,
    SESSION_SLOT_NONE,
    RequestQueueTier,
)
from repro.runtime.dfc_shard import (
    R_OVERFLOW,
    ShardedDFCRuntime,
    sequential_hetero_reference,
    shard_of_keys_host,
)

jax.config.update("jax_platform_name", "cpu")

S, CAP, LANES, THREADS, B = 8, 128, 12, 2, 8
KINDS = ("map",) * S
# values and CAS operands live in a small domain so deletes hit, lookups
# find entries, and CAS both succeeds and fails along a schedule
VAL_DOM = 8


def _gen(rng, n, key_hi=40):
    """Mixed map batch: keys from a bounded universe, ops 0..4 (OP_NONE
    included), CAS params packed ``expected * CAS_DOM + new``."""
    keys = rng.integers(0, key_hi, n)
    ops = rng.integers(0, 5, n)
    vals = rng.integers(0, VAL_DOM, n)
    expect = rng.integers(0, VAL_DOM, n)
    params = np.where(ops == OP_MAP_CAS, expect * CAS_DOM + vals, vals).astype(
        np.float64
    )
    return keys, ops, params


def _assert_map_equal(got_pairs, expect_dict, msg=""):
    got = dict(got_pairs)
    assert set(got) == set(expect_dict), (msg, got, expect_dict)
    for k, v in expect_dict.items():
        np.testing.assert_allclose(got[k], np.float32(v), rtol=1e-6, err_msg=msg)


# ================================================================ oracle sweep
@pytest.mark.parametrize("backend", ["jnp", "ref", "pallas"])
def test_map_step_matches_oracle_randomized(backend):
    """The jitted route->combine->publish step over 8 map shards matches the
    sequential dict oracle (bucket-capacity-aware) on every backend."""
    rng = np.random.default_rng(hash(("map", backend)) % 2**32)
    rt = ShardedDFCRuntime("map", S, CAP, 32, backend=backend)
    oracle = [{} for _ in range(S)]
    for phase in range(4):
        keys, ops, params = _gen(rng, 48)
        resp, kinds = rt.step(keys, ops, params)
        eresp, ekinds = sequential_hetero_reference(
            KINDS, oracle, keys, ops.tolist(), params.tolist(), 32,
            capacity=CAP,
        )
        np.testing.assert_array_equal(np.asarray(kinds), ekinds)
        np.testing.assert_allclose(
            np.asarray(resp), np.asarray(eresp, np.float32), rtol=1e-6
        )
    for s in range(S):
        _assert_map_equal(rt.shard_contents(s), oracle[s], f"shard {s}")
    sizes = rt.shard_sizes()
    for s in range(S):
        assert int(sizes[s]) == len(oracle[s])
    epochs = np.asarray(rt.shard_epochs())
    assert all(int(e) % 2 == 0 for e in epochs)


def test_map_capacity_must_fit_buckets():
    with pytest.raises(ValueError):
        init_map(12)  # not a multiple of the 8-slot bucket width
    bslots, n_buckets = map_geometry(CAP)
    assert bslots * n_buckets == CAP


# ====================================================== bucket-full isolation
def _keys_sharing_bucket(n_needed):
    """First ``n_needed`` integer keys that share one (shard, bucket)."""
    _, n_buckets = map_geometry(CAP)
    groups = {}
    k = 0
    while True:
        s = int(shard_of_keys_host(np.asarray([k]), S)[0])
        b = int(map_bucket_host([k], n_buckets)[0])
        groups.setdefault((s, b), []).append(k)
        if len(groups[(s, b)]) == n_needed:
            return (s, b), groups[(s, b)]
        k += 1


def test_bucket_full_rejects_cleanly_neighbors_intact():
    """An insert into a full bucket is a CLEAN R_FULL: the bucket keeps its
    entries, ops on other buckets in the SAME batch proceed, and freeing a
    slot lets the rejected key in afterwards (no residue from the reject)."""
    bslots, n_buckets = map_geometry(CAP)
    (s_hot, b_hot), ks = _keys_sharing_bucket(bslots + 1)
    fill, extra = ks[:bslots], ks[bslots]
    other = next(
        k
        for k in range(10_000)
        if (
            int(shard_of_keys_host(np.asarray([k]), S)[0]),
            int(map_bucket_host([k], n_buckets)[0]),
        )
        != (s_hot, b_hot)
    )
    rt = ShardedDFCRuntime("map", S, CAP, lanes=16)
    _, kinds = rt.step(
        fill, [OP_MAP_INSERT] * bslots, [float(i) for i in range(bslots)]
    )
    assert list(np.asarray(kinds)) == [R_ACK] * bslots
    # one batch: reject (full), overwrite (hit needs no free slot), a
    # neighboring bucket's insert, and a lookup of the rejected key
    _, kinds = rt.step(
        [extra, fill[0], other, extra],
        [OP_MAP_INSERT, OP_MAP_INSERT, OP_MAP_INSERT, OP_MAP_LOOKUP],
        [7.0, 99.0, 1.0, 0.0],
    )
    assert list(np.asarray(kinds)) == [R_FULL, R_ACK, R_ACK, R_EMPTY]
    hot = dict(rt.shard_contents(s_hot))
    assert extra not in hot and hot[fill[0]] == 99.0 and len(hot) >= bslots
    # delete frees a slot; the rejected insert then applies exactly once
    _, kinds = rt.step(
        [fill[1], extra], [OP_MAP_DELETE, OP_MAP_INSERT], [0.0, 7.0]
    )
    assert list(np.asarray(kinds)) == [R_VALUE, R_ACK]
    assert dict(rt.shard_contents(s_hot))[extra] == 7.0


# ================================================================ crash sweep
def _routed_map_buckets(keys, ops, params, n_shards, lanes):
    """Host routing twin keeping the KEYS: per-shard (key, op, param) lists
    plus per-op (shard, overflowed)."""
    shard = shard_of_keys_host(keys, n_shards)
    buckets = {s: [] for s in range(n_shards)}
    meta = []
    for j in range(len(ops)):
        if ops[j] == OP_NONE:
            meta.append((None, False))
            continue
        s = int(shard[j])
        if len(buckets[s]) >= lanes:
            meta.append((s, True))
            continue
        buckets[s].append((int(keys[j]), int(ops[j]), float(params[j])))
        meta.append((s, False))
    return buckets, meta


def _run_map_with_crash(tmp_path, crash_at, backend="jnp", n_phases=3):
    """Run ``n_phases`` announce+combine rounds over a map fabric, crash at
    persistence op ``crash_at``; return what post-crash verification needs."""
    inj = FaultInjector(crash_at=crash_at)
    fs = SimFS(tmp_path, inj)
    rt = ShardedDFCRuntime(
        "map", S, CAP, LANES, fs=fs, n_threads=THREADS, backend=backend
    )
    rng = np.random.default_rng(1213)
    oracle = [{} for _ in range(S)]  # state after every COMPLETED phase
    token = 0
    by_token = {}
    completed = set()
    crashed = False
    try:
        for phase in range(n_phases):
            phase_tokens = []
            batches = []
            for t in range(THREADS):
                token += 1
                keys, ops, params = _gen(rng, B)
                by_token[token] = (t, keys, ops, params)
                batches.append((t, token, keys, ops, params))
                phase_tokens.append(token)
            for t, tok, keys, ops, params in batches:
                rt.announce(t, keys, ops, params, token=tok)
            rt.combine_phase()
            flat_keys = np.concatenate([b[2] for b in batches])
            flat_ops = np.concatenate([b[3] for b in batches])
            flat_par = np.concatenate([b[4] for b in batches])
            eresp, ekinds = sequential_hetero_reference(
                KINDS, oracle, flat_keys, flat_ops.tolist(),
                flat_par.tolist(), LANES, capacity=CAP,
            )
            off = 0
            for t, tok, keys, ops, params in batches:
                ann = rt._read_ann(t, rt._read_valid(t) & 1)
                assert ann["token"] == tok and ann["val"] is not None
                np.testing.assert_array_equal(
                    ann["val"]["kinds"], ekinds[off : off + B]
                )
                np.testing.assert_allclose(
                    ann["val"]["resp"],
                    np.asarray(eresp[off : off + B], np.float32),
                    rtol=1e-6,
                )
                off += B
            completed.update(phase_tokens)
    except CrashNow:
        crashed = True
    fs2 = fs.crash()
    rt2, report = ShardedDFCRuntime.recover(
        fs2, kind="map", n_shards=S, capacity=CAP, lanes=LANES,
        n_threads=THREADS, backend=backend,
    )
    return crashed, rt2, report, oracle, by_token, completed, inj.count


def _verify_map_crash_outcome(rt2, report, oracle, by_token, completed):
    """Every announced op either took effect exactly once or is reported
    not-applied — and a COMMITTED op's recovered verdict carries the
    oracle's response kind AND value (verdict-identical: recovery reads the
    durable response slot, it does not re-execute against recovered state)."""
    interrupted = {}
    for t, r in report.items():
        if r["token"] is None or r["token"] in completed:
            continue
        interrupted[t] = r["token"]
    if interrupted:
        verdicts = {t: report[t]["ops"] for t in interrupted}
        flat_keys = np.concatenate(
            [by_token[interrupted[t]][1] for t in sorted(interrupted)]
        )
        flat_ops = np.concatenate(
            [by_token[interrupted[t]][2] for t in sorted(interrupted)]
        )
        flat_par = np.concatenate(
            [by_token[interrupted[t]][3] for t in sorted(interrupted)]
        )
        flat_verdicts = []
        for t in sorted(interrupted):
            flat_verdicts += report[t]["ops"]
        if len(flat_verdicts) == len(flat_ops) and len(interrupted) == THREADS:
            # expected verdicts of the whole interrupted phase, from a COPY
            # of the oracle (only committed shards actually advance)
            probe = [dict(d) for d in oracle]
            eresp, ekinds = sequential_hetero_reference(
                KINDS, probe, flat_keys, flat_ops.tolist(),
                flat_par.tolist(), LANES, capacity=CAP,
            )
            buckets, meta = _routed_map_buckets(
                flat_keys, flat_ops, flat_par, S, LANES
            )
            shard_applied = {}
            for i, ((s, ovf), v) in enumerate(zip(meta, flat_verdicts)):
                if s is None or ovf:
                    assert not v.applied
                    continue
                shard_applied.setdefault(s, v.applied)
                assert shard_applied[s] == v.applied, "split verdict in shard"
                if v.applied:  # verdict-identical to the oracle
                    assert v.kind == ekinds[i], (i, v.kind, ekinds[i])
                    np.testing.assert_allclose(
                        v.resp, np.float32(eresp[i]), rtol=1e-6
                    )
            # apply exactly the committed shards' keyed op lists
            for s, items in buckets.items():
                if items and shard_applied.get(s, False):
                    oracle[s], _, _ = sequential_reference_map(
                        oracle[s],
                        [k for k, _, _ in items],
                        [o for _, o, _ in items],
                        [p for _, _, p in items],
                        capacity=CAP,
                    )
        else:
            # interrupted during ANNOUNCE: combine never ran, nothing applied
            assert all(not v.applied for vs in verdicts.values() for v in vs)
    for s in range(S):
        _assert_map_equal(rt2.shard_contents(s), oracle[s], f"shard {s}")
    epochs = np.asarray(rt2.state.epoch)
    assert all(int(e) % 2 == 0 for e in epochs)
    sizes = rt2.shard_sizes()
    for s in range(S):
        assert int(sizes[s]) == len(oracle[s])


def test_map_crash_sweep_exactly_once_or_not_applied(tmp_path):
    """Tier-1 representative: crash points strided across the workload's
    persistence ops (the full every-op x every-backend grid is the slow
    twin below)."""
    crashed, *_, total = _run_map_with_crash(tmp_path / "dry", None)
    assert not crashed
    assert total > 50
    for k in range(1, total + 1, 5):
        crashed, rt2, report, oracle, by_token, completed, _ = (
            _run_map_with_crash(tmp_path / f"k{k}", k)
        )
        assert crashed
        _verify_map_crash_outcome(rt2, report, oracle, by_token, completed)


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["jnp", "ref", "pallas"])
def test_map_crash_sweep_every_persistence_op(tmp_path, backend):
    """Acceptance sweep: EVERY persistence op of the schedule, per backend,
    verdict-identical exactly-once."""
    crashed, *_, total = _run_map_with_crash(tmp_path / "dry", None, backend)
    assert not crashed
    for k in range(1, total + 1):
        crashed, rt2, report, oracle, by_token, completed, _ = (
            _run_map_with_crash(tmp_path / f"k{k}", k, backend)
        )
        assert crashed
        _verify_map_crash_outcome(rt2, report, oracle, by_token, completed)


# ========================================================== lookup purity pin
def test_lookup_only_phase_never_persists_the_table(tmp_path):
    """Lookups must never persist values: once BOTH alternating slots hold
    the table durably (the first post-insert phase legitimately replicates
    it into the cold slot), dirty-leaf elision makes every further
    lookup-only phase re-write NONE of the table arrays (keys / values /
    occupied) — only the commit metadata — yet still returns every value
    and advances the epoch durably."""
    fs = SimFS(tmp_path)
    rt = ShardedDFCRuntime("map", S, CAP, LANES, fs=fs, n_threads=1)
    # table-array leaf indices, from the pytree flatten order itself
    probe = jax.tree_util.tree_flatten(init_map(CAP))[0]
    table_leaves = {
        f"leaf_{i}.npy"
        for i, leaf in enumerate(probe)
        if np.asarray(leaf).shape == (CAP,)
    }
    assert len(table_leaves) == 3  # keys, values, occupied
    log = []
    orig_write = fs.write

    def spy(rel, data, tag=None):
        log.append(rel)
        orig_write(rel, data, tag=tag)

    fs.write = spy
    keys = list(range(1, B + 1))
    vals = [float(v) for v in range(11, 11 + B)]
    rt.announce(0, keys, [OP_MAP_INSERT] * B, vals, token=1)
    rt.combine_phase()
    insert_writes = [r.rsplit("/", 1)[1] for r in log if "/leaf_" in r]
    assert table_leaves & set(insert_writes)  # inserts DO persist the table
    # warm the cold alternate slot: this one phase may copy the table
    rt.announce(0, keys, [OP_MAP_LOOKUP] * B, [0.0] * B, token=2)
    rt.combine_phase()
    epochs_before = np.asarray(rt.shard_epochs()).copy()

    for token in (3, 4):  # steady state: both slots warm, nothing to write
        log.clear()
        rt.announce(0, keys, [OP_MAP_LOOKUP] * B, [0.0] * B, token=token)
        rt.combine_phase()
        lookup_writes = [r.rsplit("/", 1)[1] for r in log if "/leaf_" in r]
        assert not (table_leaves & set(lookup_writes)), lookup_writes
        val = rt.read_responses(0, token=token)
        assert val["kinds"] == [R_VALUE] * B
        np.testing.assert_allclose(val["resp"], np.asarray(vals, np.float32))
    # the lookup phases still committed durably (epochs moved by 2 each)
    touched = epochs_before > 0
    assert np.all(
        np.asarray(rt.shard_epochs())[touched] == epochs_before[touched] + 4
    )


# ================================================== lookup detectability fix
def test_recovered_lookup_reports_durable_read_value(tmp_path):
    """Directed regression: a recovered committed OP_MAP_LOOKUP reports the
    value it READ from the durable response slot — mutating the map after
    recovery must not change it, and replay must not re-announce it."""
    fs = SimFS(tmp_path)
    rt = ShardedDFCRuntime("map", S, CAP, LANES, fs=fs, n_threads=1)
    keys, vals = [3, 11, 27], [5.0, 6.0, 7.0]
    rt.announce(0, keys, [OP_MAP_INSERT] * 3, vals, token=1)
    rt.combine_phase()
    rt.announce(0, keys, [OP_MAP_LOOKUP] * 3, [0.0] * 3, token=2)
    rt.combine_phase()
    # crash BEFORE the host ever read the lookup responses
    rt2, report = ShardedDFCRuntime.recover(
        fs.crash(), kind="map", n_shards=S, capacity=CAP, lanes=LANES,
        n_threads=1,
    )
    r = report[0]
    assert r["token"] == 2
    for v, val in zip(r["ops"], vals):
        assert v.applied and v.kind == R_VALUE
        assert float(v.resp) == val
    # committed lookups are applied: replay must NOT re-announce them (a
    # re-executed lookup would report post-crash state the op never saw)
    assert rt2.replay_pending(report) == []
    # overwrite the entries; the durable verdict for token 2 is unchanged
    rt2.announce(0, keys, [OP_MAP_INSERT] * 3, [100.0, 101.0, 102.0], token=3)
    rt2.combine_phase()
    val = rt2.read_responses(0, token=2)
    assert val["kinds"] == [R_VALUE] * 3
    np.testing.assert_allclose(val["resp"], np.asarray(vals, np.float32))


# ======================================================= checkpoint roundtrip
def test_map_checkpoint_roundtrip(tmp_path):
    """MapState persists through combine_structure and reloads bit-identically
    (typed, with the committed count in the manifest), and the restored
    state keeps combining exactly like the original."""
    state = init_map(32)
    state, _, kinds = combine_map(
        state, [1, 2, 3, 0], [OP_MAP_INSERT] * 4, [10.0, 11.0, 12.0, 13.0]
    )
    assert list(np.asarray(kinds)) == [R_ACK] * 4
    state, _, _ = combine_map(
        state, [2, 5], [OP_MAP_DELETE, OP_MAP_INSERT], [0.0, 9.0]
    )
    fs = SimFS(tmp_path)
    mgr = DFCCheckpointManager(fs, n_workers=1)
    mgr.announce(0, {"step": 1, "cursor": 1})
    assert mgr.combine_structure(state, {"step": 1}) == [0]

    mgr2 = DFCCheckpointManager(fs.crash(), n_workers=1)
    mgr2.recover()
    restored, man = mgr2.load_structure()
    assert man["meta"]["struct"] == "map"
    assert man["meta"]["committed_count"] == 4 == int(state.active_count())
    assert type(restored) is type(state)
    for a, b in zip(
        jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    again, resp_a, kinds_a = combine_map(
        restored, [5, 2, 1], [OP_MAP_LOOKUP, OP_MAP_LOOKUP, OP_MAP_CAS],
        [0.0, 0.0, 10.0 * CAS_DOM + 2.0],
    )
    expect, resp_e, kinds_e = combine_map(
        state, [5, 2, 1], [OP_MAP_LOOKUP, OP_MAP_LOOKUP, OP_MAP_CAS],
        [0.0, 0.0, 10.0 * CAS_DOM + 2.0],
    )
    np.testing.assert_array_equal(np.asarray(kinds_a), np.asarray(kinds_e))
    np.testing.assert_allclose(np.asarray(resp_a), np.asarray(resp_e))
    for a, b in zip(
        jax.tree_util.tree_leaves(expect), jax.tree_util.tree_leaves(again)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# =========================================================== serving tier map
def _tier_schedule(tier):
    """Submit -> admit -> serve one lifecycle slice on a durable tier."""
    tier.submit([1, 2, 3, 4], priorities=[0, 1, 0, 0])
    pairs = tier.admit(2)
    if pairs:
        tier.mark_served(pairs[0][0])
    return pairs


def test_tier_session_state_survives_crash_resume(tmp_path):
    """The session-state map shard rides the SAME fabric as the queues and
    pool: after a clean crash, one recovery walk returns the full serving
    state, and the lifecycle continues from it."""
    fs = SimFS(tmp_path)
    tier = RequestQueueTier(
        n_queues=2, slots=2, capacity=512, lanes=16, durable=True, fs=fs,
        priority=True,
    )
    pairs = _tier_schedule(tier)
    assert len(pairs) == 2
    served_sid, served_slot = pairs[0]
    expect = tier.session_states()
    assert set(expect) == {1, 2, 3, 4}
    assert expect[served_sid]["stage"] == SESSION_SERVED
    assert expect[served_sid]["slot"] == served_slot
    assert expect[pairs[1][0]]["stage"] == SESSION_ADMITTED
    assert expect[2]["priority"] == 1
    queued = [sid for sid, st in expect.items() if st["stage"] == SESSION_QUEUED]
    assert len(queued) == 2
    assert all(expect[sid]["slot"] == SESSION_SLOT_NONE for sid in queued)

    tier2, info = RequestQueueTier.recover(
        fs.crash(), n_queues=2, capacity=512, lanes=16, priority=True
    )
    assert info["sessions"] == expect
    assert tier2.session_states() == expect
    # reads THROUGH the recovered fabric agree with the walk
    assert tier2.session_state(served_sid) == expect[served_sid]
    # lifecycle continues: free the served slot, admit a queued session
    tier2.submit([], release_slots=[served_slot])
    more = tier2.admit(1)
    assert len(more) == 1 and more[0][0] in queued
    st = tier2.session_state(more[0][0])
    assert st["stage"] == SESSION_ADMITTED and st["slot"] == more[0][1]


def test_tier_session_state_crash_sweep(tmp_path):
    """Crash the tier at strided persistence ops: every recovered session
    entry decodes to a coherent lifecycle state, and the recovery info's
    one-walk snapshot equals a fresh fabric read."""
    inj = FaultInjector(crash_at=None)
    fs = SimFS(tmp_path / "dry", inj)
    tier = RequestQueueTier(
        n_queues=2, slots=2, capacity=512, lanes=16, durable=True, fs=fs,
        priority=True,
    )
    _tier_schedule(tier)
    total = inj.count
    assert total > 40
    for k in range(3, total, 11):
        inj = FaultInjector(crash_at=k)
        fs = SimFS(tmp_path / f"k{k}", inj)
        try:
            t = RequestQueueTier(
                n_queues=2, slots=2, capacity=512, lanes=16, durable=True,
                fs=fs, priority=True,
            )
            _tier_schedule(t)
        except CrashNow:
            pass
        tier2, info = RequestQueueTier.recover(
            fs.crash(), n_queues=2, capacity=512, lanes=16, priority=True
        )
        assert info["sessions"] == tier2.session_states()
        for sid, st in info["sessions"].items():
            assert sid in (1, 2, 3, 4)
            assert st["stage"] in (
                SESSION_QUEUED, SESSION_ADMITTED, SESSION_SERVED,
            )
            if st["stage"] == SESSION_QUEUED:
                assert st["slot"] == SESSION_SLOT_NONE
            else:  # bound sessions always carry their decode slot
                assert st["slot"] != SESSION_SLOT_NONE
        # committed lookup reads recovered from durable response slots only
        for sid, st in info["session_reads"].items():
            assert st["stage"] in (
                SESSION_QUEUED, SESSION_ADMITTED, SESSION_SERVED,
            )
