"""Multi-thread announcing fabric under depth-D pipelining: crash harness.

Covers the ISSUE-5 acceptance criteria: ``n_threads > 1`` announcers drive
a depth-D fabric through the seeded ``MultiThreadDriver`` (replayable random
announcer/combiner interleavings), a crash is injected at EVERY persistence
op of the schedule, and recovery must produce per-thread detectability
verdicts that are both SOUND (an op reported applied is durably in the
fabric with its response) and COMPLETE (replaying the not-applied ops and
re-driving the never-surfaced batches yields every announced value exactly
once).  The grid n_threads x depth x structure runs under the ``slow``
marker; tier-1 keeps full-sweep representatives of each mechanism.

The driver's determinism is what makes the sweep meaningful: the same seed
replays the same interleaving op-for-op, so crash point k in one run is the
same protocol state as crash point k in any other.
"""

import numpy as np
import pytest

import jax

from repro.checkpoint.dfc_checkpoint import CrashNow, FaultInjector, SimFS
from repro.core.jax_dfc import OP_ENQ, OP_PUSH, OP_PUSHR
from repro.runtime.announce_driver import MultiThreadDriver
from repro.runtime.dfc_shard import ShardedDFCRuntime, StaleTokenError

jax.config.update("jax_platform_name", "cpu")

CAP, LANES = 256, 16
PUSH_OF = {"stack": OP_PUSH, "queue": OP_ENQ, "deque": OP_PUSHR}


def _submit_all(drv, kinds, n_rounds, per_thread, seed=11):
    """Insert-only workload with globally unique params: every thread gets
    ``n_rounds`` batches; multiset equality of the final contents IS the
    exactly-once check."""
    rng = np.random.default_rng(seed)
    val = 1.0
    for _ in range(n_rounds):
        for t in range(drv.n_threads):
            keys = [int(k) for k in rng.integers(0, 1000, per_thread)]
            ops = [PUSH_OF[kinds[0]]] * per_thread
            params = [val + i for i in range(per_thread)]
            val += per_thread
            drv.submit(t, keys, ops, params)
    return val


def _fabric_contents(rt):
    return sorted(sum((rt.shard_contents(s) for s in range(rt.n_shards)), []))


def _scenario(tmp, crash_at, kinds, *, n_threads, depth, seed=42,
              n_rounds=2, per_thread=4):
    """Drive the interleaved schedule with a crash at persistence op
    ``crash_at``; return (fs, recovered rt, report, driver, op count)."""
    inj = FaultInjector(crash_at=crash_at)
    fs = SimFS(tmp, inj)
    n_shards = len(kinds)
    rt = ShardedDFCRuntime(
        kinds, n_shards, CAP, LANES, fs=fs, n_threads=n_threads,
        depth=depth, chain=min(2, n_threads),
    )
    drv = MultiThreadDriver(rt, seed=seed)
    _submit_all(drv, kinds, n_rounds, per_thread)
    try:
        drv.run()
    except CrashNow:
        pass
    rt2, report = ShardedDFCRuntime.recover(
        fs.crash(), kind=kinds, n_shards=n_shards, capacity=CAP, lanes=LANES,
        n_threads=n_threads, depth=depth, chain=min(2, n_threads),
    )
    return rt2, report, drv, inj.count


def _verify_exactly_once(rt2, report, drv, *, seed=43):
    """Soundness: every applied verdict's value is already durable, for both
    announcement slots of every thread.  Completeness: replay the
    not-applied ops, re-drive the never-surfaced batches through a fresh
    seeded driver (tokens continue monotonically), and check the final
    contents hold every submitted value exactly once."""
    assert all(int(e) % 2 == 0 for e in rt2.shard_epochs())
    contents = _fabric_contents(rt2)
    assert len(contents) == len(set(contents)), "duplicated op after recovery"
    for t in range(drv.n_threads):
        r = report[t]
        for rec in ([r] if r["token"] is not None else []) + (
            [r["prev"]] if r.get("prev") else []
        ):
            _, _, params = drv.history[t][rec["token"]]
            for i, v in enumerate(rec["ops"]):
                if v.applied:
                    assert params[i] in contents, (t, rec["token"], i)
    rt2.replay_pending(report)
    surf = {t: report[t]["token"] or 0 for t in range(drv.n_threads)}
    drv2 = MultiThreadDriver(rt2, seed=seed, start_tokens=surf)
    for t, token in drv.unsurfaced(report):
        keys, ops, params = drv.history[t][token]
        assert drv2.submit(t, keys, ops, params) == token
    drv2.run()
    expect = sorted(
        p
        for t in range(drv.n_threads)
        for rec in drv.history[t].values()
        for p in rec[2]
    )
    got = _fabric_contents(rt2)
    assert got == expect, "lost or duplicated ops across the crash"


def _sweep(tmp_path, kinds, *, n_threads, depth, step=1, seed=42):
    rt_dry, report_dry, drv_dry, total = _scenario(
        tmp_path / "dry", None, kinds, n_threads=n_threads, depth=depth,
        seed=seed,
    )
    _verify_exactly_once(rt_dry, report_dry, drv_dry)
    assert total > 40
    for k in range(1, total + 1, step):
        rt2, report, drv, _ = _scenario(
            tmp_path / f"k{k}", k, kinds, n_threads=n_threads, depth=depth,
            seed=seed,
        )
        _verify_exactly_once(rt2, report, drv)


# ----------------------------------------------------------- tier-1 sweeps
def test_multithread_depth2_crash_sweep(tmp_path):
    """Acceptance representative: 2 announcers, depth 2, queue fabric —
    every persistence op of the interleaved schedule is a safe crash
    point."""
    _sweep(tmp_path, ["queue", "queue"], n_threads=2, depth=2)


def test_multithread_depth3_crash_sweep(tmp_path):
    """Acceptance representative: 2 announcers, depth 3 (two chains held in
    flight; ``announce`` force-retires on slot reclaim), stack fabric."""
    _sweep(tmp_path, ["stack", "stack"], n_threads=2, depth=3)


def test_driver_interleaving_is_replayable(tmp_path):
    """Identical seed + submissions -> identical action trace, dispatch
    order, and persistence-op count: the property the crash sweep rests
    on."""
    runs = []
    for i in range(2):
        fs = SimFS(tmp_path / f"r{i}")
        rt = ShardedDFCRuntime(
            ["queue", "deque"], 2, CAP, LANES, fs=fs, n_threads=3, depth=3,
        )
        drv = MultiThreadDriver(rt, seed=7)
        _submit_all(drv, ["queue"], 2, 3)
        drv.run()
        runs.append((drv.trace, drv.dispatch_order, fs.stats["pwb"],
                     fs.stats["pfence"], _fabric_contents(rt)))
    assert runs[0] == runs[1]


def test_depth3_holds_two_chains_in_flight(tmp_path):
    """Directed: with 4 announcing threads at depth 3, combine_phase leaves
    up to two dispatched chains un-retired; responses become durable only on
    retire, and announce() reclaiming a slot force-retires in commit
    order."""
    fs = SimFS(tmp_path)
    rt = ShardedDFCRuntime(
        ["queue"], 1, CAP, LANES, fs=fs, n_threads=4, depth=3,
    )
    # record the order chains retire in (ISSUE-6: _inflight became a deque
    # for O(1) flush — commit order must stay oldest-first regardless)
    retire_order = []
    orig_retire = rt._retire

    def _recording_retire(fl):
        retire_order.append(
            sorted({seg["token"] for info in fl["batches"]
                    for seg in info["threads"]})
        )
        return orig_retire(fl)

    rt._retire = _recording_retire
    for t in range(4):
        rt.announce(t, [t], [OP_ENQ], [float(t + 1)], token=1)
    rt.combine_phase()  # chain A dispatched, in flight
    assert len(rt._inflight) == 1
    assert rt.read_responses(0, token=1) is None  # not yet durable
    for t in range(4):
        rt.announce(t, [t], [OP_ENQ], [float(t + 5)], token=2)
    rt.combine_phase()  # chain B dispatched; A still in flight (depth 3)
    assert len(rt._inflight) == 2
    assert rt.read_responses(0, token=1) is None
    # announcing token 3 reclaims token 1's slot: chain A force-retires (its
    # responses go durable BEFORE the slot is reused), and the record is then
    # overwritten — reading it now is a loud StaleTokenError, not a stale hit
    rt.announce(0, [0], [OP_ENQ], [9.0], token=3)
    assert len(rt._inflight) == 1  # chain A retired, chain B still in flight
    with pytest.raises(StaleTokenError):
        rt.read_responses(0, token=1)
    rt.combine_phase()
    rt.flush()
    assert rt.read_responses(0, token=2) is not None  # retired, durable
    assert rt.read_responses(0, token=3) is not None
    assert _fabric_contents(rt) == sorted(
        [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0]
    )
    # chains retired strictly oldest-first: A (force-retire on slot
    # reclaim), then B and C drained by flush in dispatch order
    assert retire_order == [[1], [2], [3]]


def test_per_thread_verdicts_name_the_right_ops(tmp_path):
    """Per-thread detectability: crash between two chained commits — each
    thread's report must mark exactly its own committed ops applied, with
    the responses the oracle assigns to THAT thread's batch."""
    fs = SimFS(tmp_path)
    rt = ShardedDFCRuntime(
        ["queue"], 1, CAP, LANES, fs=fs, n_threads=2, depth=2, chain=2,
    )
    rt.announce(0, [1, 2], [OP_ENQ] * 2, [1.0, 2.0], token=1)
    rt.announce(1, [3], [OP_ENQ], [3.0], token=1)
    rt.combine_phase()  # one chained dispatch, two per-thread batches
    # crash before retire: both threads' batches in flight
    rt2, report = ShardedDFCRuntime.recover(
        fs.crash(), kind=["queue"], n_shards=1, capacity=CAP, lanes=LANES,
        n_threads=2, depth=2, chain=2,
    )
    for t in (0, 1):
        assert report[t]["token"] == 1
        assert all(not v.applied for v in report[t]["ops"])
    assert sorted(rt2.replay_pending(report)) == [0, 1]
    assert _fabric_contents(rt2) == [1.0, 2.0, 3.0]
    for t, n_ops in ((0, 2), (1, 1)):
        val = rt2.read_responses(t, token=1)
        assert val is not None and len(val["kinds"]) == n_ops


# ------------------------------------------------------------- slow grid
@pytest.mark.slow
@pytest.mark.parametrize("kind", ["stack", "queue", "deque"])
@pytest.mark.parametrize("depth", [2, 3])
@pytest.mark.parametrize("n_threads", [2, 4])
def test_multithread_crash_sweep_grid(tmp_path, kind, depth, n_threads):
    """Full ISSUE-5 grid: crash at EVERY persistence op for n_threads in
    {2,4} x depth in {2,3} x every structure kind."""
    _sweep(
        tmp_path, [kind, kind], n_threads=n_threads, depth=depth,
        seed=13 * depth + n_threads,
    )
