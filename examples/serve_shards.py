"""Serving-style traffic over the sharded DFC runtime.

Generates a Zipf-skewed key workload (a few hot keys dominate, like any
serving tier), drives a ShardedDFCRuntime with mixed push/pop batches, and
prints per-shard load, throughput, and — in durable mode — pwb/op, the
paper's Figure-3 metric, now amortized across objects as well as ops.

Run:  PYTHONPATH=src python examples/serve_shards.py [--kind queue]
      [--shards 16] [--skew 1.1] [--phases 50] [--durable]
"""

from __future__ import annotations

import argparse
import tempfile
import time
from pathlib import Path

import numpy as np

import jax

from repro.checkpoint.dfc_checkpoint import SimFS
from repro.core.jax_dfc import STRUCTS
from repro.runtime.dfc_shard import (
    R_OVERFLOW,
    ShardedDFCRuntime,
    shard_of_keys_host,
    zipf_keys,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", default="queue", choices=sorted(STRUCTS))
    ap.add_argument("--shards", type=int, default=16)
    ap.add_argument("--skew", type=float, default=1.1)
    ap.add_argument("--phases", type=int, default=50)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--durable", action="store_true")
    args = ap.parse_args()

    jax.config.update("jax_platform_name", "cpu")
    rng = np.random.default_rng(0)
    opmax = STRUCTS[args.kind].n_opcodes
    lanes = args.batch  # worst case: every op on one shard
    capacity = args.batch * (args.phases + 1)

    fs = None
    if args.durable:
        fs = SimFS(Path(tempfile.mkdtemp(prefix="dfc_serve_")))
    rt = ShardedDFCRuntime(
        args.kind, args.shards, capacity, lanes, fs=fs, n_threads=1
    )

    n_ops = n_overflow = 0
    shard_hits = np.zeros(args.shards, np.int64)
    t0 = time.perf_counter()
    for phase in range(args.phases):
        keys = zipf_keys(rng, args.batch, 4096, args.skew)
        ops = rng.integers(1, opmax, args.batch)
        params = rng.random(args.batch).astype(np.float32) * 100
        if args.durable:
            rt.announce(0, keys, ops, params, token=phase + 1)
            rt.combine_phase()
            kinds = np.asarray(rt.read_responses(0)["kinds"])
        else:
            _, kinds = rt.step(keys, ops, params)
            kinds = np.asarray(kinds)
        n_ops += int(np.sum(kinds != R_OVERFLOW))
        n_overflow += int(np.sum(kinds == R_OVERFLOW))
        shard_hits += np.bincount(
            shard_of_keys_host(keys, args.shards), minlength=args.shards
        )
    dt = time.perf_counter() - t0

    print(f"kind={args.kind} shards={args.shards} skew={args.skew}")
    print(f"throughput: {n_ops / dt:,.0f} ops/s  ({args.phases} phases, {dt:.2f}s)")
    print(f"overflow:   {n_overflow} ops rejected (re-announce to retry)")
    hot = ", ".join(f"s{s}:{h}" for s, h in enumerate(shard_hits))
    print(f"shard load: {hot}")
    touched = np.asarray(rt.meta["phases"])
    print(f"phases/shard: min={touched.min()} max={touched.max()}")
    if args.durable:
        print(
            f"pwb/op: {fs.stats['pwb'] / max(n_ops, 1):.3f}  "
            f"pfence/op: {fs.stats['pfence'] / max(n_ops, 1):.3f}"
        )


if __name__ == "__main__":
    main()
