"""Serving-style traffic over the sharded DFC runtime.

Generates a Zipf-skewed key workload (a few hot keys dominate, like any
serving tier), drives a ShardedDFCRuntime with mixed push/pop batches, and
prints per-shard load, throughput, and — in durable mode — pwb/op, the
paper's Figure-3 metric, now amortized across objects as well as ops.

PR-3 options: ``--mixed`` runs a HETEROGENEOUS fabric (stack/queue/deque
shards round-robin behind one router; op codes are drawn per key to be valid
for the target shard's kind), and ``--split-backlog N`` splits the hottest
shard crash-consistently once it has absorbed N more ops than the average —
watch the shard-load histogram flatten after the split.

ISSUE-5 options: ``--threads T`` announces each durable phase from T
concurrent announcers through the seeded ``MultiThreadDriver`` (random but
replayable announcer/combiner interleavings), and ``--depth D`` pipelines
the durable path D chains deep — together the two axes the paper's
amortization claim actually grows along.

Run:  PYTHONPATH=src python examples/serve_shards.py [--kind queue|--mixed]
      [--shards 16] [--skew 1.1] [--phases 50] [--durable] [--split-backlog N]
      [--threads 4] [--depth 3]
"""

from __future__ import annotations

import argparse
import tempfile
import time
from pathlib import Path

import numpy as np

import jax

from repro.checkpoint.dfc_checkpoint import SimFS
from repro.core.jax_dfc import STRUCTS
from repro.runtime.announce_driver import MultiThreadDriver
from repro.runtime.dfc_shard import (
    R_OVERFLOW,
    ShardedDFCRuntime,
    zipf_keys,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", default="queue", choices=sorted(STRUCTS))
    ap.add_argument("--mixed", action="store_true",
                    help="heterogeneous fabric: kinds round-robin per shard")
    ap.add_argument("--shards", type=int, default=16)
    ap.add_argument("--skew", type=float, default=1.1)
    ap.add_argument("--phases", type=int, default=50)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--durable", action="store_true")
    ap.add_argument("--threads", type=int, default=1,
                    help="announcing threads per durable phase (seeded "
                         "interleaved scheduler when > 1)")
    ap.add_argument("--depth", type=int, default=0,
                    help="durable pipeline depth (0 = serial)")
    ap.add_argument("--split-backlog", type=int, default=0,
                    help="split the hottest shard once it leads the mean "
                         "op count by N (0 = never)")
    args = ap.parse_args()

    jax.config.update("jax_platform_name", "cpu")
    rng = np.random.default_rng(0)
    all_kinds = sorted(STRUCTS)
    kinds = (
        [all_kinds[s % len(all_kinds)] for s in range(args.shards)]
        if args.mixed
        else args.kind
    )
    lanes = args.batch  # worst case: every op on one shard
    capacity = args.batch * (args.phases + 1)

    fs = None
    if args.durable:
        fs = SimFS(Path(tempfile.mkdtemp(prefix="dfc_serve_")))
    rt = ShardedDFCRuntime(
        kinds, args.shards, capacity, lanes, fs=fs, n_threads=args.threads,
        n_buckets=4 * args.shards if args.split_backlog else None,
        depth=args.depth or None,
        chain=args.threads if (args.depth or 0) > 1 else 1,
    )
    drv = (
        MultiThreadDriver(rt, seed=1)
        if args.durable and args.threads > 1
        else None
    )

    n_ops = n_overflow = 0
    shard_hits = np.zeros(args.shards, np.int64)
    splits = []
    t0 = time.perf_counter()
    for phase in range(args.phases):
        keys = zipf_keys(rng, args.batch, 4096, args.skew)
        shard = rt.route_host(keys)
        opmax = np.asarray([STRUCTS[k].n_opcodes for k in rt.kinds])
        ops = rng.integers(1, opmax[shard])  # per-key draw valid for its kind
        params = rng.random(args.batch).astype(np.float32) * 100
        if args.durable and drv is not None:
            # slice the phase's batch across the announcing threads; the
            # seeded driver interleaves announce/combine actions replayably
            per = (args.batch + args.threads - 1) // args.threads
            toks = []
            for t in range(args.threads):
                sl = slice(t * per, min((t + 1) * per, args.batch))
                if sl.start >= sl.stop:
                    break
                toks.append((t, drv.submit(t, keys[sl], ops[sl], params[sl])))
            drv.run()
            kinds_out = np.concatenate([
                np.asarray(rt.read_responses(t, token=tok)["kinds"])
                for t, tok in toks
            ])
        elif args.durable:
            rt.announce(0, keys, ops, params, token=phase + 1)
            rt.combine_phase()
            rt.flush()
            kinds_out = np.asarray(rt.read_responses(0)["kinds"])
        else:
            _, kinds_out = rt.step(keys, ops, params)
            kinds_out = np.asarray(kinds_out)
        n_ops += int(np.sum(kinds_out != R_OVERFLOW))
        n_overflow += int(np.sum(kinds_out == R_OVERFLOW))
        if shard_hits.shape[0] < rt.n_shards:  # a split added shards
            shard_hits = np.concatenate(
                [shard_hits, np.zeros(rt.n_shards - shard_hits.shape[0], np.int64)]
            )
        shard_hits[: shard.max() + 1] += np.bincount(shard, minlength=shard.max() + 1)

        if args.split_backlog:
            ops_comb = np.asarray(rt.meta["ops_combined"])
            hot = int(np.argmax(ops_comb))
            if ops_comb[hot] - ops_comb.mean() > args.split_backlog:
                try:
                    new_id = rt.split_shard(hot)
                    splits.append((phase, hot, new_id))
                except ValueError:
                    pass  # shard down to one bucket
    dt = time.perf_counter() - t0
    if shard_hits.shape[0] < rt.n_shards:  # a final-phase split added shards
        shard_hits = np.concatenate(
            [shard_hits, np.zeros(rt.n_shards - shard_hits.shape[0], np.int64)]
        )

    label = "mixed" if args.mixed else args.kind
    print(f"kind={label} shards={rt.n_shards} skew={args.skew}")
    print(f"throughput: {n_ops / dt:,.0f} ops/s  ({args.phases} phases, {dt:.2f}s)")
    print(f"overflow:   {n_overflow} ops rejected (re-announce to retry)")
    hot = ", ".join(f"s{s}({rt.kinds[s][0]}):{h}" for s, h in enumerate(shard_hits))
    print(f"shard load: {hot}")
    touched = np.asarray(rt.meta["phases"])
    print(f"phases/shard: min={touched.min()} max={touched.max()}")
    for phase, donor, new_id in splits:
        print(f"split: phase {phase}: shard {donor} -> +shard {new_id}")
    if args.durable:
        print(
            f"pwb/op: {fs.stats['pwb'] / max(n_ops, 1):.3f}  "
            f"pfence/op: {fs.stats['pfence'] / max(n_ops, 1):.3f}"
        )


if __name__ == "__main__":
    main()
