"""Batched serving example: prefill + decode with the serving stack.

Serves a reduced qwen2 (same family as the assigned qwen2-1.5b) on CPU:
prefills a batch of prompts, then decodes tokens with the jitted serve_step —
the same code path the dry-run lowers for decode_32k / long_500k on the
production mesh.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models.model import init_params

cfg = dataclasses.replace(get_reduced("qwen2-1.5b"), dtype="float32")
params = init_params(cfg, jax.random.PRNGKey(0))

B, PROMPT, GEN, MAXLEN = 4, 12, 20, 48
rng = np.random.default_rng(0)
prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, PROMPT)), jnp.int32)

prefill_step = jax.jit(make_prefill_step(cfg, max_len=MAXLEN))
serve_step = jax.jit(make_serve_step(cfg))

t0 = time.perf_counter()
last_logits, cache = prefill_step(params, {"tokens": prompts})
tok = jnp.argmax(last_logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
print(f"prefill: batch={B} len={PROMPT}  ({(time.perf_counter()-t0)*1e3:.1f} ms incl. compile)")

generated = [tok]
t0 = time.perf_counter()
for i in range(GEN - 1):
    out, cache = serve_step(params, cache, {"tokens": tok})
    tok = out["next_token"][:, None].astype(jnp.int32)
    generated.append(tok)
dt = time.perf_counter() - t0
seqs = np.concatenate([np.asarray(g) for g in generated], axis=1)
print(f"decoded {GEN} tokens/seq x {B} seqs: {dt*1e3:.1f} ms "
      f"({B*GEN/dt:.0f} tok/s on CPU)")
for b in range(B):
    print(f"  seq{b}: {seqs[b].tolist()}")
print(f"cache length: {int(cache['len'])} (== {PROMPT + GEN - 1})")
