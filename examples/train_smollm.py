"""End-to-end fault-tolerant training with DFC-Checkpoint.

Trains a reduced SmolLM (same family as the assigned smollm-135m, CPU-sized)
for a few hundred steps, checkpointing through the DFC combining protocol,
then KILLS the run mid-flight, restarts, and shows the detectable resume
producing the exact same final loss as an uninterrupted run.

Run:  PYTHONPATH=src python examples/train_smollm.py [--steps 200]
"""

import argparse
import dataclasses
import tempfile
from pathlib import Path

import jax

from repro.checkpoint.dfc_checkpoint import CrashNow, FaultInjector, SimFS
from repro.configs import get_reduced
from repro.data.pipeline import DataPipeline
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import TrainRuntime


def make_rt(root, steps_cfg, injector=None):
    cfg = dataclasses.replace(get_reduced("smollm-135m"), dtype="float32")
    pipe = DataPipeline(vocab=cfg.vocab, batch_size=8, seq_len=64, seed=42)
    fs = SimFS(Path(root), injector)
    return TrainRuntime(cfg, AdamWConfig(lr=3e-4, warmup_steps=20), pipe, fs,
                        n_workers=4, ckpt_every=20)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as d:
        ref_rt = make_rt(Path(d) / "ref", args.steps)
        print(f"reference run: {args.steps} steps ...")
        _, _, ref_losses = ref_rt.train(args.steps)
        print(f"  loss {ref_losses[0]:.3f} -> {ref_losses[-1]:.3f}")

        # crashed run: die inside a mid-training combining phase
        crash_dir = Path(d) / "crashed"
        inj = FaultInjector(crash_at=len(jax.tree.leaves(ref_rt._fresh_state())) * 3 + 60)
        rt = make_rt(crash_dir, args.steps, inj)
        try:
            rt.train(args.steps)
            print("  (no crash fired — increase crash_at)")
        except CrashNow as e:
            print(f"  CRASH injected: {e}")

        # restart: fresh process view, recover, finish
        rt2 = make_rt(crash_dir, args.steps)
        params, opt, step, cursor, report = rt2.boot()
        print(f"  recovered at step {step}, cursor {cursor}")
        print(f"  detectability report: {report}")
        _, _, losses2 = rt2.train(args.steps)
        print(f"  resumed -> final loss {losses2[-1]:.6f} "
              f"(reference {ref_losses[-1]:.6f})")
        assert abs(losses2[-1] - ref_losses[-1]) < 1e-6, "exactly-once violated!"
        print("exactly-once resume verified: final losses identical.")


if __name__ == "__main__":
    main()
