"""Quickstart: the DFC persistent stack, three ways.

1. Paper-faithful simulation (Algorithms 1-2) with persistence counters and
   an injected crash + detectable recovery.
2. The TPU-native vectorized combine (one fused op per combining phase).
3. DFC-Checkpoint: the same protocol persisting a training state.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile
from pathlib import Path

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------- 1. faithful
from repro.core.dfc import POP, PUSH, DFCStack
from repro.core.harness import check_durable_linearizability, run_with_crash
from repro.core.sim import History, Scheduler, workload_gen
from repro.nvm.memory import CrashMode, NVMemory

print("== 1. paper-faithful DFC stack ==")
mem = NVMemory()
stack = DFCStack(mem, n_threads=4)
sched = Scheduler(seed=0)
hist = History()
workloads = [
    [(PUSH, 10 + t), (POP, None)] if t % 2 == 0 else [(POP, None), (PUSH, 90 + t)]
    for t in range(4)
]
gens = {t: workload_gen(stack, sched, hist, t, workloads[t]) for t in range(4)}
sched.run(gens)
print(f"   ops: {[(o['name'], o['param'], o['value']) for o in hist.ops]}")
print(f"   combining phases: {stack.phases}, eliminated pairs: {stack.eliminated_pairs}")
print(f"   pwb: {dict(mem.stats.pwb)}  pfence: {dict(mem.stats.pfence)}")

print("   crash injection at step 25 + recovery ...")
res = run_with_crash(workloads, crash_at=25, seed=0, mode=CrashMode.RANDOM)
ok = check_durable_linearizability(res)
print(f"   durable-linearizable after recovery: {ok}; took-effect: {res.took_effect}")

# ------------------------------------------------------------- 2. vectorized
from repro.core.jax_dfc import OP_POP, OP_PUSH, combine, init_stack

print("== 2. TPU-native vectorized combine ==")
state = init_stack(capacity=64)
ops = jnp.asarray([OP_PUSH, OP_PUSH, OP_POP, OP_PUSH, OP_POP, OP_POP], jnp.int32)
params = jnp.asarray([1.0, 2.0, 0, 3.0, 0, 0], jnp.float32)
state, resp, kinds = combine(state, ops, params)
print(f"   responses: {np.asarray(resp)} kinds: {np.asarray(kinds)}")
print(f"   stack after phase: {np.asarray(state.values[: int(state.active_size())])}")

# ----------------------------------------------------------- 3. checkpointing
from repro.checkpoint.dfc_checkpoint import DFCCheckpointManager, SimFS

print("== 3. DFC-Checkpoint ==")
with tempfile.TemporaryDirectory() as d:
    fs = SimFS(Path(d))
    mgr = DFCCheckpointManager(fs, n_workers=4)
    for w in range(4):
        mgr.announce(w, {"step": 1, "cursor": 1})
    mgr.combine([np.eye(3, dtype=np.float32)], {"step": 1, "cursor": 1})
    leaves, man = mgr.load_active()
    print(f"   committed step {man['meta']['step']}; pwb={fs.stats['pwb']} "
          f"pfence={fs.stats['pfence']} (4 workers -> 1 slot persist)")
    _, report = DFCCheckpointManager(fs.crash(), 4).recover()
    print(f"   detectability report: {report}")
print("done.")
