"""fabric_top: render a per-shard activity table from a fabric trace.

Reads a flight-recorder trace (``obs/trace.jsonl`` written by
``FabricObserver``, or any JSONL of trace events) and aggregates it into
the operator's view of the fabric:

  * one row per shard — kind, last sampled backlog, last committed epoch,
    commit count, and how many retired batches touched it;
  * a persistence section — pwb/pfence counts by tag, straight from the
    EV_PWB/EV_PFENCE events the SimFS hooks emit;
  * a phase section — announcements per thread, dispatches (chained and
    fused), drains, recovery verdicts.

Run:  python tools/fabric_top.py <trace.jsonl>
(``render`` is importable for tests and tools/obs_smoke.py.)
"""

from __future__ import annotations

import argparse
import sys
from collections import Counter
from pathlib import Path
from typing import Any, Dict, List

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT / "src"))

from repro.obs import (  # noqa: E402
    EV_ANNOUNCE,
    EV_DISPATCH,
    EV_DRAIN,
    EV_EPOCH,
    EV_FABRIC,
    EV_PFENCE,
    EV_PWB,
    EV_RECOVER,
    EV_RESHARD,
    EV_RETIRE,
    EV_TOPOLOGY,
    EV_VERDICT,
    read_trace,
)


def aggregate(events: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Fold a trace into the summary ``render`` prints (kept separate so
    tests can assert on numbers instead of formatting)."""
    agg: Dict[str, Any] = {
        "kinds": [],
        "capacity": None,
        "backlog": {},      # shard -> last sampled size
        "epoch": {},        # shard -> last committed epoch
        "lane_epoch": {},   # shard -> last committed [eH, eT] (split lanes)
        "lane_backlog": {},  # shard -> last sampled [head, tail] backlog
        "commits": Counter(),  # shard -> EV_EPOCH count
        "touches": Counter(),  # shard -> retired/drained batches touching it
        "pwb": Counter(),   # tag -> count
        "pfence": Counter(),
        "announces": Counter(),  # thread -> count
        "last_token": {},   # thread -> last announced token
        "dispatches": 0,
        "fused_dispatches": 0,
        "drains": 0,
        "retires": 0,
        "reshards": 0,
        "inflight": 0,
        "verdicts": [],
        "recover_stages": [],
        "n_events": len(events),
        "seq_range": (
            (events[0]["seq"], events[-1]["seq"]) if events else (None, None)
        ),
    }
    for e in events:
        ev = e.get("ev")
        if ev == EV_TOPOLOGY:
            agg["kinds"] = list(e.get("kinds", []))
            agg["capacity"] = e.get("capacity")
        elif ev == EV_FABRIC:
            for s, size in enumerate(e.get("backlog", [])):
                agg["backlog"][s] = int(size)
            for s, ep in enumerate(e.get("epochs", [])):
                agg["epoch"][s] = int(ep)
            for s, pair in e.get("lane_epochs", {}).items():
                agg["lane_epoch"][int(s)] = [int(x) for x in pair]
            for s, bl in e.get("lane_backlog", {}).items():
                agg["lane_backlog"][int(s)] = [int(x) for x in bl]
            agg["inflight"] = int(e.get("inflight", 0))
        elif ev == EV_EPOCH:
            s = int(e["shard"])
            agg["commits"][s] += 1
            agg["epoch"][s] = int(e["epoch"])
            if "lanes" in e:
                agg["lane_epoch"][s] = [int(x) for x in e["lanes"]]
        elif ev in (EV_RETIRE, EV_DRAIN):
            agg["retires" if ev == EV_RETIRE else "drains"] += 1
            for s in e.get("touched", []):
                agg["touches"][int(s)] += 1
        elif ev == EV_PWB:
            agg["pwb"][e.get("tag") or "untagged"] += 1
        elif ev == EV_PFENCE:
            agg["pfence"][e.get("tag") or "untagged"] += 1
        elif ev == EV_ANNOUNCE:
            t = int(e["thread"])
            agg["announces"][t] += 1
            agg["last_token"][t] = int(e["token"])
        elif ev == EV_DISPATCH:
            agg["fused_dispatches" if e.get("fused") else "dispatches"] += 1
        elif ev == EV_RESHARD:
            agg["reshards"] += 1
        elif ev == EV_VERDICT:
            agg["verdicts"].append(
                (int(e["thread"]), e.get("token"), e.get("applied", []))
            )
        elif ev == EV_RECOVER:
            agg["recover_stages"].append(e.get("stage"))
    return agg


def render(events: List[Dict[str, Any]]) -> str:
    a = aggregate(events)
    shards = sorted(
        set(a["backlog"]) | set(a["epoch"]) | set(a["commits"]) | set(a["touches"])
        | set(range(len(a["kinds"])))
    )
    lanes = bool(a["lane_epoch"]) or bool(a["lane_backlog"])
    # keyed-map shards report occupancy: for them "backlog" is the committed
    # entry count, so the extra columns show it as entries + table load
    maps = "map" in a["kinds"]
    header = (
        f"{'shard':>5}  {'kind':<6} {'backlog':>7} {'epoch':>6} "
        f"{'commits':>7} {'touches':>7}"
    )
    if lanes:
        header += f" {'eH/eT':>9} {'blH/blT':>9}"
    if maps:
        header += f" {'entries':>7} {'load%':>6}"
    lines = [
        f"fabric_top — {a['n_events']} events, seq "
        f"{a['seq_range'][0]}..{a['seq_range'][1]}",
        "",
        header,
    ]
    for s in shards:
        kind = a["kinds"][s] if s < len(a["kinds"]) else "?"
        row = (
            f"{s:>5}  {kind:<6} {a['backlog'].get(s, '-'):>7} "
            f"{a['epoch'].get(s, '-'):>6} {a['commits'].get(s, 0):>7} "
            f"{a['touches'].get(s, 0):>7}"
        )
        if lanes:
            le = a["lane_epoch"].get(s)
            lb = a["lane_backlog"].get(s)
            row += (
                f" {f'{le[0]}/{le[1]}' if le else '-':>9}"
                f" {f'{lb[0]}/{lb[1]}' if lb else '-':>9}"
            )
        if maps:
            if kind == "map" and s in a["backlog"]:
                n = a["backlog"][s]
                load = (
                    f"{100 * n / a['capacity']:.1f}" if a["capacity"] else "-"
                )
                row += f" {n:>7} {load:>6}"
            else:
                row += f" {'-':>7} {'-':>6}"
        lines.append(row)
    lines.append("")
    pwb = " ".join(f"{t}={n}" for t, n in sorted(a["pwb"].items())) or "-"
    pf = " ".join(f"{t}={n}" for t, n in sorted(a["pfence"].items())) or "-"
    lines.append(f"pwb    ({sum(a['pwb'].values())}): {pwb}")
    lines.append(f"pfence ({sum(a['pfence'].values())}): {pf}")
    lines.append(
        f"phases: dispatch={a['dispatches']} fused={a['fused_dispatches']} "
        f"retire={a['retires']} drain={a['drains']} reshard={a['reshards']} "
        f"inflight={a['inflight']}"
    )
    ann = " ".join(
        f"t{t}={n}(tok {a['last_token'].get(t, '-')})"
        for t, n in sorted(a["announces"].items())
    ) or "-"
    lines.append(f"announce: {ann}")
    if a["recover_stages"]:
        lines.append(f"recovery: stages={a['recover_stages']}")
        for t, tok, applied in a["verdicts"]:
            lines.append(
                f"  verdict t{t} token={tok} "
                f"applied={sum(bool(x) for x in applied)}/{len(applied)}"
            )
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="path to a trace JSONL (obs/trace.jsonl)")
    args = ap.parse_args(argv)
    events = read_trace(Path(args.trace))
    if not events:
        print(f"no events in {args.trace}", file=sys.stderr)
        return 1
    print(render(events))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
