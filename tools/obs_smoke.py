"""Obs smoke: tracing must be a pure observer of the durable path.

The flight recorder's hard constraint (docs/observability.md): enabling
tracing may not change durable state or persistence counts by even one
operation.  This script is the CI gate for that claim.  It drives the SAME
fused phase-loop schedule twice — once untraced, once under a
``FabricObserver`` with a durable sidecar — and asserts:

  1. ``fs.stats`` (total pwb/pfence) identical;
  2. ``fs.pstats`` (per-tag pwb/pfence) identical;
  3. the durable-state digest (every byte under the root, obs/ excluded)
     identical;
  4. recovery over the traced root EXTENDS the sidecar with per-thread
     verdict events, with trace seq numbers monotone across the reboot;

then renders the ``fabric_top`` table from the sidecar as a smoke of the
operator tooling.  Exits non-zero on any violation.

Run:  python tools/obs_smoke.py  (CI runs it on every push)
"""

from __future__ import annotations

import shutil
import sys
import tempfile
from pathlib import Path

import numpy as np

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT / "src"))
sys.path.insert(0, str(_ROOT / "tools"))

from repro.checkpoint.dfc_checkpoint import SimFS  # noqa: E402
from repro.obs import EV_VERDICT, FabricObserver, durable_digest, read_trace  # noqa: E402
from repro.runtime.dfc_shard import ShardedDFCRuntime  # noqa: E402

import fabric_top  # noqa: E402

KIND, N_SHARDS, BATCH, ROUNDS = "queue", 2, 8, 12
CAP = BATCH * (ROUNDS + 2)  # map-compatible too: 112 = 14 buckets of 8


def _schedule(seed=0):
    rng = np.random.default_rng(seed)
    return [
        (
            0,
            r + 1,
            rng.integers(0, 4096, BATCH),
            rng.integers(1, 3, BATCH),
            rng.random(BATCH).astype(np.float32),
        )
        for r in range(ROUNDS)
    ]


def _map_schedule(seed=1):
    """Mixed insert/lookup/delete/CAS rounds for the keyed-map case (CAS
    params pack expected*4096 + new)."""
    rng = np.random.default_rng(seed)
    sched = []
    for r in range(ROUNDS):
        ops = rng.integers(1, 5, BATCH)
        vals = rng.integers(0, 4096, BATCH).astype(np.float64)
        expect = rng.integers(0, 4096, BATCH)
        params = np.where(ops == 4, expect * 4096.0 + vals, vals)
        sched.append((0, r + 1, rng.integers(0, 4096, BATCH), ops, params))
    return sched


def _drive(root: Path, obs=None, kind=KIND, schedule=None):
    fs = SimFS(root)
    rt = ShardedDFCRuntime(
        kind, N_SHARDS, CAP, BATCH, fs=fs, n_threads=1, depth=2, obs=obs,
    )
    rt.phase_loop(schedule if schedule is not None else _schedule())
    if obs is not None:
        obs.observe_fabric(rt)
        obs.flush()
    return fs, rt


def main() -> int:
    base = Path(tempfile.mkdtemp(prefix="dfc_obs_smoke_"))
    failures = []
    try:
        fs_plain, _ = _drive(base / "plain")
        obs = FabricObserver(root=base / "traced")
        fs_traced, _ = _drive(base / "traced", obs=obs)

        if dict(fs_plain.stats) != dict(fs_traced.stats):
            failures.append(
                f"total pwb/pfence diverged: {dict(fs_plain.stats)} vs "
                f"{dict(fs_traced.stats)}"
            )
        if fs_plain.pstats.as_dict() != fs_traced.pstats.as_dict():
            failures.append(
                f"per-tag pwb/pfence diverged: {fs_plain.pstats.as_dict()} "
                f"vs {fs_traced.pstats.as_dict()}"
            )
        d_plain = durable_digest(base / "plain")
        d_traced = durable_digest(base / "traced")
        if d_plain != d_traced:
            failures.append(
                f"durable state diverged: {d_plain} vs {d_traced}"
            )

        # the purity invariant is gated on the keyed-map kind too: the same
        # insert/lookup/delete/CAS schedule traced and untraced
        fs_mplain, _ = _drive(
            base / "map_plain", kind="map", schedule=_map_schedule()
        )
        obs_map = FabricObserver(root=base / "map_traced")
        fs_mtraced, _ = _drive(
            base / "map_traced", obs=obs_map, kind="map",
            schedule=_map_schedule(),
        )
        if dict(fs_mplain.stats) != dict(fs_mtraced.stats):
            failures.append(
                f"map: total pwb/pfence diverged: {dict(fs_mplain.stats)} "
                f"vs {dict(fs_mtraced.stats)}"
            )
        if fs_mplain.pstats.as_dict() != fs_mtraced.pstats.as_dict():
            failures.append(
                f"map: per-tag pwb/pfence diverged: "
                f"{fs_mplain.pstats.as_dict()} vs "
                f"{fs_mtraced.pstats.as_dict()}"
            )
        d_mplain = durable_digest(base / "map_plain")
        d_mtraced = durable_digest(base / "map_traced")
        if d_mplain != d_mtraced:
            failures.append(
                f"map: durable state diverged: {d_mplain} vs {d_mtraced}"
            )
        print(fabric_top.render(read_trace(obs_map.trace_path)))
        print()

        # clean-reboot recovery must extend the same sidecar with verdicts
        pre = read_trace(obs.trace_path)
        obs2 = FabricObserver(root=base / "traced")
        fs2 = SimFS(base / "traced")
        _, report = ShardedDFCRuntime.recover(
            fs2, kind=KIND, n_shards=N_SHARDS, capacity=CAP, lanes=BATCH,
            n_threads=1, depth=2, obs=obs2,
        )
        post = read_trace(obs.trace_path)
        if len(post) <= len(pre):
            failures.append("recovery did not extend the trace sidecar")
        seqs = [e["seq"] for e in post]
        if seqs != sorted(seqs) or len(set(seqs)) != len(seqs):
            failures.append("trace seq numbers not strictly monotone")
        verdicts = [e for e in post if e["ev"] == EV_VERDICT]
        if not verdicts:
            failures.append("recovery emitted no verdict events")
        if report[0]["token"] != ROUNDS:
            failures.append(
                f"recovery surfaced token {report[0]['token']}, "
                f"expected {ROUNDS}"
            )

        print(fabric_top.render(post))
        print()
        for f in failures:
            print(f"FAIL {f}")
        if not failures:
            print(
                f"obs smoke OK: {len(post)} trace events, "
                f"{len(verdicts)} verdict(s), digests equal "
                f"({d_plain}), stats equal {dict(fs_plain.stats)}"
            )
        return 1 if failures else 0
    finally:
        shutil.rmtree(base, ignore_errors=True)


if __name__ == "__main__":
    raise SystemExit(main())
