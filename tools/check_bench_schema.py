"""BENCH artifact schema check: rows without a ``meta`` block fail the build.

Every ``BENCH_*.json`` at the repo root must be a list of row objects, each
carrying the ``meta`` block ``benchmarks/bench_common.write_rows`` stamps
(documented in docs/benchmarks.md):

    meta.git_sha      str   commit the numbers were measured at
    meta.backend      str   jax backend ("cpu", "gpu", "tpu")
    meta.jax_version  str
    meta.schedule     dict  the row's schedule shape + entry point

Without it a BENCH row is an unattributable number — no way to tell which
commit, stack, or schedule produced it — so CI runs this right after the
smoke benches regenerate the artifacts (they are git-ignored).

Run:  python tools/check_bench_schema.py  [paths...]
(defaults to every BENCH_*.json at the repo root; exits non-zero listing
every violation)
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent

_META_KEYS = {
    "git_sha": str,
    "backend": str,
    "jax_version": str,
    "schedule": dict,
}


def check_file(path: Path) -> list:
    """All schema violations in one artifact, as (path, message) pairs."""
    bad = []
    try:
        rows = json.loads(path.read_text())
    except Exception as e:
        return [(path, f"unreadable JSON: {e!r}")]
    if not isinstance(rows, list) or not rows:
        return [(path, "expected a non-empty list of row objects")]
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            bad.append((path, f"row {i}: not an object"))
            continue
        meta = row.get("meta")
        if not isinstance(meta, dict):
            bad.append((path, f"row {i}: missing meta block"))
            continue
        for key, typ in _META_KEYS.items():
            if not isinstance(meta.get(key), typ):
                bad.append(
                    (path, f"row {i}: meta.{key} missing or not {typ.__name__}")
                )
    return bad


def main(argv=None) -> int:
    paths = [Path(p) for p in (argv or sys.argv[1:])]
    if not paths:
        paths = sorted(_ROOT.glob("BENCH_*.json"))
    if not paths:
        print("no BENCH_*.json artifacts found (run the smoke benches first)",
              file=sys.stderr)
        return 1
    bad = []
    for p in paths:
        bad.extend(check_file(p))
    for path, msg in bad:
        print(f"BAD {path.name}: {msg}")
    if bad:
        print(f"{len(bad)} schema violation(s)", file=sys.stderr)
        return 1
    n_rows = sum(len(json.loads(p.read_text())) for p in paths)
    print(f"bench schema check: {len(paths)} artifact(s), {n_rows} rows OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
