"""Docs link check: dead RELATIVE links in markdown fail the build.

Scans every ``*.md`` under ``docs/`` plus the repo-root markdown files for
``[text](target)`` links, skips absolute URLs (http/https/mailto) and pure
in-page anchors, resolves each remaining target against the file's own
directory, and exits non-zero listing every target that does not exist.

Run:  python tools/check_docs_links.py  (CI runs it on every push)
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def md_files(root: Path):
    yield from sorted(root.glob("*.md"))
    yield from sorted((root / "docs").rglob("*.md"))


def check(root: Path) -> list:
    broken = []
    for md in md_files(root):
        for m in LINK_RE.finditer(md.read_text()):
            target = m.group(1)
            if target.startswith(SKIP_PREFIXES):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                broken.append((md.relative_to(root), target))
    return broken


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    broken = check(root)
    for md, target in broken:
        print(f"BROKEN {md}: ({target})")
    if broken:
        print(f"{len(broken)} dead relative link(s)", file=sys.stderr)
        return 1
    n = len(list(md_files(root)))
    print(f"docs link check: {n} markdown files OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
