"""Fault-tolerant training runtime wired to DFC-Checkpoint.

The loop is the end-to-end integration of the paper's protocol:

  every `ckpt_every` steps the worker ANNOUNCES (step, data cursor); the
  coordinator COMBINES all ready announcements into one slot persist with the
  two-increment epoch commit; on restart, RECOVER() yields a detectability
  report that tells the runtime exactly which step committed — training
  resumes from that step with the data cursor from the committed manifest,
  giving exactly-once step semantics end to end.

Single-process here (the simulated cluster announces N worker records); the
jitted step runs on whatever mesh the caller provides — the same code drives
the 256-chip pod via launch/train.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.dfc_checkpoint import DFCCheckpointManager, SimFS
from repro.data.pipeline import DataPipeline
from repro.models.config import ModelConfig
from repro.models.model import init_params, loss_fn
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state


@dataclasses.dataclass
class TrainRuntime:
    cfg: ModelConfig
    opt_cfg: AdamWConfig
    pipeline: DataPipeline
    fs: SimFS
    n_workers: int = 4
    ckpt_every: int = 5

    def __post_init__(self):
        self.mgr = DFCCheckpointManager(self.fs, self.n_workers)
        self._step_fn = jax.jit(self._train_step)

    # ------------------------------------------------------------------ step
    def _train_step(self, params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, self.cfg, batch))(params)
        params, opt_state, metrics = adamw_update(params, grads, opt_state, self.opt_cfg)
        return params, opt_state, dict(metrics, loss=loss)

    def _fresh_state(self):
        params = init_params(self.cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(params, self.opt_cfg)
        return params, opt

    def _pack(self, params, opt, step, cursor):
        leaves = jax.tree_util.tree_leaves((params, opt))
        return leaves, {"step": step, "cursor": cursor}

    # ------------------------------------------------------------------ boot
    def boot(self):
        """Start or resume: returns (params, opt, step, cursor, report)."""
        params, opt = self._fresh_state()
        state, report = self.mgr.recover()
        leaves, man = self.mgr.load_active()
        if leaves is None:
            return params, opt, 0, 0, report
        treedef = jax.tree_util.tree_structure((params, opt))
        params, opt = jax.tree_util.tree_unflatten(treedef, leaves)
        step = man["meta"]["step"]
        cursor = man["meta"]["cursor"]
        return params, opt, step, cursor, report

    # ------------------------------------------------------------------ train
    def train(self, n_steps: int, resume: bool = True):
        """Run to n_steps total (resuming from the committed checkpoint)."""
        params, opt, step, cursor, report = self.boot()
        losses = []
        while step < n_steps:
            batch = self.pipeline.batch_at(cursor)
            params, opt, metrics = self._step_fn(params, opt, batch)
            step += 1
            cursor += 1
            losses.append(float(metrics["loss"]))
            if step % self.ckpt_every == 0 or step == n_steps:
                # all workers announce this step (data-parallel lockstep);
                # worker 0 is the combiner
                for w in range(self.n_workers):
                    self.mgr.announce(w, {"step": step, "cursor": cursor})
                tree = jax.tree_util.tree_leaves((params, opt))
                self.mgr.combine(tree, extra_meta={"step": step, "cursor": cursor})
        return params, opt, losses
