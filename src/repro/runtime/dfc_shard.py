"""Sharded multi-object DFC runtime: one announcement fabric, many objects.

The paper's Figure-3 result is that flat combining amortizes the expensive
persistence instructions (pwb/pfence) across every op announced in a phase
(Algorithm 2's REDUCE + the single pfence of line 80).  This runtime
amortizes across *objects* too, the way a serving tier shards traffic:
``n_shards`` DFC structures — since PR 3 a MIXED population of stacks,
queues and deques — live behind ONE announcement fabric, a key->shard router
buckets each announced batch into per-shard op lists, and a fused dispatch
runs every shard's combining phase grouped BY KIND (``vmap`` per kind for
the jnp backend, one Pallas grid per kind — program instance = shard — for
the kernel backends; see ``dfc_hetero_combine_step``).

Paper mechanisms reused at fabric scale (citations follow the repo
convention: Algorithm/Figure/line numbers of arXiv:2012.12868):

  * announce (Alg. 1 lines 2-12): per-thread double-buffered announcement
    records (``ann{0,1}`` + a 2-bit ``valid`` selector, MSB published last),
  * combine + single pfence (Alg. 2, line 80): one durable phase persists
    every touched shard's new state and every combined response, then
    pfences ONCE,
  * two-increment epoch commit (Alg. 1 lines 81-83): per SHARD — persist
    cEpoch=v+1, publish v+2 unsynced; recovery rounds odd up to even
    (lines 28-30),
  * detectability (§1, Alg. 1 lines 26-43): recovery reports, per thread and
    per op, whether the op took effect and with which response,
  * recovery GC (§4): unreachable slot files of interrupted phases are
    deleted, like the paper's volatile-bitmap node reclamation.

State layout (see ``repro.core.jax_dfc.init_sharded``): shards of the same
kind form one stacked pytree (leading shard axis on every leaf), and the
fabric is a ``{kind: stacked_state}`` group dict.  Crucially ``epoch[S]`` is
per shard: shards commit independently; a combine phase only advances the
epoch of shards that actually received ops, so persistence work scales with
touched shards, not with ``n_shards``.

Routing (PR 3: now table-driven and re-shardable): a key hashes to a BUCKET
(multiplicative hashing, ``key * 2654435761``), and an ``i32[n_buckets]``
routing table maps buckets to shards.  The default table is the identity
(``bucket % n_shards`` with ``n_buckets == n_shards``) — bit-identical to
the PR-2 router.  The lane of an op within its shard is its *batch-order
rank* among the ops routed there (an exclusive prefix sum over the shard
one-hot matrix).  Both are order-preserving and independent of array layout
or backend, so the routed per-shard op lists — and therefore the combined
linearization — are bit-identical across jnp / Pallas backends and across
host replays: the flat batch order IS the announcement order.  Overflowing
ops (rank >= lanes) are cleanly rejected with ``R_OVERFLOW`` before touching
any shard, so one hot shard can never corrupt a neighbor.

Dynamic resharding (``split_shard`` / ``merge_shards``): the routing table
itself is a persistent object committed with the SAME two-increment protocol
as the shards (``routing/rEpoch``; double-buffered ``routing/slot{0,1}``
records picked by epoch parity).  A reshard is a mini-transaction:

  1. drain ready announcements (one ordinary combine phase),
  2. checkpoint the donor shard via ``DFCCheckpointManager.combine_structure``
     (a detectable typed snapshot under ``reshard/ckpt``, same SimFS so fault
     sweeps tick through it),
  3. persist a reshard INTENT record, pfence,
  4. pwb the post-reshard shard states into their inactive slots (merge
     only) and the new routing record into the inactive routing slot, ONE
     pfence,
  5. commit ``rEpoch`` with the two-increment protocol — THE commit point,
  6. roll the touched shards' cEpochs forward (merge only), drop the intent.

A crash before step 5's first fsync aborts the reshard (old routing + old
shard states; the per-shard GC reclaims the orphaned slot writes); a crash
after it commits (recovery rolls shard cEpochs forward from the intent).
Either way detectability verdicts recorded before the reshard stay valid —
they name (shard, target-epoch) pairs, and shard ids are never reused.
In-flight announcements that missed the drain are reported not-applied and
can be replayed with ``replay_pending``, giving exactly-once semantics per
op across reshards and crashes.

Pipelined durable path (ISSUE 4, after Fatourou et al. 2021/2024: overlap
the combiner's durable writes with the collection of the next batch):

  * device-side announcement queues — ``announce`` lands each batch's
    payload in a preallocated jnp ring (``repro.core.jax_dfc.AnnounceRing``)
    so combining phases consume device arrays directly; SimFS keeps only the
    compact durable mirror recovery needs, off the hot path,
  * depth-D pipelining (``depth=D``; the legacy ``pipeline=True`` flag is
    ``depth=2``, ISSUE 5 generalizes the ISSUE-4 two-stage special case) —
    ``combine_phase`` DISPATCHES the device combine for the newly collected
    chain (stage 1), then retires the OLDEST dispatched chains — persist +
    pfence + per-shard epoch commits, strictly in commit order — until at
    most D-1 remain in flight (stage 2) while the device works; ``flush``
    retires the rest.  Every in-flight chain carries its own per-batch
    epochs, and a thread's double-buffered announcement records bound it to
    two outstanding batches: ``announce`` force-retires chains (still in
    commit order) before reclaiming a slot whose batch is un-retired, so
    deep pipelines keep serial-identical pwb/pfence counts.  The
    two-increment commit still gates visibility: an in-flight chain that
    never retires is reported not-applied by ``recover`` (which also
    resolves a thread's OLDER announcement slot — the predecessor batch k
    whose successor k+1 was already announced — and ``replay_pending``
    replays it first),
  * multi-batch chaining (``chain=N``) — up to N ready batches combine in
    ONE fused dispatch (``dfc_sharded_multi_combine_step``: a ``lax.scan``
    over the batch axis, vmap or Pallas grid per kind) but persist and
    commit batch-by-batch, so pwb/pfence counts match that many serial
    phases exactly,
  * dirty-leaf persist elision — a slot leaf whose bytes already sit
    durably in that slot is not re-written (the paper's dirty-word
    tracking at leaf granularity); the slot manifest still lists it.

Persistence layout (``SimFS``-backed, pwb=write / pfence=fsync):

  tAnn/thread_{t}/ann{0,1}.json   double-buffered announcements + valid
  shard_{s}/slot{0,1}/...         alternating state slots, picked by parity
  shard_{s}/cEpoch                per-shard two-increment commit
  routing/slot{0,1}.json          alternating routing records
  routing/rEpoch                  routing-epoch two-increment commit
  reshard/intent.json             reshard transaction record
  reshard/ckpt/...                donor snapshots (DFCCheckpointManager)
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import hashlib
import io
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint.dfc_checkpoint import BOT, DFCCheckpointManager, SimFS
from repro.obs import (
    EV_ANNOUNCE,
    EV_DISPATCH,
    EV_DRAIN,
    EV_EPOCH,
    EV_RECOVER,
    EV_RESHARD,
    EV_RETIRE,
    EV_VERDICT,
    NULL_OBS,
)
from repro.core.jax_dfc import (
    KIND_CODES,
    LANE_HEAD,
    LANE_NONE,
    LANE_TAIL,
    OP_NONE,
    PhaseIntents,
    R_NONE,
    STRUCTS,
    init_announce_ring,
    lane_of_ops_host,
    init_sharded,
    ring_announce,
    ring_announce_phases,
    ring_drain,
    ring_drain_phases,
    ring_has_room,
    shard_slice,
    stack_shards,
    state_from_contents,
)
from repro.kernels.dfc_reduce.ops import (
    _one_sharded_combine,
    dfc_hetero_combine_step,
    dfc_hetero_multi_combine_step,
    dfc_hetero_multi_phase_step,
)

# runtime-level response kind: op rejected because its shard's announcement
# lanes were full this phase — never applied, safe to re-announce.
R_OVERFLOW = 4

# ---------------------------------------------------------------------------
# Per-side combiners (ISSUE 8, after Persistent Software Combining 2107.03492
# and Highly-Efficient Persistent FIFO Queues 2402.17674): with
# ``split_lanes=True`` every queue/deque shard commits through TWO
# announcement lanes — a HEAD lane (consuming side: OP_DEQ / OP_POPL,
# plus OP_PUSHL which also lives on the deque's left end) and a TAIL lane
# (producing side: OP_ENQ / OP_PUSHR / OP_POPR) — each with its own durable
# record, its own epoch, and its own one-pfence-per-phase commit, so
# opposite-side traffic never shares a persistence barrier:
#
#   shard_{s}/laneH{0,1}/rec.json [+ values.npy]   head-lane slots
#   shard_{s}/laneT{0,1}/rec.json + values.npy     tail-lane slots
#   shard_{s}/cEpoch = "[eH, eT]"                  composite epoch pair
#
# Each lane's slot parity follows ITS OWN epoch; the composite cEpoch file
# makes the pair atomic (SimFS file writes are all-or-nothing), which is what
# the drained-queue HANDOFF commit relies on: a phase that mixes both sides —
# or a head-side phase that drains the queue to empty, i.e. the moment the
# head lane's pops catch the tail lane's pushes — commits BOTH lanes in one
# two-increment step ([eH+1, eT+1] -> fsync -> [eH+2, eT+2]), the same
# discipline resharding uses, so recovery resolves a crash on either side of
# it (before the fsync: both lanes roll back together; after: both round up).
#
# ``values`` ownership per lane: the queue's head lane never writes values
# (pops only advance the head counter), so its record is a single tiny JSON —
# that asymmetry is the pwb/op win the jitter test pins.  The deque's LEFT
# side pushes into values too, so both deque lane records carry values (with
# dirty-leaf elision); recovery picks the values of the lane whose record
# carries the larger ``phases`` counter (a per-shard commit sequence number),
# which is the chronologically last committed copy.
_LANE_WRITES_VALUES = {"queue": (False, True), "deque": (True, True)}
_LANE_TAGS = ("H", "T")  # indexed by LANE_HEAD / LANE_TAIL


class StaleTokenError(LookupError):
    """``read_responses(thread, token)`` named a batch whose durable response
    record no longer exists: the double-buffered announcement slots retain
    only a thread's last two batches, and ``token`` predates both.  Distinct
    from the ``None`` return (batch announced but not yet retired) so a
    caller polling an overwritten token fails loudly instead of spinning —
    read a batch's responses before announcing two successors, or keep your
    own copy."""

_HASH_MULT = 2654435761  # Knuth multiplicative hashing constant


# ===================================================================== router
def shard_of_keys(keys, n_shards: int):
    """bucket(key): multiplicative hash, identical on host and device.

    With the identity routing table (the default) bucket == shard, which is
    why this keeps its historical name; table-driven fabrics compose it with
    a table lookup (see ``route_batch``).
    """
    k = jnp.asarray(keys).astype(jnp.uint32)
    h = k * jnp.uint32(_HASH_MULT)
    h = h ^ (h >> jnp.uint32(16))
    return (h % jnp.uint32(n_shards)).astype(jnp.int32)


def shard_of_keys_host(keys, n_shards: int) -> np.ndarray:
    """NumPy twin of ``shard_of_keys`` for oracles and drivers."""
    k = np.asarray(keys).astype(np.uint32)
    h = k * np.uint32(_HASH_MULT)
    h = h ^ (h >> np.uint32(16))
    return (h % np.uint32(n_shards)).astype(np.int32)


def route_keys_host(keys, n_shards: int, table=None) -> np.ndarray:
    """Host routing: bucket hash + optional table lookup (oracle twin of the
    device path in ``route_batch``)."""
    if table is None:
        return shard_of_keys_host(keys, n_shards)
    table = np.asarray(table)
    return table[shard_of_keys_host(keys, len(table))].astype(np.int32)


def zipf_keys(rng, n: int, universe: int, skew: float) -> np.ndarray:
    """Zipfian key draw over a finite universe (skew=0 -> uniform) — the
    serving-style workload used by the traffic driver and benchmarks."""
    ranks = np.arange(1, universe + 1, dtype=np.float64)
    p = ranks ** (-skew) if skew > 0 else np.ones(universe)
    p /= p.sum()
    return rng.choice(universe, size=n, p=p)


def weighted_cycle(weights) -> List[int]:
    """The deterministic weighted-round-robin cycle over priority classes.

    Class ``c`` (higher = more urgent) appears ``weights[c]`` times; classes
    are laid out highest-first with each class's slots CONTIGUOUS, so within
    one cycle the urgent classes drain their whole credit burst before the
    next class starts, and the lowest class's credits sit at the cycle's
    tail.  The contiguity is what makes the starvation bound of
    :func:`weighted_dequeue_plan` tight: between two credits of class ``c``
    there are exactly ``sum(weights) - weights[c]`` foreign credits.
    """
    ws = [int(w) for w in weights]
    if not ws or any(w < 1 for w in ws):
        raise ValueError(f"class weights must all be >= 1, got {list(weights)}")
    cyc: List[int] = []
    for c in range(len(ws) - 1, -1, -1):
        cyc.extend([c] * ws[c])
    return cyc


def weighted_dequeue_plan(
    backlogs, weights, n: int, cursor: int = 0
) -> Tuple[List[int], int]:
    """Plan ``n`` dequeues across per-class shards by weighted round-robin.

    ``backlogs[c]`` is class ``c``'s committed shard backlog, ``weights[c]``
    its per-cycle dequeue credit, ``cursor`` the persistent position in the
    weighted cycle (thread it through successive calls).  Returns
    ``(plan, new_cursor)`` where ``plan`` lists the class shard to dequeue
    for each of up to ``n`` slots.  The walk is WORK-CONSERVING: a credit
    landing on an empty class is skipped (the slot goes to the next
    backlogged class in cycle order), so the plan emits
    ``min(n, sum(backlogs))`` dequeues.

    Starvation bound (the serving tier's acceptance gate): a class that
    stays backlogged is visited at least ``weights[c]`` times per full
    cycle, and every OTHER emitted dequeue consumes one of the cycle's
    ``W - weights[c]`` foreign credits (``W = sum(weights)``; skipped
    credits emit nothing) — so between two consecutive dequeues of a
    backlogged class ``c`` at most ``W - weights[c]`` other dequeues are
    emitted, across plan-call boundaries, for ANY backlog mix.  For the
    lowest class that is the bound ``W - weights[0]``.
    """
    left = [int(b) for b in backlogs]
    cyc = weighted_cycle(weights)
    if len(left) != len(set(cyc)):
        raise ValueError(
            f"backlogs ({len(left)} classes) must parallel weights "
            f"({len(set(cyc))} classes)"
        )
    W = len(cyc)
    cursor = int(cursor) % W
    plan: List[int] = []
    while len(plan) < n and any(v > 0 for v in left):
        c = cyc[cursor]
        cursor = (cursor + 1) % W
        if left[c] > 0:
            plan.append(c)
            left[c] -= 1
    return plan, cursor


@functools.partial(jax.jit, static_argnames=("n_shards", "lanes"))
def route_batch(keys, ops, params, *, n_shards: int, lanes: int, table=None):
    """Bucket a flat announced batch into per-shard op lists.

    Returns ``(shard_ops i32[S, L], shard_params f32[S, L], shard i32[B],
    lane i32[B], ok bool[B], overflow bool[B], shard_keys i32[S, L])``.
    ``shard_keys`` mirrors ``shard_ops``: each routed op's announced key in
    its landed lane (keyed kinds — the map — interpret it; ring kinds ignore
    it).  ``table`` (``i32[n_buckets]``, bucket -> shard) routes through the
    resharding-aware table; ``None`` is
    the identity table (bucket == shard, the PR-2 behavior).  Lane assignment
    is the op's batch-order rank among ops routed to its shard (stable: an
    exclusive segment prefix sum over the shard one-hot matrix), so per-shard
    op lists preserve announcement order deterministically.  Ops ranked past
    ``lanes`` overflow: they are dropped before touching any per-shard list.
    OP_NONE lanes are never routed.
    """
    b = ops.shape[0]
    if table is None:
        shard = shard_of_keys(keys, n_shards)
    else:
        shard = table[shard_of_keys(keys, table.shape[0])]
    active = ops != OP_NONE
    s_eff = jnp.where(active, shard, n_shards)  # n_shards == routed nowhere

    # stable rank of op j within its shard: exclusive prefix sum per segment
    onehot = (s_eff[None, :] == jnp.arange(n_shards)[:, None]).astype(jnp.int32)
    rank_mat = jnp.cumsum(onehot, axis=1) - 1  # [S, B]
    lane = rank_mat[jnp.clip(s_eff, 0, n_shards - 1), jnp.arange(b)]

    ok = active & (lane < lanes)
    overflow = active & (lane >= lanes)

    # scatter into the per-shard announcement matrices; dest is injective
    # over ok lanes, so the scatter is order-independent (deterministic)
    dest = jnp.where(ok, s_eff * lanes + lane, n_shards * lanes)
    flat_ops = (
        jnp.full((n_shards * lanes,), OP_NONE, jnp.int32)
        .at[dest]
        .set(ops.astype(jnp.int32), mode="drop")
    )
    flat_params = (
        jnp.zeros((n_shards * lanes,), jnp.float32)
        .at[dest]
        .set(params.astype(jnp.float32), mode="drop")
    )
    flat_keys = (
        jnp.zeros((n_shards * lanes,), jnp.int32)
        .at[dest]
        .set(jnp.asarray(keys).astype(jnp.int32), mode="drop")
    )
    return (
        flat_ops.reshape(n_shards, lanes),
        flat_params.reshape(n_shards, lanes),
        shard,
        lane,
        ok,
        overflow,
        flat_keys.reshape(n_shards, lanes),
    )


# ============================================================ fused step (jit)
@functools.partial(
    jax.jit, static_argnames=("kind", "n_shards", "lanes", "backend")
)
def sharded_step(
    state, keys, ops, params, meta, *, kind: str, n_shards: int, lanes: int,
    backend: str = "jnp",
):
    """One fused end-to-end phase over a HOMOGENEOUS fabric (PR-2 entry
    point, kept for direct users; ``ShardedDFCRuntime`` itself now always
    goes through ``hetero_step``).

    ``meta`` is the per-shard combiner metadata ``{"phases": i32[S],
    "ops_combined": i32[S]}``; untouched shards keep their old state (and old
    epoch — no phantom phases), touched shards publish with a +2 epoch bump.
    Returns ``(new_state, new_meta, responses f32[B], kinds i32[B])`` where
    ``kinds`` uses the combine-level codes plus ``R_OVERFLOW``.
    """
    shard_ops, shard_params, shard, lane, ok, overflow, shard_keys = route_batch(
        keys, ops, params, n_shards=n_shards, lanes=lanes
    )

    combined, s_resp, s_kinds = _one_sharded_combine(
        kind, backend, state, shard_ops, shard_params, keys=shard_keys
    )

    # only shards that received ops publish; the rest keep state AND epoch
    touched = jnp.any(shard_ops != OP_NONE, axis=1)  # bool[S]

    def _select(new_leaf, old_leaf):
        t = touched.reshape((n_shards,) + (1,) * (new_leaf.ndim - 1))
        return jnp.where(t, new_leaf, old_leaf)

    new_state = jax.tree_util.tree_map(_select, combined, state)
    new_meta = dict(meta)  # carry extra columns (e.g. "kind") through
    new_meta["phases"] = meta["phases"] + touched.astype(jnp.int32)
    new_meta["ops_combined"] = meta["ops_combined"] + jnp.sum(
        (shard_ops != OP_NONE).astype(jnp.int32), axis=1
    )

    # gather responses back to flat batch order
    s = jnp.clip(shard, 0, n_shards - 1)
    ln = jnp.clip(lane, 0, lanes - 1)
    responses = jnp.where(ok, s_resp[s, ln], 0.0)
    kinds = jnp.where(ok, s_kinds[s, ln], R_NONE)
    kinds = jnp.where(overflow, R_OVERFLOW, kinds)
    return new_state, new_meta, responses, kinds


@functools.lru_cache(maxsize=None)
def _group_ids(kinds: Tuple[str, ...]) -> Dict[str, Tuple[int, ...]]:
    """Global shard ids per kind, in ascending shard order."""
    out: Dict[str, List[int]] = {}
    for s, k in enumerate(kinds):
        out.setdefault(k, []).append(s)
    return {k: tuple(v) for k, v in out.items()}


@functools.partial(jax.jit, static_argnames=("kinds", "lanes", "backend"))
def hetero_step(
    groups, table, keys, ops, params, meta, *, kinds: Tuple[str, ...],
    lanes: int, backend: str = "jnp",
):
    """One fused end-to-end phase over a HETEROGENEOUS fabric.

    ``groups`` maps each structure kind to its shard-stacked state;
    ``kinds`` (static) is the per-shard kind tuple and ``table`` the
    bucket->shard routing table.  The combine is STRUCTS-dispatched per kind
    group (``dfc_hetero_combine_step``): one vmap or one Pallas grid per kind
    present, program instances grouped by kind.  Op codes are interpreted by
    the TARGET shard's structure (a code-3 op is OP_PUSHR on a deque shard
    and falls through to R_NONE on a stack/queue shard).

    Returns ``(new_groups, new_meta, responses f32[B], out_kinds i32[B])``.
    """
    n_shards = len(kinds)
    shard_ops, shard_params, shard, lane, ok, overflow, shard_keys = route_batch(
        keys, ops, params, n_shards=n_shards, lanes=lanes, table=table
    )

    gids = _group_ids(kinds)
    group_ops = {k: shard_ops[jnp.asarray(ids)] for k, ids in gids.items()}
    group_params = {k: shard_params[jnp.asarray(ids)] for k, ids in gids.items()}
    group_keys = {k: shard_keys[jnp.asarray(ids)] for k, ids in gids.items()}
    combined = dfc_hetero_combine_step(
        groups, group_ops, group_params, backend=backend, group_keys=group_keys
    )

    resp_mat = jnp.zeros((n_shards, lanes), jnp.float32)
    kind_mat = jnp.full((n_shards, lanes), R_NONE, jnp.int32)
    new_groups = {}
    for k in sorted(gids):
        ids = gids[k]
        rows = jnp.asarray(ids)
        new_state, s_resp, s_kinds = combined[k]
        g_touched = jnp.any(group_ops[k] != OP_NONE, axis=1)

        def _select(new_leaf, old_leaf, t=g_touched, m=len(ids)):
            tt = t.reshape((m,) + (1,) * (new_leaf.ndim - 1))
            return jnp.where(tt, new_leaf, old_leaf)

        new_groups[k] = jax.tree_util.tree_map(_select, new_state, groups[k])
        resp_mat = resp_mat.at[rows].set(s_resp)
        kind_mat = kind_mat.at[rows].set(s_kinds)

    touched = jnp.any(shard_ops != OP_NONE, axis=1)
    new_meta = dict(meta)
    new_meta["phases"] = meta["phases"] + touched.astype(jnp.int32)
    new_meta["ops_combined"] = meta["ops_combined"] + jnp.sum(
        (shard_ops != OP_NONE).astype(jnp.int32), axis=1
    )

    s = jnp.clip(shard, 0, n_shards - 1)
    ln = jnp.clip(lane, 0, lanes - 1)
    responses = jnp.where(ok, resp_mat[s, ln], 0.0)
    out_kinds = jnp.where(ok, kind_mat[s, ln], R_NONE)
    out_kinds = jnp.where(overflow, R_OVERFLOW, out_kinds)
    return new_groups, new_meta, responses, out_kinds


@functools.partial(
    jax.jit, static_argnames=("kinds", "lanes", "backend", "unroll")
)
def hetero_multi_step(
    groups, table, keys, ops, params, meta, *, kinds: Tuple[str, ...],
    lanes: int, backend: str = "jnp", unroll: int = 1,
):
    """Route + combine a CHAIN of flat batches over a heterogeneous fabric in
    ONE dispatch (the pipelined durable path's combine stage).

    ``keys`` / ``ops`` / ``params`` are ``[B, L]`` — B flat batches padded to
    a common length with ``OP_NONE`` lanes (never routed).  Each batch is
    routed independently and the B per-shard announcement matrices are
    chained through ``dfc_sharded_multi_combine_step`` per kind group: batch
    b+1 combines on top of batch b's post-combine state, exactly as B
    separate ``hetero_step`` calls would, but the chain costs one dispatch.
    All-``OP_NONE`` batches (chain padding) pass through untouched, and
    ``unroll`` (static; the caller passes its pipeline depth) unrolls the
    underlying scan that many batches per step.

    Returns ``(new_groups, new_meta, responses [B, L], out_kinds [B, L],
    states, epochs_before i32[S], epochs i32[B, S], phases_cum i32[B, S],
    ops_cum i32[B, S])`` where ``states[kind]`` carries the per-batch
    shard-stacked states (leading B axis — what the durable path persists
    per batch) and ``epochs[b]`` the per-shard epochs after batch b (each
    op's durable commit target).
    """
    n_batches = ops.shape[0]
    n_shards = len(kinds)
    routed = [
        route_batch(
            keys[i], ops[i], params[i],
            n_shards=n_shards, lanes=lanes, table=table,
        )
        for i in range(n_batches)
    ]
    shard_ops = jnp.stack([r[0] for r in routed])  # [B, S, L]
    shard_params = jnp.stack([r[1] for r in routed])
    shard_keys = jnp.stack([r[6] for r in routed])

    gids = _group_ids(kinds)
    group_ops = {k: shard_ops[:, jnp.asarray(ids)] for k, ids in gids.items()}
    group_params = {
        k: shard_params[:, jnp.asarray(ids)] for k, ids in gids.items()
    }
    group_keys = {
        k: shard_keys[:, jnp.asarray(ids)] for k, ids in gids.items()
    }
    multi = dfc_hetero_multi_combine_step(
        groups, group_ops, group_params, backend=backend, unroll=unroll,
        group_keys=group_keys,
    )

    resp_mat = jnp.zeros((n_batches, n_shards, lanes), jnp.float32)
    kind_mat = jnp.full((n_batches, n_shards, lanes), R_NONE, jnp.int32)
    epochs = jnp.zeros((n_batches, n_shards), jnp.int32)
    epochs_before = jnp.zeros((n_shards,), jnp.int32)
    new_groups, states = {}, {}
    for k in sorted(gids):
        rows = jnp.asarray(gids[k])
        st, s_resp, s_kinds = multi[k]
        states[k] = st
        new_groups[k] = jax.tree_util.tree_map(lambda leaf: leaf[-1], st)
        resp_mat = resp_mat.at[:, rows].set(s_resp)
        kind_mat = kind_mat.at[:, rows].set(s_kinds)
        epochs = epochs.at[:, rows].set(st.epoch)
        epochs_before = epochs_before.at[rows].set(groups[k].epoch)

    touched = jnp.any(shard_ops != OP_NONE, axis=2)  # [B, S]
    per_batch_ops = jnp.sum((shard_ops != OP_NONE).astype(jnp.int32), axis=2)
    new_meta = dict(meta)
    new_meta["phases"] = meta["phases"] + jnp.sum(touched.astype(jnp.int32), axis=0)
    new_meta["ops_combined"] = meta["ops_combined"] + jnp.sum(per_batch_ops, axis=0)
    # cumulative per-batch counters: what batch b's slot persist must record
    phases_cum = meta["phases"][None] + jnp.cumsum(touched.astype(jnp.int32), axis=0)
    ops_cum = meta["ops_combined"][None] + jnp.cumsum(per_batch_ops, axis=0)

    shard_b = jnp.stack([r[2] for r in routed])  # [B, L]
    lane_b = jnp.stack([r[3] for r in routed])
    ok_b = jnp.stack([r[4] for r in routed])
    ovf_b = jnp.stack([r[5] for r in routed])
    s = jnp.clip(shard_b, 0, n_shards - 1)
    ln = jnp.clip(lane_b, 0, lanes - 1)
    bi = jnp.arange(n_batches)[:, None]
    responses = jnp.where(ok_b, resp_mat[bi, s, ln], 0.0)
    out_kinds = jnp.where(ok_b, kind_mat[bi, s, ln], R_NONE)
    out_kinds = jnp.where(ovf_b, R_OVERFLOW, out_kinds)
    return (
        new_groups, new_meta, responses, out_kinds,
        states, epochs_before, epochs, phases_cum, ops_cum,
    )


def _hetero_phase_loop_impl(
    groups, table, keys, ops, params, meta, *, kinds: Tuple[str, ...],
    lanes: int, backend: str = "jnp", unroll: int = 1,
    phase_axis: str = "scan",
):
    """Trace body of :func:`hetero_phase_loop_step` (jitted twice below —
    once with the kind-group buffers donated, once without)."""
    n_shards = len(kinds)

    def _route(k1, o1, p1):
        return route_batch(
            k1, o1, p1, n_shards=n_shards, lanes=lanes, table=table
        )

    # route ALL K phases in one vmapped pass (no per-phase dispatch)
    (
        shard_ops, shard_params, shard_b, lane_b, ok_b, ovf_b, shard_keys
    ) = jax.vmap(_route)(
        keys, ops, params
    )  # [K, S, L], [K, S, L], [K, B], [K, B], ...

    gids = _group_ids(kinds)
    group_ops = {k: shard_ops[:, jnp.asarray(ids)] for k, ids in gids.items()}
    group_params = {
        k: shard_params[:, jnp.asarray(ids)] for k, ids in gids.items()
    }
    group_keys = {
        k: shard_keys[:, jnp.asarray(ids)] for k, ids in gids.items()
    }
    multi = dfc_hetero_multi_phase_step(
        groups, group_ops, group_params,
        backend=backend, unroll=unroll, phase_axis=phase_axis,
        group_keys=group_keys,
    )

    k_phases = ops.shape[0]
    resp_mat = jnp.zeros((k_phases, n_shards, lanes), jnp.float32)
    kind_mat = jnp.full((k_phases, n_shards, lanes), R_NONE, jnp.int32)
    epochs = jnp.zeros((k_phases, n_shards), jnp.int32)
    epochs_before = jnp.zeros((n_shards,), jnp.int32)
    touched_all = jnp.zeros((k_phases, n_shards), bool)
    phases_cum = jnp.zeros((k_phases, n_shards), jnp.int32)
    ops_cum = jnp.zeros((k_phases, n_shards), jnp.int32)
    new_groups, states = {}, {}
    for k in sorted(gids):
        rows = jnp.asarray(gids[k])
        st, s_resp, s_kinds, intents = multi[k]
        states[k] = st
        new_groups[k] = jax.tree_util.tree_map(lambda leaf: leaf[-1], st)
        resp_mat = resp_mat.at[:, rows].set(s_resp)
        kind_mat = kind_mat.at[:, rows].set(s_kinds)
        epochs = epochs.at[:, rows].set(intents.epoch)
        epochs_before = epochs_before.at[rows].set(groups[k].epoch)
        touched_all = touched_all.at[:, rows].set(intents.touched)
        # re-base the dispatch-relative cumulative counters on the fabric's
        # durable meta: row k is then exactly what phase k's slot persists
        phases_cum = phases_cum.at[:, rows].set(
            meta["phases"][rows][None] + intents.phases_cum
        )
        ops_cum = ops_cum.at[:, rows].set(
            meta["ops_combined"][rows][None] + intents.ops_cum
        )

    new_meta = dict(meta)
    new_meta["phases"] = phases_cum[-1]
    new_meta["ops_combined"] = ops_cum[-1]

    s = jnp.clip(shard_b, 0, n_shards - 1)
    ln = jnp.clip(lane_b, 0, lanes - 1)
    ki = jnp.arange(k_phases)[:, None]
    responses = jnp.where(ok_b, resp_mat[ki, s, ln], 0.0)
    out_kinds = jnp.where(ok_b, kind_mat[ki, s, ln], R_NONE)
    out_kinds = jnp.where(ovf_b, R_OVERFLOW, out_kinds)
    intents_out = PhaseIntents(
        epoch=epochs, touched=touched_all,
        phases_cum=phases_cum, ops_cum=ops_cum,
    )
    return (
        new_groups, new_meta, responses, out_kinds,
        states, epochs_before, intents_out,
    )


_PHASE_LOOP_STATICS = ("kinds", "lanes", "backend", "unroll", "phase_axis")
_phase_loop_step_plain = jax.jit(
    _hetero_phase_loop_impl, static_argnames=_PHASE_LOOP_STATICS
)
# donated variant: the old kind-group buffers are consumed by the dispatch,
# so stacked shard state never leaves the device between phases
_phase_loop_step_donated = jax.jit(
    _hetero_phase_loop_impl,
    static_argnames=_PHASE_LOOP_STATICS,
    donate_argnums=(0,),
)


def hetero_phase_loop_step(
    groups, table, keys, ops, params, meta, *, kinds: Tuple[str, ...],
    lanes: int, backend: str = "jnp", unroll: int = 1,
    phase_axis: str = "scan", donate: Optional[bool] = None,
):
    """Route + combine K PHASES over a heterogeneous fabric in ONE dispatch,
    accumulating each phase's persist intents device-side.

    ``keys`` / ``ops`` / ``params`` are ``[K, L]`` — K per-phase flat batches
    padded to a common lane count with ``OP_NONE``.  Each phase is routed
    independently (one vmapped routing pass) and the chain is fused through
    ``dfc_hetero_multi_phase_step`` per kind group: phase k+1 combines on
    top of phase k's post-combine state, exactly as K separate
    ``hetero_step`` calls would, but the whole schedule costs one dispatch
    and the stacked shard state never leaves the device between phases
    (``donate=True`` — the default off-CPU — additionally donates the old
    group buffers to the dispatch).  ``phase_axis`` picks ``lax.scan``
    (every backend) or the Pallas grid over the phase axis (Pallas
    backends); see ``dfc_multi_phase_step``.

    Returns ``(new_groups, new_meta, responses [K, L], out_kinds [K, L],
    states, epochs_before i32[S], intents)`` where ``states[kind]`` carries
    the per-phase shard-stacked states (leading K axis) and ``intents`` is
    the :class:`~repro.core.jax_dfc.PhaseIntents` log with the cumulative
    counters already re-based on the fabric's durable ``meta`` — everything
    the host's intent drain needs to replay the serial persistence schedule.
    """
    if donate is None:
        donate = jax.default_backend() != "cpu"
    fn = _phase_loop_step_donated if donate else _phase_loop_step_plain
    return fn(
        groups, table, keys, ops, params, meta,
        kinds=kinds, lanes=lanes, backend=backend,
        unroll=unroll, phase_axis=phase_axis,
    )


# ============================================================== host oracle
def sequential_hetero_reference(
    kinds, shard_lists, keys, ops, params, lanes, table=None, capacity=None
):
    """Pure-Python witness of one heterogeneous sharded phase (test oracle).

    ``kinds[s]`` names shard ``s``'s structure; ``shard_lists[s]`` is its
    Python contents, mutated in place (a dict for keyed kinds).  Returns
    (responses, kinds) in flat batch order, with overflow ops reported as
    ``R_OVERFLOW`` and untouched.  ``capacity`` bounds keyed shards so the
    oracle models bucket-full rejection the same way the device does.
    """
    n_shards = len(shard_lists)
    shard = route_keys_host(keys, n_shards, table)
    b = len(ops)
    responses = [0.0] * b
    out_kinds = [R_NONE] * b
    buckets: Dict[int, List[int]] = {}
    for j in range(b):
        if ops[j] == OP_NONE:
            continue
        s = int(shard[j])
        rank = len(buckets.setdefault(s, []))
        if rank >= lanes:
            out_kinds[j] = R_OVERFLOW
            continue
        buckets[s].append(j)
    for s, idxs in sorted(buckets.items()):
        s_ops = [ops[j] for j in idxs]
        s_par = [params[j] for j in idxs]
        spec = STRUCTS[kinds[s]]
        if spec.keyed:
            s_keys = [keys[j] for j in idxs]
            shard_lists[s], s_resp, s_kinds = spec.reference(
                shard_lists[s], s_keys, s_ops, s_par, capacity=capacity
            )
        else:
            shard_lists[s], s_resp, s_kinds = spec.reference(
                shard_lists[s], s_ops, s_par
            )
        for r, (v, k) in zip(idxs, zip(s_resp, s_kinds)):
            responses[r] = v
            out_kinds[r] = k
    return responses, out_kinds


def sequential_sharded_reference(kind, shard_lists, keys, ops, params, lanes):
    """Homogeneous wrapper of ``sequential_hetero_reference`` (PR-2 API)."""
    return sequential_hetero_reference(
        (kind,) * len(shard_lists), shard_lists, keys, ops, params, lanes
    )


# ================================================================== runtime
def _init_meta(kinds: Sequence[str]):
    n_shards = len(kinds)
    return {
        "phases": jnp.zeros((n_shards,), jnp.int32),
        "ops_combined": jnp.zeros((n_shards,), jnp.int32),
        "kind": jnp.asarray([KIND_CODES[k] for k in kinds], jnp.int32),
    }


@dataclasses.dataclass
class OpVerdict:
    """Per-op detectability verdict reported by recovery."""

    applied: bool
    kind: Optional[int] = None
    resp: Optional[float] = None
    shard: Optional[int] = None


class ShardedDFCRuntime:
    """Many persistent DFC objects — possibly of MIXED kinds — behind one
    announcement fabric, with crash-consistent dynamic resharding.

    Volatile fast path: ``step(keys, ops, params)`` — one jitted dispatch.
    Durable path: threads ``announce`` batches; ``combine_phase`` combines
    every ready announcement across all shards and commits per-shard;
    ``recover`` rebuilds the fabric (topology included) after a crash and
    reports per-thread, per-op detectability verdicts; ``replay_pending``
    re-announces exactly the not-applied ops.  Resharding:
    ``split_shard`` / ``merge_shards`` (see the module docstring for the
    commit protocol).

    ``kind`` may be a single kind name (homogeneous fabric, PR-2 behavior —
    ``rt.state`` is then the one stacked pytree) or a per-shard sequence of
    kind names (``rt.state`` is the ``{kind: stacked_state}`` group dict).

    Contract (inherited from the combine layer): per shard,
    ``capacity >= committed size + lanes``.
    """

    def __init__(
        self,
        kind: Union[str, Sequence[str]],
        n_shards: int,
        capacity: int,
        lanes: int,
        *,
        backend: str = "jnp",
        fs: Optional[SimFS] = None,
        n_threads: int = 1,
        state=None,
        meta=None,
        n_buckets: Optional[int] = None,
        table=None,
        pipeline: bool = False,
        depth: Optional[int] = None,
        chain: int = 1,
        ring_slots: int = 2048,
        split_lanes: bool = False,
        obs=None,
    ):
        kinds = [kind] * n_shards if isinstance(kind, str) else list(kind)
        if len(kinds) != n_shards:
            raise ValueError("per-shard kind list must have n_shards entries")
        for k in kinds:
            if k not in STRUCTS:
                raise ValueError(f"unknown structure kind {k!r}")
        if lanes > capacity:
            raise ValueError("lanes must be <= per-shard capacity")
        self.kinds = kinds
        self.kind = kinds[0] if len(set(kinds)) == 1 else "mixed"
        self.n_shards = n_shards
        self.capacity = capacity
        self.lanes = lanes
        self.backend = backend
        self.fs = fs
        self.n_threads = n_threads
        self.n_buckets = int(n_buckets) if n_buckets is not None else n_shards
        if self.n_buckets < n_shards:
            raise ValueError("n_buckets must be >= n_shards")
        self.table = np.asarray(
            np.arange(self.n_buckets) % n_shards if table is None else table,
            np.int32,
        )
        if self.table.shape != (self.n_buckets,):
            raise ValueError("table must have n_buckets entries")
        self.r_epoch = 0  # routing epoch (even at rest)
        self._reshard_seq = 0
        # per-side combiners (ISSUE 8): when enabled, queue/deque shards
        # commit through independent head/tail lanes.  ``lane_epochs`` is the
        # host mirror of each split shard's committed ``[eH, eT]`` pair (even
        # at rest), advanced strictly in commit order by the retire/drain
        # paths; the device epoch stays free-running (+2 per touched phase)
        # and recovery rebuilds it as eH + eT.
        self.split_lanes = bool(split_lanes)
        self.lane_epochs: Dict[int, List[int]] = {}
        # --- pipelined durable path (ISSUE 4/5): device-side announcement
        # ring, a depth-D ring of in-flight chains, dirty-leaf persist elision.
        # ``depth`` is the pipeline depth: a combine_phase dispatches a fresh
        # chain and keeps up to depth-1 dispatched chains UN-retired (their
        # persists/commits deferred), so the device may be combining chain
        # k+D-1 while chain k's durable writes drain.  depth=1 is the serial
        # path; the legacy ``pipeline=True`` flag is depth=2 (the ISSUE-4
        # two-stage special case, now just a depth setting).
        if depth is None:
            depth = 2 if pipeline else 1
        if depth < 1:
            raise ValueError("depth must be >= 1")
        self.depth = int(depth)
        self.pipeline = self.depth > 1
        self.chain = max(1, int(chain))
        self.ring = init_announce_ring(ring_slots) if fs is not None else None
        self._ring_tail = 0  # host mirror of the ring's absolute tail
        self._ring_spans: Dict[int, Tuple[int, int]] = {}  # thread -> (start, n)
        self._live: Dict[int, Dict[str, Any]] = {}  # thread -> announcement rec
        # host mirror of each announcement slot's token — what the depth
        # guard in ``announce`` consults, so the hot path never re-reads the
        # durable record it is about to overwrite
        self._slot_tokens: Dict[Tuple[int, int], int] = {}
        # dispatched-but-unretired chains, oldest first (retire = commit
        # order); a deque so the three oldest-first drains (announce's depth
        # guard, combine_phase stage 2, flush) pop in O(1) instead of the
        # O(D) head-pop of a list — flush was O(D^2) per call and runs
        # inside _drain() before every reshard
        self._inflight: "collections.deque[Dict[str, Any]]" = collections.deque()
        # (thread, token) groups of the most recent dispatch, one tuple per
        # chained batch — the linearization witness drivers/oracles replay
        # (announcements grouped into one batch combine as ONE phase)
        self.last_dispatch: List[Tuple[Tuple[int, int], ...]] = []
        self._elide: Dict[str, bytes] = {}  # rel path -> durable leaf digest
        self._elide_pending: Dict[str, bytes] = {}
        if state is None:
            self.groups = {
                k: init_sharded(k, len(ids), capacity)
                for k, ids in _group_ids(tuple(kinds)).items()
            }
        else:
            self.state = state
        self.meta = _init_meta(kinds) if meta is None else meta
        # Observability (repro.obs): disabled no-op observer by default.  A
        # live observer is shared with the SimFS so persistence hooks, span
        # events, and metrics land in ONE timeline; the hooks run after the
        # counters/injector/durable work, so tracing cannot perturb the
        # protocol (the obs parity test pins this).
        self.obs = obs if obs is not None else NULL_OBS
        if fs is not None and self.obs.enabled:
            fs.obs = self.obs
            self.obs.event(
                "topology",
                kinds=list(kinds),
                n_shards=n_shards,
                n_buckets=self.n_buckets,
                capacity=capacity,
                lanes=lanes,
                depth=self.depth,
                chain=self.chain,
                split_lanes=self.split_lanes,
            )

    # ----------------------------------------------------- state as groups
    @property
    def state(self):
        """Single stacked pytree for homogeneous fabrics (PR-2 API), the
        ``{kind: stacked_state}`` group dict otherwise."""
        if len(self.groups) == 1:
            return next(iter(self.groups.values()))
        return self.groups

    @state.setter
    def state(self, value):
        if isinstance(value, dict):
            self.groups = dict(value)
        else:
            self.groups = {self.kinds[0]: value}

    def _row(self, s: int) -> int:
        """Local row of global shard ``s`` inside its kind group."""
        return _group_ids(tuple(self.kinds))[self.kinds[s]].index(s)

    def _shard_state(self, s: int):
        return shard_slice(self.groups[self.kinds[s]], self._row(s))

    def _set_shard_state(self, s: int, one) -> None:
        k, r = self.kinds[s], self._row(s)
        self.groups[k] = jax.tree_util.tree_map(
            lambda leaf, v: leaf.at[r].set(v), self.groups[k], one
        )

    def shard_epochs(self) -> np.ndarray:
        """Per-global-shard epochs gathered from the kind groups."""
        out = np.zeros((self.n_shards,), np.int64)
        for k, ids in _group_ids(tuple(self.kinds)).items():
            out[np.asarray(ids)] = np.asarray(self.groups[k].epoch)
        return out

    # ------------------------------------------------------------- routing
    def route(self, keys, ops, params):
        return route_batch(
            jnp.asarray(keys),
            jnp.asarray(ops, jnp.int32),
            jnp.asarray(params, jnp.float32),
            n_shards=self.n_shards,
            lanes=self.lanes,
            table=jnp.asarray(self.table),
        )

    def route_host(self, keys) -> np.ndarray:
        return route_keys_host(keys, self.n_shards, self.table)

    def key_for_shard(self, s: int, start: int = 0) -> int:
        """Smallest key >= ``start`` that routes to shard ``s`` under the
        current table (host-side search; drivers use it to address a specific
        shard, e.g. to drain one request queue)."""
        for base in range(start, start + (1 << 22), 4096):
            cand = np.arange(base, base + 4096, dtype=np.int64)
            hit = np.nonzero(self.route_host(cand) == s)[0]
            if hit.size:
                return int(cand[hit[0]])
        raise ValueError(f"no key routes to shard {s} (unrouted shard?)")

    # ------------------------------------------------------- volatile path
    def step(self, keys, ops, params):
        """One fused phase over a flat batch; returns (responses, kinds)."""
        self.groups, self.meta, resp, kinds = hetero_step(
            self.groups,
            jnp.asarray(self.table),
            jnp.asarray(keys),
            jnp.asarray(ops, jnp.int32),
            jnp.asarray(params, jnp.float32),
            self.meta,
            kinds=tuple(self.kinds),
            lanes=self.lanes,
            backend=self.backend,
        )
        return resp, kinds

    # -------------------------------------------------------- announcements
    def _ann_path(self, t: int, slot: int) -> str:
        return f"tAnn/thread_{t}/ann{slot}.json"

    def _valid_path(self, t: int) -> str:
        return f"tAnn/thread_{t}/valid"

    def _read_valid(self, t: int) -> int:
        raw = self.fs.read(self._valid_path(t))
        return int(raw.decode()) if raw else 0

    def _read_ann(self, t: int, slot: int) -> Dict[str, Any]:
        raw = self.fs.read(self._ann_path(t, slot))
        return json.loads(raw.decode()) if raw else {"val": BOT, "token": -1}

    def announce(self, thread: int, keys, ops, params, token: int) -> None:
        """Thread-side announcement (paper lines 2-12): double-buffered
        record + valid selector, parallel pwb/pfence, MSB publish.

        The payload additionally lands in the device-side announcement ring
        (``AnnounceRing``), so combining phases consume device arrays
        directly; SimFS keeps only the compact durable mirror below, which is
        what recovery and replay read back.

        Contract: per-thread ``token``s must be monotonically increasing —
        recovery uses token order to tell an in-flight PREDECESSOR in the
        older announcement slot (pipelined path) from an unpublished
        successor whose announce crashed before the valid flip.

        Depth guard: the double-buffered records bound a thread to TWO
        outstanding batches.  At depth > 2 the slot this announcement reuses
        may still belong to a dispatched-but-unretired chain; retiring chains
        in commit order until that batch's responses are durable keeps the
        protocol identical to the serial schedule (same pwbs/pfences, merely
        re-timed), so deep pipelines never clobber an un-persisted response.
        """
        valid = self._read_valid(thread)
        n_op = 1 - (valid & 1)
        if self._inflight:
            old_tok = self._slot_tokens.get((thread, n_op), -1)
            while old_tok >= 0 and self._chain_holding(thread, old_tok) is not None:
                self._retire(self._inflight.popleft())
        n_op, ann = self._announce_durable(thread, token, keys, ops, params)
        self._register_live(thread, n_op, token, ann["keys"], ann["ops"], ann["params"])

    def _announce_durable(
        self, thread: int, token: int, keys, ops, params
    ) -> Tuple[int, Dict[str, Any]]:
        """The announce protocol's durable writes alone (paper lines 2-12):
        record into the inactive slot, pfence, valid flip, pfence, MSB
        publish — 3 pwb + 2 pfence, shared verbatim by ``announce`` and the
        fused phase loop's intent drain so the two paths cannot drift.
        Returns ``(slot, record)``."""
        valid = self._read_valid(thread)
        n_op = 1 - (valid & 1)
        ann = {
            "token": token,
            "keys": [int(k) for k in np.asarray(keys)],
            "ops": [int(o) for o in np.asarray(ops)],
            "params": [float(p) for p in np.asarray(params)],
            "val": BOT,
        }
        self.fs.write(
            self._ann_path(thread, n_op), json.dumps(ann).encode(), tag="announce"
        )
        self.fs.fsync([self._ann_path(thread, n_op)], tag="announce")
        self.fs.write(self._valid_path(thread), str(n_op).encode(), tag="announce")
        self.fs.fsync([self._valid_path(thread)], tag="announce")
        self.fs.write(
            self._valid_path(thread), str(2 | n_op).encode(), tag="announce"
        )  # MSB
        if self.obs.enabled:
            self.obs.event(
                EV_ANNOUNCE,
                thread=thread,
                token=token,
                slot=n_op,
                n=len(ann["ops"]),
            )
        return n_op, ann

    def _register_live(
        self, thread: int, slot: int, token: int, keys, ops, params
    ) -> Dict[str, Any]:
        """Track a live (announced, not yet combined) batch: host metadata
        for routing/retire plus a device-ring span for the combine payload.
        When the ring has no room for the span the payload stays host-side
        (``ring_start=None``) and the combine falls back to a host upload —
        the protocol is unaffected, only the fast path."""
        keys = np.asarray(keys, np.int64)
        ops = np.asarray(ops, np.int32)
        params = np.asarray(params, np.float32)
        n = int(ops.shape[0])
        start = None
        if self.ring is not None and n:
            slots = int(self.ring.keys.shape[0])
            spans = [v for t, v in self._ring_spans.items() if t != thread]
            oldest = min((s0 for s0, _ in spans), default=self._ring_tail)
            if ring_has_room(slots, self._ring_tail, oldest, n):
                # split-lane fabrics annotate each ring slot with its op's
                # announcement lane (head/tail by target-shard structure),
                # so lane-filtered drains (``ring_drain(..., lane=...)``)
                # can feed a per-side combine dispatch straight off device
                lane_col = (
                    jnp.asarray(self._op_lanes_host(ops, self.route_host(keys)))
                    if self.split_lanes
                    else None
                )
                self.ring = ring_announce(
                    self.ring,
                    jnp.asarray(keys.astype(np.int32)),
                    jnp.asarray(ops),
                    jnp.asarray(params),
                    lane_col,
                )
                start = self._ring_tail
                self._ring_tail += n
                self._ring_spans[thread] = (start, n)
            else:
                self._ring_spans.pop(thread, None)
        rec = {
            "token": int(token), "slot": int(slot), "n": n,
            "keys": keys, "ops": ops, "params": params, "ring_start": start,
        }
        self._live[thread] = rec
        self._slot_tokens[(thread, int(slot))] = int(token)
        return rec

    def ready_announcements(self) -> List[int]:
        out = []
        for t in range(self.n_threads):
            v = self._read_valid(t)
            if (v >> 1) & 1:
                ann = self._read_ann(t, v & 1)
                if ann.get("val") is BOT and ann.get("token", -1) >= 0:
                    out.append(t)
        return out

    # ------------------------------------------------------ durable layout
    def _epoch_path(self, s: int) -> str:
        return f"shard_{s}/cEpoch"

    def _slot_dir(self, s: int, epoch: int, nxt: bool) -> str:
        return f"shard_{s}/slot{(epoch // 2 + (1 if nxt else 0)) % 2}"

    def _read_shard_epoch(self, s: int) -> int:
        raw = self.fs.read(self._epoch_path(s))
        return int(raw.decode()) if raw else 0

    def _persist_shard(
        self, s: int, epoch_target: int, state=None, counters=None
    ) -> List[str]:
        """pwb shard ``s``'s post-combine (or explicitly given) state into
        its inactive slot.

        Dirty-leaf elision (the paper's dirty-word tracking, at leaf
        granularity): a leaf whose bytes are identical to what this slot
        already holds DURABLY is skipped — its file is still listed in the
        slot manifest and still readable at recovery, so crash consistency
        is unchanged, but a combining phase that only moved root counters
        (e.g. a fully-eliminating stack batch, or a queue batch served
        entirely from the committed ring window) stops re-persisting the
        whole ``values`` array.  Digests are promoted into the elision cache
        only after the phase's pfence (``_promote_elision``).
        """
        one = self._shard_state(s) if state is None else state
        slot = self._slot_dir(s, epoch_target - 2, nxt=True)
        leaves, _ = jax.tree_util.tree_flatten(one)
        files = []
        if counters is None:
            counters = (
                int(self.meta["phases"][s]),
                int(self.meta["ops_combined"][s]),
            )
        meta = {
            "kind": self.kinds[s],
            "epoch": epoch_target,
            "leaves": [],
            "phases": int(counters[0]),
            "ops_combined": int(counters[1]),
        }
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            buf = io.BytesIO()
            np.save(buf, arr)
            data = buf.getvalue()
            rel = f"{slot}/leaf_{i}.npy"
            digest = hashlib.blake2b(data, digest_size=16).digest()
            if self._elide.get(rel) != digest:
                self.fs.write(rel, data, tag="slot")
                files.append(rel)
                self._elide_pending[rel] = digest
                self.obs.metrics.counter("elision_miss", shard=s)
            else:
                self.obs.metrics.counter("elision_hit", shard=s)
            meta["leaves"].append(
                {"file": f"leaf_{i}.npy", "shape": list(arr.shape), "dtype": str(arr.dtype)}
            )
        rel = f"{slot}/meta.json"
        self.fs.write(rel, json.dumps(meta).encode(), tag="slot")
        files.append(rel)
        return files

    def _promote_elision(self) -> None:
        """Move leaf digests written since the last pfence into the elision
        cache — they are durable now, so a future identical write may skip."""
        self._elide.update(self._elide_pending)
        self._elide_pending.clear()

    # ------------------------------------------- per-side lanes (ISSUE 8)
    def _is_split(self, s: int) -> bool:
        """Whether shard ``s`` commits through independent head/tail lanes."""
        return self.split_lanes and STRUCTS[self.kinds[s]].lane_splittable

    def _lane_epoch_pair(self, s: int) -> List[int]:
        """Host mirror of split shard ``s``'s committed ``[eH, eT]``."""
        return self.lane_epochs.setdefault(s, [0, 0])

    def _op_lanes_host(self, ops, shards) -> np.ndarray:
        """Per-op announcement lane (LANE_HEAD/LANE_TAIL, LANE_NONE for ops
        on unsplit shards): an op's lane is defined by its TARGET shard's
        structure, so the same op code can be head-side on one shard and
        tail-side on another in a mixed fabric."""
        ops = np.asarray(ops, np.int32)
        shards = np.asarray(shards)
        out = np.full(ops.shape, LANE_NONE, np.int32)
        for j in range(ops.shape[0]):
            s = int(shards[j]) if j < shards.shape[0] else -1
            if ops[j] != OP_NONE and 0 <= s < self.n_shards and self._is_split(s):
                out[j] = int(lane_of_ops_host(self.kinds[s], ops[j : j + 1])[0])
        return out

    def _lane_slot_dir(self, s: int, lane: int, lane_epoch: int, nxt: bool) -> str:
        """A lane's alternating slot dir, parity from ITS OWN epoch."""
        p = (lane_epoch // 2 + (1 if nxt else 0)) % 2
        return f"shard_{s}/lane{_LANE_TAGS[lane]}{p}"

    def _read_lane_epochs(self, s: int) -> List[int]:
        """Durable ``[eH, eT]`` of a split shard (``[0, 0]`` if it never
        committed).  The composite pair lives in ONE cEpoch file so the
        handoff commit can advance both lanes atomically."""
        raw = self.fs.read(self._epoch_path(s))
        if not raw:
            return [0, 0]
        txt = raw.decode()
        if txt.lstrip().startswith("["):
            e = json.loads(txt)
            return [int(e[0]), int(e[1])]
        return [0, int(txt)]  # pre-split history: all commits were one-lane

    def _lane_mode(
        self, s: int, ops_host, kinds_host, shard_host, post_state
    ) -> str:
        """Classify one batch's phase on split shard ``s``: ``"head"`` /
        ``"tail"`` (single-side — only that lane's epoch advances) or
        ``"handoff"`` (both lanes commit atomically).

        Handoff triggers when the batch mixes both sides, and ALSO when a
        head-side phase leaves the structure DRAINED (head counter == tail
        counter): that is the moment the head lane's pops have consumed
        everything the tail lane ever published — the lanes are synchronized
        by construction, and committing both epochs here gives recovery one
        crash-consistent point to resolve either side against (the
        drained-queue handoff of arXiv 2107.03492 / 2402.17674).
        """
        ops_a = np.asarray(ops_host, np.int32)
        kinds_a = np.asarray(kinds_host)[: ops_a.shape[0]]
        sel = (
            (np.asarray(shard_host) == s)
            & (ops_a != OP_NONE)
            & (kinds_a != R_OVERFLOW)
        )
        lanes = lane_of_ops_host(self.kinds[s], ops_a[sel])
        has_h = bool(np.any(lanes == LANE_HEAD))
        has_t = bool(np.any(lanes == LANE_TAIL))
        if has_h and has_t:
            return "handoff"
        ends = np.asarray(post_state.ends)
        active = (int(post_state.epoch) // 2) % 2
        drained = int(ends[active][0]) == int(ends[active][1])
        if has_h and drained:
            return "handoff"
        return "head" if has_h else "tail"

    def _persist_split_shard(
        self, s: int, mode: str, lane_targets: Sequence[int], state, counters
    ) -> List[str]:
        """pwb split shard ``s``'s post-phase lane record(s) into their
        inactive lane slots (the split twin of ``_persist_shard``).

        Only the committing lane(s) write: a head-side queue phase writes ONE
        tiny ``rec.json`` — no values leaf, no ends leaf, no epoch leaf —
        which is where the two-lane pwb/op win comes from.  Lanes that own
        values writes (``_LANE_WRITES_VALUES``) persist ``values.npy`` with
        the same dirty-leaf digest elision as the one-lane path, so a phase
        that only moved counters (drained elimination, window-served pops)
        costs no values pwb in either layout.
        """
        one = state if state is not None else self._shard_state(s)
        kind = self.kinds[s]
        ends = np.asarray(one.ends)
        active = (int(one.epoch) // 2) % 2
        ctr = (int(ends[active][0]), int(ends[active][1]))  # (head, tail)
        if counters is None:
            counters = (
                int(self.meta["phases"][s]),
                int(self.meta["ops_combined"][s]),
            )
        commit_lanes = {
            "head": (LANE_HEAD,),
            "tail": (LANE_TAIL,),
            "handoff": (LANE_HEAD, LANE_TAIL),
        }[mode]
        files: List[str] = []
        for lane in commit_lanes:
            target = int(lane_targets[lane])
            sdir = self._lane_slot_dir(s, lane, target - 2, nxt=True)
            if _LANE_WRITES_VALUES[kind][lane]:
                arr = np.asarray(one.values)
                buf = io.BytesIO()
                np.save(buf, arr)
                data = buf.getvalue()
                rel = f"{sdir}/values.npy"
                digest = hashlib.blake2b(data, digest_size=16).digest()
                if self._elide.get(rel) != digest:
                    self.fs.write(rel, data, tag="slot")
                    files.append(rel)
                    self._elide_pending[rel] = digest
                    self.obs.metrics.counter("elision_miss", shard=s)
                else:
                    self.obs.metrics.counter("elision_hit", shard=s)
            rec = {
                "kind": kind,
                "lane": _LANE_TAGS[lane],
                "epoch": target,
                "ctr": ctr[lane],
                "phases": int(counters[0]),
                "ops_combined": int(counters[1]),
            }
            rel = f"{sdir}/rec.json"
            self.fs.write(rel, json.dumps(rec).encode(), tag="slot")
            files.append(rel)
        return files

    def _commit_lane_epochs(
        self, s: int, mode: str, lane_targets: Sequence[int]
    ) -> None:
        """Two-increment commit of a split shard's composite epoch pair:
        write the pair with the advancing lane(s) odd, fsync (THE commit
        point), publish the even pair unsynced.  Because the pair shares one
        file, a handoff's two lanes commit or roll back together — recovery
        rounds odd components up independently but a crash can never land
        between them."""
        tH, tT = int(lane_targets[LANE_HEAD]), int(lane_targets[LANE_TAIL])
        adv_h = mode in ("head", "handoff")
        adv_t = mode in ("tail", "handoff")
        odd = [tH - 1 if adv_h else tH, tT - 1 if adv_t else tT]
        path = self._epoch_path(s)
        self.fs.write(path, json.dumps(odd).encode(), tag="epoch")
        self.fs.fsync([path], tag="epoch")
        self.fs.write(path, json.dumps([tH, tT]).encode(), tag="epoch")
        self.lane_epochs[s] = [tH, tT]
        self.obs.event(
            EV_EPOCH, shard=s, epoch=tH + tT, lanes=[tH, tT], mode=mode
        )

    def _plan_lane_commit(
        self, s: int, ops_host, kinds_host, shard_host, post_state
    ) -> Tuple[str, List[int]]:
        """One touched split shard's commit plan for one phase:
        ``(mode, [eH', eT'])`` where the advancing lane(s) are the current
        mirror + 2 and the quiescent lane keeps its committed epoch (so
        per-op verdict targets on the quiescent lane are already met)."""
        mode = self._lane_mode(s, ops_host, kinds_host, shard_host, post_state)
        eH, eT = self._lane_epoch_pair(s)
        tH = eH + 2 if mode in ("head", "handoff") else eH
        tT = eT + 2 if mode in ("tail", "handoff") else eT
        return mode, [tH, tT]

    def _lane_targets_per_op(
        self, ops_host, shard_host, plans: Dict[int, Tuple[str, List[int]]],
        fallback_targets,
    ) -> Tuple[List[int], List[int]]:
        """Per-op ``(targets, lanes)`` for the durable response record.  An
        op on a split shard targets ITS LANE's post-phase epoch (quiescent
        lane ops of an untouched/other-side shard target the already
        committed value); unsplit ops keep the scalar device-epoch target
        with lane ``LANE_NONE``."""
        ops_a = np.asarray(ops_host, np.int32)
        shards_a = np.asarray(shard_host)
        lanes = self._op_lanes_host(ops_a, shards_a)
        targets: List[int] = []
        for j in range(ops_a.shape[0]):
            s = int(shards_a[j])
            if lanes[j] == LANE_NONE:
                targets.append(int(fallback_targets[j]))
            else:
                pair = (
                    plans[s][1] if s in plans else self._lane_epoch_pair(s)
                )
                targets.append(int(pair[lanes[j]]))
        return targets, [int(x) for x in lanes]

    def lane_stats(self) -> Optional[Dict[str, Any]]:
        """Per-lane observability snapshot (``None`` when lanes are off):
        committed ``[eH, eT]`` per split shard plus the per-lane BACKLOG —
        announced-but-uncombined ops bucketed by (shard, lane) — consumed by
        ``obs.observe_fabric`` and ``tools/fabric_top.py``."""
        if not self.split_lanes:
            return None
        epochs = {}
        for s in range(self.n_shards):
            if self._is_split(s):
                epochs[s] = list(self._lane_epoch_pair(s))
        backlog: Dict[int, List[int]] = {s: [0, 0] for s in epochs}
        if self.fs is not None:
            for t in self.ready_announcements():
                rec = self._live.get(t)
                if rec is None:
                    continue
                shards = self.route_host(rec["keys"])
                lanes = self._op_lanes_host(rec["ops"], shards)
                for j in range(lanes.shape[0]):
                    if lanes[j] != LANE_NONE:
                        backlog[int(shards[j])][int(lanes[j])] += 1
        return {"epochs": epochs, "backlog": backlog}

    # ------------------------------------------------- durable routing layout
    _REPOCH_PATH = "routing/rEpoch"
    _INTENT_PATH = "reshard/intent.json"

    def _routing_slot(self, repoch: int, nxt: bool) -> str:
        return f"routing/slot{(repoch // 2 + (1 if nxt else 0)) % 2}.json"

    def _routing_record(self, target: int, table, kinds) -> Dict[str, Any]:
        return {
            "epoch": target,
            "table": [int(x) for x in table],
            "kinds": list(kinds),
            "n_shards": len(kinds),
            "n_buckets": self.n_buckets,
            "capacity": self.capacity,
            "lanes": self.lanes,
            "split_lanes": self.split_lanes,
        }

    # --------------------------------------------------------- combine phase
    def _chain_holding(self, thread: int, token: int) -> Optional[Dict[str, Any]]:
        """The in-flight chain that dispatched (thread, token), if any."""
        for fl in self._inflight:
            for info in fl["batches"]:
                for seg in info["threads"]:
                    if seg["thread"] == thread and seg["token"] == token:
                        return fl
        return None

    def _collect_ready(self) -> List[Tuple[int, Dict[str, Any]]]:
        """Ready announcements as (thread, live-record) pairs, in thread
        order, excluding batches already dispatched into the pipeline."""
        inflight = set()
        for fl in self._inflight:
            for info in fl["batches"]:
                for seg in info["threads"]:
                    inflight.add((seg["thread"], seg["token"]))
        out = []
        for t in self.ready_announcements():
            rec = self._live.get(t)
            v = self._read_valid(t)
            if rec is None or rec["slot"] != (v & 1):
                # announced before this runtime object existed (or by another
                # writer): rebuild the live record from the durable mirror
                ann = self._read_ann(t, v & 1)
                rec = self._register_live(
                    t, v & 1, ann["token"], ann["keys"], ann["ops"], ann["params"]
                )
            if (t, rec["token"]) in inflight:
                continue
            out.append((t, rec))
        return out

    def _payload_view(self, rec: Dict[str, Any]):
        """A live batch's payload as device arrays: straight out of the
        announcement ring when the span landed there, host upload otherwise."""
        if rec["ring_start"] is not None:
            return ring_drain(self.ring, rec["ring_start"], rec["n"])
        return (
            jnp.asarray(rec["keys"].astype(np.int32)),
            jnp.asarray(rec["ops"]),
            jnp.asarray(rec["params"]),
        )

    def combine_phase(self) -> List[int]:
        """One durable combining phase over every ready announcement.

        Serial mode (``pipeline=False``, the default): concatenates the
        announced batches (announcement order = thread id order — the
        combiner's walk over the announcement array), runs the fused device
        step on the ring-resident payload, persists every touched shard into
        its inactive slot, writes responses + per-op commit targets into the
        combined announcements, pfences ONCE (paper line 80), then commits
        each touched shard's epoch with the two-increment protocol (lines
        81-83).  Returns the combined thread ids.

        Pipelined mode (``depth > 1``; the legacy ``pipeline=True`` is
        depth=2): stage 1 DISPATCHES the device combine for the freshly
        collected chain and appends it to the in-flight ring; stage 2
        retires the OLDEST chains — persist + pfence + per-shard epoch
        commits, strictly in commit order — until at most ``depth - 1``
        dispatched chains remain un-retired, so persistence of chain k
        overlaps the device combine of chains k+1..k+depth-1.  A chain's
        responses become durable only when it retires (a later
        ``combine_phase``, an ``announce`` reclaiming its slot, or an
        explicit ``flush``); the two-increment epoch commit still gates
        visibility, so recovery semantics are unchanged at every depth.

        With ``chain > 1``, each ready thread's announcement becomes its own
        batch (the tail group absorbs the remainder; the chain is PADDED to
        exactly ``chain`` batches with all-``OP_NONE`` pass-through batches,
        so every dispatch of the fabric shares one compiled program per lane
        width however many announcers were ready) and the whole chain is
        combined in ONE fused dispatch (``dfc_sharded_multi_combine_step``,
        scan unrolled by ``depth``) but persisted and committed
        batch-by-batch, exactly like that many serial phases — padding
        batches touch no shard and cost no persistence op.
        """
        assert self.fs is not None, "combine_phase needs a SimFS"
        ready = self._collect_ready()
        if not ready:
            self.flush()
            return []

        if self.chain > 1:
            groups = [[r] for r in ready[: self.chain - 1]]
            tail = list(ready[self.chain - 1:])
            if tail:  # fewer ready than chain: no (empty) tail batch
                groups.append(tail)
            # depth-aware dispatch: pad to the chain's full batch count with
            # pass-through batches so the compiled scan shape is fixed
            groups += [[] for _ in range(self.chain - len(groups))]
        else:
            groups = [ready]

        maxlen = max(sum(rec["n"] for _, rec in g) for g in groups)
        pad = max(8, 1 << max(0, (maxlen - 1)).bit_length())
        dev_keys, dev_ops, dev_params, batches = [], [], [], []
        for g in groups:
            karrs, oarrs, parrs, segs, off = [], [], [], [], 0
            for t, rec in g:
                k, o, p = self._payload_view(rec)
                karrs.append(k)
                oarrs.append(o)
                parrs.append(p)
                segs.append(
                    {"thread": t, "token": rec["token"], "slot": rec["slot"],
                     "off": off, "n": rec["n"]}
                )
                off += rec["n"]
                self._ring_spans.pop(t, None)  # span consumed at dispatch
            fill = pad - off
            if fill:
                karrs.append(jnp.zeros((fill,), jnp.int32))
                oarrs.append(jnp.full((fill,), OP_NONE, jnp.int32))
                parrs.append(jnp.zeros((fill,), jnp.float32))
            dev_keys.append(jnp.concatenate(karrs))
            dev_ops.append(jnp.concatenate(oarrs))
            dev_params.append(jnp.concatenate(parrs))
            host_keys = (
                np.concatenate([rec["keys"] for _, rec in g])
                if g else np.zeros((0,), np.int64)
            )
            host_ops = (
                np.concatenate([rec["ops"] for _, rec in g])
                if g else np.zeros((0,), np.int32)
            )
            batches.append(
                {"threads": segs, "shard": self.route_host(host_keys),
                 "ops": host_ops}
            )

        # stage 1: dispatch the chained device combine (async under jit)
        (
            self.groups, self.meta, resp, out_kinds,
            states, epochs_before, epochs, phases_cum, ops_cum,
        ) = hetero_multi_step(
            self.groups,
            jnp.asarray(self.table),
            jnp.stack(dev_keys),
            jnp.stack(dev_ops),
            jnp.stack(dev_params),
            self.meta,
            kinds=tuple(self.kinds),
            lanes=self.lanes,
            backend=self.backend,
            unroll=self.depth,
        )
        self._inflight.append({
            "batches": batches, "resp": resp, "kinds": out_kinds,
            "states": states, "epochs_before": epochs_before,
            "epochs": epochs, "phases_cum": phases_cum, "ops_cum": ops_cum,
            "repoch": self.r_epoch,
        })
        self.last_dispatch = [
            tuple((seg["thread"], seg["token"]) for seg in info["threads"])
            for info in batches
            if info["threads"]
        ]
        if self.obs.enabled:
            self.obs.event(
                EV_DISPATCH,
                batches=[
                    [[seg["thread"], seg["token"]] for seg in info["threads"]]
                    for info in batches
                ],
                inflight=len(self._inflight),
            )
            self.obs.metrics.gauge("inflight_chains", len(self._inflight))
        # stage 2: retire the oldest chains, in commit order, while the
        # device combines — keep at most depth-1 chains in flight
        while len(self._inflight) > self.depth - 1:
            self._retire(self._inflight.popleft())
        if self.obs.enabled:
            self.obs.observe_fabric(self)
        return [seg["thread"] for info in batches for seg in info["threads"]]

    def _retire(self, fl: Dict[str, Any]) -> List[int]:
        """Persist + commit one dispatched chain, batch by batch: persist
        batch b's touched shards into their inactive slots, write batch b's
        responses into the combined announcements, ONE pfence, then the
        per-shard two-increment epoch commits — identical durable schedule
        (and pwb/pfence counts) to that many serial phases."""
        resp = np.asarray(fl["resp"])
        kinds = np.asarray(fl["kinds"])
        epochs = np.asarray(fl["epochs"])  # [B, S]
        phases_cum = np.asarray(fl["phases_cum"])
        ops_cum = np.asarray(fl["ops_cum"])
        prev_epochs = np.asarray(fl["epochs_before"])
        # one device->host fetch per stacked leaf (not per shard slice)
        states_np = {
            k: jax.tree_util.tree_map(np.asarray, st)
            for k, st in fl["states"].items()
        }

        def batch_shard_state(b, s):
            k, r = self.kinds[s], self._row(s)
            return jax.tree_util.tree_map(lambda leaf: leaf[b, r], states_np[k])
        retired = []
        for b, info in enumerate(fl["batches"]):
            e_b = epochs[b]
            touched = [int(s) for s in np.nonzero(e_b != prev_epochs)[0]]
            if not info["threads"] and not touched:
                continue  # chain-padding pass-through: no durable work
            shard = info["shard"]
            ops_host = info["ops"]
            kinds_row = kinds[b][: len(ops_host)]
            # per-side lanes: plan each touched split shard's commit (which
            # lane(s) advance, or a handoff) from the batch's op mix + the
            # post-phase counters, BEFORE any durable write of this phase
            plans: Dict[int, Tuple[str, List[int]]] = {}
            for s in touched:
                if self._is_split(s):
                    plans[s] = self._plan_lane_commit(
                        s, ops_host, kinds_row, shard, batch_shard_state(b, s)
                    )
            files: List[str] = []
            for s in touched:
                if s in plans:
                    files += self._persist_split_shard(
                        s, plans[s][0], plans[s][1],
                        state=batch_shard_state(b, s),
                        counters=(phases_cum[b][s], ops_cum[b][s]),
                    )
                else:
                    files += self._persist_shard(
                        s,
                        int(e_b[s]),
                        state=batch_shard_state(b, s),
                        counters=(phases_cum[b][s], ops_cum[b][s]),
                    )
            fallback = e_b[shard]  # per-op commit target (its shard)
            if self.split_lanes:
                targets, op_lanes = self._lane_targets_per_op(
                    ops_host, shard, plans, fallback
                )
            else:
                targets = [int(e) for e in fallback]
                op_lanes = None
            for seg in info["threads"]:
                sl = slice(seg["off"], seg["off"] + seg["n"])
                ann = self._read_ann(seg["thread"], seg["slot"])
                ann["val"] = {
                    "resp": [float(v) for v in resp[b][sl]],
                    "kinds": [int(k) for k in kinds[b][sl]],
                    "shards": [int(s) for s in shard[sl]],
                    "targets": list(targets[sl]),
                    "repoch": fl["repoch"],
                }
                if op_lanes is not None:
                    ann["val"]["lanes"] = list(op_lanes[sl])
                rel = self._ann_path(seg["thread"], seg["slot"])
                self.fs.write(rel, json.dumps(ann).encode(), tag="resp")
                files.append(rel)
                retired.append(seg["thread"])
            self.fs.fsync(files, tag="phase")  # ONE pfence for slots + responses
            self._promote_elision()
            for s in touched:  # per-shard two-increment epoch commit
                if s in plans:
                    self._commit_lane_epochs(s, plans[s][0], plans[s][1])
                    continue
                e = int(e_b[s])
                self.fs.write(self._epoch_path(s), str(e - 1).encode(), tag="epoch")
                self.fs.fsync([self._epoch_path(s)], tag="epoch")
                self.fs.write(self._epoch_path(s), str(e).encode(), tag="epoch")
                self.obs.event(EV_EPOCH, shard=s, epoch=e)
            if self.obs.enabled:
                self.obs.event(
                    EV_RETIRE,
                    batch=b,
                    threads=[
                        [seg["thread"], seg["token"]] for seg in info["threads"]
                    ],
                    touched=touched,
                    files=len(files),
                )
            prev_epochs = e_b
        return retired

    def flush(self) -> List[int]:
        """Retire every in-flight chain, oldest first (pipelined mode):
        persist their shard states and responses and commit their epochs, in
        commit order.  Returns the thread ids whose announcements became
        durable."""
        retired: List[int] = []
        while self._inflight:
            retired += self._retire(self._inflight.popleft())
        return retired

    def _drain(self) -> None:
        """Combine every ready announcement AND retire the pipeline — the
        quiescent point resharding transactions start from."""
        self.combine_phase()
        self.flush()

    # ------------------------------------------------------ fused phase loop
    def phase_loop(
        self,
        schedule: Sequence[Tuple[int, int, Any, Any, Any]],
        *,
        unroll: Optional[int] = None,
        phase_axis: str = "scan",
    ) -> List[Dict[str, Any]]:
        """Fuse K combining phases into ONE device dispatch, then drain the
        per-phase persist intents host-side — subsuming ``combine_phase`` +
        ``_retire`` for a whole schedule of batches.

        ``schedule`` is K per-phase entries ``(thread, token, keys, ops,
        params)``: each entry is one thread's announced batch, combined as
        its OWN phase (phase order = schedule order; per-thread tokens must
        be monotone across the schedule, the ``announce`` contract).  The
        device side routes, combines, and accumulates every phase's
        epoch/persist intents in device arrays (``hetero_phase_loop_step``:
        one ``lax.scan`` — or one Pallas grid over the phase axis — per kind
        group, group buffers donated off-CPU so stacked shard state never
        leaves the device between phases), with the whole schedule staged
        through the announcement ring in one scatter when it fits.  The host
        then drains the intent log in strict serial order — for each phase:
        the batch's durable announce (3 pwb + 2 pfence, the exact
        ``announce`` write sequence), the touched shards' slot persists, the
        response record write, ONE pfence, the per-shard two-increment epoch
        commits — so oldest-first commit order and the serial path's
        pwb/pfence counts are preserved EXACTLY (``bench_phase_loop.py``
        asserts both, the way ``bench_multithread.py`` asserts for depth).

        A crash anywhere in the drain leaves the durable log shaped exactly
        like a serial run that crashed at the same persistence op — up to
        K phases of device-combined intents simply vanish with the volatile
        state — so ``recover`` / ``replay_pending`` roll the log forward to
        the last committed epoch with per-thread detectability verdicts
        intact, and phases whose announce never reached the log are the
        driver's to re-drive (same contract as the pipelined sweeps).

        Because a thread's double-buffered records retain only its last two
        batches, responses for the whole schedule are RETURNED (one record
        per phase, in phase order: ``{"thread", "token", "resp", "kinds",
        "shards", "targets", "repoch"}``); ``read_responses`` still serves
        each thread's final two tokens afterwards.
        """
        assert self.fs is not None, "phase_loop needs a SimFS"
        self._drain()  # quiescent start: no ready announcements, no chains
        if not schedule:
            return []

        k_phases = len(schedule)
        batches = []
        for thread, token, keys, ops, params in schedule:
            batches.append((
                int(thread), int(token),
                np.asarray(keys, np.int64),
                np.asarray(ops, np.int32),
                np.asarray(params, np.float32),
            ))
        maxlen = max(b[3].shape[0] for b in batches)
        pad = max(8, 1 << max(0, (maxlen - 1)).bit_length())
        keys_h = np.zeros((k_phases, pad), np.int64)
        ops_h = np.full((k_phases, pad), OP_NONE, np.int32)
        params_h = np.zeros((k_phases, pad), np.float32)
        for j, (_, _, keys, ops, params) in enumerate(batches):
            n = ops.shape[0]
            keys_h[j, :n] = keys
            ops_h[j, :n] = ops
            params_h[j, :n] = params

        # stage the whole schedule through the announcement ring (one device
        # scatter + one phase-axis gather) when it fits; host upload if not
        dev = None
        if self.ring is not None and k_phases * pad:
            slots = int(self.ring.keys.shape[0])
            oldest = min(
                (s0 for s0, _ in self._ring_spans.values()),
                default=self._ring_tail,
            )
            if ring_has_room(slots, self._ring_tail, oldest, k_phases * pad):
                self.ring = ring_announce_phases(
                    self.ring,
                    jnp.asarray(keys_h.astype(np.int32)),
                    jnp.asarray(ops_h),
                    jnp.asarray(params_h),
                )
                start = self._ring_tail
                self._ring_tail += k_phases * pad
                dev = ring_drain_phases(self.ring, start, k_phases, pad)
        if dev is None:
            dev = (
                jnp.asarray(keys_h.astype(np.int32)),
                jnp.asarray(ops_h),
                jnp.asarray(params_h),
            )

        # ONE fused dispatch for the whole schedule
        (
            self.groups, self.meta, resp, out_kinds,
            states, epochs_before, intents,
        ) = hetero_phase_loop_step(
            self.groups,
            jnp.asarray(self.table),
            dev[0], dev[1], dev[2],
            self.meta,
            kinds=tuple(self.kinds),
            lanes=self.lanes,
            backend=self.backend,
            unroll=self.depth if unroll is None else int(unroll),
            phase_axis=phase_axis,
        )
        self.last_dispatch = [((t, tok),) for t, tok, *_ in batches]
        if self.obs.enabled:
            self.obs.event(
                EV_DISPATCH,
                fused=True,
                k_phases=k_phases,
                pad=pad,
                phase_axis=phase_axis,
                batches=[[t, tok] for t, tok, *_ in batches],
            )

        # fetch the intent log: one device->host transfer per stacked leaf
        resp_np = np.asarray(resp)
        kinds_np = np.asarray(out_kinds)
        epochs = np.asarray(intents.epoch)  # [K, S]
        phases_cum = np.asarray(intents.phases_cum)
        ops_cum = np.asarray(intents.ops_cum)
        prev_epochs = np.asarray(epochs_before)
        states_np = {
            k: jax.tree_util.tree_map(np.asarray, st)
            for k, st in states.items()
        }

        def phase_shard_state(j, s):
            k, r = self.kinds[s], self._row(s)
            return jax.tree_util.tree_map(
                lambda leaf: leaf[j, r], states_np[k]
            )

        # host intent drain: replay the exact serial durable schedule,
        # phase by phase, behind the device
        out_records: List[Dict[str, Any]] = []
        for j, (thread, token, keys, ops, params) in enumerate(batches):
            n = ops.shape[0]
            slot, ann = self._announce_durable(thread, token, keys, ops, params)
            self._slot_tokens[(thread, slot)] = token
            self._live[thread] = {
                "token": token, "slot": slot, "n": n,
                "keys": keys, "ops": ops, "params": params,
                "ring_start": None,
            }
            e_j = epochs[j]
            touched = [int(s) for s in np.nonzero(e_j != prev_epochs)[0]]
            shard = self.route_host(keys)
            kinds_row = kinds_np[j][:n]
            plans: Dict[int, Tuple[str, List[int]]] = {}
            for s in touched:
                if self._is_split(s):
                    plans[s] = self._plan_lane_commit(
                        s, ops, kinds_row, shard, phase_shard_state(j, s)
                    )
            files: List[str] = []
            for s in touched:
                if s in plans:
                    files += self._persist_split_shard(
                        s, plans[s][0], plans[s][1],
                        state=phase_shard_state(j, s),
                        counters=(phases_cum[j][s], ops_cum[j][s]),
                    )
                else:
                    files += self._persist_shard(
                        s,
                        int(e_j[s]),
                        state=phase_shard_state(j, s),
                        counters=(phases_cum[j][s], ops_cum[j][s]),
                    )
            fallback = e_j[shard]
            if self.split_lanes:
                targets, op_lanes = self._lane_targets_per_op(
                    ops, shard, plans, fallback
                )
            else:
                targets = [int(e) for e in fallback]
                op_lanes = None
            ann["val"] = {
                "resp": [float(v) for v in resp_np[j][:n]],
                "kinds": [int(k) for k in kinds_row],
                "shards": [int(s) for s in shard],
                "targets": list(targets),
                "repoch": self.r_epoch,
            }
            if op_lanes is not None:
                ann["val"]["lanes"] = list(op_lanes)
            rel = self._ann_path(thread, slot)
            self.fs.write(rel, json.dumps(ann).encode(), tag="resp")
            files.append(rel)
            self.fs.fsync(files, tag="phase")  # ONE pfence for slots + responses
            self._promote_elision()
            for s in touched:  # per-shard two-increment epoch commit
                if s in plans:
                    self._commit_lane_epochs(s, plans[s][0], plans[s][1])
                    continue
                e = int(e_j[s])
                self.fs.write(self._epoch_path(s), str(e - 1).encode(), tag="epoch")
                self.fs.fsync([self._epoch_path(s)], tag="epoch")
                self.fs.write(self._epoch_path(s), str(e).encode(), tag="epoch")
                self.obs.event(EV_EPOCH, shard=s, epoch=e)
            if self.obs.enabled:
                self.obs.event(
                    EV_DRAIN,
                    phase=j,
                    thread=thread,
                    token=token,
                    touched=touched,
                    files=len(files),
                )
            prev_epochs = e_j
            out_records.append(dict(ann["val"], thread=thread, token=token))
        if self.obs.enabled:
            self.obs.observe_fabric(self)
        return out_records

    def read_responses(
        self, thread: int, token: Optional[int] = None,
        lane: Optional[int] = None,
    ) -> Optional[Dict[str, Any]]:
        """A thread's combined announcement, or None while still pending.

        Returns ``{"token", "resp", "kinds", "shards", "targets", ...}`` —
        the durable response record written when the phase that combined
        this thread's announcement was retired.  With ``token``, searches
        BOTH announcement slots for that batch — in pipelined mode a
        thread's previous batch retires while its newest is still in flight,
        so the response being read usually lives in the older slot.

        With ``lane`` (split-lane fabrics), the returned record is filtered
        to the ops that rode that announcement lane (``LANE_HEAD`` /
        ``LANE_TAIL``; ops on unsplit shards are ``LANE_NONE``).  The filter
        applies AFTER the slot search and AFTER staleness detection: with
        per-side combiners a thread's retained slots can hold one head-side
        and one tail-side batch with interleaved tokens, and the monotone
        staleness rule below must still judge ``token`` against the NEWEST
        retained token across BOTH lanes — a lane-local view would mistake
        an overwritten token of the other lane for "pending" and spin
        forever (the PR-6 gap-token regression, per-side edition).

        Raises :class:`StaleTokenError` when ``token`` predates both
        retained slots (its record was overwritten by two later
        announcements); returns ``None`` only while the batch is genuinely
        pending (announced and not yet retired, or not yet announced).
        """

        def _lane_view(val: Dict[str, Any], tok: int):
            out = dict(val, token=tok)
            if lane is None:
                return out
            lanes = val.get("lanes")
            if lanes is None:
                lanes = [LANE_NONE] * len(val.get("kinds", []))
            idx = [i for i, ln in enumerate(lanes) if ln == lane]
            for key in ("resp", "kinds", "shards", "targets", "lanes"):
                if key in out and isinstance(out[key], list):
                    out[key] = [out[key][i] for i in idx]
            return out

        v = self._read_valid(thread)
        if token is None:
            ann = self._read_ann(thread, v & 1)
            if ann.get("val") is BOT:
                return None
            return _lane_view(ann["val"], ann["token"])
        held = []
        for slot in (v & 1, 1 - (v & 1)):
            ann = self._read_ann(thread, slot)
            t = ann.get("token", -1)
            if t == token:
                if ann.get("val") is BOT:
                    return None  # announced, not yet combined/retired
                return _lane_view(ann["val"], ann["token"])
            if t >= 0:
                held.append(t)
        # Staleness: per-thread tokens are MONOTONE, so a requested token
        # below the NEWEST retained one provably predates the retained
        # slot(s) — either it was announced and its record has been
        # overwritten, or it was skipped and can never be announced now.
        # (Comparing against min(held) missed the gap case — a token between
        # the two retained ones, or below the only retained one while the
        # other slot is still unannounced — and silently returned None,
        # indistinguishable from "pending", so pollers spun forever.)
        if held and token < max(held):
            raise StaleTokenError(
                f"thread {thread} token {token} predates retained "
                f"announcement slot(s) (tokens held: {sorted(held)}); its "
                "response record was overwritten or never announced — read "
                "responses before announcing two successor batches"
            )
        return None

    # ----------------------------------------------------------- resharding
    def _snapshot_donor(self, s: int, op: str) -> None:
        """Detectable typed snapshot of the donor shard, via the checkpoint
        manager's ``combine_structure`` (same SimFS: fault-injection sweeps
        tick through the snapshot's pwb/pfence ops too)."""
        self._reshard_seq += 1
        mgr = DFCCheckpointManager(self.fs, 1, prefix="reshard/ckpt")
        e = mgr._read_epoch()
        if e % 2 == 1:  # a crash mid-snapshot commit left the log's epoch
            mgr._write_epoch(e + 1, sync=True)  # odd: finish the increment
        mgr.announce(0, {"step": self._reshard_seq})
        mgr.combine_structure(
            self._shard_state(s),
            extra_meta={"donor": int(s), "op": op, "repoch": self.r_epoch},
        )

    def _commit_routing(
        self,
        intent: Dict[str, Any],
        new_table: np.ndarray,
        new_kinds: List[str],
        shard_files: List[str],
    ) -> None:
        """Steps 3-5 of the reshard transaction: intent, routing slot (+ any
        pre-written shard slots), ONE pfence, then the rEpoch two-increment
        commit — the transaction's commit point."""
        target = self.r_epoch + 2
        self.fs.write(self._INTENT_PATH, json.dumps(intent).encode(), tag="routing")
        self.fs.fsync([self._INTENT_PATH], tag="routing")
        slot = self._routing_slot(self.r_epoch, nxt=True)
        self.fs.write(
            slot,
            json.dumps(self._routing_record(target, new_table, new_kinds)).encode(),
            tag="routing",
        )
        self.fs.fsync(shard_files + [slot], tag="routing")
        self.fs.write(self._REPOCH_PATH, str(target - 1).encode(), tag="routing")
        self.fs.fsync([self._REPOCH_PATH], tag="routing")
        self.fs.write(self._REPOCH_PATH, str(target).encode(), tag="routing")
        if self.obs.enabled:
            self.obs.event(
                EV_RESHARD,
                op=intent.get("op"),
                target_repoch=target,
                n_shards=len(new_kinds),
            )

    def split_shard(self, donor: int) -> int:
        """Split a hot shard: move half of the donor's buckets to a NEW empty
        shard of the same kind.  Crash-consistent (commit point = rEpoch);
        the donor's contents stay put — only future routing changes — so
        there is nothing to roll forward on the shard side.  Returns the new
        shard id.
        """
        buckets = [b for b in range(self.n_buckets) if self.table[b] == donor]
        if len(buckets) < 2:
            raise ValueError(
                f"shard {donor} holds {len(buckets)} bucket(s); construct the "
                "fabric with n_buckets > n_shards to make shards splittable"
            )
        kind = self.kinds[donor]
        new_id = self.n_shards
        new_table = self.table.copy()
        new_table[buckets[1::2]] = new_id
        new_kinds = self.kinds + [kind]

        if self.fs is not None:
            self._drain()  # drain ready announcements AND the pipeline
            self._snapshot_donor(donor, "split")
            intent = {
                "op": "split",
                "donor": int(donor),
                "new_shard": new_id,
                "kind": kind,
                "pre_repoch": self.r_epoch,
                "target_repoch": self.r_epoch + 2,
                "target_epochs": {},  # split moves no shard state
            }
            # the new shard needs no durable state: no cEpoch file means
            # epoch 0, no slot means a fresh empty init on recovery
            self._commit_routing(intent, new_table, new_kinds, [])
            self.fs.delete(self._INTENT_PATH)

        # in-memory install
        fresh = STRUCTS[kind].init(self.capacity)
        self.groups[kind] = jax.tree_util.tree_map(
            lambda leaf, f: jnp.concatenate([leaf, f[None]]), self.groups[kind], fresh
        )
        self.kinds = new_kinds
        self.n_shards += 1
        self.table = new_table
        self.r_epoch += 2
        new_row = _init_meta([kind])  # single source of truth for columns
        self.meta = {
            key: jnp.concatenate(
                [col, new_row.get(key, jnp.zeros((1,), col.dtype))]
            )
            for key, col in self.meta.items()
        }
        return new_id

    def merge_shards(self, src: int, dst: int) -> None:
        """Merge a cold shard into another of the SAME kind: ``dst`` absorbs
        ``src``'s committed contents (appended after ``dst``'s own — enqueued
        at the tail / pushed on top / pushed right), ``src`` empties and its
        buckets re-route to ``dst``.  ``src``'s shard id stays allocated but
        unrouted, so recorded detectability verdicts never dangle.

        Crash-consistent: both post-merge states are pwb'd into their
        inactive slots and pfenced BEFORE the rEpoch commit; recovery rolls
        their cEpochs forward when the rEpoch committed and the per-shard GC
        reclaims the orphaned slots when it did not.
        """
        if src == dst:
            raise ValueError("cannot merge a shard into itself")
        if self.kinds[src] != self.kinds[dst]:
            raise ValueError(
                f"kind mismatch: shard {src} is {self.kinds[src]!r}, "
                f"shard {dst} is {self.kinds[dst]!r}"
            )
        kind = self.kinds[src]
        if self.fs is not None:
            self._drain()  # drain ready announcements AND the pipeline
        merged = self.shard_contents(dst) + self.shard_contents(src)
        if len(merged) + self.lanes > self.capacity:
            raise ValueError(
                f"merged contents ({len(merged)}) + lanes ({self.lanes}) "
                f"exceed capacity {self.capacity}"
            )
        epochs = self.shard_epochs()
        t_src, t_dst = int(epochs[src]) + 2, int(epochs[dst]) + 2
        src_new = state_from_contents(kind, [], self.capacity, t_src)
        dst_new = state_from_contents(kind, merged, self.capacity, t_dst)
        new_table = self.table.copy()
        new_table[new_table == src] = dst

        if self.fs is not None:
            self._snapshot_donor(src, "merge")
            # split shards reshard handoff-style: BOTH lanes advance, the
            # intent records the lane pair, and recovery rolls the composite
            # epoch forward componentwise
            split = self._is_split(src)
            if split:
                lane_targets = {
                    sid: [e + 2 for e in self._lane_epoch_pair(sid)]
                    for sid in (src, dst)
                }
                intent_targets = {
                    str(sid): list(lane_targets[sid]) for sid in (src, dst)
                }
            else:
                intent_targets = {str(src): t_src, str(dst): t_dst}
            intent = {
                "op": "merge",
                "src": int(src),
                "dst": int(dst),
                "kind": kind,
                "pre_repoch": self.r_epoch,
                "target_repoch": self.r_epoch + 2,
                "target_epochs": intent_targets,
            }
            if split:
                files = self._persist_split_shard(
                    src, "handoff", lane_targets[src], state=src_new,
                    counters=None,
                )
                files += self._persist_split_shard(
                    dst, "handoff", lane_targets[dst], state=dst_new,
                    counters=None,
                )
            else:
                files = self._persist_shard(src, t_src, state=src_new)
                files += self._persist_shard(dst, t_dst, state=dst_new)
            self._commit_routing(intent, new_table, self.kinds, files)
            self._promote_elision()
            if split:
                for sid in (src, dst):
                    self._commit_lane_epochs(sid, "handoff", lane_targets[sid])
            else:
                for sid, tgt in ((src, t_src), (dst, t_dst)):
                    self.fs.write(self._epoch_path(sid), str(tgt - 1).encode(), tag="epoch")
                    self.fs.fsync([self._epoch_path(sid)], tag="epoch")
                    self.fs.write(self._epoch_path(sid), str(tgt).encode(), tag="epoch")
                    self.obs.event(EV_EPOCH, shard=sid, epoch=tgt)
            self.fs.delete(self._INTENT_PATH)

        self._set_shard_state(src, src_new)
        self._set_shard_state(dst, dst_new)
        self.table = new_table
        self.r_epoch += 2

    # -------------------------------------------------------------- recover
    @classmethod
    def recover(
        cls,
        fs: SimFS,
        *,
        kind: Union[str, Sequence[str]] = "queue",
        n_shards: int = 1,
        capacity: int,
        lanes: int,
        backend: str = "jnp",
        n_threads: int = 1,
        n_buckets: Optional[int] = None,
        table=None,
        pipeline: bool = False,
        depth: Optional[int] = None,
        chain: int = 1,
        ring_slots: int = 2048,
        split_lanes: bool = False,
        obs=None,
    ) -> Tuple["ShardedDFCRuntime", Dict[int, Dict[str, Any]]]:
        """Recover the fabric + per-thread/per-op detectability report.

        Topology first: the durable routing record (if any) overrides the
        caller's ``kind`` / ``n_shards`` / ``table`` bootstrap arguments, so
        a fabric that resharded before the crash comes back with its
        post-reshard shape (pass the construction-time ``table`` when
        recovering a custom-routed fabric that never resharded — the first
        reshard is what makes the topology durable).
        An interrupted reshard is resolved by its intent record: rolled
        FORWARD when the routing epoch committed (finish the touched shards'
        cEpoch bumps — their slot data was pfenced before the commit point),
        rolled BACK otherwise (old routing; the per-shard GC reclaims the
        orphaned slot writes).

        Then per shard: round an odd durable epoch up to even (finish the
        interrupted second increment, paper lines 28-30), garbage-collect the
        inactive slot (§4), and reload the active slot (or a fresh init when
        the shard never committed).  Per announced op: applied iff its
        shard's committed epoch reached the target recorded with the
        response; everything else is reported not-applied and is safe to
        re-announce (see ``replay_pending``).

        Overlap-aware (pipelined path): a thread's OLDER announcement slot
        may hold an in-flight predecessor — batch k, combined by the
        pipeline but never retired (no durable responses) or retired but not
        committed — while its newest slot holds batch k+1.  Recovery
        resolves it: when the predecessor never fully committed, the report
        carries its verdicts under ``report[t]["prev"]`` and
        ``replay_pending`` re-announces it BEFORE the newest batch, keeping
        per-thread op order.  A fully committed predecessor is ordinary
        history (its durable responses are readable via
        ``read_responses(t, token=...)``) and is not reported.
        """
        # Attach the observer FIRST so recovery's own repair writes join the
        # durable timeline the pre-crash incarnation left behind (the
        # recorder continues the sidecar's sequence numbering).
        obs = obs if obs is not None else NULL_OBS
        if obs.enabled:
            fs.obs = obs
            obs.event(EV_RECOVER, stage="begin")

        # --- routing epoch: round odd up (finish the second increment)
        raw = fs.read(cls._REPOCH_PATH)
        repoch = int(raw.decode()) if raw else 0
        if repoch % 2 == 1:
            repoch += 1
            fs.write(cls._REPOCH_PATH, str(repoch).encode(), tag="recovery")
            fs.fsync([cls._REPOCH_PATH], tag="recovery")

        # --- adopt the committed routing record, if any
        kinds = [kind] * n_shards if isinstance(kind, str) else list(kind)
        active_slot = f"routing/slot{(repoch // 2) % 2}.json"
        rec_raw = fs.read(active_slot)
        if rec_raw:
            rec = json.loads(rec_raw.decode())
            kinds = list(rec["kinds"])
            n_shards = int(rec["n_shards"])
            n_buckets = int(rec["n_buckets"])
            capacity = int(rec.get("capacity", capacity))
            lanes = int(rec.get("lanes", lanes))
            split_lanes = bool(rec.get("split_lanes", split_lanes))
            table = np.asarray(rec["table"], np.int32)

        # --- resolve an interrupted reshard via its intent record
        intent_raw = fs.read(cls._INTENT_PATH)
        if intent_raw:
            intent = json.loads(intent_raw.decode())
            if intent["target_repoch"] <= repoch:
                # committed: roll the touched shards' cEpochs forward (their
                # slot data was pfenced before the rEpoch commit).  Split
                # shards record a ``[eH, eT]`` lane pair; roll each
                # component forward and keep the pair in one atomic file.
                for sid_str, tgt in intent.get("target_epochs", {}).items():
                    p = f"shard_{int(sid_str)}/cEpoch"
                    raw_e = fs.read(p)
                    if isinstance(tgt, list):
                        txt = raw_e.decode() if raw_e else ""
                        cur = (
                            json.loads(txt)
                            if txt.lstrip().startswith("[")
                            else [0, int(txt)] if txt else [0, 0]
                        )
                        new = [max(int(cur[i]), int(tgt[i])) for i in (0, 1)]
                        if new != [int(cur[0]), int(cur[1])]:
                            fs.write(p, json.dumps(new).encode(), tag="recovery")
                            fs.fsync([p], tag="recovery")
                        continue
                    cur = int(raw_e.decode()) if raw_e else 0
                    if cur < int(tgt):
                        fs.write(p, str(int(tgt)).encode(), tag="recovery")
                        fs.fsync([p], tag="recovery")
            else:
                # aborted: routing and shard epochs are still pre-reshard;
                # drop the half-written inactive routing slot
                fs.delete(f"routing/slot{(repoch // 2 + 1) % 2}.json")
            fs.delete(cls._INTENT_PATH)

        rt = cls(
            kinds, n_shards, capacity, lanes,
            backend=backend, fs=fs, n_threads=n_threads,
            n_buckets=n_buckets, table=table,
            pipeline=pipeline, depth=depth, chain=chain, ring_slots=ring_slots,
            split_lanes=split_lanes, obs=obs,
        )
        rt.r_epoch = repoch

        shard_states = []
        phases = np.zeros((n_shards,), np.int32)
        ops_combined = np.zeros((n_shards,), np.int32)
        committed_epochs = np.zeros((n_shards,), np.int64)
        committed_lane_epochs: Dict[int, List[int]] = {}
        for s in range(n_shards):
            fresh = STRUCTS[kinds[s]].init(capacity)
            if rt._is_split(s):
                # --- split shard: round each lane's odd epoch component up
                # (the composite pair file keeps a handoff's two components
                # atomic — a crash can never land between them), reload the
                # two ACTIVE lane records, and reassemble one state
                pair = rt._read_lane_epochs(s)
                if any(e % 2 == 1 for e in pair):
                    pair = [e + (e % 2) for e in pair]
                    fs.write(
                        rt._epoch_path(s), json.dumps(pair).encode(),
                        tag="recovery",
                    )
                    fs.fsync([rt._epoch_path(s)], tag="recovery")
                committed_lane_epochs[s] = list(pair)
                rt.lane_epochs[s] = list(pair)
                committed_epochs[s] = pair[0] + pair[1]
                recs: List[Optional[Dict[str, Any]]] = [None, None]
                live = set()
                for lane in (LANE_HEAD, LANE_TAIL):
                    adir = rt._lane_slot_dir(s, lane, pair[lane], nxt=False)
                    rrel = f"{adir}/rec.json"
                    raw_rec = fs.read_durable(rrel)
                    if raw_rec:
                        recs[lane] = json.loads(raw_rec.decode())
                        live.add(rrel)
                        if _LANE_WRITES_VALUES[kinds[s]][lane]:
                            live.add(f"{adir}/values.npy")
                f_ends = np.asarray(fresh.ends)[0]
                h = int(recs[LANE_HEAD]["ctr"]) if recs[LANE_HEAD] else int(f_ends[0])
                t = int(recs[LANE_TAIL]["ctr"]) if recs[LANE_TAIL] else int(f_ends[1])
                # values: the lane whose record carries the larger ``phases``
                # commit-sequence number holds the chronologically last
                # committed copy (each values-owning lane re-validates its
                # slot's values at every commit, elided when identical)
                values = np.asarray(fresh.values)
                best = (-1, None)
                for lane in (LANE_HEAD, LANE_TAIL):
                    r = recs[lane]
                    if r is None or not _LANE_WRITES_VALUES[kinds[s]][lane]:
                        continue
                    if int(r.get("phases", 0)) > best[0]:
                        adir = rt._lane_slot_dir(s, lane, pair[lane], nxt=False)
                        best = (int(r.get("phases", 0)), f"{adir}/values.npy")
                if best[1] is not None:
                    raw_v = fs.read_durable(best[1])
                    if raw_v:
                        values = np.load(io.BytesIO(raw_v))
                shard_states.append(
                    fresh.__class__(
                        values=jnp.asarray(values),
                        ends=jnp.asarray([[h, t], [h, t]], jnp.int32),
                        epoch=jnp.asarray(pair[0] + pair[1], jnp.int32),
                    )
                )
                phases[s] = max(
                    int(r.get("phases", 0)) for r in recs if r is not None
                ) if any(r is not None for r in recs) else 0
                ops_combined[s] = max(
                    int(r.get("ops_combined", 0)) for r in recs if r is not None
                ) if any(r is not None for r in recs) else 0
                # GC: drop partial lane-slot writes of the interrupted phase
                for lane in (LANE_HEAD, LANE_TAIL):
                    for p in (0, 1):
                        d = f"shard_{s}/lane{_LANE_TAGS[lane]}{p}"
                        for rel in list(fs.listdir(d)):
                            if rel not in live:
                                fs.delete(rel)
                continue
            epoch = rt._read_shard_epoch(s)
            if epoch % 2 == 1:  # crashed between the two increments
                epoch += 1
                fs.write(rt._epoch_path(s), str(epoch).encode(), tag="recovery")
                fs.fsync([rt._epoch_path(s)], tag="recovery")
            committed_epochs[s] = epoch
            active = rt._slot_dir(s, epoch, nxt=False)
            inactive = rt._slot_dir(s, epoch, nxt=True)
            meta_raw = fs.read_durable(f"{active}/meta.json")
            live = {f"{active}/meta.json"}
            if meta_raw:
                meta = json.loads(meta_raw.decode())
                live |= {f"{active}/{e['file']}" for e in meta["leaves"]}
                leaves = [
                    np.load(io.BytesIO(fs.read_durable(f"{active}/{e['file']}")))
                    for e in meta["leaves"]
                ]
                treedef = jax.tree_util.tree_structure(fresh)
                shard_states.append(
                    jax.tree_util.tree_unflatten(
                        treedef, [jnp.asarray(leaf) for leaf in leaves]
                    )
                )
                phases[s] = meta.get("phases", 0)
                ops_combined[s] = meta.get("ops_combined", 0)
            else:
                shard_states.append(fresh)
            # GC: drop partial writes of the interrupted phase
            for rel in list(fs.listdir(active)) + list(fs.listdir(inactive)):
                if rel not in live:
                    fs.delete(rel)

        rt.groups = {
            k: stack_shards([shard_states[s] for s in ids])
            for k, ids in _group_ids(tuple(kinds)).items()
        }
        rt.meta = {
            "phases": jnp.asarray(phases),
            "ops_combined": jnp.asarray(ops_combined),
            "kind": jnp.asarray([KIND_CODES[k] for k in kinds], jnp.int32),
        }

        def _slot_verdicts(ann) -> Tuple[List[OpVerdict], bool]:
            """Per-op verdicts of one announcement record + whether the
            record's phase fully committed (every target epoch reached).
            Split-lane ops carry their LANE's target: committed iff that
            lane's composite-epoch component reached it — the other lane's
            progress neither commits nor rolls back this op."""
            verdicts: List[OpVerdict] = []
            val = ann.get("val")
            n_ops = len(ann.get("ops", []))
            if val is BOT:
                return [OpVerdict(applied=False) for _ in range(n_ops)], False
            op_lanes = val.get("lanes")
            fully = True
            for i in range(n_ops):
                s = val["shards"][i]
                k = val["kinds"][i]
                ln = op_lanes[i] if op_lanes is not None else LANE_NONE
                if ln != LANE_NONE and s in committed_lane_epochs:
                    committed = committed_lane_epochs[s][ln] >= val["targets"][i]
                else:
                    committed = committed_epochs[s] >= val["targets"][i]
                fully = fully and bool(committed)
                applied = bool(committed) and k != R_OVERFLOW and k != R_NONE
                verdicts.append(
                    OpVerdict(
                        applied=applied,
                        kind=k if committed else None,
                        resp=val["resp"][i] if committed else None,
                        shard=s,
                    )
                )
            return verdicts, fully

        report: Dict[int, Dict[str, Any]] = {}
        for t in range(n_threads):
            v = rt._read_valid(t)
            lsb = v & 1
            if (v >> 1) & 1 == 0:  # re-publish a half-written valid selector
                fs.write(rt._valid_path(t), str(2 | lsb).encode(), tag="recovery")
            ann = rt._read_ann(t, lsb)
            if ann.get("token", -1) < 0:
                report[t] = {"token": None, "ops": [], "prev": None}
                continue
            verdicts, _ = _slot_verdicts(ann)
            # overlap-aware: the OLDER slot may hold an in-flight PREDECESSOR
            # (combined by the pipeline, never retired or never committed).
            # Only a SMALLER token qualifies (per-thread tokens are monotone):
            # a larger one is an unpublished successor whose announce crashed
            # before the valid flip — never announced, the thread re-runs it.
            prev = None
            pann = rt._read_ann(t, 1 - lsb)
            ptok = pann.get("token", -1)
            if 0 <= ptok < ann["token"] and pann.get("ops"):
                pverdicts, pfully = _slot_verdicts(pann)
                if not pfully:
                    prev = {"token": ptok, "ops": pverdicts}
            report[t] = {"token": ann["token"], "ops": verdicts, "prev": prev}
            if ann.get("val") is BOT:
                # still pending: re-stage it (ring re-filled from the durable
                # mirror) so a post-recovery combine_phase can run unchanged
                rt._register_live(
                    t, lsb, ann["token"], ann["keys"], ann["ops"], ann["params"]
                )
        if obs.enabled:
            # Extend the pre-crash durable trace prefix with the recovery
            # timeline: one verdict event per announced thread, then flush
            # the sidecar explicitly (a sanctioned host-side flush point —
            # recovery has no pfence of its own to ride here).
            for t, rep in report.items():
                if rep["token"] is None:
                    continue
                obs.event(
                    EV_VERDICT,
                    thread=t,
                    token=rep["token"],
                    applied=[bool(v.applied) for v in rep["ops"]],
                    prev_token=(rep["prev"] or {}).get("token"),
                    prev_applied=[
                        bool(v.applied) for v in (rep["prev"] or {}).get("ops", [])
                    ],
                )
            obs.event(
                EV_RECOVER,
                stage="end",
                repoch=repoch,
                epochs=[int(e) for e in committed_epochs],
                threads=sum(1 for r in report.values() if r["token"] is not None),
            )
            obs.flush()
        return rt, report

    def replay_pending(self, report: Dict[int, Dict[str, Any]]) -> List[int]:
        """Re-announce exactly the not-applied ops of every thread (read back
        from the durable announcement records) and run one combining phase —
        the exactly-once resume step after a crash mid-phase or mid-reshard.
        Returns the thread ids that were replayed.

        Ops whose phase committed with an ``R_NONE`` response are NOT
        replayed: they completed as no-ops (an op code the target structure
        does not interpret, legal in mixed fabrics) and would no-op again on
        every replay forever.  Uncommitted ops (``kind is None``) and
        ``R_OVERFLOW`` rejections are replayed.

        Overlap-aware: when recovery reported an in-flight PREDECESSOR batch
        (``report[t]["prev"]``, pipelined path), its not-applied ops are
        replayed in a round of their own BEFORE the newest announcements, so
        per-thread op order survives the crash."""

        def _redo(ann, verdicts):
            if not ann.get("ops"):
                return None
            idx = [
                i for i, v in enumerate(verdicts)
                if not v.applied and v.kind != R_NONE
            ]
            if not idx:
                return None
            return (
                [ann["keys"][i] for i in idx],
                [ann["ops"][i] for i in idx],
                [ann["params"][i] for i in idx],
            )

        # snapshot both slots' durable records BEFORE any re-announcement
        # flips the valid selectors
        prev_round: List[Tuple[int, int, Tuple]] = []
        newest_round: List[Tuple[int, int, Dict[str, Any], List[OpVerdict]]] = []
        for t in sorted(report):
            r = report[t]
            lsb = self._read_valid(t) & 1
            prev = r.get("prev")
            if prev is not None:
                pann = self._read_ann(t, 1 - lsb)
                if pann.get("token", -1) == prev["token"]:
                    redo = _redo(pann, prev["ops"])
                    if redo is not None:
                        prev_round.append((t, prev["token"], redo))
            if r["token"] is None:
                continue
            ann = self._read_ann(t, lsb)
            if _redo(ann, r["ops"]) is not None:
                newest_round.append((t, r["token"], ann, r["ops"]))

        replayed = set()
        # round 1: in-flight predecessors, so per-thread op order survives
        for t, token, (keys, ops, params) in prev_round:
            self.announce(t, keys, ops, params, token=token)
            replayed.add(t)
        if prev_round:
            self._drain()

        # round 2: newest announcements.  A still-PENDING one (val BOT at
        # recovery) may have been swept up by round 1's combining phase —
        # the combiner takes every ready announcement — in which case it is
        # now applied and committed, and only its R_OVERFLOW rejections
        # (which never touch state) still need a replay.
        for t, token, ann, verdicts in newest_round:
            pre_combined = any(v.shard is not None for v in verdicts)
            if not pre_combined:
                val = self.read_responses(t, token=token)
                if val is not None:
                    idx = [
                        i for i, k in enumerate(val["kinds"]) if k == R_OVERFLOW
                    ]
                    if not idx:
                        continue
                    self.announce(
                        t,
                        [ann["keys"][i] for i in idx],
                        [ann["ops"][i] for i in idx],
                        [ann["params"][i] for i in idx],
                        token=token,
                    )
                    replayed.add(t)
                    continue
            keys, ops, params = _redo(ann, verdicts)
            self.announce(t, keys, ops, params, token=token)
            replayed.add(t)
        if replayed:
            self._drain()
        return sorted(replayed)

    # -------------------------------------------------------------- helpers
    def shard_contents(self, s: int) -> List[float]:
        """Committed contents of shard ``s`` (bottom-to-top / left-to-right)."""
        one = self._shard_state(s)
        if self.kinds[s] == "stack":
            top = int(one.active_size())
            return [float(v) for v in np.asarray(one.values[:top])]
        if self.kinds[s] == "map":
            occ = np.asarray(one.occupied)
            mk = np.asarray(one.keys)
            mv = np.asarray(one.values)
            return [
                (int(mk[i]), float(mv[i]))
                for i in range(occ.shape[0])
                if occ[i]
            ]
        cap = one.values.shape[0]
        e = one.active_ends()
        return [float(one.values[i % cap]) for i in range(int(e[0]), int(e[1]))]

    def shard_sizes(self) -> np.ndarray:
        """Committed sizes of every shard (for hot/cold reshard policies) —
        read from the active root counters, without materializing contents."""
        out = np.zeros((self.n_shards,), np.int64)
        for k, ids in _group_ids(tuple(self.kinds)).items():
            st = self.groups[k]
            rows = np.arange(len(ids))
            active = (np.asarray(st.epoch) // 2) % 2
            if k == "stack":
                sizes = np.asarray(st.size)[rows, active]
            elif k == "map":
                sizes = np.asarray(st.count)[rows, active]
            else:
                ends = np.asarray(st.ends)[rows, active]  # [Sg, 2]
                sizes = ends[:, 1] - ends[:, 0]
            out[np.asarray(ids)] = sizes
        return out
