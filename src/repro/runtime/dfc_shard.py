"""Sharded multi-object DFC runtime: one announcement fabric, many objects.

The paper's Figure-3 result is that flat combining amortizes the expensive
persistence instructions (pwb/pfence) across every op announced in a phase.
This runtime amortizes across *objects* too, the way a serving tier shards
traffic: ``n_shards`` homogeneous DFC structures (stack / queue / deque) live
behind ONE announcement fabric, a key->shard router buckets each announced
batch into per-shard op lists, and a single fused dispatch runs every
shard's combining phase at once (``vmap`` for the jnp backend, a Pallas grid
— one program instance per shard — for the kernel backends).

State layout (see ``repro.core.jax_dfc.init_sharded``): every leaf of the
structure state carries a leading shard axis, so the whole runtime is one
stacked pytree — ``values[S, cap]``, ``size[S, 2]`` / ``ends[S, 2, 2]``, and
crucially ``epoch[S]``: per-shard epochs.  Shards commit independently; a
combine phase only advances the epoch of shards that actually received ops,
so persistence work scales with touched shards, not with ``n_shards``.

Routing determinism: the shard of a key is a pure function of the key
(multiplicative hashing), and the lane of an op within its shard is its
*batch-order rank* among the ops routed there (an exclusive prefix sum over
the shard one-hot matrix).  Both are order-preserving and independent of
array layout or backend, so the routed per-shard op lists — and therefore
the combined linearization — are bit-identical across jnp / Pallas backends
and across host replays: the flat batch order IS the announcement order.
Overflowing ops (rank >= lanes) are cleanly rejected with ``R_OVERFLOW``
before touching any shard, so one hot shard can never corrupt a neighbor.

Persistence (``SimFS``-backed, pwb=write / pfence=fsync): per-thread
double-buffered announcements exactly like the paper's ``tAnn`` (ann{0,1} +
valid selector), per-shard double-buffered state slots selected by epoch
parity, and a per-shard TWO-INCREMENT epoch commit (persist v+1, publish
v+2 unsynced).  One phase orders its persistence as:

  1. pwb the new state of every TOUCHED shard into its inactive slot,
  2. pwb every combined announcement's responses (+ per-op shard targets),
  3. ONE pfence over all of it,
  4. per touched shard: pwb cEpoch=v+1, pfence, pwb cEpoch=v+2.

A crash anywhere leaves every shard either at its old committed state or its
new one; ``recover`` rebuilds all shards from their active slots and reports,
for every thread and every announced op, whether it took effect (its shard's
durable epoch reached the recorded target) — ops of shards that missed their
commit are reported not-applied and can be re-announced, giving exactly-once
semantics per op across the whole fabric.
"""

from __future__ import annotations

import dataclasses
import functools
import io
import json
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint.dfc_checkpoint import BOT, SimFS
from repro.core.jax_dfc import (
    OP_NONE,
    R_NONE,
    STRUCTS,
    init_sharded,
    shard_slice,
    stack_shards,
)
from repro.kernels.dfc_reduce.ops import SHARDED_COMBINE_STEPS

# runtime-level response kind: op rejected because its shard's announcement
# lanes were full this phase — never applied, safe to re-announce.
R_OVERFLOW = 4

_HASH_MULT = 2654435761  # Knuth multiplicative hashing constant


# ===================================================================== router
def shard_of_keys(keys, n_shards: int):
    """shard(key): multiplicative hash, identical on host and device."""
    k = jnp.asarray(keys).astype(jnp.uint32)
    h = k * jnp.uint32(_HASH_MULT)
    h = h ^ (h >> jnp.uint32(16))
    return (h % jnp.uint32(n_shards)).astype(jnp.int32)


def shard_of_keys_host(keys, n_shards: int) -> np.ndarray:
    """NumPy twin of ``shard_of_keys`` for oracles and drivers."""
    k = np.asarray(keys).astype(np.uint32)
    h = k * np.uint32(_HASH_MULT)
    h = h ^ (h >> np.uint32(16))
    return (h % np.uint32(n_shards)).astype(np.int32)


def zipf_keys(rng, n: int, universe: int, skew: float) -> np.ndarray:
    """Zipfian key draw over a finite universe (skew=0 -> uniform) — the
    serving-style workload used by the traffic driver and benchmarks."""
    ranks = np.arange(1, universe + 1, dtype=np.float64)
    p = ranks ** (-skew) if skew > 0 else np.ones(universe)
    p /= p.sum()
    return rng.choice(universe, size=n, p=p)


@functools.partial(jax.jit, static_argnames=("n_shards", "lanes"))
def route_batch(keys, ops, params, *, n_shards: int, lanes: int):
    """Bucket a flat announced batch into per-shard op lists.

    Returns ``(shard_ops i32[S, L], shard_params f32[S, L], shard i32[B],
    lane i32[B], ok bool[B], overflow bool[B])``.  Lane assignment is the
    op's batch-order rank among ops routed to its shard (stable: an exclusive
    segment prefix sum over the shard one-hot matrix), so per-shard op lists
    preserve announcement order deterministically.  Ops ranked past ``lanes``
    overflow: they are dropped before touching any per-shard list.  OP_NONE
    lanes are never routed.
    """
    b = ops.shape[0]
    shard = shard_of_keys(keys, n_shards)
    active = ops != OP_NONE
    s_eff = jnp.where(active, shard, n_shards)  # n_shards == routed nowhere

    # stable rank of op j within its shard: exclusive prefix sum per segment
    onehot = (s_eff[None, :] == jnp.arange(n_shards)[:, None]).astype(jnp.int32)
    rank_mat = jnp.cumsum(onehot, axis=1) - 1  # [S, B]
    lane = rank_mat[jnp.clip(s_eff, 0, n_shards - 1), jnp.arange(b)]

    ok = active & (lane < lanes)
    overflow = active & (lane >= lanes)

    # scatter into the per-shard announcement matrices; dest is injective
    # over ok lanes, so the scatter is order-independent (deterministic)
    dest = jnp.where(ok, s_eff * lanes + lane, n_shards * lanes)
    flat_ops = (
        jnp.full((n_shards * lanes,), OP_NONE, jnp.int32)
        .at[dest]
        .set(ops.astype(jnp.int32), mode="drop")
    )
    flat_params = (
        jnp.zeros((n_shards * lanes,), jnp.float32)
        .at[dest]
        .set(params.astype(jnp.float32), mode="drop")
    )
    return (
        flat_ops.reshape(n_shards, lanes),
        flat_params.reshape(n_shards, lanes),
        shard,
        lane,
        ok,
        overflow,
    )


# ============================================================ fused step (jit)
def _vmap_combine(kind: str):
    return jax.vmap(STRUCTS[kind].combine)


@functools.partial(
    jax.jit, static_argnames=("kind", "n_shards", "lanes", "backend")
)
def sharded_step(
    state, keys, ops, params, meta, *, kind: str, n_shards: int, lanes: int,
    backend: str = "jnp",
):
    """One fused end-to-end phase: route -> all-shard combine -> epoch publish.

    ``meta`` is the per-shard combiner metadata ``{"phases": i32[S],
    "ops_combined": i32[S]}``; untouched shards keep their old state (and old
    epoch — no phantom phases), touched shards publish with a +2 epoch bump.
    Returns ``(new_state, new_meta, responses f32[B], kinds i32[B])`` where
    ``kinds`` uses the combine-level codes plus ``R_OVERFLOW``.
    """
    shard_ops, shard_params, shard, lane, ok, overflow = route_batch(
        keys, ops, params, n_shards=n_shards, lanes=lanes
    )

    if backend == "jnp":
        combined, s_resp, s_kinds = _vmap_combine(kind)(state, shard_ops, shard_params)
    else:
        combined, s_resp, s_kinds = SHARDED_COMBINE_STEPS[kind](
            state, shard_ops, shard_params, backend=backend
        )

    # only shards that received ops publish; the rest keep state AND epoch
    touched = jnp.any(shard_ops != OP_NONE, axis=1)  # bool[S]

    def _select(new_leaf, old_leaf):
        t = touched.reshape((n_shards,) + (1,) * (new_leaf.ndim - 1))
        return jnp.where(t, new_leaf, old_leaf)

    new_state = jax.tree_util.tree_map(_select, combined, state)
    new_meta = {
        "phases": meta["phases"] + touched.astype(jnp.int32),
        "ops_combined": meta["ops_combined"]
        + jnp.sum(
            (shard_ops != OP_NONE).astype(jnp.int32), axis=1
        ),
    }

    # gather responses back to flat batch order
    s = jnp.clip(shard, 0, n_shards - 1)
    ln = jnp.clip(lane, 0, lanes - 1)
    responses = jnp.where(ok, s_resp[s, ln], 0.0)
    kinds = jnp.where(ok, s_kinds[s, ln], R_NONE)
    kinds = jnp.where(overflow, R_OVERFLOW, kinds)
    return new_state, new_meta, responses, kinds


# ============================================================== host oracle
def sequential_sharded_reference(kind, shard_lists, keys, ops, params, lanes):
    """Pure-Python witness of one sharded phase (test/bench oracle).

    ``shard_lists`` is a list of per-shard Python structures; mutated in
    place.  Returns (responses, kinds) in flat batch order, with overflow ops
    reported as ``R_OVERFLOW`` and untouched.
    """
    n_shards = len(shard_lists)
    ref = STRUCTS[kind].reference
    shard = shard_of_keys_host(keys, n_shards)
    b = len(ops)
    responses = [0.0] * b
    kinds = [R_NONE] * b
    buckets: Dict[int, List[int]] = {}
    for j in range(b):
        if ops[j] == OP_NONE:
            continue
        s = int(shard[j])
        rank = len(buckets.setdefault(s, []))
        if rank >= lanes:
            kinds[j] = R_OVERFLOW
            continue
        buckets[s].append(j)
    for s, idxs in sorted(buckets.items()):
        s_ops = [ops[j] for j in idxs]
        s_par = [params[j] for j in idxs]
        shard_lists[s], s_resp, s_kinds = ref(shard_lists[s], s_ops, s_par)
        for r, (v, k) in zip(idxs, zip(s_resp, s_kinds)):
            responses[r] = v
            kinds[r] = k
    return responses, kinds


# ================================================================== runtime
def _init_meta(n_shards: int):
    return {
        "phases": jnp.zeros((n_shards,), jnp.int32),
        "ops_combined": jnp.zeros((n_shards,), jnp.int32),
    }


@dataclasses.dataclass
class OpVerdict:
    """Per-op detectability verdict reported by recovery."""

    applied: bool
    kind: Optional[int] = None
    resp: Optional[float] = None
    shard: Optional[int] = None


class ShardedDFCRuntime:
    """Many persistent DFC objects behind one announcement fabric.

    Volatile fast path: ``step(keys, ops, params)`` — one jitted dispatch.
    Durable path: threads ``announce`` batches; ``combine_phase`` combines
    every ready announcement across all shards and commits per-shard;
    ``recover`` rebuilds the fabric after a crash and reports per-thread,
    per-op detectability verdicts.

    Contract (inherited from the combine layer): per shard,
    ``capacity >= committed size + lanes``.
    """

    def __init__(
        self,
        kind: str,
        n_shards: int,
        capacity: int,
        lanes: int,
        *,
        backend: str = "jnp",
        fs: Optional[SimFS] = None,
        n_threads: int = 1,
        state=None,
        meta=None,
    ):
        if kind not in STRUCTS:
            raise ValueError(f"unknown structure kind {kind!r}")
        if lanes > capacity:
            raise ValueError("lanes must be <= per-shard capacity")
        self.kind = kind
        self.n_shards = n_shards
        self.capacity = capacity
        self.lanes = lanes
        self.backend = backend
        self.fs = fs
        self.n_threads = n_threads
        self.state = init_sharded(kind, n_shards, capacity) if state is None else state
        self.meta = _init_meta(n_shards) if meta is None else meta

    # ------------------------------------------------------------- routing
    def route(self, keys, ops, params):
        return route_batch(
            jnp.asarray(keys),
            jnp.asarray(ops, jnp.int32),
            jnp.asarray(params, jnp.float32),
            n_shards=self.n_shards,
            lanes=self.lanes,
        )

    # ------------------------------------------------------- volatile path
    def step(self, keys, ops, params):
        """One fused phase over a flat batch; returns (responses, kinds)."""
        self.state, self.meta, resp, kinds = sharded_step(
            self.state,
            jnp.asarray(keys),
            jnp.asarray(ops, jnp.int32),
            jnp.asarray(params, jnp.float32),
            self.meta,
            kind=self.kind,
            n_shards=self.n_shards,
            lanes=self.lanes,
            backend=self.backend,
        )
        return resp, kinds

    # -------------------------------------------------------- announcements
    def _ann_path(self, t: int, slot: int) -> str:
        return f"tAnn/thread_{t}/ann{slot}.json"

    def _valid_path(self, t: int) -> str:
        return f"tAnn/thread_{t}/valid"

    def _read_valid(self, t: int) -> int:
        raw = self.fs.read(self._valid_path(t))
        return int(raw.decode()) if raw else 0

    def _read_ann(self, t: int, slot: int) -> Dict[str, Any]:
        raw = self.fs.read(self._ann_path(t, slot))
        return json.loads(raw.decode()) if raw else {"val": BOT, "token": -1}

    def announce(self, thread: int, keys, ops, params, token: int) -> None:
        """Thread-side announcement (paper lines 2-12): double-buffered
        record + valid selector, parallel pwb/pfence, MSB publish."""
        valid = self._read_valid(thread)
        n_op = 1 - (valid & 1)
        ann = {
            "token": token,
            "keys": [int(k) for k in np.asarray(keys)],
            "ops": [int(o) for o in np.asarray(ops)],
            "params": [float(p) for p in np.asarray(params)],
            "val": BOT,
        }
        self.fs.write(self._ann_path(thread, n_op), json.dumps(ann).encode())
        self.fs.fsync([self._ann_path(thread, n_op)])
        self.fs.write(self._valid_path(thread), str(n_op).encode())
        self.fs.fsync([self._valid_path(thread)])
        self.fs.write(self._valid_path(thread), str(2 | n_op).encode())  # MSB

    def ready_announcements(self) -> List[int]:
        out = []
        for t in range(self.n_threads):
            v = self._read_valid(t)
            if (v >> 1) & 1:
                ann = self._read_ann(t, v & 1)
                if ann.get("val") is BOT and ann.get("token", -1) >= 0:
                    out.append(t)
        return out

    # ------------------------------------------------------ durable layout
    def _epoch_path(self, s: int) -> str:
        return f"shard_{s}/cEpoch"

    def _slot_dir(self, s: int, epoch: int, nxt: bool) -> str:
        return f"shard_{s}/slot{(epoch // 2 + (1 if nxt else 0)) % 2}"

    def _read_shard_epoch(self, s: int) -> int:
        raw = self.fs.read(self._epoch_path(s))
        return int(raw.decode()) if raw else 0

    def _persist_shard(self, s: int, epoch_target: int) -> List[str]:
        """pwb shard ``s``'s post-combine state into its inactive slot."""
        one = shard_slice(self.state, s)
        slot = self._slot_dir(s, epoch_target - 2, nxt=True)
        leaves, _ = jax.tree_util.tree_flatten(one)
        files = []
        meta = {
            "kind": self.kind,
            "epoch": epoch_target,
            "leaves": [],
            "phases": int(self.meta["phases"][s]),
            "ops_combined": int(self.meta["ops_combined"][s]),
        }
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            buf = io.BytesIO()
            np.save(buf, arr)
            rel = f"{slot}/leaf_{i}.npy"
            self.fs.write(rel, buf.getvalue())
            files.append(rel)
            meta["leaves"].append(
                {"file": f"leaf_{i}.npy", "shape": list(arr.shape), "dtype": str(arr.dtype)}
            )
        rel = f"{slot}/meta.json"
        self.fs.write(rel, json.dumps(meta).encode())
        files.append(rel)
        return files

    # --------------------------------------------------------- combine phase
    def combine_phase(self) -> List[int]:
        """One durable combining phase over every ready announcement.

        Concatenates the announced batches (announcement order = thread id
        order — the combiner's walk over the announcement array), runs the
        fused device step, persists every touched shard into its inactive
        slot, writes responses + per-op commit targets into the combined
        announcements, pfences ONCE, then commits each touched shard's epoch
        with the two-increment protocol.  Returns the combined thread ids.
        """
        assert self.fs is not None, "combine_phase needs a SimFS"
        ready = self.ready_announcements()
        if not ready:
            return []
        anns = {t: self._read_ann(t, self._read_valid(t) & 1) for t in ready}
        keys = np.concatenate([np.asarray(anns[t]["keys"], np.int64) for t in ready])
        ops = np.concatenate([np.asarray(anns[t]["ops"], np.int32) for t in ready])
        params = np.concatenate(
            [np.asarray(anns[t]["params"], np.float32) for t in ready]
        )

        epochs_before = np.asarray(self.state.epoch)
        resp, kinds = self.step(keys, ops, params)
        resp = np.asarray(resp)
        kinds = np.asarray(kinds)
        epochs_after = np.asarray(self.state.epoch)
        touched = [int(s) for s in np.nonzero(epochs_after != epochs_before)[0]]
        shard = shard_of_keys_host(keys, self.n_shards)
        targets = epochs_after[shard]  # per-op commit target (its shard)

        files: List[str] = []
        for s in touched:
            files += self._persist_shard(s, int(epochs_after[s]))

        # responses + per-op (shard, target) into the combined announcements
        off = 0
        for t in ready:
            n_t = len(anns[t]["ops"])
            sl = slice(off, off + n_t)
            anns[t]["val"] = {
                "resp": [float(v) for v in resp[sl]],
                "kinds": [int(k) for k in kinds[sl]],
                "shards": [int(s) for s in shard[sl]],
                "targets": [int(e) for e in targets[sl]],
            }
            rel = self._ann_path(t, self._read_valid(t) & 1)
            self.fs.write(rel, json.dumps(anns[t]).encode())
            files.append(rel)
            off += n_t

        self.fs.fsync(files)  # ONE pfence for slots + responses
        for s in touched:  # per-shard two-increment epoch commit
            e = int(epochs_after[s])
            self.fs.write(self._epoch_path(s), str(e - 1).encode())
            self.fs.fsync([self._epoch_path(s)])
            self.fs.write(self._epoch_path(s), str(e).encode())
        return ready

    def read_responses(self, thread: int) -> Optional[Dict[str, Any]]:
        """A thread's combined announcement, or None while still pending.

        Returns ``{"token", "resp", "kinds", "shards", "targets"}`` — the
        durable response record written by the last combine_phase that
        included this thread's announcement.
        """
        ann = self._read_ann(thread, self._read_valid(thread) & 1)
        if ann.get("val") is BOT:
            return None
        return dict(ann["val"], token=ann["token"])

    # -------------------------------------------------------------- recover
    @classmethod
    def recover(
        cls,
        fs: SimFS,
        *,
        kind: str,
        n_shards: int,
        capacity: int,
        lanes: int,
        backend: str = "jnp",
        n_threads: int = 1,
    ) -> Tuple["ShardedDFCRuntime", Dict[int, Dict[str, Any]]]:
        """Recover every shard + per-thread/per-op detectability report.

        Per shard: round an odd durable epoch up to even (finish the
        interrupted second increment), garbage-collect the inactive slot,
        and reload the active slot (or a fresh init when the shard never
        committed).  Per announced op: applied iff its shard's committed
        epoch reached the target recorded with the response; everything else
        is reported not-applied and is safe to re-announce.
        """
        rt = cls(
            kind, n_shards, capacity, lanes,
            backend=backend, fs=fs, n_threads=n_threads,
        )
        shard_states = []
        phases = np.zeros((n_shards,), np.int32)
        ops_combined = np.zeros((n_shards,), np.int32)
        committed_epochs = np.zeros((n_shards,), np.int64)
        fresh = STRUCTS[kind].init(capacity)
        for s in range(n_shards):
            epoch = rt._read_shard_epoch(s)
            if epoch % 2 == 1:  # crashed between the two increments
                epoch += 1
                fs.write(rt._epoch_path(s), str(epoch).encode())
                fs.fsync([rt._epoch_path(s)])
            committed_epochs[s] = epoch
            active = rt._slot_dir(s, epoch, nxt=False)
            inactive = rt._slot_dir(s, epoch, nxt=True)
            meta_raw = fs.read_durable(f"{active}/meta.json")
            live = {f"{active}/meta.json"}
            if meta_raw:
                meta = json.loads(meta_raw.decode())
                live |= {f"{active}/{e['file']}" for e in meta["leaves"]}
                leaves = [
                    np.load(io.BytesIO(fs.read_durable(f"{active}/{e['file']}")))
                    for e in meta["leaves"]
                ]
                treedef = jax.tree_util.tree_structure(fresh)
                shard_states.append(
                    jax.tree_util.tree_unflatten(
                        treedef, [jnp.asarray(leaf) for leaf in leaves]
                    )
                )
                phases[s] = meta.get("phases", 0)
                ops_combined[s] = meta.get("ops_combined", 0)
            else:
                shard_states.append(fresh)
            # GC: drop partial writes of the interrupted phase
            for rel in list(fs.listdir(active)) + list(fs.listdir(inactive)):
                if rel not in live:
                    fs.delete(rel)

        rt.state = stack_shards(shard_states)
        rt.meta = {
            "phases": jnp.asarray(phases),
            "ops_combined": jnp.asarray(ops_combined),
        }

        report: Dict[int, Dict[str, Any]] = {}
        for t in range(n_threads):
            v = rt._read_valid(t)
            lsb = v & 1
            if (v >> 1) & 1 == 0:  # re-publish a half-written valid selector
                fs.write(rt._valid_path(t), str(2 | lsb).encode())
            ann = rt._read_ann(t, lsb)
            if ann.get("token", -1) < 0:
                report[t] = {"token": None, "ops": []}
                continue
            verdicts: List[OpVerdict] = []
            val = ann.get("val")
            n_ops = len(ann.get("ops", []))
            if val is BOT:
                verdicts = [OpVerdict(applied=False) for _ in range(n_ops)]
            else:
                for i in range(n_ops):
                    s = val["shards"][i]
                    k = val["kinds"][i]
                    committed = committed_epochs[s] >= val["targets"][i]
                    applied = bool(committed) and k != R_OVERFLOW and k != R_NONE
                    verdicts.append(
                        OpVerdict(
                            applied=applied,
                            kind=k if committed else None,
                            resp=val["resp"][i] if committed else None,
                            shard=s,
                        )
                    )
            report[t] = {"token": ann["token"], "ops": verdicts}
        return rt, report

    # -------------------------------------------------------------- helpers
    def shard_contents(self, s: int) -> List[float]:
        """Committed contents of shard ``s`` (bottom-to-top / left-to-right)."""
        one = shard_slice(self.state, s)
        if self.kind == "stack":
            top = int(one.active_size())
            return [float(v) for v in np.asarray(one.values[:top])]
        cap = one.values.shape[0]
        e = one.active_ends()
        return [float(one.values[i % cap]) for i in range(int(e[0]), int(e[1]))]
