from repro.runtime.announce_driver import MultiThreadDriver
from repro.runtime.dfc_shard import (
    R_OVERFLOW,
    OpVerdict,
    ShardedDFCRuntime,
    StaleTokenError,
    hetero_multi_step,
    hetero_step,
    route_batch,
    route_keys_host,
    sequential_hetero_reference,
    sequential_sharded_reference,
    shard_of_keys,
    shard_of_keys_host,
    sharded_step,
    zipf_keys,
)
from repro.runtime.train_loop import TrainRuntime

__all__ = [
    "MultiThreadDriver",
    "R_OVERFLOW",
    "OpVerdict",
    "ShardedDFCRuntime",
    "StaleTokenError",
    "TrainRuntime",
    "hetero_multi_step",
    "hetero_step",
    "route_batch",
    "route_keys_host",
    "sequential_hetero_reference",
    "sequential_sharded_reference",
    "shard_of_keys",
    "shard_of_keys_host",
    "sharded_step",
    "zipf_keys",
]
