from repro.runtime.train_loop import TrainRuntime

__all__ = ["TrainRuntime"]
