from repro.runtime.dfc_shard import (
    R_OVERFLOW,
    OpVerdict,
    ShardedDFCRuntime,
    route_batch,
    sequential_sharded_reference,
    shard_of_keys,
    shard_of_keys_host,
    sharded_step,
    zipf_keys,
)
from repro.runtime.train_loop import TrainRuntime

__all__ = [
    "R_OVERFLOW",
    "OpVerdict",
    "ShardedDFCRuntime",
    "TrainRuntime",
    "route_batch",
    "sequential_sharded_reference",
    "shard_of_keys",
    "shard_of_keys_host",
    "sharded_step",
    "zipf_keys",
]
