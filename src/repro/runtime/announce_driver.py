"""Multi-thread announcing driver for the sharded DFC fabric.

The paper's Figure-3 claim is about MANY threads announcing concurrently:
the combiner amortizes its pwb/pfence cost over every announcement it sweeps
up in one phase.  Until ISSUE 5 the repo drove every durable fabric from a
single announcer (``n_threads=1`` everywhere but the crash harnesses), so
neither the concurrency axis nor its interaction with pipeline depth was
exercised.  This module closes that gap with a SIMULATED-CONCURRENCY driver:

  * ``n_threads`` announcers each hold a FIFO of submitted batches and
    per-thread MONOTONE tokens (the recovery protocol's ordering contract);
  * a seeded scheduler interleaves two kinds of atomic actions — thread t
    announces its next batch (landing the payload on the fabric's
    ``AnnounceRing``), or the combiner runs one ``combine_phase`` — chosen
    uniformly at random among the actions that are currently legal;
  * the same seed + the same submissions replay the SAME interleaving
    op-for-op (the rng only ever chooses among a deterministically ordered
    action list), which is what lets crash tests sweep a fault injector
    through a genuinely concurrent schedule and re-run it exactly.

Legality mirrors the paper's thread model: a thread blocks until the
combiner has taken (dispatched) its current announcement before publishing
the next one, so at most one READY batch per thread exists at a time; the
pipelined runtime may additionally hold its previous batch un-retired in
flight (the double-buffered records bound a thread to two outstanding
batches — see ``ShardedDFCRuntime.announce``).

The driver records a ``dispatch_order`` — one tuple of (thread, token)
pairs per CHAINED BATCH, in the exact order the combiner dispatched them —
which IS the fabric's linearization witness: announcements grouped into the
same batch combine as ONE phase (their lanes concatenate in segment order),
so applying ``sequential_hetero_reference`` group-by-group in that order
reproduces every durable response and the final contents, on every combine
backend (see ``tests/test_pipeline_fuzz.py``).
"""

from __future__ import annotations

from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.runtime.dfc_shard import ShardedDFCRuntime


class MultiThreadDriver:
    """Seeded interleaver of ``n_threads`` announcers over one fabric.

    ``rt`` must be a durable ``ShardedDFCRuntime`` (``fs`` set).  Typical
    use::

        drv = MultiThreadDriver(rt, seed=7)
        for t in range(rt.n_threads):
            drv.submit(t, keys, ops, params)      # token assigned, FIFO
        drv.run()                                 # announce/combine/flush
        drv.responses(t, token)                   # durable responses

    After a crash, build a fresh driver on the recovered runtime with
    ``start_tokens`` so per-thread tokens continue monotonically::

        rt2, report = ShardedDFCRuntime.recover(...)
        drv2 = MultiThreadDriver(rt2, seed=seed, start_tokens=drv.tokens)
    """

    def __init__(
        self,
        rt: ShardedDFCRuntime,
        *,
        seed: int = 0,
        start_tokens: Optional[Dict[int, int]] = None,
    ):
        if rt.fs is None:
            raise ValueError("MultiThreadDriver needs a durable runtime (fs)")
        self.rt = rt
        self.n_threads = rt.n_threads
        self.rng = np.random.default_rng(seed)
        self.pending: Dict[int, deque] = {
            t: deque() for t in range(self.n_threads)
        }
        # per-thread monotone token counters (last token ASSIGNED)
        self.tokens: Dict[int, int] = {
            t: int((start_tokens or {}).get(t, 0)) for t in range(self.n_threads)
        }
        # token -> (keys, ops, params) per thread, for oracles and replay
        self.history: Dict[int, Dict[int, Tuple[list, list, list]]] = {
            t: {} for t in range(self.n_threads)
        }
        self.trace: List[Tuple[Any, ...]] = []
        # one tuple of (thread, token) pairs per chained batch, dispatch order
        self.dispatch_order: List[Tuple[Tuple[int, int], ...]] = []
        # announced-but-undispatched batches (thread -> token), maintained by
        # the driver so legality checks stay O(1) per step instead of
        # re-reading every thread's durable announcement record; seeded once
        # from the runtime for batches announced before this driver existed
        # (e.g. re-registered by recovery)
        self._ready: Dict[int, int] = {
            t: rec["token"] for t, rec in rt._collect_ready()
        }

    # ------------------------------------------------------------ submission
    def submit(self, thread: int, keys, ops, params) -> int:
        """Queue one batch on ``thread``; returns its (monotone) token."""
        self.tokens[thread] += 1
        token = self.tokens[thread]
        rec = (
            [int(k) for k in np.asarray(keys)],
            [int(o) for o in np.asarray(ops)],
            [float(p) for p in np.asarray(params)],
        )
        self.pending[thread].append((token,) + rec)
        self.history[thread][token] = rec
        return token

    # ------------------------------------------------------------- scheduling
    def _actions(self) -> List[Tuple[Any, ...]]:
        """Legal atomic actions, deterministically ordered."""
        acts: List[Tuple[Any, ...]] = [
            ("announce", t)
            for t in range(self.n_threads)
            if self.pending[t] and t not in self._ready
        ]
        if self._ready or self.rt._inflight:
            acts.append(("combine",))
        return acts

    def step(self) -> Optional[Tuple[Any, ...]]:
        """Execute one scheduler-chosen action; None when fully drained.

        A crash scheduled by the runtime's fault injector propagates out of
        here (``CrashNow``) exactly as it would out of a direct
        announce/combine call.
        """
        acts = self._actions()
        if not acts:
            return None
        act = acts[int(self.rng.integers(len(acts)))]
        obs = self.rt.obs
        if act[0] == "announce":
            t = act[1]
            token, keys, ops, params = self.pending[t][0]
            if obs.enabled:  # interleaving trace: the scheduler's pick,
                obs.event(  # recorded BEFORE the action so a crash inside
                    "sched",  # it still shows what was being attempted
                    action="announce",
                    thread=t,
                    token=token,
                    choices=len(acts),
                )
            # announce may force-retire in-flight chains (slot reclaim, depth
            # > 2); pop the batch only after it lands so a crash inside the
            # announce leaves it resubmittable
            self.rt.announce(t, keys, ops, params, token=token)
            self.pending[t].popleft()
            self._ready[t] = token
            self.trace.append(("announce", t, token))
        else:
            if obs.enabled:
                obs.event(
                    "sched",
                    action="combine",
                    ready=sorted(self._ready),
                    choices=len(acts),
                )
            self.rt.last_dispatch = []
            self.rt.combine_phase()
            groups = [tuple(g) for g in self.rt.last_dispatch]
            for g in groups:
                for t, _ in g:
                    self._ready.pop(t, None)
            self.dispatch_order.extend(groups)
            self.trace.append(("combine", tuple(groups)))
        return act

    def run(self, max_steps: int = 100_000) -> List[Tuple[Any, ...]]:
        """Drive the schedule to quiescence: every submitted batch announced,
        combined, and retired (``combine_phase`` with nothing ready flushes
        the pipeline).  Returns the executed action trace."""
        for _ in range(max_steps):
            if self.step() is None:
                self.rt.flush()
                return self.trace
        raise RuntimeError("driver failed to drain (livelocked schedule?)")

    # -------------------------------------------------------------- readback
    def responses(self, thread: int, token: int):
        """Durable responses of (thread, token) — ``read_responses`` sugar
        that also raises ``StaleTokenError`` for overwritten records."""
        return self.rt.read_responses(thread, token=token)

    def unsurfaced(self, report: Dict[int, Dict[str, Any]]) -> List[Tuple[int, int]]:
        """(thread, token) pairs this driver submitted that a recovery
        report does not account for — batches the crashed run never
        announced (or whose announce never published).  Re-drive them, in
        token order per thread, to complete the schedule after
        ``replay_pending``."""
        out = []
        for t in range(self.n_threads):
            r = report.get(t) or {"token": None}
            surfaced = r["token"] or 0
            for token in sorted(self.history[t]):
                if token > surfaced:
                    out.append((t, token))
        return out
