"""Persistent node pool with a volatile bitmap-tree allocator (paper §4).

All nodes live in a pre-allocated NVM region.  Which nodes are free/used is
tracked *only in volatile memory* by a two-level bitmap: 64 leaf words of 64
bits each (4096 nodes per level-1 group, extended with more groups as needed)
plus a root word marking which leaf words still have free bits.  On recovery,
a garbage-collection cycle rebuilds the bitmap by marking every node reachable
from the active ``top`` entry as used and everything else as free — so the
allocator metadata never needs persistence instructions (the paper's
"lightweight in normal operation, more expensive recovery" trade-off).

A node occupies one cache line holding (param, next).
"""

from __future__ import annotations

from typing import Hashable, Iterable, List, Optional

from repro.nvm.memory import BOT, NVMemory

WORD_BITS = 64
NIL = -1  # encoding of a ⊥ next-pointer / empty top


class BitmapTree:
    """Volatile two-level (root + leaves) free-list bitmap. Bit set = used."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        n_words = (capacity + WORD_BITS - 1) // WORD_BITS
        self.leaves: List[int] = [0] * n_words
        # root bit i set  <=>  leaf word i is completely full
        self.root = 0
        # mark the padding tail of the last word as used so it is never handed out
        tail = n_words * WORD_BITS - capacity
        if tail:
            self.leaves[-1] = ((1 << tail) - 1) << (WORD_BITS - tail)

    def alloc(self) -> int:
        for w, word in enumerate(self.leaves):
            if not (self.root >> w) & 1:
                free = ~word & ((1 << WORD_BITS) - 1)
                b = (free & -free).bit_length() - 1
                self.leaves[w] = word | (1 << b)
                if self.leaves[w] == (1 << WORD_BITS) - 1:
                    self.root |= 1 << w
                idx = w * WORD_BITS + b
                if idx >= self.capacity:
                    raise MemoryError("node pool exhausted")
                return idx
        raise MemoryError("node pool exhausted")

    def free(self, idx: int) -> None:
        w, b = divmod(idx, WORD_BITS)
        self.leaves[w] &= ~(1 << b)
        self.root &= ~(1 << w)

    def is_used(self, idx: int) -> bool:
        w, b = divmod(idx, WORD_BITS)
        return bool((self.leaves[w] >> b) & 1)

    def clear(self) -> None:
        self.__init__(self.capacity)

    def used_count(self) -> int:
        full = sum(bin(w).count("1") for w in self.leaves)
        tail = len(self.leaves) * WORD_BITS - self.capacity
        return full - tail


class NodePool:
    """NVM-resident node pool managed by a volatile :class:`BitmapTree`.

    ``extra_fields`` adds named pointer fields beyond ``next`` to every node
    line (the deque's doubly-linked nodes carry a ``prev``); a node still
    occupies a single cache line, so one pwb persists all of its fields.
    """

    def __init__(
        self,
        mem: NVMemory,
        capacity: int = 4096,
        name: str = "pool",
        extra_fields: tuple = (),
    ):
        self.mem = mem
        self.capacity = capacity
        self.name = name
        self.extra_fields = tuple(extra_fields)
        self.bitmap = BitmapTree(capacity)
        extras = {f: NIL for f in self.extra_fields}
        for i in range(capacity):
            mem.alloc_line(self._line(i), param=BOT, next=NIL, **extras)

    def _line(self, idx: int) -> Hashable:
        return (self.name, idx)

    # ------------------------------------------------------------ allocation
    def allocate(self, param, nxt: int, **extras) -> int:
        """AllocateNode(param, head): volatile bitmap claim + node field writes.

        The *caller* is responsible for pwb'ing the node line (paper line 62).
        """
        idx = self.bitmap.alloc()
        self.mem.write(self._line(idx), "param", param)
        self.mem.write(self._line(idx), "next", nxt)
        for f, v in extras.items():
            self.mem.write(self._line(idx), f, v)
        return idx

    def deallocate(self, idx: int) -> None:
        """DeallocateNode: volatile-only bit reset — no persistence needed."""
        self.bitmap.free(idx)

    # --------------------------------------------------------------- access
    def param(self, idx: int):
        return self.mem.read(self._line(idx), "param")

    def next(self, idx: int) -> int:
        return self.mem.read(self._line(idx), "next")

    def get(self, idx: int, field: str):
        return self.mem.read(self._line(idx), field)

    def set(self, idx: int, field: str, value) -> None:
        self.mem.write(self._line(idx), field, value)

    def line_of(self, idx: int) -> Hashable:
        return self._line(idx)

    # ------------------------------------------------------------------- GC
    def garbage_collect(self, roots: Iterable[int], stops: Iterable[int] = ()) -> int:
        """Recovery GC cycle (paper §4): rebuild the volatile bitmap by
        marking the nodes reachable from ``roots`` (the active top) used and
        everything else free.  Runs single-threaded under the recovery lock.

        ``stops`` bounds each walk: a node in ``stops`` is marked live but its
        ``next`` is not followed.  The queue/deque need this — the committed
        tail's ``next`` may hold a dangling link written by a combine phase
        that never published.

        Returns the number of live nodes."""
        self.bitmap.clear()
        stop_set = set(stops)
        live = 0
        for root in roots:
            idx = root
            while idx != NIL and idx is not BOT:
                if self.bitmap.is_used(idx):  # shared tail already marked
                    break
                w, b = divmod(idx, WORD_BITS)
                self.bitmap.leaves[w] |= 1 << b
                if self.bitmap.leaves[w] == (1 << WORD_BITS) - 1:
                    self.bitmap.root |= 1 << w
                live += 1
                if idx in stop_set:
                    break
                idx = self.next(idx)
        return live

    def walk(self, head: int, stop: Optional[int] = None) -> List:
        """Return [param, ...] from head following ``next`` (test helper).

        ``stop`` (inclusive) bounds the walk the same way GC ``stops`` do —
        required when walking a queue/deque whose committed tail may carry a
        stale ``next``."""
        out = []
        idx = head
        while idx != NIL and idx is not BOT:
            out.append(self.param(idx))
            if stop is not None and idx == stop:
                break
            idx = self.next(idx)
        return out
