from repro.nvm.memory import NVMemory, PersistStats, CrashMode
from repro.nvm.pool import NodePool

__all__ = ["NVMemory", "PersistStats", "CrashMode", "NodePool"]
