"""Simulated byte-addressable non-volatile memory (NVM).

Implements the *explicit epoch persistency* model of Izraelevitz et al. [18]
assumed by the paper (Section 2):

  * Shared memory is split into non-volatile lines (NVM) and volatile state.
  * Program reads/writes hit the (volatile) cache.  A write reaches the
    persistence domain only via an explicit ``pwb`` (persistent write-back)
    followed by a ``pfence`` — or nondeterministically, when the cache line is
    evicted.
  * ``pwb`` ordering is NOT preserved across lines; a ``pfence`` orders and
    completes all preceding ``pwb`` s *of the issuing thread* (the paper notes
    that on x86 a pfence acts as pfence+psync, and we follow its convention of
    a combined pfence/psync).
  * Per-line, write-backs respect program order: the persisted value of a line
    is always some prefix-point of its write history.

A crash resets all volatile state and, for every line, picks a persisted
snapshot at least as new as the last fenced write-back and no newer than the
last write (arbitrary eviction).  ``CrashMode`` selects adversarial extremes
or randomized choice.

The simulator also keeps the persistence-instruction counters (pwb/pfence,
attributed to a *tag* such as ``announce`` vs ``combine``) that drive the
paper's Figures 3b/3c/3e/3f.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, Hashable, List, Optional, Tuple

BOT = None  # the paper's ⊥


class CrashMode(enum.Enum):
    """How eagerly dirty lines are persisted at a crash."""

    MIN = "min"  # only fenced write-backs survive (most forgetful)
    MAX = "max"  # every write survives (most eager eviction)
    RANDOM = "random"  # uniformly random prefix-point per line, >= fenced


#: Tag bucket for persistence ops issued without an attribution tag, so the
#: tag dicts always partition the totals (nothing is silently untagged).
DEFAULT_TAG = "untagged"


@dataclasses.dataclass
class PersistStats:
    """pwb/pfence counters, attributed by tag.

    ``snapshot()``/``diff()`` give benchmarks and tests a windowed view
    (counts since a mark) without hand-rolled total arithmetic; untagged
    ops land in the :data:`DEFAULT_TAG` bucket.
    """

    pwb: Dict[str, int] = dataclasses.field(default_factory=dict)
    pfence: Dict[str, int] = dataclasses.field(default_factory=dict)

    def count_pwb(self, tag: Optional[str] = None) -> None:
        tag = tag or DEFAULT_TAG
        self.pwb[tag] = self.pwb.get(tag, 0) + 1

    def count_pfence(self, tag: Optional[str] = None) -> None:
        tag = tag or DEFAULT_TAG
        self.pfence[tag] = self.pfence.get(tag, 0) + 1

    def total_pwb(self) -> int:
        return sum(self.pwb.values())

    def total_pfence(self) -> int:
        return sum(self.pfence.values())

    def snapshot(self) -> "PersistStats":
        """An immutable-by-convention copy of the current counters."""
        return PersistStats(pwb=dict(self.pwb), pfence=dict(self.pfence))

    def diff(self, since: "PersistStats") -> "PersistStats":
        """Counters accumulated since ``since`` (an earlier snapshot):
        per-tag subtraction, tags absent then treated as zero."""
        return PersistStats(
            pwb={
                t: n - since.pwb.get(t, 0)
                for t, n in self.pwb.items()
                if n != since.pwb.get(t, 0)
            },
            pfence={
                t: n - since.pfence.get(t, 0)
                for t, n in self.pfence.items()
                if n != since.pfence.get(t, 0)
            },
        )

    def as_dict(self) -> Dict[str, Dict[str, int]]:
        """JSON-ready view (for BENCH rows and metrics snapshots)."""
        return {"pwb": dict(self.pwb), "pfence": dict(self.pfence)}

    def clear(self) -> None:
        self.pwb.clear()
        self.pfence.clear()


class _Line:
    """One 64-byte cache line holding a dict of named fields.

    ``committed`` is the state in the persistence domain.  ``history`` holds a
    snapshot of the line after every volatile write since the last crash (or
    line creation); ``fenced`` is the history index guaranteed persisted.
    """

    __slots__ = ("committed", "history", "fenced", "cache")

    def __init__(self, init: Dict[str, Any]):
        self.committed: Dict[str, Any] = dict(init)
        self.cache: Dict[str, Any] = dict(init)
        self.history: List[Dict[str, Any]] = []
        self.fenced: int = 0


class NVMemory:
    """A collection of named NVM cache lines + persistence instructions."""

    def __init__(self, seed: int = 0):
        self._lines: Dict[Hashable, _Line] = {}
        # per-thread pending pwbs: tid -> list of (line_id, history_index)
        self._pending: Dict[Hashable, List[Tuple[Hashable, int]]] = {}
        self.stats = PersistStats()

    # ------------------------------------------------------------------ setup
    def alloc_line(self, line_id: Hashable, **fields: Any) -> None:
        if line_id in self._lines:
            raise ValueError(f"line {line_id!r} already allocated")
        self._lines[line_id] = _Line(fields)

    def has_line(self, line_id: Hashable) -> bool:
        return line_id in self._lines

    # ------------------------------------------------------------- primitives
    def read(self, line_id: Hashable, field: str) -> Any:
        return self._lines[line_id].cache[field]

    def write(self, line_id: Hashable, field: str, value: Any) -> None:
        line = self._lines[line_id]
        line.cache[field] = value
        line.history.append(dict(line.cache))

    def write_many(self, line_id: Hashable, **fields: Any) -> None:
        """Multiple same-line field writes as one snapshot (single store of a
        packed word, e.g. an announcement's (val, epoch) pair is still 2
        stores — use write() per field when store granularity matters)."""
        line = self._lines[line_id]
        line.cache.update(fields)
        line.history.append(dict(line.cache))

    def pwb(self, tid: Hashable, line_id: Hashable, tag: str = "other") -> None:
        """Enqueue a write-back of the line's *current* content (paper: pwb)."""
        line = self._lines[line_id]
        self._pending.setdefault(tid, []).append((line_id, len(line.history)))
        self.stats.count_pwb(tag)

    def pfence(self, tid: Hashable, tag: str = "other") -> None:
        """Order + complete all of ``tid``'s preceding pwbs (pfence+psync)."""
        for line_id, idx in self._pending.get(tid, ()):  # commit marks
            line = self._lines[line_id]
            line.fenced = max(line.fenced, idx)
        self._pending[tid] = []
        self.stats.count_pfence(tag)

    # ------------------------------------------------------------------ crash
    def crash(self, mode: CrashMode = CrashMode.MIN, rng=None) -> None:
        """System-wide crash-failure.

        Volatile caches are lost; every line's persisted value becomes some
        prefix-point of its write history that is at least the last fenced
        write-back (arbitrary eviction may have persisted more).
        """
        for line in self._lines.values():
            hi = len(line.history)
            lo = min(line.fenced, hi)
            if mode is CrashMode.MIN:
                pick = lo
            elif mode is CrashMode.MAX:
                pick = hi
            else:
                if rng is None:
                    raise ValueError("CrashMode.RANDOM requires rng")
                pick = int(rng.integers(lo, hi + 1)) if hi > lo else lo
            if pick > 0:
                line.committed = dict(line.history[pick - 1])
            # rebase: post-crash, cache == committed, history empty
            line.cache = dict(line.committed)
            line.history = []
            line.fenced = 0
        self._pending.clear()

    # ------------------------------------------------------------- inspection
    def persisted(self, line_id: Hashable, field: str) -> Any:
        """What would survive a MIN-mode crash right now (for tests)."""
        line = self._lines[line_id]
        if line.fenced > 0:
            return line.history[line.fenced - 1].get(field)
        return line.committed.get(field)
