"""Crash-injection harness for the DFC structures (stack, queue, deque).

Drives a workload to a chosen global step, crashes the simulated NVM (with a
chosen eviction adversary), runs the Recover procedure for every thread —
possibly crashing *again* during recovery — and assembles the *effective
history* needed to check durable linearizability + detectability.

Detectability protocol used by the harness (mirrors the paper §2's contract):
after Recover returns, a thread inspects its active announcement.  If the
announcement matches the op it had in flight, the op took effect and
Recover's return value is its response; otherwise the op did not take effect
(its announcement never became valid) and it may be safely re-executed.

To make the announcement-identity check exact, the harness gives every
param-less op (pop/deq/popL/popR) a unique token as its ``param`` — the
announcement's param field is ignored by the combiners for removals, so the
token rides along purely as an operation identifier (the standard
sequence-number technique for detectable objects).  Without it, a thread
whose previous op had the same name could be mis-detected after a crash that
hit the announce sequence before the valid-bit flip.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple, Type

import numpy as np

from repro.core.dfc import BOT, INIT, DFCBase, DFCStack
from repro.core.linearize import is_linearizable
from repro.core.sim import Crashed, History, Scheduler, workload_gen
from repro.nvm.memory import CrashMode, NVMemory

RECOVERY_TS = 10**8  # response timestamp of ops completed by Recover


@dataclasses.dataclass
class CrashRunResult:
    crashed: bool
    history: History
    stack: DFCBase  # the structure under test (stack/queue/deque)
    mem: NVMemory
    recovered: Dict[int, Any]  # tid -> Recover return value
    effective_ops: List[dict]  # completed + taken-effect pending ops
    took_effect: Dict[int, bool]  # tid(pending only) -> bool


def _tag_ops(
    workloads: Sequence[Sequence[Tuple[str, Any]]]
) -> List[List[Tuple[str, Any]]]:
    """Unique tokens for param-less ops; asserts all params are unique."""
    out: List[List[Tuple[str, Any]]] = []
    for t, w in enumerate(workloads):
        out.append(
            [
                (name, param if param is not None else ("tok", t, i))
                for i, (name, param) in enumerate(w)
            ]
        )
    params = [p for w in out for (_, p) in w]
    assert len(params) == len(set(params)), "harness requires unique op params"
    return out


def run_with_crash(
    workloads: Sequence[Sequence[Tuple[str, Any]]],
    crash_at: Optional[int],
    seed: int = 0,
    mode: CrashMode = CrashMode.MIN,
    recovery_crash_at: Optional[int] = None,
    pool_capacity: int = 1024,
    structure: Type[DFCBase] = DFCStack,
) -> CrashRunResult:
    workloads = _tag_ops(workloads)
    n = len(workloads)
    mem = NVMemory()
    obj = structure(mem, n, pool_capacity=pool_capacity)
    sched = Scheduler(seed=seed)
    hist = History()
    rng = np.random.default_rng(seed + 1)

    gens = {t: workload_gen(obj, sched, hist, t, workloads[t]) for t in range(n)}
    try:
        sched.run(gens, crash_at=crash_at)
        return CrashRunResult(False, hist, obj, mem, {}, list(hist.ops), {})
    except Crashed:
        pass

    # ------------------------------------------------------------- the crash
    mem.crash(mode, rng=rng)
    obj.reset_volatile()

    # ---------------------------------------------------------- recovery (+N crashes)
    while True:
        rec_gens = {t: obj.recover(t) for t in range(n)}
        try:
            recovered = sched.run(rec_gens, crash_at=recovery_crash_at)
            break
        except Crashed:
            recovery_crash_at = None  # second recovery runs to completion
            mem.crash(mode, rng=rng)
            obj.reset_volatile()

    # -------------------------------------------- effective history assembly
    effective = list(hist.completed())
    took_effect: Dict[int, bool] = {}
    pending_by_tid = {o["tid"]: o for o in hist.pending()}
    for tid, op in pending_by_tid.items():
        name, param, val = obj.active_announcement(tid)
        # Exact announcement identity: every op carries a unique param (tokens
        # for removals), so the valid slot holds THIS op iff name+param match;
        # the op took effect iff its response was (or has now been) computed.
        matches = (
            name == op["name"]
            and param == op["param"]
            and val is not BOT
            and val != INIT
        )
        took_effect[tid] = bool(matches)
        if matches:
            eff = dict(op)
            eff["value"] = recovered[tid]
            # Completed at recovery: concurrent with everything pending at the
            # crash, but strictly before any post-recovery op (e.g. the drain,
            # which starts at ts 10^9).  Leaving resp=None (= +inf) is also
            # sound but makes these ops concurrent with the whole drain and
            # blows up the linearizability search.
            eff["resp"] = RECOVERY_TS
            effective.append(eff)
    return CrashRunResult(True, hist, obj, mem, recovered, effective, took_effect)


def drain_ops(result: CrashRunResult, seed: int = 99) -> List[dict]:
    """Remove everything from the recovered structure via fresh ops; return
    the drain history (appended after recovery, so timestamps are later).

    The drain is single-threaded: a sequential drain pins the exact order of
    the recovered contents (a stronger check than a concurrent drain) and
    keeps the linearizability DFS linear in the drain length — n concurrent
    drain threads produce a combinatorial number of interchangeable EMPTY
    removals that blow the checker's search space up.
    """
    obj = result.stack
    sched = Scheduler(seed=seed)
    hist = History()
    base = 10**9  # timestamps after everything else
    sched.step = base
    depth = len(obj.snapshot())
    drain = [(obj.DRAIN_OP, None)] * (depth + 2)
    gens = {0: workload_gen(obj, sched, hist, 0, drain)}
    sched.run(gens)
    return hist.ops


def check_durable_linearizability(
    result: CrashRunResult, drain: bool = True
) -> bool:
    ops = list(result.effective_ops)
    if drain:
        ops += drain_ops(result)
    return is_linearizable(ops, semantics=result.stack.SEMANTICS)


def total_steps(
    workloads,
    seed=0,
    pool_capacity: int = 1024,
    structure: Type[DFCBase] = DFCStack,
) -> int:
    """Step count of the crash-free run (for exhaustive crash sweeps)."""
    workloads = _tag_ops(workloads)
    n = len(workloads)
    mem = NVMemory()
    obj = structure(mem, n, pool_capacity=pool_capacity)
    sched = Scheduler(seed=seed)
    hist = History()
    gens = {t: workload_gen(obj, sched, hist, t, workloads[t]) for t in range(n)}
    sched.run(gens)
    return sched.step
