"""Crash-injection harness for the DFC stack.

Drives a workload to a chosen global step, crashes the simulated NVM (with a
chosen eviction adversary), runs the Recover procedure for every thread —
possibly crashing *again* during recovery — and assembles the *effective
history* needed to check durable linearizability + detectability.

Detectability protocol used by the harness (mirrors the paper §2's contract):
after Recover returns, a thread inspects its active announcement.  If the
announcement matches the op it had in flight (params are unique per op in the
harness), the op took effect and Recover's return value is its response;
otherwise the op did not take effect (its announcement never became valid)
and it may be safely re-executed.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.dfc import ACK, BOT, EMPTY, INIT, POP, PUSH, DFCStack
from repro.core.linearize import is_linearizable
from repro.core.sim import Crashed, History, Scheduler, workload_gen
from repro.nvm.memory import CrashMode, NVMemory


@dataclasses.dataclass
class CrashRunResult:
    crashed: bool
    history: History
    stack: DFCStack
    mem: NVMemory
    recovered: Dict[int, Any]  # tid -> Recover return value
    effective_ops: List[dict]  # completed + taken-effect pending ops
    took_effect: Dict[int, bool]  # tid(pending only) -> bool


def _unique_params(workloads: Sequence[Sequence[Tuple[str, Any]]]) -> None:
    params = [p for w in workloads for (n, p) in w if n == PUSH]
    assert len(params) == len(set(params)), "harness requires unique push params"


def run_with_crash(
    workloads: Sequence[Sequence[Tuple[str, Any]]],
    crash_at: Optional[int],
    seed: int = 0,
    mode: CrashMode = CrashMode.MIN,
    recovery_crash_at: Optional[int] = None,
    pool_capacity: int = 1024,
) -> CrashRunResult:
    _unique_params(workloads)
    n = len(workloads)
    mem = NVMemory()
    stack = DFCStack(mem, n, pool_capacity=pool_capacity)
    sched = Scheduler(seed=seed)
    hist = History()
    rng = np.random.default_rng(seed + 1)

    gens = {t: workload_gen(stack, sched, hist, t, workloads[t]) for t in range(n)}
    try:
        sched.run(gens, crash_at=crash_at)
        return CrashRunResult(False, hist, stack, mem, {}, list(hist.ops), {})
    except Crashed:
        pass

    # ------------------------------------------------------------- the crash
    mem.crash(mode, rng=rng)
    stack.reset_volatile()

    # ---------------------------------------------------------- recovery (+N crashes)
    while True:
        rec_gens = {t: stack.recover(t) for t in range(n)}
        try:
            recovered = sched.run(rec_gens, crash_at=recovery_crash_at)
            break
        except Crashed:
            recovery_crash_at = None  # second recovery runs to completion
            mem.crash(mode, rng=rng)
            stack.reset_volatile()

    # -------------------------------------------- effective history assembly
    effective = list(hist.completed())
    took_effect: Dict[int, bool] = {}
    pending_by_tid = {o["tid"]: o for o in hist.pending()}
    for tid, op in pending_by_tid.items():
        name, param, val = stack.active_announcement(tid)
        matches = (
            name == op["name"]
            and (name == POP or param == op["param"])
            and val is not BOT
            and val != INIT
        )
        # A pop announcement matches only if no *earlier completed* pop of this
        # thread could be confused — each thread has at most one pending op and
        # the announcement slot alternates, so name/param equality suffices for
        # pushes; for pops we additionally require the announcement epoch to be
        # recent.  With unique params and per-thread single pending op this is
        # exact for pushes; for pops we check the slot parity advanced.
        took_effect[tid] = bool(matches)
        if matches:
            eff = dict(op)
            eff["value"] = recovered[tid]
            eff["resp"] = None  # completed at recovery => concurrent tail
            effective.append(eff)
    return CrashRunResult(True, hist, stack, mem, recovered, effective, took_effect)


def drain_ops(result: CrashRunResult, seed: int = 99) -> List[dict]:
    """Pop everything off the recovered stack via fresh ops; return the drain
    history (appended after recovery, so timestamps are later)."""
    stack, mem = result.stack, result.mem
    n = stack.N
    sched = Scheduler(seed=seed)
    hist = History()
    base = 10**9  # timestamps after everything else
    sched.step = base
    depth = len(stack.peek_stack())
    drains = [[(POP, None)] * ((depth // n) + 2) for _ in range(n)]
    gens = {t: workload_gen(stack, sched, hist, t, drains[t]) for t in range(n)}
    sched.run(gens)
    return hist.ops


def check_durable_linearizability(
    result: CrashRunResult, drain: bool = True
) -> bool:
    ops = list(result.effective_ops)
    if drain:
        ops += drain_ops(result)
    return is_linearizable(ops)


def total_steps(workloads, seed=0, pool_capacity: int = 1024) -> int:
    """Step count of the crash-free run (for exhaustive crash sweeps)."""
    n = len(workloads)
    mem = NVMemory()
    stack = DFCStack(mem, n, pool_capacity=pool_capacity)
    sched = Scheduler(seed=seed)
    hist = History()
    gens = {t: workload_gen(stack, sched, hist, t, workloads[t]) for t in range(n)}
    sched.run(gens)
    return sched.step
