"""DFC — the paper's detectable flat-combining persistent stack (Algorithms 1-2).

Faithful line-by-line reproduction over the simulated NVM (`repro.nvm`).  Each
``yield`` is one atomic shared-memory step for the cooperative scheduler, so
crash points can be injected between any two steps.

Layout (Figure 1):
  NVM lines:
    'cEpoch'          {v}                    global epoch counter
    'top'             {0, 1}                 two alternating head pointers
    ('valid', t)      {v}                    2-bit valid (MSB<<1 | LSB)
    ('ann', t, s)     {val, epoch, param, name}   s ∈ {0,1} — one cache line,
                       so val+epoch persist together (the paper relies on this)
    ('pool', i)       {param, next}          pre-allocated node pool (§4)
  Volatile:
    cLock, rLock, pushList[N], popList[N], vColl[N]

The announce / lock hand-off / recovery skeleton (Algorithm 1) is shared by
all three of the paper's structures — stack, FIFO queue (`dfc_queue`), and
double-ended queue (`dfc_deque`) — via :class:`DFCBase`; only REDUCE/COMBINE
(Algorithm 2) and the double-buffered root pointers differ per structure.

Paper correspondence (mechanism -> pseudocode of arXiv:2012.12868):
  * announce + publish:        Alg. 1 lines 2-12 (double-buffered ``ann``,
                               2-bit ``valid``: LSB pfenced, MSB bare)
  * combiner lock hand-off:    Alg. 1 (``cLock`` try-lock; losers spin on
                               their response, then help-check)
  * REDUCE + elimination:      Alg. 2 (collection lines 88-101; push/pop
                               pair matching lines 102-110 — eliminated
                               pairs never touch the persistent structure)
  * one pfence per phase:      Alg. 2 line 80 (responses + new state drain
                               under a single barrier)
  * two-increment epoch:       Alg. 1 lines 81-83 — pwb+pfence ``cEpoch=v+1``
                               then write ``v+2`` WITHOUT a fence; parity
                               selects the live ``top`` entry
  * recovery + verdicts:       Alg. 1 lines 26-43 (round odd epoch up,
                               re-publish half-written ``valid`` selectors,
                               re-execute ops of the crashed phase,
                               per-thread detectability verdicts)
  * node reclamation / GC:     §4 (volatile free-bitmap rebuilt by a
                               recovery walk bounded by the committed roots)

Deviations from the pseudocode (documented):
  * Initial announcements get ``epoch=-1, val=INIT, name=NONE`` instead of
    all-zero, so that threads which never announced an operation are not
    mistaken for pending ops by Recover/Reduce.  The paper's benchmarks never
    exercise this corner (every thread always has an op in flight).
  * ``LSB/MSB(valid)`` are bit ops on a small int, as the paper suggests.
"""

from __future__ import annotations

from typing import Any, Dict, Generator, Iterable, Optional, Sequence, Tuple

from repro.nvm.memory import BOT, NVMemory
from repro.nvm.pool import NIL, NodePool

PUSH = "push"
POP = "pop"
ENQ = "enq"  # FIFO queue (repro.core.dfc_queue)
DEQ = "deq"
PUSHL = "pushL"  # double-ended queue (repro.core.dfc_deque)
POPL = "popL"
PUSHR = "pushR"
POPR = "popR"
NONE = "none"
ACK = "ACK"
EMPTY = "EMPTY"
INIT = "INIT"  # val of a never-used announcement slot


class DFCBase:
    """Algorithm 1 (announce, lock hand-off, try-to-return, recover) — the
    structure-independent detectable flat-combining skeleton.

    Subclasses provide:
      * ``SEMANTICS``  — key into ``repro.core.linearize.SEMANTICS``
      * ``DRAIN_OP``   — op name that removes one element (harness drains)
      * ``_alloc_structure()``   — allocate the double-buffered root lines
      * ``_extra_volatile()``    — combiner scratch lists
      * ``_gc_roots()``          — (roots, stops) for the recovery GC cycle
      * ``combine(t)``           — Algorithm 2 for the concrete structure
      * ``snapshot()``           — current contents (test/drain helper)
    """

    SEMANTICS = "stack"
    DRAIN_OP = POP
    POOL_EXTRA_FIELDS: Tuple[str, ...] = ()

    def __init__(self, mem: NVMemory, n_threads: int, pool_capacity: int = 4096):
        self.mem = mem
        self.N = n_threads
        self.pool = NodePool(
            mem, pool_capacity, extra_fields=self.POOL_EXTRA_FIELDS
        )
        mem.alloc_line("cEpoch", v=0)
        self._alloc_structure()
        for t in range(n_threads):
            mem.alloc_line(("valid", t), v=0)
            for s in (0, 1):
                mem.alloc_line(("ann", t, s), val=INIT, epoch=-1, param=BOT, name=NONE)
        self.vol: Dict[str, Any] = {}
        self.reset_volatile()
        self.phases = 0  # combining-phase counter (Figure 4)
        self.eliminated_pairs = 0  # op pairs resolved without structure access
        self.combined_ops = 0  # total ops collected by combiners

    # ----------------------------------------------------------------- hooks
    def _alloc_structure(self) -> None:
        raise NotImplementedError

    def _extra_volatile(self) -> Dict[str, Any]:
        raise NotImplementedError

    def _gc_roots(self) -> Tuple[Sequence[int], Iterable[int]]:
        raise NotImplementedError

    def combine(self, t: int) -> Generator:
        raise NotImplementedError

    def snapshot(self):
        raise NotImplementedError

    # ----------------------------------------------------------------- state
    def reset_volatile(self) -> None:
        """Crash: all volatile shared variables return to initial values."""
        self.vol = dict(
            cLock=0,
            rLock=0,
            vColl=[BOT] * self.N,
            **self._extra_volatile(),
        )

    def _top_entry(self, epoch: int) -> str:
        return str((epoch // 2) % 2)

    def _next_top_entry(self, epoch: int) -> str:
        return str((epoch // 2 + 1) % 2)

    # ------------------------------------------------------------------- Op
    def op(self, t: int, name: str, param: Any = None) -> Generator:
        """Algorithm 1, lines 1-18."""
        m = self.mem
        yield
        op_epoch = m.read("cEpoch", "v")  # L2
        if op_epoch % 2 == 1:  # L3
            op_epoch += 1
        yield
        n_op = 1 - (m.read(("valid", t), "v") & 1)  # L4
        ann = ("ann", t, n_op)
        yield
        m.write(ann, "val", BOT)  # L5
        yield
        m.write(ann, "epoch", op_epoch)  # L6
        yield
        m.write(ann, "param", param)  # L7
        yield
        m.write(ann, "name", name)  # L8
        yield
        m.pwb(t, ann, tag="announce")  # L9
        yield
        m.pfence(t, tag="announce")
        yield
        m.write(("valid", t), "v", n_op)  # L10 (MSB=0, LSB=n_op)
        yield
        m.pwb(t, ("valid", t), tag="announce")  # L11
        yield
        m.pfence(t, tag="announce")
        yield
        m.write(("valid", t), "v", 2 | n_op)  # L12 (MSB=1)
        value = yield from self.take_lock(t, op_epoch)  # L13
        if value is not BOT:  # L14
            return value  # L15
        yield from self.combine(t)  # L17
        yield
        return m.read(ann, "val")  # L18

    # -------------------------------------------------------------- TakeLock
    def take_lock(self, t: int, op_epoch: int) -> Generator:
        """Algorithm 1, lines 19-25."""
        m = self.mem
        yield
        if self.vol["cLock"] == 0:  # L20: CAS(0,1)
            self.vol["cLock"] = 1
            return BOT  # L25: caller becomes the combiner
        while True:  # L21
            yield
            if not (m.read("cEpoch", "v") <= op_epoch + 1):
                break
            yield
            if self.vol["cLock"] == 0 and m.read("cEpoch", "v") <= op_epoch + 1:  # L22
                return (yield from self.take_lock(t, op_epoch))  # L23
        return (yield from self.try_to_return(t, op_epoch))  # L24

    # ----------------------------------------------------------- TryToReturn
    def try_to_return(self, t: int, op_epoch: int) -> Generator:
        """Algorithm 1, lines 44-50."""
        m = self.mem
        yield
        v_op = m.read(("valid", t), "v") & 1  # L45
        yield
        val = m.read(("ann", t, v_op), "val")  # L46
        if val is BOT:  # L47: late arrival
            op_epoch += 2  # L48
            return (yield from self.take_lock(t, op_epoch))  # L49
        return val  # L50

    # ------------------------------------------------------ announcement scan
    def _collect(self, t: int) -> Generator:
        """Algorithm 2, lines 88-101 (shared collection loop of REDUCE).

        Scans the announcement array, stamps collected ops with the current
        epoch (val+epoch share the cache line, so they persist together) and
        fills ``vColl``.  Yields (i, op_name) for each collected op; the
        caller routes it into its per-structure lists.
        """
        m = self.mem
        vol = self.vol
        yield
        c_epoch = m.read("cEpoch", "v")
        for i in range(self.N):  # L88
            yield
            v_op = m.read(("valid", i), "v")  # L89
            lsb = v_op & 1
            ann = ("ann", i, lsb)
            yield
            op_val = m.read(ann, "val")  # L90
            yield
            op_name = m.read(ann, "name")
            if (v_op >> 1) & 1 == 1 and op_val is BOT and op_name != NONE:  # L91
                yield
                m.write(ann, "epoch", c_epoch)  # L92 (val+epoch share the line)
                vol["vColl"][i] = lsb  # L93
                self.combined_ops += 1
                self._route(i, op_name)  # L94-99
            else:
                vol["vColl"][i] = BOT  # L101

    def _route(self, i: int, op_name: str) -> None:
        """Place collected op ``i`` into the combiner's scratch lists."""
        raise NotImplementedError

    # --------------------------------------------------------------- publish
    def _publish(self, t: int, c_epoch: int, struct_lines: Sequence) -> Generator:
        """Algorithm 2, lines 77-85: persist responses + roots, then commit
        the phase with the two-increment epoch protocol."""
        m = self.mem
        vol = self.vol
        for i in range(self.N):  # L77
            v_op = vol["vColl"][i]  # L78
            if v_op is not BOT:  # L79
                yield
                m.pwb(t, ("ann", i, v_op), tag="combine")
        for line in struct_lines:
            yield
            m.pwb(t, line, tag="combine")  # L80
        yield
        m.pfence(t, tag="combine")
        yield
        m.write("cEpoch", "v", c_epoch + 1)  # L81
        yield
        m.pwb(t, "cEpoch", tag="combine")  # L82
        yield
        m.pfence(t, tag="combine")
        yield
        m.write("cEpoch", "v", c_epoch + 2)  # L83
        yield
        self.vol["cLock"] = 0  # L84
        self.phases += 1

    # --------------------------------------------------------------- Recover
    def recover(self, t: int) -> Generator:
        """Algorithm 1, lines 26-43."""
        m = self.mem
        yield
        if self.vol["rLock"] == 0:  # L27: rLock.CAS(0,1)
            self.vol["rLock"] = 1
            yield
            c_epoch = m.read("cEpoch", "v")
            if c_epoch % 2 == 1:  # L28
                c_epoch += 1
                yield
                m.write("cEpoch", "v", c_epoch)  # L29
                yield
                m.pwb(t, "cEpoch", tag="recover")  # L30
                yield
                m.pfence(t, tag="recover")
            yield
            roots, stops = self._gc_roots()
            self.pool.garbage_collect(roots, stops=stops)  # L31
            for i in range(self.N):  # L32
                yield
                v_op = m.read(("valid", i), "v")  # L33
                lsb = v_op & 1
                yield
                op_epoch = m.read(("ann", i, lsb), "epoch")  # L34
                if (v_op >> 1) & 1 == 0:  # L35
                    yield
                    m.write(("valid", i), "v", 2 | lsb)  # L36
                if op_epoch == c_epoch:  # L37
                    yield
                    m.write(("ann", i, lsb), "val", BOT)  # L38
            yield from self.combine(t)  # L39
            yield
            self.vol["rLock"] = 2  # L40
        else:
            while True:  # L42
                yield
                if self.vol["rLock"] != 1:
                    break
        yield
        lsb = m.read(("valid", t), "v") & 1
        return m.read(("ann", t, lsb), "val")  # L43

    # ------------------------------------------------------------ inspection
    def active_announcement(self, t: int):
        """(name, param, val) of thread t's active announcement (helper)."""
        lsb = self.mem.read(("valid", t), "v") & 1
        ann = ("ann", t, lsb)
        return (
            self.mem.read(ann, "name"),
            self.mem.read(ann, "param"),
            self.mem.read(ann, "val"),
        )


class DFCStack(DFCBase):
    """The paper's detectable FC stack (Algorithm 2 as published)."""

    SEMANTICS = "stack"
    DRAIN_OP = POP

    def _alloc_structure(self) -> None:
        self.mem.alloc_line("top", **{"0": NIL, "1": NIL})

    def _extra_volatile(self) -> Dict[str, Any]:
        return dict(pushList=[0] * self.N, popList=[0] * self.N)

    def _gc_roots(self):
        c_epoch = self.mem.read("cEpoch", "v")
        return [self.mem.read("top", self._top_entry(c_epoch))], ()

    def _route(self, i: int, op_name: str) -> None:
        vol = self.vol
        if op_name == PUSH:  # L94
            self._t_push += 1  # L95
            vol["pushList"][self._t_push] = i  # L96
        else:
            self._t_pop += 1  # L98
            vol["popList"][self._t_pop] = i  # L99

    # ---------------------------------------------------------------- Reduce
    def reduce(self, t: int) -> Generator:
        """Algorithm 2, lines 86-113 (push/pop pair elimination)."""
        m = self.mem
        vol = self.vol
        self._t_push = self._t_pop = -1  # L87
        yield from self._collect(t)  # L88-101
        t_push, t_pop = self._t_push, self._t_pop
        while t_push != -1 and t_pop != -1:  # L102: eliminate pairs
            c_push = vol["pushList"][t_push]  # L103
            c_pop = vol["popList"][t_pop]  # L104
            v_push = vol["vColl"][c_push]  # L105
            yield
            m.write(("ann", c_push, v_push), "val", ACK)  # L106
            v_pop = vol["vColl"][c_pop]  # L107
            yield
            param = m.read(("ann", c_push, v_push), "param")
            m.write(("ann", c_pop, v_pop), "val", param)  # L108
            t_push -= 1  # L109
            t_pop -= 1  # L110
            self.eliminated_pairs += 1
        if t_push != -1:
            return t_push + 1  # L111: surplus pushes
        if t_pop != -1:
            return -(t_pop + 1)  # L112: surplus pops
        return 0  # L113

    # --------------------------------------------------------------- Combine
    def combine(self, t: int) -> Generator:
        """Algorithm 2, lines 51-85 (runs with the combiner lock held)."""
        m = self.mem
        vol = self.vol
        t_index = yield from self.reduce(t)  # L52
        yield
        c_epoch = m.read("cEpoch", "v")
        head = m.read("top", self._top_entry(c_epoch))  # L53
        if t_index > 0:  # L54: surplus pushes
            while t_index > 0:  # L55
                t_index -= 1  # L56
                c_id = vol["pushList"][t_index]  # L57
                v_op = vol["vColl"][c_id]  # L58
                yield
                param = m.read(("ann", c_id, v_op), "param")  # L59
                yield
                n_node = self.pool.allocate(param, head)  # L60
                yield
                m.write(("ann", c_id, v_op), "val", ACK)  # L61
                yield
                m.pwb(t, self.pool.line_of(n_node), tag="combine")  # L62
                head = n_node  # L63
        elif t_index < 0:  # L64: surplus pops
            t_index = -t_index  # L65
            while t_index > 0:  # L66
                t_index -= 1  # L67
                c_id = vol["popList"][t_index]  # L68
                v_op = vol["vColl"][c_id]  # L69
                if head == NIL:  # L70
                    yield
                    m.write(("ann", c_id, v_op), "val", EMPTY)  # L71
                else:
                    yield
                    m.write(("ann", c_id, v_op), "val", self.pool.param(head))  # L73
                    temp_head = head  # L74
                    head = self.pool.next(head)
                    self.pool.deallocate(temp_head)  # L75
        yield
        m.write("top", self._next_top_entry(c_epoch), head)  # L76
        yield from self._publish(t, c_epoch, ("top",))  # L77-85

    # ------------------------------------------------------------ inspection
    def peek_stack(self):
        """Volatile view of the active stack (test helper)."""
        c_epoch = self.mem.read("cEpoch", "v")
        head = self.mem.read("top", self._top_entry(c_epoch))
        return self.pool.walk(head)

    def snapshot(self):
        return self.peek_stack()
