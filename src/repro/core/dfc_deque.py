"""DFC deque — the paper's detectable flat-combining persistent double-ended
queue.

Algorithm 1's announce / lock hand-off / recover skeleton is inherited from
:class:`~repro.core.dfc.DFCBase`; this module supplies the deque's
REDUCE/COMBINE over the simulated NVM.

Layout (deque analogue of Figure 1):
  NVM lines:
    'cEpoch'          {v}          global epoch counter (shared skeleton)
    'left'            {0, 1}       two alternating left-end pointers
    'right'           {0, 1}       two alternating right-end pointers
    ('valid', t), ('ann', t, s)    as in the stack
    ('pool', i)       {param, next, prev}   doubly-linked nodes, one cache
                       line each (``next`` points toward the right end)
  Volatile:
    cLock, rLock, pushLList/popLList/pushRList/popRList[N], vColl[N]

Combiner algorithm (one phase, lock held):
  1. REDUCE collects announced ops into the four side lists and eliminates
     SAME-SIDE pairs exactly as the stack does (a pushL_k;popL_k adjacent
     pair returns the pushed value and leaves the deque unchanged; ditto R).
     After elimination each side has a one-sided surplus.
  2. The left surplus is applied first (pushes prepend / pops consume from
     the left, in collection order), then the right surplus — this is the
     canonical linearization order, shared with the vectorized layer.
  3. Consumed nodes are only deallocated after the phase commits (a deque
     phase can free on one side and allocate on the other; early reuse would
     corrupt the committed chain a crash rolls back to).
  4. End-node mutations are confined to fields the committed state never
     reads: appending right writes ``next`` of the committed right end,
     prepending left writes ``prev`` of the committed left end.  Committed
     traversal is bounded by the committed (left, right) pair, so dangling
     links beyond either end are unreachable after a rollback (recovery GC
     and ``snapshot`` stop at the right end for the same reason).
  5. The phase publishes by writing the *inactive* left/right entries and
     committing with the shared two-increment epoch protocol.

Paper correspondence (arXiv:2012.12868; shared skeleton cites are in
``repro.core.dfc``):
  * announce / valid / recovery skeleton: Alg. 1 lines 2-12 and 26-43 via
    :class:`~repro.core.dfc.DFCBase`,
  * elimination rule: the same-side instance of Alg. 2 lines 102-110 —
    pushL_k pairs with popL_k (and pushR_k with popR_k); cross-side pairs
    are NOT eliminated, they linearize through the structure (step 2),
  * one pfence per phase / two-increment ``cEpoch`` commit: Alg. 2 line 80
    and Alg. 1 lines 81-83 with the (left, right) double-buffered roots,
  * deferred node reuse + bounded recovery GC walks: §4, extended to
    doubly-linked nodes (walks bounded by the committed (left, right) pair).
"""

from __future__ import annotations

from typing import Any, Dict, Generator

from repro.core.dfc import ACK, EMPTY, POPL, POPR, PUSHL, PUSHR, DFCBase
from repro.nvm.pool import NIL


class DFCDeque(DFCBase):
    SEMANTICS = "deque"
    DRAIN_OP = POPL
    POOL_EXTRA_FIELDS = ("prev",)

    def _alloc_structure(self) -> None:
        self.mem.alloc_line("left", **{"0": NIL, "1": NIL})
        self.mem.alloc_line("right", **{"0": NIL, "1": NIL})

    def _extra_volatile(self) -> Dict[str, Any]:
        n = self.N
        return dict(
            pushLList=[0] * n,
            popLList=[0] * n,
            pushRList=[0] * n,
            popRList=[0] * n,
        )

    def _gc_roots(self):
        c_epoch = self.mem.read("cEpoch", "v")
        e = self._top_entry(c_epoch)
        left = self.mem.read("left", e)
        right = self.mem.read("right", e)
        return [left], [right]

    _LISTS = {
        PUSHL: "pushLList",
        POPL: "popLList",
        PUSHR: "pushRList",
        POPR: "popRList",
    }

    def _route(self, i: int, op_name: str) -> None:
        counts = self._counts
        counts[op_name] += 1
        self.vol[self._LISTS[op_name]][counts[op_name] - 1] = i

    # ---------------------------------------------------------------- Reduce
    def reduce(self, t: int) -> Generator:
        """Collect the four op kinds, then eliminate same-side pairs.

        Returns (l_surplus, r_surplus): positive = that many surplus pushes
        on the side, negative = surplus pops, zero = fully eliminated.
        """
        m = self.mem
        vol = self.vol
        self._counts = {PUSHL: 0, POPL: 0, PUSHR: 0, POPR: 0}
        yield from self._collect(t)
        c = self._counts
        surpluses = []
        for push_name, pop_name in ((PUSHL, POPL), (PUSHR, POPR)):
            n_push, n_pop = c[push_name], c[pop_name]
            push_list = vol[self._LISTS[push_name]]
            pop_list = vol[self._LISTS[pop_name]]
            while n_push > 0 and n_pop > 0:  # eliminate from the lists' tails
                c_push = push_list[n_push - 1]
                c_pop = pop_list[n_pop - 1]
                v_push = vol["vColl"][c_push]
                yield
                m.write(("ann", c_push, v_push), "val", ACK)
                v_pop = vol["vColl"][c_pop]
                yield
                param = m.read(("ann", c_push, v_push), "param")
                m.write(("ann", c_pop, v_pop), "val", param)
                n_push -= 1
                n_pop -= 1
                self.eliminated_pairs += 1
            surpluses.append(n_push if n_push > 0 else -n_pop)
        return surpluses[0], surpluses[1]

    # --------------------------------------------------------------- Combine
    def combine(self, t: int) -> Generator:
        m = self.mem
        vol = self.vol
        l_surplus, r_surplus = yield from self.reduce(t)
        yield
        c_epoch = m.read("cEpoch", "v")
        e = self._top_entry(c_epoch)
        left = m.read("left", e)
        right = m.read("right", e)
        freed = []  # deallocated only after the phase commits (see docstring)

        sides = (
            (l_surplus, "pushLList", "popLList", True),
            (r_surplus, "pushRList", "popRList", False),
        )
        for surplus, push_list, pop_list, is_left in sides:
            if surplus > 0:  # surplus pushes on this side
                for k in range(surplus):
                    c_id = vol[push_list][k]
                    v_op = vol["vColl"][c_id]
                    yield
                    param = m.read(("ann", c_id, v_op), "param")
                    yield
                    if is_left:
                        node = self.pool.allocate(param, left, prev=NIL)
                    else:
                        node = self.pool.allocate(param, NIL, prev=right)
                    yield
                    m.write(("ann", c_id, v_op), "val", ACK)
                    yield
                    m.pwb(t, self.pool.line_of(node), tag="combine")
                    if is_left:
                        if left == NIL:
                            right = node
                        else:
                            yield
                            self.pool.set(left, "prev", node)
                            yield
                            m.pwb(t, self.pool.line_of(left), tag="combine")
                        left = node
                    else:
                        if right == NIL:
                            left = node
                        else:
                            yield
                            self.pool.set(right, "next", node)
                            yield
                            m.pwb(t, self.pool.line_of(right), tag="combine")
                        right = node
            elif surplus < 0:  # surplus pops on this side
                for k in range(-surplus):
                    c_id = vol[pop_list][k]
                    v_op = vol["vColl"][c_id]
                    if left == NIL:
                        yield
                        m.write(("ann", c_id, v_op), "val", EMPTY)
                        continue
                    end = left if is_left else right
                    yield
                    m.write(("ann", c_id, v_op), "val", self.pool.param(end))
                    freed.append(end)
                    if left == right:  # never follow links past the ends
                        left = right = NIL
                    elif is_left:
                        left = self.pool.next(left)
                    else:
                        right = self.pool.get(right, "prev")

        # ---- publish ------------------------------------------------------
        ne = self._next_top_entry(c_epoch)
        yield
        m.write("left", ne, left)
        yield
        m.write("right", ne, right)
        yield from self._publish(t, c_epoch, ("left", "right"))
        for idx in freed:
            self.pool.deallocate(idx)

    # ------------------------------------------------------------ inspection
    def peek_deque(self):
        """Volatile view of the active deque, left to right (test helper)."""
        c_epoch = self.mem.read("cEpoch", "v")
        e = self._top_entry(c_epoch)
        left = self.mem.read("left", e)
        right = self.mem.read("right", e)
        if left == NIL:
            return []
        return self.pool.walk(left, stop=right)

    def snapshot(self):
        return self.peek_deque()
