"""Persistence-schedule-faithful PTM baseline stacks (paper §5 competitors).

These reproduce the *persistence-instruction schedules* of the three PTMs the
paper compares against — the structure that determines Figures 3b/3c/3e/3f —
over the same simulated NVM counters as DFC:

  * PMDK   — undo-log PTM under a global transaction lock; every modified
             range is undo-logged (pwb+pfence before mutation), mutations are
             flushed, the log is invalidated at commit.  No combining: counts
             are flat in the thread count.
  * Romulus— lock-based PTM, flat combining for update transactions, TWO
             copies of the whole heap.  Per combining phase: dirty main-copy
             lines are flushed, the state flip is flushed, then the same
             lines are copied+flushed in the back copy.  ~2 flushes per dirty
             line, amortized over the combined batch.
  * OneFile— wait-free PTM using DCAS; every store is a DCAS (CAS count is
             the paper's pfence proxy) and concurrent helpers redundantly
             apply+flush the same write-set under contention.  The helping
             amplification coefficient is the one *calibrated* constant
             (BETA) — everything else is mechanical.

The baselines are round-based: each round every live thread announces one op
and the batch executes under the PTM's regime.  This reproduces the steady
state of the benchmark loop (all N threads always have an op in flight),
which is exactly the paper's setting.  Crash-recovery of the baselines is out
of scope (the paper evaluates them for performance only; none is detectable).
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Sequence, Tuple

import numpy as np

from repro.core.dfc import ACK, DEQ, EMPTY, ENQ, POP, POPL, POPR, PUSH, PUSHL, PUSHR
from repro.nvm.memory import NVMemory

_PUSH_NAMES = frozenset((PUSH, ENQ, PUSHL, PUSHR))


def _is_push(name: str) -> bool:
    """Insertions share one persistence schedule across all three structures
    (node + root pointer + allocator metadata), as do removals."""
    return name in _PUSH_NAMES


@dataclasses.dataclass
class BaselineStats:
    ops: int = 0
    pwb: int = 0
    pfence: int = 0
    cas: int = 0  # OneFile pfence proxy
    phases: int = 0

    def pwb_per_op(self):
        return self.pwb / max(self.ops, 1)

    def pfence_per_op(self):
        return self.pfence / max(self.ops, 1)


class _RoundStack:
    """Shared round-based driver: pops values, tracks a plain list container.

    ``FIFO = True`` subclasses remove from the front instead of the back —
    the persistence schedules are identical (what the figures measure); only
    the container semantics differ.
    """

    FIFO = False

    def __init__(self, n_threads: int):
        self.n = n_threads
        self.stack: List[Any] = []
        self.stats = BaselineStats()

    def _pop(self) -> None:
        if not self.stack:
            return
        if self.FIFO:
            self.stack.pop(0)
        else:
            self.stack.pop()

    def run(self, workloads: Sequence[Sequence[Tuple[str, Any]]]) -> BaselineStats:
        queues = [list(w) for w in workloads]
        while any(queues):
            batch = []
            for t, q in enumerate(queues):
                if q:
                    batch.append((t, *q.pop(0)))
            self._execute_batch(batch)
            self.stats.ops += len(batch)
            self.stats.phases += 1
        return self.stats

    def _execute_batch(self, batch):
        raise NotImplementedError


class PMDKStack(_RoundStack):
    """Undo-log PTM, global lock, no combining — ops run one at a time."""

    def _execute_batch(self, batch):
        s = self.stats
        for t, name, param in batch:
            if _is_push(name):
                # tx: alloc (persistent allocator metadata), undo-log the top
                # pointer, write node, write top, commit.
                s.pwb += 1  # allocator metadata persist
                s.pwb += 1; s.pfence += 1  # undo-log record (top) + fence
                s.pwb += 1  # node contents
                s.pwb += 1  # top pointer
                s.pfence += 1  # commit fence
                s.pwb += 1; s.pfence += 1  # log invalidate + fence
                self.stack.append(param)
            else:
                s.pwb += 1; s.pfence += 1  # undo-log record (top) + fence
                s.pwb += 1  # top pointer
                s.pwb += 1  # allocator free metadata
                s.pfence += 1  # commit fence
                s.pwb += 1; s.pfence += 1  # log invalidate + fence
                self._pop()


class RomulusStack(_RoundStack):
    """Two-copy PTM with flat combining for update transactions."""

    def _execute_batch(self, batch):
        s = self.stats
        # Each transaction's modified ranges are logged and flushed
        # per-transaction (the redo log records ranges per tx; repeatedly
        # touched lines like `top` are flushed once per touching tx).  What
        # combining amortizes is the state flip and the three fences.
        logged_lines = 0
        for t, name, param in batch:
            if _is_push(name):
                logged_lines += 3  # new node + top + allocator metadata
                self.stack.append(param)
            else:
                logged_lines += 2  # top + allocator metadata
                self._pop()
        # main copy flush (per-tx ranges)
        s.pwb += logged_lines
        s.pfence += 1
        # state flip (curComb)
        s.pwb += 1
        s.pfence += 1
        # back copy: replay the log onto the back heap + flush
        s.pwb += logged_lines
        s.pfence += 1


class OneFileStack(_RoundStack):
    """Wait-free DCAS-based PTM with redundant helping."""

    BETA = 0.20  # calibrated helping-amplification per extra thread

    def _execute_batch(self, batch):
        s = self.stats
        n_helpers = max(0, len(batch) - 1)
        amp = 1.0 + self.BETA * n_helpers
        for t, name, param in batch:
            write_set = 3 if _is_push(name) else 2  # node+top+alloc / top+alloc
            # publish tx descriptor
            s.cas += 1
            s.pwb += 1
            # apply phase: each word DCAS'd + flushed; helpers redundantly
            # re-apply and re-flush a BETA fraction of the write-set each.
            s.cas += int(round(write_set * amp))
            s.pwb += int(round(write_set * amp))
            # commit CAS + flush of the tx state
            s.cas += 1
            s.pwb += 1
            if _is_push(name):
                self.stack.append(param)
            else:
                self._pop()


class PMDKQueue(PMDKStack):
    FIFO = True


class RomulusQueue(RomulusStack):
    FIFO = True


class OneFileQueue(OneFileStack):
    FIFO = True


def run_dfc_counts(
    n_threads: int,
    workloads: Sequence[Sequence[Tuple[str, Any]]],
    seed: int = 0,
    think: Tuple[int, int] = None,
    structure=None,
):
    """Run a real DFC structure (default: the stack) under the cooperative
    scheduler, return (announce, combine) persistence counters + phases for
    the figures."""
    from repro.core.dfc import DFCStack
    from repro.core.sim import History, Scheduler, workload_gen

    if structure is None:
        structure = DFCStack
    mem = NVMemory()
    n_ops = sum(len(w) for w in workloads)
    obj = structure(mem, n_threads, pool_capacity=max(1024, n_ops + 64))
    sched = Scheduler(seed=seed)
    hist = History()
    rng = np.random.default_rng(seed + 17)
    gens = {
        t: workload_gen(obj, sched, hist, t, workloads[t], think=think, rng=rng)
        for t in range(n_threads)
    }
    sched.run(gens)
    st = mem.stats
    return dict(
        ops=n_ops,
        phases=obj.phases,
        eliminated_pairs=obj.eliminated_pairs,
        combined_ops=obj.combined_ops,
        pwb_announce=st.pwb.get("announce", 0),
        pwb_combine=st.pwb.get("combine", 0),
        pfence_announce=st.pfence.get("announce", 0),
        pfence_combine=st.pfence.get("combine", 0),
    )


# (insert, remove) op names per structure; deque inserts/removes pick a
# random side per op in make_workloads.
_STRUCTURE_OPS = {
    "stack": ((PUSH,), (POP,)),
    "queue": ((ENQ,), (DEQ,)),
    "deque": ((PUSHL, PUSHR), (POPL, POPR)),
}


def make_workloads(
    kind: str, n_threads: int, total_ops: int, seed: int = 0, structure: str = "stack"
):
    """The paper's benchmarks: push-pop (alternating pairs) and rand-op, for
    any of the three structures."""
    rng = np.random.default_rng(seed)
    ins_names, rem_names = _STRUCTURE_OPS[structure]
    per = max(2, total_ops // n_threads)
    out = []
    uid = 0
    for t in range(n_threads):
        ops = []
        for i in range(per):
            if kind == "push-pop":
                is_ins = i % 2 == 0
            elif kind == "rand-op":
                is_ins = rng.random() < 0.5
            else:
                raise ValueError(kind)
            names = ins_names if is_ins else rem_names
            name = names[int(rng.integers(len(names)))]
            uid += 1
            ops.append((name, uid * 10 + t) if is_ins else (name, None))
        out.append(ops)
    return out
