"""TPU-native DFC: the paper's combiners as data-parallel JAX ops.

All three of the paper's structures — LIFO stack, FIFO queue, double-ended
queue — are expressed as array-backed states with double-buffered root
pointers and a one-pass vectorized ``combine``:

  * stack: ``values[capacity]`` + two alternating ``size`` pointers,
  * queue: a ring ``values[capacity]`` + double-buffered ``(head, tail)``
    absolute counters (``ends[2, 2]``); slot = counter % capacity,
  * deque: the same ring with double-buffered ``(left, right)`` counters —
    the window [left, right) grows left on pushL and right on pushR.

A combine phase only writes ring slots *outside* the committed window and
publishes by writing the inactive counter pair with an epoch bump of +2
(contract: capacity >= committed size + lanes), so a crash mid-combine
leaves the committed state intact — exactly the paper's alternating-root
crash-consistency argument.

The paper's combiner walks an announcement array sequentially, eliminating
push/pop pairs and applying the surplus to a linked-list structure.  Here the
same *semantic combining* is done in one vectorized pass over the
announcement lanes:

  * rank-matching elimination — the k-th announced push pairs with the k-th
    announced pop (all batch ops are concurrent, so any pairing linearizes);
    computed with prefix sums over the lane masks,
  * the stack is an array `values[capacity]` with **two alternating size
    pointers** `size[2]` — exactly the paper's two `top`s: both sizes share
    the storage prefix, a combine phase only writes *above* the committed
    prefix (surplus pushes) and publishes by flipping the active size with an
    epoch bump of +2.  A crash mid-combine leaves the active prefix intact.
  * all permutations (rank-compaction, pair-value routing) are expressed as
    one-hot matmuls so the hot path maps onto the MXU (see
    `repro/kernels/dfc_reduce` for the Pallas kernel of this function).

Linearization order of a combined batch (the canonical witness used by the
tests): eliminated pairs first (push_k, pop_k adjacent, k ascending), then
surplus pushes in rank order, then surplus pops in rank order.

The host-side persistence protocol (pwb/pfence analogue: device→host fetch +
fsync; two-increment epoch commit) lives in `repro.checkpoint`.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Tuple

import numpy as np

import jax
import jax.numpy as jnp

# op codes (stack/queue: enq==push, deq==pop)
OP_NONE = 0
OP_PUSH = 1
OP_POP = 2
OP_ENQ = OP_PUSH
OP_DEQ = OP_POP
# deque op codes
OP_PUSHL = 1
OP_POPL = 2
OP_PUSHR = 3
OP_POPR = 4
# serving-tier aliases: priority admission runs a request shard as a deque —
# a normal arrival joins the BACK of the line (pushR), admission drains the
# FRONT (popL), and a high-priority arrival jumps the line (pushL).  Note
# OP_POP_FRONT == OP_DEQ == 2, so one admission op code serves both queue
# and deque request shards.
OP_PUSH_BACK = OP_PUSHR
OP_PUSH_FRONT = OP_PUSHL
OP_POP_FRONT = OP_POPL
# response kinds
R_NONE = 0
R_ACK = 1
R_VALUE = 2
R_EMPTY = 3
# keyed-map op codes (interpreted by map shards; see MapState below)
OP_MAP_INSERT = 1
OP_MAP_LOOKUP = 2
OP_MAP_DELETE = 3
OP_MAP_CAS = 4
# map response kinds: code 4 is reserved for the runtime-level R_OVERFLOW
# (repro.runtime.dfc_shard), so the map's rejections start at 5 — both are
# DEFINITIVE verdicts (the op completed without touching state), unlike
# R_OVERFLOW which marks an op that never reached its shard.
R_FULL = 5  # insert into a full bucket: clean rejection, no write
R_CAS_FAIL = 6  # CAS found the key but the expected value did not match
# OP_MAP_CAS packs (expected, new) into ONE f32 param as
# ``expected * CAS_DOM + new``, both operands in [0, CAS_DOM).  The maximum
# packed value CAS_DOM**2 - 1 == 2**24 - 1 is exactly the top of f32's
# contiguous-integer range, so the packing is lossless end to end (including
# the JSON durable mirror, which cannot carry NaN-boxed payloads).
CAS_DOM = 4096
# slots per hash bucket of a map shard (the fixed probe window)
MAP_BUCKET_SLOTS = 8


def pack_cas(expected: int, new: int) -> float:
    """Pack a CAS ``(expected, new)`` pair into one f32-exact op param.

    Owns the CAS packing domain: both operands must sit in ``[0, CAS_DOM)``
    or the packed value would alias a DIFFERENT (expected, new) pair — the
    combine unpacks with floor-divide, so an out-of-range operand wraps
    silently into the other field.  Callers that widen their own value
    encodings (e.g. the serving tier's session states) route through here
    so the domain check cannot be forgotten.
    """
    expected, new = int(expected), int(new)
    if not 0 <= expected < CAS_DOM:
        raise ValueError(f"CAS expected value {expected} outside [0, {CAS_DOM})")
    if not 0 <= new < CAS_DOM:
        raise ValueError(f"CAS new value {new} outside [0, {CAS_DOM})")
    packed = expected * CAS_DOM + new
    # CAS_DOM**2 - 1 == 2**24 - 1: the top of f32's contiguous-integer range
    assert packed < CAS_DOM * CAS_DOM and float(np.float32(packed)) == packed
    return float(packed)


def unpack_cas(packed) -> Tuple[int, int]:
    """Invert :func:`pack_cas` -> ``(expected, new)``."""
    p = int(packed)
    if not 0 <= p < CAS_DOM * CAS_DOM:
        raise ValueError(f"packed CAS param {p} outside [0, {CAS_DOM ** 2})")
    return p // CAS_DOM, p % CAS_DOM

# announcement lanes (per-side combiners, ISSUE 8): every op code of a
# two-sided structure belongs to exactly one combining lane — the HEAD lane
# (the consuming side: queue dequeues, deque left-side ops) or the TAIL lane
# (the producing side: queue enqueues, deque right-side ops).  Single-sided
# structures (the stack) have one combiner and no lane split.  LANE_NONE
# marks op codes with no lane (OP_NONE, or any op on a single-lane kind).
LANE_NONE = -1
LANE_HEAD = 0
LANE_TAIL = 1


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class StackState:
    """Array-backed DFC stack with double-buffered top (paper Fig 1)."""

    values: jax.Array  # f32[capacity]
    size: jax.Array  # i32[2] — two alternating stack sizes
    epoch: jax.Array  # i32[]  — cEpoch (always even between phases)

    @property
    def active_idx(self) -> jax.Array:
        return (self.epoch // 2) % 2

    def active_size(self) -> jax.Array:
        return self.size[self.active_idx]


def init_stack(capacity: int, dtype=jnp.float32) -> StackState:
    return StackState(
        values=jnp.zeros((capacity,), dtype=dtype),
        size=jnp.zeros((2,), dtype=jnp.int32),
        epoch=jnp.zeros((), dtype=jnp.int32),
    )


def _onehot_route(src_idx: jax.Array, vals: jax.Array, n_out: int) -> jax.Array:
    """out[src_idx[j]] += vals[j] — as a one-hot matmul (MXU-friendly).

    src_idx entries outside [0, n_out) are dropped.
    """
    onehot = (src_idx[None, :] == jnp.arange(n_out)[:, None]).astype(vals.dtype)
    return onehot @ vals


def combine(
    state: StackState, ops: jax.Array, params: jax.Array
) -> Tuple[StackState, jax.Array, jax.Array]:
    """One DFC combining phase over N announcement lanes.

    Returns (new_state, responses f32[N], kinds i32[N]).
    """
    n = ops.shape[0]
    cap = state.values.shape[0]
    idx = jnp.arange(n)

    is_push = ops == OP_PUSH
    is_pop = ops == OP_POP
    push_rank = jnp.where(is_push, jnp.cumsum(is_push) - 1, -1)
    pop_rank = jnp.where(is_pop, jnp.cumsum(is_pop) - 1, -1)
    p_total = jnp.sum(is_push)
    q_total = jnp.sum(is_pop)
    n_elim = jnp.minimum(p_total, q_total)

    old_size = state.active_size()

    # --- elimination: pop_k gets push_k's param (REDUCE lines 102-110) ------
    push_by_rank = _onehot_route(push_rank, params.astype(jnp.float32), n)
    elim_pop_val = push_by_rank[jnp.clip(pop_rank, 0, n - 1)]

    # --- surplus pushes: compact above the committed prefix -----------------
    surplus_push = is_push & (push_rank >= n_elim)
    seg_idx = jnp.where(surplus_push, push_rank - n_elim, n)  # n => dropped
    segment = _onehot_route(seg_idx, params.astype(state.values.dtype), n)
    n_push_surplus = jnp.maximum(p_total - n_elim, 0)
    new_values = jax.lax.dynamic_update_slice(
        state.values,
        segment,
        (jnp.clip(old_size, 0, cap - n),),
    )
    # only the [old_size, old_size + n_push_surplus) part of the segment is
    # real; restore the tail beyond it.  Contract: capacity >= size + N.
    keep_mask = (jnp.arange(cap) >= old_size) & (
        jnp.arange(cap) < old_size + n_push_surplus
    )
    new_values = jnp.where(keep_mask, new_values, state.values)

    # --- surplus pops: read below the committed prefix ----------------------
    surplus_pop = is_pop & (pop_rank >= n_elim)
    depth = pop_rank - n_elim  # 0 == top of committed stack
    pop_src = old_size - 1 - depth
    pop_ok = surplus_pop & (pop_src >= 0)
    stack_val = state.values[jnp.clip(pop_src, 0, cap - 1)].astype(jnp.float32)

    # --- responses -----------------------------------------------------------
    kinds = jnp.full((n,), R_NONE, dtype=jnp.int32)
    kinds = jnp.where(is_push, R_ACK, kinds)
    kinds = jnp.where(is_pop & (pop_rank < n_elim), R_VALUE, kinds)
    kinds = jnp.where(pop_ok, R_VALUE, kinds)
    kinds = jnp.where(surplus_pop & ~pop_ok, R_EMPTY, kinds)
    responses = jnp.zeros((n,), dtype=jnp.float32)
    responses = jnp.where(is_pop & (pop_rank < n_elim), elim_pop_val, responses)
    responses = jnp.where(pop_ok, stack_val, responses)

    # --- publish: write the inactive size, bump epoch by 2 -------------------
    n_popped = jnp.minimum(jnp.maximum(q_total - n_elim, 0), old_size)
    new_size_val = old_size + n_push_surplus - n_popped
    inactive = (state.epoch // 2 + 1) % 2
    new_size = state.size.at[inactive].set(new_size_val)
    new_state = StackState(
        values=new_values, size=new_size, epoch=state.epoch + 2
    )
    return new_state, responses, kinds


combine_jit = jax.jit(combine)


# ------------------------------------------------------------------ reference
def sequential_reference(stack_list, ops, params):
    """Canonical linearization witness in pure Python (test oracle).

    Applies: eliminated pairs, then surplus pushes (rank order), then surplus
    pops (rank order) to a Python list; returns (new_list, responses, kinds).
    """
    n = len(ops)
    pushes = [i for i in range(n) if ops[i] == OP_PUSH]
    pops = [i for i in range(n) if ops[i] == OP_POP]
    e = min(len(pushes), len(pops))
    responses = [0.0] * n
    kinds = [R_NONE] * n
    stack = list(stack_list)
    for k in range(e):  # eliminated pairs
        kinds[pushes[k]] = R_ACK
        kinds[pops[k]] = R_VALUE
        responses[pops[k]] = float(params[pushes[k]])
    for i in pushes[e:]:  # surplus pushes
        stack.append(float(params[i]))
        kinds[i] = R_ACK
    for i in pops[e:]:  # surplus pops
        if stack:
            responses[i] = stack.pop()
            kinds[i] = R_VALUE
        else:
            kinds[i] = R_EMPTY
    return stack, responses, kinds


# ======================================================================= queue
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QueueState:
    """Ring-backed DFC queue with double-buffered (head, tail) counters.

    ``ends[b] = (head, tail)`` are absolute (monotone) counters; the occupied
    window is [head, tail), slot index = counter % capacity.
    """

    values: jax.Array  # f32[capacity] ring
    ends: jax.Array  # i32[2, 2] — two alternating (head, tail) pairs
    epoch: jax.Array  # i32[]  — cEpoch (always even between phases)

    @property
    def active_idx(self) -> jax.Array:
        return (self.epoch // 2) % 2

    def active_ends(self) -> jax.Array:
        return self.ends[self.active_idx]

    def active_size(self) -> jax.Array:
        e = self.active_ends()
        return e[1] - e[0]


def init_queue(capacity: int, dtype=jnp.float32) -> QueueState:
    return QueueState(
        values=jnp.zeros((capacity,), dtype=dtype),
        ends=jnp.zeros((2, 2), dtype=jnp.int32),
        epoch=jnp.zeros((), dtype=jnp.int32),
    )


def combine_queue(
    state: QueueState, ops: jax.Array, params: jax.Array
) -> Tuple[QueueState, jax.Array, jax.Array]:
    """One DFC queue combining phase over N announcement lanes.

    Linearization witness (shared with ``sequential_reference_queue`` and the
    Pallas kernel): dequeues drain the committed window FIFO; once drained,
    deq rank size+k pairs with enq rank k (two-sided elimination — the value
    flows announcement-to-announcement); surplus enqueues append in rank
    order; deqs beyond every enqueue return EMPTY.

    Returns (new_state, responses f32[N], kinds i32[N]).
    """
    n = ops.shape[0]
    cap = state.values.shape[0]
    ends = state.active_ends()
    head, tail = ends[0], ends[1]
    size = tail - head

    is_enq = ops == OP_ENQ
    is_deq = ops == OP_DEQ
    enq_rank = jnp.where(is_enq, jnp.cumsum(is_enq) - 1, -1)
    deq_rank = jnp.where(is_deq, jnp.cumsum(is_deq) - 1, -1)
    p_total = jnp.sum(is_enq)
    q_total = jnp.sum(is_deq)
    n_from_q = jnp.minimum(q_total, size)  # deqs served from the ring
    n_elim = jnp.minimum(jnp.maximum(q_total - size, 0), p_total)

    # --- deqs served FIFO from the committed window -------------------------
    served = is_deq & (deq_rank < size)
    ring_val = state.values[(head + jnp.clip(deq_rank, 0, None)) % cap].astype(
        jnp.float32
    )

    # --- drained: deq rank size+k pairs with enq rank k ---------------------
    enq_by_rank = _onehot_route(enq_rank, params.astype(jnp.float32), n)
    paired = is_deq & (deq_rank >= size) & (deq_rank - size < n_elim)
    pair_val = enq_by_rank[jnp.clip(deq_rank - size, 0, n - 1)]
    empty = is_deq & (deq_rank >= size + n_elim)

    # --- surplus enqs append at the tail ------------------------------------
    surplus_enq = is_enq & (enq_rank >= n_elim)
    n_enq_surplus = p_total - n_elim
    seg_idx = jnp.where(surplus_enq, enq_rank - n_elim, n)
    segment = _onehot_route(seg_idx, params.astype(state.values.dtype), n)
    pos = (tail + jnp.arange(n)) % cap
    write = jnp.arange(n) < n_enq_surplus
    new_values = state.values.at[jnp.where(write, pos, cap)].set(
        segment, mode="drop"
    )

    # --- responses -----------------------------------------------------------
    kinds = jnp.full((n,), R_NONE, dtype=jnp.int32)
    kinds = jnp.where(is_enq, R_ACK, kinds)
    kinds = jnp.where(served | paired, R_VALUE, kinds)
    kinds = jnp.where(empty, R_EMPTY, kinds)
    responses = jnp.zeros((n,), dtype=jnp.float32)
    responses = jnp.where(served, ring_val, responses)
    responses = jnp.where(paired, pair_val, responses)

    # --- publish: write the inactive (head, tail), bump epoch by 2 -----------
    new_ends = jnp.stack([head + n_from_q, tail + n_enq_surplus])
    inactive = (state.epoch // 2 + 1) % 2
    new_state = QueueState(
        values=new_values,
        ends=state.ends.at[inactive].set(new_ends),
        epoch=state.epoch + 2,
    )
    return new_state, responses, kinds


combine_queue_jit = jax.jit(combine_queue)


def sequential_reference_queue(queue_list, ops, params):
    """Canonical queue linearization witness in pure Python (test oracle)."""
    n = len(ops)
    enqs = [i for i in range(n) if ops[i] == OP_ENQ]
    deqs = [i for i in range(n) if ops[i] == OP_DEQ]
    responses = [0.0] * n
    kinds = [R_NONE] * n
    q = list(queue_list)
    for i in enqs:
        kinds[i] = R_ACK
    di = 0
    while di < len(deqs) and q:  # serve from the committed queue
        responses[deqs[di]] = q.pop(0)
        kinds[deqs[di]] = R_VALUE
        di += 1
    ei = 0
    while di < len(deqs) and ei < len(enqs):  # eliminated pairs
        responses[deqs[di]] = float(params[enqs[ei]])
        kinds[deqs[di]] = R_VALUE
        di += 1
        ei += 1
    while di < len(deqs):
        kinds[deqs[di]] = R_EMPTY
        di += 1
    for i in enqs[ei:]:  # surplus enqueues
        q.append(float(params[i]))
    return q, responses, kinds


# ======================================================================= deque
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DequeState:
    """Ring-backed DFC deque with double-buffered (left, right) counters.

    ``ends[b] = (left, right)``; the occupied window is [left, right), slot
    index = counter % capacity (counters may go negative — Python-style
    modulo keeps slots in range).
    """

    values: jax.Array  # f32[capacity] ring
    ends: jax.Array  # i32[2, 2] — two alternating (left, right) pairs
    epoch: jax.Array  # i32[]

    @property
    def active_idx(self) -> jax.Array:
        return (self.epoch // 2) % 2

    def active_ends(self) -> jax.Array:
        return self.ends[self.active_idx]

    def active_size(self) -> jax.Array:
        e = self.active_ends()
        return e[1] - e[0]


def init_deque(capacity: int, dtype=jnp.float32) -> DequeState:
    return DequeState(
        values=jnp.zeros((capacity,), dtype=dtype),
        ends=jnp.zeros((2, 2), dtype=jnp.int32),
        epoch=jnp.zeros((), dtype=jnp.int32),
    )


def combine_deque(
    state: DequeState, ops: jax.Array, params: jax.Array
) -> Tuple[DequeState, jax.Array, jax.Array]:
    """One DFC deque combining phase over N announcement lanes.

    Linearization witness (shared with ``sequential_reference_deque`` and the
    Pallas kernel): same-side eliminated pairs first (pushL_k;popL_k and
    pushR_k;popR_k adjacent — state untouched), then the LEFT surplus in rank
    order, then the RIGHT surplus in rank order.  Right surplus pops may
    therefore consume values pushed left in the same phase.

    Returns (new_state, responses f32[N], kinds i32[N]).
    """
    n = ops.shape[0]
    cap = state.values.shape[0]
    ends = state.active_ends()
    left, right = ends[0], ends[1]
    size = right - left

    is_pl = ops == OP_PUSHL
    is_ql = ops == OP_POPL
    is_pr = ops == OP_PUSHR
    is_qr = ops == OP_POPR
    pl_rank = jnp.where(is_pl, jnp.cumsum(is_pl) - 1, -1)
    ql_rank = jnp.where(is_ql, jnp.cumsum(is_ql) - 1, -1)
    pr_rank = jnp.where(is_pr, jnp.cumsum(is_pr) - 1, -1)
    qr_rank = jnp.where(is_qr, jnp.cumsum(is_qr) - 1, -1)
    npl, nql = jnp.sum(is_pl), jnp.sum(is_ql)
    npr, nqr = jnp.sum(is_pr), jnp.sum(is_qr)
    nl_elim = jnp.minimum(npl, nql)
    nr_elim = jnp.minimum(npr, nqr)

    # --- same-side elimination: pop_k gets push_k's param -------------------
    f32params = params.astype(jnp.float32)
    pl_by_rank = _onehot_route(pl_rank, f32params, n)
    pr_by_rank = _onehot_route(pr_rank, f32params, n)
    eliml = is_ql & (ql_rank < nl_elim)
    elimr = is_qr & (qr_rank < nr_elim)
    eliml_val = pl_by_rank[jnp.clip(ql_rank, 0, n - 1)]
    elimr_val = pr_by_rank[jnp.clip(qr_rank, 0, n - 1)]

    # --- left surplus (pushes XOR pops) -------------------------------------
    sl = jnp.maximum(npl - nl_elim, 0)  # surplus pushes left
    tl = jnp.maximum(nql - nl_elim, 0)  # surplus pops left
    surplus_pl = is_pl & (pl_rank >= nl_elim)
    seg_l = _onehot_route(
        jnp.where(surplus_pl, pl_rank - nl_elim, n), params.astype(state.values.dtype), n
    )
    # push j lands at slot left-1-j (later pushes further left)
    posl = (left - 1 - jnp.arange(n)) % cap
    vals1 = state.values.at[jnp.where(jnp.arange(n) < sl, posl, cap)].set(
        seg_l, mode="drop"
    )
    dl = jnp.minimum(tl, size)  # left pops consume the committed front
    surplus_ql = is_ql & (ql_rank >= nl_elim)
    kl = ql_rank - nl_elim
    lpop_ok = surplus_ql & (kl < size)
    lpop_val = state.values[(left + jnp.clip(kl, 0, None)) % cap].astype(jnp.float32)
    size_after = size + sl - dl  # window after the left surplus

    # --- right surplus (pushes XOR pops), applied after the left ------------
    sr = jnp.maximum(npr - nr_elim, 0)
    tr = jnp.maximum(nqr - nr_elim, 0)
    surplus_pr = is_pr & (pr_rank >= nr_elim)
    seg_r = _onehot_route(
        jnp.where(surplus_pr, pr_rank - nr_elim, n), params.astype(state.values.dtype), n
    )
    posr = (right + jnp.arange(n)) % cap
    new_values = vals1.at[jnp.where(jnp.arange(n) < sr, posr, cap)].set(
        seg_r, mode="drop"
    )
    dr = jnp.minimum(tr, size_after)
    surplus_qr = is_qr & (qr_rank >= nr_elim)
    kr = qr_rank - nr_elim
    rpop_ok = surplus_qr & (kr < size_after)
    # right pop k reads slot right-1-k: committed when k < size, otherwise a
    # value pushed left in this phase (vals1 holds both)
    rpop_val = vals1[(right - 1 - jnp.clip(kr, 0, None)) % cap].astype(jnp.float32)

    # --- responses -----------------------------------------------------------
    kinds = jnp.full((n,), R_NONE, dtype=jnp.int32)
    kinds = jnp.where(is_pl | is_pr, R_ACK, kinds)
    kinds = jnp.where(eliml | elimr | lpop_ok | rpop_ok, R_VALUE, kinds)
    kinds = jnp.where(surplus_ql & ~lpop_ok, R_EMPTY, kinds)
    kinds = jnp.where(surplus_qr & ~rpop_ok, R_EMPTY, kinds)
    responses = jnp.zeros((n,), dtype=jnp.float32)
    responses = jnp.where(eliml, eliml_val, responses)
    responses = jnp.where(elimr, elimr_val, responses)
    responses = jnp.where(lpop_ok, lpop_val, responses)
    responses = jnp.where(rpop_ok, rpop_val, responses)

    # --- publish: write the inactive (left, right), bump epoch by 2 ----------
    new_ends = jnp.stack([left - sl + dl, right + sr - dr])
    inactive = (state.epoch // 2 + 1) % 2
    new_state = DequeState(
        values=new_values,
        ends=state.ends.at[inactive].set(new_ends),
        epoch=state.epoch + 2,
    )
    return new_state, responses, kinds


combine_deque_jit = jax.jit(combine_deque)


def sequential_reference_deque(deque_list, ops, params):
    """Canonical deque linearization witness in pure Python (test oracle)."""
    n = len(ops)
    pl = [i for i in range(n) if ops[i] == OP_PUSHL]
    ql = [i for i in range(n) if ops[i] == OP_POPL]
    pr = [i for i in range(n) if ops[i] == OP_PUSHR]
    qr = [i for i in range(n) if ops[i] == OP_POPR]
    nl = min(len(pl), len(ql))
    nr = min(len(pr), len(qr))
    responses = [0.0] * n
    kinds = [R_NONE] * n
    d = list(deque_list)
    for k in range(nl):  # same-side eliminated pairs
        kinds[pl[k]] = R_ACK
        kinds[ql[k]] = R_VALUE
        responses[ql[k]] = float(params[pl[k]])
    for k in range(nr):
        kinds[pr[k]] = R_ACK
        kinds[qr[k]] = R_VALUE
        responses[qr[k]] = float(params[pr[k]])
    for i in pl[nl:]:  # left surplus first…
        d.insert(0, float(params[i]))
        kinds[i] = R_ACK
    for i in ql[nl:]:
        if d:
            responses[i] = d.pop(0)
            kinds[i] = R_VALUE
        else:
            kinds[i] = R_EMPTY
    for i in pr[nr:]:  # …then right surplus
        d.append(float(params[i]))
        kinds[i] = R_ACK
    for i in qr[nr:]:
        if d:
            responses[i] = d.pop()
            kinds[i] = R_VALUE
        else:
            kinds[i] = R_EMPTY
    return d, responses, kinds


# ========================================================================= map
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MapState:
    """Bucketed-hash DFC map with a double-buffered entry count.

    Fixed capacity, open addressing confined to one bucket: slot ``i``
    belongs to bucket ``i // bslots`` where ``bslots = min(capacity,
    MAP_BUCKET_SLOTS)``, and a key only ever lives in its hash bucket's
    ``bslots`` slots — an insert into a bucket with no free slot is a CLEAN
    rejection (``R_FULL``; state untouched).  Unlike the ring structures
    there is no committed/inactive split of the table itself: a combining
    phase mutates ``keys/values/occupied`` in place and durability comes
    from the runtime's slot-alternating full-state snapshots (the same
    generic ``_persist_shard`` path every kind rides).  Only ``count`` is
    double-buffered by epoch parity so committed sizes are readable without
    trusting an in-flight phase.
    """

    keys: jax.Array  # i32[capacity]
    values: jax.Array  # f32[capacity]
    occupied: jax.Array  # i32[capacity] — 0/1 per slot
    count: jax.Array  # i32[2] — two alternating live-entry counts
    epoch: jax.Array  # i32[]  — cEpoch (always even between phases)

    @property
    def active_idx(self) -> jax.Array:
        return (self.epoch // 2) % 2

    def active_count(self) -> jax.Array:
        return self.count[self.active_idx]


def map_geometry(capacity: int) -> Tuple[int, int]:
    """(slots per bucket, bucket count) of a map shard of ``capacity``.

    Capacity must be a multiple of the bucket width so every slot belongs
    to exactly one bucket.
    """
    bslots = min(capacity, MAP_BUCKET_SLOTS)
    if capacity % bslots != 0:
        raise ValueError(
            f"map capacity {capacity} not a multiple of bucket width {bslots}"
        )
    return bslots, capacity // bslots


def init_map(capacity: int, dtype=jnp.float32) -> MapState:
    map_geometry(capacity)  # validate up front
    return MapState(
        keys=jnp.zeros((capacity,), jnp.int32),
        values=jnp.zeros((capacity,), dtype=dtype),
        occupied=jnp.zeros((capacity,), jnp.int32),
        count=jnp.zeros((2,), jnp.int32),
        epoch=jnp.zeros((), jnp.int32),
    )


def map_bucket(keys, n_buckets: int) -> jax.Array:
    """Bucket of each key inside ONE map shard (device path).

    A second multiplicative mix, decorrelated from the router's shard hash
    (which stops after the first xor-shift): keys that collide into one
    shard still spread across its buckets.
    """
    h = jnp.asarray(keys).astype(jnp.uint32) * jnp.uint32(2654435761)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(2246822519)
    h = h ^ (h >> 13)
    return (h % jnp.uint32(n_buckets)).astype(jnp.int32)


def map_bucket_host(keys, n_buckets: int) -> np.ndarray:
    """NumPy twin of :func:`map_bucket` for host-side oracles and rebuilds."""
    h = (np.asarray(keys, np.uint64) * 2654435761) & 0xFFFFFFFF
    h = h ^ (h >> 16)
    h = (h * 2246822519) & 0xFFFFFFFF
    h = h ^ (h >> 13)
    return (h % n_buckets).astype(np.int32)


def combine_map(
    state: MapState, keys: jax.Array, ops: jax.Array, params: jax.Array
) -> Tuple[MapState, jax.Array, jax.Array]:
    """One DFC map combining phase over N keyed announcement lanes.

    Map ops do not commute (insert/delete/CAS on one key), so there is no
    elimination pass: the lanes are applied in announcement order by a
    ``lax.scan`` — the linearization IS lane order, shared with
    ``sequential_reference_map`` and the Pallas twin.  Per lane:

      op              hit                      miss
      --------------  -----------------------  -------------------------
      OP_MAP_INSERT   overwrite, R_ACK         free slot: write, R_ACK;
                                               bucket full: R_FULL
      OP_MAP_LOOKUP   R_VALUE (resp=value)     R_EMPTY
      OP_MAP_DELETE   clear slot, R_VALUE      R_EMPTY
      OP_MAP_CAS      match: write new,        R_EMPTY
                      R_VALUE (resp=old);
                      mismatch: R_CAS_FAIL
                      (resp=current)

    Returns (new_state, responses f32[N], kinds i32[N]).
    """
    cap = state.keys.shape[0]
    bslots, n_buckets = map_geometry(cap)
    slot_bucket = jnp.arange(cap, dtype=jnp.int32) // bslots
    slot_idx = jnp.arange(cap, dtype=jnp.int32)

    def lane(carry, xs):
        mk, mv, mo, cnt = carry
        key, op, par = xs
        in_b = slot_bucket == map_bucket(key, n_buckets)
        occ = mo != 0
        # key 0 is legal, so a hit needs the occupied flag, not just key match
        hit = in_b & occ & (mk == key)
        has_hit = jnp.any(hit)
        hit_idx = jnp.argmax(hit).astype(jnp.int32)
        free = in_b & ~occ
        has_free = jnp.any(free)
        free_idx = jnp.argmax(free).astype(jnp.int32)
        cur = mv[jnp.where(has_hit, hit_idx, 0)].astype(jnp.float32)

        is_ins = op == OP_MAP_INSERT
        is_lku = op == OP_MAP_LOOKUP
        is_del = op == OP_MAP_DELETE
        is_cas = op == OP_MAP_CAS
        expected = jnp.floor(par / CAS_DOM)
        cas_new = par - expected * CAS_DOM
        cas_hit = is_cas & has_hit
        cas_ok = cas_hit & (cur == expected)

        do_ins = is_ins & (has_hit | has_free)
        do_del = is_del & has_hit
        do_write = do_ins | cas_ok
        wslot = jnp.where(cas_ok | has_hit, hit_idx, free_idx)
        wval = jnp.where(is_cas, cas_new, par).astype(mv.dtype)
        wmask = do_write & (slot_idx == wslot)
        dmask = do_del & (slot_idx == hit_idx)
        mk = jnp.where(wmask, key, jnp.where(dmask, 0, mk))
        mv = jnp.where(wmask, wval, jnp.where(dmask, 0, mv))
        mo = jnp.where(wmask, 1, jnp.where(dmask, 0, mo))
        cnt = (
            cnt
            + (is_ins & ~has_hit & has_free).astype(jnp.int32)
            - do_del.astype(jnp.int32)
        )

        kind = jnp.full((), R_NONE, jnp.int32)
        kind = jnp.where(do_ins, R_ACK, kind)
        kind = jnp.where(is_ins & ~has_hit & ~has_free, R_FULL, kind)
        kind = jnp.where((is_lku | is_del | is_cas) & ~has_hit, R_EMPTY, kind)
        kind = jnp.where((is_lku | do_del | cas_ok) & has_hit, R_VALUE, kind)
        kind = jnp.where(cas_hit & ~cas_ok, R_CAS_FAIL, kind)
        resp = jnp.where((is_lku | is_del | is_cas) & has_hit, cur, 0.0)
        return (mk, mv, mo, cnt), (resp, kind.astype(jnp.int32))

    (mk, mv, mo, cnt), (responses, kinds) = jax.lax.scan(
        lane,
        (state.keys, state.values, state.occupied, state.active_count()),
        (
            jnp.asarray(keys).astype(jnp.int32),
            jnp.asarray(ops).astype(jnp.int32),
            jnp.asarray(params).astype(jnp.float32),
        ),
    )

    # --- publish: write the inactive count, bump epoch by 2 ------------------
    inactive = (state.epoch // 2 + 1) % 2
    new_state = MapState(
        keys=mk,
        values=mv,
        occupied=mo,
        count=state.count.at[inactive].set(cnt),
        epoch=state.epoch + 2,
    )
    return new_state, responses, kinds


combine_map_jit = jax.jit(combine_map)


def sequential_reference_map(entries, keys, ops, params, capacity=None):
    """Canonical map linearization witness in pure Python (test oracle).

    ``entries`` is a ``{int key: float value}`` dict; lanes apply in
    announcement order.  With ``capacity``, an insert of an ABSENT key is
    rejected ``R_FULL`` iff its hash bucket already holds ``bslots`` live
    keys — bucket occupancy depends only on the live-key set (deletes fully
    clear their slot), so the dict oracle models the fixed table exactly.
    CAS decode runs in float32 so the oracle's arithmetic is bit-identical
    to the device's.  Returns (new_entries, responses, kinds).
    """
    n = len(ops)
    responses = [0.0] * n
    kinds = [R_NONE] * n
    m = dict(entries)
    if capacity is not None:
        bslots, n_buckets = map_geometry(int(capacity))
        bucket_of = {
            k: int(map_bucket_host([k], n_buckets)[0]) for k in m
        }
    for i in range(n):
        op = int(ops[i])
        key = int(keys[i])
        par = float(np.float32(params[i]))
        if op == OP_MAP_INSERT:
            if key not in m and capacity is not None:
                b = int(map_bucket_host([key], n_buckets)[0])
                if sum(1 for v in bucket_of.values() if v == b) >= bslots:
                    kinds[i] = R_FULL
                    continue
                bucket_of[key] = b
            m[key] = par
            kinds[i] = R_ACK
        elif op == OP_MAP_LOOKUP:
            if key in m:
                responses[i] = m[key]
                kinds[i] = R_VALUE
            else:
                kinds[i] = R_EMPTY
        elif op == OP_MAP_DELETE:
            if key in m:
                responses[i] = m.pop(key)
                kinds[i] = R_VALUE
                if capacity is not None:
                    bucket_of.pop(key, None)
            else:
                kinds[i] = R_EMPTY
        elif op == OP_MAP_CAS:
            expected = float(np.floor(np.float32(par) / np.float32(CAS_DOM)))
            new = float(np.float32(par) - np.float32(expected) * np.float32(CAS_DOM))
            if key not in m:
                kinds[i] = R_EMPTY
            elif m[key] == expected:
                responses[i] = m[key]
                m[key] = new
                kinds[i] = R_VALUE
            else:
                responses[i] = m[key]
                kinds[i] = R_CAS_FAIL
    return m, responses, kinds


# ================================================================== registry
@dataclasses.dataclass(frozen=True)
class StructSpec:
    """One of the paper's structures, as seen by multi-object runtimes.

    ``init``/``combine``/``reference`` are the single-object entry points
    above; ``n_opcodes`` bounds the valid op-code range [0, n_opcodes) so a
    router can generate well-formed random workloads per structure.

    ``op_lanes`` maps each op code to its announcement lane (per-side
    combiners, ISSUE 8): ``LANE_HEAD`` for the consuming side (dequeue /
    left-side deque ops), ``LANE_TAIL`` for the producing side (enqueue /
    right-side deque ops), ``LANE_NONE`` for OP_NONE or any op on a
    single-lane kind.  A kind is lane-splittable iff some op code maps to
    each of the two lanes.
    """

    kind: str
    state_cls: type
    init: Callable[..., Any]
    combine: Callable[..., Any]
    reference: Callable[..., Any]
    n_opcodes: int
    op_lanes: Tuple[int, ...] = ()
    # keyed kinds interpret the announced KEY as part of the op (the map's
    # hash key), so their combine/reference take an extra keys argument:
    # ``combine(state, keys, ops, params)`` and
    # ``reference(contents, keys, ops, params, capacity=None)``.
    keyed: bool = False

    @property
    def lane_splittable(self) -> bool:
        return LANE_HEAD in self.op_lanes and LANE_TAIL in self.op_lanes


STRUCTS: Dict[str, StructSpec] = {
    "stack": StructSpec(
        "stack", StackState, init_stack, combine, sequential_reference, 3,
        op_lanes=(LANE_NONE, LANE_NONE, LANE_NONE),  # one combiner, no split
    ),
    "queue": StructSpec(
        "queue",
        QueueState,
        init_queue,
        combine_queue,
        sequential_reference_queue,
        3,
        # OP_ENQ produces at the tail, OP_DEQ consumes at the head
        op_lanes=(LANE_NONE, LANE_TAIL, LANE_HEAD),
    ),
    "deque": StructSpec(
        "deque",
        DequeState,
        init_deque,
        combine_deque,
        sequential_reference_deque,
        5,
        # left-side ops (pushL/popL) ride the head lane, right-side ops
        # (pushR/popR) the tail lane — the serving tier's arrivals
        # (push_back) and admission pops (pop_front) land on opposite lanes
        op_lanes=(LANE_NONE, LANE_HEAD, LANE_HEAD, LANE_TAIL, LANE_TAIL),
    ),
    "map": StructSpec(
        "map",
        MapState,
        init_map,
        combine_map,
        sequential_reference_map,
        5,
        # map ops do not commute, so there is no per-side split: every op
        # rides the single combiner lane
        op_lanes=(LANE_NONE,) * 5,
        keyed=True,
    ),
}


def lane_of_ops(kind: str, ops) -> jax.Array:
    """Per-op announcement lane of a batch targeting ``kind`` shards
    (device path): LANE_HEAD / LANE_TAIL / LANE_NONE, via the kind's
    ``op_lanes`` table."""
    table = jnp.asarray(STRUCTS[kind].op_lanes, jnp.int32)
    o = jnp.asarray(ops, jnp.int32)
    return table[jnp.clip(o, 0, table.shape[0] - 1)]


def lane_of_ops_host(kind: str, ops) -> np.ndarray:
    """NumPy twin of :func:`lane_of_ops` for the runtime's host-side lane
    routing and oracles."""
    table = np.asarray(STRUCTS[kind].op_lanes, np.int32)
    o = np.asarray(ops, np.int32)
    return table[np.clip(o, 0, table.shape[0] - 1)]


def struct_kind(state) -> str:
    """Structure kind of a (possibly shard-stacked) state pytree."""
    for kind, spec in STRUCTS.items():
        if isinstance(state, spec.state_cls):
            return kind
    raise TypeError(f"not a DFC structure state: {type(state)!r}")


# Stable integer codes for structure kinds, used wherever a kind has to live
# in an array (the sharded runtime's per-shard ``kind`` metadata column) or
# in compact durable records.  Codes are assigned in sorted-kind order so they
# cannot drift as STRUCTS grows.
KIND_CODES: Dict[str, int] = {kind: i for i, kind in enumerate(sorted(STRUCTS))}
CODE_KINDS: Dict[int, str] = {i: kind for kind, i in KIND_CODES.items()}


def state_from_contents(kind: str, contents, capacity: int, epoch: int):
    """Build a committed single-object state holding exactly ``contents``.

    Used by shard merges: the absorbing shard's post-merge state is rebuilt
    from its merged value list (bottom-to-top for the stack, left-to-right
    for the ring structures) at the given (even) epoch — the active buffer
    selected by ``epoch`` holds the window [0, len(contents)).
    """
    spec = STRUCTS[kind]
    n = len(contents)
    if n > capacity:
        raise ValueError(f"{n} values exceed capacity {capacity}")
    state = spec.init(capacity)
    active = (epoch // 2) % 2
    if kind == "map":
        # contents is a list of (key, value) pairs; rebuild by host-side
        # bucket probe.  Merged shards hold disjoint key sets (routing is
        # injective per key), but the union can still overflow one bucket —
        # surface that as the same ValueError a too-long ring would raise.
        bslots, n_buckets = map_geometry(capacity)
        mk = np.zeros((capacity,), np.int32)
        mv = np.zeros((capacity,), np.asarray(state.values).dtype)
        mo = np.zeros((capacity,), np.int32)
        for key, val in contents:
            base = int(map_bucket_host([int(key)], n_buckets)[0]) * bslots
            for j in range(bslots):
                if not mo[base + j]:
                    mk[base + j] = int(key)
                    mv[base + j] = val
                    mo[base + j] = 1
                    break
            else:
                raise ValueError(
                    f"map bucket {base // bslots} overflows rebuilding "
                    f"{n} entries at capacity {capacity}"
                )
        return MapState(
            keys=jnp.asarray(mk),
            values=jnp.asarray(mv),
            occupied=jnp.asarray(mo),
            count=state.count.at[active].set(n),
            epoch=jnp.asarray(epoch, jnp.int32),
        )
    values = state.values.at[: max(n, 0)].set(
        jnp.asarray(contents, state.values.dtype)
    ) if n else state.values
    if kind == "stack":
        return StackState(
            values=values,
            size=state.size.at[active].set(n),
            epoch=jnp.asarray(epoch, jnp.int32),
        )
    ends = state.ends.at[active].set(jnp.asarray([0, n], jnp.int32))
    cls = spec.state_cls
    return cls(values=values, ends=ends, epoch=jnp.asarray(epoch, jnp.int32))


# ============================================================ announce ring
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AnnounceRing:
    """Device-side announcement queue: a preallocated ring of (key, op,
    param) lanes that announced batches land in, so combining phases consume
    device arrays directly instead of reconstructing them from per-thread
    durable records each phase (the durable mirror — SimFS — keeps only the
    compact JSON needed for recovery and replay).

    ``tail`` is an absolute (monotone) producer counter; slot index =
    counter % slots.  Consumption bookkeeping (which spans are still live) is
    host-side: the ring itself is volatile staging, rebuilt from the durable
    announcement mirror on recovery.

    ``lanes`` (ISSUE 8) is the per-slot announcement lane of the staged op —
    LANE_HEAD / LANE_TAIL for ops targeting a lane-split shard, LANE_NONE
    otherwise — so a per-side combine dispatch can drain one lane's ops
    straight off the device ring (``ring_drain(..., lane=...)`` masks the
    other lane's slots to OP_NONE without a host round-trip).
    """

    keys: jax.Array  # i32[slots]
    ops: jax.Array  # i32[slots]
    params: jax.Array  # f32[slots]
    lanes: jax.Array  # i32[slots] — LANE_HEAD/LANE_TAIL/LANE_NONE per slot
    tail: jax.Array  # i32[] — absolute producer counter


def init_announce_ring(slots: int) -> AnnounceRing:
    """STRUCTS-style init: an empty device ring of ``slots`` lanes.

    ``slots`` must be a power of two: the device-side ``tail`` is an int32
    that overflows (wraps mod 2^32) after ~2^31 announced lanes, while the
    host mirror (``ShardedDFCRuntime._ring_tail``) is an unbounded Python
    int.  With a power-of-two slot count, ``tail % slots`` is congruent
    under the int32 wraparound (2^32 is a multiple of ``slots``), so the
    two counters keep agreeing on slot indices forever; with any other slot
    count they silently diverge after the overflow.
    """
    if slots <= 0 or (slots & (slots - 1)) != 0:
        raise ValueError(f"ring slots must be a power of two, got {slots}")
    return AnnounceRing(
        keys=jnp.zeros((slots,), jnp.int32),
        ops=jnp.full((slots,), OP_NONE, jnp.int32),
        params=jnp.zeros((slots,), jnp.float32),
        lanes=jnp.full((slots,), LANE_NONE, jnp.int32),
        tail=jnp.zeros((), jnp.int32),
    )


@jax.jit
def ring_announce(
    ring: AnnounceRing,
    keys: jax.Array,
    ops: jax.Array,
    params: jax.Array,
    lanes: jax.Array = None,
) -> AnnounceRing:
    """Land one announced batch at the ring tail (device-side scatter).

    The caller guarantees the span [tail, tail+n) does not overlap a span
    that is still awaiting its combining phase (host-side bookkeeping in the
    runtime); the write itself is one masked scatter per field.  ``lanes``
    (optional) stages each op's announcement lane alongside it — the
    lane-split runtime computes it once at announce time (op code x target
    shard kind) so per-side drains never recompute routing.
    """
    n = ops.shape[0]
    slots = ring.keys.shape[0]
    pos = (ring.tail + jnp.arange(n)) % slots
    lane_col = (
        jnp.full((n,), LANE_NONE, jnp.int32)
        if lanes is None
        else jnp.asarray(lanes).astype(jnp.int32)
    )
    return AnnounceRing(
        keys=ring.keys.at[pos].set(jnp.asarray(keys).astype(jnp.int32)),
        ops=ring.ops.at[pos].set(jnp.asarray(ops).astype(jnp.int32)),
        params=ring.params.at[pos].set(jnp.asarray(params).astype(jnp.float32)),
        lanes=ring.lanes.at[pos].set(lane_col),
        tail=ring.tail + n,
    )


def ring_has_room(slots: int, tail: int, oldest_live: int, n: int) -> bool:
    """Host-side admission check for a span of ``n`` lanes landing at absolute
    position ``tail``: the write must not wrap onto the OLDEST span still
    awaiting its combining phase (``oldest_live`` is that span's absolute
    start; pass ``tail`` itself when no span is live).  The sharded
    runtime's ``_register_live`` is the canonical caller — an announcement
    that fails this check falls back to the host-upload path."""
    return n <= slots and (tail + n) - oldest_live <= slots


@jax.jit
def _ring_gather(ring: AnnounceRing, idx: jax.Array):
    return ring.keys[idx], ring.ops[idx], ring.params[idx]


@functools.partial(jax.jit, static_argnames=("lane",))
def _ring_gather_lane(ring: AnnounceRing, idx: jax.Array, lane: int):
    keys, ops, params = _ring_gather(ring, idx)
    keep = ring.lanes[idx] == lane
    return keys, jnp.where(keep, ops, OP_NONE), params


def ring_drain(ring: AnnounceRing, start: int, n: int, lane: int = None):
    """Read span [start, start+n) of the ring as device arrays (the combine
    path's view; no host round-trip).  ``start`` is the absolute counter the
    span was announced at.  With ``lane``, ops staged on the OTHER lane are
    masked to OP_NONE (lane positions are preserved, so per-op bookkeeping
    still lines up with the unfiltered span) — the per-side combine
    dispatch's view of a mixed span."""
    slots = int(ring.keys.shape[0])
    idx = (start + np.arange(n, dtype=np.int64)) % slots
    if lane is None:
        return _ring_gather(ring, jnp.asarray(idx, jnp.int32))
    return _ring_gather_lane(ring, jnp.asarray(idx, jnp.int32), int(lane))


def ring_announce_phases(
    ring: AnnounceRing,
    keys: jax.Array,
    ops: jax.Array,
    params: jax.Array,
    lanes: jax.Array = None,
) -> AnnounceRing:
    """Land a whole PHASE SCHEDULE — ``[K, pad]`` per-phase batches, padded
    with ``OP_NONE`` lanes — at the ring tail in ONE device scatter.  The
    K phases occupy the contiguous span ``[tail, tail + K*pad)``; the fused
    phase loop reads them back with :func:`ring_drain_phases`."""
    return ring_announce(
        ring,
        keys.reshape(-1),
        ops.reshape(-1),
        params.reshape(-1),
        None if lanes is None else lanes.reshape(-1),
    )


def ring_drain_phases(
    ring: AnnounceRing, start: int, k: int, pad: int, lane: int = None
):
    """Consume the announcement ring ACROSS A PHASE AXIS: read the span of
    ``k`` phases of ``pad`` lanes each announced at absolute position
    ``start`` back as ``[K, pad]`` device arrays — the fused K-phase
    dispatch's input view, one gather for the whole schedule instead of one
    per phase.  ``lane`` filters to one announcement lane, as in
    :func:`ring_drain`."""
    keys, ops, params = ring_drain(ring, start, k * pad, lane=lane)
    return (
        keys.reshape(k, pad), ops.reshape(k, pad), params.reshape(k, pad)
    )


# ------------------------------------------------------ phase-intent records
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PhaseIntents:
    """Device-side persist-intent log of a fused K-phase combine.

    A fused dispatch (``dfc_multi_phase_step`` / the runtime's
    ``phase_loop``) commits NOTHING durably by itself: it accumulates, per
    phase, everything the host needs to later issue that phase's pwb/pfence
    batch — which shards the phase touched, the epoch each touched shard
    must commit to, and the cumulative combiner counters its slot metadata
    must record.  The host drains this log phase-by-phase behind the device,
    replaying the exact serial persistence schedule.

    All leaves carry a leading ``K`` (phase) axis over ``S`` shards:

      * ``epoch``      — ``i32[K, S]``: per-shard epoch AFTER phase k (the
        two-increment commit target of every op phase k routed to shard s),
      * ``touched``    — ``bool[K, S]``: shard s received ops in phase k
        (untouched shards keep state AND epoch: no phantom phases),
      * ``phases_cum`` — ``i32[K, S]``: combining phases absorbed by shard s
        up to and including phase k, counted from this dispatch's start,
      * ``ops_cum``    — ``i32[K, S]``: ops combined into shard s likewise.

    The cumulative counters start at zero: the runtime adds its durable
    ``meta`` baseline when it turns an intent into a slot persist.
    """

    epoch: jax.Array  # i32[K, S]
    touched: jax.Array  # bool[K, S]
    phases_cum: jax.Array  # i32[K, S]
    ops_cum: jax.Array  # i32[K, S]


# ============================================================ shard stacking
def replicate_state(state, n_shards: int):
    """Stack ``n_shards`` copies of a freshly-initialized state into one
    pytree with a leading shard axis on every leaf (``vmap``-ready)."""
    return jax.tree_util.tree_map(
        lambda leaf: jnp.broadcast_to(leaf, (n_shards,) + leaf.shape), state
    )


def init_sharded(kind: str, n_shards: int, capacity: int, dtype=jnp.float32):
    """``n_shards`` homogeneous DFC objects as one stacked pytree.

    Leaf shapes: stack ``values[S, cap] / size[S, 2] / epoch[S]``; queue and
    deque ``values[S, cap] / ends[S, 2, 2] / epoch[S]``.  Each shard keeps its
    own epoch, so shards commit (and recover) independently.
    """
    return replicate_state(STRUCTS[kind].init(capacity, dtype), n_shards)


def shard_slice(state, s: int):
    """Extract shard ``s`` of a stacked state as a single-object state."""
    return jax.tree_util.tree_map(lambda leaf: leaf[s], state)


def stack_shards(shard_states):
    """Inverse of ``shard_slice`` over all shards: list of single-object
    states -> one stacked state."""
    return jax.tree_util.tree_map(lambda *leaves: jnp.stack(leaves), *shard_states)
