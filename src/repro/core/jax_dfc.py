"""TPU-native DFC: the paper's combiner as a data-parallel JAX op.

The paper's combiner walks an announcement array sequentially, eliminating
push/pop pairs and applying the surplus to a linked-list stack.  On TPU the
same *semantic combining* is done in one vectorized pass over the
announcement lanes:

  * rank-matching elimination — the k-th announced push pairs with the k-th
    announced pop (all batch ops are concurrent, so any pairing linearizes);
    computed with prefix sums over the lane masks,
  * the stack is an array `values[capacity]` with **two alternating size
    pointers** `size[2]` — exactly the paper's two `top`s: both sizes share
    the storage prefix, a combine phase only writes *above* the committed
    prefix (surplus pushes) and publishes by flipping the active size with an
    epoch bump of +2.  A crash mid-combine leaves the active prefix intact.
  * all permutations (rank-compaction, pair-value routing) are expressed as
    one-hot matmuls so the hot path maps onto the MXU (see
    `repro/kernels/dfc_reduce` for the Pallas kernel of this function).

Linearization order of a combined batch (the canonical witness used by the
tests): eliminated pairs first (push_k, pop_k adjacent, k ascending), then
surplus pushes in rank order, then surplus pops in rank order.

The host-side persistence protocol (pwb/pfence analogue: device→host fetch +
fsync; two-increment epoch commit) lives in `repro.checkpoint`.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

# op codes
OP_NONE = 0
OP_PUSH = 1
OP_POP = 2
# response kinds
R_NONE = 0
R_ACK = 1
R_VALUE = 2
R_EMPTY = 3


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class StackState:
    """Array-backed DFC stack with double-buffered top (paper Fig 1)."""

    values: jax.Array  # f32[capacity]
    size: jax.Array  # i32[2] — two alternating stack sizes
    epoch: jax.Array  # i32[]  — cEpoch (always even between phases)

    @property
    def active_idx(self) -> jax.Array:
        return (self.epoch // 2) % 2

    def active_size(self) -> jax.Array:
        return self.size[self.active_idx]


def init_stack(capacity: int, dtype=jnp.float32) -> StackState:
    return StackState(
        values=jnp.zeros((capacity,), dtype=dtype),
        size=jnp.zeros((2,), dtype=jnp.int32),
        epoch=jnp.zeros((), dtype=jnp.int32),
    )


def _onehot_route(src_idx: jax.Array, vals: jax.Array, n_out: int) -> jax.Array:
    """out[src_idx[j]] += vals[j] — as a one-hot matmul (MXU-friendly).

    src_idx entries outside [0, n_out) are dropped.
    """
    onehot = (src_idx[None, :] == jnp.arange(n_out)[:, None]).astype(vals.dtype)
    return onehot @ vals


def combine(
    state: StackState, ops: jax.Array, params: jax.Array
) -> Tuple[StackState, jax.Array, jax.Array]:
    """One DFC combining phase over N announcement lanes.

    Returns (new_state, responses f32[N], kinds i32[N]).
    """
    n = ops.shape[0]
    cap = state.values.shape[0]
    idx = jnp.arange(n)

    is_push = ops == OP_PUSH
    is_pop = ops == OP_POP
    push_rank = jnp.where(is_push, jnp.cumsum(is_push) - 1, -1)
    pop_rank = jnp.where(is_pop, jnp.cumsum(is_pop) - 1, -1)
    p_total = jnp.sum(is_push)
    q_total = jnp.sum(is_pop)
    n_elim = jnp.minimum(p_total, q_total)

    old_size = state.active_size()

    # --- elimination: pop_k gets push_k's param (REDUCE lines 102-110) ------
    push_by_rank = _onehot_route(push_rank, params.astype(jnp.float32), n)
    elim_pop_val = push_by_rank[jnp.clip(pop_rank, 0, n - 1)]

    # --- surplus pushes: compact above the committed prefix -----------------
    surplus_push = is_push & (push_rank >= n_elim)
    seg_idx = jnp.where(surplus_push, push_rank - n_elim, n)  # n => dropped
    segment = _onehot_route(seg_idx, params.astype(state.values.dtype), n)
    n_push_surplus = jnp.maximum(p_total - n_elim, 0)
    new_values = jax.lax.dynamic_update_slice(
        state.values,
        segment,
        (jnp.clip(old_size, 0, cap - n),),
    )
    # only the [old_size, old_size + n_push_surplus) part of the segment is
    # real; restore the tail beyond it.  Contract: capacity >= size + N.
    keep_mask = (jnp.arange(cap) >= old_size) & (
        jnp.arange(cap) < old_size + n_push_surplus
    )
    new_values = jnp.where(keep_mask, new_values, state.values)

    # --- surplus pops: read below the committed prefix ----------------------
    surplus_pop = is_pop & (pop_rank >= n_elim)
    depth = pop_rank - n_elim  # 0 == top of committed stack
    pop_src = old_size - 1 - depth
    pop_ok = surplus_pop & (pop_src >= 0)
    stack_val = state.values[jnp.clip(pop_src, 0, cap - 1)].astype(jnp.float32)

    # --- responses -----------------------------------------------------------
    kinds = jnp.full((n,), R_NONE, dtype=jnp.int32)
    kinds = jnp.where(is_push, R_ACK, kinds)
    kinds = jnp.where(is_pop & (pop_rank < n_elim), R_VALUE, kinds)
    kinds = jnp.where(pop_ok, R_VALUE, kinds)
    kinds = jnp.where(surplus_pop & ~pop_ok, R_EMPTY, kinds)
    responses = jnp.zeros((n,), dtype=jnp.float32)
    responses = jnp.where(is_pop & (pop_rank < n_elim), elim_pop_val, responses)
    responses = jnp.where(pop_ok, stack_val, responses)

    # --- publish: write the inactive size, bump epoch by 2 -------------------
    n_popped = jnp.minimum(jnp.maximum(q_total - n_elim, 0), old_size)
    new_size_val = old_size + n_push_surplus - n_popped
    inactive = (state.epoch // 2 + 1) % 2
    new_size = state.size.at[inactive].set(new_size_val)
    new_state = StackState(
        values=new_values, size=new_size, epoch=state.epoch + 2
    )
    return new_state, responses, kinds


combine_jit = jax.jit(combine)


# ------------------------------------------------------------------ reference
def sequential_reference(stack_list, ops, params):
    """Canonical linearization witness in pure Python (test oracle).

    Applies: eliminated pairs, then surplus pushes (rank order), then surplus
    pops (rank order) to a Python list; returns (new_list, responses, kinds).
    """
    n = len(ops)
    pushes = [i for i in range(n) if ops[i] == OP_PUSH]
    pops = [i for i in range(n) if ops[i] == OP_POP]
    e = min(len(pushes), len(pops))
    responses = [0.0] * n
    kinds = [R_NONE] * n
    stack = list(stack_list)
    for k in range(e):  # eliminated pairs
        kinds[pushes[k]] = R_ACK
        kinds[pops[k]] = R_VALUE
        responses[pops[k]] = float(params[pushes[k]])
    for i in pushes[e:]:  # surplus pushes
        stack.append(float(params[i]))
        kinds[i] = R_ACK
    for i in pops[e:]:  # surplus pops
        if stack:
            responses[i] = stack.pop()
            kinds[i] = R_VALUE
        else:
            kinds[i] = R_EMPTY
    return stack, responses, kinds
