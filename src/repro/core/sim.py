"""Deterministic cooperative scheduler for the simulated concurrent threads.

Threads are Python generators that ``yield`` before every shared-memory step
(read / write / CAS / pwb / pfence) — one yield == one atomic step.  The
scheduler interleaves live threads with a seeded RNG, which gives:

  * deterministic, replayable interleavings (seed → schedule),
  * precise crash injection: ``crash_at=k`` stops the world exactly before
    global step ``k``, after which the harness calls ``NVMemory.crash`` and
    runs the recovery generators.

This is (sequentially-consistent) shared memory — a sound under-approximation
of the paper's TSO assumption for correctness testing, since every SC
execution is a TSO execution.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Generator, Hashable, List, Optional, Tuple

import numpy as np


class Crashed(Exception):
    """Raised by Scheduler.run when the injected crash point is reached."""


class Livelock(Exception):
    """No thread finished within the step budget (scheduler bug trap)."""


class Scheduler:
    def __init__(self, seed: int = 0, max_steps: int = 5_000_000):
        self.rng = np.random.default_rng(seed)
        self.max_steps = max_steps
        self.step = 0  # global step counter (also used as event timestamps)

    def run(
        self,
        gens: Dict[Hashable, Generator],
        crash_at: Optional[int] = None,
    ) -> Dict[Hashable, Any]:
        """Drive all generators to completion (or until ``crash_at``).

        Returns {tid: return_value}.  Raises :class:`Crashed` if the crash
        point is reached before all threads finish.
        """
        live = dict(gens)
        results: Dict[Hashable, Any] = {}
        budget = self.step + self.max_steps
        while live:
            if crash_at is not None and self.step >= crash_at:
                raise Crashed()
            if self.step >= budget:
                raise Livelock(f"no progress after {self.max_steps} steps")
            tid = list(live.keys())[int(self.rng.integers(len(live)))]
            try:
                next(live[tid])
                self.step += 1
            except StopIteration as fin:
                results[tid] = fin.value
                del live[tid]
        return results


# --------------------------------------------------------------------- events
class History:
    """Invocation/response event log for linearizability checking."""

    def __init__(self):
        self.ops: List[dict] = []

    def invoke(self, tid, name, param, ts) -> int:
        self.ops.append(
            dict(tid=tid, name=name, param=param, inv=ts, resp=None, value=None)
        )
        return len(self.ops) - 1

    def respond(self, op_id: int, value, ts) -> None:
        self.ops[op_id]["resp"] = ts
        self.ops[op_id]["value"] = value

    def pending(self) -> List[dict]:
        return [o for o in self.ops if o["resp"] is None]

    def completed(self) -> List[dict]:
        return [o for o in self.ops if o["resp"] is not None]


def workload_gen(stack, sched: Scheduler, hist: History, tid, ops, think=None, rng=None):
    """Run a per-thread op sequence against ``stack``, logging the history.

    ``think=(lo, hi)`` inserts a random number of idle steps between ops —
    the arrival jitter real machines have.  Without it, a fair scheduler
    keeps alternating workloads in parity lockstep (all-push batches, then
    all-pop batches), which suppresses elimination; see EXPERIMENTS.md.
    """
    for name, param in ops:
        if think is not None:
            for _ in range(int(rng.integers(think[0], think[1] + 1))):
                yield
        op_id = hist.invoke(tid, name, param, sched.step)
        value = yield from stack.op(tid, name, param)
        hist.respond(op_id, value, sched.step)
    return True
