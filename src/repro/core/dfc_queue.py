"""DFC queue — the paper's detectable flat-combining persistent FIFO queue.

Same three-part design as the stack (`repro.core.dfc`): Algorithm 1's
announce / lock hand-off / recover skeleton is inherited from
:class:`~repro.core.dfc.DFCBase` unchanged; this module supplies the queue's
REDUCE/COMBINE (the queue analogue of Algorithm 2) over the simulated NVM.

Layout (queue analogue of Figure 1):
  NVM lines:
    'cEpoch'          {v}          global epoch counter (shared skeleton)
    'head'            {0, 1}       two alternating head pointers
    'tail'            {0, 1}       two alternating tail pointers
    ('valid', t), ('ann', t, s), ('pool', i)    as in the stack
  Volatile:
    cLock, rLock, enqList[N], deqList[N], vColl[N]

Combiner algorithm (one phase, lock held):
  1. REDUCE collects announced ops into enqList/deqList (lines 88-101 of the
     stack's pseudocode, shared via ``_collect``).
  2. Dequeues are served from the committed queue front; dequeued nodes are
     only *deallocated after the phase commits* — a queue phase can both
     allocate and free, and a node freed-then-reused before the epoch commit
     would corrupt the committed chain a crash rolls back to.
  3. When the queue drains, remaining dequeues PAIR with enqueues (the
     dequeue returns the enqueue's param directly; nothing touches the
     structure) — the queue's two-sided elimination.  A paired enq/deq is
     linearized as an adjacent enq;deq on the empty queue.
  4. Surplus enqueues build their chain back-to-front (each node line is
     written once, then pwb'd once) and are linked behind the committed tail.
     Writing the committed tail's ``next`` is crash-safe: traversal of the
     committed state is bounded by the committed (head, tail) pair, so a
     dangling link beyond the tail is unreachable after a rollback (recovery
     GC and ``snapshot`` stop at the tail for the same reason).
  5. The phase publishes by writing the *inactive* head/tail entries, pwb'ing
     responses + both pointer lines, and committing with the two-increment
     epoch protocol (shared ``_publish``).

Linearization witness of a combined batch: dequeues served from the queue
(FIFO order), then eliminated pairs (enq_k;deq_k adjacent), then surplus
enqueues in collection order; EMPTY dequeues linearize at the drained point.

Paper correspondence (arXiv:2012.12868; shared skeleton cites are in
``repro.core.dfc``):
  * announce / valid / recovery:  Alg. 1 lines 2-12 and 26-43, inherited
    unchanged from :class:`~repro.core.dfc.DFCBase`,
  * elimination rule: the queue analogue of Alg. 2 lines 102-110 — but
    TWO-SIDED and drain-gated: a deq may only pair with an enq once the
    committed queue is empty (pairing earlier would reorder FIFO),
  * one pfence per phase / two-increment ``cEpoch`` commit: Alg. 2 line 80
    and Alg. 1 lines 81-83, with the double-buffered root pair being
    (head, tail) instead of the stack's single ``top``,
  * deferred node reuse + bounded recovery GC walks: §4 — dequeued nodes
    are freed only after the epoch commits, and the recovery walk stops at
    the committed tail, so dangling links past it are unreachable.
"""

from __future__ import annotations

from typing import Any, Dict, Generator

from repro.core.dfc import ACK, DEQ, EMPTY, ENQ, DFCBase
from repro.nvm.pool import NIL


class DFCQueue(DFCBase):
    SEMANTICS = "queue"
    DRAIN_OP = DEQ

    def _alloc_structure(self) -> None:
        self.mem.alloc_line("head", **{"0": NIL, "1": NIL})
        self.mem.alloc_line("tail", **{"0": NIL, "1": NIL})

    def _extra_volatile(self) -> Dict[str, Any]:
        return dict(enqList=[0] * self.N, deqList=[0] * self.N)

    def _gc_roots(self):
        c_epoch = self.mem.read("cEpoch", "v")
        e = self._top_entry(c_epoch)
        head = self.mem.read("head", e)
        tail = self.mem.read("tail", e)
        return [head], [tail]

    def _route(self, i: int, op_name: str) -> None:
        if op_name == ENQ:
            self._n_enq += 1
            self.vol["enqList"][self._n_enq - 1] = i
        else:
            self._n_deq += 1
            self.vol["deqList"][self._n_deq - 1] = i

    # ---------------------------------------------------------------- Reduce
    def reduce(self, t: int) -> Generator:
        """Collect announced enq/deq ops; pairing is deferred to COMBINE
        because queue elimination is only legal once the queue has drained."""
        self._n_enq = self._n_deq = 0
        yield from self._collect(t)
        return self._n_enq, self._n_deq

    # --------------------------------------------------------------- Combine
    def combine(self, t: int) -> Generator:
        m = self.mem
        vol = self.vol
        n_enq, n_deq = yield from self.reduce(t)
        yield
        c_epoch = m.read("cEpoch", "v")
        e = self._top_entry(c_epoch)
        head = m.read("head", e)
        tail = m.read("tail", e)
        freed = []  # deallocated only after the phase commits (see docstring)
        ei = di = 0
        # ---- serve dequeues from the committed queue front ----------------
        while di < n_deq and head != NIL:
            c_id = vol["deqList"][di]
            v_op = vol["vColl"][c_id]
            yield
            m.write(("ann", c_id, v_op), "val", self.pool.param(head))
            freed.append(head)
            if head == tail:  # never follow next(tail): may dangle
                head = tail = NIL
            else:
                head = self.pool.next(head)
            di += 1
        # ---- queue drained: eliminate enq/deq pairs -----------------------
        while di < n_deq and ei < n_enq:
            c_deq = vol["deqList"][di]
            v_deq = vol["vColl"][c_deq]
            c_enq = vol["enqList"][ei]
            v_enq = vol["vColl"][c_enq]
            yield
            param = m.read(("ann", c_enq, v_enq), "param")
            m.write(("ann", c_deq, v_deq), "val", param)
            yield
            m.write(("ann", c_enq, v_enq), "val", ACK)
            di += 1
            ei += 1
            self.eliminated_pairs += 1
        # ---- dequeues beyond every enqueue: EMPTY -------------------------
        while di < n_deq:
            c_id = vol["deqList"][di]
            v_op = vol["vColl"][c_id]
            yield
            m.write(("ann", c_id, v_op), "val", EMPTY)
            di += 1
        # ---- surplus enqueues: build the appended chain back-to-front -----
        chain_head = NIL
        chain_tail = NIL
        j = n_enq - 1
        while j >= ei:
            c_id = vol["enqList"][j]
            v_op = vol["vColl"][c_id]
            yield
            param = m.read(("ann", c_id, v_op), "param")
            yield
            chain_head = self.pool.allocate(param, chain_head)
            if chain_tail == NIL:
                chain_tail = chain_head
            yield
            m.write(("ann", c_id, v_op), "val", ACK)
            yield
            m.pwb(t, self.pool.line_of(chain_head), tag="combine")
            j -= 1
        if chain_head != NIL:
            if tail == NIL:
                head = chain_head
            else:
                yield
                m.write(self.pool.line_of(tail), "next", chain_head)
                yield
                m.pwb(t, self.pool.line_of(tail), tag="combine")
            tail = chain_tail
        # ---- publish ------------------------------------------------------
        ne = self._next_top_entry(c_epoch)
        yield
        m.write("head", ne, head)
        yield
        m.write("tail", ne, tail)
        yield from self._publish(t, c_epoch, ("head", "tail"))
        for idx in freed:
            self.pool.deallocate(idx)

    # ------------------------------------------------------------ inspection
    def peek_queue(self):
        """Volatile view of the active queue, head first (test helper)."""
        c_epoch = self.mem.read("cEpoch", "v")
        e = self._top_entry(c_epoch)
        head = self.mem.read("head", e)
        tail = self.mem.read("tail", e)
        if head == NIL:
            return []
        return self.pool.walk(head, stop=tail)

    def snapshot(self):
        return self.peek_queue()
