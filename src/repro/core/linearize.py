"""Durable-linearizability checker — Wing & Gong style DFS, bitmask-pruned.

Checks whether a concurrent history of operations is linearizable with
respect to sequential stack (LIFO), queue (FIFO), or deque semantics.
Histories are lists of op dicts (``repro.core.sim.History`` format):
{name, param, inv, resp, value}.

Durable linearizability with detectability reduces to plain linearizability
of the *effective* history: completed ops keep their timestamps; operations
pending at a crash that the recovery reports as taken-effect are included
with a response timestamp at recovery time (they completed during Recover,
before any post-recovery op); operations reported as not-taken-effect are
excluded.

Implementation notes (the search is exercised hundreds of times per crash
sweep, so constants matter):

  * the linearized-set is an int bitmask; eligibility of op ``i`` is one AND
    against a precomputed ``before[i]`` mask (ops that responded before ``i``
    invoked),
  * memoization on (mask, abstract-state),
  * symmetry reduction: two not-yet-linearized ops with identical
    (name, param, value, before, after) signatures are interchangeable, so
    only the first is tried per DFS node — this collapses the factorial
    branching of concurrent identical EMPTY pops.
"""

from __future__ import annotations

import math
from typing import Callable, List, Optional, Tuple

from repro.core.dfc import ACK, DEQ, EMPTY, ENQ, POP, POPL, POPR, PUSH, PUSHL, PUSHR

INF = math.inf


# ------------------------------------------------------------- op semantics
def _apply_stack(state: Tuple, name, param, value) -> Optional[Tuple]:
    if name == PUSH:
        if value not in (ACK, None):
            return None
        return state + (param,)
    if name == POP:
        if not state:
            return state if value == EMPTY else None
        if value != state[-1]:
            return None
        return state[:-1]
    return None


def _apply_queue(state: Tuple, name, param, value) -> Optional[Tuple]:
    if name == ENQ:
        if value not in (ACK, None):
            return None
        return state + (param,)
    if name == DEQ:
        if not state:
            return state if value == EMPTY else None
        if value != state[0]:
            return None
        return state[1:]
    return None


def _apply_deque(state: Tuple, name, param, value) -> Optional[Tuple]:
    if name in (PUSHL, PUSHR):
        if value not in (ACK, None):
            return None
        return (param,) + state if name == PUSHL else state + (param,)
    if name in (POPL, POPR):
        if not state:
            return state if value == EMPTY else None
        end = state[0] if name == POPL else state[-1]
        if value != end:
            return None
        return state[1:] if name == POPL else state[:-1]
    return None


SEMANTICS: dict = {
    "stack": _apply_stack,
    "queue": _apply_queue,
    "deque": _apply_deque,
}


def is_linearizable(
    ops: List[dict], max_nodes: int = 2_000_000, semantics: str = "stack"
) -> bool:
    """DFS with memoization on (linearized-mask, abstract-state)."""
    n = len(ops)
    if n == 0:
        return True
    apply_op = SEMANTICS[semantics]
    resp = [o["resp"] if o["resp"] is not None else INF for o in ops]
    inv = [o["inv"] for o in ops]
    name = [o["name"] for o in ops]
    param = [o["param"] for o in ops]
    value = [o["value"] for o in ops]

    # before[i]: ops that must be linearized before i (responded before i's
    # invocation).  i is eligible at mask iff mask & before[i] == 0 (mask =
    # not-yet-linearized set).
    before = [0] * n
    for i in range(n):
        for j in range(n):
            if j != i and resp[j] < inv[i]:
                before[i] |= 1 << j
    after = [0] * n
    for i in range(n):
        for j in range(n):
            if before[j] >> i & 1:
                after[i] |= 1 << j

    sig = [(name[i], param[i], value[i], before[i], after[i]) for i in range(n)]

    seen = set()
    budget = [max_nodes]
    full = (1 << n) - 1

    def dfs(mask: int, state: Tuple) -> bool:
        """mask = bitmask of ops NOT yet linearized."""
        if mask == 0:
            return True
        key = (mask, state)
        if key in seen:
            return False
        seen.add(key)
        if budget[0] <= 0:
            raise RuntimeError("linearizability search budget exhausted")
        budget[0] -= 1
        tried = set()
        m = mask
        while m:
            low = m & -m
            i = low.bit_length() - 1
            m ^= low
            if mask & before[i]:
                continue  # a predecessor is still unlinearized
            if sig[i] in tried:
                continue  # interchangeable with an already-tried candidate
            tried.add(sig[i])
            nxt = apply_op(state, name[i], param[i], value[i])
            if nxt is None:
                continue
            if dfs(mask ^ low, nxt):
                return True
        return False

    return dfs(full, ())
