"""Stack (durable) linearizability checker — Wing & Gong style DFS.

Checks whether a concurrent history of push/pop operations is linearizable
with respect to sequential LIFO stack semantics.  Histories are lists of op
dicts (``repro.core.sim.History`` format): {name, param, inv, resp, value}.

Durable linearizability with detectability reduces to plain linearizability
of the *effective* history: completed ops keep their timestamps; operations
pending at a crash that the recovery reports as taken-effect are included
with resp=+inf (they completed at recovery, concurrent with everything that
was pending); operations reported as not-taken-effect are excluded.
"""

from __future__ import annotations

import math
from typing import List, Optional, Tuple

from repro.core.dfc import ACK, EMPTY, POP, PUSH

INF = math.inf


def _apply(state: Tuple, op: dict) -> Optional[Tuple]:
    """Sequential stack semantics; None if op's recorded response is illegal."""
    if op["name"] == PUSH:
        if op["value"] not in (ACK, None):
            return None
        return state + (op["param"],)
    # pop
    if not state:
        return state if op["value"] == EMPTY else None
    if op["value"] != state[-1]:
        return None
    return state[:-1]


def is_linearizable(ops: List[dict], max_nodes: int = 2_000_000) -> bool:
    """DFS with memoization on (linearized-set, stack-state)."""
    n = len(ops)
    if n == 0:
        return True
    resp = [o["resp"] if o["resp"] is not None else INF for o in ops]
    inv = [o["inv"] for o in ops]

    seen = set()
    budget = [max_nodes]

    def dfs(done: frozenset, state: Tuple) -> bool:
        if len(done) == n:
            return True
        key = (done, state)
        if key in seen:
            return False
        seen.add(key)
        if budget[0] <= 0:
            raise RuntimeError("linearizability search budget exhausted")
        budget[0] -= 1
        # candidate i is eligible if no unlinearized j responded before i invoked
        for i in range(n):
            if i in done:
                continue
            eligible = True
            for j in range(n):
                if j != i and j not in done and resp[j] < inv[i]:
                    eligible = False
                    break
            if not eligible:
                continue
            nxt = _apply(state, ops[i])
            if nxt is None:
                continue
            if dfs(done | {i}, nxt):
                return True
        return False

    return dfs(frozenset(), ())
