"""Pure-jnp oracles for the dfc_reduce kernels (same signatures/outputs)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dfc_reduce.kernel import (
    CAS_DOM,
    MAP_BUCKET_SLOTS,
    OP_DEQ,
    OP_ENQ,
    OP_MAP_CAS,
    OP_MAP_DELETE,
    OP_MAP_INSERT,
    OP_MAP_LOOKUP,
    OP_POP,
    OP_POPL,
    OP_POPR,
    OP_PUSH,
    OP_PUSHL,
    OP_PUSHR,
    R_ACK,
    R_CAS_FAIL,
    R_EMPTY,
    R_FULL,
    R_NONE,
    R_VALUE,
    _map_bucket,
)


def dfc_reduce_ref(ops, params, window, size):
    n = ops.shape[0]
    params = params.astype(jnp.float32)
    window = window.astype(jnp.float32)
    size = jnp.asarray(size, jnp.int32).reshape(())

    is_push = ops == OP_PUSH
    is_pop = ops == OP_POP
    push_rank = jnp.where(is_push, jnp.cumsum(is_push) - 1, -1)
    pop_rank = jnp.where(is_pop, jnp.cumsum(is_pop) - 1, -1)
    p_total = jnp.sum(is_push)
    q_total = jnp.sum(is_pop)
    n_elim = jnp.minimum(p_total, q_total)

    push_by_rank = jnp.zeros((n,), jnp.float32).at[
        jnp.where(is_push, push_rank, n)
    ].add(params, mode="drop")
    elim_pop_val = push_by_rank[jnp.clip(pop_rank, 0, n - 1)]

    surplus_push = is_push & (push_rank >= n_elim)
    segment = jnp.zeros((n,), jnp.float32).at[
        jnp.where(surplus_push, push_rank - n_elim, n)
    ].add(params, mode="drop")

    surplus_pop = is_pop & (pop_rank >= n_elim)
    depth = pop_rank - n_elim
    win_src = n - 1 - depth
    pop_ok = surplus_pop & (win_src >= 0) & (depth < size)
    stack_val = window[jnp.clip(win_src, 0, n - 1)]

    kinds = jnp.full((n,), R_NONE, dtype=jnp.int32)
    kinds = jnp.where(is_push, R_ACK, kinds)
    kinds = jnp.where(is_pop & (pop_rank < n_elim), R_VALUE, kinds)
    kinds = jnp.where(pop_ok, R_VALUE, kinds)
    kinds = jnp.where(surplus_pop & ~pop_ok, R_EMPTY, kinds)
    resp = jnp.zeros((n,), jnp.float32)
    resp = jnp.where(is_pop & (pop_rank < n_elim), elim_pop_val, resp)
    resp = jnp.where(pop_ok, stack_val, resp)

    counts = jnp.stack(
        [
            jnp.maximum(p_total - n_elim, 0),
            jnp.minimum(jnp.maximum(q_total - n_elim, 0), size),
            n_elim,
            q_total,
        ]
    ).astype(jnp.int32)
    return resp, kinds, segment, counts


def dfc_queue_reduce_ref(ops, params, window, size):
    n = ops.shape[0]
    params = params.astype(jnp.float32)
    window = window.astype(jnp.float32)
    size = jnp.asarray(size, jnp.int32).reshape(())

    is_enq = ops == OP_ENQ
    is_deq = ops == OP_DEQ
    enq_rank = jnp.where(is_enq, jnp.cumsum(is_enq) - 1, -1)
    deq_rank = jnp.where(is_deq, jnp.cumsum(is_deq) - 1, -1)
    p_total = jnp.sum(is_enq)
    q_total = jnp.sum(is_deq)
    n_from_q = jnp.minimum(q_total, size)
    n_elim = jnp.minimum(jnp.maximum(q_total - size, 0), p_total)

    served = is_deq & (deq_rank < size)
    ring_val = window[jnp.clip(deq_rank, 0, n - 1)]

    enq_by_rank = jnp.zeros((n,), jnp.float32).at[
        jnp.where(is_enq, enq_rank, n)
    ].add(params, mode="drop")
    paired = is_deq & (deq_rank >= size) & (deq_rank - size < n_elim)
    pair_val = enq_by_rank[jnp.clip(deq_rank - size, 0, n - 1)]
    empty = is_deq & (deq_rank >= size + n_elim)

    surplus_enq = is_enq & (enq_rank >= n_elim)
    segment = jnp.zeros((n,), jnp.float32).at[
        jnp.where(surplus_enq, enq_rank - n_elim, n)
    ].add(params, mode="drop")

    kinds = jnp.full((n,), R_NONE, dtype=jnp.int32)
    kinds = jnp.where(is_enq, R_ACK, kinds)
    kinds = jnp.where(served | paired, R_VALUE, kinds)
    kinds = jnp.where(empty, R_EMPTY, kinds)
    resp = jnp.zeros((n,), jnp.float32)
    resp = jnp.where(served, ring_val, resp)
    resp = jnp.where(paired, pair_val, resp)

    counts = jnp.stack(
        [jnp.maximum(p_total - n_elim, 0), n_from_q, n_elim, q_total]
    ).astype(jnp.int32)
    return resp, kinds, segment, counts


def dfc_deque_reduce_ref(ops, params, window_l, window_r, size):
    n = ops.shape[0]
    params = params.astype(jnp.float32)
    window_l = window_l.astype(jnp.float32)
    window_r = window_r.astype(jnp.float32)
    size = jnp.asarray(size, jnp.int32).reshape(())

    is_pl = ops == OP_PUSHL
    is_ql = ops == OP_POPL
    is_pr = ops == OP_PUSHR
    is_qr = ops == OP_POPR
    pl_rank = jnp.where(is_pl, jnp.cumsum(is_pl) - 1, -1)
    ql_rank = jnp.where(is_ql, jnp.cumsum(is_ql) - 1, -1)
    pr_rank = jnp.where(is_pr, jnp.cumsum(is_pr) - 1, -1)
    qr_rank = jnp.where(is_qr, jnp.cumsum(is_qr) - 1, -1)
    npl, nql = jnp.sum(is_pl), jnp.sum(is_ql)
    npr, nqr = jnp.sum(is_pr), jnp.sum(is_qr)
    nl_elim = jnp.minimum(npl, nql)
    nr_elim = jnp.minimum(npr, nqr)

    pl_by_rank = jnp.zeros((n,), jnp.float32).at[
        jnp.where(is_pl, pl_rank, n)
    ].add(params, mode="drop")
    pr_by_rank = jnp.zeros((n,), jnp.float32).at[
        jnp.where(is_pr, pr_rank, n)
    ].add(params, mode="drop")
    eliml = is_ql & (ql_rank < nl_elim)
    elimr = is_qr & (qr_rank < nr_elim)
    eliml_val = pl_by_rank[jnp.clip(ql_rank, 0, n - 1)]
    elimr_val = pr_by_rank[jnp.clip(qr_rank, 0, n - 1)]

    sl = jnp.maximum(npl - nl_elim, 0)
    tl = jnp.maximum(nql - nl_elim, 0)
    surplus_pl = is_pl & (pl_rank >= nl_elim)
    seg_l = jnp.zeros((n,), jnp.float32).at[
        jnp.where(surplus_pl, pl_rank - nl_elim, n)
    ].add(params, mode="drop")
    dl = jnp.minimum(tl, size)
    surplus_ql = is_ql & (ql_rank >= nl_elim)
    kl = ql_rank - nl_elim
    lpop_ok = surplus_ql & (kl < size)
    lpop_val = window_l[jnp.clip(kl, 0, n - 1)]
    size_after = size + sl - dl

    sr = jnp.maximum(npr - nr_elim, 0)
    tr = jnp.maximum(nqr - nr_elim, 0)
    surplus_pr = is_pr & (pr_rank >= nr_elim)
    seg_r = jnp.zeros((n,), jnp.float32).at[
        jnp.where(surplus_pr, pr_rank - nr_elim, n)
    ].add(params, mode="drop")
    dr = jnp.minimum(tr, size_after)
    surplus_qr = is_qr & (qr_rank >= nr_elim)
    kr = qr_rank - nr_elim
    rpop_ok = surplus_qr & (kr < size_after)
    rpop_val = jnp.where(
        kr < size,
        window_r[jnp.clip(kr, 0, n - 1)],
        seg_l[jnp.clip(kr - size, 0, n - 1)],
    )

    kinds = jnp.full((n,), R_NONE, dtype=jnp.int32)
    kinds = jnp.where(is_pl | is_pr, R_ACK, kinds)
    kinds = jnp.where(eliml | elimr | lpop_ok | rpop_ok, R_VALUE, kinds)
    kinds = jnp.where(surplus_ql & ~lpop_ok, R_EMPTY, kinds)
    kinds = jnp.where(surplus_qr & ~rpop_ok, R_EMPTY, kinds)
    resp = jnp.zeros((n,), jnp.float32)
    resp = jnp.where(eliml, eliml_val, resp)
    resp = jnp.where(elimr, elimr_val, resp)
    resp = jnp.where(lpop_ok, lpop_val, resp)
    resp = jnp.where(rpop_ok, rpop_val, resp)

    counts = jnp.stack(
        [sl, dl, sr, dr, nl_elim, nr_elim, size_after, jnp.zeros((), jnp.int32)]
    ).astype(jnp.int32)
    return resp, kinds, seg_l, seg_r, counts


def dfc_map_reduce_ref(mkeys, mvals, mocc, count, lkeys, ops, params):
    """Oracle for ``_map_reduce_math``: same lane-order scan, but probing via
    full-table masks instead of the kernel's dynamic_slice bucket windows."""
    cap = mkeys.shape[0]
    bslots = min(cap, MAP_BUCKET_SLOTS)
    n_buckets = cap // bslots
    slot_bucket = jnp.arange(cap, dtype=jnp.int32) // bslots
    slot_idx = jnp.arange(cap, dtype=jnp.int32)

    def lane(carry, xs):
        mk, mv, mo, cnt = carry
        key, op, par = xs
        in_b = slot_bucket == _map_bucket(key, n_buckets)
        occ = mo != 0
        hit = in_b & occ & (mk == key)
        has_hit = jnp.any(hit)
        hit_idx = jnp.argmax(hit).astype(jnp.int32)
        free = in_b & ~occ
        has_free = jnp.any(free)
        free_idx = jnp.argmax(free).astype(jnp.int32)
        cur = jnp.sum(jnp.where(hit, mv, 0.0))

        is_ins = op == OP_MAP_INSERT
        is_lku = op == OP_MAP_LOOKUP
        is_del = op == OP_MAP_DELETE
        is_cas = op == OP_MAP_CAS
        expected = jnp.floor(par / CAS_DOM)
        cas_new = par - expected * CAS_DOM
        cas_hit = is_cas & has_hit
        cas_ok = cas_hit & (cur == expected)

        do_ins = is_ins & (has_hit | has_free)
        do_del = is_del & has_hit
        do_write = do_ins | cas_ok
        wslot = jnp.where(has_hit, hit_idx, free_idx)
        wval = jnp.where(is_cas, cas_new, par)
        wmask = do_write & (slot_idx == wslot)
        dmask = do_del & (slot_idx == hit_idx)
        mk = jnp.where(wmask, key, jnp.where(dmask, 0, mk))
        mv = jnp.where(wmask, wval, jnp.where(dmask, 0.0, mv))
        mo = jnp.where(wmask, 1, jnp.where(dmask, 0, mo))
        cnt = (
            cnt
            + (is_ins & ~has_hit & has_free).astype(jnp.int32)
            - do_del.astype(jnp.int32)
        )

        kind = jnp.full((), R_NONE, jnp.int32)
        kind = jnp.where(do_ins, R_ACK, kind)
        kind = jnp.where(is_ins & ~has_hit & ~has_free, R_FULL, kind)
        kind = jnp.where((is_lku | is_del | is_cas) & ~has_hit, R_EMPTY, kind)
        kind = jnp.where((is_lku | do_del | cas_ok) & has_hit, R_VALUE, kind)
        kind = jnp.where(cas_hit & ~cas_ok, R_CAS_FAIL, kind)
        resp = jnp.where((is_lku | is_del | is_cas) & has_hit, cur, 0.0)
        return (mk, mv, mo, cnt), (resp, kind)

    (mk, mv, mo, cnt), (resp, kinds) = jax.lax.scan(
        lane,
        (
            jnp.asarray(mkeys, jnp.int32),
            jnp.asarray(mvals, jnp.float32),
            jnp.asarray(mocc, jnp.int32),
            jnp.asarray(count, jnp.int32).reshape(()),
        ),
        (
            jnp.asarray(lkeys, jnp.int32),
            jnp.asarray(ops, jnp.int32),
            jnp.asarray(params, jnp.float32),
        ),
    )
    return mk, mv, mo, cnt, resp, kinds
