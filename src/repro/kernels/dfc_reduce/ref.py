"""Pure-jnp oracle for the dfc_reduce kernel (same signature/outputs)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dfc_reduce.kernel import (
    OP_POP,
    OP_PUSH,
    R_ACK,
    R_EMPTY,
    R_NONE,
    R_VALUE,
)


def dfc_reduce_ref(ops, params, window, size):
    n = ops.shape[0]
    params = params.astype(jnp.float32)
    window = window.astype(jnp.float32)
    size = jnp.asarray(size, jnp.int32).reshape(())

    is_push = ops == OP_PUSH
    is_pop = ops == OP_POP
    push_rank = jnp.where(is_push, jnp.cumsum(is_push) - 1, -1)
    pop_rank = jnp.where(is_pop, jnp.cumsum(is_pop) - 1, -1)
    p_total = jnp.sum(is_push)
    q_total = jnp.sum(is_pop)
    n_elim = jnp.minimum(p_total, q_total)

    push_by_rank = jnp.zeros((n,), jnp.float32).at[
        jnp.where(is_push, push_rank, n)
    ].add(params, mode="drop")
    elim_pop_val = push_by_rank[jnp.clip(pop_rank, 0, n - 1)]

    surplus_push = is_push & (push_rank >= n_elim)
    segment = jnp.zeros((n,), jnp.float32).at[
        jnp.where(surplus_push, push_rank - n_elim, n)
    ].add(params, mode="drop")

    surplus_pop = is_pop & (pop_rank >= n_elim)
    depth = pop_rank - n_elim
    win_src = n - 1 - depth
    pop_ok = surplus_pop & (win_src >= 0) & (depth < size)
    stack_val = window[jnp.clip(win_src, 0, n - 1)]

    kinds = jnp.full((n,), R_NONE, dtype=jnp.int32)
    kinds = jnp.where(is_push, R_ACK, kinds)
    kinds = jnp.where(is_pop & (pop_rank < n_elim), R_VALUE, kinds)
    kinds = jnp.where(pop_ok, R_VALUE, kinds)
    kinds = jnp.where(surplus_pop & ~pop_ok, R_EMPTY, kinds)
    resp = jnp.zeros((n,), jnp.float32)
    resp = jnp.where(is_pop & (pop_rank < n_elim), elim_pop_val, resp)
    resp = jnp.where(pop_ok, stack_val, resp)

    counts = jnp.stack(
        [
            jnp.maximum(p_total - n_elim, 0),
            jnp.minimum(jnp.maximum(q_total - n_elim, 0), size),
            n_elim,
            q_total,
        ]
    ).astype(jnp.int32)
    return resp, kinds, segment, counts
