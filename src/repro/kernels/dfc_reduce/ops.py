"""Jitted public wrapper: full DFC combine step using the Pallas kernel.

Splices the kernel outputs (responses / surplus segment / counts) into the
array-backed double-buffered stack state.  ``backend`` selects the Pallas
kernel (compiled for TPU, interpret-mode on CPU) or the pure-jnp oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.jax_dfc import StackState
from repro.kernels.dfc_reduce.kernel import dfc_reduce_call
from repro.kernels.dfc_reduce.ref import dfc_reduce_ref


@functools.partial(jax.jit, static_argnames=("backend",))
def dfc_combine_step(state: StackState, ops, params, *, backend: str = "ref"):
    n = ops.shape[0]
    cap = state.values.shape[0]
    old_size = state.active_size()

    # window = stack[top-n : top], zero-padded below the bottom
    start = jnp.clip(old_size - n, 0, cap - n)
    raw = jax.lax.dynamic_slice(state.values, (start,), (n,))
    # when old_size < n the slice starts at 0 and the top is at old_size-1;
    # shift so the committed top sits at window[n-1]
    shift = jnp.where(old_size >= n, 0, n - old_size)
    window = jnp.roll(raw, shift)
    window = jnp.where(jnp.arange(n) >= shift, window, 0.0)

    if backend == "pallas":
        resp, kinds, segment, counts = dfc_reduce_call(
            ops, params, window, old_size, interpret=True
        )
    elif backend == "pallas_tpu":
        resp, kinds, segment, counts = dfc_reduce_call(
            ops, params, window, old_size, interpret=False
        )
    else:
        resp, kinds, segment, counts = dfc_reduce_ref(ops, params, window, old_size)

    n_push_surplus, n_popped = counts[0], counts[1]
    new_values = jax.lax.dynamic_update_slice(
        state.values, segment.astype(state.values.dtype), (jnp.clip(old_size, 0, cap - n),)
    )
    keep = (jnp.arange(cap) >= old_size) & (jnp.arange(cap) < old_size + n_push_surplus)
    new_values = jnp.where(keep, new_values, state.values)

    new_size_val = old_size + n_push_surplus - n_popped
    inactive = (state.epoch // 2 + 1) % 2
    new_state = StackState(
        values=new_values,
        size=state.size.at[inactive].set(new_size_val),
        epoch=state.epoch + 2,
    )
    return new_state, resp, kinds
