"""Jitted public wrappers: full DFC combine steps using the Pallas kernels.

Splice the kernel outputs (responses / surplus segments / counts) into the
array-backed double-buffered structure states (stack, queue, deque).
``backend`` selects the Pallas kernel (compiled for TPU via ``pallas_tpu``,
interpret-mode via ``pallas``) or the pure-jnp oracle (``ref``).

Each structure factors into a window builder (read the committed end(s) of
the array into the kernel's lane-sized window) and a splice (apply the
kernel's surplus segments/counts back to the double-buffered state with an
epoch bump of +2).  The sharded steps (``dfc_sharded_*_combine_step``) vmap
the builder and the splice over a leading shard axis and run ALL shards'
combining phases in one Pallas grid dispatch (grid=(S,), one program
instance per shard) — the multi-object amortization the sharded runtime
(`repro.runtime.dfc_shard`) is built on.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.jax_dfc import (
    OP_NONE,
    DequeState,
    MapState,
    PhaseIntents,
    QueueState,
    StackState,
)
from repro.kernels.dfc_reduce.kernel import (
    dfc_deque_reduce_call,
    dfc_deque_reduce_grid_call,
    dfc_map_reduce_grid_call,
    dfc_queue_reduce_call,
    dfc_queue_reduce_grid_call,
    dfc_reduce_call,
    dfc_reduce_grid_call,
)
from repro.kernels.dfc_reduce.ref import (
    dfc_deque_reduce_ref,
    dfc_map_reduce_ref,
    dfc_queue_reduce_ref,
    dfc_reduce_ref,
)


# ------------------------------------------------------------------- stack
def _stack_window(state: StackState, n: int):
    """window = stack[top-n : top], zero-padded below the bottom."""
    cap = state.values.shape[0]
    old_size = state.active_size()
    start = jnp.clip(old_size - n, 0, cap - n)
    raw = jax.lax.dynamic_slice(state.values, (start,), (n,))
    # when old_size < n the slice starts at 0 and the top is at old_size-1;
    # shift so the committed top sits at window[n-1]
    shift = jnp.where(old_size >= n, 0, n - old_size)
    window = jnp.roll(raw, shift)
    window = jnp.where(jnp.arange(n) >= shift, window, 0.0)
    return window, old_size


def _stack_splice(state: StackState, segment, counts) -> StackState:
    n = segment.shape[0]
    cap = state.values.shape[0]
    old_size = state.active_size()
    n_push_surplus, n_popped = counts[0], counts[1]
    new_values = jax.lax.dynamic_update_slice(
        state.values, segment.astype(state.values.dtype), (jnp.clip(old_size, 0, cap - n),)
    )
    keep = (jnp.arange(cap) >= old_size) & (jnp.arange(cap) < old_size + n_push_surplus)
    new_values = jnp.where(keep, new_values, state.values)

    new_size_val = old_size + n_push_surplus - n_popped
    inactive = (state.epoch // 2 + 1) % 2
    return StackState(
        values=new_values,
        size=state.size.at[inactive].set(new_size_val),
        epoch=state.epoch + 2,
    )


@functools.partial(jax.jit, static_argnames=("backend",))
def dfc_combine_step(state: StackState, ops, params, *, backend: str = "ref"):
    window, old_size = _stack_window(state, ops.shape[0])

    if backend == "pallas":
        resp, kinds, segment, counts = dfc_reduce_call(
            ops, params, window, old_size, interpret=True
        )
    elif backend == "pallas_tpu":
        resp, kinds, segment, counts = dfc_reduce_call(
            ops, params, window, old_size, interpret=False
        )
    else:
        resp, kinds, segment, counts = dfc_reduce_ref(ops, params, window, old_size)

    return _stack_splice(state, segment, counts), resp, kinds


# ------------------------------------------------------------------- queue
def _queue_window(state: QueueState, n: int):
    """Front window: queue[head : head+n], zero-padded past the tail."""
    cap = state.values.shape[0]
    ends = state.active_ends()
    head, size = ends[0], ends[1] - ends[0]
    lanes = jnp.arange(n)
    window = jnp.where(lanes < size, state.values[(head + lanes) % cap], 0.0)
    return window.astype(jnp.float32), size


def _queue_splice(state: QueueState, segment, counts) -> QueueState:
    n = segment.shape[0]
    cap = state.values.shape[0]
    ends = state.active_ends()
    head, tail = ends[0], ends[1]
    n_enq_surplus, n_from_q = counts[0], counts[1]
    lanes = jnp.arange(n)
    pos = (tail + lanes) % cap
    new_values = state.values.at[
        jnp.where(lanes < n_enq_surplus, pos, cap)
    ].set(segment.astype(state.values.dtype), mode="drop")

    inactive = (state.epoch // 2 + 1) % 2
    new_ends = jnp.stack([head + n_from_q, tail + n_enq_surplus])
    return QueueState(
        values=new_values,
        ends=state.ends.at[inactive].set(new_ends),
        epoch=state.epoch + 2,
    )


@functools.partial(jax.jit, static_argnames=("backend",))
def dfc_queue_combine_step(state: QueueState, ops, params, *, backend: str = "ref"):
    """Queue combine phase: front window -> kernel -> masked ring splice."""
    window, size = _queue_window(state, ops.shape[0])

    if backend == "pallas":
        resp, kinds, segment, counts = dfc_queue_reduce_call(
            ops, params, window, size, interpret=True
        )
    elif backend == "pallas_tpu":
        resp, kinds, segment, counts = dfc_queue_reduce_call(
            ops, params, window, size, interpret=False
        )
    else:
        resp, kinds, segment, counts = dfc_queue_reduce_ref(ops, params, window, size)

    return _queue_splice(state, segment, counts), resp, kinds


# ------------------------------------------------------------------- deque
def _deque_windows(state: DequeState, n: int):
    """End windows seen from the left and from the right."""
    cap = state.values.shape[0]
    ends = state.active_ends()
    left, right = ends[0], ends[1]
    size = right - left
    lanes = jnp.arange(n)
    window_l = jnp.where(lanes < size, state.values[(left + lanes) % cap], 0.0)
    window_r = jnp.where(lanes < size, state.values[(right - 1 - lanes) % cap], 0.0)
    return window_l.astype(jnp.float32), window_r.astype(jnp.float32), size


def _deque_splice(state: DequeState, seg_l, seg_r, counts) -> DequeState:
    n = seg_l.shape[0]
    cap = state.values.shape[0]
    ends = state.active_ends()
    left, right = ends[0], ends[1]
    sl, dl, sr, dr = counts[0], counts[1], counts[2], counts[3]
    lanes = jnp.arange(n)
    posl = (left - 1 - lanes) % cap
    new_values = state.values.at[jnp.where(lanes < sl, posl, cap)].set(
        seg_l.astype(state.values.dtype), mode="drop"
    )
    posr = (right + lanes) % cap
    new_values = new_values.at[jnp.where(lanes < sr, posr, cap)].set(
        seg_r.astype(state.values.dtype), mode="drop"
    )

    inactive = (state.epoch // 2 + 1) % 2
    new_ends = jnp.stack([left - sl + dl, right + sr - dr])
    return DequeState(
        values=new_values,
        ends=state.ends.at[inactive].set(new_ends),
        epoch=state.epoch + 2,
    )


@functools.partial(jax.jit, static_argnames=("backend",))
def dfc_deque_combine_step(state: DequeState, ops, params, *, backend: str = "ref"):
    """Deque combine phase: end windows -> two-sided kernel -> ring splices."""
    window_l, window_r, size = _deque_windows(state, ops.shape[0])

    if backend == "pallas":
        resp, kinds, seg_l, seg_r, counts = dfc_deque_reduce_call(
            ops, params, window_l, window_r, size, interpret=True
        )
    elif backend == "pallas_tpu":
        resp, kinds, seg_l, seg_r, counts = dfc_deque_reduce_call(
            ops, params, window_l, window_r, size, interpret=False
        )
    else:
        resp, kinds, seg_l, seg_r, counts = dfc_deque_reduce_ref(
            ops, params, window_l, window_r, size
        )

    return _deque_splice(state, seg_l, seg_r, counts), resp, kinds


# ----------------------------------------------------------------- sharded
# All shards' combining phases in one dispatch.  States are shard-stacked
# pytrees (leading S axis on every leaf, see ``repro.core.jax_dfc``); ops and
# params are [S, N] per-shard announcement matrices.
@functools.partial(jax.jit, static_argnames=("backend",))
def dfc_sharded_combine_step(state: StackState, ops, params, *, backend: str = "ref"):
    """Sharded stack combine: one grid dispatch, program instance = shard."""
    n = ops.shape[1]
    windows, sizes = jax.vmap(_stack_window, in_axes=(0, None))(state, n)

    if backend == "pallas":
        resp, kinds, segments, counts = dfc_reduce_grid_call(
            ops, params, windows, sizes, interpret=True
        )
    elif backend == "pallas_tpu":
        resp, kinds, segments, counts = dfc_reduce_grid_call(
            ops, params, windows, sizes, interpret=False
        )
    else:
        resp, kinds, segments, counts = jax.vmap(dfc_reduce_ref)(
            ops, params, windows, sizes
        )

    return jax.vmap(_stack_splice)(state, segments, counts), resp, kinds


@functools.partial(jax.jit, static_argnames=("backend",))
def dfc_sharded_queue_combine_step(
    state: QueueState, ops, params, *, backend: str = "ref"
):
    """Sharded queue combine: one grid dispatch, program instance = shard."""
    n = ops.shape[1]
    windows, sizes = jax.vmap(_queue_window, in_axes=(0, None))(state, n)

    if backend == "pallas":
        resp, kinds, segments, counts = dfc_queue_reduce_grid_call(
            ops, params, windows, sizes, interpret=True
        )
    elif backend == "pallas_tpu":
        resp, kinds, segments, counts = dfc_queue_reduce_grid_call(
            ops, params, windows, sizes, interpret=False
        )
    else:
        resp, kinds, segments, counts = jax.vmap(dfc_queue_reduce_ref)(
            ops, params, windows, sizes
        )

    return jax.vmap(_queue_splice)(state, segments, counts), resp, kinds


@functools.partial(jax.jit, static_argnames=("backend",))
def dfc_sharded_deque_combine_step(
    state: DequeState, ops, params, *, backend: str = "ref"
):
    """Sharded deque combine: one grid dispatch, program instance = shard."""
    n = ops.shape[1]
    windows_l, windows_r, sizes = jax.vmap(_deque_windows, in_axes=(0, None))(state, n)

    if backend == "pallas":
        resp, kinds, segs_l, segs_r, counts = dfc_deque_reduce_grid_call(
            ops, params, windows_l, windows_r, sizes, interpret=True
        )
    elif backend == "pallas_tpu":
        resp, kinds, segs_l, segs_r, counts = dfc_deque_reduce_grid_call(
            ops, params, windows_l, windows_r, sizes, interpret=False
        )
    else:
        resp, kinds, segs_l, segs_r, counts = jax.vmap(dfc_deque_reduce_ref)(
            ops, params, windows_l, windows_r, sizes
        )

    return jax.vmap(_deque_splice)(state, segs_l, segs_r, counts), resp, kinds


# --------------------------------------------------------------------- map
@functools.partial(jax.jit, static_argnames=("backend",))
def dfc_sharded_map_combine_step(state: MapState, keys, ops, params, *, backend: str = "ref"):
    """Sharded map combine: one grid dispatch, program instance = shard.

    Unlike the ring kinds there is no window/splice factoring — the whole
    bucketed table rides through the kernel (map writes scatter by bucket,
    not contiguously at an end), and only the double-buffered ``count`` is
    published on the inactive slot here.
    """
    s = ops.shape[0]
    rows = jnp.arange(s)
    active_counts = state.count[rows, (state.epoch // 2) % 2]

    if backend in ("pallas", "pallas_tpu"):
        mk, mv, mo, cnt, resp, kinds = dfc_map_reduce_grid_call(
            state.keys, state.values, state.occupied, active_counts,
            keys, ops, params, interpret=backend == "pallas",
        )
        cnt = cnt[:, 0]
    else:
        mk, mv, mo, cnt, resp, kinds = jax.vmap(dfc_map_reduce_ref)(
            state.keys, state.values, state.occupied, active_counts,
            keys, ops, params,
        )

    inactive = (state.epoch // 2 + 1) % 2
    new_state = MapState(
        keys=mk,
        values=mv.astype(state.values.dtype),
        occupied=mo,
        count=state.count.at[rows, inactive].set(cnt),
        epoch=state.epoch + 2,
    )
    return new_state, resp, kinds


SHARDED_COMBINE_STEPS = {
    "stack": dfc_sharded_combine_step,
    "queue": dfc_sharded_queue_combine_step,
    "deque": dfc_sharded_deque_combine_step,
}


# -------------------------------------------------------------- multi-batch
def _one_sharded_combine(kind: str, backend: str, state, ops, params, keys=None):
    """One sharded combining phase of ``kind`` — the shared dispatch used by
    both the single-batch and the chained entry points: a ``vmap`` of the
    single-object combine for the jnp backend, one Pallas grid otherwise.

    Keyed kinds (the map) additionally consume the announced KEYS: callers
    that routed a batch thread them through; ``None`` falls back to all-zero
    keys (only valid for batches with no keyed ops).
    """
    from repro.core.jax_dfc import STRUCTS

    spec = STRUCTS[kind]
    if spec.keyed:
        k = jnp.zeros_like(ops) if keys is None else keys
        if backend == "jnp":
            return jax.vmap(spec.combine)(state, k, ops, params)
        return dfc_sharded_map_combine_step(state, k, ops, params, backend=backend)
    if backend == "jnp":
        return jax.vmap(spec.combine)(state, ops, params)
    return SHARDED_COMBINE_STEPS[kind](state, ops, params, backend=backend)


# ---------------------------------------------------- per-side lanes (ISSUE 8)
def _lane_mask_ops(kind: str, ops, lane: int):
    """Mask a per-shard announcement matrix down to ONE announcement lane:
    ops whose side is not ``lane`` become OP_NONE (positions preserved, so
    per-op bookkeeping lines up with the unmasked batch)."""
    from repro.core.jax_dfc import lane_of_ops

    return jnp.where(lane_of_ops(kind, ops) == lane, ops, OP_NONE)


@functools.partial(jax.jit, static_argnames=("kind", "lane", "backend"))
def dfc_lane_combine_step(state, ops, params, *, kind, lane, backend="jnp"):
    """One PER-SIDE combining phase: combine only the ``lane``-side ops
    (LANE_HEAD = consuming side, LANE_TAIL = producing side) of each shard's
    announcement matrix, leaving the opposite side's ops untouched
    (their response lanes come back R_NONE).

    This is the device half of a split (two-lane) shard's ordinary phase:
    head-lane traffic moves only the head/left counter, tail-lane traffic
    only the values region and the tail/right counter, so the durable
    commit behind each dispatch persists just its own side.  Works for the
    vmap (``jnp``) and Pallas-grid (``ref`` / ``pallas`` / ``pallas_tpu``)
    paths via the shared ``_one_sharded_combine`` dispatch.
    """
    masked = _lane_mask_ops(kind, ops, lane)
    return _one_sharded_combine(kind, backend, state, masked, params)


@functools.partial(jax.jit, static_argnames=("kind", "backend"))
def dfc_handoff_combine_step(state, ops, params, *, kind, backend="jnp"):
    """The DRAINED-QUEUE HANDOFF step: both lanes' ops of a split shard in
    ONE combining phase, reusing the existing elimination math unchanged —
    when the head lane's pops outrun the tail lane's committed pushes, the
    two sides synchronize here (queue: drained two-sided elimination pairs
    deq rank size+k with enq rank k; deque: same-side elimination), and the
    runtime commits BOTH lane epochs atomically behind this dispatch.

    Semantically identical to the one-lane combine of the same batch (that
    is the point: a handoff phase must linearize exactly like the unsplit
    fabric would), for both the vmap and Pallas-grid paths.
    """
    return _one_sharded_combine(kind, backend, state, ops, params)


@functools.partial(jax.jit, static_argnames=("kind", "backend", "unroll"))
def dfc_sharded_multi_combine_step(
    state, ops, params, *, kind, backend="ref", unroll=1, keys=None
):
    """Chain B sharded combining phases through ONE dispatch.

    ``ops`` / ``params`` are ``[B, S, N]`` per-batch announcement matrices;
    the B batches are applied sequentially (``lax.scan`` over the leading
    batch axis) to the shard-stacked ``state``, exactly as B separate
    ``SHARDED_COMBINE_STEPS[kind]`` calls would — but the whole chain costs
    one dispatch (one scanned vmap for the jnp backend, one scanned Pallas
    grid for the kernel backends), which is what lets a pipelined durable
    path amortize dispatch overhead across batches.

    Per batch, shards that received no ops keep their state AND epoch (no
    phantom phases), so the per-shard epoch after batch b is exactly what b
    separate phases would have produced — the two-increment durable commit
    per batch is unchanged.  An all-``OP_NONE`` batch is therefore a pure
    pass-through (state, epochs, and counters untouched, ``R_NONE``
    responses): a depth-D pipeline exploits this by PADDING every chain to a
    fixed batch count, so all of a fabric's dispatches — however many
    announcers happened to be ready — share one compiled program per lane
    width instead of one per ready-set size.

    ``unroll`` (static) unrolls the scan body that many batches per step —
    the depth-aware dispatch knob: a depth-D pipeline passes D so XLA can
    fuse the window of batches it keeps in flight into straight-line code.

    Returns ``(states, resp, kinds)`` where ``states`` is the shard-stacked
    state AFTER each batch (every leaf gains a leading B axis; ``states[-1]``
    is the final state) and ``resp`` / ``kinds`` are ``[B, S, N]``.
    """

    all_keys = jnp.zeros_like(ops) if keys is None else keys

    def body(carry, xs):
        b_keys, b_ops, b_params = xs
        combined, s_resp, s_kinds = _one_sharded_combine(
            kind, backend, carry, b_ops, b_params, keys=b_keys
        )
        touched = jnp.any(b_ops != OP_NONE, axis=1)  # bool[S]

        def _select(new_leaf, old_leaf):
            t = touched.reshape(touched.shape + (1,) * (new_leaf.ndim - 1))
            return jnp.where(t, new_leaf, old_leaf)

        new_state = jax.tree_util.tree_map(_select, combined, carry)
        return new_state, (new_state, s_resp, s_kinds)

    _, (states, resp, kinds) = jax.lax.scan(
        body,
        state,
        (all_keys, ops, params),
        unroll=max(1, min(int(unroll), ops.shape[0])),
    )
    return states, resp, kinds


def dfc_hetero_multi_combine_step(
    groups, group_ops, group_params, *, backend="ref", unroll=1,
    group_keys=None,
):
    """Chained heterogeneous combine: ``dfc_sharded_multi_combine_step`` per
    kind group present.  ``group_ops[kind]`` is ``[B, S_kind, N]``; every kind
    chains its B batches in one dispatch, unrolled ``unroll`` batches per
    scan step (the pipeline passes its depth).  ``group_keys`` carries the
    routed announcement keys for keyed kinds (the map).  Returns ``{kind:
    (states, resp, kinds)}`` with the per-batch leading axis (see the
    homogeneous twin).  Meant to be called inside an enclosing jit (not
    jitted itself)."""
    out = {}
    for kind in sorted(groups):
        out[kind] = dfc_sharded_multi_combine_step(
            groups[kind], group_ops[kind], group_params[kind],
            kind=kind, backend=backend, unroll=unroll,
            keys=None if group_keys is None else group_keys.get(kind),
        )
    return out


# ------------------------------------------------------------ K-phase fusion
def _phase_grid_combine(kind: str, backend: str, state, ops, params, keys=None):
    """Pallas-grid-over-the-phase-axis twin of the scanned K-phase chain.

    One ``pallas_call`` with ``grid=(K,)``: program instance k runs phase k
    over ALL shards of the kind group, with the working shard-stacked state
    carried ACROSS grid steps in VMEM scratch (copied in from the input
    state at k == 0) — the phase chain never round-trips through HBM between
    phases.  Each instance applies the vectorized combine math (the same
    ``STRUCTS[kind].combine`` the jnp backend vmaps), honors the
    pass-through-batch contract (an all-``OP_NONE`` phase leaves state and
    epoch untouched), and writes phase k's post-state, responses, and kinds
    into the k-th row of the outputs.

    ``backend`` picks interpret mode (``pallas``) or compiled TPU lowering
    (``pallas_tpu``); the jnp/ref backends have no grid to run on — use the
    scan variant.
    """
    from repro.core.jax_dfc import STRUCTS

    if backend not in ("pallas", "pallas_tpu"):
        raise ValueError(
            f"phase_axis='grid' needs a Pallas backend, got {backend!r}"
        )
    k_phases, n_shards, n = ops.shape
    if keys is None:
        keys = jnp.zeros_like(ops)
    leaves, treedef = jax.tree_util.tree_flatten(state)
    n_leaves = len(leaves)
    keyed = STRUCTS[kind].keyed
    combine = jax.vmap(STRUCTS[kind].combine)

    def kernel(*refs):
        state_in = refs[:n_leaves]
        keys_ref, ops_ref, par_ref = (
            refs[n_leaves], refs[n_leaves + 1], refs[n_leaves + 2]
        )
        state_out = refs[n_leaves + 3: 2 * n_leaves + 3]
        resp_ref, kind_ref = refs[2 * n_leaves + 3], refs[2 * n_leaves + 4]
        scratch = refs[2 * n_leaves + 5:]
        k = pl.program_id(0)

        @pl.when(k == 0)
        def _():
            for dst, src in zip(scratch, state_in):
                dst[...] = src[...]

        carry = jax.tree_util.tree_unflatten(
            treedef, [s[...] for s in scratch]
        )
        b_ops, b_params = ops_ref[0], par_ref[0]
        if keyed:
            combined, resp, kinds = combine(carry, keys_ref[0], b_ops, b_params)
        else:
            combined, resp, kinds = combine(carry, b_ops, b_params)
        touched = jnp.any(b_ops != OP_NONE, axis=1)  # bool[S]

        def _select(new_leaf, old_leaf):
            t = touched.reshape(touched.shape + (1,) * (new_leaf.ndim - 1))
            return jnp.where(t, new_leaf, old_leaf)

        new_state = jax.tree_util.tree_map(_select, combined, carry)
        for dst, out, leaf in zip(
            scratch, state_out, jax.tree_util.tree_leaves(new_state)
        ):
            dst[...] = leaf
            out[0] = leaf
        resp_ref[0] = resp
        kind_ref[0] = kinds

    def _whole(leaf):  # one un-tiled block, revisited every grid step
        nd = leaf.ndim
        return pl.BlockSpec(leaf.shape, lambda k, _nd=nd: (0,) * _nd)

    def _phase_row(shape):  # (1, ...) block at phase k
        nd = len(shape)
        return pl.BlockSpec(
            (1,) + shape, lambda k, _nd=nd: (k,) + (0,) * _nd
        )

    # out_shape/out_specs MUST be flat tuples: a nested tuple makes
    # pallas_call mis-pair specs with shapes and the kernel sees fewer out
    # refs than leaves (observed: a stray scalar ref where the first state
    # leaf should be).  Flatten here, regroup after the call.
    outs = pl.pallas_call(
        kernel,
        grid=(k_phases,),
        out_shape=tuple(
            jax.ShapeDtypeStruct((k_phases,) + l.shape, l.dtype)
            for l in leaves
        )
        + (
            jax.ShapeDtypeStruct((k_phases, n_shards, n), jnp.float32),
            jax.ShapeDtypeStruct((k_phases, n_shards, n), jnp.int32),
        ),
        in_specs=[_whole(l) for l in leaves]
        + [
            _phase_row((n_shards, n)),
            _phase_row((n_shards, n)),
            _phase_row((n_shards, n)),
        ],
        out_specs=tuple(_phase_row(l.shape) for l in leaves)
        + (_phase_row((n_shards, n)), _phase_row((n_shards, n))),
        scratch_shapes=[pltpu.VMEM(l.shape, l.dtype) for l in leaves],
        interpret=backend == "pallas",
    )(*leaves, keys, ops, params)
    states = jax.tree_util.tree_unflatten(treedef, list(outs[:n_leaves]))
    resp, kinds = outs[n_leaves], outs[n_leaves + 1]
    return states, resp, kinds


@functools.partial(
    jax.jit, static_argnames=("kind", "backend", "unroll", "phase_axis")
)
def dfc_multi_phase_step(
    state, ops, params, *, kind, backend="ref", unroll=1, phase_axis="scan",
    keys=None,
):
    """Fuse K combining PHASES of one kind group into a single dispatch and
    accumulate each phase's persist INTENTS device-side.

    ``ops`` / ``params`` are ``[K, S, N]`` per-phase announcement matrices.
    The K phases chain exactly like K separate sharded combine calls — built
    on the same ``_one_sharded_combine`` dispatch and honoring the
    pass-through-batch contract (an all-``OP_NONE`` phase is a pure no-op:
    state, epochs, counters untouched) — but nothing leaves the device
    between phases, and nothing durable happens here at all.  Instead the
    per-phase epoch/persist intents come back as one
    :class:`~repro.core.jax_dfc.PhaseIntents` log that the host drains
    asynchronously behind the device, issuing each phase's pwb/pfence batch
    in serial commit order (see ``ShardedDFCRuntime.phase_loop``).

    ``phase_axis`` picks the fusion mechanism (both produce identical
    results):

      * ``"scan"`` — ``lax.scan`` over the phase axis, ``unroll`` phases per
        step; works on every backend (the scan body dispatches
        ``_one_sharded_combine``, so kernel backends still run one Pallas
        grid per phase inside the fused program),
      * ``"grid"`` — ONE Pallas grid over the phase axis itself
        (``grid=(K,)``, program instance = phase, shard-stacked state
        carried in VMEM scratch across grid steps); Pallas backends only.

    Returns ``(states, resp, kinds, intents)``: ``states`` with a leading K
    axis (``states[-1]`` is the final state), ``resp`` / ``kinds``
    ``[K, S, N]``, and ``intents`` the ``PhaseIntents`` record (cumulative
    counters start at zero — the caller adds its durable baseline).
    """
    if phase_axis == "grid":
        states, resp, kinds = _phase_grid_combine(
            kind, backend, state, ops, params, keys=keys
        )
    elif phase_axis == "scan":
        states, resp, kinds = dfc_sharded_multi_combine_step(
            state, ops, params, kind=kind, backend=backend, unroll=unroll,
            keys=keys,
        )
    else:
        raise ValueError(f"unknown phase_axis {phase_axis!r}")
    touched = jnp.any(ops != OP_NONE, axis=2)  # bool[K, S]
    per_phase_ops = jnp.sum((ops != OP_NONE).astype(jnp.int32), axis=2)
    intents = PhaseIntents(
        epoch=states.epoch.astype(jnp.int32),
        touched=touched,
        phases_cum=jnp.cumsum(touched.astype(jnp.int32), axis=0),
        ops_cum=jnp.cumsum(per_phase_ops, axis=0),
    )
    return states, resp, kinds, intents


def dfc_hetero_multi_phase_step(
    groups, group_ops, group_params, *, backend="ref", unroll=1,
    phase_axis="scan", group_keys=None,
):
    """Heterogeneous K-phase fusion: ``dfc_multi_phase_step`` per kind group
    present (``group_ops[kind]`` is ``[K, S_kind, N]``).  ``group_keys``
    carries the routed announcement keys for keyed kinds (the map).  Returns
    ``{kind: (states, resp, kinds, intents)}`` — every kind fuses its whole
    phase chain in one dispatch.  Meant to be called inside an enclosing jit
    (not jitted itself)."""
    out = {}
    for kind in sorted(groups):
        out[kind] = dfc_multi_phase_step(
            groups[kind], group_ops[kind], group_params[kind],
            kind=kind, backend=backend, unroll=unroll, phase_axis=phase_axis,
            keys=None if group_keys is None else group_keys.get(kind),
        )
    return out


# ------------------------------------------------------------- heterogeneous
def dfc_hetero_combine_step(
    groups, group_ops, group_params, *, backend="ref", group_keys=None
):
    """STRUCTS-dispatched combine over a heterogeneous shard fabric.

    ``groups`` maps a structure kind to that kind's shard-stacked state;
    ``group_ops`` / ``group_params`` hold the matching ``[S_kind, N]``
    announcement matrices.  Program instances are grouped BY KIND: each kind
    present gets exactly one dispatch — a ``vmap`` of its combine for the
    ``jnp`` backend, or one Pallas grid call (``grid=(S_kind,)``, program
    instance = shard) for the kernel backends — so a mixed stack/queue/deque
    fabric costs one dispatch per kind, not per shard.

    Returns ``{kind: (new_state, responses[S_kind, N], kinds[S_kind, N])}``.
    Meant to be called inside an enclosing jit (it is not jitted itself).
    """
    out = {}
    for kind in sorted(groups):
        out[kind] = _one_sharded_combine(
            kind, backend, groups[kind], group_ops[kind], group_params[kind],
            keys=None if group_keys is None else group_keys.get(kind),
        )
    return out
