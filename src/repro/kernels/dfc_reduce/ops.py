"""Jitted public wrappers: full DFC combine steps using the Pallas kernels.

Splice the kernel outputs (responses / surplus segments / counts) into the
array-backed double-buffered structure states (stack, queue, deque).
``backend`` selects the Pallas kernel (compiled for TPU via ``pallas_tpu``,
interpret-mode via ``pallas``) or the pure-jnp oracle (``ref``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.jax_dfc import DequeState, QueueState, StackState
from repro.kernels.dfc_reduce.kernel import (
    dfc_deque_reduce_call,
    dfc_queue_reduce_call,
    dfc_reduce_call,
)
from repro.kernels.dfc_reduce.ref import (
    dfc_deque_reduce_ref,
    dfc_queue_reduce_ref,
    dfc_reduce_ref,
)


@functools.partial(jax.jit, static_argnames=("backend",))
def dfc_combine_step(state: StackState, ops, params, *, backend: str = "ref"):
    n = ops.shape[0]
    cap = state.values.shape[0]
    old_size = state.active_size()

    # window = stack[top-n : top], zero-padded below the bottom
    start = jnp.clip(old_size - n, 0, cap - n)
    raw = jax.lax.dynamic_slice(state.values, (start,), (n,))
    # when old_size < n the slice starts at 0 and the top is at old_size-1;
    # shift so the committed top sits at window[n-1]
    shift = jnp.where(old_size >= n, 0, n - old_size)
    window = jnp.roll(raw, shift)
    window = jnp.where(jnp.arange(n) >= shift, window, 0.0)

    if backend == "pallas":
        resp, kinds, segment, counts = dfc_reduce_call(
            ops, params, window, old_size, interpret=True
        )
    elif backend == "pallas_tpu":
        resp, kinds, segment, counts = dfc_reduce_call(
            ops, params, window, old_size, interpret=False
        )
    else:
        resp, kinds, segment, counts = dfc_reduce_ref(ops, params, window, old_size)

    n_push_surplus, n_popped = counts[0], counts[1]
    new_values = jax.lax.dynamic_update_slice(
        state.values, segment.astype(state.values.dtype), (jnp.clip(old_size, 0, cap - n),)
    )
    keep = (jnp.arange(cap) >= old_size) & (jnp.arange(cap) < old_size + n_push_surplus)
    new_values = jnp.where(keep, new_values, state.values)

    new_size_val = old_size + n_push_surplus - n_popped
    inactive = (state.epoch // 2 + 1) % 2
    new_state = StackState(
        values=new_values,
        size=state.size.at[inactive].set(new_size_val),
        epoch=state.epoch + 2,
    )
    return new_state, resp, kinds


@functools.partial(jax.jit, static_argnames=("backend",))
def dfc_queue_combine_step(state: QueueState, ops, params, *, backend: str = "ref"):
    """Queue combine phase: front window -> kernel -> masked ring splice."""
    n = ops.shape[0]
    cap = state.values.shape[0]
    ends = state.active_ends()
    head, tail = ends[0], ends[1]
    size = tail - head

    lanes = jnp.arange(n)
    window = jnp.where(lanes < size, state.values[(head + lanes) % cap], 0.0)
    window = window.astype(jnp.float32)

    if backend == "pallas":
        resp, kinds, segment, counts = dfc_queue_reduce_call(
            ops, params, window, size, interpret=True
        )
    elif backend == "pallas_tpu":
        resp, kinds, segment, counts = dfc_queue_reduce_call(
            ops, params, window, size, interpret=False
        )
    else:
        resp, kinds, segment, counts = dfc_queue_reduce_ref(ops, params, window, size)

    n_enq_surplus, n_from_q = counts[0], counts[1]
    pos = (tail + lanes) % cap
    new_values = state.values.at[
        jnp.where(lanes < n_enq_surplus, pos, cap)
    ].set(segment.astype(state.values.dtype), mode="drop")

    inactive = (state.epoch // 2 + 1) % 2
    new_ends = jnp.stack([head + n_from_q, tail + n_enq_surplus])
    new_state = QueueState(
        values=new_values,
        ends=state.ends.at[inactive].set(new_ends),
        epoch=state.epoch + 2,
    )
    return new_state, resp, kinds


@functools.partial(jax.jit, static_argnames=("backend",))
def dfc_deque_combine_step(state: DequeState, ops, params, *, backend: str = "ref"):
    """Deque combine phase: end windows -> two-sided kernel -> ring splices."""
    n = ops.shape[0]
    cap = state.values.shape[0]
    ends = state.active_ends()
    left, right = ends[0], ends[1]
    size = right - left

    lanes = jnp.arange(n)
    window_l = jnp.where(lanes < size, state.values[(left + lanes) % cap], 0.0)
    window_r = jnp.where(lanes < size, state.values[(right - 1 - lanes) % cap], 0.0)
    window_l = window_l.astype(jnp.float32)
    window_r = window_r.astype(jnp.float32)

    if backend == "pallas":
        resp, kinds, seg_l, seg_r, counts = dfc_deque_reduce_call(
            ops, params, window_l, window_r, size, interpret=True
        )
    elif backend == "pallas_tpu":
        resp, kinds, seg_l, seg_r, counts = dfc_deque_reduce_call(
            ops, params, window_l, window_r, size, interpret=False
        )
    else:
        resp, kinds, seg_l, seg_r, counts = dfc_deque_reduce_ref(
            ops, params, window_l, window_r, size
        )

    sl, dl, sr, dr = counts[0], counts[1], counts[2], counts[3]
    posl = (left - 1 - lanes) % cap
    new_values = state.values.at[jnp.where(lanes < sl, posl, cap)].set(
        seg_l.astype(state.values.dtype), mode="drop"
    )
    posr = (right + lanes) % cap
    new_values = new_values.at[jnp.where(lanes < sr, posr, cap)].set(
        seg_r.astype(state.values.dtype), mode="drop"
    )

    inactive = (state.epoch // 2 + 1) % 2
    new_ends = jnp.stack([left - sl + dl, right + sr - dr])
    new_state = DequeState(
        values=new_values,
        ends=state.ends.at[inactive].set(new_ends),
        epoch=state.epoch + 2,
    )
    return new_state, resp, kinds
