"""Pallas TPU kernel for the DFC combining phase (paper Algorithm 2, REDUCE).

One program instance processes a whole announcement batch of N lanes plus a
window of the stack top.  The batch sizes the paper cares about (N = number
of threads/workers, up to a few thousand) fit a single VMEM block, so the
kernel is a single-grid fused pass:

  * prefix sums over the push/pop lane masks (VPU),
  * all value routing (push->pop elimination pairing, surplus compaction)
    expressed as one-hot f32 matmuls so it runs on the MXU — the TPU-native
    replacement for the paper's pointer-walking sequential combiner,
  * the stack-top window is read for surplus pops and the new segment is
    produced for surplus pushes; the caller splices it into the full stack
    array with a dynamic_update_slice.

Inputs (all VMEM blocks):
  ops_ref      i32[N]    op codes (0 none, 1 push, 2 pop)
  params_ref   f32[N]    push arguments
  window_ref   f32[N]    stack[top-N : top] (zero-padded below), caller-built
  size_ref     i32[1]    current committed size (for EMPTY detection)
Outputs:
  resp_ref     f32[N]    response values
  kind_ref     i32[N]    response kinds (0 none, 1 ack, 2 value, 3 empty)
  segment_ref  f32[N]    surplus-push values, rank-compacted from index 0
  counts_ref   i32[4]    (n_push_surplus, n_popped, n_elim, q_total)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

OP_PUSH = 1
OP_POP = 2
R_NONE = 0
R_ACK = 1
R_VALUE = 2
R_EMPTY = 3


def _route(src_idx, vals, n):
    """out[i] = sum_j [src_idx[j] == i] * vals[j] — one-hot MXU matmul."""
    onehot = (src_idx[None, :] == jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0)).astype(
        jnp.float32
    )
    return jnp.dot(onehot, vals.astype(jnp.float32), preferred_element_type=jnp.float32)


def dfc_reduce_kernel(ops_ref, params_ref, window_ref, size_ref, resp_ref, kind_ref, segment_ref, counts_ref):
    n = ops_ref.shape[0]
    ops = ops_ref[:]
    params = params_ref[:].astype(jnp.float32)
    window = window_ref[:].astype(jnp.float32)
    size = size_ref[0]

    is_push = ops == OP_PUSH
    is_pop = ops == OP_POP
    push_rank = jnp.where(is_push, jnp.cumsum(is_push.astype(jnp.int32)) - 1, -1)
    pop_rank = jnp.where(is_pop, jnp.cumsum(is_pop.astype(jnp.int32)) - 1, -1)
    p_total = jnp.sum(is_push.astype(jnp.int32))
    q_total = jnp.sum(is_pop.astype(jnp.int32))
    n_elim = jnp.minimum(p_total, q_total)

    # elimination pairing: pop_k <- push_k.param (one-hot route + gather-route)
    push_by_rank = _route(push_rank, params, n)
    pop_gather = (
        jnp.clip(pop_rank, 0, n - 1)[:, None]
        == jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    ).astype(jnp.float32)
    elim_pop_val = jnp.dot(pop_gather, push_by_rank, preferred_element_type=jnp.float32)

    # surplus push compaction into the segment
    surplus_push = is_push & (push_rank >= n_elim)
    seg_idx = jnp.where(surplus_push, push_rank - n_elim, n)
    segment = _route(seg_idx, params, n)

    # surplus pops read the window: window[N-1] is the committed top
    surplus_pop = is_pop & (pop_rank >= n_elim)
    depth = pop_rank - n_elim
    win_src = n - 1 - depth  # index into the window
    pop_ok = surplus_pop & (win_src >= 0) & (depth < size)
    win_gather = (
        jnp.clip(win_src, 0, n - 1)[:, None]
        == jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    ).astype(jnp.float32)
    stack_val = jnp.dot(win_gather, window, preferred_element_type=jnp.float32)

    kinds = jnp.full((n,), R_NONE, dtype=jnp.int32)
    kinds = jnp.where(is_push, R_ACK, kinds)
    kinds = jnp.where(is_pop & (pop_rank < n_elim), R_VALUE, kinds)
    kinds = jnp.where(pop_ok, R_VALUE, kinds)
    kinds = jnp.where(surplus_pop & ~pop_ok, R_EMPTY, kinds)
    resp = jnp.zeros((n,), dtype=jnp.float32)
    resp = jnp.where(is_pop & (pop_rank < n_elim), elim_pop_val, resp)
    resp = jnp.where(pop_ok, stack_val, resp)

    resp_ref[:] = resp
    kind_ref[:] = kinds
    segment_ref[:] = segment
    n_push_surplus = jnp.maximum(p_total - n_elim, 0)
    n_popped = jnp.minimum(jnp.maximum(q_total - n_elim, 0), size)
    counts_ref[0] = n_push_surplus
    counts_ref[1] = n_popped
    counts_ref[2] = n_elim
    counts_ref[3] = q_total


@functools.partial(jax.jit, static_argnames=("interpret",))
def dfc_reduce_call(ops, params, window, size, *, interpret: bool = True):
    n = ops.shape[0]
    return pl.pallas_call(
        dfc_reduce_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.float32),  # responses
            jax.ShapeDtypeStruct((n,), jnp.int32),  # kinds
            jax.ShapeDtypeStruct((n,), jnp.float32),  # segment
            jax.ShapeDtypeStruct((4,), jnp.int32),  # counts
        ),
        in_specs=[
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((1,), lambda: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((4,), lambda: (0,)),
        ),
        interpret=interpret,
    )(ops, params, window, jnp.asarray(size, jnp.int32).reshape(1))
