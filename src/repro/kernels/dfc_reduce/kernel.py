"""Pallas TPU kernels for the DFC combining phase (paper Algorithm 2, REDUCE).

One program instance processes a whole announcement batch of N lanes plus a
window of the structure's active end(s).  The batch sizes the paper cares
about (N = number of threads/workers, up to a few thousand) fit a single
VMEM block, so each kernel is a single-grid fused pass:

  * prefix sums over the op lane masks (VPU),
  * all value routing (elimination pairing, surplus compaction) expressed as
    one-hot f32 matmuls so it runs on the MXU — the TPU-native replacement
    for the paper's pointer-walking sequential combiner,
  * end windows are read for surplus removals and new segments are produced
    for surplus insertions; the caller splices them into the full array
    (stack: dynamic_update_slice above the committed top; queue/deque:
    masked ring scatter outside the committed window).

Three kernels, one per structure:

``dfc_reduce_kernel`` — LIFO stack (one-sided):
  ops_ref      i32[N]    op codes (0 none, 1 push, 2 pop)
  params_ref   f32[N]    push arguments
  window_ref   f32[N]    stack[top-N : top] (zero-padded below), caller-built
  size_ref     i32[1]    current committed size (for EMPTY detection)
  -> resp f32[N], kind i32[N], segment f32[N],
     counts i32[4] = (n_push_surplus, n_popped, n_elim, q_total)

``dfc_queue_reduce_kernel`` — FIFO queue (two-sided: consumes at the head,
appends at the tail, eliminates enq/deq pairs once the window drains):
  window_ref   f32[N]    queue[head : head+N] front window (zero-padded)
  -> resp, kind, segment (tail-append values, rank-compacted),
     counts i32[4] = (n_enq_surplus, n_from_q, n_elim, q_total)

``dfc_deque_reduce_kernel`` — deque (two-sided reduce in one pass: same-side
pair elimination, then the left surplus, then the right surplus; right pops
may consume same-phase left pushes via the in-register seg_l):
  window_l_ref f32[N]    deque[left : left+N] seen from the left
  window_r_ref f32[N]    deque[right-1 : right-1-N] seen from the right
  -> resp, kind, seg_l (left-prepend values), seg_r (right-append values),
     counts i32[8] = (sl, dl, sr, dr, nl_elim, nr_elim, size_after, 0)

Sharded grid variants (``dfc_*_reduce_grid_call``): the same math over a
stacked batch — inputs carry a leading shard axis ``[S, N]`` (sizes ``[S]``),
``grid=(S,)``, and each program instance runs ONE shard's combining phase.
The combine math itself is shared (``_*_reduce_math``) between the
single-object kernels and the grid kernels, so the two paths cannot drift.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

OP_PUSH = 1
OP_POP = 2
OP_ENQ = OP_PUSH
OP_DEQ = OP_POP
OP_PUSHL = 1
OP_POPL = 2
OP_PUSHR = 3
OP_POPR = 4
R_NONE = 0
R_ACK = 1
R_VALUE = 2
R_EMPTY = 3
# map op codes / response kinds (local copies; see core/jax_dfc.py — code 4
# is the runtime's R_OVERFLOW, so map rejections start at 5)
OP_MAP_INSERT = 1
OP_MAP_LOOKUP = 2
OP_MAP_DELETE = 3
OP_MAP_CAS = 4
R_FULL = 5
R_CAS_FAIL = 6
CAS_DOM = 4096
MAP_BUCKET_SLOTS = 8


def _route(src_idx, vals, n):
    """out[i] = sum_j [src_idx[j] == i] * vals[j] — one-hot MXU matmul."""
    onehot = (src_idx[None, :] == jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0)).astype(
        jnp.float32
    )
    return jnp.dot(onehot, vals.astype(jnp.float32), preferred_element_type=jnp.float32)


def _gather(vals, idx, n):
    """out[i] = vals[clip(idx[i])] — one-hot MXU matmul gather."""
    onehot = (
        jnp.clip(idx, 0, n - 1)[:, None]
        == jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    ).astype(jnp.float32)
    return jnp.dot(onehot, vals.astype(jnp.float32), preferred_element_type=jnp.float32)


# ------------------------------------------------------------- shared math
def _stack_reduce_math(ops, params, window, size):
    n = ops.shape[0]
    params = params.astype(jnp.float32)
    window = window.astype(jnp.float32)

    is_push = ops == OP_PUSH
    is_pop = ops == OP_POP
    push_rank = jnp.where(is_push, jnp.cumsum(is_push.astype(jnp.int32)) - 1, -1)
    pop_rank = jnp.where(is_pop, jnp.cumsum(is_pop.astype(jnp.int32)) - 1, -1)
    p_total = jnp.sum(is_push.astype(jnp.int32))
    q_total = jnp.sum(is_pop.astype(jnp.int32))
    n_elim = jnp.minimum(p_total, q_total)

    # elimination pairing: pop_k <- push_k.param (one-hot route + gather)
    push_by_rank = _route(push_rank, params, n)
    elim_pop_val = _gather(push_by_rank, pop_rank, n)

    # surplus push compaction into the segment
    surplus_push = is_push & (push_rank >= n_elim)
    seg_idx = jnp.where(surplus_push, push_rank - n_elim, n)
    segment = _route(seg_idx, params, n)

    # surplus pops read the window: window[N-1] is the committed top
    surplus_pop = is_pop & (pop_rank >= n_elim)
    depth = pop_rank - n_elim
    win_src = n - 1 - depth  # index into the window
    pop_ok = surplus_pop & (win_src >= 0) & (depth < size)
    stack_val = _gather(window, win_src, n)

    kinds = jnp.full((n,), R_NONE, dtype=jnp.int32)
    kinds = jnp.where(is_push, R_ACK, kinds)
    kinds = jnp.where(is_pop & (pop_rank < n_elim), R_VALUE, kinds)
    kinds = jnp.where(pop_ok, R_VALUE, kinds)
    kinds = jnp.where(surplus_pop & ~pop_ok, R_EMPTY, kinds)
    resp = jnp.zeros((n,), dtype=jnp.float32)
    resp = jnp.where(is_pop & (pop_rank < n_elim), elim_pop_val, resp)
    resp = jnp.where(pop_ok, stack_val, resp)

    n_push_surplus = jnp.maximum(p_total - n_elim, 0)
    n_popped = jnp.minimum(jnp.maximum(q_total - n_elim, 0), size)
    counts = jnp.stack([n_push_surplus, n_popped, n_elim, q_total]).astype(jnp.int32)
    return resp, kinds, segment, counts


def _queue_reduce_math(ops, params, window, size):
    n = ops.shape[0]
    params = params.astype(jnp.float32)
    window = window.astype(jnp.float32)  # window[j] = j-th from head

    is_enq = ops == OP_ENQ
    is_deq = ops == OP_DEQ
    enq_rank = jnp.where(is_enq, jnp.cumsum(is_enq.astype(jnp.int32)) - 1, -1)
    deq_rank = jnp.where(is_deq, jnp.cumsum(is_deq.astype(jnp.int32)) - 1, -1)
    p_total = jnp.sum(is_enq.astype(jnp.int32))
    q_total = jnp.sum(is_deq.astype(jnp.int32))
    n_from_q = jnp.minimum(q_total, size)
    n_elim = jnp.minimum(jnp.maximum(q_total - size, 0), p_total)

    # deqs served FIFO from the front window
    served = is_deq & (deq_rank < size)
    ring_val = _gather(window, deq_rank, n)

    # drained: deq rank size+k pairs with enq rank k (two-sided elimination)
    enq_by_rank = _route(enq_rank, params, n)
    paired = is_deq & (deq_rank >= size) & (deq_rank - size < n_elim)
    pair_val = _gather(enq_by_rank, deq_rank - size, n)
    empty = is_deq & (deq_rank >= size + n_elim)

    # surplus enqs, rank-compacted into the tail-append segment
    surplus_enq = is_enq & (enq_rank >= n_elim)
    seg_idx = jnp.where(surplus_enq, enq_rank - n_elim, n)
    segment = _route(seg_idx, params, n)

    kinds = jnp.full((n,), R_NONE, dtype=jnp.int32)
    kinds = jnp.where(is_enq, R_ACK, kinds)
    kinds = jnp.where(served | paired, R_VALUE, kinds)
    kinds = jnp.where(empty, R_EMPTY, kinds)
    resp = jnp.zeros((n,), dtype=jnp.float32)
    resp = jnp.where(served, ring_val, resp)
    resp = jnp.where(paired, pair_val, resp)

    counts = jnp.stack(
        [jnp.maximum(p_total - n_elim, 0), n_from_q, n_elim, q_total]
    ).astype(jnp.int32)
    return resp, kinds, segment, counts


def _deque_reduce_math(ops, params, window_l, window_r, size):
    n = ops.shape[0]
    params = params.astype(jnp.float32)
    window_l = window_l.astype(jnp.float32)  # j-th from the left end
    window_r = window_r.astype(jnp.float32)  # j-th from the right end

    is_pl = ops == OP_PUSHL
    is_ql = ops == OP_POPL
    is_pr = ops == OP_PUSHR
    is_qr = ops == OP_POPR
    pl_rank = jnp.where(is_pl, jnp.cumsum(is_pl.astype(jnp.int32)) - 1, -1)
    ql_rank = jnp.where(is_ql, jnp.cumsum(is_ql.astype(jnp.int32)) - 1, -1)
    pr_rank = jnp.where(is_pr, jnp.cumsum(is_pr.astype(jnp.int32)) - 1, -1)
    qr_rank = jnp.where(is_qr, jnp.cumsum(is_qr.astype(jnp.int32)) - 1, -1)
    npl = jnp.sum(is_pl.astype(jnp.int32))
    nql = jnp.sum(is_ql.astype(jnp.int32))
    npr = jnp.sum(is_pr.astype(jnp.int32))
    nqr = jnp.sum(is_qr.astype(jnp.int32))
    nl_elim = jnp.minimum(npl, nql)
    nr_elim = jnp.minimum(npr, nqr)

    # same-side elimination: pop_k gets push_k's param
    pl_by_rank = _route(pl_rank, params, n)
    pr_by_rank = _route(pr_rank, params, n)
    eliml = is_ql & (ql_rank < nl_elim)
    elimr = is_qr & (qr_rank < nr_elim)
    eliml_val = _gather(pl_by_rank, ql_rank, n)
    elimr_val = _gather(pr_by_rank, qr_rank, n)

    # left surplus (pushes XOR pops), applied first
    sl = jnp.maximum(npl - nl_elim, 0)
    tl = jnp.maximum(nql - nl_elim, 0)
    surplus_pl = is_pl & (pl_rank >= nl_elim)
    seg_l = _route(jnp.where(surplus_pl, pl_rank - nl_elim, n), params, n)
    dl = jnp.minimum(tl, size)
    surplus_ql = is_ql & (ql_rank >= nl_elim)
    kl = ql_rank - nl_elim
    lpop_ok = surplus_ql & (kl < size)
    lpop_val = _gather(window_l, kl, n)
    size_after = size + sl - dl

    # right surplus, applied after the left; right pop k reads the committed
    # window when k < size, else a value pushed left in this phase
    sr = jnp.maximum(npr - nr_elim, 0)
    tr = jnp.maximum(nqr - nr_elim, 0)
    surplus_pr = is_pr & (pr_rank >= nr_elim)
    seg_r = _route(jnp.where(surplus_pr, pr_rank - nr_elim, n), params, n)
    dr = jnp.minimum(tr, size_after)
    surplus_qr = is_qr & (qr_rank >= nr_elim)
    kr = qr_rank - nr_elim
    rpop_ok = surplus_qr & (kr < size_after)
    rpop_val = jnp.where(
        kr < size, _gather(window_r, kr, n), _gather(seg_l, kr - size, n)
    )

    kinds = jnp.full((n,), R_NONE, dtype=jnp.int32)
    kinds = jnp.where(is_pl | is_pr, R_ACK, kinds)
    kinds = jnp.where(eliml | elimr | lpop_ok | rpop_ok, R_VALUE, kinds)
    kinds = jnp.where(surplus_ql & ~lpop_ok, R_EMPTY, kinds)
    kinds = jnp.where(surplus_qr & ~rpop_ok, R_EMPTY, kinds)
    resp = jnp.zeros((n,), dtype=jnp.float32)
    resp = jnp.where(eliml, eliml_val, resp)
    resp = jnp.where(elimr, elimr_val, resp)
    resp = jnp.where(lpop_ok, lpop_val, resp)
    resp = jnp.where(rpop_ok, rpop_val, resp)

    counts = jnp.stack(
        [sl, dl, sr, dr, nl_elim, nr_elim, size_after, jnp.zeros((), jnp.int32)]
    ).astype(jnp.int32)
    return resp, kinds, seg_l, seg_r, counts


def _map_bucket(keys, n_buckets):
    """In-shard bucket hash (local twin of core's ``map_bucket``)."""
    h = jnp.asarray(keys).astype(jnp.uint32) * jnp.uint32(2654435761)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(2246822519)
    h = h ^ (h >> 13)
    return (h % jnp.uint32(n_buckets)).astype(jnp.int32)


def _map_reduce_math(mkeys, mvals, mocc, count, lkeys, ops, params):
    """One map shard's combining phase over N keyed lanes.

    Map ops do not commute, so lanes apply IN ANNOUNCEMENT ORDER (lax.scan);
    each lane probes only its key's bucket — a ``dynamic_slice`` window of
    ``bslots`` slots, updated in place — instead of masking the whole table
    (the vectorized combine's approach; the differential tests pin the two
    implementations to each other).

    Returns (keys', values', occupied', count', resp f32[N], kinds i32[N]).
    """
    cap = mkeys.shape[0]
    bslots = min(cap, MAP_BUCKET_SLOTS)
    n_buckets = cap // bslots
    win_idx = jax.lax.broadcasted_iota(jnp.int32, (bslots,), 0)

    def lane(carry, xs):
        mk, mv, mo, cnt = carry
        key, op, par = xs
        base = _map_bucket(key, n_buckets) * bslots
        wk = jax.lax.dynamic_slice(mk, (base,), (bslots,))
        wv = jax.lax.dynamic_slice(mv, (base,), (bslots,))
        wo = jax.lax.dynamic_slice(mo, (base,), (bslots,))
        occ = wo != 0
        hit = occ & (wk == key)  # key 0 is legal: hit needs the occupied bit
        has_hit = jnp.any(hit)
        hit_off = jnp.argmax(hit).astype(jnp.int32)
        has_free = jnp.any(~occ)
        free_off = jnp.argmax(~occ).astype(jnp.int32)
        # table keys are unique, so the masked sum IS the hit slot's value
        cur = jnp.sum(jnp.where(hit, wv, 0.0))

        is_ins = op == OP_MAP_INSERT
        is_lku = op == OP_MAP_LOOKUP
        is_del = op == OP_MAP_DELETE
        is_cas = op == OP_MAP_CAS
        expected = jnp.floor(par / CAS_DOM)
        cas_new = par - expected * CAS_DOM
        cas_hit = is_cas & has_hit
        cas_ok = cas_hit & (cur == expected)

        do_ins = is_ins & (has_hit | has_free)
        do_del = is_del & has_hit
        do_write = do_ins | cas_ok
        woff = jnp.where(has_hit, hit_off, free_off)
        wval = jnp.where(is_cas, cas_new, par)
        wmask = do_write & (win_idx == woff)
        dmask = do_del & (win_idx == hit_off)
        wk = jnp.where(wmask, key, jnp.where(dmask, 0, wk))
        wv = jnp.where(wmask, wval, jnp.where(dmask, 0.0, wv))
        wo = jnp.where(wmask, 1, jnp.where(dmask, 0, wo))
        mk = jax.lax.dynamic_update_slice(mk, wk, (base,))
        mv = jax.lax.dynamic_update_slice(mv, wv, (base,))
        mo = jax.lax.dynamic_update_slice(mo, wo, (base,))
        cnt = (
            cnt
            + (is_ins & ~has_hit & has_free).astype(jnp.int32)
            - do_del.astype(jnp.int32)
        )

        kind = jnp.full((), R_NONE, jnp.int32)
        kind = jnp.where(do_ins, R_ACK, kind)
        kind = jnp.where(is_ins & ~has_hit & ~has_free, R_FULL, kind)
        kind = jnp.where((is_lku | is_del | is_cas) & ~has_hit, R_EMPTY, kind)
        kind = jnp.where((is_lku | do_del | cas_ok) & has_hit, R_VALUE, kind)
        kind = jnp.where(cas_hit & ~cas_ok, R_CAS_FAIL, kind)
        resp = jnp.where((is_lku | is_del | is_cas) & has_hit, cur, 0.0)
        return (mk, mv, mo, cnt), (resp, kind)

    (mk, mv, mo, cnt), (resp, kinds) = jax.lax.scan(
        lane,
        (
            mkeys,
            mvals.astype(jnp.float32),
            mocc,
            jnp.asarray(count, jnp.int32).reshape(()),
        ),
        (
            lkeys.astype(jnp.int32),
            ops.astype(jnp.int32),
            params.astype(jnp.float32),
        ),
    )
    return mk, mv, mo, cnt, resp, kinds


# ------------------------------------------------------- single-object kernels
def dfc_reduce_kernel(ops_ref, params_ref, window_ref, size_ref, resp_ref, kind_ref, segment_ref, counts_ref):
    resp, kinds, segment, counts = _stack_reduce_math(
        ops_ref[:], params_ref[:], window_ref[:], size_ref[0]
    )
    resp_ref[:] = resp
    kind_ref[:] = kinds
    segment_ref[:] = segment
    counts_ref[:] = counts


def dfc_queue_reduce_kernel(
    ops_ref, params_ref, window_ref, size_ref, resp_ref, kind_ref, segment_ref, counts_ref
):
    resp, kinds, segment, counts = _queue_reduce_math(
        ops_ref[:], params_ref[:], window_ref[:], size_ref[0]
    )
    resp_ref[:] = resp
    kind_ref[:] = kinds
    segment_ref[:] = segment
    counts_ref[:] = counts


def dfc_deque_reduce_kernel(
    ops_ref,
    params_ref,
    window_l_ref,
    window_r_ref,
    size_ref,
    resp_ref,
    kind_ref,
    seg_l_ref,
    seg_r_ref,
    counts_ref,
):
    resp, kinds, seg_l, seg_r, counts = _deque_reduce_math(
        ops_ref[:], params_ref[:], window_l_ref[:], window_r_ref[:], size_ref[0]
    )
    resp_ref[:] = resp
    kind_ref[:] = kinds
    seg_l_ref[:] = seg_l
    seg_r_ref[:] = seg_r
    counts_ref[:] = counts


@functools.partial(jax.jit, static_argnames=("interpret",))
def dfc_reduce_call(ops, params, window, size, *, interpret: bool = True):
    n = ops.shape[0]
    return pl.pallas_call(
        dfc_reduce_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.float32),  # responses
            jax.ShapeDtypeStruct((n,), jnp.int32),  # kinds
            jax.ShapeDtypeStruct((n,), jnp.float32),  # segment
            jax.ShapeDtypeStruct((4,), jnp.int32),  # counts
        ),
        in_specs=[
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((1,), lambda: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((4,), lambda: (0,)),
        ),
        interpret=interpret,
    )(ops, params, window, jnp.asarray(size, jnp.int32).reshape(1))


@functools.partial(jax.jit, static_argnames=("interpret",))
def dfc_queue_reduce_call(ops, params, window, size, *, interpret: bool = True):
    n = ops.shape[0]
    return pl.pallas_call(
        dfc_queue_reduce_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.float32),  # responses
            jax.ShapeDtypeStruct((n,), jnp.int32),  # kinds
            jax.ShapeDtypeStruct((n,), jnp.float32),  # tail-append segment
            jax.ShapeDtypeStruct((4,), jnp.int32),  # counts
        ),
        in_specs=[
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((1,), lambda: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((4,), lambda: (0,)),
        ),
        interpret=interpret,
    )(ops, params, window, jnp.asarray(size, jnp.int32).reshape(1))


@functools.partial(jax.jit, static_argnames=("interpret",))
def dfc_deque_reduce_call(
    ops, params, window_l, window_r, size, *, interpret: bool = True
):
    n = ops.shape[0]
    return pl.pallas_call(
        dfc_deque_reduce_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.float32),  # responses
            jax.ShapeDtypeStruct((n,), jnp.int32),  # kinds
            jax.ShapeDtypeStruct((n,), jnp.float32),  # seg_l (left prepends)
            jax.ShapeDtypeStruct((n,), jnp.float32),  # seg_r (right appends)
            jax.ShapeDtypeStruct((8,), jnp.int32),  # counts
        ),
        in_specs=[
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((1,), lambda: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((8,), lambda: (0,)),
        ),
        interpret=interpret,
    )(ops, params, window_l, window_r, jnp.asarray(size, jnp.int32).reshape(1))


# ------------------------------------------------------------ sharded (grid)
def dfc_reduce_grid_kernel(
    ops_ref, params_ref, window_ref, size_ref, resp_ref, kind_ref, segment_ref, counts_ref
):
    resp, kinds, segment, counts = _stack_reduce_math(
        ops_ref[0, :], params_ref[0, :], window_ref[0, :], size_ref[0]
    )
    resp_ref[0, :] = resp
    kind_ref[0, :] = kinds
    segment_ref[0, :] = segment
    counts_ref[0, :] = counts


def dfc_queue_reduce_grid_kernel(
    ops_ref, params_ref, window_ref, size_ref, resp_ref, kind_ref, segment_ref, counts_ref
):
    resp, kinds, segment, counts = _queue_reduce_math(
        ops_ref[0, :], params_ref[0, :], window_ref[0, :], size_ref[0]
    )
    resp_ref[0, :] = resp
    kind_ref[0, :] = kinds
    segment_ref[0, :] = segment
    counts_ref[0, :] = counts


def dfc_deque_reduce_grid_kernel(
    ops_ref,
    params_ref,
    window_l_ref,
    window_r_ref,
    size_ref,
    resp_ref,
    kind_ref,
    seg_l_ref,
    seg_r_ref,
    counts_ref,
):
    resp, kinds, seg_l, seg_r, counts = _deque_reduce_math(
        ops_ref[0, :], params_ref[0, :], window_l_ref[0, :], window_r_ref[0, :], size_ref[0]
    )
    resp_ref[0, :] = resp
    kind_ref[0, :] = kinds
    seg_l_ref[0, :] = seg_l
    seg_r_ref[0, :] = seg_r
    counts_ref[0, :] = counts


def _row_spec(n):
    return pl.BlockSpec((1, n), lambda s: (s, 0))


def _scalar_spec():
    return pl.BlockSpec((1,), lambda s: (s,))


@functools.partial(jax.jit, static_argnames=("interpret",))
def dfc_reduce_grid_call(ops, params, windows, sizes, *, interpret: bool = True):
    """All shards' stack combines in ONE pallas dispatch: grid=(S,), program
    instance s runs shard s's combining phase over its [N]-lane row."""
    s, n = ops.shape
    return pl.pallas_call(
        dfc_reduce_grid_kernel,
        grid=(s,),
        out_shape=(
            jax.ShapeDtypeStruct((s, n), jnp.float32),  # responses
            jax.ShapeDtypeStruct((s, n), jnp.int32),  # kinds
            jax.ShapeDtypeStruct((s, n), jnp.float32),  # segments
            jax.ShapeDtypeStruct((s, 4), jnp.int32),  # counts
        ),
        in_specs=[
            _row_spec(n),
            _row_spec(n),
            _row_spec(n),
            _scalar_spec(),
        ],
        out_specs=(
            _row_spec(n),
            _row_spec(n),
            _row_spec(n),
            pl.BlockSpec((1, 4), lambda i: (i, 0)),
        ),
        interpret=interpret,
    )(ops, params, windows, sizes.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def dfc_queue_reduce_grid_call(ops, params, windows, sizes, *, interpret: bool = True):
    """All shards' queue combines in one dispatch (see dfc_reduce_grid_call)."""
    s, n = ops.shape
    return pl.pallas_call(
        dfc_queue_reduce_grid_kernel,
        grid=(s,),
        out_shape=(
            jax.ShapeDtypeStruct((s, n), jnp.float32),
            jax.ShapeDtypeStruct((s, n), jnp.int32),
            jax.ShapeDtypeStruct((s, n), jnp.float32),
            jax.ShapeDtypeStruct((s, 4), jnp.int32),
        ),
        in_specs=[
            _row_spec(n),
            _row_spec(n),
            _row_spec(n),
            _scalar_spec(),
        ],
        out_specs=(
            _row_spec(n),
            _row_spec(n),
            _row_spec(n),
            pl.BlockSpec((1, 4), lambda i: (i, 0)),
        ),
        interpret=interpret,
    )(ops, params, windows, sizes.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def dfc_deque_reduce_grid_call(
    ops, params, windows_l, windows_r, sizes, *, interpret: bool = True
):
    """All shards' deque combines in one dispatch (see dfc_reduce_grid_call)."""
    s, n = ops.shape
    return pl.pallas_call(
        dfc_deque_reduce_grid_kernel,
        grid=(s,),
        out_shape=(
            jax.ShapeDtypeStruct((s, n), jnp.float32),
            jax.ShapeDtypeStruct((s, n), jnp.int32),
            jax.ShapeDtypeStruct((s, n), jnp.float32),
            jax.ShapeDtypeStruct((s, n), jnp.float32),
            jax.ShapeDtypeStruct((s, 8), jnp.int32),
        ),
        in_specs=[
            _row_spec(n),
            _row_spec(n),
            _row_spec(n),
            _row_spec(n),
            _scalar_spec(),
        ],
        out_specs=(
            _row_spec(n),
            _row_spec(n),
            _row_spec(n),
            _row_spec(n),
            pl.BlockSpec((1, 8), lambda i: (i, 0)),
        ),
        interpret=interpret,
    )(ops, params, windows_l, windows_r, sizes.astype(jnp.int32))


def dfc_map_reduce_grid_kernel(
    mkeys_ref,
    mvals_ref,
    mocc_ref,
    count_ref,
    lkeys_ref,
    ops_ref,
    params_ref,
    keys_out_ref,
    vals_out_ref,
    occ_out_ref,
    count_out_ref,
    resp_ref,
    kind_ref,
):
    mk, mv, mo, cnt, resp, kinds = _map_reduce_math(
        mkeys_ref[0, :],
        mvals_ref[0, :],
        mocc_ref[0, :],
        count_ref[0],
        lkeys_ref[0, :],
        ops_ref[0, :],
        params_ref[0, :],
    )
    keys_out_ref[0, :] = mk
    vals_out_ref[0, :] = mv
    occ_out_ref[0, :] = mo
    count_out_ref[0, 0] = cnt
    resp_ref[0, :] = resp
    kind_ref[0, :] = kinds


@functools.partial(jax.jit, static_argnames=("interpret",))
def dfc_map_reduce_grid_call(
    mkeys, mvals, mocc, counts, lkeys, ops, params, *, interpret: bool = True
):
    """All shards' map combines in one dispatch: unlike the ring kinds there
    is no caller-side splice — the whole table rides through the kernel and
    comes back updated (map writes are scattered by bucket, not contiguous).
    """
    s, cap = mkeys.shape
    n = ops.shape[1]
    return pl.pallas_call(
        dfc_map_reduce_grid_kernel,
        grid=(s,),
        out_shape=(
            jax.ShapeDtypeStruct((s, cap), jnp.int32),  # keys'
            jax.ShapeDtypeStruct((s, cap), jnp.float32),  # values'
            jax.ShapeDtypeStruct((s, cap), jnp.int32),  # occupied'
            jax.ShapeDtypeStruct((s, 1), jnp.int32),  # count'
            jax.ShapeDtypeStruct((s, n), jnp.float32),  # responses
            jax.ShapeDtypeStruct((s, n), jnp.int32),  # kinds
        ),
        in_specs=[
            _row_spec(cap),
            _row_spec(cap),
            _row_spec(cap),
            _scalar_spec(),
            _row_spec(n),
            _row_spec(n),
            _row_spec(n),
        ],
        out_specs=(
            _row_spec(cap),
            _row_spec(cap),
            _row_spec(cap),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            _row_spec(n),
            _row_spec(n),
        ),
        interpret=interpret,
    )(
        mkeys,
        mvals.astype(jnp.float32),
        mocc,
        counts.astype(jnp.int32),
        lkeys,
        ops,
        params,
    )
