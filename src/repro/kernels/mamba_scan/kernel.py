"""Pallas TPU selective-scan kernel (mamba1, diagonal A).

The recurrence  h_t = exp(Δ_t A) ⊙ h_{t-1} + Δ_t B_t x_t,  y_t = C_t·h_t + D x_t
is evaluated chunk-by-chunk: grid (batch, channel_block, seq_chunk) with the
sequence dimension executed sequentially so the (blk_d, N) state carries in
VMEM scratch across chunks.  Inside a chunk a fori_loop walks the time steps
— all operands ((chunk, blk_d) inputs, (blk_d, N) state) stay in VMEM, which
is exactly the HBM-traffic structure that makes fused selective scan fast on
real hardware: inputs are read once, the state never leaves VMEM.

This adapts the CUDA selective-scan kernel's shared-memory strategy to the
TPU memory hierarchy (HBM -> VMEM tiles -> VREG elementwise), per the
hardware-adaptation requirement.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(dt_ref, a_ref, bx_ref, c_ref, x_ref, d_ref, y_ref, h_ref, *, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def init():
        h_ref[:] = jnp.zeros_like(h_ref)

    a = a_ref[:].astype(jnp.float32)  # (blk_d, N) log-A
    d_skip = d_ref[:].astype(jnp.float32)  # (blk_d,)

    def step(t, h):
        dt_t = dt_ref[0, t, :].astype(jnp.float32)  # (blk_d,)
        b_t = bx_ref[0, t, :].astype(jnp.float32)  # (N,)
        c_t = c_ref[0, t, :].astype(jnp.float32)  # (N,)
        x_t = x_ref[0, t, :].astype(jnp.float32)  # (blk_d,)
        abar = jnp.exp(dt_t[:, None] * (-jnp.exp(a)))  # (blk_d, N)
        h = abar * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y = jnp.sum(h * c_t[None, :], axis=1) + d_skip * x_t
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return h

    h_ref[:] = jax.lax.fori_loop(0, chunk, step, h_ref[:])


@functools.partial(jax.jit, static_argnames=("blk_d", "chunk", "interpret"))
def selective_scan(
    dt, a_log, b_ssm, c_ssm, x, d_skip, *, blk_d: int = 512, chunk: int = 64,
    interpret: bool = True,
):
    """dt/x: (B, S, DI); a_log: (DI, N); b_ssm/c_ssm: (B, S, N); d_skip: (DI,).

    Returns y: (B, S, DI)."""
    b, s, di = dt.shape
    n = a_log.shape[1]
    blk_d = min(blk_d, di)
    chunk = min(chunk, s)
    assert di % blk_d == 0 and s % chunk == 0
    grid = (b, di // blk_d, s // chunk)
    return pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, blk_d), lambda bi, dgi, ci: (bi, ci, dgi)),  # dt
            pl.BlockSpec((blk_d, n), lambda bi, dgi, ci: (dgi, 0)),  # a_log
            pl.BlockSpec((1, chunk, n), lambda bi, dgi, ci: (bi, ci, 0)),  # B
            pl.BlockSpec((1, chunk, n), lambda bi, dgi, ci: (bi, ci, 0)),  # C
            pl.BlockSpec((1, chunk, blk_d), lambda bi, dgi, ci: (bi, ci, dgi)),  # x
            pl.BlockSpec((blk_d,), lambda bi, dgi, ci: (dgi,)),  # D skip
        ],
        out_specs=pl.BlockSpec((1, chunk, blk_d), lambda bi, dgi, ci: (bi, ci, dgi)),
        out_shape=jax.ShapeDtypeStruct((b, s, di), dt.dtype),
        scratch_shapes=[pltpu.VMEM((blk_d, n), jnp.float32)],
        interpret=interpret,
    )(dt, a_log, b_ssm, c_ssm, x, d_skip)
