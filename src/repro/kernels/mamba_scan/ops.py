"""Public wrapper for the selective-scan kernel."""

from repro.kernels.mamba_scan.kernel import selective_scan
from repro.kernels.mamba_scan.ref import selective_scan_ref


def selective_scan_op(dt, a_log, b_ssm, c_ssm, x, d_skip, *, backend: str = "ref", **kw):
    if backend == "pallas":
        return selective_scan(dt, a_log, b_ssm, c_ssm, x, d_skip, interpret=True, **kw)
    if backend == "pallas_tpu":
        return selective_scan(dt, a_log, b_ssm, c_ssm, x, d_skip, interpret=False, **kw)
    return selective_scan_ref(dt, a_log, b_ssm, c_ssm, x, d_skip)
