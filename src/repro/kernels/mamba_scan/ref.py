"""Pure-jnp oracle for the selective-scan kernel (sequential reference)."""

import jax
import jax.numpy as jnp


def selective_scan_ref(dt, a_log, b_ssm, c_ssm, x, d_skip):
    """Same contract as kernel.selective_scan; lax.scan over time."""
    bsz, s, di = dt.shape
    n = a_log.shape[1]
    a = -jnp.exp(a_log.astype(jnp.float32))

    def step(h, inp):
        dt_t, b_t, c_t, x_t = inp  # (B,DI), (B,N), (B,N), (B,DI)
        abar = jnp.exp(dt_t[..., None].astype(jnp.float32) * a[None])
        h = abar * h + (dt_t * x_t)[..., None].astype(jnp.float32) * b_t[:, None, :].astype(jnp.float32)
        y = jnp.einsum("bdn,bn->bd", h, c_t.astype(jnp.float32))
        y = y + d_skip.astype(jnp.float32)[None] * x_t.astype(jnp.float32)
        return h, y

    h0 = jnp.zeros((bsz, di, n), jnp.float32)
    xs = (
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(b_ssm, 1, 0),
        jnp.moveaxis(c_ssm, 1, 0),
        jnp.moveaxis(x, 1, 0),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(dt.dtype)
