"""Pallas TPU flash attention (forward), GQA + causal.

Blocked online-softmax: grid (batch, q_head, q_block, k_block) — the TPU
grid executes the trailing dimension sequentially, so the running max /
denominator / accumulator live in VMEM scratch across k-block steps and the
output block is written on the last k step.  K/V blocks for a query head are
selected via the GQA head mapping (kv = q_head // group) in the BlockSpec
index maps, so only hd-wide tiles ever sit in VMEM:

  VMEM footprint ≈ blk_q·hd (q) + blk_k·hd (k,v) + blk_q·blk_k (scores)
                 + blk_q·(hd+2) (acc, m, l)   — fits ~2 MB at 512×512×128.

Causal masking is applied at tile granularity (full tiles above the diagonal
contribute nothing and are skipped cheaply with pl.when).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, blk_q, blk_k, n_k_blocks, scale, causal
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def init():
        m_ref[:] = jnp.full_like(m_ref, NEG_INF)
        l_ref[:] = jnp.zeros_like(l_ref)
        acc_ref[:] = jnp.zeros_like(acc_ref)

    q_start = qi * blk_q
    k_start = ki * blk_k

    # tiles entirely above the causal diagonal are skipped
    run = (k_start <= q_start + blk_q - 1) if causal else (ki >= 0)

    @pl.when(run)
    def compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (blk_q, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (blk_k, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[:]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[:] = l_ref[:] * alpha + jnp.sum(p, axis=1)
        acc_ref[:] = acc_ref[:] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[:] = m_new

    @pl.when(ki == n_k_blocks - 1)
    def finalize():
        denom = jnp.maximum(l_ref[:], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[:] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "blk_q", "blk_k", "interpret")
)
def flash_attention(
    q, k, v, *, causal: bool = True, blk_q: int = 128, blk_k: int = 128,
    interpret: bool = True,
):
    """q: (B, S, Hq, hd); k/v: (B, T, Hkv, hd) -> (B, S, Hq, hd)."""
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    blk_q = min(blk_q, s)
    blk_k = min(blk_k, t)
    n_q = s // blk_q
    n_k = t // blk_k
    scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel,
        blk_q=blk_q, blk_k=blk_k, n_k_blocks=n_k, scale=scale, causal=causal,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, blk_q, 1, hd), lambda b_, h, qi, ki: (b_, qi, h, 0)),
            pl.BlockSpec((1, blk_k, 1, hd), lambda b_, h, qi, ki, g=group: (b_, ki, h // g, 0)),
            pl.BlockSpec((1, blk_k, 1, hd), lambda b_, h, qi, ki, g=group: (b_, ki, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, 1, hd), lambda b_, h, qi, ki: (b_, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, hq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),  # running max m
            pltpu.VMEM((blk_q,), jnp.float32),  # running denom l
            pltpu.VMEM((blk_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
