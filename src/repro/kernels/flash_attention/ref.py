"""Pure-jnp oracle for flash attention (same signature)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True):
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.reshape(b, s, hkv, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qf, k.astype(jnp.float32)) / np.sqrt(hd)
    if causal:
        mask = jnp.arange(t)[None, :] <= jnp.arange(s)[:, None]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, hq, hd).astype(q.dtype)
