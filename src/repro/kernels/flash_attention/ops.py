"""Public wrapper: backend-selected attention (pallas kernel / jnp oracle).

Also provides ``chunked_attention`` — an XLA-native online-softmax attention
(scan over key blocks) used by the dry-run path where TPU Pallas cannot
lower.  Identical math to the kernel; O(S·blk) live memory instead of O(S²).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def attention(q, k, v, *, causal=True, backend: str = "ref", **kw):
    if backend == "pallas":
        return flash_attention(q, k, v, causal=causal, interpret=True, **kw)
    if backend == "pallas_tpu":
        return flash_attention(q, k, v, causal=causal, interpret=False, **kw)
    if backend == "chunked":
        return chunked_attention(q, k, v, causal=causal, **kw)
    return attention_ref(q, k, v, causal=causal)


@functools.partial(
    jax.jit, static_argnames=("causal", "blk_k", "unroll", "q_offset_static")
)
def chunked_attention(
    q, k, v, *, causal=True, blk_k: int = 512, q_offset=0, unroll: bool = True,
    q_offset_static=True,
):
    """Online-softmax attention over key chunks (flash-in-XLA).

    q: (B, S, Hq, hd); k/v: (B, T, Hkv, hd).  Never materializes (S, T).
    ``unroll=True`` uses a Python loop (static chunk count) — required for
    honest cost_analysis accounting (a lax.scan body would be counted once);
    it also lets XLA skip fully-masked chunks at compile time."""
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    blk_k = min(blk_k, t)
    n_k = t // blk_k
    scale = 1.0 / np.sqrt(hd)

    qf = q.reshape(b, s, hkv, g, hd).astype(jnp.float32)
    kc = k.reshape(b, n_k, blk_k, hkv, hd)
    vc = v.reshape(b, n_k, blk_k, hkv, hd)
    qpos = jnp.arange(s) + q_offset

    def step(carry, k_blk, v_blk, ki):
        m, l, acc = carry
        sres = jnp.einsum("bskgd,btkd->bkgst", qf, k_blk.astype(jnp.float32)) * scale
        if causal:
            kpos = ki * blk_k + jnp.arange(blk_k)
            mask = kpos[None, :] <= qpos[:, None]
            sres = jnp.where(mask[None, None, None], sres, -1e30)
        m_new = jnp.maximum(m, jnp.max(sres, axis=-1))
        p = jnp.exp(sres - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p, v_blk.astype(jnp.float32)
        )
        return (m_new, l, acc)

    carry = (
        jnp.full((b, hkv, g, s), -1e30, jnp.float32),
        jnp.zeros((b, hkv, g, s), jnp.float32),
        jnp.zeros((b, hkv, g, s, hd), jnp.float32),
    )
    if unroll:
        for ki in range(n_k):
            if causal and q_offset_static and ki * blk_k > s - 1:
                break  # fully-masked chunks contribute nothing (q_offset=0)
            carry = step(carry, kc[:, ki], vc[:, ki], ki)
    else:
        def scan_step(c, inp):
            kb, vb, ki = inp
            return step(c, kb, vb, ki), None

        carry, _ = jax.lax.scan(
            scan_step,
            carry,
            (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.arange(n_k)),
        )
    m, l, acc = carry
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, -2, 1).reshape(b, s, hq, hd)
    return out.astype(q.dtype)
