"""Public wrapper for fused RMSNorm."""

from repro.kernels.rmsnorm.kernel import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def rmsnorm_op(x, w, *, backend: str = "ref", eps: float = 1e-6):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    if backend == "pallas":
        out = rmsnorm(x2, w, eps=eps, interpret=True)
    elif backend == "pallas_tpu":
        out = rmsnorm(x2, w, eps=eps, interpret=False)
    else:
        out = rmsnorm_ref(x2, w, eps)
    return out.reshape(shape)
