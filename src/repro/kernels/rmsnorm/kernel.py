"""Pallas TPU fused RMSNorm.

One pass over each row block: mean-of-squares reduction in f32, rsqrt,
scale — avoids the separate square/reduce/multiply HLOs (3 HBM round trips)
of the unfused path.  Grid over row blocks; the full feature dim sits in
VMEM (d_model ≤ 8192 → ≤ 32 KB/row at f32).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[:].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[:] = (x * jax.lax.rsqrt(var + eps) * w_ref[:].astype(jnp.float32)).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("blk", "eps", "interpret"))
def rmsnorm(x, w, *, blk: int = 256, eps: float = 1e-6, interpret: bool = True):
    """x: (R, D) row-major activations; w: (D,)."""
    r, d = x.shape
    blk = min(blk, r)
    n = r // blk
    assert r % blk == 0, "row count must divide the block size"
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((blk, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(x, w)
