from repro.data.pipeline import DataPipeline

__all__ = ["DataPipeline"]
