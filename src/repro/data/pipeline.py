"""Deterministic, resumable data pipeline.

State is a single integer cursor (+ the immutable seed): batch k is a pure
function of (seed, k), so carrying the cursor in the DFC announcement makes
data position part of the detectable checkpoint — on recovery the pipeline
resumes from exactly the committed batch, a prerequisite for exactly-once
training semantics.

Synthetic token stream by default (language-model-shaped: zipfian tokens,
shifted-label construction); a file-backed shard reader with the same cursor
contract can be dropped in for real corpora.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass
class DataPipeline:
    vocab: int
    batch_size: int
    seq_len: int
    seed: int = 0
    worker: int = 0
    n_workers: int = 1
    zipf_a: float = 1.2

    def batch_at(self, cursor: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, worker, cursor)."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + cursor) * 65_537 + self.worker
        )
        raw = rng.zipf(self.zipf_a, size=(self.batch_size, self.seq_len + 1))
        toks = (raw - 1) % self.vocab
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def embeddings_batch_at(self, cursor: int, d_model: int) -> Dict[str, np.ndarray]:
        """For embedding-input archs (musicgen): precomputed frame embeddings."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + cursor) * 65_537 + self.worker + 7
        )
        emb = rng.standard_normal((self.batch_size, self.seq_len, d_model)) * 0.02
        labels = rng.integers(0, self.vocab, (self.batch_size, self.seq_len))
        return {
            "embeddings": emb.astype(np.float32),
            "labels": labels.astype(np.int32),
        }
