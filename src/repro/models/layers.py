"""Shared neural-net layers: norms, rotary embeddings, attention (GQA +
KV-cache + cross-attention), MLPs.  Pure-jnp reference path; the Pallas
kernels in ``repro.kernels`` implement the hot spots for TPU (selected via
``use_pallas`` at the model level — the math is identical).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------------- norms
def rmsnorm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm_np(x, _scale_unused=None, eps: float = 1e-5):
    """OLMo's non-parametric LayerNorm (no scale/bias)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def apply_norm(kind: str, x, scale):
    if kind == "rmsnorm":
        return rmsnorm(x, scale)
    return layernorm_np(x)


# -------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float, positions):
    """positions: i32[...]; returns (cos, sin) with shape positions.shape + (hd/2,)."""
    inv = 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))
    ang = positions[..., None].astype(jnp.float32) * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); cos/sin: (S, hd/2) or (B, S, hd/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if cos.ndim == 2:  # (S, hd/2): broadcast over batch + heads
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:  # (B, S, hd/2): broadcast over heads
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    rot1 = x1 * cos - x2 * sin
    rot2 = x2 * cos + x1 * sin
    return jnp.concatenate([rot1, rot2], axis=-1).astype(x.dtype)


# --------------------------------------------------------------- attention
def gqa_attention(
    q,  # (B, S, Hq, hd)
    k,  # (B, T, Hkv, hd)
    v,  # (B, T, Hkv, hd)
    causal: bool = True,
    q_offset=0,  # absolute position of q[0] (decode: T-1)
    window: int = 0,  # sliding window size, 0 = full
):
    """Grouped-query attention, f32 softmax, optional causal/sliding mask."""
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    qf = q.reshape(b, s, hkv, group, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qf, kf) / np.sqrt(hd)
    qpos = jnp.arange(s)[:, None] + q_offset
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, hq, hd).astype(q.dtype)


def attention_block(
    x,
    p,  # params: wq, wk, wv, wo (+ bq, bk, bv if qkv_bias)
    cfg,
    positions,
    kv_cache: Optional[Tuple] = None,  # (k_cache, v_cache, length)
    kv_override: Optional[Tuple] = None,  # cross-attention K/V source (B,T,D)
    window: int = 0,
):
    """Self- or cross-attention with optional KV cache.

    Returns (out, new_kv_cache_entry or None).
    """
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd()
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, s, hq, hd)
    if kv_override is not None:
        src = kv_override
        k = jnp.einsum("btd,dh->bth", src, p["wk"]).reshape(b, -1, hkv, hd)
        v = jnp.einsum("btd,dh->bth", src, p["wv"]).reshape(b, -1, hkv, hd)
    else:
        k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(b, s, hkv, hd)
        v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(b, s, hkv, hd)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(1, 1, hq, hd)
        k = k + p["bk"].reshape(1, 1, hkv, hd) if kv_override is None else k
        v = v + p["bv"].reshape(1, 1, hkv, hd) if kv_override is None else v

    new_cache = None
    if kv_override is not None:
        # cross-attention: no causal mask, no rope on kv
        cos, sin = rope_freqs(hd, cfg.rope_theta, positions)
        q = apply_rope(q, cos, sin)
        out = gqa_attention(q, k, v, causal=False)
    elif kv_cache is not None:
        k_cache, v_cache, length = kv_cache
        cos, sin = rope_freqs(hd, cfg.rope_theta, positions)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype), (0, length, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype), (0, length, 0, 0))
        # causal mask with q_offset covers the invalid (zero-init) cache tail
        out = gqa_attention(
            q, k_cache, v_cache, causal=True, q_offset=length, window=window
        )
        new_cache = (k_cache, v_cache, length + s)
    else:
        cos, sin = rope_freqs(hd, cfg.rope_theta, positions)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        if getattr(cfg, "attn_seq_shard", False) and cfg.act_sharding:
            # context-parallel attention: shard the sequence over 'model'
            # (always divisible, unlike head counts like 56 or 9 on a 16-way
            # axis) and replicate the small GQA K/V.  Kills the partial-sum
            # score all-reduce GSPMD emits for indivisible head sharding.
            from jax.sharding import PartitionSpec as P

            wsc = jax.lax.with_sharding_constraint
            q = wsc(q, P(cfg.act_sharding, "model", None, None))
            k = wsc(k, P(cfg.act_sharding, None, None, None))
            v = wsc(v, P(cfg.act_sharding, None, None, None))
        if getattr(cfg, "attn_impl", "naive") == "chunked":
            from repro.kernels.flash_attention.ops import chunked_attention

            out = chunked_attention(q, k, v, causal=True, blk_k=cfg.attn_chunk)
        else:
            out = gqa_attention(q, k, v, causal=True, window=window)
        if getattr(cfg, "attn_seq_shard", False) and cfg.act_sharding:
            from jax.sharding import PartitionSpec as P

            out = jax.lax.with_sharding_constraint(
                out, P(cfg.act_sharding, "model", None, None)
            )
    out = jnp.einsum("bsh,hd->bsd", out.reshape(b, s, hq * hd), p["wo"])
    return out, new_cache


# -------------------------------------------------------------------- MLPs
def mlp_block(x, p, kind: str = "swiglu"):
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
        return h @ p["w2"]
    h = jax.nn.gelu(x @ p["w1"])
    return h @ p["w2"]
