"""Unified scan-over-layers model covering all assigned families.

Families and their block structure:
  dense / moe / audio : scan over L identical blocks (attn + mlp/moe)
  vlm (llama-3.2-vision): scan over G groups of (cross_attn_every-1) self
        blocks + 1 cross-attention block against stub image embeddings
  ssm (falcon-mamba)  : scan over L mamba1 blocks
  hybrid (zamba2)     : scan over G groups of `attn_every` mamba2 blocks,
        one *shared* attention+MLP block applied after every group (weights
        shared across applications, zamba-style), plus a mamba tail

Three entry points per model:
  forward(params, batch)              -> logits             (training fwd)
  loss(params, batch)                 -> scalar             (train_step body)
  init_cache(cfg, batch, max_len)     -> cache pytree       (decode)
  decode_step(params, cache, tok)     -> (logits, cache)    (serve_step body)
  prefill(params, batch, max_len)     -> (logits, cache)

Decode caches for attention are *right-aligned rolling windows* when
cfg window > 0 (zamba2 long-context) and insert-at-length buffers otherwise.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_norm,
    apply_rope,
    attention_block,
    gqa_attention,
    mlp_block,
    rope_freqs,
)
from repro.models.mamba import mamba1_block, mamba2_block
from repro.models.moe import moe_ffn

Params = Dict[str, Any]


def _shard_act(x, cfg, *trailing):
    """Anchor the batch dim of an activation to the data axes (GSPMD hint).

    Without this anchor the partitioner can propagate a weight sharding onto
    the residual stream's feature dim and drop batch parallelism entirely
    (observed: 155 GB/device attention temps on smollm train_4k)."""
    if not cfg.act_sharding:
        return x
    from jax.sharding import PartitionSpec as P

    if trailing:
        spec = trailing
    elif (
        getattr(cfg, "seq_parallel_resid", False)
        and x.ndim == 3
        and x.shape[1] % 16 == 0  # never shard decode's S=1 over the TP axis
    ):
        spec = ("model",) + (None,) * (x.ndim - 2)
    else:
        spec = (None,) * (x.ndim - 1)
    return jax.lax.with_sharding_constraint(x, P(cfg.act_sharding, *spec))


# ============================================================== initialization
def _dense(key, shape, dtype, scale=0.02):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _attn_params(key, cfg, dtype, layers_shape=()):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd()
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense(ks[0], layers_shape + (d, hq * hd), dtype),
        "wk": _dense(ks[1], layers_shape + (d, hkv * hd), dtype),
        "wv": _dense(ks[2], layers_shape + (d, hkv * hd), dtype),
        "wo": _dense(ks[3], layers_shape + (hq * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros(layers_shape + (hq * hd,), dtype)
        p["bk"] = jnp.zeros(layers_shape + (hkv * hd,), dtype)
        p["bv"] = jnp.zeros(layers_shape + (hkv * hd,), dtype)
    return p


def _mlp_params(key, cfg, dtype, layers_shape=(), d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w1": _dense(ks[0], layers_shape + (d, f), dtype),
        "w2": _dense(ks[1], layers_shape + (f, d), dtype),
    }
    if cfg.mlp == "swiglu":
        p["w3"] = _dense(ks[2], layers_shape + (d, f), dtype)
    return p


def _moe_params(key, cfg, dtype, layers_shape=()):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_dff
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense(ks[0], layers_shape + (d, e), jnp.float32),
        "w1": _dense(ks[1], layers_shape + (e, d, f), dtype),
        "w3": _dense(ks[2], layers_shape + (e, d, f), dtype),
        "w2": _dense(ks[3], layers_shape + (e, f, d), dtype),
    }
    if cfg.dense_residual:
        p["dense"] = _mlp_params(ks[4], cfg, dtype, layers_shape)
    return p


def _mamba_params(key, cfg, dtype, layers_shape=()):
    d, di, n = cfg.d_model, cfg.d_inner(), cfg.ssm_state
    ks = jax.random.split(key, 10)
    if cfg.ssm_version == 1:
        dtr = cfg.dtr()
        return {
            "in_proj": _dense(ks[0], layers_shape + (d, 2 * di), dtype),
            "conv_w": _dense(ks[1], layers_shape + (di, cfg.d_conv), dtype, 0.1),
            "conv_b": jnp.zeros(layers_shape + (di,), dtype),
            "x_proj": _dense(ks[2], layers_shape + (di, dtr + 2 * n), dtype),
            "dt_proj": _dense(ks[3], layers_shape + (dtr, di), dtype),
            "dt_bias": jnp.full(layers_shape + (di,), -4.6, dtype),  # softplus^-1(0.01)
            "A_log": jnp.broadcast_to(
                jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)), layers_shape + (di, n)
            ),
            "D_skip": jnp.ones(layers_shape + (di,), jnp.float32),
            "out_proj": _dense(ks[4], layers_shape + (di, d), dtype),
        }
    nh = di // cfg.ssm_head_dim
    conv_c = di + 2 * n
    return {
        "in_proj": _dense(ks[0], layers_shape + (d, 2 * di + 2 * n + nh), dtype),
        "conv_w": _dense(ks[1], layers_shape + (conv_c, cfg.d_conv), dtype, 0.1),
        "conv_b": jnp.zeros(layers_shape + (conv_c,), dtype),
        "dt_bias": jnp.zeros(layers_shape + (nh,), dtype),
        "A_log": jnp.zeros(layers_shape + (nh,), jnp.float32),
        "D_skip": jnp.ones(layers_shape + (nh,), jnp.float32),
        "norm_scale": jnp.ones(layers_shape + (di,), dtype),
        "out_proj": _dense(ks[2], layers_shape + (di, d), dtype),
    }


def _norm_scale(cfg, dtype, layers_shape=()):
    if cfg.norm == "rmsnorm":
        return jnp.ones(layers_shape + (cfg.d_model,), dtype)
    return jnp.zeros(layers_shape + (0,), dtype)  # non-parametric: empty leaf


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = cfg.act_dtype()
    ks = jax.random.split(key, 12)
    p: Params = {}
    if not cfg.embedding_inputs:
        p["embed"] = _dense(ks[0], (cfg.vocab, cfg.d_model), dtype)
    p["final_norm"] = _norm_scale(cfg, dtype)
    if cfg.tie_embeddings and not cfg.embedding_inputs:
        pass  # logits via embed.T
    else:
        p["lm_head"] = _dense(ks[1], (cfg.d_model, cfg.vocab), dtype)

    fam = cfg.family
    if fam in ("dense", "moe", "audio"):
        L = (cfg.n_layers,)
        blocks = {
            "norm1": _norm_scale(cfg, dtype, L),
            "norm2": _norm_scale(cfg, dtype, L),
            "attn": _attn_params(ks[2], cfg, dtype, L),
        }
        if fam == "moe":
            blocks["moe"] = _moe_params(ks[3], cfg, dtype, L)
        else:
            blocks["mlp"] = _mlp_params(ks[3], cfg, dtype, L)
        p["blocks"] = blocks
    elif fam == "vlm":
        g = cfg.n_layers // cfg.cross_attn_every  # groups
        per = cfg.cross_attn_every - 1  # self layers per group
        GS = (g, per)
        p["self_blocks"] = {
            "norm1": _norm_scale(cfg, dtype, GS),
            "norm2": _norm_scale(cfg, dtype, GS),
            "attn": _attn_params(ks[2], cfg, dtype, GS),
            "mlp": _mlp_params(ks[3], cfg, dtype, GS),
        }
        p["cross_blocks"] = {
            "norm1": _norm_scale(cfg, dtype, (g,)),
            "norm2": _norm_scale(cfg, dtype, (g,)),
            "attn": _attn_params(ks[4], cfg, dtype, (g,)),
            "mlp": _mlp_params(ks[5], cfg, dtype, (g,)),
            "gate": jnp.zeros((g,), jnp.float32),  # tanh-gated cross-attn
        }
    elif fam == "ssm":
        L = (cfg.n_layers,)
        p["blocks"] = {
            "norm1": _norm_scale(cfg, dtype, L),
            "mamba": _mamba_params(ks[2], cfg, dtype, L),
        }
    elif fam == "hybrid":
        g = cfg.n_layers // cfg.attn_every
        tail = cfg.n_layers - g * cfg.attn_every
        GS = (g, cfg.attn_every)
        p["mamba_groups"] = {
            "norm1": _norm_scale(cfg, dtype, GS),
            "mamba": _mamba_params(ks[2], cfg, dtype, GS),
        }
        if tail:
            p["mamba_tail"] = {
                "norm1": _norm_scale(cfg, dtype, (tail,)),
                "mamba": _mamba_params(ks[3], cfg, dtype, (tail,)),
            }
        p["shared_attn"] = {
            "norm1": _norm_scale(cfg, dtype),
            "norm2": _norm_scale(cfg, dtype),
            "attn": _attn_params(ks[4], cfg, dtype),
            "mlp": _mlp_params(ks[5], cfg, dtype),
        }
    else:
        raise ValueError(fam)
    return p


def abstract_params(cfg: ModelConfig) -> Params:
    """Shape/dtype pytree without allocation (for the dry-run)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ================================================================ block bodies
def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    policy = {
        "nothing_saveable": jax.checkpoint_policies.nothing_saveable,
        "dots_saveable": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[cfg.remat]
    return jax.checkpoint(fn, policy=policy)


def _self_block(h, bp, cfg, positions, cache=None, window=0, ring=False):
    """Pre-norm attention + FFN.  Returns (h, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    x = apply_norm(cfg.norm, h, bp["norm1"])
    if ring:
        attn_out, new_cache = _ring_attention(x, bp["attn"], cfg, positions, cache, window)
    else:
        attn_out, new_cache = attention_block(
            x, bp["attn"], cfg, positions, kv_cache=cache, window=window
        )
    h = h + attn_out
    x = apply_norm(cfg.norm, h, bp["norm2"])
    if "moe" in bp:
        ffn_out, aux = moe_ffn(x, bp["moe"], cfg)
    else:
        ffn_out = mlp_block(x, bp["mlp"], kind=cfg.mlp)
    return h + ffn_out, new_cache, aux


def _cross_block(h, bp, cfg, positions, img_kv):
    """Gated cross-attention block (llama-3.2-vision style)."""
    x = apply_norm(cfg.norm, h, bp["norm1"])
    out, _ = attention_block(x, bp["attn"], cfg, positions, kv_override=img_kv)
    h = h + jnp.tanh(bp["gate"]).astype(h.dtype) * out
    x = apply_norm(cfg.norm, h, bp["norm2"])
    return h + mlp_block(x, bp["mlp"], kind=cfg.mlp)


def _mamba_layer(h, bp, cfg, state=None):
    x = apply_norm(cfg.norm, h, bp["norm1"])
    if cfg.ssm_version == 1:
        out, new_state = mamba1_block(x, bp["mamba"], cfg, state)
    else:
        out, new_state = mamba2_block(x, bp["mamba"], cfg, state)
    return h + out, new_state


# ---------------------------------------------------- rolling-window attention
def _ring_attention(x, p, cfg, positions, cache, window):
    """Decode attention over a right-aligned rolling KV window.

    cache = (k_win (B, W, Hkv, hd) roped, v_win, length).  x: (B, 1, D).
    """
    b, s, d = x.shape
    assert s == 1
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd()
    k_win, v_win, length = cache
    w = k_win.shape[1]
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, 1, hq, hd)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(b, 1, hkv, hd)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(b, 1, hkv, hd)
    cos, sin = rope_freqs(hd, cfg.rope_theta, positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    k_win = jnp.concatenate([k_win[:, 1:], k.astype(k_win.dtype)], axis=1)
    v_win = jnp.concatenate([v_win[:, 1:], v.astype(v_win.dtype)], axis=1)
    # slot j holds absolute position length - (W-1-j); valid iff >= 0
    valid = (jnp.arange(w) >= (w - 1 - length))[None, :]
    group = hq // hkv
    qf = q.reshape(b, 1, hkv, group, hd).astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qf, k_win.astype(jnp.float32)) / np.sqrt(hd)
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v_win.astype(jnp.float32))
    out = out.reshape(b, 1, hq * hd).astype(x.dtype)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), (k_win, v_win, length + 1)


# ===================================================================== forward
def _embed(params, cfg, batch):
    if cfg.embedding_inputs:
        return batch["embeddings"].astype(cfg.act_dtype())
    return params["embed"][batch["tokens"]]


def _logits(params, cfg, h):
    h = apply_norm(cfg.norm, h, params["final_norm"])
    if cfg.tie_embeddings and not cfg.embedding_inputs:
        out = jnp.einsum("bsd,vd->bsv", h, params["embed"])
    else:
        out = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    return _shard_act(out, cfg, None, "model")


def _img_embeds(params, cfg, batch):
    return batch["image_embeddings"].astype(cfg.act_dtype())


def forward(params: Params, cfg: ModelConfig, batch) -> Tuple[jax.Array, jax.Array]:
    """Training/prefill-style full-sequence forward.  Returns (logits, aux)."""
    h, aux_total = _trunk(params, cfg, batch)
    return _logits(params, cfg, h), aux_total


def _trunk(params: Params, cfg: ModelConfig, batch) -> Tuple[jax.Array, jax.Array]:
    """All blocks, pre-head.  Returns (hidden, aux)."""
    h = _shard_act(_embed(params, cfg, batch), cfg)
    b, s, _ = h.shape
    positions = jnp.arange(s)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "moe", "audio"):

        def body(carry, bp):
            h, aux = carry
            h, _, a = _self_block(h, bp, cfg, positions)
            return (_shard_act(h, cfg), aux + a), None

        (h, aux_total), _ = jax.lax.scan(
            _remat(cfg, body), (h, aux_total), params["blocks"]
        )
    elif cfg.family == "vlm":
        img = _img_embeds(params, cfg, batch)

        def group_body(carry, bps):
            h, aux = carry
            self_bp, cross_bp = bps

            def self_body(hh, bp):
                hh, _, a = _self_block(hh, bp, cfg, positions)
                return _shard_act(hh, cfg), a

            h, a_in = jax.lax.scan(self_body, h, self_bp)
            h = _cross_block(h, cross_bp, cfg, positions, img)
            return (_shard_act(h, cfg), aux + jnp.sum(a_in)), None

        (h, aux_total), _ = jax.lax.scan(
            _remat(cfg, group_body),
            (h, aux_total),
            (params["self_blocks"], params["cross_blocks"]),
        )
    elif cfg.family == "ssm":

        def body(h, bp):
            h, _ = _mamba_layer(h, bp, cfg)
            return _shard_act(h, cfg), None

        h, _ = jax.lax.scan(_remat(cfg, body), h, params["blocks"])
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group_body(h, bp):
            def inner(hh, lbp):
                hh, _ = _mamba_layer(hh, lbp, cfg)
                return _shard_act(hh, cfg), None

            h, _ = jax.lax.scan(inner, h, bp)
            h, _, _ = _self_block(h, shared, cfg, positions)
            return _shard_act(h, cfg), None

        h, _ = jax.lax.scan(_remat(cfg, group_body), h, params["mamba_groups"])
        if "mamba_tail" in params:

            def tail_body(h, bp):
                h, _ = _mamba_layer(h, bp, cfg)
                return _shard_act(h, cfg), None

            h, _ = jax.lax.scan(_remat(cfg, tail_body), h, params["mamba_tail"])
    else:
        raise ValueError(cfg.family)

    return h, aux_total


def _ce_terms(logits, labels):
    lf = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((logz - gold) * mask), jnp.sum(mask)


def loss_fn(params: Params, cfg: ModelConfig, batch) -> jax.Array:
    labels = batch["labels"]
    chunk = getattr(cfg, "loss_chunk", 0)
    if chunk and labels.shape[1] % chunk == 0 and labels.shape[1] > chunk:
        # sequence-chunked CE: run the trunk once, apply the LM head + CE per
        # sequence chunk so the full (B, S, V) logits never materializes.
        hs, aux = _trunk(params, cfg, batch)
        total, count = jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)
        for i in range(0, labels.shape[1], chunk):
            lg = _logits(params, cfg, hs[:, i : i + chunk])
            t, c = _ce_terms(lg, labels[:, i : i + chunk])
            total, count = total + t, count + c
        nll = total / jnp.maximum(count, 1.0)
        return nll + 0.01 * aux
    logits, aux = forward(params, cfg, batch)
    t, c = _ce_terms(logits, labels)
    return t / jnp.maximum(c, 1.0) + 0.01 * aux


# ====================================================================== decode
def init_cache(cfg: ModelConfig, batch_size: int, max_len: int, window: int = 0):
    """Zero caches for decode.  window>0 => rolling-window attention caches."""
    dtype = cfg.act_dtype()
    hkv, hd = cfg.n_kv_heads, cfg.hd()
    wlen = window or max_len
    kv = lambda n: (
        jnp.zeros((n, batch_size, wlen, hkv, hd), dtype),
        jnp.zeros((n, batch_size, wlen, hkv, hd), dtype),
    )
    if cfg.family in ("dense", "moe", "audio"):
        k, v = kv(cfg.n_layers)
        return {"k": k, "v": v, "len": jnp.zeros((), jnp.int32)}
    if cfg.family == "vlm":
        g = cfg.n_layers // cfg.cross_attn_every
        per = cfg.cross_attn_every - 1
        k = jnp.zeros((g, per, batch_size, wlen, hkv, hd), dtype)
        v = jnp.zeros((g, per, batch_size, wlen, hkv, hd), dtype)
        ik = jnp.zeros((g, batch_size, cfg.n_img_tokens, hkv, hd), dtype)
        iv = jnp.zeros((g, batch_size, cfg.n_img_tokens, hkv, hd), dtype)
        return {"k": k, "v": v, "img_k": ik, "img_v": iv, "len": jnp.zeros((), jnp.int32)}
    if cfg.family == "ssm":
        di, n = cfg.d_inner(), cfg.ssm_state
        return {
            "ssm": jnp.zeros((cfg.n_layers, batch_size, di, n), jnp.float32),
            "conv": jnp.zeros((cfg.n_layers, batch_size, cfg.d_conv - 1, di), dtype),
            "len": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "hybrid":
        di, n = cfg.d_inner(), cfg.ssm_state
        nh, hp = di // cfg.ssm_head_dim, cfg.ssm_head_dim
        g = cfg.n_layers // cfg.attn_every
        tail = cfg.n_layers - g * cfg.attn_every
        conv_c = di + 2 * n
        out = {
            "ssm": jnp.zeros((g, cfg.attn_every, batch_size, nh, hp, n), jnp.float32),
            "conv": jnp.zeros((g, cfg.attn_every, batch_size, cfg.d_conv - 1, conv_c), dtype),
            "len": jnp.zeros((), jnp.int32),
        }
        ak, av = kv(g)
        out["attn_k"], out["attn_v"] = ak, av
        if tail:
            out["tail_ssm"] = jnp.zeros((tail, batch_size, nh, hp, n), jnp.float32)
            out["tail_conv"] = jnp.zeros((tail, batch_size, cfg.d_conv - 1, conv_c), dtype)
        return out
    raise ValueError(cfg.family)


def decode_step(params: Params, cfg: ModelConfig, cache, batch, window: int = 0):
    """One-token decode.  batch: {tokens (B,1)} or {embeddings (B,1,D)} (+
    image_embeddings for vlm prefill-less runs).  Returns (logits, cache)."""
    h = _shard_act(_embed(params, cfg, batch), cfg)
    b = h.shape[0]
    length = cache["len"]
    positions = jnp.full((1,), length, jnp.int32)
    ring = window > 0

    if cfg.family in ("dense", "moe", "audio"):

        def body(h, xs):
            bp, k_l, v_l = xs
            hh, new_cache, _ = _self_block(
                h, bp, cfg, positions, cache=(k_l, v_l, length), window=window, ring=ring
            )
            return hh, (new_cache[0], new_cache[1])

        h, (new_k, new_v) = jax.lax.scan(body, h, (params["blocks"], cache["k"], cache["v"]))
        new_cache = {"k": new_k, "v": new_v, "len": length + 1}
    elif cfg.family == "vlm":

        def group_body(h, xs):
            self_bp, cross_bp, k_g, v_g, ik_g, iv_g = xs

            def self_body(hh, inner):
                bp, k_l, v_l = inner
                hh, nc, _ = _self_block(
                    hh, bp, cfg, positions, cache=(k_l, v_l, length), window=window, ring=ring
                )
                return hh, (nc[0], nc[1])

            h, (nk, nv) = jax.lax.scan(self_body, h, (self_bp, k_g, v_g))
            # cross-attention against precomputed image KV
            x = apply_norm(cfg.norm, h, cross_bp["norm1"])
            hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd()
            q = jnp.einsum("bsd,dh->bsh", x, cross_bp["attn"]["wq"]).reshape(b, 1, hq, hd)
            cos, sin = rope_freqs(hd, cfg.rope_theta, positions)
            q = apply_rope(q, cos, sin)
            out = gqa_attention(q, ik_g, iv_g, causal=False)
            out = jnp.einsum(
                "bsh,hd->bsd", out.reshape(b, 1, hq * hd), cross_bp["attn"]["wo"]
            )
            h = h + jnp.tanh(cross_bp["gate"]).astype(h.dtype) * out
            x = apply_norm(cfg.norm, h, cross_bp["norm2"])
            h = h + mlp_block(x, cross_bp["mlp"], kind=cfg.mlp)
            return h, (nk, nv)

        h, (new_k, new_v) = jax.lax.scan(
            group_body,
            h,
            (
                params["self_blocks"],
                params["cross_blocks"],
                cache["k"],
                cache["v"],
                cache["img_k"],
                cache["img_v"],
            ),
        )
        new_cache = dict(cache, k=new_k, v=new_v, len=length + 1)
    elif cfg.family == "ssm":

        def body(h, xs):
            bp, s_l, c_l = xs
            hh, (ns, nc) = _mamba_layer(h, bp, cfg, state=(s_l, c_l))
            return hh, (ns, nc)

        h, (new_s, new_c) = jax.lax.scan(
            body, h, (params["blocks"], cache["ssm"], cache["conv"])
        )
        new_cache = {"ssm": new_s, "conv": new_c, "len": length + 1}
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group_body(h, xs):
            bp, s_g, c_g, k_g, v_g = xs

            def inner(hh, inner_xs):
                lbp, s_l, c_l = inner_xs
                hh, (ns, nc) = _mamba_layer(hh, lbp, cfg, state=(s_l, c_l))
                return hh, (ns, nc)

            h, (ns_g, nc_g) = jax.lax.scan(inner, h, (bp, s_g, c_g))
            h, new_kv, _ = _self_block(
                h, shared, cfg, positions, cache=(k_g, v_g, length), window=window, ring=ring
            )
            return h, (ns_g, nc_g, new_kv[0], new_kv[1])

        h, (new_s, new_c, new_k, new_v) = jax.lax.scan(
            group_body,
            h,
            (params["mamba_groups"], cache["ssm"], cache["conv"], cache["attn_k"], cache["attn_v"]),
        )
        new_cache = dict(cache, ssm=new_s, conv=new_c, attn_k=new_k, attn_v=new_v, len=length + 1)
        if "mamba_tail" in params:

            def tail_body(h, xs):
                lbp, s_l, c_l = xs
                hh, (ns, nc) = _mamba_layer(h, lbp, cfg, state=(s_l, c_l))
                return hh, (ns, nc)

            h, (ts, tc) = jax.lax.scan(
                tail_body, h, (params["mamba_tail"], cache["tail_ssm"], cache["tail_conv"])
            )
            new_cache.update(tail_ssm=ts, tail_conv=tc)
    else:
        raise ValueError(cfg.family)

    return _logits(params, cfg, h), new_cache


def prefill(params: Params, cfg: ModelConfig, batch, max_len: int):
    """Full-sequence forward that also fills the decode cache.

    For attention families this recomputes K/V into the cache; for SSMs it
    runs the scan and keeps the final state.  Returns (last_logits, cache).
    """
    h = _shard_act(_embed(params, cfg, batch), cfg)
    b, s, _ = h.shape
    positions = jnp.arange(s)
    cache = init_cache(cfg, b, max_len)
    length = jnp.zeros((), jnp.int32)

    if cfg.family in ("dense", "moe", "audio"):

        def body(carry, xs):
            h = carry
            bp, k_l, v_l = xs
            hh, nc, _ = _self_block(h, bp, cfg, positions, cache=(k_l, v_l, length))
            return hh, (nc[0], nc[1])

        h, (nk, nv) = jax.lax.scan(
            _remat(cfg, body), h, (params["blocks"], cache["k"], cache["v"])
        )
        new_cache = {"k": nk, "v": nv, "len": length + s}
    elif cfg.family == "ssm":

        def body(h, xs):
            bp, s_l, c_l = xs
            hh, (ns, nc) = _mamba_layer(h, bp, cfg, state=None)
            return hh, (ns, nc)

        h, (ns, nc) = jax.lax.scan(
            _remat(cfg, body), h, (params["blocks"], cache["ssm"], cache["conv"])
        )
        new_cache = {"ssm": ns, "conv": nc, "len": length + s}
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group_body(h, xs):
            bp, s_g, c_g, k_g, v_g = xs

            def inner(hh, inner_xs):
                lbp, s_l, c_l = inner_xs
                hh, (ns, nc) = _mamba_layer(hh, lbp, cfg, state=None)
                return hh, (ns, nc)

            h, (ns_g, nc_g) = jax.lax.scan(inner, h, (bp, s_g, c_g))
            h, nkv, _ = _self_block(h, shared, cfg, positions, cache=(k_g, v_g, length))
            return h, (ns_g, nc_g, nkv[0], nkv[1])

        h, (ns, nc, nk, nv) = jax.lax.scan(
            _remat(cfg, group_body),
            h,
            (params["mamba_groups"], cache["ssm"], cache["conv"], cache["attn_k"], cache["attn_v"]),
        )
        new_cache = dict(cache, ssm=ns, conv=nc, attn_k=nk, attn_v=nv, len=length + s)
        if "mamba_tail" in params:

            def tail_body(h, xs):
                lbp, s_l, c_l = xs
                hh, (nss, ncc) = _mamba_layer(h, lbp, cfg, state=None)
                return hh, (nss, ncc)

            h, (ts, tc) = jax.lax.scan(
                tail_body, h, (params["mamba_tail"], cache["tail_ssm"], cache["tail_conv"])
            )
            new_cache.update(tail_ssm=ts, tail_conv=tc)
    elif cfg.family == "vlm":
        img = _img_embeds(params, cfg, batch)
        hkv, hd = cfg.n_kv_heads, cfg.hd()

        def group_body(carry, xs):
            h = carry
            self_bp, cross_bp, k_g, v_g = xs

            def self_body(hh, inner):
                bp, k_l, v_l = inner
                hh, ncc, _ = _self_block(hh, bp, cfg, positions, cache=(k_l, v_l, length))
                return hh, (ncc[0], ncc[1])

            h, (nk, nv) = jax.lax.scan(self_body, h, (self_bp, k_g, v_g))
            ik = jnp.einsum("btd,dh->bth", img, cross_bp["attn"]["wk"]).reshape(
                b, -1, hkv, hd
            )
            iv = jnp.einsum("btd,dh->bth", img, cross_bp["attn"]["wv"]).reshape(
                b, -1, hkv, hd
            )
            h = _cross_block(h, cross_bp, cfg, positions, img)
            return h, (nk, nv, ik.astype(cfg.act_dtype()), iv.astype(cfg.act_dtype()))

        h, (nk, nv, ik, iv) = jax.lax.scan(
            _remat(cfg, group_body),
            h,
            (params["self_blocks"], params["cross_blocks"], cache["k"], cache["v"]),
        )
        new_cache = dict(cache, k=nk, v=nv, img_k=ik, img_v=iv, len=length + s)
    else:
        raise ValueError(cfg.family)

    last = _logits(params, cfg, h[:, -1:])
    return last, new_cache


def init_mamba_tail_none():  # pragma: no cover - placeholder symmetry helper
    return None
