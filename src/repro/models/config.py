"""Unified model configuration covering all assigned architecture families."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads

    # dense-transformer details
    qkv_bias: bool = False  # qwen2
    norm: str = "rmsnorm"  # rmsnorm | layernorm_np (olmo non-parametric)
    mlp: str = "swiglu"  # swiglu | gelu (musicgen)
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_dff: int = 0
    dense_residual: bool = False  # arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25

    # SSM (mamba)
    ssm_version: int = 0  # 0 = none, 1 = mamba1, 2 = mamba2
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    ssm_head_dim: int = 64  # mamba2
    dt_rank: int = 0  # mamba1; 0 => ceil(d_model/16)

    # hybrid (zamba2): shared attention block applied every `attn_every`
    # mamba layers; remainder layers are pure mamba
    attn_every: int = 0

    # vlm (llama-3.2-vision): cross-attention every k layers, stub image
    # embeddings with n_img_tokens
    cross_attn_every: int = 0
    n_img_tokens: int = 1024

    # audio (musicgen): the frontend is stubbed — inputs are precomputed
    # frame embeddings (B, S, d_model) instead of token ids
    embedding_inputs: bool = False

    # numerics / scheduling
    dtype: str = "bfloat16"
    remat: str = "nothing_saveable"  # none | nothing_saveable | dots_saveable
    scan_layers: bool = True
    logits_chunk: int = 0  # 0 = unchunked loss
    # activation sharding anchor: names of the batch-parallel mesh axes; set
    # by the launchers (('data',) or ('pod','data')), empty = no constraints
    act_sharding: Tuple[str, ...] = ()
    # ---- perf levers (hillclimbed per cell; see EXPERIMENTS.md §Perf) ----
    attn_impl: str = "naive"  # naive | chunked (online-softmax, O(S·blk) mem)
    attn_chunk: int = 512  # key-block size for chunked attention
    attn_seq_shard: bool = False  # context-parallel attention: shard S over
    # 'model' and replicate (small GQA) K/V — fixes indivisible-head sharding
    loss_chunk: int = 0  # sequence-chunked CE loss (0 = off): never
    # materializes the full (B,S,V) logits tensor
    moe_shard_dispatch: bool = False  # EP anchor on the MoE capacity
    # buffer: scatter lowers to all-to-all instead of a full-buffer all-reduce
    moe_groups: int = 0  # grouped (per-data-shard) dispatch: group-local
    # capacity scatter + (G->E) all-to-all re-layout; 0 = flat dispatch
    seq_parallel_resid: bool = False  # megatron-style sequence parallelism:
    # the residual stream between blocks is sharded (batch, S/'model', d) so
    # TP boundary collectives become reduce-scatter + all-gather pairs

    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def d_inner(self) -> int:
        return self.expand * self.d_model

    def dtr(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    def act_dtype(self):
        return jnp.dtype(self.dtype)

    # ------------------------------------------------------------ accounting
    def param_count(self) -> int:
        """Analytic parameter count (drives MODEL_FLOPS = 6·N·D)."""
        d, v = self.d_model, self.vocab
        total = v * d  # embedding
        if not self.tie_embeddings and not self.embedding_inputs:
            total += d * v  # lm head
        elif self.embedding_inputs:
            total += d * v
        total += d  # final norm (rmsnorm scale) — 0 for layernorm_np but negligible
        per_layer = 0
        hd = self.hd()
        if self.family in ("dense", "moe", "vlm", "audio"):
            attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
            per_layer += attn + 2 * d  # norms
            if self.family == "moe":
                per_layer += d * self.n_experts  # router
                per_layer += self.n_experts * 3 * d * self.moe_dff
                if self.dense_residual:
                    per_layer += 3 * d * self.d_ff
            else:
                n_mats = 3 if self.mlp == "swiglu" else 2
                per_layer += n_mats * d * self.d_ff
            total += self.n_layers * per_layer
            if self.family == "vlm" and self.cross_attn_every:
                n_cross = self.n_layers // self.cross_attn_every
                cross = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d + 2 * d
                total += n_cross * cross
        elif self.family in ("ssm", "hybrid"):
            di = self.d_inner()
            if self.ssm_version == 1:
                m = d * 2 * di  # in_proj
                m += di * self.d_conv  # depthwise conv
                m += di * (self.dtr() + 2 * self.ssm_state)  # x_proj
                m += self.dtr() * di + di  # dt_proj
                m += di * self.ssm_state + di  # A_log, D skip
                m += di * d  # out_proj
                m += d  # norm
            else:  # mamba2
                nh = di // self.ssm_head_dim
                m = d * (2 * di + 2 * self.ssm_state + nh)  # fused in_proj
                m += (di + 2 * self.ssm_state) * self.d_conv
                m += nh * 2  # A_log, D per head
                m += di  # gated rmsnorm scale
                m += di * d  # out_proj
                m += d
            total += self.n_layers * m
            if self.family == "hybrid" and self.attn_every:
                # one shared attention+mlp block (applied many times)
                shared = (
                    d * (self.n_heads * hd)
                    + 2 * d * (self.n_kv_heads * hd)
                    + (self.n_heads * hd) * d
                    + 3 * d * self.d_ff
                    + 2 * d
                )
                total += shared
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts + shared)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        inactive = self.n_layers * (self.n_experts - self.top_k) * 3 * d * self.moe_dff
        return int(self.param_count() - inactive)
