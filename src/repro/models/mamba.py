"""Mamba blocks: mamba1 (falcon-mamba-7b) and mamba2/SSD (zamba2-7b).

Training path uses chunk-parallel formulations that map onto the MXU:
  * mamba1 — diagonal selective scan; sequential `lax.scan` over the time
    axis with a (B, d_inner, d_state) carry for the reference path, and the
    chunked Pallas kernel (`repro.kernels.mamba_scan`) for TPU.
  * mamba2 — the SSD chunked algorithm: intra-chunk attention-like matmuls
    plus an inter-chunk state recurrence (matmul-dominated, TPU-friendly).

Decode path is O(1) per token for both (the whole point of SSMs for the
``long_500k`` shape): the carried state is (B, d_inner, d_state) (mamba1) or
(B, H, P, N) (mamba2) plus a (B, d_conv-1, conv_width) convolution tail.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# --------------------------------------------------------------- primitives
def _causal_conv(x, w, b, tail=None):
    """Depthwise causal conv1d.  x: (B, S, C), w: (C, K), tail: (B, K-1, C).

    Returns (y, new_tail)."""
    bsz, s, c = x.shape
    k = w.shape[1]
    if tail is None:
        tail = jnp.zeros((bsz, k - 1, c), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)  # (B, S+K-1, C)
    # window sum: y[t] = sum_j xp[t+j] * w[:, j]
    y = jnp.zeros((bsz, s, c), jnp.float32)
    for j in range(k):
        y = y + xp[:, j : j + s, :].astype(jnp.float32) * w[:, j].astype(jnp.float32)
    y = y + b.astype(jnp.float32)
    new_tail = xp[:, s:, :]
    return y.astype(x.dtype), new_tail


# ------------------------------------------------------------------ mamba1
def mamba1_scan(abar, bx):
    """h_t = abar_t * h_{t-1} + bx_t over axis 1.  (B, S, DI, N) -> (B, S, DI, N).

    Associative scan (log-depth, parallel) — the jnp reference; the Pallas
    kernel uses a chunked work-efficient version."""

    def comb(a, b):
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(comb, (abar, bx), axis=1)
    return h


def mamba1_block(x, p, cfg, state: Optional[Tuple] = None):
    """x: (B, S, D).  state: (ssm_h (B, DI, N), conv_tail) for decode.

    Returns (out, new_state)."""
    b, s, d = x.shape
    di, n = cfg.d_inner(), cfg.ssm_state
    xz = x @ p["in_proj"]  # (B, S, 2*DI)
    xpart, z = jnp.split(xz, 2, axis=-1)
    conv_tail = state[1] if state is not None else None
    xpart, new_tail = _causal_conv(xpart, p["conv_w"], p["conv_b"], conv_tail)
    xpart = jax.nn.silu(xpart)

    proj = xpart @ p["x_proj"]  # (B, S, dtr + 2N)
    dtr = cfg.dtr()
    dt_raw, b_ssm, c_ssm = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt_raw @ p["dt_proj"] + p["dt_bias"])  # (B, S, DI)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))  # (DI, N)
    abar = jnp.exp(dt.astype(jnp.float32)[..., None] * a[None, None])  # (B,S,DI,N)
    bx = (
        dt.astype(jnp.float32)[..., None]
        * b_ssm.astype(jnp.float32)[:, :, None, :]
        * xpart.astype(jnp.float32)[..., None]
    )

    if state is not None and s == 1:
        h0 = state[0]  # (B, DI, N)
        h = abar[:, 0] * h0 + bx[:, 0]
        y = jnp.einsum("bdn,bn->bd", h, c_ssm[:, 0].astype(jnp.float32))[:, None]
        new_h = h
    else:
        hs = mamba1_scan(abar, bx)  # (B, S, DI, N)
        y = jnp.einsum("bsdn,bsn->bsd", hs, c_ssm.astype(jnp.float32))
        new_h = hs[:, -1]
    y = y + p["D_skip"].astype(jnp.float32) * xpart.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ p["out_proj"]
    return out, (new_h, new_tail)


# ------------------------------------------------------------------ mamba2
def ssd_chunked(xh, dt, a_log, b_ssm, c_ssm, chunk: int, init_state=None):
    """Mamba2 SSD forward.

    xh:    (B, S, H, P)   value heads
    dt:    (B, S, H)      positive step sizes (already softplus'd)
    a_log: (H,)           per-head log decay
    b_ssm: (B, S, N)      input projection (single group)
    c_ssm: (B, S, N)      output projection
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    bsz, s, h, p_dim = xh.shape
    n = b_ssm.shape[-1]
    nc = s // chunk
    q = chunk
    f32 = jnp.float32

    da = dt.astype(f32) * (-jnp.exp(a_log.astype(f32)))[None, None]  # (B,S,H) <= 0
    da = da.reshape(bsz, nc, q, h)
    xc = xh.reshape(bsz, nc, q, h, p_dim).astype(f32)
    dtc = dt.reshape(bsz, nc, q, h).astype(f32)
    bc = b_ssm.reshape(bsz, nc, q, n).astype(f32)
    cc = c_ssm.reshape(bsz, nc, q, n).astype(f32)

    cum = jnp.cumsum(da, axis=2)  # (B, C, Q, H) cumulative log decay
    total = cum[:, :, -1]  # (B, C, H)

    # intra-chunk: Y[t] = sum_{tau<=t} exp(cum_t - cum_tau) * (C_t . B_tau) dt_tau x_tau
    decay = jnp.exp(cum[:, :, :, None] - cum[:, :, None, :])  # (B,C,Qt,Qtau,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(tri[None, None, :, :, None], decay, 0.0)
    cb = jnp.einsum("bcqn,bckn->bcqk", cc, bc)  # (B,C,Qt,Qtau)
    w = cb[..., None] * decay  # (B,C,Qt,Qtau,H)
    y_diag = jnp.einsum("bcqkh,bckh,bckhp->bcqhp", w, dtc, xc)

    # chunk states: S_c = sum_tau exp(total - cum_tau) B_tau (dt_tau x_tau)
    state_decay = jnp.exp(total[:, :, None] - cum)  # (B,C,Q,H)
    s_chunk = jnp.einsum("bckn,bckh,bckhp->bchpn", bc, state_decay * dtc, xc)

    # inter-chunk recurrence over C
    def step(carry, inp):
        s_prev = carry  # (B,H,P,N)
        tot, s_c = inp  # (B,H), (B,H,P,N)
        s_new = s_prev * jnp.exp(tot)[:, :, None, None] + s_c
        return s_new, s_prev

    init = (
        init_state.astype(f32)
        if init_state is not None
        else jnp.zeros((bsz, h, p_dim, n), f32)
    )
    final, s_prevs = jax.lax.scan(
        step,
        init,
        (jnp.moveaxis(total, 1, 0), jnp.moveaxis(s_chunk, 1, 0)),
    )
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)  # (B,C,H,P,N) state entering chunk

    # off-diagonal: Y_off[t] = exp(cum_t) C_t . S_prev
    y_off = jnp.einsum("bcqn,bchpn->bcqhp", cc, s_prevs) * jnp.exp(cum)[..., None]
    y = (y_diag + y_off).reshape(bsz, s, h, p_dim)
    return y, final


def mamba2_block(x, p, cfg, state: Optional[Tuple] = None):
    """Mamba2 block (zamba2).  x: (B, S, D); state: (ssm (B,H,P,N), conv_tail)."""
    b, s, d = x.shape
    di, n = cfg.d_inner(), cfg.ssm_state
    hp = cfg.ssm_head_dim
    nh = di // hp
    zxbcdt = x @ p["in_proj"]  # (B, S, 2*DI + 2N + H)
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    conv_tail = state[1] if state is not None else None
    xbc, new_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_tail)
    xbc = jax.nn.silu(xbc)
    xpart, b_ssm, c_ssm = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])  # (B, S, H)

    xh = xpart.reshape(b, s, nh, hp)
    if state is not None and s == 1:
        h0 = state[0]  # (B, H, P, N)
        da = jnp.exp(
            dt[:, 0].astype(jnp.float32) * (-jnp.exp(p["A_log"].astype(jnp.float32)))[None]
        )  # (B, H)
        upd = jnp.einsum(
            "bn,bh,bhp->bhpn",
            b_ssm[:, 0].astype(jnp.float32),
            dt[:, 0].astype(jnp.float32),
            xh[:, 0].astype(jnp.float32),
        )
        h_new = h0 * da[:, :, None, None] + upd
        yh = jnp.einsum("bhpn,bn->bhp", h_new, c_ssm[:, 0].astype(jnp.float32))
        yh = yh + p["D_skip"].astype(jnp.float32)[None, :, None] * xh[:, 0].astype(jnp.float32)
        y = yh.reshape(b, 1, di)
        final = h_new
    else:
        chunk = min(128, s) if s % min(128, s) == 0 else s
        y4, final = ssd_chunked(
            xh, dt, p["A_log"], b_ssm, c_ssm, chunk=chunk,
            init_state=state[0] if state is not None else None,
        )
        y4 = y4 + p["D_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(
            jnp.float32
        )
        y = y4.reshape(b, s, di)
    # gated RMSNorm (mamba2 style): norm(y * silu(z))
    g = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    g = g * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"].astype(jnp.float32)
    out = g.astype(x.dtype) @ p["out_proj"]
    return out, (final, new_tail)
