"""Mixture-of-Experts FFN with sort-based capacity dispatch.

FLOP-honest expert parallelism: tokens are routed top-k, sorted by expert id,
packed into an (E, C, D) capacity buffer (overflow dropped, standard
capacity-factor semantics), processed by a batched SwiGLU, and scattered
back weighted by the router probabilities.  Expert weights carry a leading E
axis that the launcher shards over the model axis (EP); GSPMD inserts the
token all-to-alls.

arctic-480b additionally evaluates a *dense residual* MLP in parallel and
sums it (its "dense + MoE" design).  dbrx uses 16 fine-grained experts top-4.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import mlp_block


def moe_ffn(x, p, cfg):
    g = getattr(cfg, "moe_groups", 0)
    t = x.shape[0] * x.shape[1]
    # grouped dispatch needs tokens to tile the groups; decode steps (a few
    # tokens) fall back to the flat path, where dispatch is tiny anyway
    if g and t >= g and t % g == 0:
        return moe_ffn_grouped(x, p, cfg)
    return moe_ffn_flat(x, p, cfg)


def moe_ffn_flat(x, p, cfg):
    """x: (B, S, D) -> (B, S, D).  p: router (D, E), w1/w3 (E, D, F), w2 (E, F, D)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    tokens = x.reshape(b * s, d)
    t = b * s
    cap = int(cfg.capacity_factor * t * k / e) or 1
    # round capacity to a lane-friendly multiple
    cap = -(-cap // 8) * 8

    logits = (tokens.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (T, E)
    gate_all = jax.nn.softmax(logits, axis=-1)
    gates, expert_idx = jax.lax.top_k(gate_all, k)  # (T, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # flatten (T*k) assignments and sort by expert
    flat_expert = expert_idx.reshape(-1)  # (T*k,)
    flat_token = jnp.repeat(jnp.arange(t), k)
    flat_gate = gates.reshape(-1)
    order = jnp.argsort(flat_expert)
    se, st_, sg = flat_expert[order], flat_token[order], flat_gate[order]

    # position of each assignment within its expert
    counts = jnp.sum(jax.nn.one_hot(flat_expert, e, dtype=jnp.int32), axis=0)  # (E,)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k) - starts[se]
    keep = pos < cap
    dest = jnp.where(keep, se * cap + pos, e * cap)  # e*cap = drop slot

    # dispatch: (E*C, D)
    dispatched = jnp.zeros((e * cap + 1, d), x.dtype).at[dest].set(tokens[st_])
    dispatched = dispatched[:-1].reshape(e, cap, d)
    if getattr(cfg, "moe_shard_dispatch", False) and cfg.act_sharding:
        # EP anchor: keep the capacity buffer expert-sharded over 'model' so
        # the token scatter lowers to an all-to-all instead of GSPMD
        # materializing + all-reducing the full (E·C, D) buffer (observed:
        # 25 GB/layer all-reduce on arctic-480b without this).
        from jax.sharding import PartitionSpec as P

        dispatched = jax.lax.with_sharding_constraint(
            dispatched, P("model", cfg.act_sharding, None)
        )

    # batched expert SwiGLU: (E, C, D) x (E, D, F)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", dispatched, p["w1"])) * jnp.einsum(
        "ecd,edf->ecf", dispatched, p["w3"]
    )
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["w2"])  # (E, C, D)
    if getattr(cfg, "moe_shard_dispatch", False) and cfg.act_sharding:
        from jax.sharding import PartitionSpec as P

        expert_out = jax.lax.with_sharding_constraint(
            expert_out, P("model", cfg.act_sharding, None)
        )

    # combine: gather each kept assignment's output, weight, scatter-add
    flat_out = expert_out.reshape(e * cap, d)
    gathered = flat_out[jnp.clip(dest, 0, e * cap - 1)]  # (T*k, D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    combined = jnp.zeros((t, d), x.dtype).at[st_].add(
        (gathered.astype(jnp.float32) * sg[:, None]).astype(x.dtype)
    )

    out = combined
    if cfg.dense_residual:
        out = out + mlp_block(x.reshape(t, d), p["dense"], kind="swiglu")
    # auxiliary load-balance loss (standard switch-style), returned via
    # side-channel: caller sums cfg-weighted aux losses
    me = jnp.mean(gate_all, axis=0)  # (E,)
    ce = jnp.mean(jax.nn.one_hot(flat_expert, e, dtype=jnp.float32), axis=0) * k
    aux = e * jnp.sum(me * ce)
    return out.reshape(b, s, d), aux


def moe_ffn_grouped(x, p, cfg):
    """Grouped (per-data-shard) capacity dispatch — the EP-friendly layout.

    Tokens are split into G groups aligned with the data shards; routing,
    ranking, and the capacity scatter are *group-local* (zero collectives),
    so the only cross-shard movement is the (G-sharded -> E-sharded)
    re-layout of the (G, E, C_g, D) capacity buffer, which GSPMD lowers to
    an all-to-all on the expert axis — the canonical expert-parallel
    exchange (tokens·k·cf·D bytes) instead of the full-buffer all-reduce the
    flat layout provokes (observed 25 GB/layer on arctic-480b).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    g = cfg.moe_groups
    tokens = x.reshape(b * s, d)
    t = b * s
    tg = t // g
    cap = int(cfg.capacity_factor * tg * k / e) or 1
    cap = -(-cap // 8) * 8

    logits = tokens.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    gate_all = jax.nn.softmax(logits, axis=-1)
    gates, expert_idx = jax.lax.top_k(gate_all, k)  # (T, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # group-local ranking: (G, Tg*k)
    ge = expert_idx.reshape(g, tg * k)
    gt = jnp.tile(jnp.repeat(jnp.arange(tg), k)[None], (g, 1))
    gg = gates.reshape(g, tg * k)
    order = jnp.argsort(ge, axis=1)
    se = jnp.take_along_axis(ge, order, axis=1)
    st_ = jnp.take_along_axis(gt, order, axis=1)
    sg = jnp.take_along_axis(gg, order, axis=1)
    counts = jnp.sum(jax.nn.one_hot(ge, e, dtype=jnp.int32), axis=1)  # (G, E)
    starts = jnp.cumsum(counts, axis=1) - counts
    pos = jnp.arange(tg * k)[None, :] - jnp.take_along_axis(starts, se, axis=1)
    keep = pos < cap
    dest = jnp.where(keep, se * cap + pos, e * cap)  # (G, Tg*k)

    tok_g = tokens.reshape(g, tg, d)
    if cfg.act_sharding:
        from jax.sharding import PartitionSpec as P

        tok_g = jax.lax.with_sharding_constraint(tok_g, P(cfg.act_sharding, None, None))

    # group-local scatter into the capacity buffer (no cross-group writes)
    def scatter_group(tok, dst, src_idx):
        buf = jnp.zeros((e * cap + 1, d), x.dtype).at[dst].set(tok[src_idx])
        return buf[:-1]

    dispatched = jax.vmap(scatter_group)(tok_g, dest, st_)  # (G, E*C, D)
    dispatched = dispatched.reshape(g, e, cap, d)
    if cfg.act_sharding:
        from jax.sharding import PartitionSpec as P

        # re-layout: G-sharded -> E-sharded (the EP all-to-all)
        dispatched = jax.lax.with_sharding_constraint(
            dispatched, P(None, "model", None, None)
        )

    # expert FFN over all groups' slots: (G, E, C, D) x (E, D, F)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", dispatched, p["w1"])) * jnp.einsum(
        "gecd,edf->gecf", dispatched, p["w3"]
    )
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["w2"])  # (G, E, C, D)
    if cfg.act_sharding:
        from jax.sharding import PartitionSpec as P

        # back to G-sharded for the combine (second all-to-all)
        expert_out = jax.lax.with_sharding_constraint(
            expert_out, P(cfg.act_sharding, None, None, None)
        )

    flat_out = expert_out.reshape(g, e * cap, d)

    def gather_group(buf, dst, src_idx, w, kp):
        vals = buf[jnp.clip(dst, 0, e * cap - 1)]
        vals = jnp.where(kp[:, None], vals, 0)
        return jnp.zeros((tg, d), x.dtype).at[src_idx].add(
            (vals.astype(jnp.float32) * w[:, None]).astype(x.dtype)
        )

    combined = jax.vmap(gather_group)(flat_out, dest, st_, sg, keep)  # (G, Tg, D)
    out = combined.reshape(t, d)
    if cfg.dense_residual:
        out = out + mlp_block(tokens, p["dense"], kind="swiglu")
    me = jnp.mean(gate_all, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_idx.reshape(-1), e, dtype=jnp.float32), axis=0) * k
    aux = e * jnp.sum(me * ce)
    return out.reshape(b, s, d), aux
