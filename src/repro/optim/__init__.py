from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state"]
