"""AdamW with ZeRO-style sharded state.

Moments live in the same pytree structure (and therefore the same
NamedSharding tree) as the parameters, so sharding the params shards the
optimizer state for free — ZeRO-3 falls out of GSPMD.  ``state_dtype``
selects the moment precision: fp32 by default, bf16 for the 480B MoE so the
full training state fits a single 256-chip v5e pod (see configs/arctic).

Update math always runs in fp32 regardless of storage dtype.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1


def init_opt_state(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr * (step + 1.0) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps) / max(cfg.decay_steps, 1), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_update(params, grads, state, cfg: AdamWConfig):
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    lr = lr_schedule(cfg, state["count"])

    # global-norm clip
    gsq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)
    )
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))

    dt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(gf)
        mhat = mf / (1 - cfg.b1**cf)
        vhat = vf / (1 - cfg.b2**cf)
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (
            (p.astype(jnp.float32) - lr * step_).astype(p.dtype),
            mf.astype(dt),
            vf.astype(dt),
        )

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "count": count}, metrics
