"""Fabric observability: flight-recorder tracing + metrics registry.

Entry point is :class:`FabricObserver` (or the module-level
:data:`NULL_OBS` default — a disabled observer whose every method is a
no-op).  Construction is deliberately decoupled from the runtime: an
observer is handed to ``ShardedDFCRuntime`` / ``RequestQueueTier`` /
``SimFS`` by reference, never imported by them at module level, so the
``obs`` package stays dependency-free and the runtime works identically
without it.

The one invariant everything here is built around: **observability never
adds a persistence instruction**.  Durable-state digests and pwb/pfence
counts with tracing enabled must equal the untraced run exactly; the trace
sidecar's durability rides the fabric's own pfences (see ``trace.py``).
"""

from __future__ import annotations

from pathlib import Path
from typing import Any, Optional

from .metrics import (
    Histogram,
    MetricsRegistry,
    NullMetrics,
    bridge_persist_stats,
    to_chrome_trace,
)
from .trace import (
    EV_ANNOUNCE,
    EV_DISPATCH,
    EV_DRAIN,
    EV_EPOCH,
    EV_FABRIC,
    EV_PFENCE,
    EV_PWB,
    EV_RECOVER,
    EV_REQUEST,
    EV_RESHARD,
    EV_RETIRE,
    EV_SCHED,
    EV_TOPOLOGY,
    EV_VERDICT,
    NULL_RECORDER,
    NullRecorder,
    TraceRecorder,
    durable_digest,
    read_trace,
)

__all__ = [
    "FabricObserver",
    "NullObserver",
    "NULL_OBS",
    "TraceRecorder",
    "NullRecorder",
    "NULL_RECORDER",
    "MetricsRegistry",
    "NullMetrics",
    "Histogram",
    "bridge_persist_stats",
    "to_chrome_trace",
    "durable_digest",
    "read_trace",
    "EV_ANNOUNCE",
    "EV_DISPATCH",
    "EV_DRAIN",
    "EV_EPOCH",
    "EV_FABRIC",
    "EV_PFENCE",
    "EV_PWB",
    "EV_RECOVER",
    "EV_REQUEST",
    "EV_RESHARD",
    "EV_RETIRE",
    "EV_SCHED",
    "EV_TOPOLOGY",
    "EV_VERDICT",
]


class NullObserver:
    """Disabled observer: the fabric-wide default.  One ``enabled`` check
    gates any instrumentation that would cost something to compute."""

    enabled = False

    def __init__(self):
        self.trace = NULL_RECORDER
        self.metrics = NullMetrics()

    def event(self, ev: str, **fields: Any):
        return self.trace.event(ev, **fields)

    def span(self, ev: str, **fields: Any):
        return self.trace.span(ev, **fields)

    def on_pwb(self, rel: str, tag: Optional[str]) -> None:
        return None

    def on_pfence(self, rels, tag: Optional[str]) -> None:
        return None

    def flush(self) -> None:
        return None

    def observe_fabric(self, rt) -> None:
        return None


NULL_OBS = NullObserver()


class FabricObserver(NullObserver):
    """Live observer: a :class:`TraceRecorder` (optionally with a durable
    sidecar under ``<root>/obs/trace.jsonl``) plus a
    :class:`MetricsRegistry`, with the pwb/pfence hooks feeding both."""

    enabled = True

    def __init__(self, root=None, trace_capacity: int = 4096):
        self.root = Path(root) if root is not None else None
        path = self.root / "obs" / "trace.jsonl" if self.root is not None else None
        self.trace = TraceRecorder(path, capacity=trace_capacity)
        self.metrics = MetricsRegistry()

    @property
    def trace_path(self) -> Optional[Path]:
        return self.trace.path

    def on_pwb(self, rel: str, tag: Optional[str]) -> None:
        self.trace.on_pwb(rel, tag)
        self.metrics.counter("obs_pwb", tag=tag or "untagged")

    def on_pfence(self, rels, tag: Optional[str]) -> None:
        self.trace.on_pfence(rels, tag)
        self.metrics.counter("obs_pfence", tag=tag or "untagged")

    def flush(self) -> None:
        self.trace.flush()

    def observe_fabric(self, rt) -> None:
        """Sample per-shard gauges from a ``ShardedDFCRuntime`` (duck-typed
        — no runtime import).  Forces a device sync via ``shard_sizes``;
        call at phase boundaries, not per-op."""
        sizes = rt.shard_sizes()
        epochs = rt.shard_epochs()
        for s, size in enumerate(sizes):
            self.metrics.gauge("shard_backlog", int(size), shard=s, kind=rt.kinds[s])
            self.metrics.gauge("shard_epoch", int(epochs[s]), shard=s)
        inflight = len(getattr(rt, "_inflight", ()))
        self.metrics.gauge("inflight_chains", inflight)
        if getattr(rt, "ring", None) is not None:
            tail = int(getattr(rt, "_ring_tail", 0))
            spans = getattr(rt, "_ring_spans", {})
            head = min((s0 for s0, _ in spans.values()), default=tail)
            self.metrics.gauge("ring_occupancy", tail - head)
        # per-side combiners (split-lane fabrics): committed [eH, eT] pairs
        # and announced-but-uncombined backlog per (shard, lane)
        lane_stats = None
        getter = getattr(rt, "lane_stats", None)
        if callable(getter):
            lane_stats = getter()
        extra = {}
        if lane_stats:
            for s, pair in lane_stats.get("epochs", {}).items():
                self.metrics.gauge("lane_epoch_head", int(pair[0]), shard=s)
                self.metrics.gauge("lane_epoch_tail", int(pair[1]), shard=s)
            for s, bl in lane_stats.get("backlog", {}).items():
                self.metrics.gauge("lane_backlog_head", int(bl[0]), shard=s)
                self.metrics.gauge("lane_backlog_tail", int(bl[1]), shard=s)
            extra = {
                "lane_epochs": {
                    str(s): [int(e) for e in pair]
                    for s, pair in lane_stats.get("epochs", {}).items()
                },
                "lane_backlog": {
                    str(s): [int(x) for x in bl]
                    for s, bl in lane_stats.get("backlog", {}).items()
                },
            }
        self.event(
            EV_FABRIC,
            backlog=[int(x) for x in sizes],
            epochs=[int(e) for e in epochs],
            inflight=inflight,
            **extra,
        )
