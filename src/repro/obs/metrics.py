"""Counter / gauge / histogram registry for the DFC fabric.

The registry is the queryable side of the flight recorder: where
``trace.py`` records *what happened in order*, this module aggregates *how
much and how fast* — per-shard backlog and ring-occupancy gauges, pwb/op
and pfence/phase counters fed from :class:`repro.nvm.memory.PersistStats`,
elision hit rates, in-flight chain depth, and log-bucketed latency
histograms with p50/p99 readout.  Everything lives in volatile host memory:
metrics are derived state and are never persisted through the fabric (the
same never-add-a-persistence-instruction constraint the recorder obeys).

Exporters: :meth:`MetricsRegistry.to_jsonl` (one metric per line, easy to
diff/grep) and :func:`to_chrome_trace` (renders a recorded event list as a
``chrome://tracing`` / Perfetto-loadable JSON array).
"""

from __future__ import annotations

import json
import math
from pathlib import Path
from typing import Any, Dict, List, Optional

# Quarter-power-of-two buckets: ~19% relative width, 4 buckets per octave.
# Fine enough that p50/p99 are honest, coarse enough that a histogram is a
# handful of ints.
_BASE = 2.0 ** 0.25
_LN_BASE = math.log(_BASE)


class Histogram:
    """Log-bucketed histogram (quarter-octave buckets) with percentile
    readout.  Values must be non-negative; zeros land in a dedicated
    underflow bucket so latency-0 samples (same-tick admission) don't
    poison the log scale."""

    def __init__(self):
        self.buckets: Dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def record(self, value: float) -> None:
        v = float(value)
        if v < 0:
            v = 0.0
        idx = -(2 ** 31) if v == 0 else int(math.floor(math.log(v) / _LN_BASE))
        self.buckets[idx] = self.buckets.get(idx, 0) + 1
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    def percentile(self, q: float) -> float:
        """Value at quantile ``q`` in [0, 1]: the geometric midpoint of the
        bucket holding the q-th sample, clamped to the observed min/max."""
        if self.count == 0:
            return 0.0
        rank = q * (self.count - 1)
        seen = 0
        for idx in sorted(self.buckets):
            seen += self.buckets[idx]
            if seen > rank:
                if idx == -(2 ** 31):
                    return 0.0
                mid = _BASE ** (idx + 0.5)
                lo = 0.0 if self.min is None else self.min
                hi = mid if self.max is None else self.max
                return max(lo, min(mid, hi))
        return self.max or 0.0

    @property
    def p50(self) -> float:
        return self.percentile(0.50)

    @property
    def p99(self) -> float:
        return self.percentile(0.99)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "mean": self.mean,
            "min": self.min or 0.0,
            "max": self.max or 0.0,
            "p50": self.p50,
            "p99": self.p99,
        }


def _key(name: str, labels: Dict[str, Any]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Flat registry keyed by ``name{label=value,...}`` strings.

    Counters are monotone adds (or absolute sets via ``counter_set`` for
    mirroring an external monotone source like ``PersistStats``); gauges
    are last-write-wins; histograms accumulate samples.
    """

    enabled = True

    def __init__(self):
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, Histogram] = {}

    # ------------------------------------------------------------ recording
    def counter(self, name: str, delta: float = 1, **labels: Any) -> None:
        k = _key(name, labels)
        self.counters[k] = self.counters.get(k, 0) + delta

    def counter_set(self, name: str, value: float, **labels: Any) -> None:
        self.counters[_key(name, labels)] = value

    def gauge(self, name: str, value: float, **labels: Any) -> None:
        self.gauges[_key(name, labels)] = value

    def histogram(self, name: str, **labels: Any) -> Histogram:
        k = _key(name, labels)
        h = self.histograms.get(k)
        if h is None:
            h = self.histograms[k] = Histogram()
        return h

    def observe(self, name: str, value: float, **labels: Any) -> None:
        self.histogram(name, **labels).record(value)

    # ------------------------------------------------------------- readback
    def snapshot(self) -> Dict[str, Any]:
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {k: h.summary() for k, h in self.histograms.items()},
        }

    def to_jsonl(self, path) -> int:
        """Write one JSON line per metric; returns the line count."""
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        lines = []
        for k, v in sorted(self.counters.items()):
            lines.append({"type": "counter", "name": k, "value": v})
        for k, v in sorted(self.gauges.items()):
            lines.append({"type": "gauge", "name": k, "value": v})
        for k, h in sorted(self.histograms.items()):
            lines.append({"type": "histogram", "name": k, **h.summary()})
        with p.open("w") as f:
            for rec in lines:
                f.write(json.dumps(rec) + "\n")
        return len(lines)


class NullMetrics(MetricsRegistry):
    """Registry that drops everything — the disabled-observer default, so
    unguarded ``obs.metrics.counter(...)`` calls stay safe and O(1)."""

    enabled = False

    def counter(self, name, delta=1, **labels):
        return None

    def counter_set(self, name, value, **labels):
        return None

    def gauge(self, name, value, **labels):
        return None

    def observe(self, name, value, **labels):
        return None


def bridge_persist_stats(registry: MetricsRegistry, pstats, prefix: str = "persist") -> None:
    """Mirror a :class:`PersistStats` tag dict into the registry as absolute
    counters (``persist_pwb{tag=...}`` / ``persist_pfence{tag=...}``) plus
    totals.  Call at phase boundaries; PersistStats stays the source of
    truth, the registry is the queryable projection."""
    for tag, n in pstats.pwb.items():
        registry.counter_set(f"{prefix}_pwb", n, tag=tag)
    for tag, n in pstats.pfence.items():
        registry.counter_set(f"{prefix}_pfence", n, tag=tag)
    registry.counter_set(f"{prefix}_pwb_total", pstats.total_pwb())
    registry.counter_set(f"{prefix}_pfence_total", pstats.total_pfence())


def to_chrome_trace(events: List[Dict[str, Any]], path) -> int:
    """Render recorded trace events as a Chrome trace-event JSON array
    (load in chrome://tracing or ui.perfetto.dev).  Events with ``dur_us``
    become complete ('X') slices re-based to their begin time; the rest
    become instants ('i').  Returns the event count."""
    out = []
    for e in events:
        ts = float(e.get("ts_us", 0.0))
        dur = e.get("dur_us")
        rec = {
            "name": e.get("ev", "?"),
            "pid": 0,
            "tid": int(e.get("thread", 0)),
            "args": {
                k: v
                for k, v in e.items()
                if k not in ("ev", "ts_us", "dur_us", "thread")
            },
        }
        if dur is not None:
            rec.update(ph="X", ts=ts - float(dur), dur=float(dur))
        else:
            rec.update(ph="i", ts=ts, s="t")
        out.append(rec)
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(out, indent=1) + "\n")
    return len(out)
