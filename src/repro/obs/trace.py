"""Fabric flight recorder: ring-buffered structured event tracing with a
crash-durable tail.

The paper's empirical story is a *schedule* — which pwbs and pfences ran, in
what order, attributed to which protocol step — and until now the repo could
only reconstruct it by arithmetic over counter totals.  The recorder makes
the schedule itself first-class: every instrumented site (announce, chain
dispatch, intent drain, pwb, pfence, epoch commit, reshard, recovery)
appends a structured event with a MONOTONIC sequence number to an in-memory
ring, and the tail of that ring is appended to a sidecar file
(``<root>/obs/trace.jsonl``) every time the fabric completes a pfence.

Durability model — and the invariant that makes tracing a correctness
feature rather than logging:

  * the recorder NEVER issues a persistence instruction of its own.  Events
    buffer in volatile memory; the flush to the sidecar file rides the
    fabric's own ``pfence`` completions (``SimFS.fsync`` calls
    ``on_pfence`` only after the fence succeeded, and the fault injector
    ticks BEFORE the hook), so pwb/pfence counts with tracing enabled are
    EXACTLY the untraced counts and the durable state is bit-identical
    (``tests/test_obs.py`` + the CI obs smoke gate both);
  * a crash therefore leaves a durable trace PREFIX: every event recorded
    up to the last completed fence, none after it — the same prefix-point
    semantics the NVM lines themselves obey.  ``ShardedDFCRuntime.recover``
    EXTENDS that prefix with per-thread detectability verdicts, so the
    sidecar reads as a crash-forensics timeline: what the fabric was doing,
    where it died, and what recovery concluded about every announced op.

The recorder is opt-in: the default is :data:`NULL_RECORDER` (every method
a no-op, ``enabled`` False), so the hot path costs one attribute check.
"""

from __future__ import annotations

import contextlib
import hashlib
import json
import time
from collections import deque
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional

# ---------------------------------------------------------- event taxonomy
# One constant per instrumented protocol step; docs/observability.md is the
# prose companion.  Events are plain dicts: {"seq", "ts_us", "ev", ...}.
EV_TOPOLOGY = "topology"  # fabric shape: kinds, lanes, buckets
EV_ANNOUNCE = "announce"  # thread-side announcement (3 pwb + 2 pfence)
EV_DISPATCH = "dispatch"  # device combine dispatched for a chain/schedule
EV_DRAIN = "drain"  # host intent drain of one fused phase
EV_RETIRE = "retire"  # pipelined chain retired (persist + commit)
EV_PWB = "pwb"  # one persistent write-back (SimFS.write)
EV_PFENCE = "pfence"  # one persistence fence (SimFS.fsync)
EV_EPOCH = "epoch_commit"  # per-shard two-increment commit completed
EV_RESHARD = "reshard"  # split/merge transaction
EV_RECOVER = "recover"  # recovery pass begin/end
EV_VERDICT = "verdict"  # per-thread detectability verdict (recovery)
EV_SCHED = "sched"  # MultiThreadDriver interleaving action
EV_REQUEST = "request"  # serving-tier request lifecycle (arrive/admit/serve)
EV_FABRIC = "fabric"  # periodic per-shard gauge sample (backlog, epochs)


class NullRecorder:
    """The default recorder: every method a no-op.

    Instrumented code may call these unconditionally; sites that would pay
    to BUILD the event payload guard on ``enabled`` first.
    """

    enabled = False

    def event(self, ev: str, **fields: Any) -> None:
        return None

    @contextlib.contextmanager
    def span(self, ev: str, **fields: Any):
        yield None

    def on_pwb(self, rel: str, tag: Optional[str]) -> None:
        return None

    def on_pfence(self, rels, tag: Optional[str]) -> None:
        return None

    def flush(self) -> None:
        return None

    def events(self) -> List[Dict[str, Any]]:
        return []


NULL_RECORDER = NullRecorder()


class TraceRecorder(NullRecorder):
    """Ring-buffered event recorder with a pfence-riding durable tail.

    ``path`` is the sidecar file (``None`` keeps the trace memory-only —
    the ring still works, ``flush`` is a no-op).  ``capacity`` bounds the
    in-memory ring; the durable sidecar is append-only and unbounded (it is
    a forensics artifact, not runtime state — recovery never reads it).
    """

    enabled = True

    def __init__(self, path: Optional[Path] = None, capacity: int = 4096):
        self.path = Path(path) if path is not None else None
        self.capacity = int(capacity)
        self.seq = 0
        self.ring: "deque[Dict[str, Any]]" = deque(maxlen=self.capacity)
        self._pending: List[Dict[str, Any]] = []
        self._t0 = time.perf_counter_ns()
        if self.path is not None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            if self.path.exists():
                # A prior incarnation (pre-crash run) left a durable prefix:
                # continue its sequence numbering so the sidecar reads as ONE
                # monotone timeline across the crash.
                lines = self.path.read_text().splitlines()
                for line in reversed(lines):
                    line = line.strip()
                    if line:
                        self.seq = int(json.loads(line).get("seq", -1)) + 1
                        break

    # ------------------------------------------------------------ recording
    def event(self, ev: str, **fields: Any) -> Dict[str, Any]:
        rec = {
            "seq": self.seq,
            "ts_us": (time.perf_counter_ns() - self._t0) / 1e3,
            "ev": ev,
        }
        rec.update(fields)
        self.seq += 1
        self.ring.append(rec)
        self._pending.append(rec)
        return rec

    @contextlib.contextmanager
    def span(self, ev: str, **fields: Any):
        """Record ``ev`` as ONE event carrying its wall duration (closed at
        exit, so the event's ``ts_us`` marks the END and ``dur_us`` spans
        back — the Chrome exporter re-bases it to a begin timestamp)."""
        t0 = time.perf_counter_ns()
        try:
            yield self
        finally:
            self.event(ev, dur_us=(time.perf_counter_ns() - t0) / 1e3, **fields)

    # -------------------------------------------------- persistence hooks
    def on_pwb(self, rel: str, tag: Optional[str]) -> None:
        self.event(EV_PWB, rel=rel, tag=tag or "untagged")

    def on_pfence(self, rels, tag: Optional[str]) -> None:
        """A fence COMPLETED: record it, then write the buffered tail to
        the sidecar.  Riding the fence (instead of fsyncing a trace file of
        our own) is what keeps tracing persistence-free; a crash loses
        exactly the events since the last fence — a durable prefix."""
        self.event(
            EV_PFENCE,
            n=(len(rels) if rels is not None else -1),
            tag=tag or "untagged",
        )
        self.flush()

    def flush(self) -> None:
        """Append the un-flushed tail to the sidecar file (host file I/O,
        not a fabric persistence op).  Called from ``on_pfence`` and from
        sanctioned host-side flush points (end of recovery, clean
        shutdown)."""
        if not self._pending:
            return
        if self.path is not None:
            with self.path.open("a") as f:
                for rec in self._pending:
                    f.write(json.dumps(rec) + "\n")
        self._pending.clear()

    # ------------------------------------------------------------- readback
    def events(self) -> List[Dict[str, Any]]:
        """The in-memory ring, oldest first (bounded by ``capacity``)."""
        return list(self.ring)


def read_trace(path) -> List[Dict[str, Any]]:
    """Load a trace sidecar file back into a list of event dicts."""
    p = Path(path)
    if not p.exists():
        return []
    out = []
    for line in p.read_text().splitlines():
        line = line.strip()
        if line:
            out.append(json.loads(line))
    return out


def durable_digest(root, exclude: Iterable[str] = ("obs",)) -> str:
    """Content digest of everything DURABLE under ``root`` (the on-disk
    files — SimFS pending buffers are volatile by definition), excluding
    the observability sidecars.  The traced-vs-untraced parity gate hashes
    this: tracing must leave the durable state bit-identical."""
    root = Path(root)
    skip = tuple(exclude)
    h = hashlib.blake2b(digest_size=16)
    for p in sorted(root.rglob("*")):
        if not p.is_file():
            continue
        rel = p.relative_to(root).as_posix()
        if any(rel == s or rel.startswith(s + "/") for s in skip):
            continue
        h.update(rel.encode())
        h.update(b"\0")
        h.update(p.read_bytes())
        h.update(b"\1")
    return h.hexdigest()
