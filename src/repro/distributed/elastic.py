"""Elastic scaling at combining-phase boundaries.

DFC makes elastic resizes natural: the announcement array is sized N_max and
the *active worker set* is just manifest metadata — growing or shrinking the
job is a combining phase that (1) commits the current state, (2) rewrites
the active set, (3) re-shards the data-cursor space.  Workers joining later
announce into their pre-allocated slot (the paper's late-arrival path);
departed workers simply stop announcing and the combiner's quorum logic
(straggler deadline) proceeds without them.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List


@dataclasses.dataclass
class ElasticPlan:
    old_workers: List[int]
    new_workers: List[int]
    cursor_map: Dict[int, int]  # worker -> starting cursor after resize


def plan_resize(
    old_workers: List[int], new_workers: List[int], committed_cursor: int
) -> ElasticPlan:
    """Deterministic cursor re-sharding: the global batch stream is a single
    logical sequence; after resize each worker w (rank r of the new set)
    consumes cursors committed_cursor + r, + r + N, ...  — no sample is lost
    or duplicated across the resize (exactly-once extends across elasticity).
    """
    cursor_map = {
        w: committed_cursor + rank for rank, w in enumerate(sorted(new_workers))
    }
    return ElasticPlan(sorted(old_workers), sorted(new_workers), cursor_map)
