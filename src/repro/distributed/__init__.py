from repro.distributed.compression import (
    CompressionState,
    compress_topk,
    decompress_topk,
    ef_compress_grads,
    init_compression,
    quantize_int8,
    dequantize_int8,
)
from repro.distributed.elastic import ElasticPlan, plan_resize

__all__ = [
    "CompressionState",
    "compress_topk",
    "decompress_topk",
    "ef_compress_grads",
    "init_compression",
    "quantize_int8",
    "dequantize_int8",
    "ElasticPlan",
    "plan_resize",
]
