"""Gradient compression for the cross-pod (DCN) data-parallel axis.

At 2+ pods the pod-axis all-reduce crosses the slow DCN links; compressing
it is the standard distributed-optimization trick.  Two composable schemes:

  * error-feedback top-k sparsification (memory = one residual per param):
    the residual carries the un-transmitted mass into the next step, which
    preserves convergence (Stich et al.),
  * int8 linear quantization with per-tensor scale (4x over f32, 2x bf16).

These run *inside* the jitted step on the pod-axis gradients; the DFC
announcement records the compression config so recovery reproduces the same
math (determinism contract of the exactly-once resume).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class CompressionState:
    residual: Any  # pytree like grads


def init_compression(params) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    )


# --------------------------------------------------------------------- top-k
def compress_topk(g: jax.Array, frac: float = 0.01) -> Tuple[jax.Array, jax.Array]:
    """Keep the top-|frac| entries by magnitude.  Returns (values, flat_idx)."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.shape[0] * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def decompress_topk(vals, idx, shape) -> jax.Array:
    n = 1
    for s in shape:
        n *= s
    return jnp.zeros((n,), jnp.float32).at[idx].set(vals).reshape(shape)


def ef_compress_grads(grads, state: CompressionState, frac: float = 0.01):
    """Error-feedback top-k over a gradient pytree.

    Returns (compressed_grads_dense, new_state).  The dense reconstruction is
    what enters the (cheap, sparse-in-content) cross-pod all-reduce; the
    residual keeps whatever was dropped."""

    def one(g, r):
        acc = g.astype(jnp.float32) + r
        vals, idx = compress_topk(acc, frac)
        sent = decompress_topk(vals, idx, acc.shape)
        return sent.astype(g.dtype), acc - sent

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(state.residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    sent = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    resid = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return sent, CompressionState(residual=resid)


# ---------------------------------------------------------------------- int8
def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale
