"""zamba2-7b [hybrid] — 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64; Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; unverified]

Structure: 13 groups of 6 mamba2 layers, the single *shared* attention+MLP
block (32 heads, d_ff 14336) applied after each group, + a 3-layer mamba
tail (13*6 + 3 = 81).  Zamba2's concatenated-embedding input to the shared
block and its LoRA adapters are simplified to a standard pre-norm shared
block (see DESIGN.md).  Runs ``long_500k`` with a 4096-token rolling window
on the shared attention (its Mamba state is O(1)).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        d_ff=14336,
        vocab=32000,
        ssm_version=2,
        ssm_state=64,
        ssm_head_dim=64,
        expand=2,
        attn_every=6,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b-smoke",
        family="hybrid",
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        ssm_version=2,
        ssm_state=16,
        ssm_head_dim=16,
        expand=2,
        attn_every=2,
        remat="none",
        dtype="float32",
    )
