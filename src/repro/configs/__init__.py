"""Architecture registry: ``get_config(arch_id)`` / ``get_reduced(arch_id)``.

All 10 assigned architectures are selectable via ``--arch <id>`` in the
launchers; each module holds the exact published configuration plus a smoke
(reduced) configuration of the same family for CPU tests.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

_MODULES: Dict[str, str] = {
    "llama-3.2-vision-11b": "repro.configs.llama_3_2_vision_11b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "smollm-135m": "repro.configs.smollm_135m",
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
    "olmo-1b": "repro.configs.olmo_1b",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "musicgen-large": "repro.configs.musicgen_large",
    "arctic-480b": "repro.configs.arctic_480b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch_id]).config()


def get_reduced(arch_id: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch_id]).reduced_config()
