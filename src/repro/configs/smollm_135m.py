"""smollm-135m [dense] — 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152,
llama-arch small, tied embeddings.  [hf:HuggingFaceTB/SmolLM-135M; hf]
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m",
        family="dense",
        n_layers=30,
        d_model=576,
        n_heads=9,
        n_kv_heads=3,
        d_ff=1536,
        vocab=49152,
        tie_embeddings=True,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m-smoke",
        family="dense",
        n_layers=4,
        d_model=48,
        n_heads=3,
        n_kv_heads=1,
        d_ff=96,
        vocab=256,
        tie_embeddings=True,
        remat="none",
        dtype="float32",
    )
