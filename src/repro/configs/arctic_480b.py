"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) expert d_ff=4864
vocab=32000; MoE 128 experts top-2 **plus a dense residual MLP** evaluated in
parallel (Snowflake Arctic's dense-MoE hybrid).
[hf:Snowflake/snowflake-arctic-base; hf]

Memory note: ~480B params.  The launcher shards experts over the model axis
(8 experts/shard on a 16-way axis) and everything over data (ZeRO); optimizer
moments are kept in bf16 for this arch so train_4k fits a 256×16 GB pod (see
EXPERIMENTS.md §Dry-run memory analysis).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=4864,  # dense residual MLP width
        vocab=32000,
        n_experts=128,
        top_k=2,
        moe_dff=4864,
        dense_residual=True,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b-smoke",
        family="moe",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab=256,
        n_experts=8,
        top_k=2,
        moe_dff=96,
        dense_residual=True,
        remat="none",
        dtype="float32",
    )
