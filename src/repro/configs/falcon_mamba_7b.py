"""falcon-mamba-7b [ssm] — 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16; mamba1 architecture.  [arXiv:2410.05355; unverified]

d_inner = 2*4096 = 8192, d_conv = 4, dt_rank = 256.  O(1)-state decode makes
this one of the two archs assigned to run ``long_500k``.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        n_layers=64,
        d_model=4096,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab=65024,
        ssm_version=1,
        ssm_state=16,
        d_conv=4,
        expand=2,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b-smoke",
        family="ssm",
        n_layers=4,
        d_model=64,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab=256,
        ssm_version=1,
        ssm_state=4,
        d_conv=4,
        expand=2,
        remat="none",
        dtype="float32",
    )
