"""Assigned input shapes and per-(arch × shape) input specs.

Four shapes per LM architecture:
  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> prefill (serve)
  decode_32k   ctx 32,768  global_batch 128   -> serve_step (1 new token)
  long_500k    ctx 524,288 global_batch 1     -> serve_step; SSM/hybrid only

``input_specs`` returns jax.ShapeDtypeStruct stand-ins (no allocation) for
everything a step function consumes — batch AND (for decode) the KV/SSM cache.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import init_cache

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode
    window: int = 0  # rolling attention window for long-context decode


SHAPES: Dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524_288, 1, "decode", window=4_096),
}

# archs allowed to run long_500k (sub-quadratic decode state)
LONG_CONTEXT_ARCHS = ("zamba2-7b", "falcon-mamba-7b")


def supports(arch_name: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_name in LONG_CONTEXT_ARCHS
    return True


def _batch_specs(cfg: ModelConfig, b: int, s: int, with_labels: bool):
    specs: Dict[str, SDS] = {}
    if cfg.embedding_inputs:
        specs["embeddings"] = SDS((b, s, cfg.d_model), cfg.act_dtype())
    else:
        specs["tokens"] = SDS((b, s), jnp.int32)
    if with_labels:
        specs["labels"] = SDS((b, s), jnp.int32)
    if cfg.family == "vlm":
        specs["image_embeddings"] = SDS((b, cfg.n_img_tokens, cfg.d_model), cfg.act_dtype())
    return specs


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict:
    """ShapeDtypeStruct pytree for the step function of (arch, shape)."""
    sh = SHAPES[shape_name]
    if sh.kind == "train":
        return {"batch": _batch_specs(cfg, sh.global_batch, sh.seq_len, True)}
    if sh.kind == "prefill":
        return {"batch": _batch_specs(cfg, sh.global_batch, sh.seq_len, False)}
    # decode: one new token + a full cache at context length
    cache = jax.eval_shape(
        lambda: init_cache(cfg, sh.global_batch, sh.seq_len, window=sh.window)
    )
    return {
        "batch": _batch_specs(cfg, sh.global_batch, 1, False),
        "cache": cache,
    }
