"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) expert d_ff=10752
vocab=100352; 16 experts top-4, fine-grained.  [hf:databricks/dbrx-base;
unverified]
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b",
        family="moe",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=10752,
        vocab=100352,
        n_experts=16,
        top_k=4,
        moe_dff=10752,
        rope_theta=500_000.0,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b-smoke",
        family="moe",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        n_experts=4,
        top_k=2,
        moe_dff=128,
        remat="none",
        dtype="float32",
    )
