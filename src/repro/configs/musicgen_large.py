"""musicgen-large [audio] — 48L d_model=2048 32H (MHA kv=32) d_ff=8192
vocab=2048; decoder-only over EnCodec tokens.  [arXiv:2306.05284; hf]

Backbone only per the assignment: the EnCodec frontend + codebook delay
pattern are stubbed — ``input_specs`` provides precomputed frame embeddings
(B, S, d_model); logits are over the 2048-entry codebook.  MusicGen's
parametric LayerNorm is mapped to RMSNorm (see DESIGN.md).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=2048,
        embedding_inputs=True,
        mlp="gelu",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large-smoke",
        family="audio",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=64,
        embedding_inputs=True,
        mlp="gelu",
        remat="none",
        dtype="float32",
    )
