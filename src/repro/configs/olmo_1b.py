"""olmo-1b [dense] — 16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304;
non-parametric LayerNorm.  [arXiv:2402.00838; hf]
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b",
        family="dense",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=8192,
        vocab=50304,
        norm="layernorm_np",
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b-smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        norm="layernorm_np",
        remat="none",
        dtype="float32",
    )
