"""deepseek-coder-33b [dense] — 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256; llama-arch.  [arXiv:2401.14196; hf]
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b",
        family="dense",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=19200,
        vocab=32256,
        rope_theta=100_000.0,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b-smoke",
        family="dense",
        n_layers=4,
        d_model=56,
        n_heads=7,
        n_kv_heads=1,
        d_ff=144,
        vocab=256,
        remat="none",
        dtype="float32",
    )
