"""qwen2-1.5b [dense] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936; GQA with QKV bias, tied embeddings.  [arXiv:2407.10671; hf]
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b",
        family="dense",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab=151936,
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b-smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab=256,
        qkv_bias=True,
        tie_embeddings=True,
        remat="none",
        dtype="float32",
    )
