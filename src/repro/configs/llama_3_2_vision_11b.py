"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; cross-attention image layers every 5th layer (8 total).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision frontend (ViT) is a stub per the assignment: ``input_specs``
provides precomputed patch embeddings (B, n_img_tokens, d_model).
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b",
        family="vlm",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=128256,
        cross_attn_every=5,
        n_img_tokens=1024,
        rope_theta=500_000.0,
    )


def reduced_config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b-smoke",
        family="vlm",
        n_layers=10,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        cross_attn_every=5,
        n_img_tokens=16,
        remat="none",
        dtype="float32",
    )
