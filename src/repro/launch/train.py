"""Production training launcher.

Selects an architecture (``--arch``), builds the mesh, shards params/opt/
batch per launch/sharding.py, and runs the fault-tolerant training loop with
DFC-Checkpoint.  On this CPU container it is exercised with reduced configs
(``--reduced``) — the same code path the dry-run lowers for the full configs
on the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --steps 50 --ckpt-dir /tmp/dfc_ckpt
"""

from __future__ import annotations

import argparse
from pathlib import Path

import jax

from repro.checkpoint.dfc_checkpoint import SimFS
from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.data.pipeline import DataPipeline
from repro.launch.tuned import apply_tuning
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import TrainRuntime


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", help="CPU-sized smoke config")
    ap.add_argument("--tuned", action="store_true", default=True)
    ap.add_argument("--no-tuned", dest="tuned", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/dfc_ckpt")
    ap.add_argument("--workers", type=int, default=4)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.tuned:
        cfg = apply_tuning(cfg)
    if cfg.family in ("vlm", "audio"):
        raise SystemExit(
            f"{args.arch}: frontend-stub arch — drive via examples/ or dryrun"
        )

    pipe = DataPipeline(vocab=cfg.vocab, batch_size=args.batch, seq_len=args.seq)
    fs = SimFS(Path(args.ckpt_dir))
    rt = TrainRuntime(
        cfg, AdamWConfig(), pipe, fs, n_workers=args.workers, ckpt_every=args.ckpt_every
    )
    params, opt, step, cursor, report = rt.boot()
    if step:
        print(f"resuming from committed step {step} (detectability: {report})")
    params, opt, losses = rt.train(args.steps)
    print(f"trained to step {args.steps}: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    print(f"persistence: {fs.stats}")


if __name__ == "__main__":
    main()
