"""Per-arch tuned perf levers — the hillclimb results as deployable defaults.

`apply_tuning(cfg)` returns the optimized configuration for the production
mesh (EXPERIMENTS.md §Perf).  Levers are math-preserving (validated in
tests/); they only change sharding structure, dispatch layout, and
chunking.  Baseline (paper-faithful substrate) is always available with
``--no-tuned``.
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

# context-parallel attention + sequence-parallel residual: wins on every
# attention-bearing arch whose head count is not divisible by the TP width,
# and is neutral-to-positive on the others (EXPERIMENTS.md §Perf A/C)
_ATTN_TUNING = dict(attn_seq_shard=True, seq_parallel_resid=True)

TUNED = {
    "llama-3.2-vision-11b": _ATTN_TUNING,
    "zamba2-7b": _ATTN_TUNING,
    "smollm-135m": _ATTN_TUNING,
    "qwen2-1.5b": _ATTN_TUNING,
    "olmo-1b": _ATTN_TUNING,
    "deepseek-coder-33b": _ATTN_TUNING,
    "musicgen-large": _ATTN_TUNING,
    "arctic-480b": dict(moe_groups=16, **_ATTN_TUNING),
    "dbrx-132b": dict(moe_groups=16, **_ATTN_TUNING),
    "falcon-mamba-7b": dict(seq_parallel_resid=True),
}


def apply_tuning(cfg: ModelConfig) -> ModelConfig:
    overrides = TUNED.get(cfg.name, {})
    return dataclasses.replace(cfg, **overrides)
