import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Hillclimb harness: probe one (arch × shape) cell under config variants.

Runs the full-module dry-run + body probes for a list of named config
overrides and prints the three roofline terms per variant, so each
hypothesis→change→measure iteration is one invocation (§Perf methodology).

Usage:
  PYTHONPATH=src python -m repro.launch.hillclimb --arch deepseek-coder-33b \
      --shape train_4k --variant baseline --variant chunked_attn ...
"""

import argparse
import dataclasses
import json
from pathlib import Path

import jax

from repro.configs import get_config
from repro.launch import dryrun as DR
from benchmarks.roofline import PEAK_FLOPS, HBM_BW, _coll_seconds, _merge_colls, fmt_seconds

VARIANTS = {
    "baseline": {},
    "chunked_attn": {"attn_impl": "chunked", "attn_chunk": 512},
    "chunked_attn_1k": {"attn_impl": "chunked", "attn_chunk": 1024},
    "seq_shard": {"attn_seq_shard": True},
    "seq_shard_chunked": {"attn_seq_shard": True, "attn_impl": "chunked", "attn_chunk": 512},
    "loss_chunk": {"loss_chunk": 512},
    "dots_remat": {"remat": "dots_saveable"},
    "no_remat": {"remat": "none"},
    "chunked_all": {
        "attn_impl": "chunked", "attn_chunk": 512, "attn_seq_shard": True, "loss_chunk": 512,
    },
    "seq_resid": {"attn_seq_shard": True, "seq_parallel_resid": True},
    "seq_resid_loss": {
        "attn_seq_shard": True, "seq_parallel_resid": True, "loss_chunk": 512,
    },
    "seq_resid_loss_chunked": {
        "attn_seq_shard": True, "seq_parallel_resid": True, "loss_chunk": 512,
        "attn_impl": "chunked", "attn_chunk": 1024,
    },
    "seq_resid_dots": {
        "attn_seq_shard": True, "seq_parallel_resid": True, "remat": "dots_saveable",
    },
    "seq_resid_norem": {
        "attn_seq_shard": True, "seq_parallel_resid": True, "remat": "none",
    },
    "moe_ep": {"moe_shard_dispatch": True},
    "moe_ep_seq_resid": {
        "moe_shard_dispatch": True, "attn_seq_shard": True, "seq_parallel_resid": True,
    },
    "moe_ep_seq_resid_cap1": {
        "moe_shard_dispatch": True, "attn_seq_shard": True, "seq_parallel_resid": True,
        "capacity_factor": 1.0,
    },
    "seq_resid_lc_norem": {
        "attn_seq_shard": True, "seq_parallel_resid": True, "loss_chunk": 512,
        "remat": "none",
    },
    "moe_grouped": {"moe_groups": 16},
    "moe_grouped_seq_resid": {
        "moe_groups": 16, "attn_seq_shard": True, "seq_parallel_resid": True,
    },
    "cap_tight": {"capacity_factor": 1.0},
    "cap_tight_chunked": {"capacity_factor": 1.0, "attn_impl": "chunked", "attn_chunk": 512},
}


def measure(arch: str, shape: str, overrides: dict, mesh_kind: str = "single"):
    from repro.launch.probe import probe_bodies
    from repro.launch.mesh import make_production_mesh
    from repro.models.model import abstract_params

    mod = DR.run_cell(arch, shape, mesh_kind, cfg_overrides=overrides)
    cfg = dataclasses.replace(
        get_config(arch), act_sharding=("data",), **overrides
    )
    mesh = make_production_mesh(multi_pod=False)
    bodies = probe_bodies(cfg, shape, mesh, abstract_params(cfg), DR._parse_collectives)

    flops = mod["flops"] or 0.0
    bytes_ = mod["bytes_accessed"] or 0.0
    colls = mod["collectives"]
    for b in bodies:
        app = 2 if (arch == "zamba2-7b" and b["name"].startswith("mamba")) else 1
        extra = b["trips"] - app
        for part in ("fwd", "bwd"):
            if part in b and extra > 0:
                flops += extra * b[part]["flops"]
                bytes_ += extra * b[part]["bytes"]
                colls = _merge_colls(colls, b[part]["collectives"], extra)
    return {
        "flops": flops,
        "bytes": bytes_,
        "colls": colls,
        "t_compute": flops / PEAK_FLOPS,
        "t_memory": bytes_ / HBM_BW,
        "t_collective": _coll_seconds(colls),
        "temp_gb": (mod["memory"]["temp_bytes"] or 0) / 1e9,
        "bodies": bodies,
        "module": mod,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", action="append", default=None)
    ap.add_argument("--out", default="experiments/hillclimb")
    args = ap.parse_args()
    variants = args.variant or ["baseline"]

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    print(f"{'variant':22s} {'compute':>10s} {'memory':>10s} {'collective':>11s} {'temp GB':>8s}")
    for name in variants:
        ov = VARIANTS[name]
        try:
            r = measure(args.arch, args.shape, ov)
        except Exception as e:  # noqa: BLE001
            print(f"{name:22s} FAILED: {repr(e)[:160]}")
            continue
        tag = f"{args.arch}_{args.shape}_{name}"
        (outdir / f"{tag}.json").write_text(
            json.dumps({k: v for k, v in r.items() if k != "module"} | {"module_mem": r["module"]["memory"]}, indent=2, default=float)
        )
        print(
            f"{name:22s} {fmt_seconds(r['t_compute']):>10s} {fmt_seconds(r['t_memory']):>10s} "
            f"{fmt_seconds(r['t_collective']):>11s} {r['temp_gb']:8.1f}"
        )


if __name__ == "__main__":
    main()
