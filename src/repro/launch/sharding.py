"""Sharding rules: parameter, batch, and cache PartitionSpecs per arch.

Scheme (megatron-style TP on the `model` axis + ZeRO/FSDP on the data axes):

  embed (V, D)                     -> (model, data)
  lm_head (D, V)                   -> (data, model)
  attn wq/wk/wv (…, D, H·hd)       -> (…, data, model)     head-sharded TP
  attn wo (…, H·hd, D)             -> (…, model, data)
  mlp w1/w3 (…, D, F)              -> (…, data, model)
  mlp w2 (…, F, D)                 -> (…, model, data)
  moe router (…, D, E)             -> (…, data, None)
  moe w1/w3 (…, E, D, F)           -> (…, model, data, None)   expert parallel
  moe w2 (…, E, F, D)              -> (…, model, None, data)
  mamba in/out projections         -> like mlp (d_inner on model)
  norms / biases / gates / scalars -> model on the channel dim where it is
                                       d_inner-sized, else replicated

`…` are the leading layer-stack axes (never sharded).  On the multi-pod mesh
the data axes are ('pod', 'data') so parameters/optimizer state shard over
all 512 chips.

Batch: (B, …) over the data axes.  Decode KV caches shard batch over data and
the *context* dim over model (context-parallel decode — always divisible,
unlike kv-head sharding with kv=8 on a 16-way axis).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def _param_rule(path: Tuple[str, ...], ndim: int, cfg: ModelConfig, d: Tuple[str, ...]):
    name = path[-1]
    parent = path[-2] if len(path) > 1 else ""
    in_moe = "moe" in path
    lead = lambda k: (None,) * (ndim - k)

    if name == "embed":
        return P("model", d)
    if name == "lm_head":
        return P(d, "model")
    if name in ("wq", "wk", "wv"):
        return P(*lead(2), d, "model")
    if name == "wo":
        return P(*lead(2), "model", d)
    if name in ("w1", "w3"):
        if in_moe and parent == "moe":  # (…, E, D, F)
            return P(*lead(3), "model", d, None)
        return P(*lead(2), d, "model")
    if name == "w2":
        if in_moe and parent == "moe":  # (…, E, F, D)
            return P(*lead(3), "model", None, d)
        return P(*lead(2), "model", d)
    if name == "router":
        return P(*lead(2), d, None)
    if name == "in_proj":
        return P(*lead(2), d, "model")
    if name == "out_proj":
        return P(*lead(2), "model", d)
    if name in ("conv_w",):
        return P(*lead(2), "model", None)
    if name in ("x_proj",):
        return P(*lead(2), "model", None)
    if name == "dt_proj":
        return P(*lead(2), None, "model")
    if name == "A_log" and cfg.ssm_version == 1:
        return P(*lead(2), "model", None)
    if name in ("conv_b", "dt_bias", "D_skip", "norm_scale", "A_log"):
        return P(*lead(1), "model")
    if name in ("bq", "bk", "bv"):
        return P(*lead(1), "model")
    # norms, gates, counters: replicated
    return P()


def param_pspecs(abstract_params, cfg: ModelConfig, mesh: Mesh):
    d = ("pod", "data") if "pod" in mesh.axis_names else "data"

    def rule(path, leaf):
        keys = tuple(
            k.key if hasattr(k, "key") else str(k) for k in path
        )
        return _param_rule(keys, leaf.ndim, cfg, d)

    return jax.tree_util.tree_map_with_path(rule, abstract_params)


def opt_pspecs(abstract_opt, param_specs):
    return {
        "m": param_specs,
        "v": param_specs,
        "count": P(),
    }


def batch_pspecs(batch_specs, mesh: Mesh, *, shard_batch: bool = True):
    d = ("pod", "data") if "pod" in mesh.axis_names else "data"

    def rule(path, leaf):
        if not shard_batch or leaf.shape[0] == 1:
            return P()
        return P(d, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(rule, batch_specs)


def cache_pspecs(cache_specs, cfg: ModelConfig, mesh: Mesh, batch_size: int):
    """Decode caches: batch over data (when divisible), context over model."""
    d = ("pod", "data") if "pod" in mesh.axis_names else "data"
    n_data = mesh.shape["data"] * (mesh.shape.get("pod", 1))
    bspec = d if batch_size % n_data == 0 and batch_size > 1 else None

    def rule(path, leaf):
        keys = tuple(k.key if hasattr(k, "key") else str(k) for k in path)
        name = keys[-1]
        if name == "len":
            return P()
        nd = leaf.ndim
        if name in ("k", "v", "attn_k", "attn_v", "img_k", "img_v"):
            # (L…, B, W, kv, hd): batch over data, context over model
            lead = nd - 4
            return P(*([None] * lead), bspec, "model", None, None)
        if name in ("ssm", "tail_ssm"):
            # (L…, B, H|DI, P?, N): batch over data, channel/head over model
            lead = nd - (4 if cfg.ssm_version == 2 else 3)
            if cfg.ssm_version == 2:
                return P(*([None] * lead), bspec, "model", None, None)
            return P(*([None] * lead), bspec, "model", None)
        if name in ("conv", "tail_conv"):
            # (L…, B, K-1, C): channel over model
            lead = nd - 3
            return P(*([None] * lead), bspec, None, "model")
        return P()

    return jax.tree_util.tree_map_with_path(rule, cache_specs)


def to_named(tree_of_pspecs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_of_pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
