"""Production mesh construction.

Single pod: 16×16 = 256 chips, axes (data, model).
Multi pod:  2×16×16 = 512 chips, axes (pod, data, model) — the pod axis is
the outer data-parallel axis (DCN-linked); params are sharded over
(pod, data) for ZeRO storage and gradients reduce over it.

Functions, not module constants: importing this module must never touch jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 (dryrun.py does this)"
        )
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh(shape: Tuple[int, ...] = (1, 1), axes=("data", "model")) -> Mesh:
    """Tiny mesh for CPU tests (1 device)."""
    return Mesh(np.asarray(jax.devices()[: int(np.prod(shape))]).reshape(shape), axes)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes that act as data parallel (pod joins data when present)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
