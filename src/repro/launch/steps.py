"""Step functions lowered by the dry-run and executed by the launchers.

  train_step(params, opt_state, batch)        -> (params, opt_state, metrics)
  prefill_step(params, batch)                 -> (last_logits, cache)
  serve_step(params, cache, batch)            -> (logits, cache)
  quantum_step(params, cache, tok)            -> (tokens, cache)
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import decode_step, loss_fn, prefill
from repro.optim.adamw import AdamWConfig, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)
        new_params, new_opt, metrics = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, batch):
        return prefill(params, cfg, batch, max_len)

    return prefill_step


def make_serve_step(cfg: ModelConfig, window: int = 0):
    def serve_step(params, cache, batch):
        logits, new_cache = decode_step(params, cfg, cache, batch, window=window)
        next_token = jnp.argmax(logits[:, -1], axis=-1)
        return {"logits": logits, "next_token": next_token}, new_cache

    return serve_step


def make_quantum_step(cfg: ModelConfig, window: int = 0, quantum: int = 8):
    """Greedy-decode ``quantum`` tokens in one jitted dispatch.

    Scans ``decode_step`` so a continuous-batching server amortises the
    host<->device round-trip over a whole decode quantum instead of paying
    it per token. Carry is ``(cache, last_token [B,1] i32)``; each scan
    step feeds the previous argmax back in and emits the next one.

        quantum_step(params, cache, tok)
            -> ({"tokens": [B, quantum] i32, "next_token": [B, 1] i32},
                cache)

    ``tokens[:, 0]`` is the token produced FROM ``tok`` — the caller is
    assumed to have already emitted ``tok`` itself (e.g. the prefill
    argmax).
    """

    def quantum_step(params, cache, tok):
        def body(carry, _):
            cache, prev = carry
            logits, cache = decode_step(
                params, cfg, cache, {"tokens": prev}, window=window
            )
            nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            return (cache, nxt), nxt[:, 0]

        (cache, tok), toks = jax.lax.scan(
            body, (cache, tok), None, length=quantum
        )
        return {"tokens": jnp.moveaxis(toks, 0, 1), "next_token": tok}, cache

    return quantum_step
