"""Step functions lowered by the dry-run and executed by the launchers.

  train_step(params, opt_state, batch)        -> (params, opt_state, metrics)
  prefill_step(params, batch)                 -> (last_logits, cache)
  serve_step(params, cache, batch)            -> (logits, cache)
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import decode_step, loss_fn, prefill
from repro.optim.adamw import AdamWConfig, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)
        new_params, new_opt, metrics = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, batch):
        return prefill(params, cfg, batch, max_len)

    return prefill_step


def make_serve_step(cfg: ModelConfig, window: int = 0):
    def serve_step(params, cache, batch):
        logits, new_cache = decode_step(params, cfg, cache, batch, window=window)
        next_token = jnp.argmax(logits[:, -1], axis=-1)
        return {"logits": logits, "next_token": next_token}, new_cache

    return serve_step
