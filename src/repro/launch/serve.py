"""Production serving launcher: batched prefill + decode.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.launch.tuned import apply_tuning
from repro.models.model import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=0)
    args = ap.parse_args()

    cfg = apply_tuning(get_reduced(args.arch) if args.reduced else get_config(args.arch))
    if cfg.embedding_inputs or cfg.family == "vlm":
        raise SystemExit(f"{args.arch}: frontend-stub arch — see examples/")

    params = init_params(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.gen + 8
    prefill_step = jax.jit(make_prefill_step(cfg, max_len=max_len))
    serve_step = jax.jit(make_serve_step(cfg, window=args.window))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
    )
    last, cache = prefill_step(params, {"tokens": prompts})
    tok = jnp.argmax(last[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    outs = [tok]
    for _ in range(args.gen - 1):
        out, cache = serve_step(params, cache, {"tokens": tok})
        tok = out["next_token"][:, None].astype(jnp.int32)
        outs.append(tok)
    dt = time.perf_counter() - t0
    print(
        f"{args.arch}: decoded {args.gen} tok x {args.batch} seqs in {dt*1e3:.0f} ms "
        f"({args.batch*args.gen/dt:.0f} tok/s)"
    )


if __name__ == "__main__":
    main()
