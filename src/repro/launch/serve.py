"""Production serving launcher: DFC request-queue tier + batched prefill/decode.

The sharded DFC fabric (``repro.runtime.dfc_shard``) is mounted as the
serving tier's REQUEST QUEUE — the ROADMAP's "request-queue tier" item:

  * session ids are the routing keys; an arriving session is ENQUEUED into
    its request shard, and each prefill round DEQUEUES up to ``--batch``
    sessions into the model batch;
  * the pool of free decode slots (KV-cache rows) is a LIFO **stack shard in
    the same fabric** — a heterogeneous fabric in production position:
    arrivals (queue enq) and slot releases (stack push) combine in ONE fused
    phase;
  * per-session serving state (priority, decode-slot binding, lifecycle
    stage) lives in a **map shard of the same fabric**: arrival inserts it,
    admission binds the slot with a fabric CAS, service marks it SERVED —
    so ``recover()`` returns queues, slot pool, and session table from one
    walk;
  * ``--priority`` (ISSUE 5) runs the request shards as DEQUES: a normal
    arrival joins the back of the line (``OP_PUSH_BACK``), admission drains
    the front (``OP_POP_FRONT``), and a high-priority session jumps the line
    with a front-of-queue push (``OP_PUSH_FRONT``).  Priority order lives in
    the fabric state itself, so it survives a crash/recover;
  * ``--durable`` runs the tier over the announce/combine persistence path
    (SimFS-backed) and reports pwb/op — the paper's Figure-3 metric at the
    serving tier; ``--depth D`` pipelines the durable path D chains deep;
  * ``--reshard-backlog N`` splits a request shard whose backlog exceeds N
    (crash-consistent: see ``ShardedDFCRuntime.split_shard``);
  * ``--state-dir`` + ``--crash-at K`` + ``--resume`` demo the paper's
    detectability story at the serving tier: the launcher crashes at the
    K-th persistence op, and a second invocation with ``--resume`` recovers
    the fabric, reconciles (served log ∪ queued sessions ∪ in-flight
    admissions), and finishes serving with no session lost or duplicated
    (``--expect-exactly-once`` asserts it; wired into CI).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 16 --gen 32 --sessions 12

Crash/resume demo (tier only, no model):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --tier-only \
      --durable --priority --sessions 8 --state-dir /tmp/dfc_serve \
      --crash-at 60 ; \
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --tier-only \
      --durable --priority --sessions 8 --state-dir /tmp/dfc_serve \
      --resume --expect-exactly-once
"""

from __future__ import annotations

import argparse
import tempfile
import time
from collections import deque
from pathlib import Path
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint.dfc_checkpoint import CrashNow, FaultInjector, SimFS
from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.launch.tuned import apply_tuning
from repro.core.jax_dfc import (
    CAS_DOM,
    OP_DEQ,
    OP_ENQ,
    OP_MAP_CAS,
    OP_MAP_INSERT,
    OP_MAP_LOOKUP,
    OP_POP,
    OP_POP_FRONT,
    OP_PUSH,
    OP_PUSH_BACK,
    OP_PUSH_FRONT,
    R_CAS_FAIL,
    R_VALUE,
    pack_cas,
)
from repro.runtime.dfc_shard import (
    _HASH_MULT,
    R_OVERFLOW,
    ShardedDFCRuntime,
    weighted_dequeue_plan,
)


# ------------------------------------------------- session-state map packing
# The tier keeps per-session serving state (priority class, decode-slot
# binding, lifecycle stage) in a MAP SHARD of the same fabric, one entry per
# session.  The packed value fits in 12 bits so a whole-state swap rides a
# single fabric CAS (``pack_cas`` needs both sides < CAS_DOM):
#
#   bits 10..11  priority class (0 = lowest; the binary ``priority=True``
#                tier uses classes 0/1, ``k_classes=k`` uses 0..k-1, k <= 4)
#   bits 3..9    decode slot binding (SESSION_SLOT_NONE = unbound)
#   bits 0..2    stage: QUEUED -> ADMITTED -> SERVED
SESSION_QUEUED, SESSION_ADMITTED, SESSION_SERVED = 1, 2, 3
SESSION_STAGE_DOM = 8
SESSION_SLOT_DOM = 128
SESSION_CLASS_DOM = 4
SESSION_MAX_CLASSES = SESSION_CLASS_DOM
SESSION_SLOT_NONE = SESSION_SLOT_DOM - 1
# Decode PROGRESS (tokens emitted so far) rides a SECOND map entry per
# session, tagged by value range so the recovery walk separates the two
# without a side table: state entries are < CAS_DOM, progress entries are
# stored as PROGRESS_TAG + tokens (tokens < PROGRESS_MAX keeps the stored
# value inside f32's contiguous-integer range, like the CAS packing).
PROGRESS_TAG = CAS_DOM
PROGRESS_MAX = CAS_DOM * CAS_DOM - PROGRESS_TAG
# Each session owns the key window [sid * stride, (sid + 1) * stride): its
# state key is the FIRST window key routing to the session shard and its
# progress key the SECOND, so map keys are unique BY CONSTRUCTION (windows
# are disjoint) and the recovery walk inverts them: sid = key // stride.
_SESSION_KEY_STRIDE = 64


def pack_session(cls: int, slot: int, stage: int) -> int:
    """Pack (priority class, slot, stage) into one CAS-swappable map value.

    Out-of-range fields used to wrap modulo into ANOTHER session's fields
    with no error; every field is now validated, and the packed value is
    asserted to stay inside the CAS packing domain (< CAS_DOM, so a
    state swap packs f32-exactly through ``pack_cas``).
    """
    cls, slot, stage = int(cls), int(slot), int(stage)
    if not 0 <= cls < SESSION_CLASS_DOM:
        raise ValueError(
            f"priority class {cls} outside [0, {SESSION_CLASS_DOM})"
        )
    if not 0 <= slot < SESSION_SLOT_DOM:
        raise ValueError(f"decode slot {slot} outside [0, {SESSION_SLOT_DOM})")
    if not 0 <= stage < SESSION_STAGE_DOM:
        raise ValueError(f"stage {stage} outside [0, {SESSION_STAGE_DOM})")
    packed = cls * (SESSION_SLOT_DOM * SESSION_STAGE_DOM) + slot * SESSION_STAGE_DOM + stage
    assert packed < CAS_DOM, (cls, slot, stage)  # CAS-swappable by design
    return packed


def unpack_session(packed) -> Dict[str, int]:
    p = int(packed)
    if not 0 <= p < CAS_DOM:
        raise ValueError(f"packed session state {p} outside [0, {CAS_DOM})")
    cls = p // (SESSION_SLOT_DOM * SESSION_STAGE_DOM)
    return {
        "cls": cls,
        # legacy binary view: any class above the lowest counts as priority
        "priority": 1 if cls > 0 else 0,
        "slot": (p // SESSION_STAGE_DOM) % SESSION_SLOT_DOM,
        "stage": p % SESSION_STAGE_DOM,
    }


class RequestQueueTier:
    """Session admission over a heterogeneous DFC fabric.

    ``n_queues`` request shards (FIFO queues, or DEQUES when
    ``priority=True``) plus ONE stack shard (the free-slot pool) plus ONE
    map shard (per-session serving state: priority, decode-slot binding,
    lifecycle stage) behind a single router.  Bucket 0 of the routing table
    is pinned to the pool shard and every fourth bucket to the session
    shard; session ids are deterministically re-probed away from both, so
    every session key lands on a request shard.  All tier traffic —
    arrivals, slot pops, dequeues, releases, session-state updates — flows
    through the fabric's fused combine, volatile (``step``) or durable
    (``announce`` / ``combine_phase``), and a recovered tier restores
    queues, pool, and session table from one fabric walk.

    Priority admission (``priority=True``): ``submit`` takes a parallel
    ``priorities`` list; a session with priority > 0 is pushed at the FRONT
    of its request deque and therefore dequeues ahead of the whole backlog
    (high-priority sessions are LIFO among themselves — the latest urgent
    arrival is the most urgent).  Because the order is fabric state, it is
    exactly as durable as the queue contents: a recovered tier admits the
    same sessions in the same order.

    k-class admission (``k_classes=k``, 2 <= k <= ``SESSION_MAX_CLASSES``):
    the tier generalizes the binary front-of-line path to ``k`` PRIORITY
    CLASSES, one request queue shard per class (shard ``c`` <-> class ``c``).
    ``submit`` takes a parallel ``classes`` list; arrivals enqueue FIFO into
    their class shard, and ``admit`` dequeues by WEIGHTED round-robin across
    the backlogged class shards (``weighted_dequeue_plan``): class ``c``
    holds ``class_weights[c]`` dequeue credits per cycle, unused credits
    fall through to the next backlogged class, and a backlogged class is
    never passed over for more than ``sum(weights) - weights[c]``
    consecutive admissions — the provable starvation bound the serving
    benchmark gates on (``starvation_bound()``).  Per-class FIFO order is
    fabric state and survives crash/recover; the weighted-cycle CURSOR is
    host scheduling state and restarts at the cycle head on recovery (the
    bound holds within each run's admission stream).
    """

    def __init__(
        self,
        n_queues: int = 4,
        slots: int = 4,
        *,
        capacity: int = 4096,
        lanes: int = 64,
        durable: bool = False,
        fs: Optional[SimFS] = None,
        reshard_backlog: Optional[int] = None,
        n_buckets: Optional[int] = None,
        pipeline: bool = False,
        depth: Optional[int] = None,
        priority: bool = False,
        k_classes: int = 0,
        class_weights: Optional[Sequence[int]] = None,
        split_lanes: bool = False,
        obs=None,
        _seed_slots: bool = True,
        _rt: Optional[ShardedDFCRuntime] = None,
    ):
        if k_classes and k_classes >= 2:
            if priority:
                raise ValueError(
                    "k_classes generalizes priority=True; pick one"
                )
            if k_classes > SESSION_MAX_CLASSES:
                raise ValueError(
                    f"k_classes={k_classes} exceeds the packed class field "
                    f"(SESSION_MAX_CLASSES={SESSION_MAX_CLASSES})"
                )
            if reshard_backlog is not None:
                raise ValueError(
                    "k_classes pins shard c to class c; autosplit would "
                    "break the mapping (reshard_backlog must be None)"
                )
            n_queues = k_classes  # shard c == class c
            self.k_classes = k_classes
            self.class_weights = (
                [int(w) for w in class_weights]
                if class_weights is not None
                else [1 << c for c in range(k_classes)]
            )
            if len(self.class_weights) != k_classes or any(
                w < 1 for w in self.class_weights
            ):
                raise ValueError(
                    f"class_weights must be k_classes={k_classes} ints >= 1, "
                    f"got {class_weights}"
                )
        else:
            if class_weights is not None:
                raise ValueError("class_weights needs k_classes >= 2")
            self.k_classes = 0
            self.class_weights = []
        self._class_cursor = 0
        # (sid, class) per admission, in admission order — the starvation
        # gate's witness (k-class tiers only)
        self.admit_log: List[Tuple[int, int]] = []
        if slots > SESSION_SLOT_NONE:
            raise ValueError(
                f"slots={slots} exceeds the packed slot field "
                f"(max {SESSION_SLOT_NONE}: id {SESSION_SLOT_NONE} is the "
                f"unbound sentinel)"
            )
        req_kind = "deque" if priority else "queue"
        kinds = [req_kind] * n_queues + ["stack", "map"]
        n_shards = n_queues + 2
        n_buckets = n_buckets or 4 * n_shards
        self.n_queues = n_queues
        self.pool_shard = n_queues
        self.session_shard = n_queues + 1
        self.priority = priority
        if durable and fs is None:
            fs = SimFS(Path(tempfile.mkdtemp(prefix="dfc_serve_tier_")))
        self.durable = durable
        self.pipeline = pipeline or (depth or 1) > 1
        # per-side combiners (``split_lanes=True``): arrivals (enqueues /
        # back-pushes) ride each request shard's TAIL lane while admission
        # pops ride its HEAD lane, each with its own epoch and commit — the
        # op->lane routing in the runtime makes this automatic
        self.split_lanes = split_lanes
        # ``_rt`` lets ``recover`` mount an already-recovered fabric instead
        # of building a throwaway one just to replace it
        self.rt = _rt if _rt is not None else ShardedDFCRuntime(
            kinds, n_shards, capacity, lanes,
            fs=fs if durable else None, n_threads=1,
            n_buckets=n_buckets,
            table=self._default_table(
                n_queues, n_buckets, k_classes=bool(self.k_classes)
            ),
            pipeline=pipeline, depth=depth,
            split_lanes=split_lanes,
            obs=obs,
        )
        # the tier and the fabric share ONE observer: per-request lifecycle
        # spans (arrive -> admit -> served) land in the same timeline as the
        # durable-path events, and admission latency histograms live in the
        # same registry as the per-shard gauges
        self.obs = obs if obs is not None else self.rt.obs
        self._arrival_t: Dict[int, float] = {}  # sid -> arrival perf_counter
        self._admit_t: Dict[int, float] = {}  # sid -> admission perf_counter
        self.reshard_backlog = reshard_backlog
        self._rep_keys: Dict[int, int] = {}
        self._smap_keys: Dict[int, int] = {}  # sid -> session-state map key
        self._sprog_keys: Dict[int, int] = {}  # sid -> decode-progress map key
        self._slot_retry: List[int] = []  # pool pushes that overflowed a phase
        # session-state writes that overflowed the map shard's lanes, retried
        # on the next submit: (sid, packed) pairs
        self._state_retry: List[Tuple[int, int]] = []
        # host mirrors of the session map (rebuilt from the fabric walk on
        # recovery) — caches, never the source of truth
        self._session_prio: Dict[int, int] = {}
        self._session_slot: Dict[int, int] = {}
        self._token = 0
        self.stats = {"arrived": 0, "admitted": 0, "rejected": 0, "splits": 0}
        if _seed_slots:
            # seed the slot pool (submit chunks pushes to the pool's lanes)
            self.submit([], release_slots=list(range(slots)))
            while self._slot_retry:
                self.submit([])

    # ------------------------------------------------------------ internals
    @staticmethod
    def _default_table(
        n_queues: int, n_buckets: int, k_classes: bool = False
    ) -> np.ndarray:
        """Bucket 0 -> pool stack (shard ``n_queues``); every fourth bucket
        after it -> session map (shard ``n_queues + 1``, a ~1/4 share so the
        per-session key-window probe in ``session_map_key`` converges in a
        few steps); the rest round-robin over the request shards.

        k-class tiers round-robin over the SURVIVING buckets instead of
        ``b % n_queues``: when ``n_queues`` divides 4 the session map's
        ``b % 4 == 1`` buckets alias an entire residue class, which would
        leave that class shard unroutable."""
        pool, smap = n_queues, n_queues + 1
        if not k_classes:
            return np.asarray(
                [pool]
                + [
                    smap if b % 4 == 1 else b % n_queues
                    for b in range(1, n_buckets)
                ],
                np.int32,
            )
        out, nxt = [pool], 0
        for b in range(1, n_buckets):
            if b % 4 == 1:
                out.append(smap)
            else:
                out.append(nxt % n_queues)
                nxt += 1
        return np.asarray(out, np.int32)

    def _key_for(self, shard: int) -> int:
        if shard not in self._rep_keys:
            self._rep_keys[shard] = self.rt.key_for_shard(shard)
        return self._rep_keys[shard]

    def _phase(self, keys, ops, params) -> Tuple[np.ndarray, np.ndarray]:
        """One tier phase: fused volatile step, or announce+combine+read.

        The durable path goes through the fabric's announcement RING: the
        payload lands in the preallocated device ring at ``announce`` and
        the combining phase consumes it there — SimFS only carries the
        compact durable mirror.  The tier needs each phase's responses
        synchronously (admission decisions), so it flushes any in-flight
        chains right after dispatch; the ring fast path and the per-batch
        commit schedule are identical at every depth.
        """
        if not self.durable:
            resp, kinds = self.rt.step(keys, ops, params)
            return np.asarray(resp), np.asarray(kinds)
        self._token += 1
        self.rt.announce(0, keys, ops, params, token=self._token)
        self.rt.combine_phase()
        self.rt.flush()
        val = self.rt.read_responses(0, token=self._token)
        return np.asarray(val["resp"]), np.asarray(val["kinds"])

    def session_key(self, sid: int) -> int:
        """Deterministic key for a session id, re-probed off the pool and
        session-map shards (so the id stays the key in spirit; collisions
        with their buckets hop)."""
        if not 0 <= sid < (1 << 24):
            # sids round-trip through the fabric's float32 values; past the
            # f32 mantissa two sessions would silently collide
            raise ValueError(f"session id {sid} must be in [0, 2^24)")
        k = int(sid)
        while int(self.rt.route_host([k])[0]) in (
            self.pool_shard, self.session_shard,
        ):
            k = (k * _HASH_MULT + 1) % (1 << 31)
        return k

    def _session_window_keys(self, sid: int, need: int = 2) -> List[int]:
        """The first ``need`` keys of ``sid``'s private window
        ``[sid * 64, (sid + 1) * 64)`` that route to the session shard.
        Windows are disjoint, so two sessions can never collide on a map key
        (unlike a rehash chain, whose orbits can merge), and the recovery
        walk inverts the encoding: ``sid = key // 64``."""
        base = int(sid) * _SESSION_KEY_STRIDE
        cand = np.arange(base, base + _SESSION_KEY_STRIDE, dtype=np.int64)
        hit = np.nonzero(self.rt.route_host(cand) == self.session_shard)[0]
        if hit.size < need:  # P ~ binom tail at a ~1/4 share over 64 keys
            raise RuntimeError(
                f"only {hit.size} keys in window [{base}, "
                f"{base + _SESSION_KEY_STRIDE}) route to the session map "
                f"shard (need {need}); widen its bucket share"
            )
        return [int(cand[h]) for h in hit[:need]]

    def session_map_key(self, sid: int) -> int:
        """Unique fabric key addressing ``sid``'s session-STATE map entry:
        the first key in the session's private window routing to the
        session shard."""
        if sid not in self._smap_keys:
            self._smap_keys[sid] = self._session_window_keys(sid)[0]
        return self._smap_keys[sid]

    def session_progress_key(self, sid: int) -> int:
        """Unique fabric key addressing ``sid``'s decode-PROGRESS map entry:
        the second window key routing to the session shard (the entry's
        value is tagged ``PROGRESS_TAG + tokens``, so the recovery walk
        separates state from progress by value range alone)."""
        if sid not in self._sprog_keys:
            self._sprog_keys[sid] = self._session_window_keys(sid)[1]
        return self._sprog_keys[sid]

    def _smap_write_key(self, sid: int, packed: int) -> int:
        """Map key for a staged session write: progress entries (tagged
        values) go to the progress key, state entries to the state key."""
        if packed >= PROGRESS_TAG:
            return self.session_progress_key(sid)
        return self.session_map_key(sid)

    def _stage_session_writes(
        self, sids: Sequence[int], cls_list: Sequence[int]
    ) -> List[Tuple[int, int]]:
        """Arrival-time session-state map inserts (plus retries from earlier
        phases), capped at the map shard's per-phase lanes — every write
        targets the ONE session shard, so at most ``lanes`` fit per phase.
        Retried arrivals whose session already advanced past QUEUED (its
        slot got bound meanwhile) are dropped instead of regressing it;
        retried PROGRESS entries (tagged values) always pass through."""
        writes = [
            (sid, packed)
            for sid, packed in self._state_retry
            if packed >= PROGRESS_TAG
            or unpack_session(packed)["stage"] != SESSION_QUEUED
            or sid not in self._session_slot
        ]
        for s, c in zip(sids, cls_list):
            self._session_prio[int(s)] = int(c)
            writes.append(
                (int(s), pack_session(int(c), SESSION_SLOT_NONE, SESSION_QUEUED))
            )
        self._state_retry = writes[self.rt.lanes:]
        return writes[: self.rt.lanes]

    def _arrival_classes(
        self,
        sids: Sequence[int],
        priorities: Optional[Sequence[int]],
        classes: Optional[Sequence[int]],
    ) -> List[int]:
        """Validate + normalize per-arrival class labels for every tier
        flavor: FIFO -> all zero, binary priority -> 0/1 from
        ``priorities``, k-class -> ``classes`` in [0, k)."""
        if priorities is not None and not self.priority:
            raise ValueError("priorities given but tier built without priority=True")
        if priorities is not None and len(priorities) != len(sids):
            raise ValueError(
                f"priorities ({len(priorities)}) must parallel sids ({len(sids)})"
            )
        if classes is not None and not self.k_classes:
            raise ValueError("classes given but tier built without k_classes")
        if self.k_classes:
            cls = list(classes) if classes is not None else [0] * len(sids)
            if len(cls) != len(sids):
                raise ValueError(
                    f"classes ({len(cls)}) must parallel sids ({len(sids)})"
                )
            for c in cls:
                if not 0 <= int(c) < self.k_classes:
                    raise ValueError(
                        f"class {c} outside [0, {self.k_classes})"
                    )
            return [int(c) for c in cls]
        if self.priority:
            pr = list(priorities) if priorities is not None else [0] * len(sids)
            return [1 if p > 0 else 0 for p in pr]
        return [0] * len(sids)

    def _queue_backlogs(self) -> Dict[int, int]:
        """Committed backlog per request shard, straight from the fabric's
        active root counters (no host-side shadow accounting to drift)."""
        sizes = self.rt.shard_sizes()
        return {
            s: int(sizes[s])
            for s in range(self.rt.n_shards)
            if self.rt.kinds[s] in ("queue", "deque")
        }

    # ------------------------------------------------------------- tier API
    def submit(
        self,
        sids: Sequence[int],
        release_slots: Sequence[int] = (),
        priorities: Optional[Sequence[int]] = None,
        classes: Optional[Sequence[int]] = None,
    ) -> List[int]:
        """Enqueue arriving sessions and return freed decode slots to the
        pool — one mixed-kind combined phase.  Returns session ids that
        overflowed their shard's lanes (re-submit next round).

        ``priorities[i] > 0`` (priority tier only) pushes session ``i`` at
        the FRONT of its request deque, ahead of the whole backlog.
        ``classes[i]`` (k-class tier only) enqueues session ``i`` FIFO into
        its priority-class shard (class 0 = lowest).

        Pool pushes all route to the single pool shard, so at most ``lanes``
        of them fit per phase; the surplus — and any push the fabric rejects
        with R_OVERFLOW — is carried in ``_slot_retry`` and retried on the
        next submit, so a decode slot can never leak."""
        cls_list = self._arrival_classes(sids, priorities, classes)
        pool = self._slot_retry + list(release_slots)
        self._slot_retry = pool[self.rt.lanes :]
        pool = pool[: self.rt.lanes]
        smap = self._stage_session_writes(sids, cls_list)
        if self.k_classes:
            keys = [self._key_for(c) for c in cls_list]  # shard c == class c
        else:
            keys = [self.session_key(s) for s in sids]
        keys += [self._key_for(self.pool_shard)] * len(pool)
        keys += [self._smap_write_key(sid, v) for sid, v in smap]
        if self.priority:
            enq_ops = [
                OP_PUSH_FRONT if c > 0 else OP_PUSH_BACK for c in cls_list
            ]
        else:
            enq_ops = [OP_ENQ] * len(sids)
        ops = enq_ops + [OP_PUSH] * len(pool) + [OP_MAP_INSERT] * len(smap)
        params = [float(s) for s in sids] + [float(s) for s in pool]
        params += [float(v) for _, v in smap]
        if not ops:
            return []
        now = time.perf_counter()
        for s in sids:  # first-arrival timestamp survives overflow retries
            self._arrival_t.setdefault(int(s), now)
        resp, kinds = self._phase(keys, ops, params)
        rejected = [s for i, s in enumerate(sids) if kinds[i] == R_OVERFLOW]
        for j, slot in enumerate(pool):
            if kinds[len(sids) + j] == R_OVERFLOW:
                self._slot_retry.append(slot)
        off = len(sids) + len(pool)
        for j, (sid, packed) in enumerate(smap):
            if kinds[off + j] == R_OVERFLOW:
                self._state_retry.append((sid, packed))
        self.stats["arrived"] += len(sids)
        self.stats["rejected"] += len(rejected)
        if self.obs.enabled and sids:
            self.obs.event(
                "request",
                stage="arrive",
                sids=[int(s) for s in sids],
                rejected=[int(s) for s in rejected],
            )
        self._maybe_split()
        return rejected

    def submit_waves(
        self,
        waves: Sequence[
            Tuple[Sequence[int], Sequence[int], Optional[Sequence[int]]]
        ],
    ) -> List[List[int]]:
        """Commit MANY submit rounds in ONE fused device dispatch — the tier
        riding the fabric's K-phase ``phase_loop``.

        ``waves`` is a sequence of ``(sids, release_slots, priorities)``
        rounds (``priorities`` may be ``None``); each wave becomes one
        combining phase of the fused schedule, with the same durable
        schedule, commit order, and pwb/pfence counts as that many
        ``submit`` calls — but the device combines the whole arrival replay
        in a single dispatch and the host drains the persist intents behind
        it.  Volatile tiers fall back to one fused ``step`` per wave.
        Returns the per-wave rejected session ids (re-submit next round).

        Slot-pool retries discovered by wave j's responses are carried in
        ``_slot_retry`` and re-pushed by the NEXT ``submit``/``submit_waves``
        call, exactly like the per-round path — they cannot join a later
        wave of this schedule, which was already committed device-side.
        """
        staged = []
        for wave in waves:
            # (sids, release_slots, priorities[, classes]) — the optional
            # fourth element labels k-class arrivals, mirroring ``submit``
            sids, release_slots, priorities = wave[0], wave[1], wave[2]
            classes = wave[3] if len(wave) > 3 else None
            cls_list = self._arrival_classes(sids, priorities, classes)
            pool = self._slot_retry + list(release_slots)
            self._slot_retry = pool[self.rt.lanes:]
            pool = pool[: self.rt.lanes]
            smap = self._stage_session_writes(sids, cls_list)
            if self.k_classes:
                keys = [self._key_for(c) for c in cls_list]
            else:
                keys = [self.session_key(s) for s in sids]
            keys += [self._key_for(self.pool_shard)] * len(pool)
            keys += [self._smap_write_key(sid, v) for sid, v in smap]
            if self.priority:
                enq_ops = [
                    OP_PUSH_FRONT if c > 0 else OP_PUSH_BACK for c in cls_list
                ]
            else:
                enq_ops = [OP_ENQ] * len(sids)
            ops = enq_ops + [OP_PUSH] * len(pool) + [OP_MAP_INSERT] * len(smap)
            params = [float(s) for s in sids] + [float(s) for s in pool]
            params += [float(v) for _, v in smap]
            now = time.perf_counter()
            for s in sids:
                self._arrival_t.setdefault(int(s), now)
            staged.append((list(sids), pool, smap, keys, ops, params))

        # one phase per non-empty wave, the whole schedule in one dispatch
        rejected_per_wave: List[List[int]] = [[] for _ in staged]
        live = [i for i, st in enumerate(staged) if st[4]]
        if live:
            if self.durable:
                schedule = []
                for i in live:
                    _, _, _, keys, ops, params = staged[i]
                    self._token += 1
                    schedule.append((0, self._token, keys, ops, params))
                records = self.rt.phase_loop(schedule)
                kinds_per_wave = [np.asarray(r["kinds"]) for r in records]
            else:
                kinds_per_wave = []
                for i in live:
                    _, _, _, keys, ops, params = staged[i]
                    _, kinds = self.rt.step(keys, ops, params)
                    kinds_per_wave.append(np.asarray(kinds))
            for i, kinds in zip(live, kinds_per_wave):
                sids, pool, smap, _, _, _ = staged[i]
                rejected = [
                    s for j, s in enumerate(sids) if kinds[j] == R_OVERFLOW
                ]
                for j, slot in enumerate(pool):
                    if kinds[len(sids) + j] == R_OVERFLOW:
                        self._slot_retry.append(slot)
                off = len(sids) + len(pool)
                for j, (sid, packed) in enumerate(smap):
                    if kinds[off + j] == R_OVERFLOW:
                        self._state_retry.append((sid, packed))
                self.stats["arrived"] += len(sids)
                self.stats["rejected"] += len(rejected)
                rejected_per_wave[i] = rejected
                if self.obs.enabled and sids:
                    self.obs.event(
                        "request",
                        stage="arrive",
                        wave=i,
                        sids=[int(s) for s in sids],
                        rejected=[int(s) for s in rejected],
                    )
        self._maybe_split()
        return rejected_per_wave

    def admit(self, max_n: int) -> List[Tuple[int, int]]:
        """Admit up to ``max_n`` sessions: pop free slots from the pool
        stack, then dequeue that many sessions from the backlogged request
        shards — round-robin on FIFO/priority tiers (front-of-queue on
        priority tiers: ``OP_POP_FRONT`` and ``OP_DEQ`` share op code 2),
        WEIGHTED round-robin across the class shards on k-class tiers
        (``weighted_dequeue_plan``; the cycle cursor persists across calls,
        so the starvation bound spans admissions, not just one batch).
        Returns ``[(session_id, slot), ...]``."""
        if max_n <= 0:
            return []
        pool_key = self._key_for(self.pool_shard)
        resp, kinds = self._phase(
            [pool_key] * max_n, [OP_POP] * max_n, [0.0] * max_n
        )
        slots = [int(resp[i]) for i in range(max_n) if kinds[i] == R_VALUE]
        if not slots:
            return []
        deqs: List[Tuple[int, int]] = []  # (shard, representative key)
        budget = self._queue_backlogs()
        if self.k_classes:
            plan, self._class_cursor = weighted_dequeue_plan(
                [budget.get(c, 0) for c in range(self.k_classes)],
                self.class_weights,
                len(slots),
                self._class_cursor,
            )
            deqs = [(c, self._key_for(c)) for c in plan]
        else:
            while len(deqs) < len(slots):
                ready = [s for s, n in sorted(budget.items()) if n > 0]
                if not ready:
                    break
                for s in ready:
                    if len(deqs) >= len(slots):
                        break
                    deqs.append((s, self._key_for(s)))
                    budget[s] -= 1
        if not deqs:
            self.submit([], release_slots=slots)  # nothing queued: put back
            return []
        deq_op = OP_POP_FRONT if self.priority else OP_DEQ
        resp, kinds = self._phase(
            [k for _, k in deqs], [deq_op] * len(deqs), [0.0] * len(deqs)
        )
        admitted: List[Tuple[int, int]] = []
        # deque, not list: popping the head of a list is O(n) and made the
        # admission drain O(n^2) in the batch size
        spare = deque(slots)
        for i, (shard, _) in enumerate(deqs):
            if kinds[i] == R_VALUE:
                admitted.append((int(resp[i]), spare.popleft()))
                if self.k_classes:
                    self.admit_log.append((int(resp[i]), shard))
        if spare:
            self.submit([], release_slots=list(spare))
        self._bind_sessions(admitted)
        self.stats["admitted"] += len(admitted)
        if self.obs.enabled and admitted:
            now = time.perf_counter()
            for sid, slot in admitted:
                t_arr = self._arrival_t.get(sid)
                self._admit_t[sid] = now
                if t_arr is not None:
                    self.obs.metrics.observe(
                        "admission_ms", (now - t_arr) * 1e3
                    )
            self.obs.event(
                "request",
                stage="admit",
                pairs=[[int(s), int(sl)] for s, sl in admitted],
            )
        return admitted

    def _bind_sessions(self, pairs: List[Tuple[int, int]]) -> None:
        """Bind decode slots at admission: QUEUED -> ADMITTED via fabric CAS
        on the session map.  A CAS that loses (stale host mirror) reveals the
        current packed state in its failure response; that — and a missing
        entry (the arrival insert overflowed and has not retried yet) — falls
        back to one plain insert of the exact new state, so the update always
        converges in at most two phases."""
        if not pairs:
            return
        expect = {}
        for sid, slot in pairs:
            self._session_slot[sid] = slot
            expect[sid] = pack_session(
                self._session_prio.get(sid, 0), SESSION_SLOT_NONE, SESSION_QUEUED
            )
        keys = [self.session_map_key(sid) for sid, _ in pairs]
        params = [
            pack_cas(
                expect[sid],
                pack_session(
                    self._session_prio.get(sid, 0), slot, SESSION_ADMITTED
                ),
            )
            for sid, slot in pairs
        ]
        resp, kinds = self._phase(keys, [OP_MAP_CAS] * len(pairs), params)
        fallback = []
        for j, (sid, slot) in enumerate(pairs):
            if kinds[j] == R_CAS_FAIL:
                self._session_prio[sid] = unpack_session(resp[j])["priority"]
                fallback.append((sid, slot))
            elif kinds[j] != R_VALUE:  # R_EMPTY / R_OVERFLOW
                fallback.append((sid, slot))
        if fallback:
            keys = [self.session_map_key(sid) for sid, _ in fallback]
            packs = [
                pack_session(self._session_prio.get(sid, 0), slot, SESSION_ADMITTED)
                for sid, slot in fallback
            ]
            _, kinds = self._phase(
                keys, [OP_MAP_INSERT] * len(fallback), [float(p) for p in packs]
            )
            for j, (sid, _) in enumerate(fallback):
                if kinds[j] == R_OVERFLOW:
                    self._state_retry.append((sid, packs[j]))

    def session_state(self, sid: int) -> Optional[Dict[str, int]]:
        """Read one session's committed state THROUGH the fabric (a combined
        ``OP_MAP_LOOKUP``, not a host walk): ``{"priority", "slot", "stage"}``
        or ``None`` when the session has no entry."""
        resp, kinds = self._phase(
            [self.session_map_key(sid)], [OP_MAP_LOOKUP], [0.0]
        )
        if kinds[0] == R_VALUE:
            return unpack_session(resp[0])
        return None

    def session_states(self) -> Dict[int, Dict[str, int]]:
        """Committed session-state table, decoded from one walk of the
        session map shard: ``{sid: {"cls", "priority", "slot", "stage"}}``
        (progress entries share the shard but are value-tagged, so the walk
        filters them out by range)."""
        return {
            int(k) // _SESSION_KEY_STRIDE: unpack_session(v)
            for k, v in self.rt.shard_contents(self.session_shard)
            if int(v) < PROGRESS_TAG
        }

    def session_progress_table(self) -> Dict[int, int]:
        """Committed decode progress (tokens emitted) per session, from the
        SAME walk of the session map shard: ``{sid: tokens}``."""
        return {
            int(k) // _SESSION_KEY_STRIDE: int(v) - PROGRESS_TAG
            for k, v in self.rt.shard_contents(self.session_shard)
            if int(v) >= PROGRESS_TAG
        }

    def record_progress(self, progress: Mapping[int, int]) -> None:
        """Commit per-session decode progress through the fabric — ONE
        combined phase for the whole batch of ``{sid: tokens_emitted}``
        updates (the continuous-batching loop calls this once per round).
        Entries are plain tagged inserts at each session's progress key;
        writes past the map shard's lanes or rejected with R_OVERFLOW are
        carried in the session-write retry queue."""
        items = [(int(sid), int(tok)) for sid, tok in sorted(progress.items())]
        for sid, tok in items:
            if not 0 <= tok < PROGRESS_MAX:
                raise ValueError(
                    f"progress {tok} for session {sid} outside "
                    f"[0, {PROGRESS_MAX})"
                )
        writes = [(sid, PROGRESS_TAG + tok) for sid, tok in items]
        overflow, writes = writes[self.rt.lanes:], writes[: self.rt.lanes]
        self._state_retry.extend(overflow)
        if not writes:
            return
        keys = [self.session_progress_key(sid) for sid, _ in writes]
        _, kinds = self._phase(
            keys, [OP_MAP_INSERT] * len(writes), [float(v) for _, v in writes]
        )
        for j, (sid, v) in enumerate(writes):
            if kinds[j] == R_OVERFLOW:
                self._state_retry.append((sid, v))

    def starvation_bound(self) -> int:
        """Max number of OTHER-class admissions between two consecutive
        admissions of the backlogged LOWEST class: ``sum(w) - w[0]``
        (see ``weighted_dequeue_plan``).  k-class tiers only."""
        if not self.k_classes:
            raise ValueError("starvation_bound needs a k_classes tier")
        return sum(self.class_weights) - self.class_weights[0]

    def backlog(self) -> int:
        return sum(self._queue_backlogs().values())

    def queued_sessions(self) -> List[int]:
        """Session ids currently committed in the request shards, in
        admission order per shard (front first) — what a resumed launcher
        reconciles against."""
        out: List[int] = []
        for s in range(self.rt.n_shards):
            if self.rt.kinds[s] in ("queue", "deque"):
                out.extend(int(v) for v in self.rt.shard_contents(s))
        return out

    def pool_slots(self) -> List[int]:
        """Free decode slots committed in the pool stack."""
        return [int(v) for v in self.rt.shard_contents(self.pool_shard)]

    def _maybe_split(self) -> None:
        """Split the hottest request shard when its backlog crosses the
        threshold (crash-consistent; new shard inherits half the buckets)."""
        if self.reshard_backlog is None:
            return
        backlogs = self._queue_backlogs()
        hot = max(backlogs, key=backlogs.get)
        if backlogs[hot] < self.reshard_backlog:
            return
        try:
            self.rt.split_shard(hot)
        except ValueError:
            return  # no spare bucket left on this shard
        self._rep_keys.clear()  # table changed: representative keys stale
        self._smap_keys.clear()
        self._sprog_keys.clear()
        self.stats["splits"] += 1

    def persistence_stats(self) -> Optional[Dict[str, float]]:
        if not self.durable:
            return None
        ops = max(self.stats["arrived"] + self.stats["admitted"], 1)
        return {
            "pwb_per_op": self.rt.fs.stats["pwb"] / ops,
            "pfence_per_op": self.rt.fs.stats["pfence"] / ops,
        }

    def mark_served(self, sid: int) -> None:
        """Record the request lifecycle's final stage.  The session map entry
        advances to SERVED through the fabric (keeping the slot binding, so
        the walk still shows which slot served the session); with tracing on,
        service latency (admit -> served) and end-to-end latency
        (arrive -> served) land in the metrics registry, the event in the
        trace."""
        packed = pack_session(
            self._session_prio.get(sid, 0),
            self._session_slot.get(sid, SESSION_SLOT_NONE),
            SESSION_SERVED,
        )
        _, kinds = self._phase(
            [self.session_map_key(sid)], [OP_MAP_INSERT], [float(packed)]
        )
        if kinds[0] == R_OVERFLOW:
            self._state_retry.append((sid, packed))
        if not self.obs.enabled:
            return
        now = time.perf_counter()
        t_adm = self._admit_t.pop(sid, None)
        t_arr = self._arrival_t.pop(sid, None)
        if t_adm is not None:
            self.obs.metrics.observe("service_ms", (now - t_adm) * 1e3)
        if t_arr is not None:
            self.obs.metrics.observe("e2e_ms", (now - t_arr) * 1e3)
        self.obs.event("request", stage="served", sid=int(sid))

    def latency_stats(self) -> Optional[Dict[str, Dict[str, float]]]:
        """p50/p99 (plus count/mean/min/max) per latency histogram —
        ``admission_ms`` always, ``service_ms``/``e2e_ms`` when
        ``mark_served`` ran.  None when the tier runs unobserved."""
        if not self.obs.enabled:
            return None
        return {
            name: h.summary()
            for name, h in sorted(self.obs.metrics.histograms.items())
            if name.endswith("_ms")
        }

    # -------------------------------------------------------------- recovery
    @classmethod
    def recover(
        cls,
        fs: SimFS,
        *,
        n_queues: int = 4,
        capacity: int = 4096,
        lanes: int = 64,
        n_buckets: Optional[int] = None,
        priority: bool = False,
        k_classes: int = 0,
        class_weights: Optional[Sequence[int]] = None,
        reshard_backlog: Optional[int] = None,
        pipeline: bool = False,
        depth: Optional[int] = None,
        split_lanes: bool = False,
        obs=None,
    ) -> Tuple["RequestQueueTier", Dict[str, Any]]:
        """Recover a durable tier after a crash.

        Rebuilds the fabric via ``ShardedDFCRuntime.recover`` (the durable
        routing record, if the tier autosplit before the crash, overrides the
        bootstrap shape) and returns ``(tier, info)`` where ``info`` carries
        what a resuming launcher reconciles with its own durable records:

          * ``"report"`` — the raw per-thread detectability report;
          * ``"queued"`` — session ids still committed in the request shards
            (admission order per shard);
          * ``"pool"`` — free slot ids committed in the pool stack;
          * ``"in_flight"`` — session ids whose DEQUEUE committed durably
            (they left the queue) but whose service the launcher may not
            have recorded: serve these first, deduplicated against the
            launcher's own served log;
          * ``"lost_arrivals"`` — session ids whose ENQUEUE was announced
            but reported not-applied: resubmit them;
          * ``"sessions"`` — the committed session-state table decoded from
            ONE walk of the session map shard:
            ``{sid: {"cls", "priority", "slot", "stage"}}`` — queues, slot
            pool, and per-session state all come back from the same fabric;
          * ``"progress"`` — committed decode progress per session
            (``{sid: tokens_emitted}``), from the SAME walk (progress
            entries are value-tagged): a resumed continuous-batching loop
            re-prefills each in-flight sequence at its committed offset;
          * ``"session_reads"`` — committed ``OP_MAP_LOOKUP`` results
            recovered FROM THE DURABLE RESPONSE SLOT: a lookup whose combine
            committed is detectable-applied, so its read value is the one it
            observed at combine time — re-executing it against the
            post-crash map could report a state the op never saw.

        The tier deliberately does NOT blanket-``replay_pending``: replaying
        a not-applied pop/dequeue would admit a session into a response
        record nobody is waiting on.  Insert-side losses are surfaced as
        ``lost_arrivals`` instead, and the pop side is reconciled by the
        launcher against total slot capacity (see ``main``).
        """
        req_kind = "deque" if priority else "queue"
        if k_classes and k_classes >= 2:
            n_queues = k_classes  # shard c == class c, as in __init__
        n_shards = n_queues + 2
        n_buckets = n_buckets or 4 * n_shards
        rt, report = ShardedDFCRuntime.recover(
            fs,
            kind=[req_kind] * n_queues + ["stack", "map"],
            n_shards=n_shards,
            capacity=capacity,
            lanes=lanes,
            n_threads=1,
            n_buckets=n_buckets,
            table=cls._default_table(
                n_queues, n_buckets, k_classes=bool(k_classes and k_classes >= 2)
            ),
            pipeline=pipeline,
            depth=depth,
            split_lanes=split_lanes,
            obs=obs,
        )
        tier = cls(
            n_queues=n_queues, slots=0, capacity=capacity, lanes=lanes,
            durable=True, fs=fs, reshard_backlog=reshard_backlog,
            n_buckets=n_buckets, pipeline=pipeline, depth=depth,
            priority=priority, k_classes=k_classes,
            class_weights=class_weights, split_lanes=rt.split_lanes, obs=obs,
            _seed_slots=False, _rt=rt,
        )
        tier.n_queues = sum(
            1 for k in rt.kinds if k in ("queue", "deque")
        )
        tier.pool_shard = next(
            s for s, k in enumerate(rt.kinds) if k == "stack"
        )
        tier.session_shard = next(
            s for s, k in enumerate(rt.kinds) if k == "map"
        )
        # ONE walk of the session shard restores the per-session serving
        # state AND reseeds the host mirrors the admission CAS consults
        sessions = tier.session_states()
        progress = tier.session_progress_table()
        for sid, st in sessions.items():
            tier._session_prio[sid] = st["cls"]
            if st["slot"] != SESSION_SLOT_NONE:
                tier._session_slot[sid] = st["slot"]
        in_flight: List[int] = []
        lost_arrivals: List[int] = []
        session_reads: Dict[int, Dict[str, int]] = {}
        max_token = 0
        r = report.get(0) or {"token": None, "ops": [], "prev": None}
        recs = ([dict(r, slot="newest")] if r["token"] is not None else []) + (
            [dict(r["prev"], slot="prev")] if r.get("prev") else []
        )
        for rec in recs:
            max_token = max(max_token, rec["token"])
            lsb = rt._read_valid(0) & 1
            ann = rt._read_ann(0, lsb if rec["slot"] == "newest" else 1 - lsb)
            if ann.get("token", -1) != rec["token"]:
                continue
            for i, v in enumerate(rec["ops"]):
                op = ann["ops"][i]
                shard = (
                    v.shard
                    if v.shard is not None
                    else int(rt.route_host([ann["keys"][i]])[0])
                )
                on_request = rt.kinds[shard] in ("queue", "deque")
                if v.applied and on_request and op in (OP_DEQ, OP_POP_FRONT):
                    in_flight.append(int(v.resp))
                if (
                    not v.applied
                    and op in (OP_ENQ, OP_PUSH_BACK, OP_PUSH_FRONT)
                    and on_request
                ):
                    lost_arrivals.append(int(ann["params"][i]))
                # lookup detectability: a committed OP_MAP_LOOKUP's read
                # value comes from the durable response slot, NEVER from
                # re-executing it against the post-crash map state (later
                # committed phases may have overwritten the entry it read)
                if (
                    v.applied
                    and rt.kinds[shard] == "map"
                    and op == OP_MAP_LOOKUP
                    and v.kind == R_VALUE
                    and int(v.resp) < PROGRESS_TAG  # progress reads untagged here
                ):
                    sid = int(ann["keys"][i]) // _SESSION_KEY_STRIDE
                    session_reads[sid] = unpack_session(int(v.resp))
        tier._token = max_token
        info = {
            "report": report,
            "queued": tier.queued_sessions(),
            "pool": tier.pool_slots(),
            "in_flight": sorted(set(in_flight)),
            "lost_arrivals": sorted(set(lost_arrivals)),
            "sessions": sessions,
            "progress": progress,
            "session_reads": session_reads,
        }
        return tier, info


# ---------------------------------------------------------------- launcher
def _served_log_path(state_dir: Path) -> Path:
    return state_dir / "served.log"


def _read_served(state_dir: Path) -> List[int]:
    p = _served_log_path(state_dir)
    if not p.exists():
        return []
    return [int(x) for x in p.read_text().split()]


def _log_served(state_dir: Optional[Path], sid: int) -> None:
    """Downstream consumer's durable record of a completed session — a
    plain append-only file OUTSIDE the fault-injected SimFS (the demo
    crashes the TIER, not the consumer)."""
    if state_dir is None:
        return
    with _served_log_path(state_dir).open("a") as f:
        f.write(f"{sid}\n")
        f.flush()


def _tokens_log_path(state_dir: Path) -> Path:
    return state_dir / "tokens.log"


def _read_token_entries(
    state_dir: Optional[Path],
) -> Dict[int, List[Tuple[int, int]]]:
    """Raw consumer token log: ``{sid: [(idx, token), ...]}`` in file order
    (the exactly-once audit reads this unfiltered)."""
    if state_dir is None:
        return {}
    p = _tokens_log_path(state_dir)
    if not p.exists():
        return {}
    out: Dict[int, List[Tuple[int, int]]] = {}
    for line in p.read_text().splitlines():
        if not line.strip():
            continue
        sid, idx, tok = (int(x) for x in line.split())
        out.setdefault(sid, []).append((idx, tok))
    return out


def _committed_tokens(entries: Sequence[Tuple[int, int]]) -> List[int]:
    """Contiguous committed token prefix of one session's raw log entries
    (first write wins per index) — what a resumed decode continues from."""
    by_idx: Dict[int, int] = {}
    for idx, tok in entries:
        by_idx.setdefault(idx, tok)
    toks: List[int] = []
    while len(toks) in by_idx:
        toks.append(by_idx[len(toks)])
    return toks


def _log_tokens(
    state_dir: Optional[Path], sid: int, start: int, toks: Sequence[int]
) -> None:
    """Consumer-side durable record of emitted decode tokens (same
    append-only contract as ``served.log``: outside the fault-injected
    SimFS, flushed per batch)."""
    if state_dir is None or not toks:
        return
    with _tokens_log_path(state_dir).open("a") as f:
        for j, t in enumerate(toks):
            f.write(f"{sid} {start + j} {int(t)}\n")
        f.flush()


def verify_exactly_once(
    sids: Sequence[int],
    gen: int,
    served: Sequence[int],
    token_entries: Mapping[int, Sequence[Tuple[int, int]]],
) -> None:
    """Audit the consumer logs after a (possibly crashed + resumed) run:
    every session served exactly once, and every token index ``0..gen-1``
    of every session emitted exactly once — no sequence lost, none
    double-decoded."""
    expect = sorted(int(s) for s in sids)
    got = sorted(int(s) for s in served)
    assert got == expect and len(served) == len(set(served)), (
        f"exactly-once violated: served={got} expected={expect}"
    )
    for s in expect:
        idxs = sorted(i for i, _ in token_entries.get(s, []))
        assert idxs == list(range(gen)), (
            f"token exactly-once violated for session {s}: "
            f"indices {idxs} != 0..{gen - 1}"
        )


class ContinuousServer:
    """Continuous-batching decode loop where EVERY scheduling decision is a
    fabric op: arrivals enqueue into the k priority-class shards
    (``submit``), admission pops ride the weighted multi-shard dequeue
    (``admit``), decode-slot allocation rides the slot-pool stack shard,
    per-session stage/slot/progress lives in the session map shard
    (``record_progress`` commits each round's token counts in one combined
    phase), and completion retirement is a fabric op (``mark_served``).

    The loop interleaves sessions: each round every active slot decodes one
    QUANTUM of tokens (``decode`` callable — the launcher wires the jitted
    prefill/quantum steps in, tests and benchmarks use the deterministic
    simulated decoder), emits them to the consumer token log, and commits
    progress; finished sessions retire and their slots return through the
    fabric, so admissions join mid-stream as capacity frees.

    Crash-exact resume: the consumer logs (``served.log``/``tokens.log``)
    live OUTSIDE the fault-injected SimFS; a resumed server rebuilds
    in-flight sessions from the recovery walk (announcement-level in-flight
    dequeues plus map entries stuck at ADMITTED), deduplicates against the
    served log, re-prefills each sequence at its committed token offset,
    and emits exactly the remaining tokens — ``verify_exactly_once`` audits
    the combined logs.
    """

    def __init__(
        self,
        tier: RequestQueueTier,
        *,
        sids: Sequence[int],
        batch: int,
        gen: int,
        quantum: int = 0,
        arrival: int = 0,
        class_of: Optional[Callable[[int], int]] = None,
        state_dir: Optional[Path] = None,
        decode: Optional[Callable[..., List[int]]] = None,
        resume_info: Optional[Dict[str, Any]] = None,
        served_before: Sequence[int] = (),
        token_log: Optional[Mapping[int, Sequence[int]]] = None,
    ):
        self.tier = tier
        self.sids = [int(s) for s in sids]
        self.batch = int(batch)
        self.gen = int(gen)
        self.quantum = int(quantum) or self.gen
        self.arrival = int(arrival) or self.batch
        k = tier.k_classes
        self.class_of = class_of or (
            (lambda sid: sid % k) if k else (lambda sid: 0)
        )
        self.state_dir = state_dir
        self.decode = decode or self._sim_decode
        self.served: List[int] = [int(s) for s in served_before]
        # committed token prefix per session (mirrors the consumer log)
        self.token_log: Dict[int, List[int]] = {
            int(s): list(t) for s, t in (token_log or {}).items()
        }
        # sid -> {"slot", "done", "state"}; "state" is the decoder's
        # per-session scratch (the model path keeps its KV cache there)
        self.active: Dict[int, Dict[str, Any]] = {}
        self.rounds = 0
        self.decoded = 0
        if resume_info is not None:
            self.pending = self._reconcile(resume_info)
        else:
            self.pending = list(self.sids)

    # deterministic simulated decode: lets the tier-only path (and the
    # crash campaign) check token-level exactly-once without a model
    @staticmethod
    def sim_token(sid: int, idx: int) -> int:
        return (int(sid) * 1009 + int(idx) * 31) % 4093

    def _sim_decode(self, sid, start, n, state, history):
        return [self.sim_token(sid, start + j) for j in range(n)]

    def _reconcile(self, info: Dict[str, Any]) -> List[int]:
        """Rebuild the serving state from one recovery walk: in-flight
        sequences resume mid-decode (holding their bound slots), queued
        sessions stay queued, everything else resubmits; the slot pool is
        restored to exactly ``batch`` minus free minus held."""
        served_set = set(self.served)
        sessions = info["sessions"]
        universe = set(self.sids)
        # in-flight = dequeues that committed in the announcement slots,
        # PLUS sessions whose map entry is stuck at ADMITTED (admitted many
        # rounds ago: their dequeue announcement was long overwritten, but
        # the session map keeps the stage durable) — deduplicated against
        # the consumer's served log, which wins every conflict.  A map entry
        # at SERVED that never reached the served log resumes too: its
        # tokens are already consumer-logged (they commit first), so it
        # retires on the next round without re-decoding a single token.
        in_flight = sorted(
            (set(info["in_flight"])
             | {s for s, st in sessions.items()
                if st["stage"] in (SESSION_ADMITTED, SESSION_SERVED)})
            & universe - served_set
        )
        queued = set(info["queued"])
        pending = [
            s for s in self.sids
            if s not in served_set and s not in queued and s not in in_flight
        ]
        pool = set(info["pool"])
        complement = [i for i in range(self.batch) if i not in pool]
        assert len(complement) >= len(in_flight), (complement, in_flight)
        taken: set = set()
        for sid in in_flight:
            st = sessions.get(sid)
            slot = st["slot"] if st is not None else SESSION_SLOT_NONE
            if (
                slot == SESSION_SLOT_NONE or slot >= self.batch
                or slot in pool or slot in taken
            ):
                slot = next(i for i in complement if i not in taken)
            taken.add(slot)
            done = min(len(self.token_log.get(sid, ())), self.gen)
            self.active[sid] = {"slot": slot, "done": done, "state": {}}
        # complement slots no in-flight session holds go back to the pool
        leftovers = [i for i in complement if i not in taken]
        if leftovers:
            self.tier.submit([], release_slots=leftovers)
        return pending

    def _outstanding(self) -> List[int]:
        done = set(self.served)
        return [s for s in self.sids if s not in done]

    def run(self, max_rounds: Optional[int] = None) -> Dict[str, Any]:
        tier = self.tier
        waiting: List[int] = []
        next_idx = 0
        limit = max_rounds or (8 * max(len(self.sids), 1) + 64)
        for _ in range(limit):
            if not self._outstanding():
                break
            self.rounds += 1
            fresh = self.pending[next_idx : next_idx + self.arrival]
            next_idx += len(fresh)
            subs = waiting + fresh
            if subs:
                kw: Dict[str, Any] = {}
                if tier.k_classes:
                    kw["classes"] = [self.class_of(s) for s in subs]
                elif tier.priority:
                    kw["priorities"] = [self.class_of(s) for s in subs]
                waiting = tier.submit(subs, **kw)
            free = self.batch - len(self.active)
            for sid, slot in tier.admit(free):
                self.active[sid] = {"slot": slot, "done": 0, "state": {}}
            progress: Dict[int, int] = {}
            finished: List[int] = []
            for sid, st in sorted(self.active.items()):
                n_new = min(self.quantum, self.gen - st["done"])
                history = self.token_log.setdefault(sid, [])
                toks = (
                    self.decode(sid, st["done"], n_new, st["state"], history)
                    if n_new > 0 else []
                )
                if toks:
                    # consumer durability FIRST, fabric progress after: a
                    # crash between the two resumes from the (longer)
                    # consumer log and never re-emits a logged token
                    _log_tokens(self.state_dir, sid, st["done"], toks)
                    history.extend(int(t) for t in toks)
                    st["done"] += len(toks)
                    self.decoded += len(toks)
                progress[sid] = st["done"]
                if st["done"] >= self.gen:
                    finished.append(sid)
            if progress:
                tier.record_progress(progress)
            for sid in finished:
                _log_served(self.state_dir, sid)
                self.served.append(sid)
                tier.mark_served(sid)
            if finished:
                tier.submit(
                    [],
                    release_slots=[
                        self.active.pop(sid)["slot"] for sid in finished
                    ],
                )
            if (
                not self.active and not waiting
                and next_idx >= len(self.pending) and tier.backlog() == 0
            ):
                break  # nothing left anywhere (lost-session guard)
        return {
            "completed": len(set(self.served) & set(self.sids)),
            "rounds": self.rounds,
            "decoded_tokens": self.decoded,
            "served": list(self.served),
        }


def make_model_decode(
    cfg, params, prefill_step, serve_step, quantum_step,
    prompt_len: int, quantum: int,
):
    """Build the per-session model decoder the continuous loop drives.

    Emits the next ``n`` greedy tokens of session ``sid``: fresh sessions
    prefill the (sid-seeded) prompt; resumed sessions re-prefill prompt +
    committed history — argmax decode is deterministic, so the
    continuation is crash-exact. The KV cache lives in ``state`` between
    rounds; full quanta ride the scanned ``quantum_step`` (one dispatch),
    remainders single-step."""
    import jax.numpy as jnp

    def decode(sid, start, n, state, history):
        if n <= 0:
            return []
        out: List[int] = []
        if "cache" not in state:
            prompt = np.random.default_rng(sid).integers(
                0, cfg.vocab, prompt_len
            )
            row = np.concatenate(
                [prompt, np.asarray(list(history[:start]), np.int64)]
            )
            last, cache = prefill_step(
                params, {"tokens": jnp.asarray(row[None, :], jnp.int32)}
            )
            tok = jnp.argmax(last[:, -1], axis=-1)[:, None].astype(jnp.int32)
            state["cache"], state["tok"] = cache, tok
            out.append(int(tok[0, 0]))
        while len(out) < n:
            if n - len(out) >= quantum:
                o, state["cache"] = quantum_step(
                    params, state["cache"], state["tok"]
                )
                state["tok"] = o["next_token"]
                out.extend(int(t) for t in np.asarray(o["tokens"])[0])
            else:
                o, state["cache"] = serve_step(
                    params, state["cache"], {"tokens": state["tok"]}
                )
                state["tok"] = o["next_token"][:, None].astype(jnp.int32)
                out.append(int(state["tok"][0, 0]))
        return out

    return decode


def _serve_continuous(
    args, cfg, params, prefill_step, serve_step, fs, obs,
    tier_kw, state_dir, served_before, n_sessions, arrival,
):
    """Launcher branch for ``--k-classes``: continuous-batching decode with
    the jitted quantum step, crash/resume via the consumer logs plus one
    recovery walk."""
    quantum = args.quantum or min(8, args.gen)
    decode = None
    if not args.tier_only:
        import jax

        from repro.launch.steps import make_quantum_step

        quantum_step = jax.jit(
            make_quantum_step(cfg, window=args.window, quantum=quantum)
        )
        decode = make_model_decode(
            cfg, params, prefill_step, serve_step, quantum_step,
            args.prompt_len, quantum,
        )

    sids = list(range(1, n_sessions + 1))
    t0 = time.perf_counter()
    try:
        if args.resume:
            tier, info = RequestQueueTier.recover(fs, **tier_kw)
        else:
            tier = RequestQueueTier(
                slots=args.batch, durable=args.durable, fs=fs, **tier_kw
            )
            info = None
        entries = _read_token_entries(state_dir)
        srv = ContinuousServer(
            tier,
            sids=sids,
            batch=args.batch,
            gen=args.gen,
            quantum=quantum,
            arrival=arrival,
            class_of=lambda s: s % args.k_classes,
            state_dir=state_dir,
            decode=decode,
            resume_info=info,
            served_before=served_before,
            token_log={s: _committed_tokens(e) for s, e in entries.items()},
        )
        if info is not None:
            print(
                f"resume: served={len(set(served_before))} "
                f"in_flight={sorted(srv.active)} "
                f"lost_arrivals={info['lost_arrivals']} "
                f"resubmitting={len(srv.pending)} "
                f"progress={ {s: st['done'] for s, st in sorted(srv.active.items())} }"
            )
        res = srv.run()
    except CrashNow as e:
        print(f"CRASHED: {e}")
        print(
            f"tier state is durable under {state_dir}; resume with "
            f"--resume --state-dir {state_dir}"
        )
        return
    dt = time.perf_counter() - t0

    print(
        f"{args.arch}: continuous batching served {res['completed']}/"
        f"{n_sessions} sessions in {res['rounds']} rounds, "
        f"{res['decoded_tokens']} tok (quantum={quantum}) in {dt*1e3:.0f} ms"
        + ("" if args.tier_only or dt == 0
           else f" ({res['decoded_tokens']/dt:.0f} tok/s)")
    )
    print(
        f"k-class tier: k={tier.k_classes} weights={tier.class_weights} "
        f"starvation_bound={tier.starvation_bound()} "
        f"arrived={tier.stats['arrived']} admitted={tier.stats['admitted']} "
        f"rejected={tier.stats['rejected']} backlog={tier.backlog()}"
    )
    p = tier.persistence_stats()
    if p:
        print(f"pwb/op: {p['pwb_per_op']:.2f}  pfence/op: {p['pfence_per_op']:.2f}")
    lat = tier.latency_stats()
    if lat:
        for name, s in lat.items():
            print(
                f"{name}: p50={s['p50']:.3f} p99={s['p99']:.3f} "
                f"mean={s['mean']:.3f} n={int(s['count'])}"
            )
    if obs is not None:
        obs.flush()
    if args.expect_exactly_once:
        verify_exactly_once(
            sids, args.gen, _read_served(state_dir),
            _read_token_entries(state_dir),
        )
        print("exactly-once: OK (sessions + token indices)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--sessions", type=int, default=0,
                    help="total sessions through the request-queue tier "
                         "(default: one round of --batch)")
    ap.add_argument("--arrival", type=int, default=0,
                    help="arrivals per round (default: --batch)")
    ap.add_argument("--queues", type=int, default=4,
                    help="request-queue shards in the DFC fabric")
    ap.add_argument("--durable", action="store_true",
                    help="run the tier over the SimFS persistence path")
    ap.add_argument("--pipeline", action="store_true",
                    help="pipelined durable path (dispatch/retire overlap)")
    ap.add_argument("--depth", type=int, default=0,
                    help="pipeline depth D (>1 keeps D-1 chains in flight; "
                         "0 = serial, or 2 with --pipeline)")
    ap.add_argument("--priority", action="store_true",
                    help="deque request shards: high-priority sessions jump "
                         "the line (front-of-queue push)")
    ap.add_argument("--split-lanes", action="store_true",
                    help="per-side combiners: arrivals ride each request "
                         "shard's tail lane, admission pops its head lane, "
                         "with independent epochs and commits")
    ap.add_argument("--high-every", type=int, default=0,
                    help="with --priority: every Nth session arrives "
                         "high-priority (0 = none)")
    ap.add_argument("--k-classes", type=int, default=0,
                    help="continuous-batching mode with k priority classes "
                         "(2..4): per-class queue shards, weighted "
                         "round-robin admission, quantum decode with "
                         "crash-exact resume")
    ap.add_argument("--class-weights", default="",
                    help="comma-separated dequeue credits per class "
                         "(default: 1<<c, i.e. 1,2,4,...)")
    ap.add_argument("--quantum", type=int, default=0,
                    help="decode tokens per session per scheduling round "
                         "(default: min(8, --gen))")
    ap.add_argument("--reshard-backlog", type=int, default=0,
                    help="split a request shard when its backlog exceeds N")
    ap.add_argument("--bulk-arrivals", action="store_true",
                    help="submit the whole arrival schedule up front through "
                         "the fabric's fused K-phase loop (one device "
                         "dispatch per schedule), then admit from the "
                         "committed backlog")
    ap.add_argument("--tier-only", action="store_true",
                    help="skip model init/decode: serve = tier admission "
                         "only (fast crash/resume demos and CI smoke)")
    ap.add_argument("--state-dir", default="",
                    help="durable tier root (enables crash/resume demos); "
                         "default: fresh temp dir")
    ap.add_argument("--crash-at", type=int, default=0,
                    help="inject a crash at the K-th tier persistence op "
                         "(requires --durable --state-dir)")
    ap.add_argument("--resume", action="store_true",
                    help="recover the tier from --state-dir, reconcile, and "
                         "finish serving")
    ap.add_argument("--expect-exactly-once", action="store_true",
                    help="with --resume: assert every session was served "
                         "exactly once across crash + resume")
    ap.add_argument("--trace", action="store_true",
                    help="enable the fabric flight recorder: durable trace "
                         "sidecar under the tier root (with --state-dir), "
                         "metrics + Chrome trace exports, and p50/p99 "
                         "admission latency in the tier report")
    args = ap.parse_args()

    cfg = apply_tuning(get_reduced(args.arch) if args.reduced else get_config(args.arch))
    if not args.tier_only and (cfg.embedding_inputs or cfg.family == "vlm"):
        raise SystemExit(f"{args.arch}: frontend-stub arch — see examples/")

    if args.tier_only:
        prefill_step = serve_step = params = None
    else:
        import jax

        from repro.launch.steps import make_prefill_step, make_serve_step
        from repro.models.model import init_params

        params = init_params(cfg, jax.random.PRNGKey(0))
        max_len = args.prompt_len + args.gen + 8
        prefill_step = jax.jit(make_prefill_step(cfg, max_len=max_len))
        serve_step = jax.jit(make_serve_step(cfg, window=args.window))

    n_sessions = args.sessions or args.batch
    arrival = args.arrival or args.batch
    depth = args.depth or None
    state_dir = Path(args.state_dir) if args.state_dir else None
    if (args.crash_at or args.resume) and not (args.durable and state_dir):
        raise SystemExit("--crash-at/--resume need --durable and --state-dir")

    fs = None
    if args.durable and state_dir is not None:
        state_dir.mkdir(parents=True, exist_ok=True)
        fs = SimFS(
            state_dir / "tier",
            FaultInjector(crash_at=args.crash_at or None),
        )

    obs = None
    if args.trace:
        from repro.obs import FabricObserver

        # durable tiers get the crash-durable sidecar under the tier root;
        # volatile tiers trace in memory (metrics + ring only)
        obs = FabricObserver(root=fs.root if fs is not None else None)

    k = args.k_classes if args.k_classes >= 2 else 0
    tier_kw = dict(
        n_queues=args.queues,
        capacity=4096,
        lanes=max(arrival, args.batch) * 2,
        reshard_backlog=args.reshard_backlog or None,
        pipeline=args.pipeline,
        depth=depth,
        priority=args.priority,
        split_lanes=args.split_lanes,
        k_classes=k,
        class_weights=(
            [int(x) for x in args.class_weights.split(",")]
            if k and args.class_weights else None
        ),
        obs=obs,
    )
    served_before = _read_served(state_dir) if state_dir else []

    if k:
        _serve_continuous(
            args, cfg, params, prefill_step, serve_step, fs, obs,
            tier_kw, state_dir, served_before, n_sessions, arrival,
        )
        return

    in_flight: List[int] = []

    def serve_batch(sids: List[int]) -> None:
        """Prefill + decode one admitted batch (or a tier-only no-op)."""
        if args.tier_only or not sids:
            return
        import jax
        import jax.numpy as jnp

        rows = sids + [sids[0]] * (args.batch - len(sids))
        prompts = jnp.asarray(
            np.stack([
                np.random.default_rng(sid).integers(0, cfg.vocab, args.prompt_len)
                for sid in rows
            ]),
            jnp.int32,
        )
        last, cache = prefill_step(params, {"tokens": prompts})
        tok = jnp.argmax(last[:, -1], axis=-1)[:, None].astype(jnp.int32)
        for _ in range(args.gen - 1):
            out, cache = serve_step(params, cache, {"tokens": tok})
            tok = out["next_token"][:, None].astype(jnp.int32)
        jax.block_until_ready(tok)

    waiting: List[int] = []
    next_idx = 0
    decoded_tokens = 0
    t0 = time.perf_counter()
    round_no = 0
    try:
        # tier construction / recovery runs under the same crash handler:
        # the fault injector ticks through the slot-pool seeding and the
        # resume-time reconciliation phases too, so ANY --crash-at value
        # exits the demo gracefully
        if args.resume:
            tier, info = RequestQueueTier.recover(fs, **tier_kw)
            served_set = set(served_before)
            in_flight = [s for s in info["in_flight"] if s not in served_set]
            queued = set(info["queued"])
            to_submit = [
                s for s in range(1, n_sessions + 1)
                if s not in served_set and s not in queued
                and s not in in_flight
            ]
            # rebuild the slot pool: total slots minus those still free minus
            # the ones in-flight sessions hold (released after service)
            missing = args.batch - len(info["pool"]) - len(in_flight)
            if missing > 0:
                free_ids = [
                    i for i in range(args.batch) if i not in set(info["pool"])
                ][:missing]
                tier.submit([], release_slots=free_ids)
            stages = [st["stage"] for st in info["sessions"].values()]
            print(
                f"resume: served={len(served_set)} queued={len(queued)} "
                f"in_flight={in_flight} lost_arrivals={info['lost_arrivals']} "
                f"resubmitting={len(to_submit)} "
                f"sessions={len(stages)} "
                f"(q={stages.count(SESSION_QUEUED)} "
                f"a={stages.count(SESSION_ADMITTED)} "
                f"s={stages.count(SESSION_SERVED)})"
            )
            pending_sids = to_submit
            completed = len(served_set)
        else:
            tier = RequestQueueTier(
                slots=args.batch, durable=args.durable, fs=fs, **tier_kw
            )
            pending_sids = list(range(1, n_sessions + 1))
            completed = 0
        # resumed in-flight admissions go first: their dequeue committed
        # before the crash, so they must be served (once) without re-queueing
        if in_flight:
            pool = tier.pool_slots()
            slot_src = [i for i in range(args.batch) if i not in set(pool)]
            # the reconciliation above rebuilt the pool to batch - in_flight
            # slots, so the complement always covers the in-flight sessions;
            # fabricating extra ids here would duplicate slots in the pool
            assert len(slot_src) >= len(in_flight), (slot_src, in_flight)
            pairs = list(zip(in_flight, slot_src))
            serve_batch([sid for sid, _ in pairs])
            decoded_tokens += 0 if args.tier_only else args.gen * len(pairs)
            for sid, slot in pairs:
                _log_served(state_dir, sid)
                tier.mark_served(sid)
                completed += 1
            tier.submit([], release_slots=[slot for _, slot in pairs])
        if args.bulk_arrivals and pending_sids:
            # the tier rides the fused phase loop: the whole arrival
            # schedule commits in ONE device dispatch (wave = one phase)
            bulk_waves = []
            for i in range(0, len(pending_sids), arrival):
                fresh = pending_sids[i : i + arrival]
                prio = (
                    [1 if s % args.high_every == 0 else 0 for s in fresh]
                    if args.priority and args.high_every else None
                )
                bulk_waves.append((fresh, [], prio))
            rejected = tier.submit_waves(bulk_waves)
            waiting = [s for wave in rejected for s in wave]
            next_idx = len(pending_sids)
            print(
                f"bulk arrivals: {len(pending_sids)} sessions committed in "
                f"{len(bulk_waves)} fused phases ({len(waiting)} to retry)"
            )
        while completed < n_sessions:
            round_no += 1
            fresh = pending_sids[next_idx : next_idx + arrival]
            next_idx += len(fresh)
            prio = None
            if args.priority and args.high_every:
                prio = [1 if s % args.high_every == 0 else 0 for s in waiting + fresh]
            waiting = tier.submit(waiting + fresh, priorities=prio)

            admitted = tier.admit(args.batch)
            if not admitted:
                if not fresh and not waiting and tier.backlog() == 0:
                    break  # nothing left anywhere (lost-session guard)
                continue
            sids = [sid for sid, _ in admitted]
            serve_batch(sids)
            decoded_tokens += 0 if args.tier_only else args.gen * len(sids)
            for sid in sids:
                _log_served(state_dir, sid)
                tier.mark_served(sid)
            completed += len(sids)
            # sessions finished: their decode slots go back through the fabric
            tier.submit([], release_slots=[slot for _, slot in admitted])
    except CrashNow as e:
        print(f"CRASHED: {e}")
        print(
            f"tier state is durable under {state_dir}; resume with "
            f"--resume --state-dir {state_dir}"
        )
        return
    dt = time.perf_counter() - t0

    print(
        f"{args.arch}: served {completed} sessions in {round_no} rounds, "
        f"{decoded_tokens} tok in {dt*1e3:.0f} ms"
        + ("" if args.tier_only or dt == 0 else f" ({decoded_tokens/dt:.0f} tok/s)")
    )
    print(
        f"request tier: queues={tier.n_queues} (+ slot-pool stack shard "
        f"+ session-state map shard) "
        f"priority={args.priority} depth={tier.rt.depth} "
        f"arrived={tier.stats['arrived']} admitted={tier.stats['admitted']} "
        f"rejected={tier.stats['rejected']} splits={tier.stats['splits']} "
        f"backlog={tier.backlog()}"
    )
    if tier.split_lanes:
        ls = tier.rt.lane_stats() or {}
        pairs = " ".join(
            f"s{s}=[{e[0]},{e[1]}]" for s, e in sorted(ls.get("epochs", {}).items())
        )
        print(f"split lanes: head/tail epochs {pairs}")
    p = tier.persistence_stats()
    if p:
        print(f"pwb/op: {p['pwb_per_op']:.2f}  pfence/op: {p['pfence_per_op']:.2f}")
    lat = tier.latency_stats()
    if lat:
        for name, s in lat.items():
            print(
                f"{name}: p50={s['p50']:.3f} p99={s['p99']:.3f} "
                f"mean={s['mean']:.3f} n={int(s['count'])}"
            )
    if obs is not None:
        from repro.obs import bridge_persist_stats, to_chrome_trace

        if tier.durable:
            bridge_persist_stats(obs.metrics, tier.rt.fs.pstats)
        obs.flush()  # clean shutdown: durable-tail the last partial fence
        if obs.root is not None:
            n_m = obs.metrics.to_jsonl(obs.root / "obs" / "metrics.jsonl")
            n_e = to_chrome_trace(
                obs.trace.events(), obs.root / "obs" / "trace_chrome.json"
            )
            print(
                f"trace: {obs.trace_path} (+{n_m} metrics, "
                f"{n_e} chrome events under {obs.root / 'obs'})"
            )
    if args.expect_exactly_once:
        served = _read_served(state_dir)
        expect = sorted(range(1, n_sessions + 1))
        assert sorted(served) == expect and len(served) == len(set(served)), (
            f"exactly-once violated: served={sorted(served)} expected={expect}"
        )
        print(f"exactly-once OK: {n_sessions} sessions, none lost, none duplicated")


if __name__ == "__main__":
    main()
