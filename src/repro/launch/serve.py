"""Production serving launcher: DFC request-queue tier + batched prefill/decode.

The sharded DFC fabric (``repro.runtime.dfc_shard``) is mounted as the
serving tier's REQUEST QUEUE — the ROADMAP's "request-queue tier" item:

  * session ids are the routing keys; an arriving session is ENQUEUED into
    its FIFO request shard, and each prefill round DEQUEUES up to ``--batch``
    sessions into the model batch;
  * the pool of free decode slots (KV-cache rows) is a LIFO **stack shard in
    the same fabric** — a heterogeneous fabric in production position:
    arrivals (queue enq) and slot releases (stack push) combine in ONE fused
    phase;
  * ``--durable`` runs the tier over the announce/combine persistence path
    (SimFS-backed) and reports pwb/op — the paper's Figure-3 metric at the
    serving tier;
  * ``--reshard-backlog N`` splits a request shard whose backlog exceeds N
    (crash-consistent: see ``ShardedDFCRuntime.split_shard``).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 16 --gen 32 --sessions 12
"""

from __future__ import annotations

import argparse
import tempfile
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.dfc_checkpoint import SimFS
from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.core.jax_dfc import OP_DEQ, OP_ENQ, OP_POP, OP_PUSH, R_VALUE
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.launch.tuned import apply_tuning
from repro.models.model import init_params
from repro.runtime.dfc_shard import _HASH_MULT, R_OVERFLOW, ShardedDFCRuntime


class RequestQueueTier:
    """Session admission over a heterogeneous DFC fabric.

    ``n_queues`` FIFO request shards plus ONE stack shard (the free-slot
    pool) behind a single router.  Bucket 0 of the routing table is pinned
    to the pool shard; session ids are deterministically re-probed away from
    it, so every session key lands on a request shard.  All tier traffic —
    arrivals, slot pops, dequeues, releases — flows through the fabric's
    fused combine, volatile (``step``) or durable (``announce`` /
    ``combine_phase``).
    """

    def __init__(
        self,
        n_queues: int = 4,
        slots: int = 4,
        *,
        capacity: int = 4096,
        lanes: int = 64,
        durable: bool = False,
        fs: Optional[SimFS] = None,
        reshard_backlog: Optional[int] = None,
        n_buckets: Optional[int] = None,
        pipeline: bool = False,
    ):
        kinds = ["queue"] * n_queues + ["stack"]
        n_shards = n_queues + 1
        n_buckets = n_buckets or 4 * n_shards
        self.pool_shard = n_queues
        # bucket 0 -> pool stack; the rest round-robin over the request shards
        table = np.asarray(
            [self.pool_shard] + [b % n_queues for b in range(1, n_buckets)],
            np.int32,
        )
        if durable and fs is None:
            fs = SimFS(Path(tempfile.mkdtemp(prefix="dfc_serve_tier_")))
        self.durable = durable
        self.pipeline = pipeline
        self.rt = ShardedDFCRuntime(
            kinds, n_shards, capacity, lanes,
            fs=fs if durable else None, n_threads=1,
            n_buckets=n_buckets, table=table, pipeline=pipeline,
        )
        self.reshard_backlog = reshard_backlog
        self._rep_keys: Dict[int, int] = {}
        self._slot_retry: List[int] = []  # pool pushes that overflowed a phase
        self._token = 0
        self.stats = {"arrived": 0, "admitted": 0, "rejected": 0, "splits": 0}
        # seed the slot pool (submit chunks pushes to the pool shard's lanes)
        self.submit([], release_slots=list(range(slots)))
        while self._slot_retry:
            self.submit([])

    # ------------------------------------------------------------ internals
    def _key_for(self, shard: int) -> int:
        if shard not in self._rep_keys:
            self._rep_keys[shard] = self.rt.key_for_shard(shard)
        return self._rep_keys[shard]

    def _phase(self, keys, ops, params) -> Tuple[np.ndarray, np.ndarray]:
        """One tier phase: fused volatile step, or announce+combine+read.

        The durable path goes through the fabric's announcement RING: the
        payload lands in the preallocated device ring at ``announce`` and
        the combining phase consumes it there — SimFS only carries the
        compact durable mirror.  The tier needs each phase's responses
        synchronously (admission decisions), so in pipelined mode it flushes
        the one in-flight chain right after dispatch; the ring fast path and
        the per-batch commit schedule are identical either way.
        """
        if not self.durable:
            resp, kinds = self.rt.step(keys, ops, params)
            return np.asarray(resp), np.asarray(kinds)
        self._token += 1
        self.rt.announce(0, keys, ops, params, token=self._token)
        self.rt.combine_phase()
        if self.pipeline:
            self.rt.flush()
        val = self.rt.read_responses(0, token=self._token)
        return np.asarray(val["resp"]), np.asarray(val["kinds"])

    def session_key(self, sid: int) -> int:
        """Deterministic key for a session id, re-probed off the pool shard
        (so the id stays the key in spirit; collisions with bucket 0 hop)."""
        if not 0 <= sid < (1 << 24):
            # sids round-trip through the fabric's float32 values; past the
            # f32 mantissa two sessions would silently collide
            raise ValueError(f"session id {sid} must be in [0, 2^24)")
        k = int(sid)
        while int(self.rt.route_host([k])[0]) == self.pool_shard:
            k = (k * _HASH_MULT + 1) % (1 << 31)
        return k

    def _queue_backlogs(self) -> Dict[int, int]:
        """Committed backlog per request shard, straight from the fabric's
        active root counters (no host-side shadow accounting to drift)."""
        sizes = self.rt.shard_sizes()
        return {
            s: int(sizes[s])
            for s in range(self.rt.n_shards)
            if self.rt.kinds[s] == "queue"
        }

    # ------------------------------------------------------------- tier API
    def submit(self, sids: Sequence[int], release_slots: Sequence[int] = ()) -> List[int]:
        """Enqueue arriving sessions and return freed decode slots to the
        pool — one mixed-kind combined phase.  Returns session ids that
        overflowed their shard's lanes (re-submit next round).

        Pool pushes all route to the single pool shard, so at most ``lanes``
        of them fit per phase; the surplus — and any push the fabric rejects
        with R_OVERFLOW — is carried in ``_slot_retry`` and retried on the
        next submit, so a decode slot can never leak."""
        pool = self._slot_retry + list(release_slots)
        self._slot_retry = pool[self.rt.lanes :]
        pool = pool[: self.rt.lanes]
        keys = [self.session_key(s) for s in sids]
        keys += [self._key_for(self.pool_shard)] * len(pool)
        ops = [OP_ENQ] * len(sids) + [OP_PUSH] * len(pool)
        params = [float(s) for s in sids] + [float(s) for s in pool]
        if not ops:
            return []
        resp, kinds = self._phase(keys, ops, params)
        rejected = [s for i, s in enumerate(sids) if kinds[i] == R_OVERFLOW]
        for j, slot in enumerate(pool):
            if kinds[len(sids) + j] == R_OVERFLOW:
                self._slot_retry.append(slot)
        self.stats["arrived"] += len(sids)
        self.stats["rejected"] += len(rejected)
        self._maybe_split()
        return rejected

    def admit(self, max_n: int) -> List[Tuple[int, int]]:
        """Admit up to ``max_n`` sessions: pop free slots from the pool
        stack, then dequeue that many sessions round-robin from the backlogged
        request shards.  Returns ``[(session_id, slot), ...]``."""
        if max_n <= 0:
            return []
        pool_key = self._key_for(self.pool_shard)
        resp, kinds = self._phase(
            [pool_key] * max_n, [OP_POP] * max_n, [0.0] * max_n
        )
        slots = [int(resp[i]) for i in range(max_n) if kinds[i] == R_VALUE]
        if not slots:
            return []
        deqs: List[Tuple[int, int]] = []  # (shard, representative key)
        budget = self._queue_backlogs()
        while len(deqs) < len(slots):
            ready = [s for s, n in sorted(budget.items()) if n > 0]
            if not ready:
                break
            for s in ready:
                if len(deqs) >= len(slots):
                    break
                deqs.append((s, self._key_for(s)))
                budget[s] -= 1
        if not deqs:
            self.submit([], release_slots=slots)  # nothing queued: put back
            return []
        resp, kinds = self._phase(
            [k for _, k in deqs], [OP_DEQ] * len(deqs), [0.0] * len(deqs)
        )
        admitted: List[Tuple[int, int]] = []
        spare = list(slots)
        for i, (shard, _) in enumerate(deqs):
            if kinds[i] == R_VALUE:
                admitted.append((int(resp[i]), spare.pop(0)))
        if spare:
            self.submit([], release_slots=spare)
        self.stats["admitted"] += len(admitted)
        return admitted

    def backlog(self) -> int:
        return sum(self._queue_backlogs().values())

    def _maybe_split(self) -> None:
        """Split the hottest request shard when its backlog crosses the
        threshold (crash-consistent; new shard inherits half the buckets)."""
        if self.reshard_backlog is None:
            return
        backlogs = self._queue_backlogs()
        hot = max(backlogs, key=backlogs.get)
        if backlogs[hot] < self.reshard_backlog:
            return
        try:
            self.rt.split_shard(hot)
        except ValueError:
            return  # no spare bucket left on this shard
        self._rep_keys.clear()  # table changed: representative keys stale
        self.stats["splits"] += 1

    def persistence_stats(self) -> Optional[Dict[str, float]]:
        if not self.durable:
            return None
        ops = max(self.stats["arrived"] + self.stats["admitted"], 1)
        return {
            "pwb_per_op": self.rt.fs.stats["pwb"] / ops,
            "pfence_per_op": self.rt.fs.stats["pfence"] / ops,
        }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--sessions", type=int, default=0,
                    help="total sessions through the request-queue tier "
                         "(default: one round of --batch)")
    ap.add_argument("--arrival", type=int, default=0,
                    help="arrivals per round (default: --batch)")
    ap.add_argument("--queues", type=int, default=4,
                    help="request-queue shards in the DFC fabric")
    ap.add_argument("--durable", action="store_true",
                    help="run the tier over the SimFS persistence path")
    ap.add_argument("--pipeline", action="store_true",
                    help="pipelined durable path (dispatch/retire overlap)")
    ap.add_argument("--reshard-backlog", type=int, default=0,
                    help="split a request shard when its backlog exceeds N")
    args = ap.parse_args()

    cfg = apply_tuning(get_reduced(args.arch) if args.reduced else get_config(args.arch))
    if cfg.embedding_inputs or cfg.family == "vlm":
        raise SystemExit(f"{args.arch}: frontend-stub arch — see examples/")

    params = init_params(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.gen + 8
    prefill_step = jax.jit(make_prefill_step(cfg, max_len=max_len))
    serve_step = jax.jit(make_serve_step(cfg, window=args.window))

    n_sessions = args.sessions or args.batch
    arrival = args.arrival or args.batch
    tier = RequestQueueTier(
        n_queues=args.queues,
        slots=args.batch,
        lanes=max(arrival, args.batch) * 2,
        durable=args.durable,
        reshard_backlog=args.reshard_backlog or None,
        pipeline=args.pipeline,
    )

    rng = np.random.default_rng(0)
    next_sid = 1
    waiting: List[int] = []
    completed = 0
    decoded_tokens = 0
    t0 = time.perf_counter()
    round_no = 0
    while completed < n_sessions:
        round_no += 1
        # arrivals into the request-queue tier (+ any overflow retries)
        fresh = list(range(next_sid, min(next_sid + arrival, n_sessions + 1)))
        next_sid = next_sid + len(fresh)
        waiting = tier.submit(waiting + fresh)

        admitted = tier.admit(args.batch)
        if not admitted:
            continue
        # prefill a fixed [batch, prompt_len] block; idle rows repeat row 0
        sids = [sid for sid, _ in admitted]
        rows = sids + [sids[0]] * (args.batch - len(sids))
        prompts = jnp.asarray(
            np.stack([
                np.random.default_rng(sid).integers(0, cfg.vocab, args.prompt_len)
                for sid in rows
            ]),
            jnp.int32,
        )
        last, cache = prefill_step(params, {"tokens": prompts})
        tok = jnp.argmax(last[:, -1], axis=-1)[:, None].astype(jnp.int32)
        for _ in range(args.gen - 1):
            out, cache = serve_step(params, cache, {"tokens": tok})
            tok = out["next_token"][:, None].astype(jnp.int32)
        jax.block_until_ready(tok)
        decoded_tokens += args.gen * len(sids)
        completed += len(sids)
        # sessions finished: their decode slots go back through the fabric
        tier.submit([], release_slots=[slot for _, slot in admitted])
    dt = time.perf_counter() - t0

    print(
        f"{args.arch}: served {completed} sessions in {round_no} rounds, "
        f"{decoded_tokens} tok in {dt*1e3:.0f} ms ({decoded_tokens/dt:.0f} tok/s)"
    )
    print(
        f"request tier: queues={args.queues} (+1 slot-pool stack shard) "
        f"arrived={tier.stats['arrived']} admitted={tier.stats['admitted']} "
        f"rejected={tier.stats['rejected']} splits={tier.stats['splits']} "
        f"backlog={tier.backlog()}"
    )
    p = tier.persistence_stats()
    if p:
        print(f"pwb/op: {p['pwb_per_op']:.2f}  pfence/op: {p['pfence_per_op']:.2f}")


if __name__ == "__main__":
    main()
