import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, WITHOUT allocating any real tensors
(ShapeDtypeStruct inputs only):

  * proof that the sharded step function compiles for the production mesh
    (16×16 single pod and 2×16×16 multi-pod),
  * compiled.memory_analysis()  — per-device bytes (does it fit a v5e?),
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * a collective census parsed from the post-SPMD HLO text — bytes per
    all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
    for the collective roofline term.

Results are dumped as JSON under experiments/dryrun/ and consumed by
benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import re
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, input_specs, supports
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (
    batch_pspecs,
    cache_pspecs,
    opt_pspecs,
    param_pspecs,
    to_named,
)
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models.model import abstract_params, init_cache
from repro.optim.adamw import AdamWConfig, init_opt_state

from jax.sharding import PartitionSpec as P

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\bf64|\bf32|\bbf16|\bf16|\bs32|\bu32|\bs8|\bu8|\bpred|\bs64|\bu64)\[([0-9,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4, "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1}


_COLL_RE = re.compile(
    r"=\s*(?P<shapes>.*?)\s*(?P<op>all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(?P<suffix>-start|-done)?\("
)


def _parse_collectives(hlo_text: str):
    """Sum *result* bytes per collective kind from post-partition HLO.

    The result shape(s) sit between '=' and the op name; async '-done' ops
    are skipped so start/done pairs are counted once."""
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or m.group("suffix") == "-done":
            continue
        kind = m.group("op")
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(m.group("shapes")):
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            nbytes += n * _BYTES[dt]
        out[kind]["count"] += 1
        out[kind]["bytes"] += nbytes
    return out


def _maybe(d, *names):
    for n in names:
        if d and n in d:
            return d[n]
    return None


def run_cell(arch: str, shape_name: str, mesh_kind: str, cfg_overrides=None):
    cfg = get_config(arch)
    act_axes = ("pod", "data") if mesh_kind == "multi" else ("data",)
    cfg = dataclasses.replace(cfg, act_sharding=act_axes, **(cfg_overrides or {}))
    if arch == "arctic-480b":
        opt_cfg = AdamWConfig(state_dtype="bfloat16")
    else:
        opt_cfg = AdamWConfig()
    sh = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size

    specs = input_specs(cfg, shape_name)
    aparams = abstract_params(cfg)
    pspec = param_pspecs(aparams, cfg, mesh)
    param_sh = to_named(pspec, mesh)
    batch_sh = to_named(batch_pspecs(specs["batch"], mesh), mesh)

    t0 = time.time()
    if sh.kind == "train":
        aopt = jax.eval_shape(lambda: init_opt_state(aparams, opt_cfg))
        opt_sh = to_named(opt_pspecs(aopt, pspec), mesh)
        step = make_train_step(cfg, opt_cfg)
        jitted = jax.jit(
            step,
            in_shardings=(param_sh, opt_sh, batch_sh),
            out_shardings=(param_sh, opt_sh, to_named(P(), mesh)),
            donate_argnums=(0, 1),
        )
        with mesh:
            lowered = jitted.lower(aparams, aopt, specs["batch"])
    elif sh.kind == "prefill":
        step = make_prefill_step(cfg, max_len=sh.seq_len)
        cache_abs = jax.eval_shape(
            lambda: init_cache(cfg, sh.global_batch, sh.seq_len)
        )
        cache_sh = to_named(cache_pspecs(cache_abs, cfg, mesh, sh.global_batch), mesh)
        jitted = jax.jit(
            step,
            in_shardings=(param_sh, batch_sh),
            out_shardings=(to_named(P(), mesh), cache_sh),
        )
        with mesh:
            lowered = jitted.lower(aparams, specs["batch"])
    else:  # decode
        step = make_serve_step(cfg, window=sh.window)
        cache_sh = to_named(
            cache_pspecs(specs["cache"], cfg, mesh, sh.global_batch), mesh
        )
        jitted = jax.jit(
            step,
            in_shardings=(param_sh, cache_sh, batch_sh),
            out_shardings=(to_named(P(), mesh), cache_sh),
            donate_argnums=(1,),
        )
        with mesh:
            lowered = jitted.lower(aparams, specs["cache"], specs["batch"])

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = _parse_collectives(hlo)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "n_devices": int(n_dev),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": _maybe(cost, "flops"),
        "bytes_accessed": _maybe(cost, "bytes accessed", "bytes accessed0{}"),
        "transcendentals": _maybe(cost, "transcendentals"),
        "cost_analysis_keys": sorted(cost.keys())[:40] if cost else [],
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "collectives": coll,
        "params": get_config(arch).param_count(),
        "active_params": get_config(arch).active_param_count(),
    }
    return result


def run_bodies(arch: str, shape_name: str, mesh_kind: str):
    """Per-body probes (scan-trip correction) — see launch/probe.py."""
    from repro.launch.probe import probe_bodies

    cfg = get_config(arch)
    act_axes = ("pod", "data") if mesh_kind == "multi" else ("data",)
    cfg = dataclasses.replace(cfg, act_sharding=act_axes)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    aparams = abstract_params(cfg)
    return probe_bodies(cfg, shape_name, mesh, aparams, _parse_collectives)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--bodies", action="store_true", help="run per-body probes instead of full modules")
    ap.add_argument("--tuned", action="store_true", help="apply launch.tuned perf levers")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    if args.tuned and args.out == "experiments/dryrun":
        args.out = "experiments/dryrun_tuned"

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            if not supports(arch, shape):
                print(f"SKIP  {arch} × {shape} (documented: full attention at 500k)")
                continue
            for mesh_kind in meshes:
                tag = f"{arch}_{shape}_{mesh_kind}"
                path = outdir / (f"{tag}.bodies.json" if args.bodies else f"{tag}.json")
                if path.exists():
                    print(f"CACHED {tag}")
                    continue
                print(f"RUN   {tag} ...", flush=True)
                try:
                    overrides = None
                    if args.tuned:
                        from repro.launch.tuned import TUNED

                        overrides = TUNED.get(arch, {})
                    if args.bodies:
                        res = run_bodies(arch, shape, mesh_kind)
                        path.write_text(json.dumps(res, indent=2))
                        print("  ok (bodies)", flush=True)
                        continue
                    res = run_cell(arch, shape, mesh_kind, cfg_overrides=overrides)
                    path.write_text(json.dumps(res, indent=2))
                    print(
                        f"  ok: compile {res['compile_s']}s flops/dev {res['flops']:.3e} "
                        f"colls {sum(c['count'] for c in res['collectives'].values())}"
                        if res["flops"]
                        else f"  ok: compile {res['compile_s']}s",
                        flush=True,
                    )
                except Exception as e:  # noqa: BLE001 - report and continue
                    failures.append((tag, repr(e)[:300]))
                    print(f"  FAIL {tag}: {repr(e)[:300]}", flush=True)
    if failures:
        print("\nFAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err}")
        raise SystemExit(1)
    print("\nall dry-run cells passed")


if __name__ == "__main__":
    main()
