"""Per-body cost probes — correcting XLA's scan-once cost accounting.

``compiled.cost_analysis()`` counts a ``lax.scan``'s (while-loop) body ONCE,
regardless of trip count (verified empirically; see EXPERIMENTS.md §Dry-run).
The dry-run therefore compiles each *distinct block body* separately, with
the same shardings and mesh as the full module, and reports

    corrected_X = module_X + Σ_bodies (trips_b - 1) · body_X

for X ∈ {flops, bytes, per-collective bytes}.  For training cells both the
forward body and its VJP (with remat recompute) are probed, matching the
fwd/bwd while-loops of the real module.  Prefill/decode bodies carry their
KV/SSM cache slices so cache-dominated attention costs are captured.
"""

from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import SHAPES
from repro.launch.sharding import _param_rule, to_named
from repro.models import model as M
from repro.models.config import ModelConfig

SDS = jax.ShapeDtypeStruct


def _slice_lead(tree, n_lead: int):
    def f(leaf):
        return SDS(leaf.shape[n_lead:], leaf.dtype)
    return jax.tree.map(f, tree)


def _param_sh(tree, cfg, mesh):
    d = ("pod", "data") if "pod" in mesh.axis_names else "data"

    def rule(path, leaf):
        keys = tuple(k.key if hasattr(k, "key") else str(k) for k in path)
        return _param_rule(keys, leaf.ndim, cfg, d)

    return to_named(jax.tree_util.tree_map_with_path(rule, tree), mesh)


def _cost_of(compiled, parse_collectives):
    cost = compiled.cost_analysis() or {}
    return {
        "flops": cost.get("flops", 0.0),
        "bytes": cost.get("bytes accessed", 0.0),
        "collectives": parse_collectives(compiled.as_text()),
    }


class BodyProber:
    def __init__(self, cfg: ModelConfig, shape_name: str, mesh: Mesh, aparams, parse_collectives):
        self.cfg = cfg
        self.sh = SHAPES[shape_name]
        self.mesh = mesh
        self.aparams = aparams
        self.parse = parse_collectives
        self.kind = self.sh.kind
        self.b = self.sh.global_batch
        self.s = 1 if self.kind == "decode" else self.sh.seq_len
        self.dt = cfg.act_dtype()
        d = ("pod", "data") if "pod" in mesh.axis_names else "data"
        self.dspec = d
        n_data = mesh.shape["data"] * mesh.shape.get("pod", 1)
        self.bspec = d if (self.b % n_data == 0 and self.b > 1) else None
        self.h_sh = NamedSharding(mesh, P(self.bspec, None, None))

    # ---------------------------------------------------------------- pieces
    def h_spec(self):
        return SDS((self.b, self.s, self.cfg.d_model), self.dt)

    def kv_cache_piece(self):
        cfg, sh = self.cfg, self.sh
        wlen = sh.window or sh.seq_len
        kshape = (self.b, wlen, cfg.n_kv_heads, cfg.hd())
        spec = P(self.bspec, "model", None, None)
        return (
            (SDS(kshape, self.dt), SDS(kshape, self.dt)),
            (NamedSharding(self.mesh, spec), NamedSharding(self.mesh, spec)),
        )

    def ssm_cache_piece(self):
        cfg = self.cfg
        di, n = cfg.d_inner(), cfg.ssm_state
        if cfg.ssm_version == 2:
            nh, hp = di // cfg.ssm_head_dim, cfg.ssm_head_dim
            sshape = (self.b, nh, hp, n)
            sspec = P(self.bspec, "model", None, None)
            conv_c = di + 2 * n
        else:
            sshape = (self.b, di, n)
            sspec = P(self.bspec, "model", None)
            conv_c = di
        cshape = (self.b, cfg.d_conv - 1, conv_c)
        cspec = P(self.bspec, None, "model")
        return (
            (SDS(sshape, jnp.float32), SDS(cshape, self.dt)),
            (NamedSharding(self.mesh, sspec), NamedSharding(self.mesh, cspec)),
        )

    # ----------------------------------------------------------------- probe
    def _run(self, fn, specs, shardings, vjp):
        out: Dict[str, Any] = {}
        jf = jax.jit(fn, in_shardings=shardings)
        with self.mesh:
            out["fwd"] = _cost_of(jf.lower(*specs).compile(), self.parse)
        if vjp:
            def bwd_fn(*args):
                y, pullback = jax.vjp(fn, *args)
                ct = jax.tree.map(lambda t: jnp.ones(t.shape, t.dtype), y)
                return pullback(ct)

            jb = jax.jit(bwd_fn, in_shardings=shardings)
            with self.mesh:
                out["bwd"] = _cost_of(jb.lower(*specs).compile(), self.parse)
        return out

    def _attn_body(self, bp_abs, trips, name):
        cfg, kind = self.cfg, self.kind
        is_train = kind == "train"
        ring = kind == "decode" and self.sh.window > 0
        window = self.sh.window

        if kind == "train":
            def body(h, bp):
                out, _, _ = M._self_block(h, bp, cfg, jnp.arange(h.shape[1]))
                return M._shard_act(out, cfg)

            body = M._remat(cfg, body)
            return dict(
                name=name, trips=trips,
                **self._run(body, (self.h_spec(), bp_abs), (self.h_sh, _param_sh(bp_abs, cfg, self.mesh)), True),
            )
        (kv_specs, kv_sh) = self.kv_cache_piece()
        length = self.sh.seq_len - 1 if kind == "decode" else 0

        def body(h, bp, k_l, v_l):
            out, _, _ = M._self_block(
                h, bp, cfg,
                jnp.full((1,), length, jnp.int32) if kind == "decode" else jnp.arange(h.shape[1]),
                cache=(k_l, v_l, jnp.asarray(length, jnp.int32)),
                window=window, ring=ring,
            )
            return M._shard_act(out, cfg)

        return dict(
            name=name, trips=trips,
            **self._run(
                body,
                (self.h_spec(), bp_abs, *kv_specs),
                (self.h_sh, _param_sh(bp_abs, cfg, self.mesh), *kv_sh),
                False,
            ),
        )

    def _mamba_body(self, bp_abs, trips, name):
        cfg, kind = self.cfg, self.kind
        if kind == "train":
            def body(h, bp):
                out, _ = M._mamba_layer(h, bp, cfg)
                return M._shard_act(out, cfg)

            body = M._remat(cfg, body)
            return dict(
                name=name, trips=trips,
                **self._run(body, (self.h_spec(), bp_abs), (self.h_sh, _param_sh(bp_abs, cfg, self.mesh)), True),
            )
        (st_specs, st_sh) = self.ssm_cache_piece()

        def body(h, bp, s_l, c_l):
            out, _ = M._mamba_layer(h, bp, cfg, state=(s_l, c_l) if kind == "decode" else None)
            return M._shard_act(out, cfg)

        return dict(
            name=name, trips=trips,
            **self._run(
                body,
                (self.h_spec(), bp_abs, *st_specs),
                (self.h_sh, _param_sh(bp_abs, cfg, self.mesh), *st_sh),
                False,
            ),
        )

    def _cross_body(self, bp_abs, trips):
        cfg = self.cfg
        img_spec = SDS((self.b, cfg.n_img_tokens, cfg.d_model), self.dt)
        is_train = self.kind == "train"

        def body(h, bp, img):
            return M._shard_act(
                M._cross_block(h, bp, cfg, jnp.arange(h.shape[1]), img), cfg
            )

        if is_train:
            body = M._remat(cfg, body)
        return dict(
            name="cross_block", trips=trips,
            **self._run(
                body,
                (self.h_spec(), bp_abs, img_spec),
                (self.h_sh, _param_sh(bp_abs, cfg, self.mesh), self.h_sh),
                is_train,
            ),
        )

    # ------------------------------------------------------------------ main
    def probe(self) -> List[Dict[str, Any]]:
        cfg, p = self.cfg, self.aparams
        fam = cfg.family
        if fam in ("dense", "moe", "audio"):
            return [self._attn_body(_slice_lead(p["blocks"], 1), cfg.n_layers, "self_block")]
        if fam == "ssm":
            return [self._mamba_body(_slice_lead(p["blocks"], 1), cfg.n_layers, "mamba1_layer")]
        if fam == "hybrid":
            out = [
                self._mamba_body(_slice_lead(p["mamba_groups"], 2), cfg.n_layers, "mamba2_layer"),
                self._attn_body(p["shared_attn"], cfg.n_layers // cfg.attn_every, "shared_attn"),
            ]
            return out
        if fam == "vlm":
            g = cfg.n_layers // cfg.cross_attn_every
            per = cfg.cross_attn_every - 1
            out = [
                self._attn_body(_slice_lead(p["self_blocks"], 2), g * per, "self_block"),
            ]
            # decode-path cross block uses precomputed image KV; approximate
            # with the full cross block for train/prefill, skip the tiny
            # decode cross-attn correction (image KV already cached)
            if self.kind != "decode":
                out.append(self._cross_body(_slice_lead(p["cross_blocks"], 1), g))
            return out
        raise ValueError(fam)


def probe_bodies(cfg, shape_name, mesh, aparams, parse_collectives):
    return BodyProber(cfg, shape_name, mesh, aparams, parse_collectives).probe()
