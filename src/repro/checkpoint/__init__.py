from repro.checkpoint.dfc_checkpoint import (
    CrashNow,
    DFCCheckpointManager,
    FaultInjector,
    SimFS,
)

__all__ = ["DFCCheckpointManager", "SimFS", "FaultInjector", "CrashNow"]
