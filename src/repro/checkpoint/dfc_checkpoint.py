"""DFC-Checkpoint: the paper's detectable flat-combining persistence protocol
as a distributed checkpoint manager.

This is the hardware adaptation of DFC's core insight (DESIGN.md §4): at
datacenter scale the expensive persistence instruction is the device→host
fetch + durable file write (`pwb` analogue) and the fsync barrier (`pfence`
analogue).  DFC's structure transfers verbatim:

  tAnn  -> per-worker double-buffered announcement records (announce/ann{0,1}
           + a `valid` selector), written and fsynced by workers in parallel
  cEpoch-> an epoch file committed with the TWO-INCREMENT protocol: persist
           v+1, publish v+2 without persisting — recovery rounds odd -> even
  top[2]-> two alternating checkpoint slots; a combining phase writes ONLY
           the inactive slot; the epoch parity selects the active one
  Reduce-> elimination: K workers' announcements are combined into ONE slot
           persist (the newest state subsumes all K requests) — persistence
           cost per announcement drops as 1/K, the paper's Figure-3 effect
  GC    -> recovery rebuilds the slot-file index from the active manifest and
           deletes unreachable tensor files (volatile bitmap analogue)

Detectability: Recover() reports, for every worker, whether its announced
step committed and at which epoch — training resumes exactly-once (no step
replayed into the optimizer twice, none lost).

Durability is simulated through SimFS: writes are buffered in memory and hit
the real filesystem only at fsync; a crash drops unsynced buffers (or flushes
an adversarial subset), exactly like the NVM cache model in repro.nvm.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import numpy as np

import jax

from repro.nvm.memory import PersistStats
from repro.obs import NULL_OBS


class CrashNow(Exception):
    """Raised by FaultInjector at the scheduled persistence op."""


@dataclasses.dataclass
class FaultInjector:
    """Crash at the k-th persistence operation (pwb or pfence)."""

    crash_at: Optional[int] = None
    count: int = 0

    def tick(self):
        self.count += 1
        if self.crash_at is not None and self.count >= self.crash_at:
            raise CrashNow(f"injected crash at persistence op {self.count}")


class SimFS:
    """Buffered filesystem: content reaches disk only at fsync (pwb=write,
    pfence=fsync).  Crash drops unsynced buffers.

    Persistence ops carry an optional attribution ``tag`` (announce, slot,
    resp, epoch, routing, ...) counted into ``pstats`` — a
    :class:`PersistStats` partitioning the legacy ``stats`` totals by
    protocol step.  An observer (``repro.obs.FabricObserver``) may be
    attached via ``obs``; its hooks run strictly AFTER the counters, the
    fault-injector tick, and the durable work, so tracing can never perturb
    counts, crash points, or on-disk bytes.
    """

    def __init__(self, root: Path, injector: Optional[FaultInjector] = None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.pending: Dict[str, bytes] = {}
        self.injector = injector or FaultInjector()
        self.stats = {"pwb": 0, "pfence": 0}
        self.pstats = PersistStats()
        self.obs = NULL_OBS

    def _p(self, rel: str) -> Path:
        return self.root / rel

    def write(self, rel: str, data: bytes, tag: Optional[str] = None) -> None:
        """pwb: buffered write — NOT durable until fsync."""
        self.stats["pwb"] += 1
        self.pstats.count_pwb(tag)
        self.injector.tick()
        self.pending[rel] = data
        self.obs.on_pwb(rel, tag)

    def fsync(self, rels: Optional[List[str]] = None, tag: Optional[str] = None) -> None:
        """pfence: flush pending writes to the real filesystem."""
        self.stats["pfence"] += 1
        self.pstats.count_pfence(tag)
        self.injector.tick()
        items = (
            list(self.pending.items())
            if rels is None
            else [(r, self.pending[r]) for r in rels if r in self.pending]
        )
        for rel, data in items:
            p = self._p(rel)
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_bytes(data)
            self.pending.pop(rel, None)
        self.obs.on_pfence(rels, tag)

    def read(self, rel: str) -> Optional[bytes]:
        """Reads see the buffered (volatile) view, like a CPU cache."""
        if rel in self.pending:
            return self.pending[rel]
        p = self._p(rel)
        return p.read_bytes() if p.exists() else None

    def read_durable(self, rel: str) -> Optional[bytes]:
        p = self._p(rel)
        return p.read_bytes() if p.exists() else None

    def exists(self, rel: str) -> bool:
        return rel in self.pending or self._p(rel).exists()

    def listdir(self, rel: str) -> List[str]:
        p = self._p(rel)
        disk = [f"{rel}/{x}" for x in os.listdir(p)] if p.exists() else []
        buf = [k for k in self.pending if k.startswith(rel + "/")]
        return sorted(set(disk) | set(buf))

    def delete(self, rel: str) -> None:
        self.pending.pop(rel, None)
        p = self._p(rel)
        if p.exists():
            p.unlink()

    def crash(self) -> "SimFS":
        """Lose all unsynced writes; return a fresh post-crash view."""
        fs = SimFS(self.root, FaultInjector())
        return fs


BOT = None


class DFCCheckpointManager:
    """Detectable flat-combining checkpoint manager (one per job).

    Workers call ``announce(worker, payload)``; the coordinator calls
    ``combine(state)`` which persists one combined checkpoint for every ready
    announcement and publishes it with the two-increment epoch commit.
    ``recover()`` fixes the epoch, garbage-collects the slot pool, re-commits
    pending announcements (using the caller-provided state getter), and
    returns each worker's detectability verdict.
    """

    def __init__(self, fs: SimFS, n_workers: int, prefix: str = ""):
        """``prefix`` roots every durable path of this manager under a
        subdirectory of ``fs`` — multiple managers (e.g. a sharded fabric
        plus its reshard donor-snapshot log) can then share ONE SimFS, so
        fault injection sweeps tick through every manager's persistence ops.
        """
        self.fs = fs
        self.n = n_workers
        self.prefix = prefix if (not prefix or prefix.endswith("/")) else prefix + "/"

    def _rel(self, rel: str) -> str:
        return self.prefix + rel

    # ------------------------------------------------------------- epoch I/O
    def _read_epoch(self) -> int:
        raw = self.fs.read(self._rel("cEpoch"))
        return int(raw.decode()) if raw else 0

    def _write_epoch(self, v: int, sync: bool) -> None:
        self.fs.write(self._rel("cEpoch"), str(v).encode())
        if sync:
            self.fs.fsync([self._rel("cEpoch")])

    # ---------------------------------------------------------- announcements
    def _ann_path(self, w: int, slot: int) -> str:
        return self._rel(f"tAnn/worker_{w}/ann{slot}.json")

    def _valid_path(self, w: int) -> str:
        return self._rel(f"tAnn/worker_{w}/valid")

    def _read_valid(self, w: int) -> int:
        raw = self.fs.read(self._valid_path(w))
        return int(raw.decode()) if raw else 0

    def _read_ann(self, w: int, slot: int) -> Dict[str, Any]:
        raw = self.fs.read(self._ann_path(w, slot))
        return json.loads(raw.decode()) if raw else {"val": BOT, "epoch": -1}

    def announce(self, worker: int, payload: Dict[str, Any]) -> None:
        """Worker-side announcement (paper lines 2-12), parallel pwb/pfence."""
        epoch = self._read_epoch()
        if epoch % 2 == 1:
            epoch += 1
        valid = self._read_valid(worker)
        n_op = 1 - (valid & 1)
        ann = dict(payload, val=BOT, epoch=epoch)
        self.fs.write(self._ann_path(worker, n_op), json.dumps(ann).encode())
        self.fs.fsync([self._ann_path(worker, n_op)])  # L9
        self.fs.write(self._valid_path(worker), str(n_op).encode())
        self.fs.fsync([self._valid_path(worker)])  # L11
        self.fs.write(self._valid_path(worker), str(2 | n_op).encode())  # L12 MSB

    def ready_announcements(self) -> List[int]:
        out = []
        for w in range(self.n):
            v = self._read_valid(w)
            if (v >> 1) & 1:
                ann = self._read_ann(w, v & 1)
                if ann.get("val") is BOT and ann.get("step") is not None:
                    out.append(w)
        return out

    # ---------------------------------------------------------------- combine
    def _slot_dir(self, epoch: int, nxt: bool) -> str:
        idx = (epoch // 2 + (1 if nxt else 0)) % 2
        return self._rel(f"top/slot{idx}")

    def combine(self, state_tree, extra_meta: Optional[Dict] = None) -> List[int]:
        """One combining phase: persist `state_tree` into the inactive slot
        for ALL ready announcements (elimination: K requests -> 1 persist),
        set responses, two-increment commit.  Returns combined workers."""
        epoch = self._read_epoch()
        assert epoch % 2 == 0, "combine under an uncommitted epoch"
        ready = self.ready_announcements()
        if not ready:
            return []

        slot = self._slot_dir(epoch, nxt=True)
        leaves, treedef = jax.tree_util.tree_flatten(state_tree)
        manifest = {"leaves": [], "epoch": epoch + 2, "meta": extra_meta or {}}
        files = []
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            rel = f"{slot}/leaf_{i}.npy"
            import io

            buf = io.BytesIO()
            np.save(buf, arr)
            self.fs.write(rel, buf.getvalue())  # pwb per tensor
            files.append(rel)
            manifest["leaves"].append({"file": f"leaf_{i}.npy", "shape": list(arr.shape), "dtype": str(arr.dtype)})
        self.fs.write(f"{slot}/manifest.json", json.dumps(manifest).encode())
        files.append(f"{slot}/manifest.json")

        # responses into the combined announcements (paper L92/L61: epoch+val)
        for w in ready:
            v = self._read_valid(w)
            ann = self._read_ann(w, v & 1)
            ann["epoch"] = epoch
            ann["val"] = "ACK"
            self.fs.write(self._ann_path(w, v & 1), json.dumps(ann).encode())
            files.append(self._ann_path(w, v & 1))

        # single pfence for slot + responses (paper L80)
        self.fs.fsync(files)
        # two-increment epoch commit (paper L81-83)
        self._write_epoch(epoch + 1, sync=True)
        self._write_epoch(epoch + 2, sync=False)
        return ready

    # ---------------------------------------------------------------- recover
    def recover(self, state_getter: Optional[Callable[[], Any]] = None):
        """Recovery combiner (paper lines 26-43) + detectability report.

        Returns (restored_state_leaves_or_None, report) where report[w] =
        {"committed": bool, "step": int|None} for each worker's latest
        announcement."""
        fs = self.fs
        epoch = self._read_epoch()
        if epoch % 2 == 1:  # L28-30
            epoch += 1
            self._write_epoch(epoch, sync=True)

        # garbage-collect the slot pool (paper §4): keep only files reachable
        # from the ACTIVE slot's manifest
        active = self._slot_dir(epoch, nxt=False)
        inactive = self._slot_dir(epoch, nxt=True)
        man_raw = fs.read_durable(f"{active}/manifest.json")
        live = set()
        if man_raw:
            man = json.loads(man_raw.decode())
            live = {f"{active}/{e['file']}" for e in man["leaves"]}
            live.add(f"{active}/manifest.json")
        for rel in list(fs.listdir(active)) + list(fs.listdir(inactive)):
            if rel not in live:
                fs.delete(rel)

        # announcements scan (L32-38)
        pending = []
        for w in range(self.n):
            v = self._read_valid(w)
            lsb = v & 1
            if (v >> 1) & 1 == 0:
                fs.write(self._valid_path(w), str(2 | lsb).encode())  # L36
            ann = self._read_ann(w, lsb)
            if ann.get("epoch") == epoch and ann.get("val") is not BOT:
                ann["val"] = BOT  # L38: re-commit ops of the crashed phase
                fs.write(self._ann_path(w, lsb), json.dumps(ann).encode())
            if ann.get("val") is BOT and ann.get("step") is not None:
                pending.append(w)

        # restore the active state
        state = None
        if man_raw:
            man = json.loads(man_raw.decode())
            state = [
                np.load(io_bytes(fs.read_durable(f"{active}/{e['file']}")))
                for e in man["leaves"]
            ]

        # recovery combine (L39).  Divergence from the stack (documented in
        # DESIGN.md §4): a stack announcement is self-contained, so the paper
        # re-executes it; a checkpoint announcement's payload (device state)
        # died with the crash.  If the runtime can still produce the state
        # (coordinator-only failure), roll FORWARD by re-combining; otherwise
        # write the definite negative verdict LOST — the worker re-runs from
        # the committed slot (exactly-once at the training-step level).
        if pending:
            if state_getter is not None:
                self.combine(state_getter())
            else:
                files = []
                for w in pending:
                    v = self._read_valid(w)
                    ann = self._read_ann(w, v & 1)
                    ann["val"] = "LOST"
                    fs.write(self._ann_path(w, v & 1), json.dumps(ann).encode())
                    files.append(self._ann_path(w, v & 1))
                fs.fsync(files)

        report = {}
        for w in range(self.n):
            v = self._read_valid(w)
            ann = self._read_ann(w, v & 1)
            report[w] = {
                "committed": ann.get("val") == "ACK" and ann.get("step") is not None,
                "step": ann.get("step"),
            }
        return state, report

    def load_active(self):
        """Read the committed checkpoint (leaves list + manifest meta)."""
        epoch = self._read_epoch()
        if epoch % 2 == 1:
            epoch += 1
        active = self._slot_dir(epoch, nxt=False)
        man_raw = self.fs.read_durable(f"{active}/manifest.json")
        if not man_raw:
            return None, None
        man = json.loads(man_raw.decode())
        leaves = [
            np.load(io_bytes(self.fs.read_durable(f"{active}/{e['file']}")))
            for e in man["leaves"]
        ]
        return leaves, man

    # ------------------------------------------------- DFC structure states
    # The manager's combine() persists any pytree; these wrappers add the
    # structure-aware layer for the array-backed DFC states of
    # ``repro.core.jax_dfc``: the buffer is persisted ALONGSIDE its
    # double-buffered root counters — ``size[2]`` for the stack,
    # ``ends[2, 2]`` = (head, tail) / (left, right) for the ring-backed queue
    # and deque — under the same two-increment epoch commit, and the manifest
    # records the kind so ``load_structure`` can rebuild the typed state.
    def combine_structure(self, state, extra_meta: Optional[Dict] = None) -> List[int]:
        """Persist a StackState / QueueState / DequeState / MapState for every
        ready announcement (same elimination + two-increment commit as
        combine)."""
        from repro.core.jax_dfc import struct_kind

        kind = struct_kind(state)
        meta = dict(extra_meta or {})
        meta["struct"] = kind
        meta["struct_epoch"] = int(state.epoch)
        if kind == "stack":
            meta["committed_size"] = int(state.active_size())
        elif kind == "map":
            meta["committed_count"] = int(state.active_count())
        else:
            ends = state.active_ends()
            meta["committed_ends"] = [int(ends[0]), int(ends[1])]
        return self.combine(state, extra_meta=meta)

    def load_structure(self):
        """Rebuild the committed structure state (typed) from the active
        slot.  Returns (state, manifest) or (None, None)."""
        from repro.core.jax_dfc import STRUCTS

        import jax.numpy as jnp

        leaves, man = self.load_active()
        if leaves is None:
            return None, None
        kind = man["meta"].get("struct")
        if kind is None:
            raise ValueError("active checkpoint was not written by combine_structure")
        fresh = STRUCTS[kind].init(1)
        treedef = jax.tree_util.tree_structure(fresh)
        return (
            jax.tree_util.tree_unflatten(
                treedef, [jnp.asarray(leaf) for leaf in leaves]
            ),
            man,
        )


def io_bytes(data: bytes):
    import io

    return io.BytesIO(data)
