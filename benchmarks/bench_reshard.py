"""Dynamic resharding: pwb/op and throughput before / during / after.

The reshard transaction (``split_shard`` / ``merge_shards``) buys routing
balance with a burst of persistence work: a donor snapshot (via
``combine_structure``), an intent record, the rewritten shard slots (merge),
a routing-slot write, and two two-increment epoch commits.  This bench
quantifies the trade under Zipf load on a durable fabric:

  * BEFORE: skewed traffic concentrates on the hot shard — good pwb/op
    (few touched shards per phase) but overflow grows with skew;
  * DURING: one window that contains a split of the hottest shard (and, in
    the full grid, a later merge of the two coldest) — pwb/op spikes by the
    transaction cost;
  * AFTER: the hot key range is spread over donor + new shard — overflow
    drops, touched-shards/phase (and so pwb/op) rises slightly: the paper's
    Figure-3 amortization traded against balance.

Emits ``name,value,derived`` rows via ``emit`` and (as a script) writes the
window-level result set to ``BENCH_reshard.json``.  ``--smoke`` runs a
seconds-scale subset on CPU jax — wired into CI so resharding cannot rot.
"""

from __future__ import annotations

import argparse
import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.checkpoint.dfc_checkpoint import SimFS
from repro.runtime.dfc_shard import R_OVERFLOW, ShardedDFCRuntime, zipf_keys

_ROOT = Path(__file__).resolve().parent.parent  # repo root, CWD-independent


def _window(rt, fs, rng, batch, phases, token0):
    """Drive ``phases`` durable announce+combine rounds; return metrics."""
    pwb0, pf0 = fs.stats["pwb"], fs.stats["pfence"]
    snap0 = fs.pstats.snapshot()
    applied = overflow = 0
    t0 = time.perf_counter()
    for i in range(phases):
        keys = zipf_keys(rng, batch, 4096, 1.2)
        ops = rng.integers(1, 3, batch)
        params = rng.random(batch).astype(np.float32)
        rt.announce(0, keys, ops, params, token=token0 + i)
        rt.combine_phase()
        kinds = np.asarray(rt.read_responses(0)["kinds"])
        applied += int(np.sum(kinds != R_OVERFLOW))
        overflow += int(np.sum(kinds == R_OVERFLOW))
    dt = time.perf_counter() - t0
    return {
        "ops_per_s": applied / dt,
        "pwb_per_op": (fs.stats["pwb"] - pwb0) / max(applied, 1),
        "pfence_per_op": (fs.stats["pfence"] - pf0) / max(applied, 1),
        "persist": fs.pstats.diff(snap0).as_dict(),  # this window's tags only
        "overflow": overflow,
        "n_shards": rt.n_shards,
    }


def _one_config(n_shards, batch, phases, do_merge, results, emit):
    rng = np.random.default_rng(0)
    lanes = batch // 2  # tight lanes so the hot shard visibly overflows
    capacity = batch * (3 * phases + 2)
    root = Path(tempfile.mkdtemp(prefix="dfc_bench_reshard_"))
    try:
        fs = SimFS(root)
        rt = ShardedDFCRuntime(
            "queue", n_shards, capacity, lanes, fs=fs, n_threads=1,
            n_buckets=8 * n_shards,
        )
        windows = {}
        windows["before"] = _window(rt, fs, rng, batch, phases, 1)

        pwb0 = fs.stats["pwb"]
        hot = int(np.argmax(np.asarray(rt.meta["ops_combined"])))
        rt.split_shard(hot)
        if do_merge:
            sizes = rt.shard_sizes()
            cold = np.argsort(sizes)[:2]
            rt.merge_shards(int(cold[1]), int(cold[0]))
        reshard_pwb = fs.stats["pwb"] - pwb0
        windows["during"] = _window(rt, fs, rng, batch, phases, phases + 1)
        windows["during"]["reshard_pwb"] = reshard_pwb
        windows["after"] = _window(rt, fs, rng, batch, phases, 2 * phases + 1)

        for w, m in windows.items():
            name = f"reshard_s{n_shards}{'_merge' if do_merge else ''}_{w}"
            emit(
                name,
                f"{m['ops_per_s']:.0f}",
                f"ops/s,pwb/op={m['pwb_per_op']:.2f},overflow={m['overflow']}",
            )
            results.append(
                dict(m, window=w, base_shards=n_shards, merge=do_merge, batch=batch)
            )
    finally:
        shutil.rmtree(root, ignore_errors=True)


def run(emit, smoke: bool = False):
    results = []
    grid = [(4, False)] if smoke else [(4, False), (4, True), (16, False), (16, True)]
    batch, phases = (64, 4) if smoke else (256, 10)
    for n_shards, do_merge in grid:
        _one_config(n_shards, batch, phases, do_merge, results, emit)
    return results


def main(emit, smoke: bool = True):
    """Benchmark-harness entry point (smoke-sized by default: run.py and CI
    both call this; the full grid is `python bench_reshard.py` without
    --smoke)."""
    return run(emit, smoke=smoke)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="seconds-scale CI subset")
    ap.add_argument("--out", default=str(_ROOT / "BENCH_reshard.json"), help="JSON results path (defaults to the repo root)")
    args = ap.parse_args()
    rows = run(lambda n, v, d="": print(f"{n},{v},{d}", flush=True), smoke=args.smoke)
    try:
        from benchmarks.bench_common import write_rows
    except ImportError:
        from bench_common import write_rows
    write_rows(args.out, rows, extra={"entry": "script", "smoke": args.smoke})
    print(f"# wrote {args.out} ({len(rows)} configs)")
