"""Paper Figures 3b/3c/3e/3f: persistence instructions per operation.

DFC counts come from the real simulated algorithm under the cooperative
scheduler; Romulus/OneFile/PMDK from their schedule-faithful baselines.
DFC (combiner-only) and DFC-TOTAL (incl. parallel announce path) are
reported separately, as in the paper.
"""

from __future__ import annotations

from repro.core.baselines import (
    OneFileStack,
    PMDKStack,
    RomulusStack,
    make_workloads,
    run_dfc_counts,
)

THREADS = (1, 2, 4, 8, 16, 24, 32, 40)


def measure(kind: str, total_ops: int = 800):
    rows = []
    for n in THREADS:
        w = make_workloads(kind, n, total_ops)
        dfc = run_dfc_counts(n, w, seed=7, think=(0, 30))
        ops = dfc["ops"]
        rom = RomulusStack(n).run(make_workloads(kind, n, total_ops))
        one = OneFileStack(n).run(make_workloads(kind, n, total_ops))
        pmdk = PMDKStack(n).run(make_workloads(kind, n, total_ops))
        rows.append(
            dict(
                threads=n,
                workload=kind,
                dfc_pwb=dfc["pwb_combine"] / ops,
                dfc_total_pwb=(dfc["pwb_combine"] + dfc["pwb_announce"]) / ops,
                dfc_pfence=dfc["pfence_combine"] / ops,
                dfc_total_pfence=(dfc["pfence_combine"] + dfc["pfence_announce"]) / ops,
                romulus_pwb=rom.pwb_per_op(),
                romulus_pfence=rom.pfence_per_op(),
                onefile_pwb=one.pwb_per_op(),
                onefile_pfence=one.cas / max(one.ops, 1),  # CAS = pfence proxy
                pmdk_pwb=pmdk.pwb_per_op(),
                pmdk_pfence=pmdk.pfence_per_op(),
                phases_per_op=dfc["phases"] / ops,
                elim_frac=2 * dfc["eliminated_pairs"] / max(dfc["combined_ops"], 1),
            )
        )
    return rows


def main(emit):
    for kind in ("push-pop", "rand-op"):
        for r in measure(kind):
            emit(
                f"fig3_pwb_{kind}_t{r['threads']}",
                r["dfc_total_pwb"],
                f"dfc={r['dfc_pwb']:.2f},rom={r['romulus_pwb']:.2f},one={r['onefile_pwb']:.2f},pmdk={r['pmdk_pwb']:.2f}",
            )
            emit(
                f"fig3_pfence_{kind}_t{r['threads']}",
                r["dfc_total_pfence"],
                f"dfc={r['dfc_pfence']:.3f},rom={r['romulus_pfence']:.3f},one={r['onefile_pfence']:.2f},pmdk={r['pmdk_pfence']:.2f}",
            )


if __name__ == "__main__":
    main(lambda n, v, d: print(f"{n},{v},{d}"))
